//! Image-generation experiments (paper §6.1).
//!
//!   --fig2    theta sweep on latent16 (StableDiffusion stand-in), K=1000
//!   --fig4    theta sweep on pixel64 (LSUN pixel-model stand-in)
//!   --table1  CLIP-proxy alignment, DDPM vs ASD-theta (latent16)
//!   --table2  FID-proxy, DDPM vs ASD-theta (pixel64)
//!   --fig3    paired samples DDPM vs ASD-inf, shared seeds (CSV)
//!
//! Defaults run a reduced-n version of everything; see EXPERIMENTS.md
//! for the recorded full runs.
//!
//! Run: cargo run --release --example image_generation -- [--table1 ...]

use std::sync::Arc;

use asd::exp::latency::default_latency_model;
use asd::exp::quality::{format_quality_table, make_class_conds, sample_asd,
                        sample_ddpm, score};
use asd::exp::{format_rows, sweep_thetas};
use asd::model::DenoiseModel;
use asd::runtime::Runtime;
use asd::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&["fig2", "fig4", "table1", "table2", "fig3"]);
    let all = !(args.flag("fig2") || args.flag("fig4") || args.flag("table1")
        || args.flag("table2") || args.flag("fig3"));
    let rt = Runtime::load_default()?;

    if all || args.flag("fig2") {
        fig_speedup(&rt, "latent16", "Fig 2 — speedup on latent diffusion",
                    &args)?;
    }
    if all || args.flag("fig4") {
        fig_speedup(&rt, "pixel64", "Fig 4 — speedup on pixel diffusion",
                    &args)?;
    }
    if all || args.flag("table1") {
        table_quality(&rt, "latent16", "Table 1 — CLIP-proxy (higher=better)",
                      &args)?;
    }
    if all || args.flag("table2") {
        table_quality(&rt, "pixel64", "Table 2 — FID-proxy (lower=better)",
                      &args)?;
    }
    if all || args.flag("fig3") {
        fig3_pairs(&rt, &args)?;
    }
    Ok(())
}

fn fig_speedup(rt: &Runtime, variant: &str, title: &str, args: &Args)
               -> anyhow::Result<()> {
    let n = args.get_usize("n", 6)?;
    let model = rt.model(variant)?;
    model.warmup()?;
    let k = model.info.k_steps;
    let dyn_model: Arc<dyn DenoiseModel> = model.clone();

    // measured sequential wall-clock (per sample)
    let seq = asd::ddpm::SequentialSampler::new(dyn_model.clone());
    let t0 = std::time::Instant::now();
    let reps = 2.min(n);
    for s in 0..reps {
        let cond = vec![0.0; model.info.cond_dim];
        seq.sample(s as u64, &cond)?;
    }
    let seq_wall = t0.elapsed().as_secs_f64() / reps as f64;

    let latency = default_latency_model(&model, 8)?;
    let conds: Option<Vec<Vec<f64>>> = if model.info.cond_dim > 0 {
        Some(make_class_conds(&dyn_model, n).0)
    } else {
        None
    };
    let thetas = args.get_usize_list("thetas", &[2, 4, 6, 8, 0])?;
    let rows = sweep_thetas(dyn_model, &thetas, n, seq_wall, 500,
                            conds.as_deref(), &latency)?;
    println!("\n=== {title} (K={k}, n={n}) ===");
    println!("measured sequential wall: {:.1} ms/sample", seq_wall * 1e3);
    print!("{}", format_rows(k, &rows));
    Ok(())
}

fn table_quality(rt: &Runtime, variant: &str, title: &str, args: &Args)
                 -> anyhow::Result<()> {
    let n = args.get_usize("n", 64)?;
    let model = rt.model(variant)?;
    model.warmup()?;
    let dyn_model: Arc<dyn DenoiseModel> = model.clone();
    let target = model.info.target.clone();
    let (conds, classes) = make_class_conds(&dyn_model, n);
    let conditional = model.info.cond_dim > 0;

    let mut rows = Vec::new();
    let ddpm = sample_ddpm(&dyn_model, n, 42, &conds)?;
    rows.push(score(&target, ddpm,
                    conditional.then_some(classes.as_slice()), "DDPM", 9));
    for theta in args.get_usize_list("thetas", &[2, 4, 8, 0])? {
        let label = if theta == 0 { "ASD-inf".into() }
                    else { format!("ASD-{theta}") };
        let samples = sample_asd(&dyn_model, theta, n, 42, &conds)?;
        rows.push(score(&target, samples,
                        conditional.then_some(classes.as_slice()), &label, 9));
    }
    println!("\n=== {title} (n={n}) ===");
    print!("{}", format_quality_table(
        &rows, if conditional { "align (CLIP~)" } else { "-" }));
    Ok(())
}

fn fig3_pairs(rt: &Runtime, args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("n", 4)?;
    let model = rt.model("latent16")?;
    let dyn_model: Arc<dyn DenoiseModel> = model.clone();
    let (conds, classes) = make_class_conds(&dyn_model, n);
    let ddpm = sample_ddpm(&dyn_model, n, 7, &conds)?;
    let asd = sample_asd(&dyn_model, 0, n, 7, &conds)?;
    println!("\n=== Fig 3 — paired samples (shared seeds), CSV ===");
    println!("class,method,{}",
             (0..model.info.d).map(|i| format!("x{i}"))
                 .collect::<Vec<_>>().join(","));
    for i in 0..n {
        for (m, s) in [("DDPM", &ddpm[i]), ("ASD-inf", &asd[i])] {
            println!("{},{m},{}", classes[i],
                     s.iter().map(|v| format!("{v:.4}"))
                         .collect::<Vec<_>>().join(","));
        }
    }
    Ok(())
}
