//! Robot-control experiments (paper §6.2): diffusion policies on the
//! three simulated manipulation tasks.
//!
//!   --fig5    speedup of ASD vs DDPM per task (batched, single device)
//!   --table3  success rates, DDPM vs ASD-theta (seeds x repeats)
//!
//! Run: cargo run --release --example robot_control -- [--seeds 20]

use std::sync::Arc;

use asd::env::{rollout_policy, DiffusionPolicy, SamplerKind, TaskSpec};
use asd::math::stats::Welford;
use asd::model::DenoiseModel;
use asd::runtime::Runtime;
use asd::util::cli::Args;

const TASKS: [&str; 3] = ["square", "transport", "toolhang"];

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&["fig5", "table3"]);
    let all = !(args.flag("fig5") || args.flag("table3"));
    let rt = Runtime::load_default()?;

    if all || args.flag("fig5") {
        fig5(&rt, &args)?;
    }
    if all || args.flag("table3") {
        table3(&rt, &args)?;
    }
    Ok(())
}

fn policy_for(rt: &Runtime, task: &str) -> anyhow::Result<DiffusionPolicy> {
    let model = rt.model(&format!("policy_{task}"))?;
    model.warmup()?;
    let dyn_model: Arc<dyn DenoiseModel> = model;
    DiffusionPolicy::new(dyn_model, TaskSpec::by_name(task).unwrap())
}

fn fig5(rt: &Runtime, args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("episodes", 3)?;
    let thetas = args.get_usize_list("thetas", &[8, 12, 16, 20, 24, 0])?;
    println!("\n=== Fig 5 — diffusion-policy speedup (K=100, batched \
              single-device verification) ===");
    for task in TASKS {
        let policy = policy_for(rt, task)?;
        // sequential baseline
        let mut seq_rounds = Welford::default();
        let mut seq_wall = Welford::default();
        for s in 0..n {
            let r = rollout_policy(&policy, SamplerKind::Sequential, s as u64)?;
            seq_rounds.push(r.parallel_rounds as f64 / r.plans.max(1) as f64);
            seq_wall.push(r.wallclock_s / r.plans.max(1) as f64);
        }
        println!("\n[{task}] sequential: {:.0} rounds/plan, {:.1} ms/plan",
                 seq_rounds.mean(), seq_wall.mean() * 1e3);
        println!("{:<10} {:>12} {:>14} {:>12}", "method", "alg speedup",
                 "wall x (1dev)", "rounds/plan");
        for &theta in &thetas {
            let mut rounds = Welford::default();
            let mut wall = Welford::default();
            for s in 0..n {
                let r = rollout_policy(&policy, SamplerKind::Asd(theta),
                                       s as u64)?;
                rounds.push(r.parallel_rounds as f64 / r.plans.max(1) as f64);
                wall.push(r.wallclock_s / r.plans.max(1) as f64);
            }
            let label = if theta == 0 { "ASD-inf".into() }
                        else { format!("ASD-{theta}") };
            println!("{:<10} {:>12.2} {:>14.2} {:>12.1}", label,
                     seq_rounds.mean() / rounds.mean(),
                     seq_wall.mean() / wall.mean(), rounds.mean());
        }
    }
    Ok(())
}

fn table3(rt: &Runtime, args: &Args) -> anyhow::Result<()> {
    let seeds = args.get_usize("seeds", 20)?;
    let repeats = args.get_usize("repeats", 2)?;
    let thetas = args.get_usize_list("thetas", &[8, 16, 24, 0])?;
    println!("\n=== Table 3 — success rates ({seeds} seeds x {repeats} \
              repeats; mean +- SEM %) ===");
    let mut header = format!("{:<11} {:>13}", "env", "DDPM");
    for &t in &thetas {
        let label = if t == 0 { "ASD-inf".into() } else { format!("ASD-{t}") };
        header.push_str(&format!(" {label:>13}"));
    }
    println!("{header}");

    for task in TASKS {
        let policy = policy_for(rt, task)?;
        let mut row = format!("{task:<11}");
        let mut samplers = vec![SamplerKind::Sequential];
        samplers.extend(thetas.iter().map(|&t| SamplerKind::Asd(t)));
        for sampler in samplers {
            let mut reps = Welford::default();
            for rep in 0..repeats {
                let mut ok = 0usize;
                for s in 0..seeds {
                    let seed = (rep * 10_000 + s) as u64;
                    ok += rollout_policy(&policy, sampler, seed)?.success
                        as usize;
                }
                reps.push(100.0 * ok as f64 / seeds as f64);
            }
            row.push_str(&format!(" {:>6.1}+-{:<5.1}", reps.mean(),
                                  reps.sem()));
        }
        println!("{row}");
    }
    Ok(())
}
