//! End-to-end serving driver (the repository's system validation run):
//! load real AOT-compiled models, run the coordinator with a mixed
//! concurrent workload, and report latency/throughput — recorded in
//! EXPERIMENTS.md §End-to-end.
//!
//! Run: cargo run --release --example serve -- [--requests 64]

use std::time::Instant;

use asd::coordinator::{Coordinator, Request, SamplerSpec, ServerConfig};
use asd::runtime::Runtime;
use asd::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let n_requests = args.get_usize("requests", 64)?;
    let workers = args.get_usize("workers", 2)?;
    let theta = args.get_usize("theta", 8)?;

    let pool_size = args.get_usize("pool", 1)?;

    let rt = Runtime::load_default()?;
    let coordinator = Coordinator::new(ServerConfig {
        workers,
        max_batch: 8,
        enable_batching: true,
        pool: asd::runtime::pool::PoolConfig {
            pool_size,
            ..Default::default()
        },
        ..Default::default()
    })?;
    // serve two real models side by side
    for variant in ["gmm2d", "latent16"] {
        let m = rt.model(variant)?;
        m.warmup()?;
        coordinator.register_model(variant, m);
    }

    println!("mixed workload: {n_requests} requests over 2 models, \
              {workers} workers, dynamic batching on");
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for i in 0..n_requests {
        let variant = if i % 3 == 0 { "latent16" } else { "gmm2d" };
        let sampler = if i % 2 == 0 {
            SamplerSpec::Asd(theta)
        } else {
            SamplerSpec::Sequential
        };
        let cond = if variant == "latent16" {
            let mut c = vec![0.0; 10];
            c[i % 10] = 1.0;
            c
        } else {
            vec![]
        };
        let (_, rx) = coordinator.submit(Request {
            id: 0,
            variant: variant.into(),
            sampler,
            seed: 7_000 + i as u64,
            cond,
            deadline: None,
        });
        pending.push((variant, sampler, rx));
    }

    let mut failures = 0usize;
    let mut asd_rounds = 0usize;
    let mut asd_count = 0usize;
    let mut seq_rounds = 0usize;
    let mut seq_count = 0usize;
    for (_, sampler, rx) in pending {
        let r = rx.recv()?;
        if r.error.is_some() {
            failures += 1;
            continue;
        }
        match sampler {
            SamplerSpec::Asd(_) => {
                asd_rounds += r.parallel_rounds;
                asd_count += 1;
            }
            _ => {
                seq_rounds += r.parallel_rounds;
                seq_count += 1;
            }
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let m = coordinator.metrics();
    println!("\n--- results ---");
    println!("throughput:       {:.1} requests/s ({n_requests} in {elapsed:.2}s)",
             n_requests as f64 / elapsed);
    println!("mean latency:     {:.1} ms service + {:.1} ms queue",
             m.mean_service_ms, m.mean_queue_wait_ms);
    println!("dynamic batching: {} requests fused into {} groups \
              ({:.1} rows/fused round)",
             m.batched_requests, m.batched_groups, m.fused_rows_per_round);
    if asd_count > 0 && seq_count > 0 {
        println!(
            "rounds/request:   ASD {:.1} vs sequential {:.1} ({:.2}x fewer)",
            asd_rounds as f64 / asd_count as f64,
            seq_rounds as f64 / seq_count as f64,
            seq_rounds as f64 / seq_count as f64
                / (asd_rounds as f64 / asd_count as f64)
        );
    }
    println!("failures:         {failures}");
    coordinator.shutdown();
    Ok(())
}
