//! Theorem 4 validation: the number of parallel rounds of ASD on the SL
//! process scales as O(K^{2/3} (beta d eta)^{1/3}).
//!
//! Uses the analytic GMM oracle m(t, y) (zero network error) so the
//! measured scaling reflects the algorithm alone. We sweep K at fixed
//! total SL time (so eta ~ 1/K) with theta = theta*(K) ~ (K/(beta d
//! eta))^{1/3} as the theorem prescribes, and fit the log-log slope of
//! rounds vs K. Prediction: with eta ~ T/K, rounds ~ K^{2/3} (T beta
//! d / K)^{1/3} ~ K^{1/3} — slope 1/3 in this parametrization.
//!
//! Run: cargo run --release --example scaling_law -- [--samples 5]

use asd::asd::SlAsd;
use asd::model::{Gmm, GmmSlOracle};
use asd::schedule::SlGrid;
use asd::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let samples = args.get_usize("samples", 5)?;
    let t_max = args.get_f64("t-max", 200.0)?;

    println!("=== Theorem 4 — parallel rounds vs K (SL, analytic GMM) ===");
    println!("total SL time T={t_max}; eta = T/K; theta = (K^2 / (beta d \
              T))^(1/3)\n");

    for (label, gmm) in [
        ("d=2 (circle GMM)", Gmm::circle_2d()),
        ("d=8 (2 modes)", two_mode_gmm(8)),
        ("d=32 (2 modes)", two_mode_gmm(32)),
    ] {
        let oracle = GmmSlOracle { gmm };
        let d = oracle.gmm.d;
        println!("--- {label} ---");
        println!("{:>6} {:>8} {:>10} {:>12} {:>12}", "K", "theta", "rounds",
                 "rounds/K", "K^(1/3) fit");
        let mut pts = Vec::new();
        for k in [128usize, 256, 512, 1024, 2048] {
            let eta = t_max / k as f64;
            // Thm 4: theta ~ (K / (beta d eta))^{1/3}, beta ~ sigma^2+mu^2 ~ O(1)
            let theta = ((k as f64 / (d as f64 * eta)).powf(1.0 / 3.0))
                .ceil().max(2.0) as usize;
            let grid = SlGrid::uniform(t_max, k);
            let asd = SlAsd { oracle: &oracle, grid: &grid, theta };
            let mut rounds = 0usize;
            for s in 0..samples {
                let (_, stats) = asd.sample(s as u64);
                rounds += stats.parallel_rounds;
            }
            let mean_rounds = rounds as f64 / samples as f64;
            pts.push((k as f64, mean_rounds));
            println!("{:>6} {:>8} {:>10.1} {:>12.3} {:>12.2}", k, theta,
                     mean_rounds, mean_rounds / k as f64,
                     mean_rounds / (k as f64).powf(1.0 / 3.0));
        }
        let slope = loglog_slope(&pts);
        println!("log-log slope(rounds vs K) = {slope:.3}  \
                  (Thm 4 prediction ~0.33, sequential would be 1.0)\n");
    }
    Ok(())
}

fn two_mode_gmm(d: usize) -> Gmm {
    let mut m1 = vec![0.0; d];
    let mut m2 = vec![0.0; d];
    m1[0] = 1.0;
    m2[0] = -1.0;
    m1[d - 1] = 0.5;
    m2[d - 1] = -0.5;
    Gmm::new(vec![m1, m2], vec![0.3, 0.3], vec![0.5, 0.5])
}

fn loglog_slope(pts: &[(f64, f64)]) -> f64 {
    let n = pts.len() as f64;
    let (mut sx, mut sy, mut sxx, mut sxy) = (0.0, 0.0, 0.0, 0.0);
    for &(x, y) in pts {
        let (lx, ly) = (x.ln(), y.ln());
        sx += lx;
        sy += ly;
        sxx += lx * lx;
        sxy += lx * ly;
    }
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}
