//! Quickstart: load a model from the AOT artifacts, sample with
//! sequential DDPM and with ASD, and verify the headline claims on a
//! small target — error-free output, fewer parallel rounds.
//!
//! Run: cargo run --release --example quickstart

use asd::asd::{AsdConfig, AsdEngine, KernelBackend};
use asd::ddpm::SequentialSampler;
use asd::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // 1. The runtime loads artifacts/manifest.json and talks PJRT.
    let rt = Runtime::load_default()?;
    let model = rt.model("gmm2d")?;
    let k = model.info.k_steps;
    println!("loaded gmm2d: d={} K={k}", model.info.d);

    // 2. Baseline: sequential ancestral sampling (K model calls).
    let seq = SequentialSampler::new(model.clone());
    let (y_seq, st) = seq.sample(7, &[])?;
    println!("\nsequential DDPM: {} model calls -> y = [{:+.3}, {:+.3}]",
             st.model_calls, y_seq[0], y_seq[1]);

    // 3. ASD: same distribution, far fewer parallel rounds.
    let mut engine = AsdEngine::new(
        model.clone(),
        AsdConfig {
            theta: 8,
            eval_tail: true,
            backend: KernelBackend::Native,
            ..Default::default()
        },
    );
    let out = engine.sample(7)?;
    println!(
        "ASD-8:           {} parallel rounds ({} calls) -> y = [{:+.3}, {:+.3}]",
        out.stats.parallel_rounds, out.stats.model_calls,
        out.y0[0], out.y0[1]
    );
    println!("algorithmic speedup: {:.2}x, acceptance rate {:.3}",
             out.stats.algorithmic_speedup(k), out.stats.acceptance_rate());

    // 4. Error-free check: both estimators hit the target's radius.
    let n = 200;
    let mut r_seq = 0.0;
    let mut r_asd = 0.0;
    for seed in 0..n {
        r_seq += norm2(&seq.sample(seed, &[])?.0);
        r_asd += norm2(&engine.sample(10_000 + seed)?.y0);
    }
    println!(
        "\nmean radius over {n} samples: sequential {:.3}, ASD {:.3} \
         (target 1.500)",
        r_seq / n as f64, r_asd / n as f64
    );

    // 5. Lemma 13 in action: the first speculated step never rejects.
    let out = engine.sample(99)?;
    assert!(out.stats.accepted >= out.stats.iterations);
    println!(
        "Lemma 13 invariant held: {} accepts >= {} iterations",
        out.stats.accepted, out.stats.iterations
    );
    Ok(())
}

fn norm2(v: &[f64]) -> f64 {
    (v[0] * v[0] + v[1] * v[1]).sqrt()
}
