//! # asd — Autospeculative Decoding for DDPMs
//!
//! Production-quality reproduction of *"Diffusion Models are Secretly
//! Exchangeable: Parallelizing DDPMs via Autospeculation"* (ICML 2025):
//! error-free parallel DDPM inference with a guaranteed `O(K^{1/3})`
//! parallel speedup, plus every substrate its evaluation depends on.
//!
//! Three-layer architecture (see DESIGN.md):
//! * **L3 (this crate)** — request-path coordinator: the ASD engine
//!   (Algorithms 1–3), sequential & Picard baselines, serving stack
//!   (router / variant lanes / worker pool), simulated robot
//!   environments, quality metrics, CLI.
//! * **L2 (python/compile)** — JAX denoiser models, AOT-lowered once to
//!   HLO text artifacts.
//! * **L1 (python/compile/kernels)** — Pallas kernels (fused linear,
//!   speculation prefix scan, Gaussian rejection sampler).
//!
//! Python never runs on the request path: [`runtime`] loads the
//! artifacts through PJRT and executes them natively.

pub mod asd;
pub mod coordinator;
pub mod ddpm;
pub mod env;
pub mod exp;
pub mod faults;
pub mod math;
pub mod model;
pub mod picard;
pub mod quality;
pub mod rng;
pub mod runtime;
pub mod sampler;
pub mod schedule;
pub mod util;

/// Convenient re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::asd::{AsdConfig, AsdEngine, AsdOutput, AsdStats};
    pub use crate::coordinator::{Coordinator, FailReason, Request,
                                 ServerConfig};
    pub use crate::faults::{ChaosModel, FaultPlan};
    pub use crate::ddpm::SequentialSampler;
    pub use crate::model::{DenoiseModel, Manifest};
    pub use crate::rng::Philox;
    pub use crate::runtime::Runtime;
    pub use crate::sampler::{ArenaSpan, DenoiseDemand, RoundArena,
                             RoundExec, SamplerPoll, StepSampler};
    pub use crate::schedule::DdpmSchedule;
}

/// Locate the artifacts directory: `$ASD_ARTIFACTS` or `./artifacts`
/// relative to the repo root (walking up from the current directory).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("ASD_ARTIFACTS") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}
