//! Sequential DDPM ancestral sampling — the K-model-call baseline that
//! ASD accelerates (and the ground truth its output law must match).

use std::sync::Arc;

use anyhow::Result;

use crate::math::vec_ops::lincomb_into;
use crate::model::{DenoiseModel, ParallelModel};
use crate::rng::Philox;
use crate::runtime::pool::PoolConfig;
use crate::sampler::{ArenaSpan, DenoiseDemand, RoundArena, RoundExec,
                     SamplerPoll, StepSampler};

/// Per-request noise streams (the "randomness contract"): `xi[j]` and
/// `u[j]` are consumed by the transition to index j (0-based row of the
/// schedule arrays), identically across sequential / Picard / ASD.
#[derive(Clone)]
pub struct NoiseStreams {
    pub y_k: Vec<f64>,
    /// K*d row-major; row j drives transition (j+1) -> j
    pub xi: Vec<f64>,
    /// K uniforms; u[j] seeds the GRS for transition (j+1) -> j
    pub u: Vec<f64>,
}

impl NoiseStreams {
    pub fn draw(seed: u64, stream: u64, k: usize, d: usize) -> NoiseStreams {
        let mut rng = Philox::new(seed, stream);
        let y_k = (0..d).map(|_| rng.normal()).collect();
        let xi = (0..k * d).map(|_| rng.normal()).collect();
        let u = (0..k).map(|_| rng.uniform()).collect();
        NoiseStreams { y_k, xi, u }
    }

    pub fn xi_row(&self, j: usize, d: usize) -> &[f64] {
        &self.xi[j * d..(j + 1) * d]
    }
}

/// Sequential ancestral sampler — a thin driver over
/// [`SequentialStepMachine`].
pub struct SequentialSampler {
    pub model: Arc<dyn DenoiseModel>,
}

#[derive(Debug, Clone, Default)]
pub struct SeqStats {
    pub model_calls: usize,
}

impl SequentialSampler {
    pub fn new(model: Arc<dyn DenoiseModel>) -> SequentialSampler {
        SequentialSampler { model }
    }

    /// Sample with explicit noise streams; `cond` is empty when the
    /// model is unconditional. Returns (y_0, stats). Clones the streams
    /// for the machine; `sample` hands its own over without a copy.
    pub fn sample_with_noise(&self, noise: &NoiseStreams, cond: &[f64])
                             -> Result<(Vec<f64>, SeqStats)> {
        self.sample_owned_noise(noise.clone(), cond)
    }

    pub fn sample(&self, seed: u64, cond: &[f64]) -> Result<(Vec<f64>, SeqStats)> {
        let noise = NoiseStreams::draw(seed, 0, self.model.k_steps(),
                                       self.model.dim());
        self.sample_owned_noise(noise, cond)
    }

    fn sample_owned_noise(&self, noise: NoiseStreams, cond: &[f64])
                          -> Result<(Vec<f64>, SeqStats)> {
        let mut machine = SequentialStepMachine::new(
            self.model.clone(), noise, cond)?;
        let y = crate::sampler::drive(&mut machine, &self.model,
                                      PoolConfig::default())?;
        Ok((y, machine.into_stats()))
    }
}

/// Sequential ancestral sampling as a poll/resume state machine: one
/// single-row demand per DDPM step. Bit-identical to the closed loop it
/// replaced — the transition applies `lincomb_into` then adds
/// `sigma * xi`, in that order, exactly as before.
pub struct SequentialStepMachine {
    model: Arc<dyn DenoiseModel>,
    noise: NoiseStreams,
    cond: Vec<f64>,
    y: Vec<f64>,
    next: Vec<f64>,
    /// staged demand timestep (len 1)
    ts: Vec<f64>,
    /// current DDPM index; the next demand evaluates x0hat at (y, i_cur)
    i_cur: usize,
    stats: SeqStats,
}

impl SequentialStepMachine {
    pub fn new(model: Arc<dyn DenoiseModel>, noise: NoiseStreams,
               cond: &[f64]) -> Result<SequentialStepMachine> {
        anyhow::ensure!(cond.len() == model.cond_dim(),
                        "conditioning length {} != cond_dim {}",
                        cond.len(), model.cond_dim());
        let k = model.k_steps();
        Ok(SequentialStepMachine {
            y: noise.y_k.clone(),
            next: vec![0.0; model.dim()],
            ts: vec![k as f64],
            i_cur: k,
            cond: cond.to_vec(),
            model,
            noise,
            stats: SeqStats::default(),
        })
    }

    pub fn stats(&self) -> &SeqStats {
        &self.stats
    }

    pub fn into_stats(self) -> SeqStats {
        self.stats
    }
}

impl StepSampler for SequentialStepMachine {
    fn poll(&mut self) -> Result<SamplerPoll<'_>> {
        if self.i_cur == 0 {
            return Ok(SamplerPoll::Done(&self.y));
        }
        Ok(SamplerPoll::Demand(DenoiseDemand {
            ys: &self.y,
            ts: &self.ts,
            cond: &self.cond,
            n: 1,
        }))
    }

    /// Arena path: write the one demanded row straight into the arena
    /// (the single copy any executor needs — there is no intermediate
    /// staging or mega-batch pack behind it).
    fn poll_into(&mut self, arena: &mut RoundArena)
                 -> Result<Option<ArenaSpan>> {
        if self.i_cur == 0 {
            return Ok(None);
        }
        let (span, rows) = arena.reserve(1);
        rows.ys.copy_from_slice(&self.y);
        rows.ts[0] = self.ts[0];
        rows.cond.copy_from_slice(&self.cond);
        Ok(Some(span))
    }

    fn resume(&mut self, x0: &[f64], _exec: RoundExec) -> Result<()> {
        let d = self.model.dim();
        anyhow::ensure!(self.i_cur > 0, "resume after Done");
        anyhow::ensure!(x0.len() == d, "resume row length {} != d {d}",
                        x0.len());
        self.stats.model_calls += 1;
        let s = self.model.schedule();
        let j = self.i_cur - 1;
        lincomb_into(&mut self.next, s.c1[j], x0, s.c2[j], &self.y);
        if s.sigma[j] > 0.0 {
            let xi = self.noise.xi_row(j, d);
            for idx in 0..d {
                self.next[idx] += s.sigma[j] * xi[idx];
            }
        }
        std::mem::swap(&mut self.y, &mut self.next);
        self.i_cur -= 1;
        self.ts[0] = self.i_cur as f64;
        Ok(())
    }
}

/// Lockstep-batched sequential sampler: n chains advance together, one
/// batched model call per step. (The serving coordinator now fuses
/// arbitrary sampler mixes through `StepSampler` machines instead; this
/// stays as the direct API for bulk baseline sampling and the benches.)
pub struct BatchedSequentialSampler {
    pub model: Arc<dyn DenoiseModel>,
}

impl BatchedSequentialSampler {
    pub fn new(model: Arc<dyn DenoiseModel>) -> BatchedSequentialSampler {
        BatchedSequentialSampler { model }
    }

    /// Lockstep sampler whose per-step batched call is sharded over the
    /// global worker pool (bit-transparent; see runtime::pool).
    pub fn with_pool(model: Arc<dyn DenoiseModel>, pool: PoolConfig)
                     -> BatchedSequentialSampler {
        BatchedSequentialSampler { model: ParallelModel::wrap(model, pool) }
    }

    /// `conds` is n*cond_dim row-major. Returns n*d row-major samples.
    pub fn sample_batch(&self, seeds: &[u64], conds: &[f64])
                        -> Result<(Vec<f64>, SeqStats)> {
        let n = seeds.len();
        let d = self.model.dim();
        let k = self.model.k_steps();
        let model = self.model.clone();
        let s = model.schedule(); // borrow, not clone (hot path)
        let noises: Vec<NoiseStreams> = seeds.iter()
            .map(|&sd| NoiseStreams::draw(sd, 0, k, d))
            .collect();
        let mut ys: Vec<f64> = noises.iter().flat_map(|ns| ns.y_k.clone()).collect();
        let mut x0 = vec![0.0; n * d];
        let mut ts = vec![0.0; n];
        let mut stats = SeqStats::default();
        for i in (1..=k).rev() {
            ts.iter_mut().for_each(|t| *t = i as f64);
            self.model.denoise_batch(&ys, &ts, conds, n, &mut x0)?;
            stats.model_calls += 1; // one *parallel* call
            let j = i - 1;
            for r in 0..n {
                let xi = noises[r].xi_row(j, d);
                for idx in 0..d {
                    let o = r * d + idx;
                    ys[o] = s.c1[j] * x0[o] + s.c2[j] * ys[o]
                        + if s.sigma[j] > 0.0 { s.sigma[j] * xi[idx] } else { 0.0 };
                }
            }
        }
        Ok((ys, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Gmm, GmmDdpmOracle};

    #[test]
    fn sequential_hits_gmm_modes() {
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 60, false);
        let sampler = SequentialSampler::new(oracle);
        let mut r_sum = 0.0;
        let n = 60;
        for seed in 0..n {
            let (y0, st) = sampler.sample(seed, &[]).unwrap();
            assert_eq!(st.model_calls, 60);
            r_sum += (y0[0] * y0[0] + y0[1] * y0[1]).sqrt();
        }
        let r_mean = r_sum / n as f64;
        assert!((r_mean - 1.5).abs() < 0.15, "mean radius {r_mean}");
    }

    #[test]
    fn batched_matches_individual() {
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 30, false);
        let seq = SequentialSampler::new(oracle.clone());
        let bat = BatchedSequentialSampler::new(oracle);
        let seeds = [5u64, 6, 7];
        let (batch, st) = bat.sample_batch(&seeds, &[]).unwrap();
        assert_eq!(st.model_calls, 30);
        for (r, &seed) in seeds.iter().enumerate() {
            let (one, _) = seq.sample(seed, &[]).unwrap();
            for i in 0..2 {
                assert!((batch[r * 2 + i] - one[i]).abs() < 1e-9,
                        "row {r} dim {i}");
            }
        }
    }

    #[test]
    fn pooled_batched_matches_inline_bitwise() {
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 30, false);
        let inline = BatchedSequentialSampler::new(oracle.clone());
        let pooled = BatchedSequentialSampler::with_pool(
            oracle, PoolConfig { pool_size: 4, shard_min: 1 });
        let seeds = [1u64, 2, 3, 4, 5]; // odd n on purpose
        let (a, _) = inline.sample_batch(&seeds, &[]).unwrap();
        let (b, _) = pooled.sample_batch(&seeds, &[]).unwrap();
        let bits = |v: &[f64]| -> Vec<u64> {
            v.iter().map(|x| x.to_bits()).collect()
        };
        assert_eq!(bits(&a), bits(&b));
    }

    #[test]
    fn step_machine_demands_descending_steps_and_matches_sampler() {
        use crate::sampler::{RoundExec, SamplerPoll, StepSampler};
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 20, false);
        let noise = NoiseStreams::draw(4, 0, 20, 2);
        // drive the machine by hand, checking the demand protocol
        let mut m = SequentialStepMachine::new(oracle.clone(),
                                               noise.clone(), &[]).unwrap();
        let mut expect_t = 20.0;
        let mut x0 = vec![0.0; 2];
        loop {
            let (ys, t) = match m.poll().unwrap() {
                SamplerPoll::Done(_) => break,
                SamplerPoll::Demand(dem) => {
                    assert_eq!(dem.n, 1);
                    assert_eq!(dem.ts[0], expect_t);
                    (dem.ys.to_vec(), dem.ts[0])
                }
            };
            oracle.denoise_one(&ys, t as usize, &[], &mut x0).unwrap();
            m.resume(&x0, RoundExec::inline()).unwrap();
            expect_t -= 1.0;
        }
        assert_eq!(expect_t, 0.0);
        assert_eq!(m.stats().model_calls, 20);
        // hand-driven result is bit-identical to the sampler entry point
        let machine_y = match m.poll().unwrap() {
            SamplerPoll::Done(y) => y.to_vec(),
            _ => unreachable!(),
        };
        let sampler = SequentialSampler::new(oracle);
        let (want, _) = sampler.sample_with_noise(&noise, &[]).unwrap();
        assert_eq!(crate::math::vec_ops::to_bits_vec(&machine_y),
                   crate::math::vec_ops::to_bits_vec(&want));
    }

    #[test]
    fn noise_streams_deterministic() {
        let a = NoiseStreams::draw(1, 2, 10, 3);
        let b = NoiseStreams::draw(1, 2, 10, 3);
        assert_eq!(a.y_k, b.y_k);
        assert_eq!(a.xi, b.xi);
        assert_eq!(a.u, b.u);
        let c = NoiseStreams::draw(1, 3, 10, 3);
        assert_ne!(a.xi, c.xi);
    }
}
