//! PJRT runtime: loads the AOT HLO artifacts and executes them on the
//! request path (python is never involved here).
//!
//! Threading model: one **device thread** owns the `PjRtClient`, every
//! compiled executable and the device-resident weight buffers (PJRT
//! handles are not `Send`); the rest of the system talks to it through a
//! cloneable [`DeviceHandle`] (mpsc). This mirrors a GPU dispatch queue
//! and centralizes the per-call latency measurements that feed the
//! multi-worker wall-clock model (DESIGN.md §3).

pub mod device;
pub mod hlo_model;
pub mod host;
pub mod kernels;
pub mod pool;

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;

pub use device::{DeviceHandle, DeviceStats, ExeId, WeightsId};
pub use hlo_model::HloModel;
pub use host::HostArray;
pub use kernels::HloKernels;
pub use pool::{PoolConfig, ThreadPool};

use crate::model::Manifest;

/// Top-level runtime: manifest + device thread + model cache.
pub struct Runtime {
    pub manifest: Manifest,
    pub device: DeviceHandle,
    artifacts_dir: PathBuf,
}

impl Runtime {
    pub fn new(manifest: Manifest) -> Result<Runtime> {
        let dir = manifest.dir.clone();
        let device = DeviceHandle::spawn()?;
        Ok(Runtime { manifest, device, artifacts_dir: dir })
    }

    /// Load the manifest from the default artifacts directory.
    pub fn load_default() -> Result<Runtime> {
        Runtime::new(Manifest::load_default()?)
    }

    /// Build the HLO-backed model for a variant (compiles its denoise
    /// artifacts lazily; uploads weights once).
    pub fn model(&self, variant: &str) -> Result<Arc<HloModel>> {
        let info = self.manifest.variant(variant)?.clone();
        HloModel::load(&self.device, info, &self.artifacts_dir)
    }

    /// Load the HLO speculate/verify kernels for dimension `d`.
    pub fn kernels(&self, d: usize) -> Result<HloKernels> {
        HloKernels::load(&self.device, &self.manifest, d)
    }

    /// Snapshot of per-executable timing stats.
    pub fn device_stats(&self) -> DeviceStats {
        self.device.stats()
    }
}
