//! HLO-backed denoiser: the production `DenoiseModel` implementation.
//!
//! One compiled executable per (variant, batch-size); weights uploaded
//! once as device-resident buffers. Batches are padded up to the nearest
//! compiled size and chunked above the maximum (a chunked verify round
//! still counts as ONE parallel round — the chunks model the paper's
//! per-GPU shards; see DESIGN.md §3).

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::model::{DenoiseModel, VariantInfo};
use crate::runtime::device::{DeviceHandle, ExeId, WeightsId};
use crate::runtime::host::HostArray;
use crate::schedule::DdpmSchedule;

pub struct HloModel {
    pub info: VariantInfo,
    device: DeviceHandle,
    weights: WeightsId,
    /// compiled executables per batch size (lazy)
    exes: Mutex<BTreeMap<usize, ExeId>>,
    artifacts_dir: std::path::PathBuf,
    schedule: DdpmSchedule,
}

impl HloModel {
    pub fn load(device: &DeviceHandle, info: VariantInfo, dir: &Path)
                -> Result<Arc<HloModel>> {
        // read + upload weights once
        let path = dir.join(&info.weights_file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let flat: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let expected: usize = info.weights_layout.iter()
            .map(|&(a, b)| a * b + b).sum();
        if flat.len() != expected {
            bail!("weights file for {} has {} floats, expected {expected}",
                  info.name, flat.len());
        }
        let mut arrays = Vec::new();
        let mut off = 0usize;
        for &(n_in, n_out) in &info.weights_layout {
            arrays.push(HostArray::new(vec![n_in, n_out],
                                       flat[off..off + n_in * n_out].to_vec())?);
            off += n_in * n_out;
            arrays.push(HostArray::new(vec![n_out],
                                       flat[off..off + n_out].to_vec())?);
            off += n_out;
        }
        if off != flat.len() {
            bail!("weights length mismatch for {}", info.name);
        }
        let weights = device.upload_weights(arrays)?;
        let schedule = info.schedule();
        Ok(Arc::new(HloModel {
            info,
            device: device.clone(),
            weights,
            exes: Mutex::new(BTreeMap::new()),
            artifacts_dir: dir.to_path_buf(),
            schedule,
        }))
    }

    fn exe_for_batch(&self, b: usize) -> Result<ExeId> {
        if let Some(&id) = self.exes.lock().unwrap().get(&b) {
            return Ok(id);
        }
        let fname = self.info.artifacts.get(&b).with_context(|| {
            format!("variant {} has no batch-{b} artifact", self.info.name)
        })?;
        let label = format!("denoise_{}_b{b}", self.info.name);
        let id = self
            .device
            .compile(self.artifacts_dir.join(fname), &label)?;
        self.exes.lock().unwrap().insert(b, id);
        Ok(id)
    }

    /// Pre-compile all batch sizes (avoids first-call latency spikes).
    pub fn warmup(&self) -> Result<()> {
        let sizes: Vec<usize> = self.info.artifacts.keys().copied().collect();
        for b in sizes {
            self.exe_for_batch(b)?;
        }
        Ok(())
    }

    /// Execute one padded chunk of at most max_batch rows.
    fn run_chunk(&self, ys: &[f64], ts: &[f64], cond: &[f64], n: usize,
                 out: &mut [f64]) -> Result<()> {
        let d = self.info.d;
        let c = self.info.cond_dim;
        let b = self
            .info
            .batch_for(n)
            .with_context(|| format!("no artifact for batch {n}"))?;
        let exe = self.exe_for_batch(b)?;

        // pad by repeating row 0
        let mut y32 = Vec::with_capacity(b * d);
        let mut t32 = Vec::with_capacity(b);
        let mut c32 = Vec::with_capacity(b * c);
        for r in 0..b {
            let src = if r < n { r } else { 0 };
            y32.extend(ys[src * d..(src + 1) * d].iter().map(|&v| v as f32));
            t32.push(ts[src] as f32);
            c32.extend(cond[src * c..(src + 1) * c].iter().map(|&v| v as f32));
        }
        let mut inputs = vec![
            HostArray::new(vec![b, d], y32)?,
            HostArray::new(vec![b], t32)?,
        ];
        if c > 0 {
            // zero-width cond params are dropped by jax at lowering time
            inputs.push(HostArray::new(vec![b, c], c32)?);
        }
        let outs = self.device.execute(exe, inputs, Some(self.weights))?;
        let x0 = &outs[0];
        if x0.dims != [b, d] {
            bail!("unexpected output dims {:?}", x0.dims);
        }
        for r in 0..n {
            for i in 0..d {
                out[r * d + i] = x0.data[r * d + i] as f64;
            }
        }
        Ok(())
    }
}

impl DenoiseModel for HloModel {
    fn dim(&self) -> usize {
        self.info.d
    }

    fn cond_dim(&self) -> usize {
        self.info.cond_dim
    }

    fn k_steps(&self) -> usize {
        self.info.k_steps
    }

    fn schedule(&self) -> &DdpmSchedule {
        &self.schedule
    }

    fn denoise_batch(&self, ys: &[f64], ts: &[f64], cond: &[f64], n: usize,
                     out: &mut [f64]) -> Result<()> {
        let d = self.info.d;
        let c = self.info.cond_dim;
        anyhow::ensure!(ys.len() == n * d, "ys length {} != n*d {}",
                        ys.len(), n * d);
        anyhow::ensure!(cond.len() == n * c,
                        "cond length {} != n*cond_dim {} (model '{}')",
                        cond.len(), n * c, self.info.name);
        let max_b = self.info.max_batch();
        let mut done = 0usize;
        while done < n {
            let take = (n - done).min(max_b);
            self.run_chunk(
                &ys[done * d..(done + take) * d],
                &ts[done..done + take],
                &cond[done * c..(done + take) * c],
                take,
                &mut out[done * d..(done + take) * d],
            )?;
            done += take;
        }
        Ok(())
    }
}
