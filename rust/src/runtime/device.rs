//! The device thread: owns all PJRT state, serves execution jobs.
//!
//! API: [`DeviceHandle::spawn`] starts the thread; `compile`,
//! `upload_weights` and `execute` are synchronous RPCs over mpsc
//! channels. Per-executable wall-clock stats are recorded on the device
//! side and feed the modeled multi-worker latency (DESIGN.md §3).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::host::HostArray;

/// Opaque id of a compiled executable.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ExeId(pub usize);

/// Opaque id of a device-resident buffer set (model weights).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct WeightsId(pub usize);

/// Timing record per executable.
#[derive(Debug, Clone, Default)]
pub struct ExeStats {
    pub calls: u64,
    pub total_s: f64,
    pub label: String,
}

#[derive(Debug, Clone, Default)]
pub struct DeviceStats {
    pub per_exe: Vec<ExeStats>,
    pub total_calls: u64,
}

impl DeviceStats {
    /// Mean seconds per call for one executable (None if never called).
    pub fn mean_call_s(&self, exe: ExeId) -> Option<f64> {
        let s = self.per_exe.get(exe.0)?;
        if s.calls == 0 {
            None
        } else {
            Some(s.total_s / s.calls as f64)
        }
    }
}

enum Job {
    Compile {
        path: PathBuf,
        label: String,
        reply: Sender<Result<ExeId>>,
    },
    UploadWeights {
        arrays: Vec<HostArray>,
        reply: Sender<Result<WeightsId>>,
    },
    Execute {
        exe: ExeId,
        inputs: Vec<HostArray>,
        weights: Option<WeightsId>,
        reply: Sender<Result<Vec<HostArray>>>,
    },
}

/// Cloneable handle to the device thread. The sender is wrapped in a
/// mutex so the handle is `Sync` (mpsc senders are Send but not Sync)
/// and can live inside `Arc<HloModel>` shared across worker threads.
pub struct DeviceHandle {
    tx: Mutex<Sender<Job>>,
    stats: Arc<Mutex<DeviceStats>>,
}

impl Clone for DeviceHandle {
    fn clone(&self) -> DeviceHandle {
        DeviceHandle {
            tx: Mutex::new(self.tx.lock().unwrap().clone()),
            stats: self.stats.clone(),
        }
    }
}

impl DeviceHandle {
    fn send(&self, job: Job) -> Result<()> {
        self.tx
            .lock()
            .unwrap()
            .send(job)
            .map_err(|_| anyhow!("device thread gone"))
    }

    pub fn spawn() -> Result<DeviceHandle> {
        let (tx, rx) = channel::<Job>();
        let stats = Arc::new(Mutex::new(DeviceStats::default()));
        let stats_thread = stats.clone();
        let (ready_tx, ready_rx) = channel();
        std::thread::Builder::new()
            .name("pjrt-device".into())
            .spawn(move || {
                let client = match xla::PjRtClient::cpu() {
                    Ok(c) => {
                        let _ = ready_tx.send(Ok(()));
                        c
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(anyhow!("PJRT init: {e}")));
                        return;
                    }
                };
                let mut state = DeviceState {
                    client,
                    exes: Vec::new(),
                    weight_sets: Vec::new(),
                    compiled_paths: HashMap::new(),
                };
                while let Ok(job) = rx.recv() {
                    state.handle(job, &stats_thread);
                }
            })?;
        ready_rx.recv().context("device thread died during init")??;
        Ok(DeviceHandle { tx: Mutex::new(tx), stats })
    }

    pub fn compile(&self, path: PathBuf, label: &str) -> Result<ExeId> {
        let (reply, rx) = channel();
        self.send(Job::Compile { path, label: label.to_string(), reply })?;
        rx.recv().map_err(|_| anyhow!("device thread dropped reply"))?
    }

    pub fn upload_weights(&self, arrays: Vec<HostArray>) -> Result<WeightsId> {
        let (reply, rx) = channel();
        self.send(Job::UploadWeights { arrays, reply })?;
        rx.recv().map_err(|_| anyhow!("device thread dropped reply"))?
    }

    /// Execute: inputs are uploaded, weights (if any) are the persistent
    /// device buffers appended after the inputs. Returns the flattened
    /// output tuple as host arrays.
    pub fn execute(&self, exe: ExeId, inputs: Vec<HostArray>,
                   weights: Option<WeightsId>) -> Result<Vec<HostArray>> {
        let (reply, rx) = channel();
        self.send(Job::Execute { exe, inputs, weights, reply })?;
        rx.recv().map_err(|_| anyhow!("device thread dropped reply"))?
    }

    pub fn stats(&self) -> DeviceStats {
        self.stats.lock().unwrap().clone()
    }
}

struct DeviceState {
    client: xla::PjRtClient,
    exes: Vec<xla::PjRtLoadedExecutable>,
    weight_sets: Vec<Vec<xla::PjRtBuffer>>,
    /// path -> already compiled id (dedup)
    compiled_paths: HashMap<PathBuf, ExeId>,
}

impl DeviceState {
    fn handle(&mut self, job: Job, stats: &Arc<Mutex<DeviceStats>>) {
        match job {
            Job::Compile { path, label, reply } => {
                let _ = reply.send(self.compile(path, label, stats));
            }
            Job::UploadWeights { arrays, reply } => {
                let _ = reply.send(self.upload(arrays));
            }
            Job::Execute { exe, inputs, weights, reply } => {
                let t0 = Instant::now();
                let result = self.execute(exe, inputs, weights);
                let dt = t0.elapsed().as_secs_f64();
                {
                    let mut s = stats.lock().unwrap();
                    if let Some(e) = s.per_exe.get_mut(exe.0) {
                        e.calls += 1;
                        e.total_s += dt;
                    }
                    s.total_calls += 1;
                }
                let _ = reply.send(result);
            }
        }
    }

    fn compile(&mut self, path: PathBuf, label: String,
               stats: &Arc<Mutex<DeviceStats>>) -> Result<ExeId> {
        if let Some(&id) = self.compiled_paths.get(&path) {
            return Ok(id);
        }
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 path"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| anyhow!("parsing HLO {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
        let id = ExeId(self.exes.len());
        self.exes.push(exe);
        self.compiled_paths.insert(path, id);
        stats.lock().unwrap().per_exe.push(ExeStats {
            calls: 0,
            total_s: 0.0,
            label,
        });
        Ok(id)
    }

    fn upload(&mut self, arrays: Vec<HostArray>) -> Result<WeightsId> {
        let mut bufs = Vec::with_capacity(arrays.len());
        for a in arrays {
            bufs.push(
                self.client
                    .buffer_from_host_buffer::<f32>(&a.data, &a.dims, None)
                    .map_err(|e| anyhow!("uploading weights: {e}"))?,
            );
        }
        let id = WeightsId(self.weight_sets.len());
        self.weight_sets.push(bufs);
        Ok(id)
    }

    fn execute(&mut self, exe: ExeId, inputs: Vec<HostArray>,
               weights: Option<WeightsId>) -> Result<Vec<HostArray>> {
        let exe_obj = self
            .exes
            .get(exe.0)
            .ok_or_else(|| anyhow!("bad exe id {exe:?}"))?;
        let mut arg_bufs = Vec::with_capacity(inputs.len() + 8);
        for a in &inputs {
            arg_bufs.push(
                self.client
                    .buffer_from_host_buffer::<f32>(&a.data, &a.dims, None)
                    .map_err(|e| anyhow!("uploading input: {e}"))?,
            );
        }
        let weight_slice: &[xla::PjRtBuffer] = match weights {
            Some(id) => self
                .weight_sets
                .get(id.0)
                .ok_or_else(|| anyhow!("bad weights id {id:?}"))?,
            None => &[],
        };
        let arg_refs: Vec<&xla::PjRtBuffer> =
            arg_bufs.iter().chain(weight_slice.iter()).collect();
        let results = exe_obj
            .execute_b(&arg_refs)
            .map_err(|e| anyhow!("execute: {e}"))?;
        let first = results
            .into_iter()
            .next()
            .and_then(|r| r.into_iter().next())
            .ok_or_else(|| anyhow!("no output buffer"))?;
        let mut literal = first
            .to_literal_sync()
            .map_err(|e| anyhow!("download: {e}"))?;
        // artifacts are lowered with return_tuple=True
        let parts = literal
            .decompose_tuple()
            .map_err(|e| anyhow!("decompose: {e}"))?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            let shape = p
                .array_shape()
                .map_err(|e| anyhow!("shape: {e}"))?;
            let dims: Vec<usize> =
                shape.dims().iter().map(|&d| d as usize).collect();
            let data = p
                .to_vec::<f32>()
                .map_err(|e| anyhow!("to_vec: {e}"))?;
            out.push(HostArray::new(dims, data)?);
        }
        if out.is_empty() {
            bail!("empty output tuple");
        }
        Ok(out)
    }
}
