//! Host-side tensor: the currency between the coordinator and the
//! device thread (f32, matching the HLO artifacts; f64 engine state is
//! narrowed at this boundary).

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct HostArray {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostArray {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Result<HostArray> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            bail!("dims {:?} imply {} elements, got {}", dims, n, data.len());
        }
        Ok(HostArray { dims, data })
    }

    pub fn scalar_vec(data: Vec<f32>) -> HostArray {
        HostArray { dims: vec![data.len()], data }
    }

    pub fn matrix(rows: usize, cols: usize, data: Vec<f32>) -> Result<HostArray> {
        HostArray::new(vec![rows, cols], data)
    }

    pub fn from_f64(dims: Vec<usize>, data: &[f64]) -> Result<HostArray> {
        HostArray::new(dims, data.iter().map(|&x| x as f32).collect())
    }

    pub fn zeros(dims: Vec<usize>) -> HostArray {
        let n = dims.iter().product();
        HostArray { dims, data: vec![0.0; n] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Copy into an f64 slice.
    pub fn widen_into(&self, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.data.len());
        for (o, &v) in out.iter_mut().zip(&self.data) {
            *o = v as f64;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        assert!(HostArray::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostArray::new(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(HostArray::new(vec![0], vec![]).is_ok());
    }

    #[test]
    fn conversions() {
        let a = HostArray::from_f64(vec![2], &[1.5, -2.5]).unwrap();
        assert_eq!(a.data, vec![1.5f32, -2.5f32]);
        let mut out = [0.0f64; 2];
        a.widen_into(&mut out);
        assert_eq!(out, [1.5, -2.5]);
    }
}
