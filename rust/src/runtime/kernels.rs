//! HLO-backed L1 kernels (speculate / GRS verify).
//!
//! The default hot path computes these O(theta * d) ops natively in rust
//! (PJRT dispatch overhead dominates them on this testbed); these
//! wrappers exercise the full three-layer path (`--kernel-backend hlo`)
//! and are parity-tested against the native implementations.

use anyhow::{bail, Context, Result};

use crate::model::Manifest;
use crate::runtime::device::{DeviceHandle, ExeId};
use crate::runtime::host::HostArray;

#[derive(Clone)]
pub struct HloKernels {
    pub d: usize,
    /// fixed speculation-chain length the artifacts were lowered with
    pub t_steps: usize,
    device: DeviceHandle,
    speculate_exe: ExeId,
    verify_exe: ExeId,
}

impl HloKernels {
    pub fn load(device: &DeviceHandle, manifest: &Manifest, d: usize)
                -> Result<HloKernels> {
        let sp = manifest.speculate_kernels.get(&d)
            .with_context(|| format!("no speculate kernel for d={d}"))?;
        let vf = manifest.verify_kernels.get(&d)
            .with_context(|| format!("no verify kernel for d={d}"))?;
        let speculate_exe = device.compile(manifest.dir.join(sp),
                                           &format!("speculate_d{d}"))?;
        let verify_exe = device.compile(manifest.dir.join(vf),
                                        &format!("verify_d{d}"))?;
        Ok(HloKernels {
            d,
            t_steps: manifest.spec_t,
            device: device.clone(),
            speculate_exe,
            verify_exe,
        })
    }

    /// Proposal chain (kernel `speculate`): returns (m_hat, y_hat) each
    /// t_steps*d row-major. Inputs shorter than t_steps are zero-padded
    /// (padding rows are ignored by the caller).
    pub fn speculate(&self, y_a: &[f64], x0a: &[f64], c1: &[f64], c2: &[f64],
                     sigma: &[f64], xi: &[f64])
                     -> Result<(Vec<f64>, Vec<f64>)> {
        let t = self.t_steps;
        let d = self.d;
        if y_a.len() != d || x0a.len() != d {
            bail!("bad y_a/x0a length");
        }
        let n = c1.len();
        if n > t {
            bail!("chain length {n} exceeds kernel T={t}");
        }
        let pad = |v: &[f64]| {
            let mut out: Vec<f32> = v.iter().map(|&x| x as f32).collect();
            out.resize(t, 0.0);
            out
        };
        let mut xi32: Vec<f32> = xi.iter().map(|&x| x as f32).collect();
        xi32.resize(t * d, 0.0);
        let inputs = vec![
            HostArray::from_f64(vec![d], y_a)?,
            HostArray::from_f64(vec![d], x0a)?,
            HostArray::scalar_vec(pad(c1)),
            HostArray::scalar_vec(pad(c2)),
            HostArray::scalar_vec(pad(sigma)),
            HostArray::new(vec![t, d], xi32)?,
        ];
        let outs = self.device.execute(self.speculate_exe, inputs, None)?;
        if outs.len() != 2 {
            bail!("speculate returned {} outputs", outs.len());
        }
        let m_hat = outs[0].data[..n * d].iter().map(|&x| x as f64).collect();
        let y_hat = outs[1].data[..n * d].iter().map(|&x| x as f64).collect();
        Ok((m_hat, y_hat))
    }

    /// Batched GRS (kernel `grs_verify`): returns (z, accept) with z
    /// n*d row-major, accept n flags. Padding rows use sigma=1,
    /// m_hat=m=0 (always accepted, ignored by the caller).
    pub fn verify(&self, u: &[f64], xi: &[f64], m_hat: &[f64], m: &[f64],
                  sigma: &[f64]) -> Result<(Vec<f64>, Vec<bool>)> {
        let t = self.t_steps;
        let d = self.d;
        let n = u.len();
        if n > t {
            bail!("batch {n} exceeds kernel T={t}");
        }
        let padf = |v: &[f64], fill: f32, len: usize| {
            let mut out: Vec<f32> = v.iter().map(|&x| x as f32).collect();
            out.resize(len, fill);
            out
        };
        let inputs = vec![
            HostArray::scalar_vec(padf(u, 0.5, t)),
            HostArray::new(vec![t, d], padf(xi, 0.0, t * d))?,
            HostArray::new(vec![t, d], padf(m_hat, 0.0, t * d))?,
            HostArray::new(vec![t, d], padf(m, 0.0, t * d))?,
            HostArray::scalar_vec(padf(sigma, 1.0, t)),
        ];
        let outs = self.device.execute(self.verify_exe, inputs, None)?;
        if outs.len() != 2 {
            bail!("verify returned {} outputs", outs.len());
        }
        let z = outs[0].data[..n * d].iter().map(|&x| x as f64).collect();
        let accept = outs[1].data[..n].iter().map(|&x| x > 0.5).collect();
        Ok((z, accept))
    }
}
