//! Sharded worker-pool execution substrate.
//!
//! Until this module existed, every "parallel round" in the repo was
//! bookkeeping: the ASD verify batch, the Picard window sweep and the
//! lockstep sequential gang all executed their `denoise_batch` rows
//! serially on the calling thread, so `parallel_rounds` had no physical
//! counterpart and wall-clock never tracked Theorem 4. This pool makes
//! rounds *real*: a batched call is split into contiguous per-shard row
//! ranges that execute concurrently on a set of persistent worker
//! threads (std-only: `std::thread` + `Mutex`/`Condvar`, in the spirit
//! of the mini-rayon registry but self-contained).
//!
//! Design rules:
//! * **One global pool.** All sharded execution in the process runs on
//!   [`global()`], sized once from `ASD_POOL_THREADS` or the machine's
//!   available parallelism. Config knobs ([`PoolConfig::pool_size`])
//!   control how many *shards* a call is split into, never how many OS
//!   threads exist — so an ASD engine, a Picard sampler and the serving
//!   coordinator can all be "parallel" without oversubscribing cores.
//! * **Caller participates.** `run_sharded` enqueues helper entries and
//!   then works shards itself, so it completes even if every worker is
//!   busy (or the pool has a single thread). Nested calls from inside a
//!   worker are deadlock-free for the same reason — the submitting
//!   thread drains its own shards; nested shards still queue on the
//!   same fixed worker set, so the OS thread count never grows.
//! * **Determinism.** Shards are contiguous row ranges executed by the
//!   wrapped model row-by-row; no cross-row reduction ever moves between
//!   shards, so outputs are bit-identical for every `pool_size`
//!   (enforced by tests/test_parallel_determinism.rs).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Sharding knobs threaded through `AsdConfig`, `PicardConfig`,
/// `BatchedSequentialSampler` and `ServerConfig`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolConfig {
    /// Maximum shards a batched call is split into; 0/1 = inline
    /// (serial) execution, the default.
    pub pool_size: usize,
    /// Minimum rows per shard: tiny batches stay inline so sharding
    /// overhead never dominates cheap rounds.
    pub shard_min: usize,
}

impl Default for PoolConfig {
    fn default() -> PoolConfig {
        PoolConfig { pool_size: 1, shard_min: 2 }
    }
}

impl PoolConfig {
    /// Shorthand for `pool_size` shards with the default `shard_min`.
    pub fn sharded(pool_size: usize) -> PoolConfig {
        PoolConfig { pool_size, ..Default::default() }
    }

    /// Whether this config ever shards.
    pub fn parallel(&self) -> bool {
        self.pool_size > 1
    }

    /// Shard count for an `n`-row batch: capped by `pool_size` and by
    /// `ceil(n / shard_min)`, so shards carry `shard_min` rows *on
    /// average* (the last, smallest shard may carry fewer); batches of
    /// `shard_min` rows or less stay inline (returns 1).
    pub fn shards_for(&self, n: usize) -> usize {
        if self.pool_size <= 1 || n <= self.shard_min.max(1) {
            return 1;
        }
        self.pool_size.min(n.div_ceil(self.shard_min.max(1))).max(1)
    }
}

/// One sharded call: a type-erased borrowed closure plus claim/latch
/// state. The closure pointer is only dereferenced while `run_sharded`
/// is blocked waiting on `done`, which keeps the borrow alive.
struct Job {
    f: *const (dyn Fn(usize, usize) + Sync),
    ranges: Vec<(usize, usize)>,
    /// next unclaimed shard index
    next: AtomicUsize,
    /// shards not yet finished; the thread that finishes the last one
    /// opens the latch
    pending: AtomicUsize,
    poisoned: AtomicBool,
    done: Mutex<bool>,
    cv: Condvar,
}

// SAFETY: `f` points at a `Sync` closure that outlives the job (the
// submitting thread blocks until `done`); all other state is atomics or
// lock-guarded.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

impl Job {
    /// Claim and execute shards until none remain. Runs on workers and
    /// on the submitting thread alike.
    fn work(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.ranges.len() {
                return;
            }
            let (start, end) = self.ranges[i];
            // SAFETY: see the `Send`/`Sync` impls above.
            let f = unsafe { &*self.f };
            let outcome = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| f(start, end)));
            if outcome.is_err() {
                self.poisoned.store(true, Ordering::Relaxed);
            }
            // AcqRel: the final decrement observes every shard's writes
            // through the RMW chain before opening the latch.
            if self.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
                let mut done = self.done.lock().unwrap();
                *done = true;
                self.cv.notify_all();
            }
        }
    }
}

struct PoolShared {
    queue: Mutex<VecDeque<Arc<Job>>>,
    cv: Condvar,
    shutdown: AtomicBool,
}

/// A fixed set of persistent worker threads executing sharded calls.
pub struct ThreadPool {
    shared: Arc<PoolShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn a pool with `size` worker threads (min 1).
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let mut workers = Vec::with_capacity(size);
        for w in 0..size {
            let s = shared.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("asd-pool-{w}"))
                    .spawn(move || worker_loop(s))
                    .expect("spawn pool worker"),
            );
        }
        ThreadPool { shared, workers, size }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Execute `f(start, end)` over `shards` contiguous, balanced,
    /// disjoint sub-ranges of `0..n`, concurrently on the pool (the
    /// caller works too). Blocks until every shard finished; panics if
    /// any shard panicked. Returns the effective shard count.
    pub fn run_sharded<F: Fn(usize, usize) + Sync>(&self, n: usize,
                                                   shards: usize, f: F)
                                                   -> usize {
        let shards = shards.min(n).max(1);
        if n == 0 {
            return 0;
        }
        if shards == 1 {
            f(0, n);
            return 1;
        }
        let base = n / shards;
        let rem = n % shards;
        let mut ranges = Vec::with_capacity(shards);
        let mut start = 0usize;
        for i in 0..shards {
            let len = base + usize::from(i < rem);
            ranges.push((start, start + len));
            start += len;
        }
        // Erase the closure's lifetime: the job cannot outlive this
        // frame because we block on the latch before returning.
        let f_ref: &(dyn Fn(usize, usize) + Sync) = &f;
        let f_ptr: &'static (dyn Fn(usize, usize) + Sync) =
            unsafe { std::mem::transmute(f_ref) };
        let job = Arc::new(Job {
            f: f_ptr as *const _,
            ranges,
            next: AtomicUsize::new(0),
            pending: AtomicUsize::new(shards),
            poisoned: AtomicBool::new(false),
            done: Mutex::new(false),
            cv: Condvar::new(),
        });
        {
            let mut q = self.shared.queue.lock().unwrap();
            // one helper entry per shard the caller won't take itself,
            // capped by the worker count — extra entries would only be
            // popped, see all shards claimed, and go back to sleep
            let helpers = (shards - 1).min(self.size);
            for _ in 0..helpers {
                q.push_back(job.clone());
            }
        }
        self.shared.cv.notify_all();
        job.work();
        let mut done = job.done.lock().unwrap();
        while !*done {
            done = job.cv.wait(done).unwrap();
        }
        if job.poisoned.load(Ordering::Relaxed) {
            panic!("a pool shard panicked");
        }
        shards
    }

    /// Like [`run_sharded`](Self::run_sharded), but shard boundaries
    /// land on multiples of `block` (the last shard absorbs the
    /// remainder), and `f` receives *item* ranges over `0..n`. Aligned
    /// boundaries keep block-tiled kernels on their full-width
    /// micro-kernel except at the very end of the range. A thin 1-D
    /// view over [`run_sharded_tiles`](Self::run_sharded_tiles)
    /// (degenerate single-column grid), kept as the simpler API for
    /// callers without a second dimension.
    pub fn run_sharded_blocks<F: Fn(usize, usize) + Sync>(
        &self, n: usize, block: usize, shards: usize, f: F) -> usize {
        self.run_sharded_tiles(n, block, 1, 1, shards,
                               |r0, r1, _c0, _c1| f(r0, r1))
    }

    /// 2-D tile scheduler: split the `m × n` iteration space into a
    /// grid of up to `shards` rectangular tiles — row boundaries on
    /// multiples of `m_block`, column boundaries on multiples of
    /// `n_block` (the last tile in each dimension absorbs the
    /// remainder) — and execute `f(r0, r1, c0, c1)` for every tile
    /// concurrently on the pool (caller participating). Each output
    /// tile is owned by exactly one worker, so kernels whose elements
    /// are computed whole inside a tile stay bit-invariant in the
    /// shard count.
    ///
    /// The grid prefers splitting M first (a row-range tile streams
    /// fewer A rows and reuses each B panel across its whole range) and
    /// overflows the leftover parallelism into N only when M alone
    /// cannot fill `shards` — the small-M serving-round case that an
    /// M-only split would leave running serial. Returns the effective
    /// tile count.
    pub fn run_sharded_tiles<F: Fn(usize, usize, usize, usize) + Sync>(
        &self, m: usize, m_block: usize, n: usize, n_block: usize,
        shards: usize, f: F) -> usize {
        if m == 0 || n == 0 {
            return 0;
        }
        let (mbs, nbs) = (m_block.max(1), n_block.max(1));
        let (mb, nb) = (m.div_ceil(mbs), n.div_ceil(nbs));
        let shards = shards.max(1);
        let sm = mb.min(shards);
        let sn = nb.min((shards / sm).max(1));
        let tiles = sm * sn;
        if tiles <= 1 {
            f(0, m, 0, n);
            return 1;
        }
        // balanced block-aligned ranges per dimension (parts <= blocks,
        // so every range is non-empty)
        let ranges = |items: usize, blocks: usize, bsz: usize,
                      parts: usize| -> Vec<(usize, usize)> {
            let (base, rem) = (blocks / parts, blocks % parts);
            let mut out = Vec::with_capacity(parts);
            let mut b0 = 0usize;
            for i in 0..parts {
                let len = base + usize::from(i < rem);
                out.push((b0 * bsz, ((b0 + len) * bsz).min(items)));
                b0 += len;
            }
            out
        };
        let rrows = ranges(m, mb, mbs, sm);
        let rcols = ranges(n, nb, nbs, sn);
        self.run_sharded(tiles, tiles, |s, e| {
            for t in s..e {
                let (r0, r1) = rrows[t / sn];
                let (c0, c1) = rcols[t % sn];
                f(r0, r1, c0, c1);
            }
        });
        tiles
    }

    /// Run `n` independent *tasks* concurrently (`f(i)` once for each
    /// `i in 0..n`), the caller participating as usual. Task
    /// granularity — one shard per task — for co-scheduling
    /// heterogeneous work items on the one global pool: e.g. the
    /// coordinator executes every serving lane's fused round as one
    /// task per tick, so two variants' rounds share wall-clock instead
    /// of queueing behind each other. Tasks may issue nested sharded
    /// calls (deadlock-free; see module docs).
    pub fn run_tasks<F: Fn(usize) + Sync>(&self, n: usize, f: F) {
        self.run_sharded(n, n, |s, e| {
            for i in s..e {
                f(i);
            }
        });
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            // hold the queue lock while flipping the flag: a worker that
            // just observed shutdown=false under this lock is serialized
            // against us, so it either re-checks and exits or is already
            // parked in cv.wait when notify_all fires — no lost wakeup
            let _guard = self.shared.queue.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::SeqCst);
        }
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: Arc<PoolShared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(job) = q.pop_front() {
                    break job;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        job.work();
    }
}

/// Worker-thread count for the global pool: `ASD_POOL_THREADS` if set,
/// else the machine's available parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("ASD_POOL_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// The process-wide pool (the "one global pool" rule). Initialized
/// lazily on first sharded call; never torn down.
pub fn global() -> &'static ThreadPool {
    static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();
    GLOBAL.get_or_init(|| ThreadPool::new(default_threads()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_every_index_exactly_once() {
        let pool = ThreadPool::new(3);
        for n in [1usize, 2, 3, 5, 7, 16, 33] {
            for shards in [1usize, 2, 3, 4, 8, 40] {
                let hits: Vec<AtomicUsize> =
                    (0..n).map(|_| AtomicUsize::new(0)).collect();
                let eff = pool.run_sharded(n, shards, |s, e| {
                    for i in s..e {
                        hits[i].fetch_add(1, Ordering::Relaxed);
                    }
                });
                assert!(eff >= 1 && eff <= shards.max(1).min(n));
                for (i, h) in hits.iter().enumerate() {
                    assert_eq!(h.load(Ordering::Relaxed), 1,
                               "index {i} (n={n} shards={shards})");
                }
            }
        }
    }

    #[test]
    fn block_sharding_covers_all_items_on_aligned_boundaries() {
        let pool = ThreadPool::new(3);
        for n in [0usize, 1, 3, 4, 5, 16, 17, 31] {
            for block in [1usize, 2, 4, 7] {
                for shards in [1usize, 2, 3, 8] {
                    let hits: Vec<AtomicUsize> =
                        (0..n).map(|_| AtomicUsize::new(0)).collect();
                    pool.run_sharded_blocks(n, block, shards, |s, e| {
                        assert!(s % block == 0,
                                "unaligned shard start {s} (block {block})");
                        assert!(e == n || e % block == 0);
                        for i in s..e {
                            hits[i].fetch_add(1, Ordering::Relaxed);
                        }
                    });
                    for (i, h) in hits.iter().enumerate() {
                        assert_eq!(h.load(Ordering::Relaxed), 1,
                                   "item {i} (n={n} block={block} \
                                    shards={shards})");
                    }
                }
            }
        }
    }

    #[test]
    fn tile_sharding_covers_every_cell_exactly_once_on_aligned_bounds() {
        let pool = ThreadPool::new(3);
        for (m, n) in [(0usize, 5usize), (5, 0), (1, 1), (4, 128), (37, 19),
                       (16, 40), (3, 9)] {
            for (mb, nb) in [(1usize, 1usize), (4, 8), (7, 3)] {
                for shards in [1usize, 2, 8, 64] {
                    let hits: Vec<AtomicUsize> =
                        (0..m * n).map(|_| AtomicUsize::new(0)).collect();
                    let eff = pool.run_sharded_tiles(
                        m, mb, n, nb, shards, |r0, r1, c0, c1| {
                            assert!(r0 % mb == 0 && c0 % nb == 0,
                                    "unaligned tile start ({r0},{c0})");
                            assert!(r1 == m || r1 % mb == 0);
                            assert!(c1 == n || c1 % nb == 0);
                            for i in r0..r1 {
                                for j in c0..c1 {
                                    hits[i * n + j]
                                        .fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        });
                    if m == 0 || n == 0 {
                        assert_eq!(eff, 0);
                        continue;
                    }
                    assert!(eff >= 1 && eff <= shards.max(1),
                            "eff={eff} shards={shards}");
                    for (i, h) in hits.iter().enumerate() {
                        assert_eq!(h.load(Ordering::Relaxed), 1,
                                   "cell {i} (m={m} n={n} mb={mb} nb={nb} \
                                    shards={shards})");
                    }
                }
            }
        }
    }

    #[test]
    fn tile_grid_fans_out_over_columns_when_m_is_one_block() {
        // the small-M serving case: a single row block must still
        // produce > 1 tile by splitting the column dimension
        let pool = ThreadPool::new(3);
        let eff = pool.run_sharded_tiles(4, 4, 64, 8, 8, |_, _, _, _| {});
        assert!(eff > 1, "single-row-block grid stayed serial (eff={eff})");
        // and a square grid fills the shard budget without exceeding it
        let eff = pool.run_sharded_tiles(64, 4, 64, 8, 8, |_, _, _, _| {});
        assert!(eff >= 8 / 2 && eff <= 8);
    }

    #[test]
    fn run_tasks_executes_each_task_exactly_once() {
        let pool = ThreadPool::new(3);
        for n in [0usize, 1, 2, 5, 17] {
            let hits: Vec<AtomicUsize> =
                (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.run_tasks(n, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "task {i} (n={n})");
            }
        }
        // tasks nesting sharded calls complete (the lane tick pattern)
        let total = AtomicUsize::new(0);
        global().run_tasks(3, |_| {
            global().run_sharded(8, 4, |s, e| {
                total.fetch_add(e - s, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 24);
    }

    #[test]
    fn zero_items_is_a_noop() {
        let pool = ThreadPool::new(2);
        let eff = pool.run_sharded(0, 4, |_, _| panic!("must not run"));
        assert_eq!(eff, 0);
    }

    #[test]
    fn results_accumulate_across_shards() {
        let pool = ThreadPool::new(4);
        let n = 1000usize;
        let total = AtomicU64::new(0);
        pool.run_sharded(n, 4, |s, e| {
            let part: u64 = (s..e).map(|i| i as u64).sum();
            total.fetch_add(part, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed),
                   (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn shard_writes_are_visible_to_caller() {
        let pool = ThreadPool::new(4);
        let n = 64usize;
        let mut out = vec![0.0f64; n];
        let ptr = out.as_mut_ptr() as usize;
        pool.run_sharded(n, 8, |s, e| {
            // disjoint ranges: aliasing-free by construction
            let slice = unsafe {
                std::slice::from_raw_parts_mut((ptr as *mut f64).add(s), e - s)
            };
            for (off, v) in slice.iter_mut().enumerate() {
                *v = (s + off) as f64 * 2.0;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as f64 * 2.0);
        }
    }

    #[test]
    fn nested_calls_complete() {
        // a shard issuing its own sharded call must not deadlock: the
        // inner caller participates and drains its own shards
        let pool = global();
        let outer_hits = AtomicUsize::new(0);
        pool.run_sharded(4, 2, |s, e| {
            for _ in s..e {
                let inner_hits = AtomicUsize::new(0);
                global().run_sharded(6, 3, |is, ie| {
                    inner_hits.fetch_add(ie - is, Ordering::Relaxed);
                });
                assert_eq!(inner_hits.load(Ordering::Relaxed), 6);
                outer_hits.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert_eq!(outer_hits.load(Ordering::Relaxed), 4);
    }

    #[test]
    #[should_panic(expected = "pool shard panicked")]
    fn shard_panic_propagates_to_caller() {
        let pool = ThreadPool::new(2);
        pool.run_sharded(8, 4, |s, _| {
            if s == 0 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn pool_survives_a_panicked_job() {
        let pool = ThreadPool::new(2);
        let got_panic = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                pool.run_sharded(8, 4, |_, _| panic!("boom"));
            }))
            .is_err();
        assert!(got_panic);
        // workers caught the panic and still serve
        let count = AtomicUsize::new(0);
        pool.run_sharded(10, 4, |s, e| {
            count.fetch_add(e - s, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn shards_for_caps_by_batch_and_min() {
        let cfg = PoolConfig { pool_size: 8, shard_min: 2 };
        assert_eq!(cfg.shards_for(0), 1);
        assert_eq!(cfg.shards_for(1), 1);
        assert_eq!(cfg.shards_for(2), 1); // n <= shard_min stays inline
        assert_eq!(cfg.shards_for(3), 2);
        assert_eq!(cfg.shards_for(7), 4);
        assert_eq!(cfg.shards_for(100), 8);
        let inline = PoolConfig::default();
        assert_eq!(inline.shards_for(100), 1);
        assert!(!inline.parallel());
        assert!(PoolConfig::sharded(4).parallel());
    }
}
