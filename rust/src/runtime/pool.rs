//! Work-stealing execution substrate: sharded calls + lane round tasks.
//!
//! Until this module existed, every "parallel round" in the repo was
//! bookkeeping: the ASD verify batch, the Picard window sweep and the
//! lockstep sequential gang all executed their `denoise_batch` rows
//! serially on the calling thread, so `parallel_rounds` had no physical
//! counterpart and wall-clock never tracked Theorem 4. This pool makes
//! rounds *real*: work executes concurrently on a set of persistent
//! worker threads (std-only: `std::thread` + `Mutex`/`Condvar`, in the
//! shape of the mini-rayon registry — a global injector plus one deque
//! per worker — but self-contained).
//!
//! Three kinds of work ride the same deques:
//!
//! * **Sharded calls** ([`ThreadPool::run_sharded`] and its block/tile
//!   variants): a batched call split into contiguous row ranges (or
//!   2-D tiles). The queued entries are *claim hints* — whoever pops
//!   one claims shards from the job's atomic counter until none remain,
//!   so a stale hint is a no-op and the caller always completes by
//!   claiming shards itself.
//! * **Round tasks** ([`ThreadPool::submit_round`]): one-shot closures
//!   (a serving lane's fused round) submitted asynchronously; their
//!   completions are reported to a [`RoundGroup`] mailbox that the
//!   submitting driver drains with [`ThreadPool::wait_rounds`].
//! * **Tile graphs** ([`ThreadPool::submit_graph`] /
//!   [`ThreadPool::run_graph`]): a dependency-counted DAG of one-shot
//!   tile tasks built with [`TileGraph`]. Only *ready* tiles (atomic
//!   dependency count zero) are ever queued; whichever thread finishes
//!   a tile decrements its dependents' counters and pushes the newly
//!   ready ones to the injector, so a multi-layer fused round executes
//!   with **zero** intra-round pool barriers — the last tile posts one
//!   `(key, panicked)` completion into the [`RoundGroup`] mailbox,
//!   exactly like a round task. Idle workers fill layer-boundary gaps
//!   of one graph with ready tiles of another (or with any other queued
//!   work), which is what makes lanes overlap *inside* a round.
//!
//! Scheduling topology (the work-stealing part):
//!
//! * A thread that is not a pool worker pushes to the **global
//!   injector**; a pool worker pushes to **its own deque**.
//! * A worker pops its own deque LIFO (locality), then the injector
//!   FIFO, then **steals** from sibling deques FIFO. Idle workers
//!   therefore drain whichever worker (or lane) is hottest — a fused
//!   round that shards its GEMM enqueues tile hints on the executing
//!   worker's deque, and every idle thread converges on them.
//! * A driver blocked in `wait_rounds` **helps**: it executes queued
//!   entries instead of idling, preferring the *newest* injected entry
//!   (LIFO) — its own just-submitted short rounds — while workers take
//!   the oldest (FIFO), which keeps the blocked driver off the
//!   long-running straggler round whenever there is a choice.
//! * Parking is latch-style: a worker that finds every queue empty
//!   registers as a sleeper and re-checks the pending-entry count under
//!   the sleep lock before waiting, so a concurrent push can never be
//!   lost.
//!
//! Design rules:
//! * **One global pool.** All sharded execution in the process runs on
//!   [`global()`], sized once from `ASD_POOL_THREADS` or the machine's
//!   available parallelism. Config knobs ([`PoolConfig::pool_size`])
//!   control how many *shards* a call is split into, never how many OS
//!   threads exist — so an ASD engine, a Picard sampler and the serving
//!   coordinator can all be "parallel" without oversubscribing cores.
//! * **Caller participates.** `run_sharded` enqueues claim hints and
//!   then works shards itself, so it completes even if every worker is
//!   busy (or the pool has a single thread). Nested calls from inside a
//!   worker are deadlock-free for the same reason — the submitting
//!   thread drains its own shards; nested shards still queue on the
//!   same fixed worker set, so the OS thread count never grows.
//! * **Determinism.** Stealing moves *which thread* runs a shard or
//!   tile, never how the work is partitioned: shards are contiguous row
//!   ranges executed row-by-row, each 2-D tile is owned by exactly one
//!   executor, and no cross-row reduction ever moves between shards —
//!   so outputs are bit-identical for every pool size and every steal
//!   schedule (enforced by tests/test_parallel_determinism.rs). Tile
//!   graphs inherit the same contract: the schedule changes only *when*
//!   a ready tile runs, never the node partition or any reduction
//!   order inside a node, and the dependency counters order every
//!   writer before every reader regardless of which thread runs what.
//! * **Poison recovery.** All pool mutexes are locked through
//!   [`lock_recover`]: a panicking thread must degrade that panic's own
//!   call, never cascade into pool-wide worker death or a
//!   panic-in-drop abort (user closures are additionally wrapped in
//!   `catch_unwind`, so poisoning is rare to begin with).

use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, PoisonError};

/// Lock a mutex, recovering the guard if a panicking thread poisoned
/// it. Every closure the pool executes runs under `catch_unwind`, so a
/// poisoned pool mutex means a panic escaped in bookkeeping code that
/// only pushes/pops structurally-valid entries — recovering beats the
/// old behavior (`.unwrap()` everywhere), where one poisoned mutex
/// killed every worker that touched it and made `Drop` abort the
/// process via panic-in-drop.
fn lock_recover<'a, T>(m: &'a Mutex<T>) -> MutexGuard<'a, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// `Condvar::wait` with the same poison recovery as [`lock_recover`].
fn wait_recover<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>)
                       -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(PoisonError::into_inner)
}

/// Sharding knobs threaded through `AsdConfig`, `PicardConfig`,
/// `BatchedSequentialSampler` and `ServerConfig`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolConfig {
    /// Maximum shards a batched call is split into; 0/1 = inline
    /// (serial) execution, the default.
    pub pool_size: usize,
    /// Minimum rows per shard: tiny batches stay inline so sharding
    /// overhead never dominates cheap rounds.
    pub shard_min: usize,
}

impl Default for PoolConfig {
    fn default() -> PoolConfig {
        PoolConfig { pool_size: 1, shard_min: 2 }
    }
}

impl PoolConfig {
    /// Shorthand for `pool_size` shards with the default `shard_min`.
    pub fn sharded(pool_size: usize) -> PoolConfig {
        PoolConfig { pool_size, ..Default::default() }
    }

    /// Whether this config ever shards.
    pub fn parallel(&self) -> bool {
        self.pool_size > 1
    }

    /// Shard count for an `n`-row batch: capped by `pool_size` and by
    /// `ceil(n / shard_min)`, so shards carry `shard_min` rows *on
    /// average* (the last, smallest shard may carry fewer); batches of
    /// `shard_min` rows or less stay inline (returns 1).
    pub fn shards_for(&self, n: usize) -> usize {
        if self.pool_size <= 1 || n <= self.shard_min.max(1) {
            return 1;
        }
        self.pool_size.min(n.div_ceil(self.shard_min.max(1))).max(1)
    }
}

/// One sharded call: a type-erased borrowed closure plus claim/latch
/// state. The closure pointer is only dereferenced while `run_sharded`
/// is blocked waiting on `done`, which keeps the borrow alive.
struct Job {
    f: *const (dyn Fn(usize, usize) + Sync),
    ranges: Vec<(usize, usize)>,
    /// next unclaimed shard index
    next: AtomicUsize,
    /// shards not yet finished; the thread that finishes the last one
    /// opens the latch
    pending: AtomicUsize,
    poisoned: AtomicBool,
    done: Mutex<bool>,
    cv: Condvar,
}

// SAFETY: `f` points at a `Sync` closure that outlives the job (the
// submitting thread blocks until `done`); all other state is atomics or
// lock-guarded.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

impl Job {
    /// Claim and execute shards until none remain. Runs on workers and
    /// on the submitting thread alike.
    fn work(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.ranges.len() {
                return;
            }
            let (start, end) = self.ranges[i];
            // SAFETY: see the `Send`/`Sync` impls above.
            let f = unsafe { &*self.f };
            let outcome = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| f(start, end)));
            if outcome.is_err() {
                self.poisoned.store(true, Ordering::Relaxed);
            }
            // AcqRel: the final decrement observes every shard's writes
            // through the RMW chain before opening the latch.
            if self.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
                let mut done = lock_recover(&self.done);
                *done = true;
                self.cv.notify_all();
            }
        }
    }
}

/// Shared completion mailbox between a driver and its submitted round
/// tasks.
struct GroupShared {
    /// `(key, panicked)` completions not yet drained by `wait_rounds`
    done: Mutex<Vec<(usize, bool)>>,
    cv: Condvar,
}

/// Completion mailbox for [`ThreadPool::submit_round`] tasks: a driver
/// creates one group, submits any number of keyed round closures
/// against it, and drains finished keys with
/// [`ThreadPool::wait_rounds`]. Each submitted key is reported exactly
/// once, with a flag saying whether the closure panicked (the panic is
/// contained — it never unwinds a pool worker).
pub struct RoundGroup {
    shared: Arc<GroupShared>,
}

impl RoundGroup {
    pub fn new() -> RoundGroup {
        RoundGroup {
            shared: Arc::new(GroupShared {
                done: Mutex::new(Vec::new()),
                cv: Condvar::new(),
            }),
        }
    }
}

impl Default for RoundGroup {
    fn default() -> RoundGroup {
        RoundGroup::new()
    }
}

/// One node of a compiled tile graph: the task, its live dependency
/// count, and the indices of the nodes waiting on it.
struct GraphNode {
    /// The tile task. `Fn` rather than `FnOnce` so nodes can live in a
    /// shared, lock-free structure; the scheduler still runs each node
    /// exactly once (a node is pushed only by the thread that drops its
    /// dependency count to zero, and counts never go back up).
    run: Box<dyn Fn() + Send + Sync>,
    /// Unfinished dependencies; the decrement that reaches zero pushes
    /// the node.
    deps: AtomicUsize,
    /// Nodes whose `deps` this node decrements when it finishes.
    dependents: Vec<u32>,
}

/// Executor-side state of one submitted graph.
struct GraphShared {
    nodes: Vec<GraphNode>,
    /// Nodes not yet finished (run or cancelled); the thread that
    /// retires the last one posts the round completion.
    remaining: AtomicUsize,
    /// Set by the first tile panic; later tiles skip their task (the
    /// round already failed) and dependents cascade-cancel.
    failed: AtomicBool,
    key: usize,
    group: Arc<GroupShared>,
}

/// A dependency-counted DAG of one-shot tile tasks, built once per
/// fused round and executed barrier-free on the pool via
/// [`ThreadPool::submit_graph`] (asynchronous, lane rounds) or
/// [`ThreadPool::run_graph`] (synchronous, batch calls).
///
/// Nodes are added in topological order: each node's dependencies must
/// already be in the graph, which makes cycles unrepresentable and
/// guarantees node 0 is a root. The builder is deliberately generic —
/// the MLP round compiler, the bench harness and tests all describe
/// their pipelines with the same two calls.
pub struct TileGraph {
    nodes: Vec<GraphNode>,
}

impl TileGraph {
    pub fn new() -> TileGraph {
        TileGraph { nodes: Vec::new() }
    }

    /// Append a node that runs `task` once every node in `deps` has
    /// finished, returning its index for later nodes to depend on.
    /// Dependencies must reference already-added nodes (topological
    /// insertion order).
    pub fn add_node<F>(&mut self, deps: &[usize], task: F) -> usize
    where
        F: Fn() + Send + Sync + 'static,
    {
        let id = self.nodes.len();
        assert!(id < u32::MAX as usize, "tile graph too large");
        for &d in deps {
            assert!(d < id, "graph dependency {d} is not an earlier node \
                             (adding node {id})");
            self.nodes[d].dependents.push(id as u32);
        }
        self.nodes.push(GraphNode {
            run: Box::new(task),
            deps: AtomicUsize::new(deps.len()),
            dependents: Vec::new(),
        });
        id
    }

    /// Replace node `idx`'s task with one that panics with `msg` —
    /// the deterministic mid-graph fault-injection hook
    /// (`faults::ChaosModel`). Dependency edges are untouched, so the
    /// panic exercises the real cascade-cancel path: the poisoned
    /// tile's dependents never run and the round reports failed.
    pub fn poison_node(&mut self, idx: usize, msg: &str) {
        let msg = msg.to_string();
        self.nodes[idx].run = Box::new(move || panic!("{msg}"));
    }

    /// Number of nodes added so far.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Execute every node on the calling thread, in insertion order.
    /// `add_node` only accepts already-inserted dependencies, so
    /// insertion order is a topological order and this is the serial
    /// schedule of the same compiled pipeline — no pool, no atomics.
    pub fn run_inline(self) {
        for node in &self.nodes {
            (node.run)();
        }
    }

    /// Freeze into executor state, returning the shared graph and its
    /// root node indices (dependency count zero). `None` for an empty
    /// graph.
    fn into_shared(self, key: usize, group: Arc<GroupShared>)
                   -> Option<(Arc<GraphShared>, Vec<u32>)> {
        if self.nodes.is_empty() {
            return None;
        }
        let roots: Vec<u32> = self.nodes.iter().enumerate()
            .filter(|(_, n)| n.deps.load(Ordering::Relaxed) == 0)
            .map(|(i, _)| i as u32)
            .collect();
        let remaining = self.nodes.len();
        Some((
            Arc::new(GraphShared {
                nodes: self.nodes,
                remaining: AtomicUsize::new(remaining),
                failed: AtomicBool::new(false),
                key,
                group,
            }),
            roots,
        ))
    }
}

impl Default for TileGraph {
    fn default() -> TileGraph {
        TileGraph::new()
    }
}

/// One queued unit of work.
enum Entry {
    /// Claim hint for a sharded call: executing it claims and works
    /// shards from the job's counter until none remain. Stale hints
    /// (job already fully claimed) are no-ops by construction.
    Shards(Arc<Job>),
    /// One lane round: runs exactly once, then reports
    /// `(key, panicked)` to its group's mailbox.
    Round {
        f: Box<dyn FnOnce() + Send>,
        key: usize,
        group: Arc<GroupShared>,
    },
    /// One ready tile of a submitted graph: runs its task (unless the
    /// graph already failed), then decrements dependents and pushes the
    /// newly ready ones. The thread that retires the graph's last node
    /// reports `(key, failed)` to the group mailbox.
    Tile {
        graph: Arc<GraphShared>,
        node: u32,
    },
}

#[derive(Debug, Default)]
struct Counters {
    /// entries executed (all kinds, all threads)
    executed: AtomicU64,
    /// entries taken from a sibling worker's deque (true steals)
    stolen: AtomicU64,
    /// entries pushed from non-worker threads via the injector
    injected: AtomicU64,
    /// round tasks executed to completion
    rounds: AtomicU64,
    /// graph tile entries executed (including cancelled-by-failure)
    tile_tasks: AtomicU64,
    /// graphs retired (one per submitted non-empty graph)
    graph_rounds: AtomicU64,
    /// ready tiles pushed to the injector (roots + dependency-count
    /// zero crossings)
    ready_pushes: AtomicU64,
}

/// Monotone scheduling counters, snapshotted by [`ThreadPool::stats`]
/// (process-lifetime totals for the global pool; see
/// [`global_stats`]). `stolen / executed` is the observable steal rate;
/// `rounds` counts boxed lane round tasks and `graph_rounds` graph
/// rounds — together the coordinator's units of fused work;
/// `tile_tasks`/`ready_pushes` expose the barrier-free graph schedule
/// (a graph round pushes each tile exactly once, as it becomes ready,
/// instead of fork/joining per layer).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    pub executed: u64,
    pub stolen: u64,
    pub injected: u64,
    pub rounds: u64,
    pub tile_tasks: u64,
    pub graph_rounds: u64,
    pub ready_pushes: u64,
}

impl PoolStats {
    /// Counter deltas since an earlier snapshot (saturating — safe even
    /// if `base` came from a different pool generation).
    pub fn since(&self, base: &PoolStats) -> PoolStats {
        PoolStats {
            executed: self.executed.saturating_sub(base.executed),
            stolen: self.stolen.saturating_sub(base.stolen),
            injected: self.injected.saturating_sub(base.injected),
            rounds: self.rounds.saturating_sub(base.rounds),
            tile_tasks: self.tile_tasks.saturating_sub(base.tile_tasks),
            graph_rounds: self.graph_rounds
                .saturating_sub(base.graph_rounds),
            ready_pushes: self.ready_pushes
                .saturating_sub(base.ready_pushes),
        }
    }
}

struct PoolShared {
    /// entries from non-worker threads; workers drain it FIFO, helping
    /// drivers drain it LIFO (see module docs)
    injector: Mutex<VecDeque<Entry>>,
    /// one deque per worker: owner pushes/pops the back, thieves pop
    /// the front
    deques: Vec<Mutex<VecDeque<Entry>>>,
    /// entries pushed but not yet popped, across injector + deques;
    /// incremented *before* the push so a worker never parks while an
    /// in-flight push is about to land
    pending: AtomicUsize,
    /// sleep latch: workers park on `wake` under `sleep`, re-checking
    /// `pending`/`shutdown` after registering in `sleepers`
    sleep: Mutex<()>,
    wake: Condvar,
    sleepers: AtomicUsize,
    shutdown: AtomicBool,
    stats: Counters,
}

thread_local! {
    /// `(pool identity, worker index)` when this thread is a pool
    /// worker; pool identity 0 = not a worker.
    static WORKER_ID: Cell<(usize, usize)> = Cell::new((0, 0));
}

/// This thread's worker index in `shared`'s pool, if it is one of its
/// workers (routes pushes to the own deque and own-deque pops).
fn own_index(shared: &PoolShared) -> Option<usize> {
    let (pool, idx) = WORKER_ID.with(|c| c.get());
    (pool == shared as *const PoolShared as usize).then_some(idx)
}

/// Enqueue an entry: a worker keeps it local (own deque, LIFO end),
/// everyone else goes through the injector. Wakes one parked worker.
fn push_entry(shared: &PoolShared, entry: Entry) {
    // pending++ strictly before the push: a worker that observes the
    // count under the sleep lock rescans instead of parking, so the
    // entry cannot be stranded in a queue full of sleepers
    shared.pending.fetch_add(1, Ordering::SeqCst);
    match own_index(shared) {
        Some(w) => lock_recover(&shared.deques[w]).push_back(entry),
        None => {
            lock_recover(&shared.injector).push_back(entry);
            shared.stats.injected.fetch_add(1, Ordering::Relaxed);
        }
    }
    if shared.sleepers.load(Ordering::SeqCst) > 0 {
        // take the sleep lock so the notify is serialized against a
        // worker between its pending re-check and its cv.wait
        let _g = lock_recover(&shared.sleep);
        shared.wake.notify_one();
    }
}

/// Enqueue a ready graph tile. Always the global injector — even from
/// a worker — so every idle thread (and every helping driver)
/// converges on ready tiles in FIFO submission order: two lanes' tiles
/// interleave instead of one lane's chain monopolizing the finishing
/// worker's own deque.
fn push_ready_tile(shared: &PoolShared, graph: Arc<GraphShared>,
                   node: u32) {
    shared.pending.fetch_add(1, Ordering::SeqCst);
    lock_recover(&shared.injector)
        .push_back(Entry::Tile { graph, node });
    shared.stats.ready_pushes.fetch_add(1, Ordering::Relaxed);
    if shared.sleepers.load(Ordering::SeqCst) > 0 {
        let _g = lock_recover(&shared.sleep);
        shared.wake.notify_one();
    }
}

/// Run one ready tile (skipped if its graph already failed), then
/// retire it: decrement dependents, push the newly ready ones, and —
/// from whichever thread retires the graph's last node — post the
/// round completion. Cancelled dependents (ready after failure) retire
/// through an iterative worklist without ever queueing, so a mid-graph
/// panic can neither run a dependent nor strand the completion.
fn run_tile(shared: &PoolShared, graph: &Arc<GraphShared>, node: u32) {
    if !graph.failed.load(Ordering::Acquire) {
        let task = &graph.nodes[node as usize].run;
        let outcome = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| task()));
        if outcome.is_err() {
            graph.failed.store(true, Ordering::Release);
        }
    }
    let mut retired = 0usize;
    let mut worklist = vec![node];
    while let Some(nid) = worklist.pop() {
        retired += 1;
        for &d in &graph.nodes[nid as usize].dependents {
            // AcqRel: the zero-crossing decrement observes every
            // dependency's writes through the RMW chain before the
            // dependent can run
            let dep = &graph.nodes[d as usize].deps;
            if dep.fetch_sub(1, Ordering::AcqRel) == 1 {
                if graph.failed.load(Ordering::Acquire) {
                    worklist.push(d);
                } else {
                    push_ready_tile(shared, graph.clone(), d);
                }
            }
        }
    }
    if graph.remaining.fetch_sub(retired, Ordering::AcqRel) == retired {
        shared.stats.graph_rounds.fetch_add(1, Ordering::Relaxed);
        let failed = graph.failed.load(Ordering::Acquire);
        let mut done = lock_recover(&graph.group.done);
        done.push((graph.key, failed));
        graph.group.cv.notify_all();
    }
}

/// Scheduling role of the thread scanning for work: a pool worker pops
/// the injector oldest-first, a helping driver newest-first (its own
/// just-submitted rounds — keeping the blocked driver off straggler
/// rounds whenever there is a choice).
#[derive(Clone, Copy, PartialEq, Eq)]
enum Scan {
    Worker(usize),
    Helper,
}

/// Find one entry: own deque (LIFO), then injector, then steal from
/// sibling deques (FIFO, round-robin from the scanner's successor).
fn find_work(shared: &PoolShared, scan: Scan) -> Option<Entry> {
    let own = match scan {
        Scan::Worker(w) => {
            if let Some(e) = lock_recover(&shared.deques[w]).pop_back() {
                shared.pending.fetch_sub(1, Ordering::SeqCst);
                return Some(e);
            }
            Some(w)
        }
        Scan::Helper => None,
    };
    {
        let mut inj = lock_recover(&shared.injector);
        let e = match scan {
            Scan::Worker(_) => inj.pop_front(),
            Scan::Helper => inj.pop_back(),
        };
        if let Some(e) = e {
            drop(inj);
            shared.pending.fetch_sub(1, Ordering::SeqCst);
            return Some(e);
        }
    }
    let n = shared.deques.len();
    let start = own.map_or(0, |w| w + 1);
    for k in 0..n {
        let v = (start + k) % n;
        if own == Some(v) {
            continue;
        }
        if let Some(e) = lock_recover(&shared.deques[v]).pop_front() {
            shared.pending.fetch_sub(1, Ordering::SeqCst);
            shared.stats.stolen.fetch_add(1, Ordering::Relaxed);
            return Some(e);
        }
    }
    None
}

/// Execute one entry. Round-task and tile panics are contained here
/// and reported through the group mailbox; shard panics are contained
/// in [`Job::work`].
fn execute_entry(shared: &PoolShared, entry: Entry) {
    shared.stats.executed.fetch_add(1, Ordering::Relaxed);
    match entry {
        Entry::Shards(job) => job.work(),
        Entry::Round { f, key, group } => {
            shared.stats.rounds.fetch_add(1, Ordering::Relaxed);
            let panicked = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(f)).is_err();
            let mut done = lock_recover(&group.done);
            done.push((key, panicked));
            group.cv.notify_all();
        }
        Entry::Tile { graph, node } => {
            shared.stats.tile_tasks.fetch_add(1, Ordering::Relaxed);
            run_tile(shared, &graph, node);
        }
    }
}

fn worker_loop(shared: Arc<PoolShared>, index: usize) {
    WORKER_ID.with(|c| {
        c.set((shared.as_ref() as *const PoolShared as usize, index));
    });
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        if let Some(entry) = find_work(&shared, Scan::Worker(index)) {
            execute_entry(&shared, entry);
            continue;
        }
        // park: register as a sleeper, then re-check under the sleep
        // lock — a pusher increments `pending` before reading
        // `sleepers`, so one side always sees the other (no lost
        // wakeup); a push landing mid-scan is caught by the re-check
        let guard = lock_recover(&shared.sleep);
        shared.sleepers.fetch_add(1, Ordering::SeqCst);
        if !shared.shutdown.load(Ordering::SeqCst)
            && shared.pending.load(Ordering::SeqCst) == 0
        {
            drop(wait_recover(&shared.wake, guard));
        }
        shared.sleepers.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A fixed set of persistent worker threads executing sharded calls
/// and round tasks over work-stealing deques.
pub struct ThreadPool {
    shared: Arc<PoolShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn a pool with `size` worker threads (min 1).
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let shared = Arc::new(PoolShared {
            injector: Mutex::new(VecDeque::new()),
            deques: (0..size).map(|_| Mutex::new(VecDeque::new()))
                .collect(),
            pending: AtomicUsize::new(0),
            sleep: Mutex::new(()),
            wake: Condvar::new(),
            sleepers: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            stats: Counters::default(),
        });
        let mut workers = Vec::with_capacity(size);
        for w in 0..size {
            let s = shared.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("asd-pool-{w}"))
                    .spawn(move || worker_loop(s, w))
                    .expect("spawn pool worker"),
            );
        }
        ThreadPool { shared, workers, size }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Snapshot the pool's scheduling counters.
    pub fn stats(&self) -> PoolStats {
        let c = &self.shared.stats;
        PoolStats {
            executed: c.executed.load(Ordering::Relaxed),
            stolen: c.stolen.load(Ordering::Relaxed),
            injected: c.injected.load(Ordering::Relaxed),
            rounds: c.rounds.load(Ordering::Relaxed),
            tile_tasks: c.tile_tasks.load(Ordering::Relaxed),
            graph_rounds: c.graph_rounds.load(Ordering::Relaxed),
            ready_pushes: c.ready_pushes.load(Ordering::Relaxed),
        }
    }

    /// Execute `f(start, end)` over `shards` contiguous, balanced,
    /// disjoint sub-ranges of `0..n`, concurrently on the pool (the
    /// caller works too). Blocks until every shard finished; panics if
    /// any shard panicked. Returns the effective shard count.
    pub fn run_sharded<F: Fn(usize, usize) + Sync>(&self, n: usize,
                                                   shards: usize, f: F)
                                                   -> usize {
        let shards = shards.min(n).max(1);
        if n == 0 {
            return 0;
        }
        if shards == 1 {
            f(0, n);
            return 1;
        }
        let base = n / shards;
        let rem = n % shards;
        let mut ranges = Vec::with_capacity(shards);
        let mut start = 0usize;
        for i in 0..shards {
            let len = base + usize::from(i < rem);
            ranges.push((start, start + len));
            start += len;
        }
        // Erase the closure's lifetime: the job cannot outlive this
        // frame because we block on the latch before returning.
        let f_ref: &(dyn Fn(usize, usize) + Sync) = &f;
        let f_ptr: &'static (dyn Fn(usize, usize) + Sync) =
            unsafe { std::mem::transmute(f_ref) };
        let job = Arc::new(Job {
            f: f_ptr as *const _,
            ranges,
            next: AtomicUsize::new(0),
            pending: AtomicUsize::new(shards),
            poisoned: AtomicBool::new(false),
            done: Mutex::new(false),
            cv: Condvar::new(),
        });
        // one claim hint per shard the caller won't take itself, capped
        // by the worker count — extra hints would only be popped, see
        // all shards claimed, and be dropped as no-ops
        let helpers = (shards - 1).min(self.size);
        for _ in 0..helpers {
            push_entry(&self.shared, Entry::Shards(job.clone()));
        }
        job.work();
        let mut done = lock_recover(&job.done);
        while !*done {
            done = wait_recover(&job.cv, done);
        }
        drop(done);
        if job.poisoned.load(Ordering::Relaxed) {
            panic!("a pool shard panicked");
        }
        shards
    }

    /// Like [`run_sharded`](Self::run_sharded), but shard boundaries
    /// land on multiples of `block` (the last shard absorbs the
    /// remainder), and `f` receives *item* ranges over `0..n`. Aligned
    /// boundaries keep block-tiled kernels on their full-width
    /// micro-kernel except at the very end of the range. A thin 1-D
    /// view over [`run_sharded_tiles`](Self::run_sharded_tiles)
    /// (degenerate single-column grid), kept as the simpler API for
    /// callers without a second dimension.
    pub fn run_sharded_blocks<F: Fn(usize, usize) + Sync>(
        &self, n: usize, block: usize, shards: usize, f: F) -> usize {
        self.run_sharded_tiles(n, block, 1, 1, shards,
                               |r0, r1, _c0, _c1| f(r0, r1))
    }

    /// 2-D tile scheduler: split the `m × n` iteration space into a
    /// grid of up to `shards` rectangular tiles — row boundaries on
    /// multiples of `m_block`, column boundaries on multiples of
    /// `n_block` (the last tile in each dimension absorbs the
    /// remainder) — and execute `f(r0, r1, c0, c1)` for every tile
    /// concurrently on the pool (caller participating). Each output
    /// tile is owned by exactly one executor, so kernels whose elements
    /// are computed whole inside a tile stay bit-invariant in the
    /// shard count *and* in the steal schedule.
    ///
    /// The grid is the `sm × sn` factorization (`sm` row splits ≤ the
    /// row-block count, `sn` column splits ≤ the column-block count)
    /// that maximizes tile count within the `shards` budget, breaking
    /// ties toward more M splits (a row-range tile streams fewer A rows
    /// and reuses each B panel across its whole range). The previous
    /// greedy pick `sm = mb.min(shards); sn = shards / sm` dropped
    /// parallelism whenever `shards % sm != 0` — e.g. 4 row blocks on a
    /// 6-shard budget produced a 4×1 grid (4 tiles, 2 idle workers)
    /// where 3×2 fills all 6. Returns the effective tile count.
    ///
    /// The grid is a pure function of `(m, m_block, n, n_block,
    /// shards)` and must stay **ISA-agnostic**: the SIMD microkernels
    /// in `math::gemm` pick their instruction set *inside* a tile, so
    /// the same partition (and therefore the same per-element
    /// reduction geometry) is handed to every kernel variant. Keying
    /// the grid on the host ISA would silently break the
    /// reproducible-given-config determinism tier.
    pub fn run_sharded_tiles<F: Fn(usize, usize, usize, usize) + Sync>(
        &self, m: usize, m_block: usize, n: usize, n_block: usize,
        shards: usize, f: F) -> usize {
        if m == 0 || n == 0 {
            return 0;
        }
        let (mbs, nbs) = (m_block.max(1), n_block.max(1));
        let (mb, nb) = (m.div_ceil(mbs), n.div_ceil(nbs));
        let shards = shards.max(1);
        // exhaustive factorization search — O(min(mb, shards)), and
        // shards is small (a worker-count budget)
        let (mut sm, mut sn) = (1usize, 1usize);
        for cm in 1..=mb.min(shards) {
            let cn = nb.min(shards / cm);
            if cm * cn > sm * sn || (cm * cn == sm * sn && cm > sm) {
                (sm, sn) = (cm, cn);
            }
        }
        let tiles = sm * sn;
        if tiles <= 1 {
            f(0, m, 0, n);
            return 1;
        }
        // balanced block-aligned ranges per dimension (parts <= blocks,
        // so every range is non-empty)
        let ranges = |items: usize, blocks: usize, bsz: usize,
                      parts: usize| -> Vec<(usize, usize)> {
            let (base, rem) = (blocks / parts, blocks % parts);
            let mut out = Vec::with_capacity(parts);
            let mut b0 = 0usize;
            for i in 0..parts {
                let len = base + usize::from(i < rem);
                out.push((b0 * bsz, ((b0 + len) * bsz).min(items)));
                b0 += len;
            }
            out
        };
        let rrows = ranges(m, mb, mbs, sm);
        let rcols = ranges(n, nb, nbs, sn);
        self.run_sharded(tiles, tiles, |s, e| {
            for t in s..e {
                let (r0, r1) = rrows[t / sn];
                let (c0, c1) = rcols[t % sn];
                f(r0, r1, c0, c1);
            }
        });
        tiles
    }

    /// Run `n` independent *tasks* concurrently (`f(i)` once for each
    /// `i in 0..n`), the caller participating as usual. Task
    /// granularity — one shard per task — for co-scheduling
    /// heterogeneous work items on the one global pool. Synchronous (a
    /// barrier over all `n`); the coordinator's lane runtime uses the
    /// asynchronous [`submit_round`](Self::submit_round) /
    /// [`wait_rounds`](Self::wait_rounds) pair instead, which has no
    /// such barrier. Tasks may issue nested sharded calls
    /// (deadlock-free; see module docs).
    pub fn run_tasks<F: Fn(usize) + Sync>(&self, n: usize, f: F) {
        self.run_sharded(n, n, |s, e| {
            for i in s..e {
                f(i);
            }
        });
    }

    /// Submit one round task tagged `key`: `f` runs exactly once on
    /// whichever thread pops it (a pool worker, or a driver helping in
    /// [`wait_rounds`](Self::wait_rounds)), then `(key, panicked)` is
    /// reported to `group`. Panics inside `f` are contained and
    /// reported, never unwound into the executing thread's loop.
    ///
    /// Asynchronous: this returns immediately. The submitting driver
    /// owns the key space and must keep whatever `f` captures alive
    /// (and untouched) until the key is drained from `group`.
    pub fn submit_round(&self, group: &RoundGroup, key: usize,
                        f: Box<dyn FnOnce() + Send>) {
        push_entry(&self.shared, Entry::Round {
            f,
            key,
            group: group.shared.clone(),
        });
    }

    /// Submit one compiled tile graph tagged `key`: its root tiles go
    /// to the injector immediately, every other tile is pushed by
    /// whichever thread finishes its last dependency, and the thread
    /// that retires the final node reports `(key, failed)` to `group` —
    /// the graph-shaped sibling of [`submit_round`](Self::submit_round)
    /// with zero intra-round barriers. An empty graph completes
    /// immediately (reported `(key, false)`).
    ///
    /// Asynchronous: this returns immediately. As with `submit_round`,
    /// the submitter owns the key space and must keep everything the
    /// graph's tasks capture alive (and untouched) until the key is
    /// drained from `group`.
    pub fn submit_graph(&self, group: &RoundGroup, key: usize,
                        graph: TileGraph) {
        match graph.into_shared(key, group.shared.clone()) {
            None => {
                let mut done = lock_recover(&group.shared.done);
                done.push((key, false));
                group.shared.cv.notify_all();
            }
            Some((g, roots)) => {
                for r in roots {
                    push_ready_tile(&self.shared, g.clone(), r);
                }
            }
        }
    }

    /// Execute one tile graph synchronously, the caller helping until
    /// it completes (so a single-thread pool — or a fully busy one —
    /// still finishes). Panics if any tile panicked, mirroring
    /// [`run_sharded`](Self::run_sharded)'s contract for batch callers.
    pub fn run_graph(&self, graph: TileGraph) {
        if graph.is_empty() {
            return;
        }
        let group = RoundGroup::new();
        self.submit_graph(&group, 0, graph);
        let mut out = Vec::new();
        while out.is_empty() {
            self.wait_rounds(&group, &mut out);
        }
        if out.iter().any(|&(_, failed)| failed) {
            panic!("a graph tile panicked");
        }
    }

    /// Block until `group` has at least one completed round, draining
    /// every available `(key, panicked)` completion into `out` (append;
    /// the caller clears). While waiting the driver *helps*: it
    /// executes queued pool entries — preferring the newest injected
    /// entry, i.e. its own just-submitted rounds — instead of idling,
    /// so a single-worker pool still overlaps a driver's lanes. Only
    /// call with at least one undrained key in flight, or this blocks
    /// forever. Returns the number of completions drained.
    pub fn wait_rounds(&self, group: &RoundGroup,
                       out: &mut Vec<(usize, bool)>) -> usize {
        loop {
            {
                let mut done = lock_recover(&group.shared.done);
                if !done.is_empty() {
                    let n = done.len();
                    out.append(&mut done);
                    return n;
                }
            }
            if let Some(entry) = find_work(&self.shared, Scan::Helper) {
                execute_entry(&self.shared, entry);
                continue;
            }
            // nothing to help with: park on the group mailbox — the
            // completing thread notifies under the same lock, so the
            // re-check below cannot miss it
            let done = lock_recover(&group.shared.done);
            if done.is_empty() {
                drop(wait_recover(&group.shared.cv, done));
            }
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            // flip the flag under the sleep lock: a worker between its
            // pending re-check and cv.wait is serialized against us, so
            // it either sees shutdown or is already parked when
            // notify_all fires — no lost wakeup
            let _guard = lock_recover(&self.shared.sleep);
            self.shared.shutdown.store(true, Ordering::SeqCst);
        }
        self.shared.wake.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Interpret an `ASD_POOL_THREADS` value: `Ok(n >= 1)`, or a
/// diagnostic for unusable values (not an integer, or zero — a
/// zero-thread pool cannot exist, so treating `0` as "decide for me"
/// silently would hide the typo).
pub fn parse_pool_threads(raw: &str) -> Result<usize, String> {
    match raw.trim().parse::<usize>() {
        Ok(0) => Err("ASD_POOL_THREADS=0 is not a valid worker count \
                      (need >= 1)"
            .to_string()),
        Ok(n) => Ok(n),
        Err(e) => Err(format!(
            "ASD_POOL_THREADS='{raw}' is not a worker count ({e})")),
    }
}

/// Worker-thread count for the global pool: `ASD_POOL_THREADS` if set
/// and valid, else the machine's available parallelism. An *invalid*
/// value no longer falls through silently — it is reported once to
/// stderr, because a typo'd `ASD_POOL_THREADS=o8` silently running on
/// all cores (or a benchmark matrix silently ignoring its pin) is
/// exactly the kind of misconfiguration that invalidates measurements.
pub fn default_threads() -> usize {
    let fallback = || {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    };
    match std::env::var("ASD_POOL_THREADS") {
        Ok(raw) => match parse_pool_threads(&raw) {
            Ok(n) => n,
            Err(msg) => {
                static WARNED: std::sync::Once = std::sync::Once::new();
                WARNED.call_once(|| {
                    eprintln!("[asd::runtime::pool] {msg}; falling back \
                               to available parallelism");
                });
                fallback()
            }
        },
        Err(_) => fallback(),
    }
}

static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();

/// The process-wide pool (the "one global pool" rule). Initialized
/// lazily on first sharded call; never torn down.
pub fn global() -> &'static ThreadPool {
    GLOBAL.get_or_init(|| ThreadPool::new(default_threads()))
}

/// The global pool's scheduling counters — zeros if no sharded call
/// ever forced pool creation (metrics readers must not themselves spawn
/// the worker set).
pub fn global_stats() -> PoolStats {
    GLOBAL.get().map(ThreadPool::stats).unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_every_index_exactly_once() {
        let pool = ThreadPool::new(3);
        for n in [1usize, 2, 3, 5, 7, 16, 33] {
            for shards in [1usize, 2, 3, 4, 8, 40] {
                let hits: Vec<AtomicUsize> =
                    (0..n).map(|_| AtomicUsize::new(0)).collect();
                let eff = pool.run_sharded(n, shards, |s, e| {
                    for i in s..e {
                        hits[i].fetch_add(1, Ordering::Relaxed);
                    }
                });
                assert!(eff >= 1 && eff <= shards.max(1).min(n));
                for (i, h) in hits.iter().enumerate() {
                    assert_eq!(h.load(Ordering::Relaxed), 1,
                               "index {i} (n={n} shards={shards})");
                }
            }
        }
    }

    #[test]
    fn block_sharding_covers_all_items_on_aligned_boundaries() {
        let pool = ThreadPool::new(3);
        for n in [0usize, 1, 3, 4, 5, 16, 17, 31] {
            for block in [1usize, 2, 4, 7] {
                for shards in [1usize, 2, 3, 8] {
                    let hits: Vec<AtomicUsize> =
                        (0..n).map(|_| AtomicUsize::new(0)).collect();
                    pool.run_sharded_blocks(n, block, shards, |s, e| {
                        assert!(s % block == 0,
                                "unaligned shard start {s} (block {block})");
                        assert!(e == n || e % block == 0);
                        for i in s..e {
                            hits[i].fetch_add(1, Ordering::Relaxed);
                        }
                    });
                    for (i, h) in hits.iter().enumerate() {
                        assert_eq!(h.load(Ordering::Relaxed), 1,
                                   "item {i} (n={n} block={block} \
                                    shards={shards})");
                    }
                }
            }
        }
    }

    #[test]
    fn tile_sharding_covers_every_cell_exactly_once_on_aligned_bounds() {
        let pool = ThreadPool::new(3);
        for (m, n) in [(0usize, 5usize), (5, 0), (1, 1), (4, 128), (37, 19),
                       (16, 40), (3, 9)] {
            for (mb, nb) in [(1usize, 1usize), (4, 8), (7, 3)] {
                for shards in [1usize, 2, 8, 64] {
                    let hits: Vec<AtomicUsize> =
                        (0..m * n).map(|_| AtomicUsize::new(0)).collect();
                    let eff = pool.run_sharded_tiles(
                        m, mb, n, nb, shards, |r0, r1, c0, c1| {
                            assert!(r0 % mb == 0 && c0 % nb == 0,
                                    "unaligned tile start ({r0},{c0})");
                            assert!(r1 == m || r1 % mb == 0);
                            assert!(c1 == n || c1 % nb == 0);
                            for i in r0..r1 {
                                for j in c0..c1 {
                                    hits[i * n + j]
                                        .fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        });
                    if m == 0 || n == 0 {
                        assert_eq!(eff, 0);
                        continue;
                    }
                    assert!(eff >= 1 && eff <= shards.max(1),
                            "eff={eff} shards={shards}");
                    for (i, h) in hits.iter().enumerate() {
                        assert_eq!(h.load(Ordering::Relaxed), 1,
                                   "cell {i} (m={m} n={n} mb={mb} nb={nb} \
                                    shards={shards})");
                    }
                }
            }
        }
    }

    #[test]
    fn tile_grid_fans_out_over_columns_when_m_is_one_block() {
        // the small-M serving case: a single row block must still
        // produce > 1 tile by splitting the column dimension
        let pool = ThreadPool::new(3);
        let eff = pool.run_sharded_tiles(4, 4, 64, 8, 8, |_, _, _, _| {});
        assert!(eff > 1, "single-row-block grid stayed serial (eff={eff})");
        // and a square grid fills the shard budget without exceeding it
        let eff = pool.run_sharded_tiles(64, 4, 64, 8, 8, |_, _, _, _| {});
        assert!(eff >= 8 / 2 && eff <= 8);
    }

    #[test]
    fn tile_grid_factorization_maximizes_utilization() {
        let pool = ThreadPool::new(3);
        // the regression case: 4 row blocks (m=16, m_block=4) on a
        // 6-shard budget. The old greedy pick produced a 4×1 grid (4
        // tiles, 2 idle workers); the factorization search must find
        // 3×2 = 6.
        let tiles = AtomicUsize::new(0);
        let eff = pool.run_sharded_tiles(16, 4, 48, 8, 6, |_, _, _, _| {
            tiles.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(eff, 6, "factorization left shards idle");
        assert_eq!(tiles.load(Ordering::Relaxed), 6);
        // when an exact fill is impossible, it still maximizes: 3 row
        // blocks × 1 column block on 2 shards → 2×1
        assert_eq!(pool.run_sharded_tiles(3, 1, 1, 1, 2, |_, _, _, _| {}),
                   2);
        // ties break toward M splits: 8×8 blocks on 8 shards is 8×1,
        // never 1×8 or 2×4 (full-M split streams B panels once)
        let mut max_rows = 0usize;
        let rows = Mutex::new(&mut max_rows);
        pool.run_sharded_tiles(8, 1, 8, 1, 8, |r0, r1, _, _| {
            let mut g = rows.lock().unwrap();
            **g = (**g).max(r1 - r0);
        });
        assert_eq!(max_rows, 1, "tie did not prefer the M split");
    }

    #[test]
    fn run_tasks_executes_each_task_exactly_once() {
        let pool = ThreadPool::new(3);
        for n in [0usize, 1, 2, 5, 17] {
            let hits: Vec<AtomicUsize> =
                (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.run_tasks(n, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "task {i} (n={n})");
            }
        }
        // tasks nesting sharded calls complete (the lane round pattern)
        let total = AtomicUsize::new(0);
        global().run_tasks(3, |_| {
            global().run_sharded(8, 4, |s, e| {
                total.fetch_add(e - s, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 24);
    }

    #[test]
    fn zero_items_is_a_noop() {
        let pool = ThreadPool::new(2);
        let eff = pool.run_sharded(0, 4, |_, _| panic!("must not run"));
        assert_eq!(eff, 0);
    }

    #[test]
    fn results_accumulate_across_shards() {
        let pool = ThreadPool::new(4);
        let n = 1000usize;
        let total = AtomicU64::new(0);
        pool.run_sharded(n, 4, |s, e| {
            let part: u64 = (s..e).map(|i| i as u64).sum();
            total.fetch_add(part, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed),
                   (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn shard_writes_are_visible_to_caller() {
        let pool = ThreadPool::new(4);
        let n = 64usize;
        let mut out = vec![0.0f64; n];
        let ptr = out.as_mut_ptr() as usize;
        pool.run_sharded(n, 8, |s, e| {
            // disjoint ranges: aliasing-free by construction
            let slice = unsafe {
                std::slice::from_raw_parts_mut((ptr as *mut f64).add(s), e - s)
            };
            for (off, v) in slice.iter_mut().enumerate() {
                *v = (s + off) as f64 * 2.0;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as f64 * 2.0);
        }
    }

    #[test]
    fn nested_calls_complete() {
        // a shard issuing its own sharded call must not deadlock: the
        // inner caller participates and drains its own shards
        let pool = global();
        let outer_hits = AtomicUsize::new(0);
        pool.run_sharded(4, 2, |s, e| {
            for _ in s..e {
                let inner_hits = AtomicUsize::new(0);
                global().run_sharded(6, 3, |is, ie| {
                    inner_hits.fetch_add(ie - is, Ordering::Relaxed);
                });
                assert_eq!(inner_hits.load(Ordering::Relaxed), 6);
                outer_hits.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert_eq!(outer_hits.load(Ordering::Relaxed), 4);
    }

    #[test]
    #[should_panic(expected = "pool shard panicked")]
    fn shard_panic_propagates_to_caller() {
        let pool = ThreadPool::new(2);
        pool.run_sharded(8, 4, |s, _| {
            if s == 0 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn pool_survives_a_panicked_job() {
        let pool = ThreadPool::new(2);
        let got_panic = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                pool.run_sharded(8, 4, |_, _| panic!("boom"));
            }))
            .is_err();
        assert!(got_panic);
        // workers caught the panic and still serve
        let count = AtomicUsize::new(0);
        pool.run_sharded(10, 4, |s, e| {
            count.fetch_add(e - s, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn panic_in_one_shard_leaves_pool_serviceable_under_stress() {
        // the poison-cascade regression: repeated panic-in-one-shard
        // waves must leave every worker alive and the pool fully
        // serviceable — both for sharded calls and for round tasks
        let pool = ThreadPool::new(3);
        for wave in 0..20usize {
            let got_panic = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| {
                    pool.run_sharded(12, 6, |s, _| {
                        if s == 4 {
                            panic!("shard boom {wave}");
                        }
                    });
                }))
                .is_err();
            assert!(got_panic, "wave {wave} swallowed the shard panic");
            let count = AtomicUsize::new(0);
            pool.run_sharded(9, 3, |s, e| {
                count.fetch_add(e - s, Ordering::Relaxed);
            });
            assert_eq!(count.load(Ordering::Relaxed), 9, "wave {wave}");
        }
        let group = RoundGroup::new();
        pool.submit_round(&group, 0, Box::new(|| {}));
        let mut out = Vec::new();
        pool.wait_rounds(&group, &mut out);
        assert_eq!(out, vec![(0, false)]);
    }

    #[test]
    fn pool_recovers_poisoned_mutexes() {
        // deliberately poison the pool's own mutexes (panic while
        // holding each guard) and verify the pool still schedules and
        // drops cleanly — the old `.unwrap()` guards turned this state
        // into pool-wide worker death plus a panic-in-drop abort
        let pool = ThreadPool::new(2);
        for which in 0..3usize {
            let shared = pool.shared.clone();
            let _ = std::thread::spawn(move || {
                let _g = match which {
                    0 => lock_recover(&shared.injector),
                    1 => {
                        let _s = lock_recover(&shared.sleep);
                        panic!("poison sleep");
                    }
                    _ => lock_recover(&shared.deques[0]),
                };
                panic!("poison queue {which}");
            })
            .join();
        }
        assert!(pool.shared.injector.is_poisoned());
        assert!(pool.shared.sleep.is_poisoned());
        assert!(pool.shared.deques[0].is_poisoned());
        // sharded calls still complete through the poisoned locks
        let count = AtomicUsize::new(0);
        pool.run_sharded(16, 4, |s, e| {
            count.fetch_add(e - s, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 16);
        // round tasks too
        let group = RoundGroup::new();
        pool.submit_round(&group, 9, Box::new(|| {}));
        let mut out = Vec::new();
        pool.wait_rounds(&group, &mut out);
        assert_eq!(out, vec![(9, false)]);
        drop(pool); // must not panic-in-drop on the poisoned mutexes
    }

    #[test]
    fn round_tasks_complete_and_report_their_keys() {
        let pool = ThreadPool::new(2);
        let group = RoundGroup::new();
        let hits: Vec<AtomicUsize> =
            (0..5).map(|_| AtomicUsize::new(0)).collect();
        let hits = Arc::new(hits);
        for key in 0..5usize {
            let h = hits.clone();
            pool.submit_round(&group, key, Box::new(move || {
                h[key].fetch_add(1, Ordering::Relaxed);
            }));
        }
        let mut out = Vec::new();
        while out.len() < 5 {
            pool.wait_rounds(&group, &mut out);
        }
        let mut keys: Vec<usize> = out.iter().map(|&(k, _)| k).collect();
        keys.sort_unstable();
        assert_eq!(keys, vec![0, 1, 2, 3, 4]);
        assert!(out.iter().all(|&(_, panicked)| !panicked));
        for (key, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "round {key}");
        }
        let stats = pool.stats();
        assert!(stats.rounds >= 5, "rounds executed {}", stats.rounds);
        assert!(stats.injected >= 5, "injected {}", stats.injected);
        assert!(stats.executed >= 5, "executed {}", stats.executed);
    }

    #[test]
    fn round_task_panic_is_reported_not_fatal() {
        let pool = ThreadPool::new(2);
        let group = RoundGroup::new();
        pool.submit_round(&group, 3, Box::new(|| panic!("round boom")));
        let mut out = Vec::new();
        pool.wait_rounds(&group, &mut out);
        assert_eq!(out, vec![(3, true)]);
        // the executing thread survived; both work kinds still serve
        pool.submit_round(&group, 4, Box::new(|| {}));
        out.clear();
        pool.wait_rounds(&group, &mut out);
        assert_eq!(out, vec![(4, false)]);
        let count = AtomicUsize::new(0);
        pool.run_sharded(8, 4, |s, e| {
            count.fetch_add(e - s, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn graph_runs_each_node_once_in_dependency_order() {
        // diamond: 0 → {1, 2} → 3, run synchronously; every node runs
        // exactly once and never before its dependencies
        let pool = ThreadPool::new(2);
        let order = Arc::new(Mutex::new(Vec::new()));
        let mut g = TileGraph::new();
        let o = order.clone();
        let n0 = g.add_node(&[], move || o.lock().unwrap().push(0usize));
        let o = order.clone();
        let n1 = g.add_node(&[n0], move || o.lock().unwrap().push(1));
        let o = order.clone();
        let n2 = g.add_node(&[n0], move || o.lock().unwrap().push(2));
        let o = order.clone();
        let n3 = g.add_node(&[n1, n2], move || o.lock().unwrap().push(3));
        assert_eq!((n0, n1, n2, n3), (0, 1, 2, 3));
        assert_eq!(g.len(), 4);
        pool.run_graph(g);
        let order = order.lock().unwrap().clone();
        assert_eq!(order.len(), 4, "order={order:?}");
        let pos = |v: usize| order.iter().position(|&x| x == v).unwrap();
        assert_eq!(pos(0), 0, "root did not run first: {order:?}");
        assert_eq!(pos(3), 3, "join did not run last: {order:?}");
        let stats = pool.stats();
        assert_eq!(stats.tile_tasks, 4);
        assert_eq!(stats.graph_rounds, 1);
        assert_eq!(stats.ready_pushes, 4);
    }

    #[test]
    fn empty_graph_completes_immediately() {
        let pool = ThreadPool::new(1);
        pool.run_graph(TileGraph::new()); // must not block or panic
        let group = RoundGroup::new();
        pool.submit_graph(&group, 7, TileGraph::new());
        let mut out = Vec::new();
        pool.wait_rounds(&group, &mut out);
        assert_eq!(out, vec![(7, false)]);
        assert_eq!(pool.stats().graph_rounds, 0);
    }

    #[test]
    fn two_graphs_interleave_on_a_single_worker() {
        // the layer-boundary overlap property: two chain graphs (two
        // lanes' fused rounds) submitted to a 1-worker pool must make
        // progress together — some lane-B tile executes between lane-A
        // tiles — because ready tiles sit FIFO on the shared injector
        // instead of one chain fork/joining the pool per layer
        let pool = ThreadPool::new(1);
        let group = RoundGroup::new();
        let logv: Arc<Mutex<Vec<(usize, usize)>>> =
            Arc::new(Mutex::new(Vec::new()));
        for lane in 0..2usize {
            let mut g = TileGraph::new();
            let mut prev: Option<usize> = None;
            for layer in 0..8usize {
                let l = logv.clone();
                let deps: Vec<usize> = prev.into_iter().collect();
                prev = Some(g.add_node(&deps, move || {
                    std::thread::sleep(
                        std::time::Duration::from_millis(2));
                    l.lock().unwrap().push((lane, layer));
                }));
            }
            pool.submit_graph(&group, lane, g);
        }
        let mut out = Vec::new();
        while out.len() < 2 {
            pool.wait_rounds(&group, &mut out);
        }
        assert!(out.iter().all(|&(_, failed)| !failed));
        let logv = logv.lock().unwrap().clone();
        assert_eq!(logv.len(), 16);
        // each lane's own chain is ordered...
        for lane in 0..2usize {
            let layers: Vec<usize> = logv.iter()
                .filter(|&&(l, _)| l == lane)
                .map(|&(_, lay)| lay)
                .collect();
            assert_eq!(layers, (0..8).collect::<Vec<_>>(),
                       "lane {lane} chain ran out of order: {logv:?}");
        }
        // ...and the lanes interleave: lane 1 must appear strictly
        // between two lane-0 tiles (and vice versa)
        let first = |lane| logv.iter()
            .position(|&(l, _)| l == lane).unwrap();
        let last = |lane| logv.iter()
            .rposition(|&(l, _)| l == lane).unwrap();
        assert!(first(1) < last(0) && first(0) < last(1),
                "lanes ran back-to-back, no overlap: {logv:?}");
    }

    #[test]
    fn mid_graph_tile_panic_cancels_dependents_and_reports() {
        // chain 0 → 1(panics) → 2 → 3: the round reports failed, the
        // dependents never fire, and the pool keeps serving graphs
        let pool = ThreadPool::new(2);
        let group = RoundGroup::new();
        let ran_after = Arc::new(AtomicUsize::new(0));
        let mut g = TileGraph::new();
        let n0 = g.add_node(&[], || {});
        let n1 = g.add_node(&[n0], || panic!("tile boom"));
        let r = ran_after.clone();
        let n2 = g.add_node(&[n1], move || {
            r.fetch_add(1, Ordering::Relaxed);
        });
        let r = ran_after.clone();
        g.add_node(&[n2], move || {
            r.fetch_add(1, Ordering::Relaxed);
        });
        pool.submit_graph(&group, 5, g);
        let mut out = Vec::new();
        while out.is_empty() {
            pool.wait_rounds(&group, &mut out);
        }
        assert_eq!(out, vec![(5, true)], "panic not reported");
        assert_eq!(ran_after.load(Ordering::Relaxed), 0,
                   "a dependent of the panicked tile fired");
        // the pool and the group survive: the next graph completes
        let ok = Arc::new(AtomicUsize::new(0));
        let mut g = TileGraph::new();
        let o = ok.clone();
        let a = g.add_node(&[], move || {
            o.fetch_add(1, Ordering::Relaxed);
        });
        let o = ok.clone();
        g.add_node(&[a], move || {
            o.fetch_add(1, Ordering::Relaxed);
        });
        pool.submit_graph(&group, 6, g);
        out.clear();
        while out.is_empty() {
            pool.wait_rounds(&group, &mut out);
        }
        assert_eq!(out, vec![(6, false)]);
        assert_eq!(ok.load(Ordering::Relaxed), 2);
    }

    #[test]
    #[should_panic(expected = "graph tile panicked")]
    fn run_graph_propagates_tile_panic_to_caller() {
        let pool = ThreadPool::new(2);
        let mut g = TileGraph::new();
        let n0 = g.add_node(&[], || {});
        g.add_node(&[n0], || panic!("boom"));
        pool.run_graph(g);
    }

    #[test]
    fn waiting_driver_helps_execute_rounds() {
        // single-worker pool: occupy the worker with a gated round,
        // then submit a second round — the driver blocked in
        // wait_rounds must steal and execute it itself (this is the
        // property that keeps a one-thread pool's lanes overlapped)
        let pool = ThreadPool::new(1);
        let group = RoundGroup::new();
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let g = gate.clone();
        pool.submit_round(&group, 0, Box::new(move || {
            let (lock, cv) = &*g;
            let mut open = lock.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
        }));
        // let the worker pop the gated round before queueing the next
        std::thread::sleep(std::time::Duration::from_millis(100));
        // safety net: a detached opener fires the gate eventually, so a
        // helping-logic regression fails the assertion instead of
        // hanging the suite
        let g = gate.clone();
        let _opener = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_secs(10));
            let (lock, cv) = &*g;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        });
        pool.submit_round(&group, 1, Box::new(|| {}));
        let mut out = Vec::new();
        pool.wait_rounds(&group, &mut out);
        assert_eq!(out, vec![(1, false)],
                   "driver did not execute the queued round itself");
        let (lock, cv) = &*gate;
        *lock.lock().unwrap() = true;
        cv.notify_all();
        while !out.iter().any(|&(k, _)| k == 0) {
            pool.wait_rounds(&group, &mut out);
        }
    }

    #[test]
    fn workers_steal_across_deques() {
        // a round task executing on one worker shards a nested call:
        // its claim hints land on that worker's own deque, and the
        // sibling workers must steal them (observable in the stolen
        // counter — with 4 workers and repeated 8-way jobs inside a
        // round, at least one hint is overwhelmingly likely stolen; a
        // zero steal count would mean the topology is wired wrong)
        let pool = ThreadPool::new(4);
        let group = RoundGroup::new();
        let total = Arc::new(AtomicUsize::new(0));
        let t = total.clone();
        // pool reference smuggled as a raw pointer: the test blocks in
        // wait_rounds until the round completes, outliving the task
        struct SendPool(*const ThreadPool);
        unsafe impl Send for SendPool {}
        let p = SendPool(&pool as *const ThreadPool);
        pool.submit_round(&group, 0, Box::new(move || {
            let pool = unsafe { &*p.0 };
            for _ in 0..50 {
                pool.run_sharded(64, 8, |s, e| {
                    t.fetch_add(e - s, Ordering::Relaxed);
                    std::thread::sleep(
                        std::time::Duration::from_micros(200));
                });
            }
        }));
        let mut out = Vec::new();
        pool.wait_rounds(&group, &mut out);
        assert_eq!(out, vec![(0, false)]);
        assert_eq!(total.load(Ordering::Relaxed), 50 * 64);
        let stats = pool.stats();
        assert!(stats.stolen > 0,
                "no steals across {} executed entries", stats.executed);
    }

    #[test]
    fn pool_threads_parsing() {
        assert_eq!(parse_pool_threads("8"), Ok(8));
        assert_eq!(parse_pool_threads(" 4 "), Ok(4));
        assert_eq!(parse_pool_threads("1"), Ok(1));
        // zero is invalid, not "one" and not "auto"
        assert!(parse_pool_threads("0").unwrap_err().contains(">= 1"));
        // garbage is diagnosed, not swallowed
        assert!(parse_pool_threads("o8").unwrap_err().contains("o8"));
        assert!(parse_pool_threads("").is_err());
        assert!(parse_pool_threads("-2").is_err());
        // unset (whatever the ambient env) always yields a usable count
        assert!(default_threads() >= 1);
    }

    #[test]
    fn shards_for_caps_by_batch_and_min() {
        let cfg = PoolConfig { pool_size: 8, shard_min: 2 };
        assert_eq!(cfg.shards_for(0), 1);
        assert_eq!(cfg.shards_for(1), 1);
        assert_eq!(cfg.shards_for(2), 1); // n <= shard_min stays inline
        assert_eq!(cfg.shards_for(3), 2);
        assert_eq!(cfg.shards_for(7), 4);
        assert_eq!(cfg.shards_for(100), 8);
        let inline = PoolConfig::default();
        assert_eq!(inline.shards_for(100), 1);
        assert!(!inline.parallel());
        assert!(PoolConfig::sharded(4).parallel());
    }
}
