//! Scripted expert (P-controller over the current leg) — mirror of
//! python envs.expert_action. Used in rust only for env-parity tests and
//! the expert-baseline row of the robot-control experiments (demos for
//! training are generated on the python side).

use crate::env::point_mass::{LegKind, PointMassEnv};
use crate::rng::Philox;

pub const KP: f64 = 4.0;
pub const GRIP_CLOSE_FRAC: f64 = 0.9;

fn dist(a: &[f64; 2], b: &[f64; 2]) -> f64 {
    ((a[0] - b[0]).powi(2) + (a[1] - b[1]).powi(2)).sqrt()
}

/// Expert action; `rng = None` gives the noiseless deterministic expert
/// (golden traces add noise from an explicit recorded sequence instead).
pub fn expert_action(env: &PointMassEnv, rng: Option<&mut Philox>) -> Vec<f64> {
    let s = &env.spec;
    let mut act = vec![0.0; s.action_dim()];
    let leg = s.legs.get(env.leg_idx);
    for a in 0..s.n_arms {
        let (tgt, grip_cmd) = if let Some(leg) = leg.filter(|l| l.arm == a) {
            match leg.kind {
                LegKind::Grasp => {
                    let close = dist(&env.ee[a], &env.obj)
                        < leg.tol * GRIP_CLOSE_FRAC;
                    (env.obj, if close { 1.0 } else { -1.0 })
                }
                LegKind::Via => {
                    let t = leg.target.unwrap();
                    ([t.0, t.1], 1.0)
                }
                LegKind::Place => {
                    let t = leg.target.unwrap();
                    let near = dist(&env.ee[a], &[t.0, t.1])
                        < leg.tol * GRIP_CLOSE_FRAC;
                    ([t.0, t.1], if near { -1.0 } else { 1.0 })
                }
            }
        } else {
            (next_target_for_arm(env, a), -1.0)
        };
        act[7 * a] = (KP * (tgt[0] - env.ee[a][0])).clamp(-1.0, 1.0);
        act[7 * a + 1] = (KP * (tgt[1] - env.ee[a][1])).clamp(-1.0, 1.0);
        act[7 * a + 2] = grip_cmd;
    }
    if let Some(rng) = rng {
        for v in act.iter_mut() {
            *v = (*v + s.expert_noise * rng.normal()).clamp(-1.0, 1.0);
        }
    }
    act
}

fn next_target_for_arm(env: &PointMassEnv, arm: usize) -> [f64; 2] {
    for leg in &env.spec.legs[env.leg_idx.min(env.spec.legs.len())..] {
        if leg.arm == arm {
            return match leg.kind {
                LegKind::Grasp => env.obj,
                _ => {
                    let t = leg.target.unwrap();
                    [t.0, t.1]
                }
            };
        }
    }
    env.ee[arm]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::point_mass::TaskSpec;

    #[test]
    fn noiseless_expert_solves_every_task() {
        for spec in [TaskSpec::square(), TaskSpec::transport(),
                     TaskSpec::toolhang()] {
            let name = spec.name;
            let mut env = PointMassEnv::new(spec);
            let mut rng = Philox::new(10, 0);
            let mut ok = 0;
            let n = 20;
            for _ in 0..n {
                env.reset(&mut rng);
                while !env.done() {
                    let a = expert_action(&env, None);
                    env.step(&a);
                }
                ok += env.success() as usize;
            }
            assert_eq!(ok, n, "noiseless expert failed on {name}");
        }
    }

    #[test]
    fn noisy_expert_mostly_succeeds() {
        for spec in [TaskSpec::square(), TaskSpec::transport(),
                     TaskSpec::toolhang()] {
            let name = spec.name;
            let mut env = PointMassEnv::new(spec);
            let mut rng = Philox::new(11, 0);
            let mut noise_rng = Philox::new(12, 0);
            let mut ok = 0;
            let n = 30;
            for _ in 0..n {
                env.reset(&mut rng);
                while !env.done() {
                    let a = expert_action(&env, Some(&mut noise_rng));
                    env.step(&a);
                }
                ok += env.success() as usize;
            }
            assert!(ok as f64 / n as f64 > 0.6,
                    "noisy expert only {ok}/{n} on {name}");
        }
    }
}
