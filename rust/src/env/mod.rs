//! Simulated robot-control environments (Robomimic stand-ins).
//!
//! Deterministic point-mass kinematics mirrored line-for-line from
//! python/compile/envs.py (the datagen side); golden traces exported by
//! aot.py pin the two implementations together
//! (tests/test_env_parity.rs).

pub mod expert;
pub mod point_mass;
pub mod rollout;

pub use expert::expert_action;
pub use point_mass::{Leg, LegKind, PointMassEnv, TaskSpec, DT};
pub use rollout::{rollout_policy, DiffusionPolicy, RolloutResult, SamplerKind};
