//! Point-mass manipulation tasks (mirror of python/compile/envs.py).
//!
//! n_arms point masses with 2-D position and binary gripper; action is
//! 7-D per arm ([dx, dy, grip, 4 unused] — the paper's 7-DoF action
//! space); an episode is a sequence of legs (GRASP / VIA / PLACE).
//! Success = all legs done within max_steps. See DESIGN.md §7.

use crate::rng::Philox;

pub const DT: f64 = 0.05;
pub const ACTION_DIM_PER_ARM: usize = 7;
pub const CHUNK: usize = 16;
pub const EXEC_STEPS: usize = 8;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LegKind {
    Grasp,
    Via,
    Place,
}

#[derive(Debug, Clone)]
pub struct Leg {
    pub arm: usize,
    pub kind: LegKind,
    pub target: Option<(f64, f64)>,
    pub tol: f64,
}

#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub name: &'static str,
    pub n_arms: usize,
    pub obj_box: (f64, f64, f64, f64),
    pub ee_start: Vec<(f64, f64, f64, f64)>,
    pub legs: Vec<Leg>,
    pub max_steps: usize,
    pub expert_noise: f64,
}

impl TaskSpec {
    pub fn action_dim(&self) -> usize {
        ACTION_DIM_PER_ARM * self.n_arms
    }

    pub fn obs_dim(&self) -> usize {
        3 * self.n_arms + 2 + (self.n_arms + 1) + 1 + 2
    }

    pub fn chunk_dim(&self) -> usize {
        CHUNK * self.action_dim()
    }

    pub fn square() -> TaskSpec {
        TaskSpec {
            name: "square",
            n_arms: 1,
            obj_box: (0.55, 0.15, 0.85, 0.45),
            ee_start: vec![(0.05, 0.05, 0.30, 0.30)],
            legs: vec![
                Leg { arm: 0, kind: LegKind::Grasp, target: None, tol: 0.05 },
                Leg { arm: 0, kind: LegKind::Place, target: Some((0.30, 0.70)), tol: 0.06 },
            ],
            max_steps: 100,
            expert_noise: 0.07,
        }
    }

    pub fn transport() -> TaskSpec {
        TaskSpec {
            name: "transport",
            n_arms: 2,
            obj_box: (0.10, 0.40, 0.30, 0.60),
            ee_start: vec![(0.05, 0.05, 0.25, 0.25), (0.75, 0.75, 0.95, 0.95)],
            legs: vec![
                Leg { arm: 0, kind: LegKind::Grasp, target: None, tol: 0.05 },
                Leg { arm: 0, kind: LegKind::Place, target: Some((0.50, 0.50)), tol: 0.05 },
                Leg { arm: 1, kind: LegKind::Grasp, target: None, tol: 0.05 },
                Leg { arm: 1, kind: LegKind::Place, target: Some((0.85, 0.50)), tol: 0.07 },
            ],
            max_steps: 160,
            expert_noise: 0.07,
        }
    }

    pub fn toolhang() -> TaskSpec {
        TaskSpec {
            name: "toolhang",
            n_arms: 1,
            obj_box: (0.15, 0.10, 0.45, 0.30),
            ee_start: vec![(0.60, 0.60, 0.85, 0.85)],
            legs: vec![
                Leg { arm: 0, kind: LegKind::Grasp, target: None, tol: 0.035 },
                Leg { arm: 0, kind: LegKind::Via, target: Some((0.50, 0.35)), tol: 0.035 },
                Leg { arm: 0, kind: LegKind::Via, target: Some((0.55, 0.75)), tol: 0.035 },
                Leg { arm: 0, kind: LegKind::Place, target: Some((0.62, 0.80)), tol: 0.035 },
            ],
            max_steps: 120,
            expert_noise: 0.12,
        }
    }

    pub fn by_name(name: &str) -> Option<TaskSpec> {
        match name {
            "square" => Some(TaskSpec::square()),
            "transport" => Some(TaskSpec::transport()),
            "toolhang" => Some(TaskSpec::toolhang()),
            _ => None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct PointMassEnv {
    pub spec: TaskSpec,
    pub ee: Vec<[f64; 2]>,
    pub grip: Vec<bool>,
    pub obj: [f64; 2],
    /// -1 = free, else arm index
    pub carried: i64,
    pub leg_idx: usize,
    pub steps: usize,
    pub failed: bool,
}

fn dist(a: &[f64; 2], b: &[f64; 2]) -> f64 {
    ((a[0] - b[0]).powi(2) + (a[1] - b[1]).powi(2)).sqrt()
}

impl PointMassEnv {
    pub fn new(spec: TaskSpec) -> PointMassEnv {
        let n = spec.n_arms;
        PointMassEnv {
            spec,
            ee: vec![[0.0, 0.0]; n],
            grip: vec![false; n],
            obj: [0.0, 0.0],
            carried: -1,
            leg_idx: 0,
            steps: 0,
            failed: false,
        }
    }

    pub fn reset(&mut self, rng: &mut Philox) {
        for (a, b) in self.ee.iter_mut().zip(&self.spec.ee_start) {
            a[0] = b.0 + rng.uniform() * (b.2 - b.0);
            a[1] = b.1 + rng.uniform() * (b.3 - b.1);
        }
        let b = self.spec.obj_box;
        self.obj = [b.0 + rng.uniform() * (b.2 - b.0),
                    b.1 + rng.uniform() * (b.3 - b.1)];
        self.grip.iter_mut().for_each(|g| *g = false);
        self.carried = -1;
        self.leg_idx = 0;
        self.steps = 0;
        self.failed = false;
    }

    /// Reset to an explicit state (golden-trace parity).
    pub fn reset_to(&mut self, ee: &[[f64; 2]], obj: [f64; 2]) {
        self.ee.copy_from_slice(ee);
        self.obj = obj;
        self.grip.iter_mut().for_each(|g| *g = false);
        self.carried = -1;
        self.leg_idx = 0;
        self.steps = 0;
        self.failed = false;
    }

    pub fn obs(&self) -> Vec<f64> {
        let s = &self.spec;
        let mut o = Vec::with_capacity(s.obs_dim());
        for ee in &self.ee {
            o.push(ee[0]);
            o.push(ee[1]);
        }
        for &g in &self.grip {
            o.push(if g { 1.0 } else { 0.0 });
        }
        o.push(self.obj[0]);
        o.push(self.obj[1]);
        for c in -1..(s.n_arms as i64) {
            o.push(if self.carried == c { 1.0 } else { 0.0 });
        }
        o.push(self.leg_idx as f64 / s.legs.len() as f64);
        let tgt = self.current_target();
        o.push(tgt[0]);
        o.push(tgt[1]);
        o
    }

    pub fn current_target(&self) -> [f64; 2] {
        if self.leg_idx < self.spec.legs.len() {
            let leg = &self.spec.legs[self.leg_idx];
            match leg.kind {
                LegKind::Grasp => self.obj,
                _ => {
                    let t = leg.target.unwrap();
                    [t.0, t.1]
                }
            }
        } else {
            self.obj
        }
    }

    pub fn done(&self) -> bool {
        self.leg_idx >= self.spec.legs.len() || self.failed
            || self.steps >= self.spec.max_steps
    }

    pub fn success(&self) -> bool {
        self.leg_idx >= self.spec.legs.len() && !self.failed
    }

    pub fn step(&mut self, action: &[f64]) {
        let s = self.spec.clone();
        debug_assert_eq!(action.len(), s.action_dim());
        self.steps += 1;
        for a in 0..s.n_arms {
            let dx = action[7 * a].clamp(-1.0, 1.0);
            let dy = action[7 * a + 1].clamp(-1.0, 1.0);
            self.ee[a][0] += DT * dx;
            self.ee[a][1] += DT * dy;
            self.grip[a] = action[7 * a + 2] > 0.0;
        }

        // dropping: the carrier opened its grip
        if self.carried >= 0 && !self.grip[self.carried as usize] {
            let dropped_by = self.carried as usize;
            self.carried = -1;
            if self.leg_idx < s.legs.len() {
                let leg = &s.legs[self.leg_idx];
                if leg.kind == LegKind::Via && leg.arm == dropped_by {
                    self.failed = true;
                }
            }
        }

        if self.carried >= 0 {
            self.obj = self.ee[self.carried as usize];
        }

        if self.leg_idx < s.legs.len() {
            let leg = &s.legs[self.leg_idx];
            match leg.kind {
                LegKind::Grasp => {
                    if self.carried == -1 && self.grip[leg.arm]
                        && dist(&self.ee[leg.arm], &self.obj) < leg.tol
                    {
                        self.carried = leg.arm as i64;
                        self.leg_idx += 1;
                    }
                }
                LegKind::Via => {
                    let t = leg.target.unwrap();
                    if self.carried == leg.arm as i64
                        && dist(&self.ee[leg.arm], &[t.0, t.1]) < leg.tol
                    {
                        self.leg_idx += 1;
                    }
                }
                LegKind::Place => {
                    let t = leg.target.unwrap();
                    if self.carried == -1 && !self.grip[leg.arm]
                        && dist(&self.obj, &[t.0, t.1]) < leg.tol
                    {
                        self.leg_idx += 1;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obs_dims_match_spec() {
        for spec in [TaskSpec::square(), TaskSpec::transport(),
                     TaskSpec::toolhang()] {
            let mut env = PointMassEnv::new(spec.clone());
            let mut rng = Philox::new(1, 0);
            env.reset(&mut rng);
            assert_eq!(env.obs().len(), spec.obs_dim(), "{}", spec.name);
            assert_eq!(spec.action_dim(), 7 * spec.n_arms);
        }
    }

    #[test]
    fn clipping_and_dt() {
        let mut env = PointMassEnv::new(TaskSpec::square());
        let mut rng = Philox::new(2, 0);
        env.reset(&mut rng);
        let before = env.ee[0];
        let mut a = vec![0.0; 7];
        a[0] = 5.0;
        a[1] = -5.0;
        env.step(&a);
        assert!((env.ee[0][0] - before[0] - DT).abs() < 1e-12);
        assert!((env.ee[0][1] - before[1] + DT).abs() < 1e-12);
    }

    #[test]
    fn grasp_carry_place_cycle() {
        let mut env = PointMassEnv::new(TaskSpec::square());
        let mut rng = Philox::new(3, 0);
        env.reset(&mut rng);
        // teleport the arm onto the object by stepping toward it
        env.ee[0] = env.obj;
        let mut a = vec![0.0; 7];
        a[2] = 1.0; // close grip
        env.step(&a);
        assert_eq!(env.carried, 0);
        assert_eq!(env.leg_idx, 1);
        // move: object follows
        a[0] = 1.0;
        env.step(&a);
        assert_eq!(env.obj, env.ee[0]);
        // place: move to target then release
        env.ee[0] = [0.30, 0.70];
        a[0] = 0.0;
        env.step(&a); // settle at target (still gripped)
        a[2] = -1.0;
        env.step(&a); // release on target
        assert!(env.success(), "leg_idx {} failed {}", env.leg_idx, env.failed);
    }

    #[test]
    fn via_drop_fails() {
        let mut env = PointMassEnv::new(TaskSpec::toolhang());
        let mut rng = Philox::new(4, 0);
        env.reset(&mut rng);
        env.ee[0] = env.obj;
        let mut a = vec![0.0; 7];
        a[2] = 1.0;
        env.step(&a);
        assert_eq!(env.carried, 0);
        a[2] = -1.0; // open mid-VIA
        env.step(&a);
        assert!(env.failed && env.done() && !env.success());
    }

    #[test]
    fn timeout_ends_episode() {
        let spec = TaskSpec::square();
        let max = spec.max_steps;
        let mut env = PointMassEnv::new(spec);
        let mut rng = Philox::new(5, 0);
        env.reset(&mut rng);
        let a = vec![0.0; 7];
        for _ in 0..max {
            env.step(&a);
        }
        assert!(env.done() && !env.success());
    }
}
