//! Receding-horizon evaluation of diffusion policies (Fig 5 / Table 3).
//!
//! The policy models pi(a_{t:t+16} | o_t): each replanning point samples
//! a 16-step action chunk from the conditional DDPM (sequentially or via
//! ASD) and executes the first 8 actions — exactly the paper's protocol
//! (k = 16, following Chi et al.).

use std::sync::Arc;

use anyhow::Result;

use crate::asd::{AsdConfig, AsdEngine, KernelBackend};
use crate::ddpm::SequentialSampler;
use crate::env::point_mass::{PointMassEnv, TaskSpec, CHUNK, EXEC_STEPS};
use crate::model::DenoiseModel;
use crate::rng::Philox;

/// Which sampler generates each action chunk.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SamplerKind {
    Sequential,
    /// theta; 0 = infinity
    Asd(usize),
}

pub struct DiffusionPolicy {
    pub model: Arc<dyn DenoiseModel>,
    pub spec: TaskSpec,
}

impl DiffusionPolicy {
    pub fn new(model: Arc<dyn DenoiseModel>, spec: TaskSpec) -> Result<Self> {
        anyhow::ensure!(model.dim() == spec.chunk_dim(),
                        "model d={} != chunk dim {}", model.dim(),
                        spec.chunk_dim());
        anyhow::ensure!(model.cond_dim() == spec.obs_dim(),
                        "model cond={} != obs dim {}", model.cond_dim(),
                        spec.obs_dim());
        Ok(DiffusionPolicy { model, spec })
    }
}

#[derive(Debug, Clone, Default)]
pub struct RolloutResult {
    pub success: bool,
    pub env_steps: usize,
    pub plans: usize,
    /// total denoiser evaluations across all plans
    pub model_calls: usize,
    /// total parallel rounds across all plans (sequential: = model calls)
    pub parallel_rounds: usize,
    pub wallclock_s: f64,
}

/// Roll one episode; `seed` controls the env reset and all sampling noise.
pub fn rollout_policy(policy: &DiffusionPolicy, sampler: SamplerKind,
                      seed: u64) -> Result<RolloutResult> {
    let t0 = std::time::Instant::now();
    let mut env = PointMassEnv::new(policy.spec.clone());
    let mut rng = Philox::new(seed, 100);
    env.reset(&mut rng);

    let mut result = RolloutResult::default();
    let mut engine = match sampler {
        SamplerKind::Asd(theta) => Some(AsdEngine::new(
            policy.model.clone(),
            AsdConfig {
                theta,
                eval_tail: true,
                backend: KernelBackend::Native,
                ..Default::default()
            },
        )),
        SamplerKind::Sequential => None,
    };
    let seq = SequentialSampler::new(policy.model.clone());
    let act_dim = policy.spec.action_dim();

    while !env.done() {
        let obs = env.obs();
        let plan_seed = seed.wrapping_mul(1000).wrapping_add(result.plans as u64);
        let chunk = match &mut engine {
            Some(e) => {
                let out = e.sample_cond(plan_seed, &obs)?;
                result.model_calls += out.stats.model_calls;
                result.parallel_rounds += out.stats.parallel_rounds;
                out.y0
            }
            None => {
                let (y0, st) = seq.sample(plan_seed, &obs)?;
                result.model_calls += st.model_calls;
                result.parallel_rounds += st.model_calls;
                y0
            }
        };
        result.plans += 1;
        for step in 0..EXEC_STEPS.min(CHUNK) {
            if env.done() {
                break;
            }
            let a = &chunk[step * act_dim..(step + 1) * act_dim];
            env.step(a);
            result.env_steps += 1;
        }
    }
    result.success = env.success();
    result.wallclock_s = t0.elapsed().as_secs_f64();
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::TargetSpec;
    use crate::model::{NativeMlp, VariantInfo};

    /// A fake "policy model" whose x0hat is the expert chunk — lets us
    /// test the rollout plumbing without trained weights.
    struct ExpertChunkModel {
        spec: TaskSpec,
        schedule: crate::schedule::DdpmSchedule,
    }

    impl crate::model::DenoiseModel for ExpertChunkModel {
        fn dim(&self) -> usize {
            self.spec.chunk_dim()
        }
        fn cond_dim(&self) -> usize {
            self.spec.obs_dim()
        }
        fn k_steps(&self) -> usize {
            self.schedule.k_steps
        }
        fn schedule(&self) -> &crate::schedule::DdpmSchedule {
            &self.schedule
        }
        fn denoise_batch(&self, _ys: &[f64], _ts: &[f64], cond: &[f64],
                         n: usize, out: &mut [f64]) -> Result<()> {
            // reconstruct env state from obs and emit the noiseless
            // expert's repeated action as the chunk
            let d = self.dim();
            let act_dim = self.spec.action_dim();
            for r in 0..n {
                let obs = &cond[r * self.cond_dim()..(r + 1) * self.cond_dim()];
                let mut env = PointMassEnv::new(self.spec.clone());
                let n_arms = self.spec.n_arms;
                for a in 0..n_arms {
                    env.ee[a] = [obs[2 * a], obs[2 * a + 1]];
                    env.grip[a] = obs[2 * n_arms + a] > 0.5;
                }
                env.obj = [obs[3 * n_arms], obs[3 * n_arms + 1]];
                // carried one-hot
                for c in 0..=n_arms {
                    if obs[3 * n_arms + 2 + c] > 0.5 {
                        env.carried = c as i64 - 1;
                    }
                }
                env.leg_idx = (obs[4 * n_arms + 3] * self.spec.legs.len() as f64)
                    .round() as usize;
                let mut sim = env.clone();
                for step in 0..CHUNK {
                    let a = if sim.done() {
                        vec![0.0; act_dim]
                    } else {
                        let a = crate::env::expert_action(&sim, None);
                        sim.step(&a);
                        a
                    };
                    out[r * d + step * act_dim
                        ..r * d + (step + 1) * act_dim].copy_from_slice(&a);
                }
            }
            Ok(())
        }
    }

    #[test]
    fn rollout_with_expert_model_succeeds() {
        let spec = TaskSpec::square();
        let model = Arc::new(ExpertChunkModel {
            spec: spec.clone(),
            schedule: crate::schedule::DdpmSchedule::new(20),
        });
        let policy = DiffusionPolicy::new(model, spec).unwrap();
        let mut ok = 0;
        for seed in 0..5 {
            let r = rollout_policy(&policy, SamplerKind::Sequential, seed)
                .unwrap();
            ok += r.success as usize;
            assert!(r.plans > 0 && r.model_calls >= r.plans * 20);
        }
        // DDPM noise perturbs the expert chunk, but most runs succeed
        assert!(ok >= 3, "only {ok}/5 succeeded");
    }

    #[test]
    fn asd_rollout_uses_fewer_rounds() {
        let spec = TaskSpec::square();
        let model = Arc::new(ExpertChunkModel {
            spec: spec.clone(),
            schedule: crate::schedule::DdpmSchedule::new(30),
        });
        let policy = DiffusionPolicy::new(model, spec).unwrap();
        let seq = rollout_policy(&policy, SamplerKind::Sequential, 3).unwrap();
        let asd = rollout_policy(&policy, SamplerKind::Asd(8), 3).unwrap();
        assert!(asd.parallel_rounds < seq.parallel_rounds,
                "{} !< {}", asd.parallel_rounds, seq.parallel_rounds);
    }

    #[test]
    fn dim_mismatch_rejected() {
        let spec = TaskSpec::square();
        let info = VariantInfo {
            name: "bad".into(),
            d: 3,
            cond_dim: 1,
            hidden: 4,
            layers: 1,
            temb_dim: 32,
            k_steps: 10,
            train_loss: 0.0,
            artifacts: Default::default(),
            weights_file: String::new(),
            weights_layout: vec![(3 + 32 + 1, 4), (4, 3)],
            abar: (1..=10).map(|i| 0.9f64.powi(i)).collect(),
            target: TargetSpec::Env { task: "square".into() },
            env: Some("square".into()),
        };
        let n_w: usize = info.weights_layout.iter().map(|(a, b)| a * b + b).sum();
        let mlp = NativeMlp::from_flat(&info, &vec![0.0; n_w]).unwrap();
        assert!(DiffusionPolicy::new(mlp, spec).is_err());
    }
}
