//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()[1..]`; `known_flags` lists options
    /// that take no value.
    pub fn parse(raw: impl Iterator<Item = String>, known_flags: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = raw.peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&rest) {
                    out.flags.push(rest.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    out.options.insert(rest.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn from_env(known_flags: &[&str]) -> Args {
        Args::parse(std::env::args().skip(1), known_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => match s.parse() {
                Ok(v) => Ok(v),
                Err(_) => bail!("--{name} expects an integer, got '{s}'"),
            },
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => match s.parse() {
                Ok(v) => Ok(v),
                Err(_) => bail!("--{name} expects a float, got '{s}'"),
            },
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => match s.parse() {
                Ok(v) => Ok(v),
                Err(_) => bail!("--{name} expects an integer, got '{s}'"),
            },
        }
    }

    /// Parse a comma-separated list of usizes, e.g. `--thetas 2,4,8`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad list item '{p}' in --{name}"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str], flags: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()), flags)
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["serve", "--port", "8080", "--verbose"], &["verbose"]);
        assert_eq!(a.positional, vec!["serve"]);
        assert_eq!(a.get("port"), Some("8080"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse(&["--model=latent16", "--theta=8"], &[]);
        assert_eq!(a.get("model"), Some("latent16"));
        assert_eq!(a.get_usize("theta", 0).unwrap(), 8);
    }

    #[test]
    fn flag_followed_by_option() {
        let a = parse(&["--fast", "--k", "100"], &["fast"]);
        assert!(a.flag("fast"));
        assert_eq!(a.get_usize("k", 0).unwrap(), 100);
    }

    #[test]
    fn unknown_flag_at_end_is_flag() {
        let a = parse(&["--dry-run"], &[]);
        assert!(a.flag("dry-run"));
    }

    #[test]
    fn list_parsing() {
        let a = parse(&["--thetas", "2,4, 8"], &[]);
        assert_eq!(a.get_usize_list("thetas", &[]).unwrap(), vec![2, 4, 8]);
        assert_eq!(a.get_usize_list("missing", &[1]).unwrap(), vec![1]);
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse(&["--k", "abc"], &[]);
        assert!(a.get_usize("k", 0).is_err());
        assert!(a.get_f64("k", 0.0).is_err());
    }
}
