//! Mini property-testing harness (proptest is unavailable offline).
//!
//! `check(name, cases, |g| ...)` runs a closure over `cases` seeded
//! generators; a failure reports the offending seed so the case can be
//! replayed deterministically with `replay(seed, ...)`.

use crate::rng::Philox;

/// Value generator handed to property closures.
pub struct Gen {
    pub rng: Philox,
    pub seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + (self.rng.next_u64() as usize) % (hi - lo + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.uniform() * (hi - lo)
    }

    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.rng.normal()).collect()
    }

    pub fn uniform_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.rng.uniform()).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize_in(0, items.len() - 1)]
    }
}

/// Run `f` over `cases` random cases; panics with the failing seed.
pub fn check<F: FnMut(&mut Gen)>(name: &str, cases: usize, mut f: F) {
    for case in 0..cases {
        let seed = 0x5eed_0000_0000 + case as u64;
        let mut g = Gen { rng: Philox::new(seed, 0), seed };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || f(&mut g),
        ));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed at seed {seed:#x} (case {case}): {msg}");
        }
    }
}

/// Replay a single failing case.
pub fn replay<F: FnMut(&mut Gen)>(seed: u64, mut f: F) {
    let mut g = Gen { rng: Philox::new(seed, 0), seed };
    f(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check("count", 17, |_| n += 1);
        assert_eq!(n, 17);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn check_reports_seed() {
        check("fails", 5, |g| {
            let x = g.f64_in(0.0, 1.0);
            assert!(x < 2.0); // passes
            if g.seed == 0x5eed_0000_0003 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn gen_ranges() {
        check("ranges", 20, |g| {
            let u = g.usize_in(3, 7);
            assert!((3..=7).contains(&u));
            let f = g.f64_in(-1.0, 1.0);
            assert!((-1.0..=1.0).contains(&f));
            let v = g.normal_vec(4);
            assert_eq!(v.len(), 4);
        });
    }
}
