//! Infrastructure substrates that the offline environment forces us to
//! hand-roll: JSON, CLI parsing, logging, timing, property testing.

pub mod cli;
pub mod json;
pub mod log;
pub mod prop;
pub mod timer;

pub use json::Json;
pub use timer::Timer;
