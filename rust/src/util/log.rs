//! Leveled stderr logger with a global verbosity switch.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, msg: &str) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{tag}] {msg}");
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info,
                               &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn,
                               &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug,
                               &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
