//! Timing helpers + a tiny bench harness (criterion is unavailable
//! offline). Used by `benches/*.rs` (all `harness = false`).

use std::time::{Duration, Instant};

pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Summary statistics over repeated timed runs.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub iters: usize,
    pub mean_ms: f64,
    pub median_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
    pub stddev_ms: f64,
}

impl BenchStats {
    pub fn row(&self, label: &str) -> String {
        format!(
            "{label:<44} {:>10.3} ms/iter  (median {:>8.3}, min {:>8.3}, max {:>8.3}, n={})",
            self.mean_ms, self.median_ms, self.min_ms, self.max_ms, self.iters
        )
    }
}

/// Run `f` for `warmup` unmeasured + `iters` measured iterations.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    summarize(&samples)
}

/// Run `f` until `budget` elapses (at least 3 iterations).
pub fn bench_for<F: FnMut()>(budget: Duration, mut f: F) -> BenchStats {
    let mut samples = Vec::new();
    let t0 = Instant::now();
    while samples.len() < 3 || t0.elapsed() < budget {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
        if samples.len() > 100_000 {
            break;
        }
    }
    summarize(&samples)
}

fn summarize(samples: &[f64]) -> BenchStats {
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = sorted.len();
    let mean = sorted.iter().sum::<f64>() / n as f64;
    let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
        / (n.max(2) - 1) as f64;
    BenchStats {
        iters: n,
        mean_ms: mean,
        median_ms: sorted[n / 2],
        min_ms: sorted[0],
        max_ms: sorted[n - 1],
        stddev_ms: var.sqrt(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_iters() {
        let mut count = 0;
        let stats = bench(2, 10, || count += 1);
        assert_eq!(count, 12);
        assert_eq!(stats.iters, 10);
        assert!(stats.min_ms <= stats.median_ms);
        assert!(stats.median_ms <= stats.max_ms);
    }

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.elapsed_ms() >= 1.0);
    }
}
