//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Supports the full JSON grammar we emit from python (objects, arrays,
//! f64 numbers, strings with escapes, bool, null). Designed for the
//! manifest/golden files: tens of MBs parse in well under a second.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Context, Result};

/// A JSON value. Numbers are stored as f64 (the manifest only carries
/// f64-representable values; python's json emits the same).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != bytes.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Json::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    // ------------------------------------------------------------------
    // Typed accessors
    // ------------------------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key).filter(|v| !matches!(v, Json::Null)),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a usize: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 {
            bail!("not an integer: {n}");
        }
        Ok(n as i64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// Array of numbers -> Vec<f64>.
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    /// Array of numbers -> Vec<f32>.
    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        Ok(self.as_f64_vec()?.into_iter().map(|x| x as f32).collect())
    }

    /// Nested array-of-arrays -> row-major matrix (rows, data).
    pub fn as_f64_matrix(&self) -> Result<(usize, usize, Vec<f64>)> {
        let rows = self.as_arr()?;
        let n = rows.len();
        if n == 0 {
            return Ok((0, 0, vec![]));
        }
        let m = rows[0].as_arr()?.len();
        let mut data = Vec::with_capacity(n * m);
        for r in rows {
            let row = r.as_f64_vec()?;
            if row.len() != m {
                bail!("ragged matrix");
            }
            data.extend(row);
        }
        Ok((n, m, data))
    }
}

// ----------------------------------------------------------------------
// Parser
// ----------------------------------------------------------------------

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i,
                  self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'N' => self.lit("NaN", Json::Num(f64::NAN)),
            b'I' => self.lit("Infinity", Json::Num(f64::INFINITY)),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // (surrogate pairs unsupported; manifest is ASCII)
                            s.push(char::from_u32(cp)
                                .ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        _ => bail!("bad escape"),
                    }
                }
                _ => {
                    // fast path: consume a UTF-8 run up to the next " or \
                    let start = self.i - 1;
                    let mut j = self.i;
                    while j < self.b.len()
                        && self.b[j] != b'"'
                        && self.b[j] != b'\\'
                    {
                        j += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..j])?);
                    self.i = j;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
            // python may emit -Infinity
            if self.peek()? == b'I' {
                self.lit("Infinity", Json::Null)?;
                return Ok(Json::Num(f64::NEG_INFINITY));
            }
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                        b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().with_context(|| {
            format!("bad number '{text}' at byte {start}")
        })?))
    }
}

// ----------------------------------------------------------------------
// Serializer (used by experiment drivers to dump results)
// ----------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.is_nan() {
                    write!(f, "NaN")
                } else if n.is_infinite() {
                    write!(f, "{}Infinity", if *n < 0.0 { "-" } else { "" })
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

/// Builder helpers for emitting result JSON from experiments.
impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f64s(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(),
                   Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x");
        assert!(!j.get("a").unwrap().as_arr().unwrap()[2]
            .get("b").unwrap().as_bool().unwrap());
    }

    #[test]
    fn parse_matrix() {
        let j = Json::parse("[[1,2],[3,4],[5,6]]").unwrap();
        let (n, m, data) = j.as_f64_matrix().unwrap();
        assert_eq!((n, m), (3, 2));
        assert_eq!(data, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn roundtrip_display() {
        let src = r#"{"a":[1,2.5,true,null],"b":"x\"y"}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn errors_are_reported() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn special_floats() {
        // python json.dump emits these for nan/inf
        assert!(Json::parse("NaN").unwrap().as_f64().unwrap().is_nan());
        assert_eq!(Json::parse("-Infinity").unwrap().as_f64().unwrap(),
                   f64::NEG_INFINITY);
    }

    #[test]
    fn whitespace_tolerance() {
        let j = Json::parse(" {\n\t\"k\" :\r [ 1 , 2 ] } ").unwrap();
        assert_eq!(j.get("k").unwrap().as_f64_vec().unwrap(), vec![1.0, 2.0]);
    }
}
