//! Deterministic fault injection — the chaos harness the
//! failure-domain tests and `asd chaos` bench drive.
//!
//! A [`FaultPlan`] is a *pure function* from `(lane, round, site)` to a
//! fault decision, indexed through the same counter-based Philox block
//! the samplers draw noise from ([`crate::rng::Philox::block`]). No
//! mutable RNG state is threaded through execution, so the injection
//! schedule is bit-reproducible across pool sizes, steal schedules,
//! and driver paths: round `r` of lane `l` faults (or not) identically
//! whether the round ran on 1 OS thread or 8, compiled to a tile graph
//! or executed as a closure.
//!
//! [`ChaosModel`] is a [`DenoiseModel`] decorator that consults the
//! plan once per fused round and injects:
//!
//! * **Panic** — the model call panics (the scheduler's
//!   `catch_unwind` containment and retry path must absorb it),
//! * **NonFinite** — the round executes, then one deterministic output
//!   element is overwritten with NaN (exercises output validation:
//!   fail the offending request, not the lane),
//! * **Latency** — the round sleeps `FaultPlan::latency` first
//!   (wall-clock only; bits are untouched),
//! * **Tile** — the round's compiled [`TileGraph`] gets one node
//!   poisoned ([`TileGraph::poison_node`]), so the panic happens
//!   *mid-graph* on a pool worker and must ride the cancel-dependents
//!   path, failing only this lane's round.
//!
//! The wrapper must sit **outside** `ParallelModel`: the plan is
//! consulted once per round, never once per shard, or the injection
//! schedule would depend on the shard partition.
//!
//! The solo (batching-off) path `server::run_sampler` is intentionally
//! not chaos'd — `denoise_batch` forwards untouched; the failure
//! domains under test (fused groups, lanes, tile graphs) only exist on
//! the fused path.

use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex, PoisonError};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::fusion::{FusionScheduler, RecoveryPolicy};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{QueuedJob, Request, SamplerSpec};
use crate::coordinator::FailReason;
use crate::model::{DenoiseModel, ParallelModel};
use crate::rng::Philox;
use crate::runtime::pool::{self, PoolConfig, TileGraph};
use crate::sampler::RoundArena;
use crate::schedule::DdpmSchedule;

/// Sub-round draw index within a round's counter block. Each round
/// owns `SITES` consecutive Philox counters, so per-site draws are
/// independent and the site space can grow without reshuffling
/// existing plans.
const SITES: u64 = 4;
const SITE_DECIDE: u64 = 0;
const SITE_CORRUPT: u64 = 1;

/// One injected fault. `Tile` carries the raw u32 draw that picks the
/// poisoned node (`draw % graph.len()` at injection time, so the same
/// plan is usable against graphs of any size).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    Panic,
    NonFinite,
    Latency,
    Tile(u32),
}

/// A seeded, schedule-independent fault-injection plan.
///
/// Rates are independent per-round probabilities evaluated in priority
/// order panic > non-finite > latency > tile (one fault per round at
/// most). All decisions derive from `Philox::block(key(lane),
/// round * SITES + site)` — pure, so the plan can also be *queried*
/// ahead of time (tests scan for a seed whose first fault lands in a
/// chosen window instead of hoping).
#[derive(Debug, Clone)]
pub struct FaultPlan {
    pub seed: u64,
    /// per-round probability the fused model call panics
    pub panic_rate: f64,
    /// per-round probability one output element becomes NaN
    pub non_finite_rate: f64,
    /// per-round probability the round sleeps `latency` first
    pub latency_rate: f64,
    /// injected latency for `FaultKind::Latency` rounds
    pub latency: Duration,
    /// per-round probability one tile of the round's compiled graph
    /// panics mid-graph
    pub tile_rate: f64,
    /// restrict injection to one lane (None = every wrapped lane)
    pub only_lane: Option<String>,
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan {
            seed: 0,
            panic_rate: 0.0,
            non_finite_rate: 0.0,
            latency_rate: 0.0,
            latency: Duration::from_millis(1),
            tile_rate: 0.0,
            only_lane: None,
        }
    }
}

impl FaultPlan {
    /// A plan injecting only round panics — the common test shape.
    pub fn panics(seed: u64, rate: f64) -> FaultPlan {
        FaultPlan { seed, panic_rate: rate, ..FaultPlan::default() }
    }

    /// Per-lane Philox key: FNV-1a of the lane name folded into the
    /// plan seed, so two lanes draw independent fault schedules from
    /// one seed.
    fn key(&self, lane: &str) -> [u32; 2] {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in lane.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        [(self.seed as u32) ^ (h as u32),
         ((self.seed >> 32) as u32) ^ ((h >> 32) as u32)]
    }

    /// The raw 4x32 draw for `(lane, round, site)` — pure.
    pub fn draw(&self, lane: &str, round: u64, site: u64) -> [u32; 4] {
        Philox::block(self.key(lane), round * SITES + site)
    }

    /// The fault (if any) this plan injects into fused round `round`
    /// of `lane`. Pure — callable ahead of execution.
    pub fn round_fault(&self, lane: &str, round: u64) -> Option<FaultKind> {
        if let Some(only) = &self.only_lane {
            if only != lane {
                return None;
            }
        }
        let u = self.draw(lane, round, SITE_DECIDE);
        let thr = |rate: f64| (rate.clamp(0.0, 1.0) * 4_294_967_296.0) as u64;
        if (u[0] as u64) < thr(self.panic_rate) {
            return Some(FaultKind::Panic);
        }
        if (u[1] as u64) < thr(self.non_finite_rate) {
            return Some(FaultKind::NonFinite);
        }
        if (u[2] as u64) < thr(self.latency_rate) {
            return Some(FaultKind::Latency);
        }
        if (u[3] as u64) < thr(self.tile_rate) {
            return Some(FaultKind::Tile(u[3]));
        }
        None
    }

    /// Index of the first faulted round in `[0, horizon)`, if any —
    /// lets tests *construct* seeds with a fault in a known window.
    pub fn first_fault(&self, lane: &str, horizon: u64) -> Option<u64> {
        (0..horizon).find(|&r| self.round_fault(lane, r).is_some())
    }
}

/// Round counter + the decision staged between `compile_round` and
/// `denoise_round` (a round that compiles to `None` falls through to
/// the closure path, which must consume the *same* round's decision,
/// not advance the counter again).
struct ChaosState {
    next_round: u64,
    staged: Option<(u64, Option<FaultKind>)>,
}

/// Fault-injecting [`DenoiseModel`] decorator. Wrap **outside**
/// `ParallelModel` (see module docs); one wrapper per lane.
pub struct ChaosModel {
    inner: Arc<dyn DenoiseModel>,
    plan: FaultPlan,
    lane: String,
    state: Mutex<ChaosState>,
}

impl ChaosModel {
    pub fn wrap(inner: Arc<dyn DenoiseModel>, plan: FaultPlan, lane: &str)
                -> Arc<dyn DenoiseModel> {
        Arc::new(ChaosModel {
            inner,
            plan,
            lane: lane.to_string(),
            state: Mutex::new(ChaosState { next_round: 0, staged: None }),
        })
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, ChaosState> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn tile_msg(&self, round: u64, idx: usize) -> String {
        format!("chaos: injected tile fault (lane {} round {round} \
                 tile {idx})", self.lane)
    }

    /// Overwrite one deterministic output element with NaN — which
    /// element is a site-indexed draw, so pool size never moves it.
    fn corrupt(&self, arena: &mut RoundArena, round: u64) {
        let d = self.inner.dim();
        let (_, _, _, n, out) = arena.round_io();
        if n == 0 || d == 0 {
            return;
        }
        let u = self.plan.draw(&self.lane, round, SITE_CORRUPT);
        let bits = ((u[0] as u64) << 32) | u[1] as u64;
        out[(bits % (n * d) as u64) as usize] = f64::NAN;
    }
}

impl DenoiseModel for ChaosModel {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn cond_dim(&self) -> usize {
        self.inner.cond_dim()
    }

    fn k_steps(&self) -> usize {
        self.inner.k_steps()
    }

    fn schedule(&self) -> &DdpmSchedule {
        self.inner.schedule()
    }

    /// Solo-path calls forward untouched (see module docs).
    fn denoise_batch(&self, ys: &[f64], ts: &[f64], cond: &[f64], n: usize,
                     out: &mut [f64]) -> Result<()> {
        self.inner.denoise_batch(ys, ts, cond, n, out)
    }

    fn denoise_round(&self, arena: &mut RoundArena) -> Result<()> {
        let (round, fault) = {
            let mut st = self.lock();
            match st.staged.take() {
                Some(rf) => rf,
                None => {
                    let round = st.next_round;
                    st.next_round += 1;
                    (round, self.plan.round_fault(&self.lane, round))
                }
            }
        };
        match fault {
            Some(FaultKind::Panic) => panic!(
                "chaos: injected model panic (lane {} round {round})",
                self.lane),
            Some(FaultKind::Latency) => {
                std::thread::sleep(self.plan.latency);
                self.inner.denoise_round(arena)
            }
            Some(FaultKind::NonFinite) => {
                self.inner.denoise_round(arena)?;
                self.corrupt(arena, round);
                Ok(())
            }
            Some(FaultKind::Tile(draw)) => {
                // a driver that skipped compile_round (lockstep tick
                // path) must still see the mid-graph fault: compile +
                // poison + run here. Backends with no graph form this
                // round just execute clean — the tile fault has no
                // tile to land on.
                match self.inner.compile_round(arena)? {
                    Some(mut graph) if !graph.is_empty() => {
                        let idx = draw as usize % graph.len();
                        graph.poison_node(idx, &self.tile_msg(round, idx));
                        // resumes the tile panic on this thread once
                        // the pool has cancelled the dependents
                        pool::global().run_graph(graph);
                        Ok(())
                    }
                    _ => self.inner.denoise_round(arena),
                }
            }
            None => self.inner.denoise_round(arena),
        }
    }

    fn compile_round(&self, arena: &mut RoundArena)
                     -> Result<Option<TileGraph>> {
        let mut st = self.lock();
        let round = st.next_round;
        st.next_round += 1;
        st.staged = None;
        let fault = self.plan.round_fault(&self.lane, round);
        if matches!(fault, Some(FaultKind::Panic) | Some(FaultKind::NonFinite)
                           | Some(FaultKind::Latency)) {
            // round-granularity fault: refuse the graph form so the
            // round takes the closure path, where denoise_round
            // injects it
            st.staged = Some((round, fault));
            return Ok(None);
        }
        match self.inner.compile_round(arena) {
            Ok(Some(mut graph)) => {
                if let Some(FaultKind::Tile(draw)) = fault {
                    if !graph.is_empty() {
                        let idx = draw as usize % graph.len();
                        graph.poison_node(idx, &self.tile_msg(round, idx));
                    }
                }
                Ok(Some(graph))
            }
            Ok(None) => {
                // falls through to denoise_round — hand it this
                // round's decision
                st.staged = Some((round, fault));
                Ok(None)
            }
            Err(e) => Err(e),
        }
    }

    fn round_shards(&self, n: usize) -> usize {
        self.inner.round_shards(n)
    }

    fn round_barriers(&self, n: usize) -> usize {
        self.inner.round_barriers(n)
    }
}

/// One request's outcome from [`run_chaos_burst`].
#[derive(Debug, Clone)]
pub struct ChaosOutcome {
    pub id: u64,
    pub sample: Vec<f64>,
    pub error: Option<String>,
    pub reason: Option<FailReason>,
    pub retries: u32,
}

/// Deterministic lockstep chaos driver: admit `specs` as one burst
/// into a single fused lane and tick it dry. Unlike the coordinator
/// (whose admission batching is timing-dependent), this produces an
/// identical round schedule on every run, so the determinism suite can
/// compare *failure sets* — not just survivor bits — across pool
/// sizes. Requests are unconditional (`cond = []`), ids are the spec
/// index.
pub fn run_chaos_burst(model: Arc<dyn DenoiseModel>,
                       draft: Option<Arc<dyn DenoiseModel>>, lane: &str,
                       plan: Option<&FaultPlan>, recovery: RecoveryPolicy,
                       pool: PoolConfig, specs: &[(SamplerSpec, u64)])
                       -> Vec<ChaosOutcome> {
    let mut wrapped = ParallelModel::wrap(model, pool);
    if let Some(p) = plan {
        wrapped = ChaosModel::wrap(wrapped, p.clone(), lane);
    }
    let metrics = Metrics::default();
    let mut sched = FusionScheduler::new(wrapped, draft, lane, 0, recovery);
    let mut rxs = Vec::with_capacity(specs.len());
    for (i, &(sampler, seed)) in specs.iter().enumerate() {
        let (tx, rx) = channel();
        sched.admit(QueuedJob {
            request: Request {
                id: i as u64,
                variant: lane.to_string(),
                sampler,
                seed,
                cond: vec![],
                deadline: None,
            },
            reply: tx,
            enqueued: Instant::now(),
        }, &metrics);
        rxs.push(rx);
    }
    let mut ticks = 0usize;
    while !sched.is_empty() {
        sched.tick(&metrics);
        ticks += 1;
        assert!(ticks < 1_000_000, "chaos burst failed to drain");
    }
    rxs.into_iter()
        .enumerate()
        .map(|(i, rx)| {
            let r = rx.recv().expect("request dropped without a response");
            ChaosOutcome {
                id: i as u64,
                sample: r.sample,
                error: r.error,
                reason: r.reason,
                retries: r.retries,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Gmm, GmmDdpmOracle};

    fn oracle(k: usize) -> Arc<dyn DenoiseModel> {
        GmmDdpmOracle::new(Gmm::circle_2d(), k, false)
    }

    fn staged_arena(model: &dyn DenoiseModel, n: usize) -> RoundArena {
        let mut arena = RoundArena::for_model(model);
        arena.begin_round();
        let (_, rows) = arena.reserve(n);
        for (i, y) in rows.ys.iter_mut().enumerate() {
            *y = (i as f64 * 0.31).sin();
        }
        for (i, t) in rows.ts.iter_mut().enumerate() {
            *t = (1 + i % 5) as f64;
        }
        arena
    }

    #[test]
    fn plan_is_a_pure_function_of_lane_round_site() {
        let plan = FaultPlan { seed: 42, panic_rate: 0.3,
                               non_finite_rate: 0.2, tile_rate: 0.1,
                               ..FaultPlan::default() };
        for round in 0..200 {
            assert_eq!(plan.round_fault("a", round),
                       plan.round_fault("a", round));
        }
        // rate extremes are certain
        assert_eq!(FaultPlan::panics(7, 1.0).round_fault("x", 3),
                   Some(FaultKind::Panic));
        assert_eq!(FaultPlan::panics(7, 0.0).round_fault("x", 3), None);
        // only_lane masks every other lane
        let scoped = FaultPlan { only_lane: Some("a".into()),
                                 ..FaultPlan::panics(7, 1.0) };
        assert_eq!(scoped.round_fault("a", 0), Some(FaultKind::Panic));
        assert_eq!(scoped.round_fault("b", 0), None);
    }

    #[test]
    fn lanes_draw_independent_schedules() {
        // with a mid-range rate, two lanes must not share a schedule
        // for every round (the FNV fold makes their keys differ)
        let plan = FaultPlan::panics(11, 0.5);
        let differs = (0..64).any(|r| {
            plan.round_fault("lane-a", r) != plan.round_fault("lane-b", r)
        });
        assert!(differs, "lane keys collided");
    }

    #[test]
    fn chaos_panic_round_panics_and_clean_plan_is_transparent() {
        let base = oracle(5);
        let chaotic = ChaosModel::wrap(base.clone(),
                                       FaultPlan::panics(1, 1.0), "l");
        let mut arena = staged_arena(base.as_ref(), 3);
        let err = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                chaotic.denoise_round(&mut arena)
            }));
        assert!(err.is_err(), "panic fault did not panic");

        // zero-rate plan: bit-identical to the inner model
        let clean = ChaosModel::wrap(base.clone(),
                                     FaultPlan::panics(1, 0.0), "l");
        let mut a1 = staged_arena(base.as_ref(), 3);
        let mut a2 = staged_arena(base.as_ref(), 3);
        base.denoise_round(&mut a1).unwrap();
        clean.denoise_round(&mut a2).unwrap();
        let (_, _, _, n, o1) = a1.round_io();
        let (_, _, _, _, o2) = a2.round_io();
        for i in 0..n * 2 {
            assert_eq!(o1[i].to_bits(), o2[i].to_bits(), "i={i}");
        }
    }

    #[test]
    fn non_finite_fault_corrupts_exactly_one_element() {
        let base = oracle(5);
        let plan = FaultPlan { non_finite_rate: 1.0,
                               ..FaultPlan::default() };
        let chaotic = ChaosModel::wrap(base.clone(), plan, "l");
        let mut arena = staged_arena(base.as_ref(), 4);
        chaotic.denoise_round(&mut arena).unwrap();
        let (_, _, _, n, out) = arena.round_io();
        let bad = out[..n * 2].iter().filter(|v| !v.is_finite()).count();
        assert_eq!(bad, 1, "expected exactly one corrupted element");
    }

    #[test]
    fn compile_stages_the_decision_for_the_closure_path() {
        // compile_round on a graph-less backend returns None and must
        // hand the SAME round's fault to denoise_round — the panic
        // fires there, and the counter advanced exactly once
        let base = oracle(5);
        let plan = FaultPlan::panics(3, 1.0);
        let chaotic = ChaosModel::wrap(base.clone(), plan, "l");
        let mut arena = staged_arena(base.as_ref(), 2);
        assert!(chaotic.compile_round(&mut arena).unwrap().is_none());
        let err = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                chaotic.denoise_round(&mut arena)
            }));
        assert!(err.is_err(), "staged panic fault did not fire");
    }

    #[test]
    fn chaos_burst_without_plan_matches_plain_burst_bitwise() {
        let specs = [(SamplerSpec::Sequential, 5u64),
                     (SamplerSpec::Asd(4), 6u64)];
        let a = run_chaos_burst(oracle(20), None, "gmm", None,
                                RecoveryPolicy::default(),
                                PoolConfig::default(), &specs);
        let b = run_chaos_burst(oracle(20), None, "gmm", None,
                                RecoveryPolicy::default(),
                                PoolConfig::default(), &specs);
        assert_eq!(a.len(), 2);
        for (x, y) in a.iter().zip(&b) {
            assert!(x.error.is_none(), "{:?}", x.error);
            assert_eq!(x.retries, 0);
            let xb: Vec<u64> =
                x.sample.iter().map(|v| v.to_bits()).collect();
            let yb: Vec<u64> =
                y.sample.iter().map(|v| v.to_bits()).collect();
            assert_eq!(xb, yb, "id {}", x.id);
        }
    }
}
