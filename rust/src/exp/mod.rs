//! Shared experiment drivers: the code behind every reproduced table
//! and figure (examples/ and benches/ are thin wrappers over these).

pub mod chaos_bench;
pub mod latency;
pub mod quality;
pub mod serve_bench;
pub mod speedup;

pub use chaos_bench::{bench_chaos, bench_chaos_json, format_chaos_rows,
                      ChaosRow};
pub use latency::LatencyModel;
pub use quality::{format_quality_table, QualityRow};
pub use serve_bench::{bench_coordinator, bench_coordinator_json,
                      bench_mixed_variants, format_coord_rows,
                      format_lanes, CoordBenchRow, MixedVariantBench};
pub use speedup::{bench_parallel_json, bench_pareto_grid,
                  bench_pareto_json, format_pareto_rows, format_pool_rows,
                  format_rows, outputs_bit_identical, run_pareto_grid,
                  sweep_pool_sizes, sweep_thetas, write_bench_json,
                  ForwardBenchRow, ParetoRow, PoolRow, SpeedupRow};
