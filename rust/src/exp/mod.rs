//! Shared experiment drivers: the code behind every reproduced table
//! and figure (examples/ and benches/ are thin wrappers over these).

pub mod latency;
pub mod quality;
pub mod speedup;

pub use latency::LatencyModel;
pub use quality::{format_quality_table, QualityRow};
pub use speedup::{format_rows, sweep_thetas, SpeedupRow};
