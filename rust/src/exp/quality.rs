//! Quality-table drivers (Tables 1 and 2): sample N points with each
//! method and score them against the ground-truth target.

use std::sync::Arc;

use anyhow::Result;

use crate::asd::{AsdConfig, AsdEngine, KernelBackend};
use crate::ddpm::BatchedSequentialSampler;
use crate::model::targets::sample_target;
use crate::model::{DenoiseModel, Gmm, TargetSpec};
use crate::quality::{alignment_score, frechet_diag, sliced_w};
use crate::rng::Philox;

#[derive(Debug, Clone)]
pub struct QualityRow {
    pub method: String,
    /// CLIP-proxy (conditional variants only)
    pub alignment: Option<f64>,
    /// FID-proxy vs held-out target samples
    pub frechet: f64,
    pub sliced_w: f64,
    pub n_samples: usize,
}

/// Generate `n` samples with sequential DDPM (lockstep-batched).
pub fn sample_ddpm(model: &Arc<dyn DenoiseModel>, n: usize, seed0: u64,
                   conds: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
    let d = model.dim();
    let c = model.cond_dim();
    let sampler = BatchedSequentialSampler::new(model.clone());
    let mut out = Vec::with_capacity(n);
    let chunk = 32usize;
    let mut i = 0;
    while i < n {
        let take = chunk.min(n - i);
        let seeds: Vec<u64> = (0..take).map(|r| seed0 + (i + r) as u64).collect();
        let mut cond_rows = vec![0.0; take * c];
        for r in 0..take {
            if c > 0 {
                cond_rows[r * c..(r + 1) * c]
                    .copy_from_slice(&conds[(i + r) % conds.len().max(1)]);
            }
        }
        let (ys, _) = sampler.sample_batch(&seeds, &cond_rows)?;
        for r in 0..take {
            out.push(ys[r * d..(r + 1) * d].to_vec());
        }
        i += take;
    }
    Ok(out)
}

/// Generate `n` samples with ASD-theta.
pub fn sample_asd(model: &Arc<dyn DenoiseModel>, theta: usize, n: usize,
                  seed0: u64, conds: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
    let mut engine = AsdEngine::new(
        model.clone(),
        AsdConfig {
            theta,
            eval_tail: true,
            backend: KernelBackend::Native,
            ..Default::default()
        },
    );
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let seed = seed0 + i as u64;
        let y0 = if model.cond_dim() > 0 {
            engine.sample_cond(seed, &conds[i % conds.len()])?.y0
        } else {
            engine.sample(seed)?.y0
        };
        out.push(y0);
    }
    Ok(out)
}

/// Score one method's samples against the target.
pub fn score(target: &TargetSpec, samples: Vec<Vec<f64>>,
             classes: Option<&[usize]>, method: &str, seed: u64)
             -> QualityRow {
    let mut rng = Philox::new(seed, 0xf1d);
    let n = samples.len();
    let (reference, _) = sample_target(target, n, &mut rng);
    let alignment = match (classes, Gmm::from_target(target)) {
        (Some(cls), Some(gmm)) => {
            Some(alignment_score(&gmm, &samples, &cls[..n]))
        }
        _ => None,
    };
    QualityRow {
        method: method.to_string(),
        alignment,
        frechet: frechet_diag(&samples, &reference),
        sliced_w: sliced_w(&samples, &reference),
        n_samples: n,
    }
}

/// Build per-sample conditioning rows (+ the class labels) for a
/// conditional GMM variant: classes cycle 0..C.
pub fn make_class_conds(model: &Arc<dyn DenoiseModel>, n: usize)
                        -> (Vec<Vec<f64>>, Vec<usize>) {
    let c = model.cond_dim();
    let mut conds = Vec::with_capacity(n);
    let mut classes = Vec::with_capacity(n);
    for i in 0..n {
        let cls = i % c.max(1);
        let mut row = vec![0.0; c];
        if c > 0 {
            row[cls] = 1.0;
        }
        conds.push(row);
        classes.push(cls);
    }
    (conds, classes)
}

pub fn format_quality_table(rows: &[QualityRow], metric_name: &str) -> String {
    let mut out = String::new();
    out.push_str(&format!("{:<12} {:>14} {:>12} {:>12} {:>8}\n", "method",
                          metric_name, "FID-proxy", "sliced-W", "n"));
    for r in rows {
        let a = r.alignment.map(|v| format!("{v:.4}"))
            .unwrap_or_else(|| "-".into());
        out.push_str(&format!("{:<12} {:>14} {:>12.4} {:>12.4} {:>8}\n",
                              r.method, a, r.frechet, r.sliced_w, r.n_samples));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::GmmDdpmOracle;

    #[test]
    fn ddpm_and_asd_quality_match_on_oracle() {
        let gmm = Gmm::circle_2d();
        let target = TargetSpec::Gmm {
            means: (0..8).map(|c| gmm.mean_of(c).to_vec()).collect(),
            sigmas: gmm.sigmas.clone(),
            weights: gmm.weights.clone(),
        };
        let model: Arc<dyn DenoiseModel> =
            GmmDdpmOracle::new(gmm, 60, false);
        let n = 80;
        let ddpm = sample_ddpm(&model, n, 0, &[]).unwrap();
        let asd = sample_asd(&model, 8, n, 0, &[]).unwrap();
        let row_d = score(&target, ddpm, None, "DDPM", 1);
        let row_a = score(&target, asd, None, "ASD-8", 1);
        // both near the target; neither dramatically worse
        assert!(row_d.frechet < 0.3, "ddpm frechet {}", row_d.frechet);
        assert!(row_a.frechet < 0.3, "asd frechet {}", row_a.frechet);
        let table = format_quality_table(&[row_d, row_a], "align");
        assert!(table.contains("ASD-8"));
    }
}
