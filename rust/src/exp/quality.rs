//! Quality-table drivers (Tables 1 and 2): sample N points with each
//! method and score them against the ground-truth target.

use std::sync::Arc;

use anyhow::Result;

use crate::asd::{AsdConfig, AsdEngine, DraftConfig, DraftEngine,
                 KernelBackend};
use crate::ddpm::BatchedSequentialSampler;
use crate::model::targets::sample_target;
use crate::model::{DenoiseModel, Gmm, TargetSpec};
use crate::quality::{alignment_score, frechet_diag, sliced_w};
use crate::rng::Philox;

#[derive(Debug, Clone)]
pub struct QualityRow {
    pub method: String,
    /// CLIP-proxy (conditional variants only)
    pub alignment: Option<f64>,
    /// FID-proxy vs held-out target samples
    pub frechet: f64,
    pub sliced_w: f64,
    pub n_samples: usize,
}

/// Generate `n` samples with sequential DDPM (lockstep-batched).
pub fn sample_ddpm(model: &Arc<dyn DenoiseModel>, n: usize, seed0: u64,
                   conds: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
    let d = model.dim();
    let c = model.cond_dim();
    let sampler = BatchedSequentialSampler::new(model.clone());
    let mut out = Vec::with_capacity(n);
    let chunk = 32usize;
    let mut i = 0;
    while i < n {
        let take = chunk.min(n - i);
        let seeds: Vec<u64> = (0..take).map(|r| seed0 + (i + r) as u64).collect();
        let mut cond_rows = vec![0.0; take * c];
        for r in 0..take {
            if c > 0 {
                cond_rows[r * c..(r + 1) * c]
                    .copy_from_slice(&conds[(i + r) % conds.len().max(1)]);
            }
        }
        let (ys, _) = sampler.sample_batch(&seeds, &cond_rows)?;
        for r in 0..take {
            out.push(ys[r * d..(r + 1) * d].to_vec());
        }
        i += take;
    }
    Ok(out)
}

/// Generate `n` samples with ASD-theta.
pub fn sample_asd(model: &Arc<dyn DenoiseModel>, theta: usize, n: usize,
                  seed0: u64, conds: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
    let mut engine = AsdEngine::new(
        model.clone(),
        AsdConfig {
            theta,
            eval_tail: true,
            backend: KernelBackend::Native,
            ..Default::default()
        },
    );
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let seed = seed0 + i as u64;
        let y0 = if model.cond_dim() > 0 {
            engine.sample_cond(seed, &conds[i % conds.len()])?.y0
        } else {
            engine.sample(seed)?.y0
        };
        out.push(y0);
    }
    Ok(out)
}

/// Generate `n` samples with draft-model speculative sampling: `draft`
/// proposes `k_window`-step trajectories, `model` verifies each window
/// in one fused round. Exactness does not depend on the draft — GRS
/// accepts or resamples against the target's own law.
pub fn sample_draft_sd(model: &Arc<dyn DenoiseModel>,
                       draft: &Arc<dyn DenoiseModel>, k_window: usize,
                       n: usize, seed0: u64, conds: &[Vec<f64>])
                       -> Result<Vec<Vec<f64>>> {
    let mut engine = DraftEngine::new(
        model.clone(), draft.clone(),
        DraftConfig { k: k_window, ..Default::default() });
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let seed = seed0 + i as u64;
        let y0 = if model.cond_dim() > 0 {
            engine.sample_cond(seed, &conds[i % conds.len()])?.y0
        } else {
            engine.sample(seed)?.y0
        };
        out.push(y0);
    }
    Ok(out)
}

/// Score one method's samples against the target.
pub fn score(target: &TargetSpec, samples: Vec<Vec<f64>>,
             classes: Option<&[usize]>, method: &str, seed: u64)
             -> QualityRow {
    let mut rng = Philox::new(seed, 0xf1d);
    let n = samples.len();
    let (reference, _) = sample_target(target, n, &mut rng);
    let alignment = match (classes, Gmm::from_target(target)) {
        (Some(cls), Some(gmm)) => {
            Some(alignment_score(&gmm, &samples, &cls[..n]))
        }
        _ => None,
    };
    QualityRow {
        method: method.to_string(),
        alignment,
        frechet: frechet_diag(&samples, &reference),
        sliced_w: sliced_w(&samples, &reference),
        n_samples: n,
    }
}

/// Build per-sample conditioning rows (+ the class labels) for a
/// conditional GMM variant: classes cycle 0..C.
pub fn make_class_conds(model: &Arc<dyn DenoiseModel>, n: usize)
                        -> (Vec<Vec<f64>>, Vec<usize>) {
    let c = model.cond_dim();
    let mut conds = Vec::with_capacity(n);
    let mut classes = Vec::with_capacity(n);
    for i in 0..n {
        let cls = i % c.max(1);
        let mut row = vec![0.0; c];
        if c > 0 {
            row[cls] = 1.0;
        }
        conds.push(row);
        classes.push(cls);
    }
    (conds, classes)
}

/// Quantized-tier quality leg on the GMM analytic workload: the same
/// fixed native MLP sampled under f32, f16, and int8 packed panels,
/// plus an f32 *reseed* row that calibrates pure sampling noise.
/// Absolute scores are irrelevant here (the toy MLP is untrained) —
/// the claim under test is that panel quantization shifts the score
/// distribution by no more than seed noise does, so the
/// quantized-with-error-bound tier is statistically indistinguishable
/// from f32 at sampling time. Returns the target plus rows in order:
/// `native-f32`, `native-f32-reseed`, `native-f16`, `native-int8`.
pub fn quantized_tier_rows(n: usize, seed0: u64)
                           -> Result<(TargetSpec, Vec<QualityRow>)> {
    use crate::math::isa::{IsaRequest, KernelPolicy, Precision};
    use crate::model::{NativeMlp, VariantInfo};
    let gmm = Gmm::circle_2d();
    let target = TargetSpec::Gmm {
        means: (0..8).map(|c| gmm.mean_of(c).to_vec()).collect(),
        sigmas: gmm.sigmas.clone(),
        weights: gmm.weights.clone(),
    };
    let info = VariantInfo::toy("quant-tier", 2, 0, 24, 1, 20);
    let flat: Vec<f32> = (0..info.weights_len())
        .map(|i| ((((i * 37) % 101) as f32 / 101.0) - 0.5) * 0.6)
        .collect();
    let mut rows = Vec::new();
    for (method, precision, seed) in [
        ("native-f32", Precision::F32, seed0),
        ("native-f32-reseed", Precision::F32, seed0 + 7919),
        ("native-f16", Precision::F16, seed0),
        ("native-int8", Precision::Int8, seed0),
    ] {
        let policy = KernelPolicy { isa: IsaRequest::Auto, precision };
        let model: Arc<dyn DenoiseModel> =
            NativeMlp::from_flat_with(&info, &flat, policy)?;
        let samples = sample_ddpm(&model, n, seed, &[])?;
        rows.push(score(&target, samples, None, method, 1));
    }
    Ok((target, rows))
}

/// Assert rows from [`quantized_tier_rows`] are statistically
/// indistinguishable: per metric, each quantized row may differ from
/// the f32 row by at most a few reseed-noise widths plus a small
/// absolute floor (the floor keeps a near-zero noise estimate from
/// turning sampling jitter into a failure).
pub fn quantized_indistinguishable(rows: &[QualityRow]) -> Result<()> {
    anyhow::ensure!(rows.len() >= 3,
                    "need f32, f32-reseed, and quantized rows (got {})",
                    rows.len());
    let base = &rows[0];
    let reseed = &rows[1];
    for quant in &rows[2..] {
        for (name, a, b, noise) in [
            ("sliced_w", base.sliced_w, quant.sliced_w,
             (base.sliced_w - reseed.sliced_w).abs()),
            ("frechet", base.frechet, quant.frechet,
             (base.frechet - reseed.frechet).abs()),
        ] {
            let bound = 4.0 * noise + 0.15 * a.abs().max(1.0);
            anyhow::ensure!((a - b).abs() <= bound,
                            "{} {name}: |{b} - {a}| = {} exceeds the \
                             indistinguishability bound {bound} \
                             (reseed noise {noise})",
                            quant.method, (a - b).abs());
        }
    }
    Ok(())
}

pub fn format_quality_table(rows: &[QualityRow], metric_name: &str) -> String {
    let mut out = String::new();
    out.push_str(&format!("{:<12} {:>14} {:>12} {:>12} {:>8}\n", "method",
                          metric_name, "FID-proxy", "sliced-W", "n"));
    for r in rows {
        let a = r.alignment.map(|v| format!("{v:.4}"))
            .unwrap_or_else(|| "-".into());
        out.push_str(&format!("{:<12} {:>14} {:>12.4} {:>12.4} {:>8}\n",
                              r.method, a, r.frechet, r.sliced_w, r.n_samples));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::GmmDdpmOracle;

    #[test]
    fn ddpm_and_asd_quality_match_on_oracle() {
        let gmm = Gmm::circle_2d();
        let target = TargetSpec::Gmm {
            means: (0..8).map(|c| gmm.mean_of(c).to_vec()).collect(),
            sigmas: gmm.sigmas.clone(),
            weights: gmm.weights.clone(),
        };
        let model: Arc<dyn DenoiseModel> =
            GmmDdpmOracle::new(gmm, 60, false);
        let n = 80;
        let ddpm = sample_ddpm(&model, n, 0, &[]).unwrap();
        let asd = sample_asd(&model, 8, n, 0, &[]).unwrap();
        let row_d = score(&target, ddpm, None, "DDPM", 1);
        let row_a = score(&target, asd, None, "ASD-8", 1);
        // both near the target; neither dramatically worse
        assert!(row_d.frechet < 0.3, "ddpm frechet {}", row_d.frechet);
        assert!(row_a.frechet < 0.3, "asd frechet {}", row_a.frechet);
        let table = format_quality_table(&[row_d, row_a], "align");
        assert!(table.contains("ASD-8"));
    }

    #[test]
    fn draft_sd_quality_matches_ddpm_on_oracle() {
        // exactness leg for draft-model speculation: even with a draft
        // whose component means are shifted (so GRS must actually
        // reject), the drawn marginals score the same as sequential
        // DDPM against the analytic target
        let gmm = Gmm::circle_2d();
        let target = TargetSpec::Gmm {
            means: (0..8).map(|c| gmm.mean_of(c).to_vec()).collect(),
            sigmas: gmm.sigmas.clone(),
            weights: gmm.weights.clone(),
        };
        let eps = 0.05;
        let shifted: Vec<Vec<f64>> = (0..8)
            .map(|c| {
                gmm.mean_of(c).iter().enumerate()
                    .map(|(i, &v)| {
                        v + eps * if i % 2 == 0 { 1.0 } else { -1.0 }
                    })
                    .collect()
            })
            .collect();
        let draft_gmm = Gmm::new(shifted, gmm.sigmas.clone(),
                                 gmm.weights.clone());
        let model: Arc<dyn DenoiseModel> =
            GmmDdpmOracle::new(gmm, 60, false);
        let draft: Arc<dyn DenoiseModel> =
            GmmDdpmOracle::new(draft_gmm, 60, false);
        let n = 80;
        let ddpm = sample_ddpm(&model, n, 0, &[]).unwrap();
        let dsd = sample_draft_sd(&model, &draft, 8, n, 0, &[]).unwrap();
        let row_d = score(&target, ddpm, None, "DDPM", 1);
        let row_s = score(&target, dsd, None, "draft-SD", 1);
        assert!(row_d.frechet < 0.3, "ddpm frechet {}", row_d.frechet);
        assert!(row_s.frechet < 0.3, "draft-SD frechet {}", row_s.frechet);
        assert!((row_d.sliced_w - row_s.sliced_w).abs() < 0.2,
                "sliced-W gap: ddpm {} vs draft-SD {}", row_d.sliced_w,
                row_s.sliced_w);
        let table = format_quality_table(&[row_d, row_s], "align");
        assert!(table.contains("draft-SD"));
    }

    #[test]
    fn quantized_tiers_are_statistically_indistinguishable() {
        let (_, rows) = quantized_tier_rows(160, 5).unwrap();
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].method, "native-f32");
        assert_eq!(rows[2].method, "native-f16");
        for r in &rows {
            assert!(r.frechet.is_finite() && r.sliced_w.is_finite(),
                    "{r:?}");
        }
        quantized_indistinguishable(&rows).unwrap();
        let table = format_quality_table(&rows, "align");
        assert!(table.contains("native-int8"));
    }
}
