//! Coordinator throughput/latency bench — the `BENCH_coordinator.json`
//! emitter tracked across PRs (the serving-layer sibling of
//! `BENCH_parallel.json`).
//!
//! Two scenarios:
//! * **Closed-loop concurrency sweep** ([`bench_coordinator`]): at each
//!   concurrency level c it keeps waves of c requests in flight against
//!   a fresh coordinator (mixed sequential / ASD / Picard traffic on
//!   one variant) and reports requests/s, p50/p99 end-to-end latency,
//!   the fused-round shape (`fused_rows_per_round`, occupancy) and the
//!   per-lane aggregates.
//! * **Mixed-variant lanes** ([`bench_mixed_variants`]): concurrent
//!   bursts on several registered variants through ONE coordinator,
//!   reporting each lane's fused-round shape, queue wait and — the
//!   no-head-of-line-blocking proof — whether every lane's round
//!   window overlapped the others' (lanes' round tasks ran
//!   concurrently instead of back to back).
//!
//! Schema v6: each lane gains the failure-domain counters (`rejected`
//! / `timed_out` / `cancelled` / `retried` / `breaker_trips` /
//! `reloads` — see `coordinator::fusion::RecoveryPolicy`), all 0 in a
//! healthy fault-free run.
//!
//! Schema v5: rows carry a `lanes` array and a `pool` object with the
//! work-stealing scheduler's counters (entries executed / stolen /
//! injected, lane round tasks) accumulated over that row's run; the
//! document carries an optional `mixed_variants` section with its own
//! `pool` object. v4 added the GRS verifier outcome per lane
//! (`accepted_steps` / `rejected_steps` / `mean_accept_run`) — the
//! observed accept-run length speculative samplers (ASD, draft-SD)
//! achieve under serving traffic. v5 adds the tile-graph runtime's
//! observability: `pool` gains `tile_tasks` / `graph_rounds` /
//! `ready_pushes` (how many GEMM tiles the barrier-free graph path
//! executed, how many rounds completed as graphs, how many
//! dependency-release pushes the counters performed) and each lane
//! gains `mean_layer_stall_ms` — the estimated per-round time lost to
//! intra-round fork/join barriers, identically 0 on the graph path.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::{Coordinator, LaneSnapshot, Request, SamplerSpec,
                         ServerConfig};
use crate::model::DenoiseModel;
use crate::runtime::pool::PoolStats;
use crate::util::Json;

/// One concurrency level's measurements.
#[derive(Debug, Clone)]
pub struct CoordBenchRow {
    pub concurrency: usize,
    pub requests: usize,
    pub requests_per_s: f64,
    /// end-to-end (queue + service) latency percentiles
    pub p50_latency_ms: f64,
    pub p99_latency_ms: f64,
    /// mean rows per fused round — the batch the kernels actually see
    pub fused_rows_per_round: f64,
    /// mean worker-pool shard occupancy of fused rounds
    pub fused_occupancy: f64,
    pub completed: u64,
    pub failed: u64,
    pub rejected: u64,
    /// per-lane aggregates (one lane in this single-variant sweep)
    pub lanes: Vec<LaneSnapshot>,
    /// work-stealing scheduler counters accumulated during this level
    /// (process-global, so a lower bound on this run's activity)
    pub pool: PoolStats,
}

/// Result of the mixed-variant lane scenario.
#[derive(Debug, Clone)]
pub struct MixedVariantBench {
    pub requests: usize,
    pub wall_s: f64,
    pub requests_per_s: f64,
    pub completed: u64,
    pub failed: u64,
    /// per-variant lane aggregates
    pub lanes: Vec<LaneSnapshot>,
    /// every pair of lanes' fused-round windows overlapped: all
    /// variants' round tasks ran concurrently (no cross-variant
    /// head-of-line blocking, no tick barrier)
    pub lanes_overlap: bool,
    /// work-stealing scheduler counters accumulated during the run
    pub pool: PoolStats,
}

/// Nearest-rank percentile (q in [0, 1]) over a sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (q * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Traffic mix: rotate sequential / ASD / Picard, like the e2e tests.
fn sampler_for(i: usize, theta: usize) -> SamplerSpec {
    match i % 3 {
        0 => SamplerSpec::Sequential,
        1 => SamplerSpec::Asd(theta),
        _ => SamplerSpec::Picard(8, 1e-4),
    }
}

fn one_hot(cond_dim: usize, i: usize) -> Vec<f64> {
    let mut cond = vec![0.0; cond_dim];
    if cond_dim > 0 {
        cond[i % cond_dim] = 1.0;
    }
    cond
}

/// Run the closed-loop bench at each concurrency level. Every level
/// gets a fresh coordinator (fresh metrics) serving `model` as
/// `variant`; `n_requests` total requests are pushed through in waves
/// of `concurrency`.
pub fn bench_coordinator(model: Arc<dyn DenoiseModel>, variant: &str,
                         concurrencies: &[usize], n_requests: usize,
                         config: &ServerConfig, theta: usize)
                         -> Result<Vec<CoordBenchRow>> {
    let cond_dim = model.cond_dim();
    let mut rows = Vec::new();
    for &concurrency in concurrencies {
        let concurrency = concurrency.max(1);
        let n = n_requests.max(concurrency);
        let c = Coordinator::new(ServerConfig {
            // fuse up to the full wave; keep the configured caps
            // otherwise
            max_batch: config.max_batch.max(concurrency),
            ..config.clone()
        })?;
        c.register_model(variant, model.clone());
        let mut latencies_s: Vec<f64> = Vec::with_capacity(n);
        let mut submitted = 0usize;
        let t0 = std::time::Instant::now();
        while submitted < n {
            let wave = concurrency.min(n - submitted);
            let mut rxs = Vec::with_capacity(wave);
            for w in 0..wave {
                let i = submitted + w;
                rxs.push(c.submit(Request {
                    id: 0,
                    variant: variant.to_string(),
                    sampler: sampler_for(i, theta),
                    seed: 10_000 + i as u64,
                    cond: one_hot(cond_dim, i),
                    deadline: None,
                }).1);
            }
            for rx in rxs {
                let r = rx.recv()?;
                if r.error.is_none() {
                    latencies_s.push(r.queued_s + r.service_s);
                }
            }
            submitted += wave;
        }
        let wall_s = t0.elapsed().as_secs_f64().max(1e-12);
        let m = c.metrics();
        c.shutdown();
        latencies_s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        rows.push(CoordBenchRow {
            concurrency,
            requests: n,
            requests_per_s: n as f64 / wall_s,
            p50_latency_ms: percentile(&latencies_s, 0.50) * 1e3,
            p99_latency_ms: percentile(&latencies_s, 0.99) * 1e3,
            fused_rows_per_round: m.fused_rows_per_round,
            fused_occupancy: m.fused_occupancy,
            completed: m.completed,
            failed: m.failed,
            rejected: m.rejected,
            lanes: m.lanes,
            pool: m.pool,
        });
    }
    Ok(rows)
}

/// Mixed-variant closed-loop scenario: one coordinator serving every
/// `(name, model)` pair, `n_per_variant` requests per variant submitted
/// interleaved (round-robin across variants, rotating samplers within
/// each). The returned per-lane windows prove — or disprove — that all
/// lanes progressed concurrently.
pub fn bench_mixed_variants(models: &[(String, Arc<dyn DenoiseModel>)],
                            n_per_variant: usize, config: &ServerConfig,
                            theta: usize) -> Result<MixedVariantBench> {
    anyhow::ensure!(!models.is_empty(), "need at least one variant");
    let c = Coordinator::new(config.clone())?;
    for (name, model) in models {
        c.register_model(name, model.clone());
    }
    let n_total = n_per_variant.max(1) * models.len();
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::with_capacity(n_total);
    for i in 0..n_per_variant.max(1) {
        for (name, model) in models {
            rxs.push(c.submit(Request {
                id: 0,
                variant: name.clone(),
                sampler: sampler_for(i, theta),
                seed: 20_000 + rxs.len() as u64,
                cond: one_hot(model.cond_dim(), i),
                deadline: None,
            }).1);
        }
    }
    for rx in rxs {
        let _ = rx.recv()?;
    }
    let wall_s = t0.elapsed().as_secs_f64().max(1e-12);
    let m = c.metrics();
    c.shutdown();
    let lanes = m.lanes;
    let lanes_overlap = lanes.len() >= 2
        && lanes.iter().enumerate().all(|(i, a)| {
            lanes.iter().skip(i + 1).all(|b| a.overlaps(b))
        });
    Ok(MixedVariantBench {
        requests: n_total,
        wall_s,
        requests_per_s: n_total as f64 / wall_s,
        completed: m.completed,
        failed: m.failed,
        lanes,
        lanes_overlap,
        pool: m.pool,
    })
}

fn lane_json(l: &LaneSnapshot) -> Json {
    Json::obj(vec![
        ("lane", Json::Str(l.lane.clone())),
        ("fused_rounds", Json::Num(l.fused_rounds as f64)),
        ("fused_rows_per_round", Json::Num(l.fused_rows_per_round)),
        ("mean_requests_per_round", Json::Num(l.mean_requests_per_round)),
        ("occupancy", Json::Num(l.occupancy)),
        ("mean_layer_stall_ms", Json::Num(l.mean_layer_stall_ms)),
        ("mean_queue_wait_ms", Json::Num(l.mean_queue_wait_ms)),
        ("admitted", Json::Num(l.admitted as f64)),
        ("first_round_ms", Json::Num(l.first_round_ms)),
        ("last_round_ms", Json::Num(l.last_round_ms)),
        ("arena_high_water_bytes",
         Json::Num(l.arena_high_water_bytes as f64)),
        ("accepted_steps", Json::Num(l.accepted_steps as f64)),
        ("rejected_steps", Json::Num(l.rejected_steps as f64)),
        ("mean_accept_run", Json::Num(l.mean_accept_run)),
        ("rejected", Json::Num(l.rejected as f64)),
        ("timed_out", Json::Num(l.timed_out as f64)),
        ("cancelled", Json::Num(l.cancelled as f64)),
        ("retried", Json::Num(l.retried as f64)),
        ("breaker_trips", Json::Num(l.breaker_trips as f64)),
        ("reloads", Json::Num(l.reloads as f64)),
    ])
}

fn pool_json(p: &PoolStats) -> Json {
    Json::obj(vec![
        ("executed", Json::Num(p.executed as f64)),
        ("stolen", Json::Num(p.stolen as f64)),
        ("injected", Json::Num(p.injected as f64)),
        ("rounds", Json::Num(p.rounds as f64)),
        ("tile_tasks", Json::Num(p.tile_tasks as f64)),
        ("graph_rounds", Json::Num(p.graph_rounds as f64)),
        ("ready_pushes", Json::Num(p.ready_pushes as f64)),
    ])
}

fn row_json(r: &CoordBenchRow) -> Json {
    Json::obj(vec![
        ("concurrency", Json::Num(r.concurrency as f64)),
        ("requests", Json::Num(r.requests as f64)),
        ("requests_per_s", Json::Num(r.requests_per_s)),
        ("p50_latency_ms", Json::Num(r.p50_latency_ms)),
        ("p99_latency_ms", Json::Num(r.p99_latency_ms)),
        ("fused_rows_per_round", Json::Num(r.fused_rows_per_round)),
        ("fused_occupancy", Json::Num(r.fused_occupancy)),
        ("completed", Json::Num(r.completed as f64)),
        ("failed", Json::Num(r.failed as f64)),
        ("rejected", Json::Num(r.rejected as f64)),
        ("lanes", Json::Arr(r.lanes.iter().map(lane_json).collect())),
        ("pool", pool_json(&r.pool)),
    ])
}

fn mixed_json(b: &MixedVariantBench) -> Json {
    Json::obj(vec![
        ("requests", Json::Num(b.requests as f64)),
        ("requests_per_s", Json::Num(b.requests_per_s)),
        ("completed", Json::Num(b.completed as f64)),
        ("failed", Json::Num(b.failed as f64)),
        ("lanes_overlap", Json::Bool(b.lanes_overlap)),
        ("lanes", Json::Arr(b.lanes.iter().map(lane_json).collect())),
        ("pool", pool_json(&b.pool)),
    ])
}

/// Assemble the `BENCH_coordinator.json` document (schema v6: per-lane
/// failure-domain counters on top of v5's per-row `lanes` arrays with
/// GRS accept/reject outcomes and layer-stall estimates + `pool`
/// scheduler counters including the tile-graph counters + optional
/// `mixed_variants` section).
pub fn bench_coordinator_json(variant: &str, k: usize,
                              rows: &[CoordBenchRow],
                              mixed: Option<&MixedVariantBench>) -> Json {
    let mut fields = vec![
        ("bench", Json::Str("bench_coordinator".into())),
        ("schema_version", Json::Num(6.0)),
        ("variant", Json::Str(variant.to_string())),
        ("k", Json::Num(k as f64)),
        ("pool_threads",
         Json::Num(crate::runtime::pool::default_threads() as f64)),
        ("rows", Json::Arr(rows.iter().map(row_json).collect())),
    ];
    if let Some(b) = mixed {
        fields.push(("mixed_variants", mixed_json(b)));
    }
    Json::obj(fields)
}

/// Render the bench as a table.
pub fn format_coord_rows(rows: &[CoordBenchRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<12} {:>10} {:>10} {:>10} {:>12} {:>10}\n",
        "concurrency", "req/s", "p50 ms", "p99 ms", "rows/round", "occup."));
    for r in rows {
        out.push_str(&format!(
            "{:<12} {:>10.1} {:>10.2} {:>10.2} {:>12.2} {:>10.2}\n",
            r.concurrency, r.requests_per_s, r.p50_latency_ms,
            r.p99_latency_ms, r.fused_rows_per_round, r.fused_occupancy));
    }
    out
}

/// Render per-lane aggregates as a table.
pub fn format_lanes(lanes: &[LaneSnapshot]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<16} {:>8} {:>12} {:>8} {:>12} {:>18} {:>12}\n",
        "lane", "rounds", "rows/round", "occup.", "queue ms",
        "window ms", "arena KiB"));
    for l in lanes {
        out.push_str(&format!(
            "{:<16} {:>8} {:>12.2} {:>8.2} {:>12.2} {:>8.1}..{:<8.1} \
             {:>12.1}\n",
            l.lane, l.fused_rounds, l.fused_rows_per_round, l.occupancy,
            l.mean_queue_wait_ms, l.first_round_ms, l.last_round_ms,
            l.arena_high_water_bytes as f64 / 1024.0));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Gmm, GmmDdpmOracle};

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.50), 50.0);
        assert_eq!(percentile(&v, 0.99), 99.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
    }

    #[test]
    fn bench_runs_and_roundtrips_json() {
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 30, false);
        let rows = bench_coordinator(oracle, "gmm", &[1, 4], 8,
                                     &ServerConfig {
                                         workers: 1,
                                         ..Default::default()
                                     }, 8)
            .unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert_eq!(r.completed, r.requests as u64);
            assert_eq!(r.failed, 0);
            assert!(r.requests_per_s > 0.0);
            assert!(r.p99_latency_ms >= r.p50_latency_ms);
        }
        // concurrency 4 must actually fuse rows, and the lane array
        // carries the single lane's aggregates
        assert!(rows[1].fused_rows_per_round > 1.0,
                "rows/round {}", rows[1].fused_rows_per_round);
        assert_eq!(rows[1].lanes.len(), 1);
        assert_eq!(rows[1].lanes[0].lane, "gmm");
        assert!(rows[1].lanes[0].fused_rounds > 0);
        let doc = bench_coordinator_json("gmm", 30, &rows, None);
        let back = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(back.get("bench").unwrap().as_str().unwrap(),
                   "bench_coordinator");
        assert_eq!(back.get("schema_version").unwrap().as_usize().unwrap(),
                   6);
        let rs = back.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[1].get("concurrency").unwrap().as_usize().unwrap(), 4);
        let lanes = rs[1].get("lanes").unwrap().as_arr().unwrap();
        assert_eq!(lanes.len(), 1);
        assert!(lanes[0].get("fused_rows_per_round").unwrap()
                    .as_f64().unwrap() > 1.0);
        assert!(lanes[0].get("mean_queue_wait_ms").is_ok());
        // schema v4: the GRS outcome rode along — the mix includes ASD
        // requests, so the lane must have accepted transitions and a
        // positive mean accept-run length
        assert!(lanes[0].get("accepted_steps").unwrap()
                    .as_f64().unwrap() > 0.0);
        assert!(lanes[0].get("mean_accept_run").unwrap()
                    .as_f64().unwrap() > 0.0);
        assert!(lanes[0].get("rejected_steps").is_ok());
        // the scheduler counters rode along: fused rounds flow through
        // the pool's round-task registry
        let pool = rs[1].get("pool").unwrap();
        assert!(pool.get("rounds").unwrap().as_f64().unwrap() > 0.0);
        assert!(pool.get("executed").unwrap().as_f64().unwrap() > 0.0);
        // schema v5: tile-graph counters and the per-lane stall
        // estimate are present (the analytic oracle has no graph form,
        // so the values can be 0 here — nonzero coverage lives in the
        // NativeMlp determinism suite)
        assert!(pool.get("tile_tasks").is_ok());
        assert!(pool.get("graph_rounds").is_ok());
        assert!(pool.get("ready_pushes").is_ok());
        assert!(lanes[0].get("mean_layer_stall_ms").is_ok());
        // schema v6: failure-domain counters ride along per lane, all
        // 0 in this fault-free run
        for key in ["rejected", "timed_out", "cancelled", "retried",
                    "breaker_trips", "reloads"] {
            assert_eq!(lanes[0].get(key).unwrap().as_f64().unwrap(), 0.0,
                       "{key} nonzero in a fault-free run");
        }
        let table = format_coord_rows(&rows);
        assert!(table.contains("rows/round"));
    }

    #[test]
    fn mixed_variant_bench_reports_overlapping_lanes() {
        // ONE worker, two variants: the lane driver must progress both
        // lanes concurrently (overlapping round windows)
        let a: Arc<dyn DenoiseModel> =
            GmmDdpmOracle::new(Gmm::circle_2d(), 50, false);
        let b: Arc<dyn DenoiseModel> =
            GmmDdpmOracle::new(Gmm::random(3, 4, 1.5, 11), 50, false);
        let models = vec![("gmm-a".to_string(), a),
                          ("gmm-b".to_string(), b)];
        let bench = bench_mixed_variants(&models, 6, &ServerConfig {
            workers: 1,
            max_batch: 8,
            ..Default::default()
        }, 8).unwrap();
        assert_eq!(bench.requests, 12);
        assert_eq!(bench.completed, 12);
        assert_eq!(bench.failed, 0);
        assert_eq!(bench.lanes.len(), 2);
        for lane in &bench.lanes {
            assert!(lane.fused_rounds > 0, "lane {} never fused",
                    lane.lane);
            assert!(lane.fused_rows_per_round > 1.0,
                    "lane {} rows/round {}", lane.lane,
                    lane.fused_rows_per_round);
        }
        assert!(bench.lanes_overlap,
                "lanes ran back to back: {:?}", bench.lanes);
        // document embeds the mixed section
        let doc = bench_coordinator_json("mixed", 50, &[], Some(&bench));
        let back = Json::parse(&doc.to_string()).unwrap();
        let mixed = back.get("mixed_variants").unwrap();
        assert!(mixed.get("lanes_overlap").unwrap().as_bool().unwrap());
        assert_eq!(mixed.get("lanes").unwrap().as_arr().unwrap().len(), 2);
        assert!(mixed.get("pool").unwrap().get("rounds").unwrap()
                    .as_f64().unwrap() > 0.0);
        let table = format_lanes(&bench.lanes);
        assert!(table.contains("gmm-a") && table.contains("gmm-b"));
    }
}
