//! Coordinator throughput/latency bench — the `BENCH_coordinator.json`
//! emitter tracked across PRs (the serving-layer sibling of
//! `BENCH_parallel.json`).
//!
//! Closed-loop load generator: at each concurrency level c it keeps
//! waves of c requests in flight against a fresh coordinator (mixed
//! sequential / ASD / Picard traffic on one variant) and reports
//! requests/s, p50/p99 end-to-end latency, and the fused-round shape
//! (`fused_rows_per_round`, occupancy) that shows cross-request fusion
//! actually saturating the batch dimension.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::{Coordinator, Request, SamplerSpec, ServerConfig};
use crate::model::DenoiseModel;
use crate::util::Json;

/// One concurrency level's measurements.
#[derive(Debug, Clone)]
pub struct CoordBenchRow {
    pub concurrency: usize,
    pub requests: usize,
    pub requests_per_s: f64,
    /// end-to-end (queue + service) latency percentiles
    pub p50_latency_ms: f64,
    pub p99_latency_ms: f64,
    /// mean rows per fused round — the batch the kernels actually see
    pub fused_rows_per_round: f64,
    /// mean worker-pool shard occupancy of fused rounds
    pub fused_occupancy: f64,
    pub completed: u64,
    pub failed: u64,
    pub rejected: u64,
}

/// Nearest-rank percentile (q in [0, 1]) over a sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (q * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Traffic mix: rotate sequential / ASD / Picard, like the e2e tests.
fn sampler_for(i: usize, theta: usize) -> SamplerSpec {
    match i % 3 {
        0 => SamplerSpec::Sequential,
        1 => SamplerSpec::Asd(theta),
        _ => SamplerSpec::Picard(8, 1e-4),
    }
}

/// Run the closed-loop bench at each concurrency level. Every level
/// gets a fresh coordinator (fresh metrics) serving `model` as
/// `variant`; `n_requests` total requests are pushed through in waves
/// of `concurrency`.
pub fn bench_coordinator(model: Arc<dyn DenoiseModel>, variant: &str,
                         concurrencies: &[usize], n_requests: usize,
                         config: &ServerConfig, theta: usize)
                         -> Result<Vec<CoordBenchRow>> {
    let cond_dim = model.cond_dim();
    let mut rows = Vec::new();
    for &concurrency in concurrencies {
        let concurrency = concurrency.max(1);
        let n = n_requests.max(concurrency);
        let c = Coordinator::new(ServerConfig {
            // fuse up to the full wave; keep the configured caps
            // otherwise
            max_batch: config.max_batch.max(concurrency),
            ..config.clone()
        });
        c.register_model(variant, model.clone());
        let mut latencies_s: Vec<f64> = Vec::with_capacity(n);
        let mut submitted = 0usize;
        let t0 = std::time::Instant::now();
        while submitted < n {
            let wave = concurrency.min(n - submitted);
            let mut rxs = Vec::with_capacity(wave);
            for w in 0..wave {
                let i = submitted + w;
                let mut cond = vec![0.0; cond_dim];
                if cond_dim > 0 {
                    cond[i % cond_dim] = 1.0;
                }
                rxs.push(c.submit(Request {
                    id: 0,
                    variant: variant.to_string(),
                    sampler: sampler_for(i, theta),
                    seed: 10_000 + i as u64,
                    cond,
                }).1);
            }
            for rx in rxs {
                let r = rx.recv()?;
                if r.error.is_none() {
                    latencies_s.push(r.queued_s + r.service_s);
                }
            }
            submitted += wave;
        }
        let wall_s = t0.elapsed().as_secs_f64().max(1e-12);
        let m = c.metrics();
        c.shutdown();
        latencies_s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        rows.push(CoordBenchRow {
            concurrency,
            requests: n,
            requests_per_s: n as f64 / wall_s,
            p50_latency_ms: percentile(&latencies_s, 0.50) * 1e3,
            p99_latency_ms: percentile(&latencies_s, 0.99) * 1e3,
            fused_rows_per_round: m.fused_rows_per_round,
            fused_occupancy: m.fused_occupancy,
            completed: m.completed,
            failed: m.failed,
            rejected: m.rejected,
        });
    }
    Ok(rows)
}

fn row_json(r: &CoordBenchRow) -> Json {
    Json::obj(vec![
        ("concurrency", Json::Num(r.concurrency as f64)),
        ("requests", Json::Num(r.requests as f64)),
        ("requests_per_s", Json::Num(r.requests_per_s)),
        ("p50_latency_ms", Json::Num(r.p50_latency_ms)),
        ("p99_latency_ms", Json::Num(r.p99_latency_ms)),
        ("fused_rows_per_round", Json::Num(r.fused_rows_per_round)),
        ("fused_occupancy", Json::Num(r.fused_occupancy)),
        ("completed", Json::Num(r.completed as f64)),
        ("failed", Json::Num(r.failed as f64)),
        ("rejected", Json::Num(r.rejected as f64)),
    ])
}

/// Assemble the `BENCH_coordinator.json` document.
pub fn bench_coordinator_json(variant: &str, k: usize,
                              rows: &[CoordBenchRow]) -> Json {
    Json::obj(vec![
        ("bench", Json::Str("bench_coordinator".into())),
        ("schema_version", Json::Num(1.0)),
        ("variant", Json::Str(variant.to_string())),
        ("k", Json::Num(k as f64)),
        ("pool_threads",
         Json::Num(crate::runtime::pool::default_threads() as f64)),
        ("rows", Json::Arr(rows.iter().map(row_json).collect())),
    ])
}

/// Render the bench as a table.
pub fn format_coord_rows(rows: &[CoordBenchRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<12} {:>10} {:>10} {:>10} {:>12} {:>10}\n",
        "concurrency", "req/s", "p50 ms", "p99 ms", "rows/round", "occup."));
    for r in rows {
        out.push_str(&format!(
            "{:<12} {:>10.1} {:>10.2} {:>10.2} {:>12.2} {:>10.2}\n",
            r.concurrency, r.requests_per_s, r.p50_latency_ms,
            r.p99_latency_ms, r.fused_rows_per_round, r.fused_occupancy));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Gmm, GmmDdpmOracle};

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.50), 50.0);
        assert_eq!(percentile(&v, 0.99), 99.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
    }

    #[test]
    fn bench_runs_and_roundtrips_json() {
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 30, false);
        let rows = bench_coordinator(oracle, "gmm", &[1, 4], 8,
                                     &ServerConfig {
                                         workers: 1,
                                         ..Default::default()
                                     }, 8)
            .unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert_eq!(r.completed, r.requests as u64);
            assert_eq!(r.failed, 0);
            assert!(r.requests_per_s > 0.0);
            assert!(r.p99_latency_ms >= r.p50_latency_ms);
        }
        // concurrency 4 must actually fuse rows
        assert!(rows[1].fused_rows_per_round > 1.0,
                "rows/round {}", rows[1].fused_rows_per_round);
        let doc = bench_coordinator_json("gmm", 30, &rows);
        let back = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(back.get("bench").unwrap().as_str().unwrap(),
                   "bench_coordinator");
        let rs = back.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[1].get("concurrency").unwrap().as_usize().unwrap(), 4);
        let table = format_coord_rows(&rows);
        assert!(table.contains("rows/round"));
    }
}
