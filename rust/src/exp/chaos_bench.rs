//! Chaos bench — the `BENCH_chaos.json` emitter behind `asd chaos`.
//!
//! Sweeps per-round fault rates against a live coordinator serving a
//! mixed sequential / ASD / Picard / draft-SD burst under a seeded
//! [`FaultPlan`] and reports, per rate:
//!
//! * **completion rate** — fraction of submitted requests answered
//!   successfully despite injected round panics / NaN outputs /
//!   latency spikes,
//! * **goodput** — successful requests per second of wall clock (the
//!   throughput the client actually sees under faults),
//! * **recovery latency** — mean end-to-end service time of requests
//!   that needed at least one retry (how much a faulted round costs
//!   the request that survives it),
//! * the failure-domain counters (`timed_out` / `retried` /
//!   `breaker_trips`) from the metrics snapshot.
//!
//! Every 8th request carries an already-expired deadline so the sweep
//! exercises the queue-side deadline sweep even at fault rate 0.
//!
//! Schema v1: `{bench: "bench_chaos", schema_version: 1, k, theta,
//! requests_per_rate, seed, rows: [...]}`.

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::{Coordinator, RecoveryPolicy, Request,
                         SamplerSpec, ServerConfig};
use crate::faults::FaultPlan;
use crate::model::{DenoiseModel, Gmm, GmmDdpmOracle};
use crate::util::Json;

/// One fault rate's measurements.
#[derive(Debug, Clone)]
pub struct ChaosRow {
    /// per-round panic probability; non-finite / latency / tile faults
    /// are injected at half this rate each
    pub fault_rate: f64,
    pub requests: usize,
    pub completed: u64,
    pub failed: u64,
    /// admission rejections (open breaker / draining / full queue)
    pub rejected: u64,
    pub timed_out: u64,
    pub retried: u64,
    pub breaker_trips: u64,
    /// completed / requests
    pub completion_rate: f64,
    /// completed / elapsed_s — successful requests per wall second
    pub goodput_rps: f64,
    /// mean service time (ms) of requests that retried at least once;
    /// 0 when no request retried
    pub mean_recovery_ms: f64,
    pub elapsed_s: f64,
}

/// Target model for the sweep: the 8-d GMM oracle the determinism
/// suites use, analytic so the bench runs anywhere.
fn target(k: usize) -> Arc<dyn DenoiseModel> {
    GmmDdpmOracle::new(Gmm::random(8, 6, 1.5, 3), k, false)
}

/// Imperfect draft for [`target`]: component means shifted by 0.05
/// with alternating sign, so draft-SD verification rejects some
/// windows under chaos too.
fn draft(k: usize) -> Arc<dyn DenoiseModel> {
    let base = Gmm::random(8, 6, 1.5, 3);
    let means: Vec<Vec<f64>> = (0..base.weights.len())
        .map(|c| {
            base.mean_of(c).iter().enumerate()
                .map(|(i, &v)| {
                    v + 0.05 * if i % 2 == 0 { 1.0 } else { -1.0 }
                })
                .collect()
        })
        .collect();
    let gmm = Gmm::new(means, base.sigmas.clone(), base.weights.clone());
    GmmDdpmOracle::new(gmm, k, false)
}

/// Traffic mix: rotate all four sampler families so every machine kind
/// rides through the fault schedule.
fn sampler_for(i: usize, theta: usize) -> SamplerSpec {
    match i % 4 {
        0 => SamplerSpec::Sequential,
        1 => SamplerSpec::Asd(theta),
        2 => SamplerSpec::Picard(8, 1e-6),
        _ => SamplerSpec::Draft(theta),
    }
}

/// Run the chaos sweep: one fresh coordinator per fault rate, each
/// serving `n_requests` mixed-sampler requests under a [`FaultPlan`]
/// seeded with `seed` whose panic rate is the row's `fault_rate` (and
/// non-finite / latency / tile rates at half that).
pub fn bench_chaos(k: usize, theta: usize, n_requests: usize,
                   workers: usize, fault_rates: &[f64], seed: u64)
                   -> Result<Vec<ChaosRow>> {
    let mut rows = Vec::with_capacity(fault_rates.len());
    for &rate in fault_rates {
        let plan = FaultPlan {
            seed,
            panic_rate: rate,
            non_finite_rate: rate / 2.0,
            latency_rate: rate / 2.0,
            latency: Duration::from_millis(1),
            tile_rate: rate / 2.0,
            only_lane: None,
        };
        let c = Coordinator::new(ServerConfig {
            workers,
            faults: if rate > 0.0 { Some(plan) } else { None },
            recovery: RecoveryPolicy {
                retry_max: 3,
                backoff_rounds: 1,
                // high enough that the breaker only trips under a
                // genuinely pathological streak, not ambient chaos
                breaker_threshold: 8,
                breaker_cooldown: Duration::from_millis(50),
                validate_outputs: true,
            },
            ..ServerConfig::default()
        })?;
        c.register_model("gmm", target(k));
        c.register_model("gmm-draft", draft(k));
        c.pair_draft("gmm", "gmm-draft")?;
        let n = n_requests.max(1);
        let t0 = std::time::Instant::now();
        let mut rxs = Vec::with_capacity(n);
        for i in 0..n {
            rxs.push(c.submit(Request {
                id: 0,
                variant: "gmm".into(),
                sampler: sampler_for(i, theta),
                seed: 40_000 + i as u64,
                cond: vec![],
                // every 8th request is born expired: the deadline
                // sweep must fire even in the fault-free row
                deadline: if i % 8 == 7 {
                    Some(Duration::ZERO)
                } else {
                    None
                },
            }).1);
        }
        let mut recovery_ms: Vec<f64> = Vec::new();
        for rx in rxs {
            let r = rx.recv()?;
            if r.retries > 0 {
                recovery_ms.push(r.service_s * 1e3);
            }
        }
        let elapsed_s = t0.elapsed().as_secs_f64().max(1e-12);
        let m = c.metrics();
        c.shutdown();
        let mean_recovery_ms = if recovery_ms.is_empty() {
            0.0
        } else {
            recovery_ms.iter().sum::<f64>() / recovery_ms.len() as f64
        };
        rows.push(ChaosRow {
            fault_rate: rate,
            requests: n,
            completed: m.completed,
            failed: m.failed,
            rejected: m.rejected,
            timed_out: m.timed_out,
            retried: m.retried,
            breaker_trips: m.breaker_trips,
            completion_rate: m.completed as f64 / n as f64,
            goodput_rps: m.completed as f64 / elapsed_s,
            mean_recovery_ms,
            elapsed_s,
        });
    }
    Ok(rows)
}

fn row_json(r: &ChaosRow) -> Json {
    Json::obj(vec![
        ("fault_rate", Json::Num(r.fault_rate)),
        ("requests", Json::Num(r.requests as f64)),
        ("completed", Json::Num(r.completed as f64)),
        ("failed", Json::Num(r.failed as f64)),
        ("rejected", Json::Num(r.rejected as f64)),
        ("timed_out", Json::Num(r.timed_out as f64)),
        ("retried", Json::Num(r.retried as f64)),
        ("breaker_trips", Json::Num(r.breaker_trips as f64)),
        ("completion_rate", Json::Num(r.completion_rate)),
        ("goodput_rps", Json::Num(r.goodput_rps)),
        ("mean_recovery_ms", Json::Num(r.mean_recovery_ms)),
        ("elapsed_s", Json::Num(r.elapsed_s)),
    ])
}

/// Assemble the `BENCH_chaos.json` document (schema v1).
pub fn bench_chaos_json(k: usize, theta: usize, n_requests: usize,
                        seed: u64, rows: &[ChaosRow]) -> Json {
    Json::obj(vec![
        ("bench", Json::Str("bench_chaos".into())),
        ("schema_version", Json::Num(1.0)),
        ("k", Json::Num(k as f64)),
        ("theta", Json::Num(theta as f64)),
        ("requests_per_rate", Json::Num(n_requests as f64)),
        ("seed", Json::Num(seed as f64)),
        ("rows", Json::Arr(rows.iter().map(row_json).collect())),
    ])
}

/// Render the sweep as a table.
pub fn format_chaos_rows(rows: &[ChaosRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>12} {:>10}\n",
        "fault", "completed", "failed", "timed_out", "retried",
        "breakers", "recovery ms", "goodput"));
    for r in rows {
        out.push_str(&format!(
            "{:<10.3} {:>10} {:>10} {:>10} {:>10} {:>10} {:>12.2} \
             {:>10.1}\n",
            r.fault_rate, r.completed, r.failed, r.timed_out, r.retried,
            r.breaker_trips, r.mean_recovery_ms, r.goodput_rps));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_sweep_runs_and_roundtrips_json() {
        let rows = bench_chaos(20, 8, 16, 1, &[0.0, 0.25], 7).unwrap();
        assert_eq!(rows.len(), 2);
        // fault-free row: only the born-expired deadlines fail
        assert_eq!(rows[0].timed_out, 2);
        assert_eq!(rows[0].completed, 14);
        assert_eq!(rows[0].retried, 0);
        assert!((rows[0].completion_rate - 14.0 / 16.0).abs() < 1e-12);
        // faulted row: every request is answered one way or the other
        assert_eq!(rows[1].completed + rows[1].failed + rows[1].rejected,
                   16);
        assert!(rows[1].goodput_rps > 0.0);
        let doc = bench_chaos_json(20, 8, 16, 7, &rows);
        let back = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(back.get("bench").unwrap().as_str().unwrap(),
                   "bench_chaos");
        assert_eq!(back.get("schema_version").unwrap().as_usize().unwrap(),
                   1);
        let rs = back.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0].get("timed_out").unwrap().as_f64().unwrap(), 2.0);
        assert!(rs[1].get("completion_rate").unwrap().as_f64().unwrap()
                    > 0.0);
        let table = format_chaos_rows(&rows);
        assert!(table.contains("recovery ms"));
    }
}
