//! Multi-worker wall-clock model (DESIGN.md §3).
//!
//! The paper measures wall-clock speedup on 8 GPUs (images) or one GPU
//! with batching (policies). This testbed has one CPU core, so measured
//! wall-clock under-reports parallelism; we therefore report BOTH the
//! real measured wall-clock and a modeled multi-worker wall-clock built
//! from measured per-call latencies:
//!
//!   T_round(B) = T_call(ceil(B / workers) batch rows)  +  xfer(B)
//!   xfer(B)    = xfer_per_float * B * d   (inter-process transfer)
//!
//! with T_call(b) interpolated from the measured per-batch-size latency
//! table of the actual HLO executables on this machine.

#[derive(Debug, Clone)]
pub struct LatencyModel {
    /// measured mean seconds per call, per compiled batch size
    pub call_s: Vec<(usize, f64)>,
    /// simulated worker count ("GPUs")
    pub workers: usize,
    /// seconds per transferred f32 between workers (paper: PCIe hop)
    pub xfer_per_float: f64,
    /// data dimension
    pub d: usize,
}

impl LatencyModel {
    /// Interpolated single-call latency for an arbitrary batch size.
    pub fn call_latency(&self, batch: usize) -> f64 {
        if self.call_s.is_empty() {
            return 0.0;
        }
        if let Some(&(_, s)) = self.call_s.iter().find(|(b, _)| *b >= batch) {
            return s;
        }
        // beyond the table: scale the largest entry linearly
        let &(b_max, s_max) = self.call_s.last().unwrap();
        s_max * batch as f64 / b_max as f64
    }

    /// Modeled duration of one parallel round with `batch` model calls
    /// spread over `workers` devices.
    pub fn round_s(&self, batch: usize) -> f64 {
        if batch == 0 {
            return 0.0;
        }
        let per_worker = batch.div_ceil(self.workers.max(1));
        let xfer = if batch > 1 {
            self.xfer_per_float * (batch * self.d) as f64
        } else {
            0.0
        };
        self.call_latency(per_worker) + xfer
    }

    /// Modeled wall-clock of a whole run given its per-round batches.
    pub fn run_s(&self, round_batches: &[usize]) -> f64 {
        round_batches.iter().map(|&b| self.round_s(b)).sum()
    }

    /// Sequential baseline: K rounds of batch 1.
    pub fn sequential_s(&self, k: usize) -> f64 {
        self.call_latency(1) * k as f64
    }
}

/// Measure the per-batch-size call latency table of an HLO model on this
/// machine (drives the modeled multi-worker wall-clock).
pub fn measure_call_table(model: &std::sync::Arc<crate::runtime::HloModel>,
                          reps: usize) -> anyhow::Result<Vec<(usize, f64)>> {
    use crate::model::DenoiseModel;
    let d = model.info.d;
    let c = model.info.cond_dim;
    let k = model.info.k_steps;
    let sizes: Vec<usize> = model.info.artifacts.keys().copied().collect();
    let mut table = Vec::new();
    for &b in &sizes {
        let ys = vec![0.1; b * d];
        let ts = vec![(k / 2) as f64; b];
        let cond = vec![0.0; b * c];
        let mut out = vec![0.0; b * d];
        // warmup (compiles lazily on first call)
        model.denoise_batch(&ys, &ts, &cond, b, &mut out)?;
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            model.denoise_batch(&ys, &ts, &cond, b, &mut out)?;
        }
        table.push((b, t0.elapsed().as_secs_f64() / reps as f64));
    }
    Ok(table)
}

/// Default latency model for a variant: measured call table, the
/// paper's 8 workers, and a PCIe-class transfer cost per float.
pub fn default_latency_model(model: &std::sync::Arc<crate::runtime::HloModel>,
                             workers: usize)
                             -> anyhow::Result<LatencyModel> {
    Ok(LatencyModel {
        call_s: measure_call_table(model, 10)?,
        workers,
        xfer_per_float: 2e-9, // ~2 GB/s effective host<->device per float pair
        d: model.info.d,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> LatencyModel {
        LatencyModel {
            call_s: vec![(1, 1e-3), (2, 1.2e-3), (4, 1.6e-3), (8, 2.5e-3)],
            workers: 4,
            xfer_per_float: 1e-8,
            d: 16,
        }
    }

    #[test]
    fn interpolation_picks_next_size() {
        let m = model();
        assert_eq!(m.call_latency(1), 1e-3);
        assert_eq!(m.call_latency(3), 1.6e-3);
        // beyond the table: linear extrapolation
        assert!((m.call_latency(16) - 5e-3).abs() < 1e-12);
    }

    #[test]
    fn workers_cut_round_latency() {
        let m = model();
        // batch 8 over 4 workers: per-worker batch 2 + transfer
        let r = m.round_s(8);
        assert!(r < m.call_latency(8), "parallel round must beat 1 worker");
        assert!(r >= m.call_latency(2));
    }

    #[test]
    fn run_and_sequential() {
        let m = model();
        let seq = m.sequential_s(100);
        assert!((seq - 0.1).abs() < 1e-9);
        let asd = m.run_s(&[1, 7, 1, 7, 1, 7]);
        assert!(asd < seq);
    }

    #[test]
    fn zero_batch_is_free() {
        assert_eq!(model().round_s(0), 0.0);
    }
}
