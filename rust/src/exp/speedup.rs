//! Speedup drivers: the theta sweep (Figures 2, 4, 5) and the
//! pool-size sweep (measured wall-clock vs algorithmic rounds).
//!
//! Two speedup columns, two different claims:
//! * **algorithmic** — `K / parallel_rounds`, the Theorem 4 quantity;
//!   counts rounds of (possibly batched) model calls, hardware-blind.
//! * **measured** — real wall-clock against the same sweep at
//!   `pool_size = 1`, with verify batches physically sharded across the
//!   global worker pool. This is the column that proves rounds are real
//!   work, not bookkeeping; outputs stay bit-identical across pool
//!   sizes (checked via `bits_checksum`).

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::asd::{AsdConfig, AsdEngine, KernelBackend};
use crate::exp::latency::LatencyModel;
use crate::model::DenoiseModel;
use crate::runtime::pool::PoolConfig;
use crate::util::Json;

#[derive(Debug, Clone)]
pub struct SpeedupRow {
    /// 0 = infinity
    pub theta: usize,
    pub algorithmic_speedup: f64,
    /// measured on this testbed (single device)
    pub wallclock_speedup_1dev: f64,
    /// modeled multi-worker wall-clock speedup (DESIGN.md §3)
    pub wallclock_speedup_modeled: f64,
    pub acceptance_rate: f64,
    pub mean_rounds: f64,
    pub mean_model_calls: f64,
}

impl SpeedupRow {
    pub fn label(&self) -> String {
        if self.theta == 0 {
            "ASD-inf".to_string()
        } else {
            format!("ASD-{}", self.theta)
        }
    }
}

/// Run `n_samples` ASD samplings per theta (plus the sequential baseline)
/// and aggregate the paper's speedup numbers. `seq_wall_s` must be the
/// measured per-sample sequential wall-clock on the same model.
pub fn sweep_thetas(model: Arc<dyn DenoiseModel>, thetas: &[usize],
                    n_samples: usize, seq_wall_s: f64, seed0: u64,
                    conds: Option<&[Vec<f64>]>,
                    latency: &LatencyModel) -> Result<Vec<SpeedupRow>> {
    let k = model.k_steps();
    let mut rows = Vec::new();
    for &theta in thetas {
        let mut engine = AsdEngine::new(
            model.clone(),
            AsdConfig {
                theta,
                eval_tail: true,
                backend: KernelBackend::Native,
                ..Default::default()
            },
        );
        let mut rounds = 0usize;
        let mut calls = 0usize;
        let mut accepted = 0usize;
        let mut rejected = 0usize;
        let mut wall = 0.0;
        let mut modeled = 0.0;
        for s in 0..n_samples {
            let seed = seed0 + s as u64;
            let out = match conds {
                Some(cs) => engine.sample_cond(seed, &cs[s % cs.len()])?,
                None => engine.sample(seed)?,
            };
            rounds += out.stats.parallel_rounds;
            calls += out.stats.model_calls;
            accepted += out.stats.accepted;
            rejected += out.stats.rejected;
            wall += out.wallclock_s;
            modeled += latency.run_s(&out.stats.round_batches);
        }
        let n = n_samples as f64;
        rows.push(SpeedupRow {
            theta,
            algorithmic_speedup: k as f64 / (rounds as f64 / n),
            wallclock_speedup_1dev: seq_wall_s / (wall / n),
            wallclock_speedup_modeled: latency.sequential_s(k) / (modeled / n),
            acceptance_rate: accepted as f64 / (accepted + rejected).max(1) as f64,
            mean_rounds: rounds as f64 / n,
            mean_model_calls: calls as f64 / n,
        });
    }
    Ok(rows)
}

/// One pool-size sweep point: measured wall-clock next to the
/// algorithmic rounds speedup, plus a bitwise output checksum proving
/// sharding left every sample untouched.
#[derive(Debug, Clone)]
pub struct PoolRow {
    pub pool_size: usize,
    /// `K / mean parallel_rounds` (Theorem 4 quantity; pool-invariant)
    pub algorithmic_speedup: f64,
    /// measured wall-clock speedup vs the first (pool_size=1) row
    pub measured_speedup: f64,
    pub mean_wall_s: f64,
    /// mean measured latency of batched (verify) rounds, milliseconds
    pub mean_round_latency_ms: f64,
    /// mean shard occupancy across rounds
    pub mean_occupancy: f64,
    /// FNV-1a over every output f64 bit pattern (order-sensitive)
    pub bits_checksum: u64,
}

/// Sweep worker-pool sizes on a fixed ASD workload. `pool_sizes[0]`
/// should be 1 — it is the measured baseline the other rows are divided
/// by. Outputs must be bit-identical across rows (the engine consumes
/// identical Philox streams; sharding only splits row execution), which
/// callers can assert via [`outputs_bit_identical`].
pub fn sweep_pool_sizes(model: Arc<dyn DenoiseModel>, pool_sizes: &[usize],
                        shard_min: usize, theta: usize, n_samples: usize,
                        seed0: u64) -> Result<Vec<PoolRow>> {
    let k = model.k_steps();
    let mut rows: Vec<PoolRow> = Vec::new();
    let mut base_wall = 0.0;
    for &pool_size in pool_sizes {
        let mut engine = AsdEngine::new(
            model.clone(),
            AsdConfig {
                theta,
                eval_tail: true,
                backend: KernelBackend::Native,
                pool: PoolConfig { pool_size, shard_min },
            },
        );
        // warmup: spin up pool workers / warm caches off the clock
        engine.sample(seed0)?;
        let mut wall = 0.0;
        let mut rounds = 0usize;
        let mut lat_s = 0.0;
        let mut lat_samples = 0usize;
        let mut occ = 0.0;
        let mut checksum = 0xcbf2_9ce4_8422_2325u64; // FNV offset basis
        for s in 0..n_samples {
            let out = engine.sample(seed0 + s as u64)?;
            wall += out.wallclock_s;
            rounds += out.stats.parallel_rounds;
            if out.stats.round_batches.iter().any(|&b| b > 1) {
                lat_s += out.stats.mean_batched_round_latency_s();
                lat_samples += 1;
            }
            occ += out.stats.mean_occupancy();
            for &v in &out.y0 {
                checksum =
                    (checksum ^ v.to_bits()).wrapping_mul(0x100_0000_01b3);
            }
        }
        let n = n_samples.max(1) as f64;
        let mean_wall = wall / n;
        if rows.is_empty() {
            base_wall = mean_wall;
        }
        rows.push(PoolRow {
            pool_size,
            algorithmic_speedup: k as f64 / (rounds as f64 / n).max(1e-12),
            measured_speedup: base_wall / mean_wall.max(1e-12),
            mean_wall_s: mean_wall,
            mean_round_latency_ms: if lat_samples > 0 {
                lat_s / lat_samples as f64 * 1e3
            } else {
                0.0
            },
            mean_occupancy: occ / n,
            bits_checksum: checksum,
        });
    }
    Ok(rows)
}

/// True when every sweep row produced bitwise-identical outputs.
pub fn outputs_bit_identical(rows: &[PoolRow]) -> bool {
    rows.windows(2).all(|w| w[0].bits_checksum == w[1].bits_checksum)
}

/// One native-forward throughput measurement for `BENCH_parallel.json`
/// (the machine-readable perf trajectory tracked across PRs).
#[derive(Debug, Clone)]
pub struct ForwardBenchRow {
    /// which measurement: "gemm" (MLP batched pipeline) and
    /// "scalar_ref" (MLP row-at-a-time oracle) are mutually
    /// comparable — same workload, rows = batch rows. Other labels
    /// (e.g. "raw_gemm_sharded", a standalone matrix product) are
    /// their own workload; never compare rows/s across labels unless
    /// the workload matches.
    pub backend: String,
    pub batch: usize,
    /// shard count for sharded backends (1 = serial)
    pub pool_size: usize,
    pub rows_per_s: f64,
    pub ns_per_row: f64,
}

impl ForwardBenchRow {
    /// Build a row from the mean wall-clock of one batched forward.
    pub fn from_mean_s(backend: &str, batch: usize, pool_size: usize,
                       mean_iter_s: f64) -> ForwardBenchRow {
        let rows = batch.max(1) as f64;
        let s = mean_iter_s.max(1e-12);
        ForwardBenchRow {
            backend: backend.to_string(),
            batch,
            pool_size,
            rows_per_s: rows / s,
            ns_per_row: s * 1e9 / rows,
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("backend", Json::Str(self.backend.clone())),
            ("batch", Json::Num(self.batch as f64)),
            ("pool_size", Json::Num(self.pool_size as f64)),
            ("rows_per_s", Json::Num(self.rows_per_s)),
            ("ns_per_row", Json::Num(self.ns_per_row)),
        ])
    }
}

/// One GEMM shape-grid measurement for `BENCH_gemm.json` (schema v3):
/// a single `(m, n, k)` product timed under one kernel generation on
/// one ISA/precision pairing.
///
/// Kernels: `"ref"` (naive triple loop), `"v1"` (PR-2 cache-blocked
/// MR-row kernel over row-major B), `"packed"` (prepacked KC×NR panel
/// kernel, serial — one row per ISA × panel precision the host can
/// run), `"packed2d"` (packed kernel 2-D M×N-sharded on the global
/// pool — `pool_size` carries the tile-shard budget), and two
/// 3-GEMM-chain cells (`k→n`, `n→n`, `n→n`; SiLU/SiLU/Linear):
/// `"chain2d"` runs the chain as three sharded GEMMs with a pool
/// barrier at each layer boundary, `"pipelined"` compiles the same
/// chain into a dependency-counted tile graph and runs it with zero
/// intra-chain barriers. Chain rows report whole-chain throughput
/// (flops = 2m·(nk + 2n²)). Each row is parity-checked per its
/// determinism tier before timing: portable f32 bit-identical to
/// `gemm_ref`, SIMD f32 within the FMA tolerance *and* bit-stable
/// across reruns, f16/int8 within the quantization tolerance (see
/// `math::isa::gemm_rel_tolerance`).
#[derive(Debug, Clone)]
pub struct GemmBenchRow {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub kernel: String,
    /// instruction set the kernel ran on: "portable" | "avx2" | "neon"
    pub isa: String,
    /// packed-panel store: "f32" | "f16" | "int8" (ref/v1 read
    /// row-major f32 B and always report "f32")
    pub precision: String,
    /// tile-shard budget (1 = serial)
    pub pool_size: usize,
    pub mean_ms: f64,
    /// 2·m·n·k / wall-clock
    pub gflops: f64,
}

impl GemmBenchRow {
    #[allow(clippy::too_many_arguments)]
    pub fn from_mean_ms(m: usize, n: usize, k: usize, kernel: &str,
                        isa: &str, precision: &str, pool_size: usize,
                        mean_ms: f64) -> GemmBenchRow {
        let flops = 2.0 * m as f64 * n as f64 * k as f64;
        GemmBenchRow {
            m,
            n,
            k,
            kernel: kernel.to_string(),
            isa: isa.to_string(),
            precision: precision.to_string(),
            pool_size,
            mean_ms,
            gflops: flops / (mean_ms.max(1e-9) * 1e-3) / 1e9,
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("m", Json::Num(self.m as f64)),
            ("n", Json::Num(self.n as f64)),
            ("k", Json::Num(self.k as f64)),
            ("kernel", Json::Str(self.kernel.clone())),
            ("isa", Json::Str(self.isa.clone())),
            ("precision", Json::Str(self.precision.clone())),
            ("pool_size", Json::Num(self.pool_size as f64)),
            ("mean_ms", Json::Num(self.mean_ms)),
            ("gflops", Json::Num(self.gflops)),
        ])
    }
}

/// Square, training-ish GEMM shapes (big batches; the M dimension
/// alone fills the pool).
pub fn gemm_square_shapes() -> Vec<(usize, usize, usize)> {
    vec![(128, 128, 128), (256, 256, 256)]
}

/// Small-M serving shapes — the fused-round products where serve-time
/// rounds are tens of rows against hidden-width weight panels, and the
/// 2-D (M×N) split is what keeps the pool busy.
pub fn gemm_serve_shapes() -> Vec<(usize, usize, usize)> {
    vec![(4, 256, 256), (16, 256, 256), (64, 256, 256)]
}

/// One layer of the chain-bench pipeline, captured as raw pointers so
/// graph tiles (whose closures must be `'static`) can run it. The
/// safety contract mirrors `model::mlp`'s round compiler: every
/// buffer outlives the graph run, row blocks own disjoint rows, and a
/// layer's tiles only read rows its graph dependencies have finished
/// writing.
#[derive(Clone, Copy)]
struct ChainStage {
    pb: *const crate::math::gemm::PackedB,
    bias: *const f32,
    bias_len: usize,
    epi: crate::math::gemm::Epilogue,
    /// inner (reduction) dimension of this layer
    k: usize,
    /// input plane, row-major with stride `k`
    src: *const f32,
    /// output plane, row-major with stride `n`
    dst: *mut f32,
}

// raw pointers strip Send/Sync; the graph's dependency edges restore
// the exclusive-writer discipline (see the struct doc)
unsafe impl Send for ChainStage {}
unsafe impl Sync for ChainStage {}

/// Compile an m-row GEMM chain into a dependency-counted tile graph:
/// per row block, a layer-(l+1) tile depends only on that block's
/// layer-l tiles, so one block can be in layer 3 while another is
/// still in layer 1 — no layer-boundary barrier anywhere. Partition
/// matches the serve-path compiler in `model::mlp` (2·MR-row blocks ×
/// 8·NR-column panels).
fn compile_chain_graph(isa: crate::math::isa::Isa, m: usize, n: usize,
                       stages: &[ChainStage])
                       -> crate::runtime::pool::TileGraph {
    use crate::math::gemm::{gemm_packed_tile_on, MR, NR};
    let row_block = 2 * MR;
    let panel_cols = 8 * NR;
    let mut graph = crate::runtime::pool::TileGraph::new();
    let mut r0 = 0;
    while r0 < m {
        let rows = row_block.min(m - r0);
        let mut prev: Vec<usize> = Vec::new();
        for &stage in stages {
            let mut ids = Vec::new();
            let mut j0 = 0;
            while j0 < n {
                let j1 = j0.saturating_add(panel_cols).min(n);
                ids.push(graph.add_node(&prev, move || unsafe {
                    let bias = std::slice::from_raw_parts(
                        stage.bias, stage.bias_len);
                    gemm_packed_tile_on(isa, rows, j0, j1, stage.k,
                                        stage.src.add(r0 * stage.k),
                                        &*stage.pb, Some(bias),
                                        stage.epi, None,
                                        stage.dst.add(r0 * n));
                }));
                j0 = j1;
            }
            prev = ids;
        }
        r0 += rows;
    }
    graph
}

/// Time the kernel generations over a shape grid (bias + SiLU
/// epilogue — the hidden-layer workload). `tile_shards` is the
/// `packed2d` shard budget; `warmup`/`iters` feed `util::timer::bench`.
///
/// The packed kernel is timed once per (ISA × panel precision) the
/// host can run — portable × {f32, f16, int8} everywhere, plus the
/// detected SIMD ISA's rows on capable hosts. Every row is
/// parity-checked per its determinism tier before its timing is
/// recorded — a wrong-fast kernel must not produce a
/// plausible-looking row: portable f32 must match `gemm_ref`
/// bit-for-bit; SIMD f32 must land within the FMA-contraction
/// tolerance *and* reproduce its own bits on a rerun; f16/int8 must
/// land within the quantization tolerance. `packed2d` (active ISA,
/// f32) must match the serial same-config product bit-for-bit —
/// sharding may never move a bit within a fixed kernel config.
///
/// Each shape also gets the two 3-GEMM-chain cells (`chain2d` /
/// `pipelined` — barrier chain vs tile graph over the identical
/// layer stack). Both must match the serial same-config chain
/// bit-for-bit — neither sharding nor graph scheduling may move a
/// bit — and the serial chain is itself parity-checked against a
/// `gemm_ref` chain per the active tier.
pub fn bench_gemm_grid(shapes: &[(usize, usize, usize)], tile_shards: usize,
                       warmup: usize, iters: usize)
                       -> Result<Vec<GemmBenchRow>> {
    use crate::math::gemm::{gemm_bias_act, gemm_packed_bias_act_on,
                            gemm_packed_sharded_on, gemm_ref, Epilogue,
                            PackedB};
    use crate::math::isa::{detect_isa, gemm_rel_tolerance, Isa, Precision};
    use crate::util::timer::bench;

    // a zero iteration count would panic inside the bench harness's
    // empty-sample summary; one measured iteration is the floor
    let iters = iters.max(1);
    let active = detect_isa();
    let mut rows = Vec::new();
    for &(m, n, k) in shapes {
        let a: Vec<f32> =
            (0..m * k).map(|i| ((i % 601) as f32 / 601.0) - 0.5).collect();
        let b: Vec<f32> =
            (0..k * n).map(|i| ((i % 709) as f32 / 709.0) - 0.5).collect();
        let bias: Vec<f32> =
            (0..n).map(|i| ((i % 53) as f32 / 53.0) - 0.5).collect();
        let mut c = vec![0.0f32; m * n];
        let mut want = vec![0.0f32; m * n];
        gemm_ref(m, n, k, &a, &b, Some(&bias), Epilogue::Silu, None,
                 &mut want);
        let want_bits: Vec<u32> =
            want.iter().map(|v| v.to_bits()).collect();
        let check_bits = |c: &[f32], kernel: &str| -> Result<()> {
            let got: Vec<u32> = c.iter().map(|v| v.to_bits()).collect();
            anyhow::ensure!(got == want_bits,
                            "{kernel} kernel diverged from gemm_ref at \
                             m={m} n={n} k={k}");
            Ok(())
        };
        let check_tol = |c: &[f32], tol: f64, label: &str| -> Result<()> {
            for (i, (&got, &wv)) in c.iter().zip(&want).enumerate() {
                let bound = tol * (wv.abs() as f64).max(1.0);
                anyhow::ensure!(((got - wv).abs() as f64) <= bound,
                                "{label} kernel outside its tier \
                                 tolerance at m={m} n={n} k={k} i={i}: \
                                 got {got}, ref {wv}, tol {tol}");
            }
            Ok(())
        };

        let st = bench(warmup, iters, || {
            gemm_ref(m, n, k, &a, &b, Some(&bias), Epilogue::Silu, None,
                     &mut c);
        });
        check_bits(&c, "ref")?;
        rows.push(GemmBenchRow::from_mean_ms(m, n, k, "ref", "portable",
                                             "f32", 1, st.mean_ms));

        let st = bench(warmup, iters, || {
            gemm_bias_act(m, n, k, &a, &b, Some(&bias), Epilogue::Silu,
                          None, &mut c);
        });
        check_bits(&c, "v1")?;
        rows.push(GemmBenchRow::from_mean_ms(m, n, k, "v1", "portable",
                                             "f32", 1, st.mean_ms));

        // serial packed kernel: every ISA × precision the host can run
        let mut isas = vec![Isa::Portable];
        if active != Isa::Portable {
            isas.push(active);
        }
        for &isa in &isas {
            for precision in
                [Precision::F32, Precision::F16, Precision::Int8]
            {
                let pb = PackedB::pack_as(k, n, &b, precision);
                let label = format!("packed[{isa}/{precision}]");
                let st = bench(warmup, iters, || {
                    gemm_packed_bias_act_on(isa, m, n, k, &a, &pb,
                                            Some(&bias), Epilogue::Silu,
                                            None, &mut c);
                });
                let tol = gemm_rel_tolerance(isa, precision);
                if tol == 0.0 {
                    // bit-exact tier: portable f32 is today's contract
                    check_bits(&c, &label)?;
                } else {
                    check_tol(&c, tol, &label)?;
                    // reproducible-given-config: rerunning the same
                    // kernel config must reproduce the exact bits
                    let bits: Vec<u32> =
                        c.iter().map(|v| v.to_bits()).collect();
                    gemm_packed_bias_act_on(isa, m, n, k, &a, &pb,
                                            Some(&bias), Epilogue::Silu,
                                            None, &mut c);
                    let again: Vec<u32> =
                        c.iter().map(|v| v.to_bits()).collect();
                    anyhow::ensure!(bits == again,
                                    "{label} kernel is not bit-stable \
                                     across reruns at m={m} n={n} k={k}");
                }
                rows.push(GemmBenchRow::from_mean_ms(
                    m, n, k, "packed", isa.name(), precision.name(), 1,
                    st.mean_ms));
            }
        }

        // 2-D sharded packed kernel on the active ISA (f32 panels):
        // shard-count invariance is bitwise within a fixed config
        let pb = PackedB::pack(k, n, &b);
        let mut serial = vec![0.0f32; m * n];
        gemm_packed_bias_act_on(active, m, n, k, &a, &pb, Some(&bias),
                                Epilogue::Silu, None, &mut serial);
        let st = bench(warmup, iters, || {
            gemm_packed_sharded_on(active, m, n, k, &a, &pb, Some(&bias),
                                   Epilogue::Silu, None, &mut c,
                                   tile_shards);
        });
        let serial_bits: Vec<u32> =
            serial.iter().map(|v| v.to_bits()).collect();
        let got: Vec<u32> = c.iter().map(|v| v.to_bits()).collect();
        anyhow::ensure!(got == serial_bits,
                        "packed2d sharding moved a bit vs the serial \
                         same-config product at m={m} n={n} k={k}");
        rows.push(GemmBenchRow::from_mean_ms(m, n, k, "packed2d",
                                             active.name(), "f32",
                                             tile_shards, st.mean_ms));

        // 3-GEMM chain cells (k→n, n→n, n→n; SiLU, SiLU, Linear) —
        // the layer-boundary workload the serve path actually runs.
        // "chain2d" is three sharded GEMMs with a full pool barrier at
        // every layer boundary; "pipelined" compiles the same chain
        // into a tile graph (compile cost inside the timed cell, as
        // on the serve path) and runs it barrier-free.
        let w1: Vec<f32> =
            (0..n * n).map(|i| ((i % 461) as f32 / 461.0) - 0.5).collect();
        let w2: Vec<f32> =
            (0..n * n).map(|i| ((i % 347) as f32 / 347.0) - 0.5).collect();
        let bias2: Vec<f32> =
            (0..n).map(|i| ((i % 29) as f32 / 29.0) - 0.5).collect();
        let pb1 = PackedB::pack(n, n, &w1);
        let pb2 = PackedB::pack(n, n, &w2);
        // reference chain: layer 0 is exactly the `want` product above
        let mut ref1 = vec![0.0f32; m * n];
        let mut ref2 = vec![0.0f32; m * n];
        gemm_ref(m, n, n, &want, &w1, Some(&bias2), Epilogue::Silu,
                 None, &mut ref1);
        gemm_ref(m, n, n, &ref1, &w2, Some(&bias), Epilogue::Linear,
                 None, &mut ref2);
        // serial same-config chain on the active ISA: the bitwise
        // anchor both parallel schedules must reproduce exactly
        let mut s0 = vec![0.0f32; m * n];
        let mut s1 = vec![0.0f32; m * n];
        let mut s2 = vec![0.0f32; m * n];
        gemm_packed_bias_act_on(active, m, n, k, &a, &pb, Some(&bias),
                                Epilogue::Silu, None, &mut s0);
        gemm_packed_bias_act_on(active, m, n, n, &s0, &pb1, Some(&bias2),
                                Epilogue::Silu, None, &mut s1);
        gemm_packed_bias_act_on(active, m, n, n, &s1, &pb2, Some(&bias),
                                Epilogue::Linear, None, &mut s2);
        let chain_bits: Vec<u32> =
            s2.iter().map(|v| v.to_bits()).collect();
        let tol = gemm_rel_tolerance(active, Precision::F32);
        if tol == 0.0 {
            let ref_bits: Vec<u32> =
                ref2.iter().map(|v| v.to_bits()).collect();
            anyhow::ensure!(chain_bits == ref_bits,
                            "serial packed chain diverged from the \
                             gemm_ref chain at m={m} n={n} k={k}");
        } else {
            // the per-layer FMA tolerance compounds over the 3-deep
            // chain; 8× is generous headroom without masking a bug
            for (i, (&got, &wv)) in s2.iter().zip(&ref2).enumerate() {
                let bound = 8.0 * tol * (wv.abs() as f64).max(1.0);
                anyhow::ensure!(((got - wv).abs() as f64) <= bound,
                                "serial packed chain outside its tier \
                                 tolerance at m={m} n={n} k={k} i={i}: \
                                 got {got}, ref {wv}, tol {tol}");
            }
        }
        let chain_flops = 2.0 * m as f64
            * (n as f64 * k as f64 + 2.0 * n as f64 * n as f64);
        let chain_row = |kernel: &str, mean_ms: f64| GemmBenchRow {
            m,
            n,
            k,
            kernel: kernel.to_string(),
            isa: active.name().to_string(),
            precision: "f32".to_string(),
            pool_size: tile_shards,
            mean_ms,
            gflops: chain_flops / (mean_ms.max(1e-9) * 1e-3) / 1e9,
        };
        let mut h0 = vec![0.0f32; m * n];
        let mut h1 = vec![0.0f32; m * n];
        let mut cout = vec![0.0f32; m * n];
        let st = bench(warmup, iters, || {
            gemm_packed_sharded_on(active, m, n, k, &a, &pb,
                                   Some(&bias), Epilogue::Silu, None,
                                   &mut h0, tile_shards);
            gemm_packed_sharded_on(active, m, n, n, &h0, &pb1,
                                   Some(&bias2), Epilogue::Silu, None,
                                   &mut h1, tile_shards);
            gemm_packed_sharded_on(active, m, n, n, &h1, &pb2,
                                   Some(&bias), Epilogue::Linear, None,
                                   &mut cout, tile_shards);
        });
        let got: Vec<u32> = cout.iter().map(|v| v.to_bits()).collect();
        anyhow::ensure!(got == chain_bits,
                        "chain2d barrier chain moved a bit vs the \
                         serial same-config chain at m={m} n={n} k={k}");
        rows.push(chain_row("chain2d", st.mean_ms));

        let stages = [
            ChainStage { pb: &pb, bias: bias.as_ptr(), bias_len: n,
                         epi: Epilogue::Silu, k, src: a.as_ptr(),
                         dst: h0.as_mut_ptr() },
            ChainStage { pb: &pb1, bias: bias2.as_ptr(), bias_len: n,
                         epi: Epilogue::Silu, k: n, src: h0.as_ptr(),
                         dst: h1.as_mut_ptr() },
            ChainStage { pb: &pb2, bias: bias.as_ptr(), bias_len: n,
                         epi: Epilogue::Linear, k: n, src: h1.as_ptr(),
                         dst: cout.as_mut_ptr() },
        ];
        let st = bench(warmup, iters, || {
            let graph = compile_chain_graph(active, m, n, &stages);
            crate::runtime::pool::global().run_graph(graph);
        });
        let got: Vec<u32> = cout.iter().map(|v| v.to_bits()).collect();
        anyhow::ensure!(got == chain_bits,
                        "pipelined graph chain moved a bit vs the \
                         serial same-config chain at m={m} n={n} k={k}");
        rows.push(chain_row("pipelined", st.mean_ms));
    }
    Ok(rows)
}

/// The full GEMM-grid pipeline shared by `benches/bench_parallel.rs`
/// and `asd pool --gemm-grid`: run the square + small-M serve shapes,
/// print the table, write the `BENCH_gemm.json` document to `path`,
/// and return the rows (for the bench's acceptance floors). One
/// definition, so the CLI artifact and the bench artifact can never
/// silently diverge.
pub fn run_gemm_grid(tile_shards: usize, warmup: usize, iters: usize,
                     path: &std::path::Path) -> Result<Vec<GemmBenchRow>> {
    let mut shapes = gemm_square_shapes();
    shapes.extend(gemm_serve_shapes());
    let rows = bench_gemm_grid(&shapes, tile_shards, warmup, iters)?;
    print!("{}", format_gemm_rows(&rows));
    write_bench_json(path, &bench_gemm_json(&rows, tile_shards))?;
    println!("wrote {} ({} rows)", path.display(), rows.len());
    Ok(rows)
}

/// Assemble the `BENCH_gemm.json` document (GFLOP/s per kernel
/// generation × ISA × precision over the shape grid). Schema v2 adds
/// per-row `isa`/`precision` fields and the top-level `isa_detected`;
/// v3 adds the 3-GEMM-chain kernels (`chain2d`, `pipelined`) so the
/// layer-boundary win of the tile graph is visible in the artifact.
pub fn bench_gemm_json(rows: &[GemmBenchRow], tile_shards: usize) -> Json {
    use crate::math::gemm::{KC, MR, NR};
    Json::obj(vec![
        ("bench", Json::Str("bench_gemm".into())),
        ("schema_version", Json::Num(3.0)),
        ("pool_threads",
         Json::Num(crate::runtime::pool::default_threads() as f64)),
        ("isa_detected",
         Json::Str(crate::math::isa::detect_isa().name().into())),
        ("tile_shards", Json::Num(tile_shards as f64)),
        ("mr", Json::Num(MR as f64)),
        ("nr", Json::Num(NR as f64)),
        ("kc", Json::Num(KC as f64)),
        ("rows", Json::Arr(rows.iter().map(|r| r.to_json()).collect())),
    ])
}

/// Render the GEMM grid as a table, one line per (shape, kernel, ISA,
/// precision).
pub fn format_gemm_rows(rows: &[GemmBenchRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!("{:<18} {:<10} {:<10} {:<10} {:>6} {:>12} \
                           {:>10}\n",
                          "shape (m n k)", "kernel", "isa", "precision",
                          "tiles", "ms/call", "GFLOP/s"));
    for r in rows {
        out.push_str(&format!(
            "{:<18} {:<10} {:<10} {:<10} {:>6} {:>12.4} {:>10.2}\n",
            format!("{}x{}x{}", r.m, r.n, r.k), r.kernel, r.isa,
            r.precision, r.pool_size, r.mean_ms, r.gflops));
    }
    out
}

fn pool_row_json(r: &PoolRow) -> Json {
    Json::obj(vec![
        ("pool_size", Json::Num(r.pool_size as f64)),
        ("algorithmic_speedup", Json::Num(r.algorithmic_speedup)),
        ("measured_speedup", Json::Num(r.measured_speedup)),
        ("mean_wall_s", Json::Num(r.mean_wall_s)),
        ("mean_round_latency_ms", Json::Num(r.mean_round_latency_ms)),
        ("mean_occupancy", Json::Num(r.mean_occupancy)),
        // hex string: u64 checksums don't fit f64-backed JSON numbers
        ("bits_checksum", Json::Str(format!("{:016x}", r.bits_checksum))),
    ])
}

/// Assemble the `BENCH_parallel.json` document: native-forward
/// throughput rows plus the ASD pool sweep (K/rounds per pool size).
/// Either section may be empty.
pub fn bench_parallel_json(forward: &[ForwardBenchRow], k: usize,
                           theta: usize, pool_rows: &[PoolRow]) -> Json {
    Json::obj(vec![
        ("bench", Json::Str("bench_parallel".into())),
        ("schema_version", Json::Num(1.0)),
        ("pool_threads",
         Json::Num(crate::runtime::pool::default_threads() as f64)),
        ("native_forward",
         Json::Arr(forward.iter().map(|r| r.to_json()).collect())),
        ("pool_sweep", Json::obj(vec![
            ("k", Json::Num(k as f64)),
            ("theta", Json::Num(theta as f64)),
            ("outputs_bit_identical",
             Json::Bool(outputs_bit_identical(pool_rows))),
            ("rows", Json::Arr(pool_rows.iter().map(pool_row_json)
                                   .collect())),
        ])),
    ])
}

/// Write a bench document to disk (pretty enough for diffs: one line).
pub fn write_bench_json(path: &std::path::Path, doc: &Json) -> Result<()> {
    std::fs::write(path, format!("{doc}\n"))
        .with_context(|| format!("writing {}", path.display()))
}

// ---------------------------------------------------------------------
// Pareto grid: sequential vs ASD vs SL-ASD vs draft-SD
// ---------------------------------------------------------------------

/// One `BENCH_pareto.json` measurement: one sampler on one grid cell
/// (a target × draft × precision pairing). Every cell emits four rows —
/// sequential DDPM, ASD, SL-ASD and draft-SD — so the speedup-vs-cost
/// frontier can be read per cell.
#[derive(Debug, Clone)]
pub struct ParetoRow {
    /// cell label: target × draft pairing this row was measured in
    pub cell: String,
    pub target: String,
    /// draft variant ("-" for samplers that use no draft)
    pub draft: String,
    /// draft weight-panel precision ("f32" | "int8"; "-" = no draft /
    /// analytic oracle draft)
    pub precision: String,
    /// "sequential" | "asd" | "sl_asd" | "draft_sd"
    pub sampler: String,
    /// target chain steps K
    pub k: usize,
    /// speculation window (theta for ASD/SL-ASD, draft window for
    /// draft-SD; 0 for sequential)
    pub k_window: usize,
    pub accept_rate: f64,
    pub mean_rounds: f64,
    pub mean_wall_s: f64,
    pub mean_model_calls: f64,
    /// draft chain calls per sample (0 for draft-free samplers)
    pub mean_draft_calls: f64,
    /// draft FLOPs / target FLOPs per model call (0 = no draft; 1 =
    /// analytic oracle draft priced at parity)
    pub flops_ratio: f64,
    /// K / mean_rounds — the Theorem 4 round-compression quantity
    pub alg_speedup: f64,
}

/// Forward FLOPs of one MLP call under `info`'s layout (2·n_in·n_out
/// per layer; bias and activation noise ignored — panel precision does
/// not change the count, only the bytes).
pub fn mlp_flops(info: &crate::model::VariantInfo) -> f64 {
    info.weights_layout.iter()
        .map(|&(a, b)| 2.0 * a as f64 * b as f64)
        .sum()
}

/// A GMM whose component means are shifted by `eps` (alternating sign
/// per coordinate) — the analytic stand-in for an imperfect draft: the
/// draft's x0hat is wrong by O(eps), so the GRS accept rate degrades
/// smoothly with eps.
fn perturbed_gmm(base: &crate::model::Gmm, eps: f64) -> crate::model::Gmm {
    let comps = base.weights.len();
    let means: Vec<Vec<f64>> = (0..comps)
        .map(|c| {
            base.mean_of(c).iter().enumerate()
                .map(|(i, &v)| v + eps * if i % 2 == 0 { 1.0 } else { -1.0 })
                .collect()
        })
        .collect();
    crate::model::Gmm::new(means, base.sigmas.clone(), base.weights.clone())
}

/// Everything one Pareto cell needs: the target/draft models, the cell
/// labels, and the matched-d GMM the SL-ASD leg runs on.
struct ParetoCell {
    cell: String,
    target_name: String,
    draft_name: String,
    precision: String,
    target: Arc<dyn DenoiseModel>,
    draft: Arc<dyn DenoiseModel>,
    /// GMM for the SL-ASD leg (the cell's own GMM for analytic cells;
    /// a matched-dimension companion for native-MLP cells, where no
    /// analytic SL oracle exists)
    sl_gmm: crate::model::Gmm,
    flops_ratio: f64,
}

/// Run all four samplers on one cell and emit the four rows.
fn pareto_cell_rows(cell: &ParetoCell, k_window: usize, n_samples: usize,
                    seed0: u64) -> Result<Vec<ParetoRow>> {
    use crate::asd::{DraftConfig, DraftEngine, SlAsd};
    use crate::ddpm::SequentialSampler;
    use crate::model::GmmSlOracle;
    use crate::schedule::SlGrid;

    let k = cell.target.k_steps();
    let n = n_samples.max(1);
    let nf = n as f64;
    let row = |sampler: &str, k_window: usize, accept_rate: f64,
               rounds: f64, wall: f64, calls: f64, draft_calls: f64,
               flops_ratio: f64| {
        ParetoRow {
            cell: cell.cell.clone(),
            target: cell.target_name.clone(),
            draft: if flops_ratio > 0.0 {
                cell.draft_name.clone()
            } else {
                "-".into()
            },
            precision: if flops_ratio > 0.0 {
                cell.precision.clone()
            } else {
                "-".into()
            },
            sampler: sampler.to_string(),
            k,
            k_window,
            accept_rate,
            mean_rounds: rounds / nf,
            mean_wall_s: wall / nf,
            mean_model_calls: calls / nf,
            mean_draft_calls: draft_calls / nf,
            flops_ratio,
            alg_speedup: k as f64 / (rounds / nf).max(1e-12),
        }
    };
    let mut rows = Vec::with_capacity(4);

    // sequential DDPM: the 1x baseline (every transition is trivially
    // "accepted" — there is no verifier)
    let seq = SequentialSampler::new(cell.target.clone());
    let mut wall = 0.0;
    for s in 0..n {
        let t0 = std::time::Instant::now();
        seq.sample(seed0 + s as u64, &[])?;
        wall += t0.elapsed().as_secs_f64();
    }
    rows.push(row("sequential", 0, 1.0, (n * k) as f64, wall,
                  (n * k) as f64, 0.0, 0.0));

    // ASD: draft-free autospeculation at theta = k_window
    let mut engine = AsdEngine::new(cell.target.clone(), AsdConfig {
        theta: k_window,
        eval_tail: true,
        backend: KernelBackend::Native,
        ..Default::default()
    });
    let (mut rounds, mut calls, mut acc, mut rej, mut wall) =
        (0usize, 0usize, 0usize, 0usize, 0.0);
    for s in 0..n {
        let out = engine.sample(seed0 + s as u64)?;
        rounds += out.stats.parallel_rounds;
        calls += out.stats.model_calls;
        acc += out.stats.accepted;
        rej += out.stats.rejected;
        wall += out.wallclock_s;
    }
    rows.push(row("asd", k_window,
                  acc as f64 / (acc + rej).max(1) as f64, rounds as f64,
                  wall, calls as f64, 0.0, 0.0));

    // SL-ASD: autospeculation over the SL Euler chain on the cell's
    // (companion) GMM, same K and theta — the Thm-4 theory leg
    let oracle = GmmSlOracle { gmm: cell.sl_gmm.clone() };
    let grid = SlGrid::uniform(300.0, k);
    let sl = SlAsd { oracle: &oracle, grid: &grid, theta: k_window };
    let (mut rounds, mut calls, mut acc, mut rej, mut wall) =
        (0usize, 0usize, 0usize, 0usize, 0.0);
    for s in 0..n {
        let t0 = std::time::Instant::now();
        let (_, st) = sl.sample(seed0 + s as u64);
        wall += t0.elapsed().as_secs_f64();
        rounds += st.parallel_rounds;
        calls += st.oracle_calls;
        acc += st.accepted;
        rej += st.rejected;
    }
    rows.push(row("sl_asd", k_window,
                  acc as f64 / (acc + rej).max(1) as f64, rounds as f64,
                  wall, calls as f64, 0.0, 0.0));

    // draft-SD: the cell's draft proposes, the target verifies in one
    // fused round per window
    let mut engine = DraftEngine::new(cell.target.clone(),
                                      cell.draft.clone(), DraftConfig {
                                          k: k_window,
                                          ..Default::default()
                                      });
    let (mut rounds, mut calls, mut dcalls, mut acc, mut rej, mut wall) =
        (0usize, 0usize, 0usize, 0usize, 0usize, 0.0);
    for s in 0..n {
        let out = engine.sample(seed0 + s as u64)?;
        rounds += out.stats.parallel_rounds;
        calls += out.stats.model_calls;
        dcalls += out.stats.draft_calls;
        acc += out.stats.accepted;
        rej += out.stats.rejected;
        wall += out.wallclock_s;
    }
    rows.push(row("draft_sd", k_window,
                  acc as f64 / (acc + rej).max(1) as f64, rounds as f64,
                  wall, calls as f64, dcalls as f64, cell.flops_ratio));
    Ok(rows)
}

/// The speedup-vs-cost Pareto grid: sequential vs ASD vs SL-ASD vs
/// draft-SD across target sizes × draft configs × precision tiers.
///
/// * **Analytic cells** (always run): GMM DDPM oracles at two target
///   sizes, each paired with perturbed-means oracle drafts at two
///   error levels (`eps`) — the draft costs exactly one oracle call,
///   so `flops_ratio = 1` and the frontier isolates the *accept-rate*
///   axis.
/// * **Native cells** (skipped when `analytic_only`): `NativeMlp` toys
///   at two hidden widths, drafts distilled from the target's own
///   weights (`model::distill`) at two fold factors, the cheaper one
///   additionally quantized to int8 panels — `flops_ratio < 1`
///   exercises the *cost* axis. SL-ASD runs on a matched-dimension
///   companion GMM in these cells (no analytic SL oracle exists for an
///   MLP).
pub fn bench_pareto_grid(analytic_only: bool, n_samples: usize,
                         k_window: usize, seed0: u64)
                         -> Result<Vec<ParetoRow>> {
    use crate::math::isa::{IsaRequest, KernelPolicy, Precision};
    use crate::model::{distill_draft, synth_group_constant, Gmm,
                       GmmDdpmOracle, NativeMlp, VariantInfo};

    let k_window = k_window.max(1);
    let mut rows = Vec::new();

    // ---- analytic cells: 2 target sizes x 2 draft error levels ----
    let targets: Vec<(&str, Gmm, usize)> = vec![
        ("gmm-d2-K96", Gmm::circle_2d(), 96),
        ("gmm-d8-K192", Gmm::random(8, 6, 1.5, 17), 192),
    ];
    for (tname, gmm, k) in &targets {
        let target = GmmDdpmOracle::new(gmm.clone(), *k, false);
        for eps in [0.02, 0.10] {
            let dname = format!("oracle-eps{eps}");
            let draft = GmmDdpmOracle::new(perturbed_gmm(gmm, eps), *k,
                                           false);
            let cell = ParetoCell {
                cell: format!("{tname}/{dname}"),
                target_name: tname.to_string(),
                draft_name: dname,
                precision: "-".into(),
                target: target.clone(),
                draft,
                sl_gmm: gmm.clone(),
                flops_ratio: 1.0,
            };
            rows.extend(pareto_cell_rows(&cell, k_window, n_samples,
                                         seed0)?);
        }
    }
    if analytic_only {
        return Ok(rows);
    }

    // ---- native cells: 2 target widths x {fold-4 f32, fold-8 int8} --
    // group-constant-plus-jitter weights make the distilled draft a
    // faithful-but-imperfect approximation of the target (the jitter is
    // what the fold averages away), so accept rates land strictly
    // inside (0, 1)
    let natives: Vec<(&str, usize, usize)> = vec![
        ("mlp-h48", 48, 1),
        ("mlp-h96", 96, 2),
    ];
    for (tname, hidden, blocks) in &natives {
        let info = VariantInfo::toy(tname, 2, 0, *hidden, *blocks, 64);
        let flat = synth_group_constant(&info, 8, 0.02, 0xC0FFEE)?;
        let target = NativeMlp::from_flat(&info, &flat)?;
        let t_flops = mlp_flops(&info);
        for (fold, precision) in [(4usize, Precision::F32),
                                  (8usize, Precision::Int8)] {
            let (dinfo, dflat) = distill_draft(&info, &flat, fold)?;
            let draft = NativeMlp::from_flat_with(
                &dinfo, &dflat,
                KernelPolicy { isa: IsaRequest::Auto, precision })?;
            let cell = ParetoCell {
                cell: format!("{tname}/{}-{}", dinfo.name,
                              precision.name()),
                target_name: tname.to_string(),
                draft_name: dinfo.name.clone(),
                precision: precision.name().to_string(),
                target: target.clone(),
                draft,
                sl_gmm: Gmm::circle_2d(),
                flops_ratio: mlp_flops(&dinfo) / t_flops,
            };
            rows.extend(pareto_cell_rows(&cell, k_window, n_samples,
                                         seed0)?);
        }
    }
    Ok(rows)
}

fn pareto_row_json(r: &ParetoRow) -> Json {
    Json::obj(vec![
        ("cell", Json::Str(r.cell.clone())),
        ("target", Json::Str(r.target.clone())),
        ("draft", Json::Str(r.draft.clone())),
        ("precision", Json::Str(r.precision.clone())),
        ("sampler", Json::Str(r.sampler.clone())),
        ("k", Json::Num(r.k as f64)),
        ("k_window", Json::Num(r.k_window as f64)),
        ("accept_rate", Json::Num(r.accept_rate)),
        ("mean_rounds", Json::Num(r.mean_rounds)),
        ("mean_wall_s", Json::Num(r.mean_wall_s)),
        ("mean_model_calls", Json::Num(r.mean_model_calls)),
        ("mean_draft_calls", Json::Num(r.mean_draft_calls)),
        ("flops_ratio", Json::Num(r.flops_ratio)),
        ("alg_speedup", Json::Num(r.alg_speedup)),
    ])
}

/// Assemble the `BENCH_pareto.json` document (schema v1: one row per
/// cell × sampler, four samplers per cell).
pub fn bench_pareto_json(rows: &[ParetoRow]) -> Json {
    Json::obj(vec![
        ("bench", Json::Str("bench_pareto".into())),
        ("schema_version", Json::Num(1.0)),
        ("pool_threads",
         Json::Num(crate::runtime::pool::default_threads() as f64)),
        ("samplers", Json::Arr(
            ["sequential", "asd", "sl_asd", "draft_sd"].iter()
                .map(|s| Json::Str((*s).into())).collect())),
        ("rows", Json::Arr(rows.iter().map(pareto_row_json).collect())),
    ])
}

/// Render the Pareto grid as a table, one line per (cell, sampler).
pub fn format_pareto_rows(rows: &[ParetoRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<28} {:<10} {:>6} {:>8} {:>8} {:>10} {:>8} {:>10}\n",
        "cell", "sampler", "win", "accept", "rounds", "alg x", "flops",
        "wall ms"));
    for r in rows {
        out.push_str(&format!(
            "{:<28} {:<10} {:>6} {:>8.3} {:>8.1} {:>10.2} {:>8.3} \
             {:>10.2}\n",
            r.cell, r.sampler, r.k_window, r.accept_rate, r.mean_rounds,
            r.alg_speedup, r.flops_ratio, r.mean_wall_s * 1e3));
    }
    out
}

/// The full Pareto pipeline shared by `benches/bench_parallel.rs` and
/// `asd pareto`: run the grid, print the table, write the
/// `BENCH_pareto.json` document to `path`, and return the rows. One
/// definition, so the CLI artifact and the bench artifact can never
/// silently diverge.
pub fn run_pareto_grid(analytic_only: bool, n_samples: usize,
                       k_window: usize, path: &std::path::Path)
                       -> Result<Vec<ParetoRow>> {
    let rows = bench_pareto_grid(analytic_only, n_samples, k_window, 4242)?;
    print!("{}", format_pareto_rows(&rows));
    write_bench_json(path, &bench_pareto_json(&rows))?;
    println!("wrote {} ({} rows)", path.display(), rows.len());
    Ok(rows)
}

/// Render the pool sweep as a table: both speedup columns side by side.
pub fn format_pool_rows(k: usize, rows: &[PoolRow]) -> String {
    let base = rows.first().map(|r| r.pool_size).unwrap_or(1);
    let mut out = String::new();
    out.push_str(&format!(
        "{:<10} {:>12} {:>16} {:>14} {:>12} {:>10}\n",
        "pool", "alg speedup", "wall x (meas.)", "round ms", "occupancy",
        "wall ms"));
    out.push_str(&format!("(K={k}; alg = K/rounds, hardware-blind; \
                           meas. = wall-clock vs pool={base})\n"));
    for r in rows {
        out.push_str(&format!(
            "{:<10} {:>12.2} {:>16.2} {:>14.3} {:>12.2} {:>10.1}\n",
            r.pool_size, r.algorithmic_speedup, r.measured_speedup,
            r.mean_round_latency_ms, r.mean_occupancy, r.mean_wall_s * 1e3));
    }
    out
}

/// Render rows as the paper-style table.
pub fn format_rows(k: usize, rows: &[SpeedupRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<10} {:>12} {:>16} {:>18} {:>12} {:>10}\n",
        "method", "alg speedup", "wall x (1 dev)", "wall x (modeled)",
        "acc rate", "rounds"));
    out.push_str(&format!(
        "{:<10} {:>12} {:>16} {:>18} {:>12} {:>10}\n",
        "DDPM", "1.00", "1.00", "1.00", "-", k));
    for r in rows {
        out.push_str(&format!(
            "{:<10} {:>12.2} {:>16.2} {:>18.2} {:>12.3} {:>10.1}\n",
            r.label(), r.algorithmic_speedup, r.wallclock_speedup_1dev,
            r.wallclock_speedup_modeled, r.acceptance_rate, r.mean_rounds));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Gmm, GmmDdpmOracle};

    #[test]
    fn sweep_produces_monotone_alg_speedup() {
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 60, false);
        let latency = LatencyModel {
            call_s: vec![(1, 1e-4), (8, 2e-4), (32, 5e-4)],
            workers: 8,
            xfer_per_float: 1e-9,
            d: 2,
        };
        let rows = sweep_thetas(oracle, &[1, 4, 0], 5, 1e-2, 0, None,
                                &latency).unwrap();
        assert_eq!(rows.len(), 3);
        assert!(rows[1].algorithmic_speedup > rows[0].algorithmic_speedup);
        assert!(rows[2].algorithmic_speedup >= rows[1].algorithmic_speedup * 0.9);
        // theta=1 speedup ~1 (every step verified once, tail-chained)
        assert!(rows[0].algorithmic_speedup <= 1.3);
        let table = format_rows(60, &rows);
        assert!(table.contains("ASD-inf"));
    }

    #[test]
    fn bench_json_roundtrips_and_carries_both_sections() {
        let fwd = vec![
            ForwardBenchRow::from_mean_s("scalar_ref", 64, 1, 6.4e-3),
            ForwardBenchRow::from_mean_s("gemm", 64, 1, 1.0e-3),
        ];
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 40, false);
        let rows = sweep_pool_sizes(oracle, &[1, 2], 1, 8, 2, 7).unwrap();
        let doc = bench_parallel_json(&fwd, 40, 8, &rows);
        let back = Json::parse(&doc.to_string()).unwrap();
        let nf = back.get("native_forward").unwrap().as_arr().unwrap();
        assert_eq!(nf.len(), 2);
        for r in nf {
            // rows/s and ns/row stay mutually consistent through the
            // text roundtrip: rows_per_s * ns_per_row == 1e9
            let rps = r.get("rows_per_s").unwrap().as_f64().unwrap();
            let nspr = r.get("ns_per_row").unwrap().as_f64().unwrap();
            assert!((rps * nspr / 1e9 - 1.0).abs() < 1e-9);
        }
        let sweep = back.get("pool_sweep").unwrap();
        assert_eq!(sweep.get("k").unwrap().as_usize().unwrap(), 40);
        assert!(sweep.get("outputs_bit_identical").unwrap()
                    .as_bool().unwrap());
        let sweep_rows = sweep.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(sweep_rows.len(), 2);
        // checksums travel as 16-hex-digit strings (u64 doesn't fit an
        // f64-backed JSON number)
        let c = sweep_rows[0].get("bits_checksum").unwrap()
            .as_str().unwrap();
        assert_eq!(c.len(), 16);
        assert!(c.chars().all(|ch| ch.is_ascii_hexdigit()));
    }

    #[test]
    fn gemm_grid_measures_every_kernel_generation_and_serializes() {
        // tiny odd shape: correctness (per-tier parity checks inside
        // the grid runner) + schema, not speed. Host-agnostic: a
        // portable-only host produces 8 rows per shape (ref, v1,
        // packed × 3 precisions, packed2d, chain2d, pipelined), a
        // SIMD host 11 (+ the active ISA's 3 packed rows).
        let rows = bench_gemm_grid(&[(5, 9, 17)], 4, 0, 1).unwrap();
        assert!(rows.len() == 8 || rows.len() == 11, "{}", rows.len());
        let kernels: Vec<&str> =
            rows.iter().map(|r| r.kernel.as_str()).collect();
        for kernel in ["ref", "v1", "packed", "packed2d", "chain2d",
                       "pipelined"] {
            assert!(kernels.contains(&kernel), "missing {kernel}");
        }
        for precision in ["f32", "f16", "int8"] {
            assert!(rows.iter().any(|r| r.kernel == "packed"
                                        && r.precision == precision),
                    "missing packed/{precision} row");
        }
        for r in &rows {
            assert!(r.gflops > 0.0, "{r:?}");
            assert_eq!((r.m, r.n, r.k), (5, 9, 17));
            assert!(["portable", "avx2", "neon"]
                        .contains(&r.isa.as_str()), "{r:?}");
        }
        let last = rows.last().unwrap();
        assert_eq!((last.kernel.as_str(), last.pool_size),
                   ("pipelined", 4));
        let doc = bench_gemm_json(&rows, 4);
        let back = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(back.get("bench").unwrap().as_str().unwrap(),
                   "bench_gemm");
        assert_eq!(back.get("schema_version").unwrap()
                       .as_usize().unwrap(), 3);
        assert_eq!(back.get("isa_detected").unwrap().as_str().unwrap(),
                   crate::math::isa::detect_isa().name());
        assert_eq!(back.get("nr").unwrap().as_usize().unwrap(),
                   crate::math::gemm::NR);
        assert_eq!(back.get("kc").unwrap().as_usize().unwrap(),
                   crate::math::gemm::KC);
        let rs = back.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rs.len(), rows.len());
        for (j, r) in rows.iter().enumerate() {
            assert_eq!(rs[j].get("isa").unwrap().as_str().unwrap(),
                       r.isa);
            assert_eq!(rs[j].get("precision").unwrap().as_str().unwrap(),
                       r.precision);
        }
        let table = format_gemm_rows(&rows);
        assert!(table.contains("packed2d") && table.contains("GFLOP/s")
                && table.contains("precision") && table.contains("int8")
                && table.contains("pipelined"));
    }

    #[test]
    fn pareto_grid_analytic_cells_cover_all_four_samplers() {
        let rows = bench_pareto_grid(true, 3, 6, 11).unwrap();
        // 2 targets x 2 draft eps levels x 4 samplers
        assert_eq!(rows.len(), 16);
        let cells: std::collections::BTreeSet<&str> =
            rows.iter().map(|r| r.cell.as_str()).collect();
        assert_eq!(cells.len(), 4);
        for cell in &cells {
            let samplers: Vec<&str> = rows.iter()
                .filter(|r| r.cell == *cell)
                .map(|r| r.sampler.as_str())
                .collect();
            assert_eq!(samplers,
                       vec!["sequential", "asd", "sl_asd", "draft_sd"],
                       "cell {cell}");
        }
        for r in &rows {
            assert!(r.accept_rate > 0.0 && r.accept_rate <= 1.0, "{r:?}");
            assert!(r.mean_rounds > 0.0 && r.mean_wall_s > 0.0, "{r:?}");
            match r.sampler.as_str() {
                "sequential" => {
                    assert_eq!(r.mean_rounds, r.k as f64);
                    assert_eq!(r.flops_ratio, 0.0);
                    assert_eq!(r.mean_draft_calls, 0.0);
                }
                "draft_sd" => {
                    // analytic drafts are priced at oracle parity and
                    // the chain calls every transition exactly once
                    assert_eq!(r.flops_ratio, 1.0);
                    assert!(r.mean_draft_calls >= r.k as f64);
                }
                _ => assert_eq!(r.flops_ratio, 0.0),
            }
        }
        // the tentpole claim on the large-target / accurate-draft cell:
        // draft-SD verifies each window in ONE round where ASD pays
        // propose + verify, so with a close draft it wins on rounds
        let cheap = rows.iter()
            .find(|r| r.cell.contains("K192") && r.cell.contains("0.02")
                      && r.sampler == "draft_sd").unwrap();
        let asd = rows.iter()
            .find(|r| r.cell == cheap.cell && r.sampler == "asd").unwrap();
        assert!(cheap.mean_rounds < asd.mean_rounds,
                "draft-SD {} rounds vs ASD {} rounds",
                cheap.mean_rounds, asd.mean_rounds);
    }

    #[test]
    fn pareto_native_cells_price_the_draft_below_the_target() {
        let rows = bench_pareto_grid(false, 1, 6, 5).unwrap();
        // 4 analytic cells + (2 widths x 2 draft configs) native cells
        assert_eq!(rows.len(), 32);
        let native: Vec<&ParetoRow> = rows.iter()
            .filter(|r| r.cell.starts_with("mlp-") &&
                        r.sampler == "draft_sd")
            .collect();
        assert_eq!(native.len(), 4);
        for r in &native {
            assert!(r.flops_ratio > 0.0 && r.flops_ratio < 1.0,
                    "distilled draft must be cheaper: {r:?}");
            assert!(r.accept_rate > 0.0, "{r:?}");
        }
        // both precision tiers made it into the grid
        assert!(native.iter().any(|r| r.precision == "f32"));
        assert!(native.iter().any(|r| r.precision == "int8"));
        // the fold-8 draft is cheaper than the fold-4 draft
        let f4 = native.iter()
            .find(|r| r.cell.contains("mlp-h96") && r.precision == "f32")
            .unwrap();
        let f8 = native.iter()
            .find(|r| r.cell.contains("mlp-h96") && r.precision == "int8")
            .unwrap();
        assert!(f8.flops_ratio < f4.flops_ratio);
    }

    #[test]
    fn pareto_json_roundtrips_schema_v1() {
        let rows = bench_pareto_grid(true, 1, 8, 3).unwrap();
        let doc = bench_pareto_json(&rows);
        let back = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(back.get("bench").unwrap().as_str().unwrap(),
                   "bench_pareto");
        assert_eq!(back.get("schema_version").unwrap().as_usize().unwrap(),
                   1);
        let samplers = back.get("samplers").unwrap().as_arr().unwrap();
        assert_eq!(samplers.len(), 4);
        let rs = back.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rs.len(), rows.len());
        let dsd = rs.iter()
            .find(|r| r.get("sampler").unwrap().as_str().unwrap()
                      == "draft_sd")
            .expect("a draft_sd row");
        for field in ["accept_rate", "mean_rounds", "mean_wall_s",
                      "flops_ratio", "alg_speedup"] {
            assert!(dsd.get(field).unwrap().as_f64().is_ok(),
                    "missing {field}");
        }
        let table = format_pareto_rows(&rows);
        assert!(table.contains("draft_sd") && table.contains("accept"));
    }

    #[test]
    fn pool_sweep_is_bit_identical_and_reports_both_columns() {
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 50, false);
        let rows = sweep_pool_sizes(oracle, &[1, 2, 4], 1, 8, 3, 42).unwrap();
        assert_eq!(rows.len(), 3);
        assert!(outputs_bit_identical(&rows),
                "sharding changed sample bits: {rows:?}");
        assert_eq!(rows[0].pool_size, 1);
        assert!((rows[0].measured_speedup - 1.0).abs() < 1e-9);
        // algorithmic column is pool-invariant by construction
        for r in &rows[1..] {
            assert!((r.algorithmic_speedup - rows[0].algorithmic_speedup)
                        .abs() < 1e-9);
        }
        assert!(rows[2].mean_occupancy > rows[0].mean_occupancy);
        let table = format_pool_rows(50, &rows);
        assert!(table.contains("alg speedup") && table.contains("meas."));
    }
}
