//! Theta-sweep speedup driver (Figures 2, 4, 5).

use std::sync::Arc;

use anyhow::Result;

use crate::asd::{AsdConfig, AsdEngine, KernelBackend};
use crate::exp::latency::LatencyModel;
use crate::model::DenoiseModel;

#[derive(Debug, Clone)]
pub struct SpeedupRow {
    /// 0 = infinity
    pub theta: usize,
    pub algorithmic_speedup: f64,
    /// measured on this testbed (single device)
    pub wallclock_speedup_1dev: f64,
    /// modeled multi-worker wall-clock speedup (DESIGN.md §3)
    pub wallclock_speedup_modeled: f64,
    pub acceptance_rate: f64,
    pub mean_rounds: f64,
    pub mean_model_calls: f64,
}

impl SpeedupRow {
    pub fn label(&self) -> String {
        if self.theta == 0 {
            "ASD-inf".to_string()
        } else {
            format!("ASD-{}", self.theta)
        }
    }
}

/// Run `n_samples` ASD samplings per theta (plus the sequential baseline)
/// and aggregate the paper's speedup numbers. `seq_wall_s` must be the
/// measured per-sample sequential wall-clock on the same model.
pub fn sweep_thetas(model: Arc<dyn DenoiseModel>, thetas: &[usize],
                    n_samples: usize, seq_wall_s: f64, seed0: u64,
                    conds: Option<&[Vec<f64>]>,
                    latency: &LatencyModel) -> Result<Vec<SpeedupRow>> {
    let k = model.k_steps();
    let mut rows = Vec::new();
    for &theta in thetas {
        let mut engine = AsdEngine::new(
            model.clone(),
            AsdConfig { theta, eval_tail: true, backend: KernelBackend::Native },
        );
        let mut rounds = 0usize;
        let mut calls = 0usize;
        let mut accepted = 0usize;
        let mut rejected = 0usize;
        let mut wall = 0.0;
        let mut modeled = 0.0;
        for s in 0..n_samples {
            let seed = seed0 + s as u64;
            let out = match conds {
                Some(cs) => engine.sample_cond(seed, &cs[s % cs.len()])?,
                None => engine.sample(seed)?,
            };
            rounds += out.stats.parallel_rounds;
            calls += out.stats.model_calls;
            accepted += out.stats.accepted;
            rejected += out.stats.rejected;
            wall += out.wallclock_s;
            modeled += latency.run_s(&out.stats.round_batches);
        }
        let n = n_samples as f64;
        rows.push(SpeedupRow {
            theta,
            algorithmic_speedup: k as f64 / (rounds as f64 / n),
            wallclock_speedup_1dev: seq_wall_s / (wall / n),
            wallclock_speedup_modeled: latency.sequential_s(k) / (modeled / n),
            acceptance_rate: accepted as f64 / (accepted + rejected).max(1) as f64,
            mean_rounds: rounds as f64 / n,
            mean_model_calls: calls as f64 / n,
        });
    }
    Ok(rows)
}

/// Render rows as the paper-style table.
pub fn format_rows(k: usize, rows: &[SpeedupRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<10} {:>12} {:>16} {:>18} {:>12} {:>10}\n",
        "method", "alg speedup", "wall x (1 dev)", "wall x (modeled)",
        "acc rate", "rounds"));
    out.push_str(&format!(
        "{:<10} {:>12} {:>16} {:>18} {:>12} {:>10}\n",
        "DDPM", "1.00", "1.00", "1.00", "-", k));
    for r in rows {
        out.push_str(&format!(
            "{:<10} {:>12.2} {:>16.2} {:>18.2} {:>12.3} {:>10.1}\n",
            r.label(), r.algorithmic_speedup, r.wallclock_speedup_1dev,
            r.wallclock_speedup_modeled, r.acceptance_rate, r.mean_rounds));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Gmm, GmmDdpmOracle};

    #[test]
    fn sweep_produces_monotone_alg_speedup() {
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 60, false);
        let latency = LatencyModel {
            call_s: vec![(1, 1e-4), (8, 2e-4), (32, 5e-4)],
            workers: 8,
            xfer_per_float: 1e-9,
            d: 2,
        };
        let rows = sweep_thetas(oracle, &[1, 4, 0], 5, 1e-2, 0, None,
                                &latency).unwrap();
        assert_eq!(rows.len(), 3);
        assert!(rows[1].algorithmic_speedup > rows[0].algorithmic_speedup);
        assert!(rows[2].algorithmic_speedup >= rows[1].algorithmic_speedup * 0.9);
        // theta=1 speedup ~1 (every step verified once, tail-chained)
        assert!(rows[0].algorithmic_speedup <= 1.3);
        let table = format_rows(60, &rows);
        assert!(table.contains("ASD-inf"));
    }
}
