//! `StepSampler` — poll-style sampler state machines.
//!
//! The paper's exchangeability result makes the *parallel round* (one
//! batched denoiser call) the unit of work, not the per-request loop.
//! Every sampler in this crate (sequential DDPM, Picard, ASD, SL-ASD)
//! is therefore factored into a state machine that, instead of calling
//! the model itself, *demands* the rows it needs evaluated this round
//! and is *resumed* with the results:
//!
//! ```text
//!   loop {
//!       match machine.poll()? {
//!           SamplerPoll::Done(y0)    => return y0,
//!           SamplerPoll::Demand(dem) => {
//!               x0 = denoise_batch(dem.ys, dem.ts, dem.cond, dem.n);
//!               machine.resume(&x0, exec)?;
//!           }
//!       }
//!   }
//! ```
//!
//! The classic `run()` entry points ([`crate::ddpm::SequentialSampler`],
//! [`crate::picard::PicardSampler`], [`crate::asd::AsdEngine`]) are thin
//! drivers over their machines ([`drive`]), so solo execution is
//! unchanged. The serving win is that an *external* executor — the
//! coordinator's `FusionScheduler` — can hold many machines for
//! different requests, collect all their demands each tick, evaluate
//! them in one fused `denoise_batch` mega-call, and scatter the results
//! back. Because every machine consumes only its own pre-drawn Philox
//! noise and the native models are row-independent (see
//! `model::parallel`), fused execution is bit-identical to solo
//! execution — batching changes wall-clock, never samples.
//!
//! Contract:
//! * `poll` is cheap and idempotent: it returns the same demand until
//!   `resume` is called (demands are staged by the previous `resume` /
//!   the constructor, never recomputed inside `poll`).
//! * `resume(x0, exec)` must receive exactly `n * d` values laid out as
//!   the demand's rows; `exec` reports how the round was executed
//!   (latency, worker-pool shards) for stats that need it.
//! * Machines never call the model; they only do O(theta * d) sampler
//!   math (speculation chains, GRS scans, Picard updates) in `resume`.

use std::sync::Arc;

use anyhow::Result;

use crate::model::DenoiseModel;
use crate::runtime::pool::PoolConfig;

/// The rows a sampler needs evaluated in the current parallel round.
/// All slices borrow the machine's internal staging buffers.
pub struct DenoiseDemand<'a> {
    /// `n * d` row-major iterates
    pub ys: &'a [f64],
    /// `n` step indices / times
    pub ts: &'a [f64],
    /// `n * cond_dim` conditioning rows (empty when unconditional)
    pub cond: &'a [f64],
    /// number of rows demanded
    pub n: usize,
}

/// Result of polling a sampler state machine.
pub enum SamplerPoll<'a> {
    /// the machine needs these rows denoised before it can advance
    Demand(DenoiseDemand<'a>),
    /// sampling finished; the final `y_0` (borrowed from the machine)
    Done(&'a [f64]),
}

/// How the executor ran the round the machine is being resumed from —
/// recorded into per-request stats (`AsdStats::round_latency_s` /
/// `round_shards`). A fused executor reports the *fused* call's values.
#[derive(Debug, Clone, Copy)]
pub struct RoundExec {
    /// measured wall-clock seconds of the round's model call
    pub latency_s: f64,
    /// worker-pool shards the round's batch was split into (1 = inline)
    pub shards: usize,
}

impl RoundExec {
    /// An inline, unmeasured round (unit tests / synthetic resumes).
    pub fn inline() -> RoundExec {
        RoundExec { latency_s: 0.0, shards: 1 }
    }
}

/// A sampler factored as a poll/resume state machine. See the module
/// docs for the contract.
pub trait StepSampler {
    /// Current demand, or `Done` with the finished sample. Idempotent
    /// until the next `resume`.
    fn poll(&mut self) -> Result<SamplerPoll<'_>>;

    /// Advance the machine with the `n * d` x0hat rows answering the
    /// last demand.
    fn resume(&mut self, x0: &[f64], exec: RoundExec) -> Result<()>;
}

/// Drive a machine to completion against an arbitrary row evaluator
/// (`eval(ys, ts, cond, n, out)`), measuring per-round latency and
/// reporting `pool`-derived shard counts. This is the substrate both
/// for [`drive`] (a `DenoiseModel` evaluator) and for samplers whose
/// evaluator is not a `DenoiseModel` (the SL oracle in
/// `asd::sl_engine`).
pub fn drive_with<F>(machine: &mut dyn StepSampler, d: usize,
                     pool: PoolConfig, mut eval: F) -> Result<Vec<f64>>
where
    F: FnMut(&[f64], &[f64], &[f64], usize, &mut [f64]) -> Result<()>,
{
    let mut out: Vec<f64> = Vec::new();
    loop {
        let n;
        let t0;
        match machine.poll()? {
            SamplerPoll::Done(y0) => return Ok(y0.to_vec()),
            SamplerPoll::Demand(dem) => {
                n = dem.n;
                let need = n * d;
                if out.len() < need {
                    out.resize(need, 0.0);
                }
                t0 = std::time::Instant::now();
                eval(dem.ys, dem.ts, dem.cond, n, &mut out[..need])?;
            }
        }
        let exec = RoundExec {
            latency_s: t0.elapsed().as_secs_f64(),
            shards: pool.shards_for(n),
        };
        machine.resume(&out[..n * d], exec)?;
    }
}

/// Drive a machine to completion against a `DenoiseModel` (solo
/// execution — one request, one machine, one model call per round).
pub fn drive(machine: &mut dyn StepSampler, model: &Arc<dyn DenoiseModel>,
             pool: PoolConfig) -> Result<Vec<f64>> {
    let d = model.dim();
    drive_with(machine, d, pool,
               |ys, ts, cond, n, out| model.denoise_batch(ys, ts, cond, n, out))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two-round toy machine: demands one row, then its double, then is
    /// done with the sum — exercises the poll/resume protocol itself.
    struct Toy {
        stage: usize,
        ys: Vec<f64>,
        ts: Vec<f64>,
        acc: Vec<f64>,
        execs: Vec<RoundExec>,
    }

    impl StepSampler for Toy {
        fn poll(&mut self) -> Result<SamplerPoll<'_>> {
            if self.stage >= 2 {
                return Ok(SamplerPoll::Done(&self.acc));
            }
            Ok(SamplerPoll::Demand(DenoiseDemand {
                ys: &self.ys,
                ts: &self.ts,
                cond: &[],
                n: 1,
            }))
        }

        fn resume(&mut self, x0: &[f64], exec: RoundExec) -> Result<()> {
            anyhow::ensure!(x0.len() == 2, "row shape");
            for i in 0..2 {
                self.acc[i] += x0[i];
                self.ys[i] = 2.0 * x0[i];
            }
            self.stage += 1;
            self.ts[0] += 1.0;
            self.execs.push(exec);
            Ok(())
        }
    }

    #[test]
    fn drive_with_runs_machine_to_done() {
        let mut m = Toy {
            stage: 0,
            ys: vec![1.0, 2.0],
            ts: vec![0.0],
            acc: vec![0.0, 0.0],
            execs: vec![],
        };
        // evaluator: identity on ys
        let y0 = drive_with(&mut m, 2, PoolConfig::default(),
                            |ys, _ts, _c, n, out| {
                                out[..n * 2].copy_from_slice(&ys[..n * 2]);
                                Ok(())
                            })
            .unwrap();
        // round 1 adds [1,2]; round 2 adds [2,4]
        assert_eq!(y0, vec![3.0, 6.0]);
        assert_eq!(m.execs.len(), 2);
        assert!(m.execs.iter().all(|e| e.shards == 1));
        // poll is idempotent after Done
        assert!(matches!(m.poll().unwrap(), SamplerPoll::Done(_)));
    }

    #[test]
    fn poll_is_idempotent_between_resumes() {
        let mut m = Toy {
            stage: 0,
            ys: vec![5.0, 7.0],
            ts: vec![3.0],
            acc: vec![0.0, 0.0],
            execs: vec![],
        };
        for _ in 0..3 {
            match m.poll().unwrap() {
                SamplerPoll::Demand(d) => {
                    assert_eq!(d.ys, &[5.0, 7.0]);
                    assert_eq!(d.ts, &[3.0]);
                    assert_eq!(d.n, 1);
                }
                _ => panic!("expected demand"),
            }
        }
    }

    #[test]
    fn drive_surfaces_eval_errors() {
        let mut m = Toy {
            stage: 0,
            ys: vec![1.0, 1.0],
            ts: vec![0.0],
            acc: vec![0.0, 0.0],
            execs: vec![],
        };
        let err = drive_with(&mut m, 2, PoolConfig::default(),
                             |_, _, _, _, _| anyhow::bail!("injected"))
            .unwrap_err();
        assert!(err.to_string().contains("injected"));
    }
}
