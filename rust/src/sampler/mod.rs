//! `StepSampler` — poll-style sampler state machines — and
//! [`RoundArena`], the zero-copy round data plane between them and the
//! batched denoiser call.
//!
//! The paper's exchangeability result makes the *parallel round* (one
//! batched denoiser call) the unit of work, not the per-request loop.
//! Every sampler in this crate (sequential DDPM, Picard, ASD, SL-ASD)
//! is therefore factored into a state machine that, instead of calling
//! the model itself, *demands* the rows it needs evaluated this round
//! and is *resumed* with the results:
//!
//! ```text
//!   loop {
//!       arena.begin_round();
//!       match machine.poll_into(&mut arena)? {
//!           None       => return arena-independent final sample,
//!           Some(span) => {
//!               model.denoise_round(&mut arena)?;     // fused GEMM call
//!               machine.resume_from(&arena, span, exec)?;
//!           }
//!       }
//!   }
//! ```
//!
//! **The arena data plane.** A [`RoundArena`] owns the staged round:
//! row-major iterates, timesteps, conditioning rows and the output
//! region, plus the GEMM [`Workspace`](crate::model::Workspace) the
//! native backend converts into. Machines write their demanded rows
//! *directly* into arena row ranges ([`StepSampler::poll_into`]) and
//! are resumed from *views* into the arena's output region
//! ([`StepSampler::resume_from`]) — there is no intermediate mega-batch
//! pack and no scatter copy. The model side consumes the arena through
//! [`crate::model::DenoiseModel::denoise_round`]: `ParallelModel`
//! shards arena rows on the global pool, `NativeMlp` converts f64→f32
//! once per round into the arena's workspace. All buffers grow to the
//! high-water round size and are reused, so the steady-state fused path
//! performs zero heap allocations per round.
//!
//! The classic `run()` entry points ([`crate::ddpm::SequentialSampler`],
//! [`crate::picard::PicardSampler`], [`crate::asd::AsdEngine`]) are thin
//! drivers over their machines ([`drive`]) and run on the same arena
//! path, so the golden-trace and determinism suites pin it end to end.
//! The serving win is that an *external* executor — the coordinator's
//! per-variant lanes (`coordinator::lanes`) — can hold many machines
//! for different requests, stage all their demands in one arena per
//! tick, evaluate them in one fused `denoise_round` mega-call, and
//! resume every machine from its span. Because every machine consumes
//! only its own pre-drawn Philox noise and the native models are
//! row-independent (see `model::parallel`), fused execution is
//! bit-identical to solo execution — batching changes wall-clock, never
//! samples.
//!
//! Contract:
//! * `poll` is cheap and idempotent: it returns the same demand until
//!   `resume` is called. `poll_into` stages the same rows the
//!   compatibility `poll` would return, written straight into the
//!   arena; a machine must support interleaving both forms.
//! * `resume(x0, exec)` / `resume_from(arena, span, exec)` must receive
//!   exactly the rows answering the last demand; `exec` reports how the
//!   round was executed (latency, worker-pool shards) for stats.
//! * Machines never call the model; they only do O(theta * d) sampler
//!   math (speculation chains, GRS scans, Picard updates) in `resume`.

use std::sync::Arc;

use anyhow::Result;

use crate::model::{DenoiseModel, Workspace};
use crate::runtime::pool::PoolConfig;

/// The rows a sampler needs evaluated in the current parallel round.
/// All slices borrow the machine's internal staging buffers.
pub struct DenoiseDemand<'a> {
    /// `n * d` row-major iterates
    pub ys: &'a [f64],
    /// `n` step indices / times
    pub ts: &'a [f64],
    /// `n * cond_dim` conditioning rows (empty when unconditional)
    pub cond: &'a [f64],
    /// number of rows demanded
    pub n: usize,
}

/// Result of polling a sampler state machine.
pub enum SamplerPoll<'a> {
    /// the machine needs these rows denoised before it can advance
    Demand(DenoiseDemand<'a>),
    /// sampling finished; the final `y_0` (borrowed from the machine)
    Done(&'a [f64]),
}

/// How the executor ran the round the machine is being resumed from —
/// recorded into per-request stats (`AsdStats::round_latency_s` /
/// `round_shards`). A fused executor reports the *fused* call's values.
#[derive(Debug, Clone, Copy)]
pub struct RoundExec {
    /// measured wall-clock seconds of the round's model call
    pub latency_s: f64,
    /// worker-pool shards the round's batch was split into (1 = inline)
    pub shards: usize,
}

impl RoundExec {
    /// An inline, unmeasured round (unit tests / synthetic resumes).
    pub fn inline() -> RoundExec {
        RoundExec { latency_s: 0.0, shards: 1 }
    }
}

/// A contiguous row range a machine reserved in a [`RoundArena`] for
/// the current round. Returned by [`StepSampler::poll_into`] and handed
/// back to [`StepSampler::resume_from`] to locate the output rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArenaSpan {
    /// first row of the range
    pub off: usize,
    /// number of rows
    pub rows: usize,
}

/// Mutable views over a freshly reserved arena row range — the machine
/// writes its demand straight into these (no staging copy).
pub struct ArenaRowsMut<'a> {
    /// `rows * d` row-major iterates
    pub ys: &'a mut [f64],
    /// `rows` step indices / times
    pub ts: &'a mut [f64],
    /// `rows * cond_dim` conditioning rows
    pub cond: &'a mut [f64],
}

/// The round staging arena: the zero-copy data plane from sampler
/// machines down to the fused GEMM call.
///
/// One arena per execution lane (a solo driver, or one serving-lane
/// variant in the coordinator). Per round: `begin_round` resets the row
/// cursor, every machine `poll_into`s its rows, the model consumes the
/// input region and fills the output region (`denoise_round`), and
/// machines resume from output views. Buffers — including the GEMM
/// [`Workspace`] the native backend packs f32 inputs into — grow to the
/// high-water round size and are reused across rounds/ticks: the
/// steady-state fused path allocates nothing.
pub struct RoundArena {
    d: usize,
    c: usize,
    ys: Vec<f64>,
    ts: Vec<f64>,
    cond: Vec<f64>,
    out: Vec<f64>,
    rows: usize,
    ws: Workspace,
    /// grow-to-high-water byte budget: past this, [`shrink_to_cap`]
    /// (called by owners at idle points) releases the buffers instead
    /// of pinning a burst's footprint forever. 0 = unbounded.
    byte_cap: usize,
    /// largest total footprint ([`bytes`]) ever observed — surfaced
    /// per lane in coordinator metrics
    high_water_bytes: usize,
}

impl RoundArena {
    pub fn new(d: usize, cond_dim: usize) -> RoundArena {
        RoundArena {
            d,
            c: cond_dim,
            ys: Vec::new(),
            ts: Vec::new(),
            cond: Vec::new(),
            out: Vec::new(),
            rows: 0,
            ws: Workspace::new(),
            byte_cap: 0,
            high_water_bytes: 0,
        }
    }

    /// Arena shaped for `model`'s row layout.
    pub fn for_model(model: &dyn DenoiseModel) -> RoundArena {
        RoundArena::new(model.dim(), model.cond_dim())
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn cond_dim(&self) -> usize {
        self.c
    }

    /// Rows staged in the current round.
    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Byte budget for [`shrink_to_cap`](Self::shrink_to_cap); 0 (the
    /// default) keeps the pre-cap grow-forever behavior.
    pub fn set_byte_cap(&mut self, cap: usize) {
        self.byte_cap = cap;
    }

    pub fn byte_cap(&self) -> usize {
        self.byte_cap
    }

    /// Total bytes currently held: the four f64 staging buffers plus
    /// the GEMM workspace (all capacity, not round usage).
    pub fn bytes(&self) -> usize {
        (self.ys.capacity() + self.ts.capacity() + self.cond.capacity()
         + self.out.capacity()) * std::mem::size_of::<f64>()
            + self.ws.bytes()
    }

    /// Largest [`bytes`](Self::bytes) footprint ever observed
    /// (sampled at round boundaries, so it includes the workspace
    /// growth of the previous round's model call).
    pub fn high_water_bytes(&self) -> usize {
        self.high_water_bytes
    }

    /// Release every buffer when the footprint exceeds the byte cap
    /// (no-op when uncapped or under cap). Buffers regrow to the next
    /// rounds' needs — callers invoke this at idle points (a drained
    /// serving lane, the end of a drive), never mid-round: the current
    /// round's staged rows are discarded.
    pub fn shrink_to_cap(&mut self) {
        if self.byte_cap == 0 || self.bytes() <= self.byte_cap {
            return;
        }
        for v in [&mut self.ys, &mut self.ts, &mut self.cond,
                  &mut self.out] {
            v.clear();
            v.shrink_to_fit();
        }
        self.ws.shrink_to_cap(0);
        self.rows = 0;
    }

    /// Start a new round: forget the previous round's rows but keep
    /// every buffer's capacity (and the workspace) for reuse.
    pub fn begin_round(&mut self) {
        self.high_water_bytes = self.high_water_bytes.max(self.bytes());
        self.rows = 0;
    }

    /// Reserve `n` rows and return mutable views for the caller to
    /// write its demand into. Grows buffers only past their high-water
    /// mark (amortized; zero steady-state allocations).
    pub fn reserve(&mut self, n: usize) -> (ArenaSpan, ArenaRowsMut<'_>) {
        let off = self.rows;
        let end = off + n;
        grow(&mut self.ys, end * self.d);
        grow(&mut self.ts, end);
        grow(&mut self.cond, end * self.c);
        grow(&mut self.out, end * self.d);
        self.rows = end;
        (
            ArenaSpan { off, rows: n },
            ArenaRowsMut {
                ys: &mut self.ys[off * self.d..end * self.d],
                ts: &mut self.ts[off..end],
                cond: &mut self.cond[off * self.c..end * self.c],
            },
        )
    }

    /// Stage a prepared [`DenoiseDemand`] — the compatibility path the
    /// default [`StepSampler::poll_into`] shim uses for machines that
    /// only implement `poll`.
    pub fn push_demand(&mut self, dem: &DenoiseDemand<'_>)
                       -> Result<ArenaSpan> {
        anyhow::ensure!(dem.ys.len() == dem.n * self.d
                            && dem.ts.len() == dem.n
                            && dem.cond.len() == dem.n * self.c,
                        "demand shape mismatch: n={} d={} c={} ys={} ts={} \
                         cond={}",
                        dem.n, self.d, self.c, dem.ys.len(), dem.ts.len(),
                        dem.cond.len());
        let (span, rows) = self.reserve(dem.n);
        rows.ys.copy_from_slice(dem.ys);
        rows.ts.copy_from_slice(dem.ts);
        rows.cond.copy_from_slice(dem.cond);
        Ok(span)
    }

    /// The staged round as model-call views: `(ys, ts, cond, n, out)`.
    pub fn round_io(&mut self) -> (&[f64], &[f64], &[f64], usize,
                                   &mut [f64]) {
        let n = self.rows;
        (
            &self.ys[..n * self.d],
            &self.ts[..n],
            &self.cond[..n * self.c],
            n,
            &mut self.out[..n * self.d],
        )
    }

    /// Like [`round_io`](Self::round_io), plus the arena's GEMM
    /// workspace — the native backend's f64→f32 conversion target
    /// (per-lane, reused across rounds).
    pub fn round_io_ws(&mut self) -> (&[f64], &[f64], &[f64], usize,
                                      &mut [f64], &mut Workspace) {
        let n = self.rows;
        (
            &self.ys[..n * self.d],
            &self.ts[..n],
            &self.cond[..n * self.c],
            n,
            &mut self.out[..n * self.d],
            &mut self.ws,
        )
    }

    /// Output rows for a span — the view a machine is resumed from.
    pub fn out_rows(&self, span: ArenaSpan) -> &[f64] {
        &self.out[span.off * self.d..(span.off + span.rows) * self.d]
    }
}

fn grow(v: &mut Vec<f64>, len: usize) {
    if v.len() < len {
        v.resize(len, 0.0);
    }
}

/// A sampler factored as a poll/resume state machine. See the module
/// docs for the contract. `poll`/`resume` are the classic slice-based
/// form (kept as the compatibility surface for hand-driven tests and
/// external impls); `poll_into`/`resume_from` are the arena data plane
/// every driver and the serving lanes use — machines override them to
/// write demands straight into arena row ranges.
pub trait StepSampler {
    /// Current demand, or `Done` with the finished sample. Idempotent
    /// until the next `resume`.
    fn poll(&mut self) -> Result<SamplerPoll<'_>>;

    /// Advance the machine with the `n * d` x0hat rows answering the
    /// last demand.
    fn resume(&mut self, x0: &[f64], exec: RoundExec) -> Result<()>;

    /// Stage the current demand directly into `arena` row ranges and
    /// return the reserved span, or `None` when the machine is done
    /// (fetch the final sample via `poll`). The default shim routes
    /// through `poll` + a copy; machines override it to write in place.
    fn poll_into(&mut self, arena: &mut RoundArena)
                 -> Result<Option<ArenaSpan>> {
        match self.poll()? {
            SamplerPoll::Done(_) => Ok(None),
            SamplerPoll::Demand(dem) => Ok(Some(arena.push_demand(&dem)?)),
        }
    }

    /// Resume from the arena's output region for `span` (the rows
    /// reserved by the matching `poll_into`).
    fn resume_from(&mut self, arena: &RoundArena, span: ArenaSpan,
                   exec: RoundExec) -> Result<()> {
        self.resume(arena.out_rows(span), exec)
    }
}

/// Drive a machine to completion against an arbitrary row evaluator
/// (`eval(ys, ts, cond, n, out)`), measuring per-round latency and
/// reporting `pool`-derived shard counts. Runs on the arena data plane
/// (one arena for the whole drive). This is the substrate for samplers
/// whose evaluator is not a `DenoiseModel` (the SL oracle in
/// `asd::sl_engine`); [`drive`] covers the `DenoiseModel` case.
pub fn drive_with<F>(machine: &mut dyn StepSampler, d: usize,
                     cond_dim: usize, pool: PoolConfig, mut eval: F)
                     -> Result<Vec<f64>>
where
    F: FnMut(&[f64], &[f64], &[f64], usize, &mut [f64]) -> Result<()>,
{
    let mut arena = RoundArena::new(d, cond_dim);
    loop {
        arena.begin_round();
        let span = match machine.poll_into(&mut arena)? {
            None => return finished_sample(&mut *machine),
            Some(span) => span,
        };
        let t0 = std::time::Instant::now();
        {
            let (ys, ts, cond, n, out) = arena.round_io();
            eval(ys, ts, cond, n, out)?;
        }
        let exec = RoundExec {
            latency_s: t0.elapsed().as_secs_f64(),
            shards: pool.shards_for(span.rows),
        };
        machine.resume_from(&arena, span, exec)?;
    }
}

/// Drive a machine to completion against a `DenoiseModel` (solo
/// execution — one request, one machine, one fused `denoise_round` per
/// round, on the same arena path the serving lanes use). Reported
/// round shards come from the model's own routing decision
/// (`DenoiseModel::round_shards`: row shards, or the 2-D tile budget
/// for small-M tiled rounds) — engines hand the same `PoolConfig` to
/// their `ParallelModel` wrapper and to this driver, so the `_pool`
/// parameter stays only as API-compat for callers without a wrapper
/// (an unwrapped model runs inline and now truthfully reports 1).
pub fn drive(machine: &mut dyn StepSampler, model: &Arc<dyn DenoiseModel>,
             _pool: PoolConfig) -> Result<Vec<f64>> {
    let mut arena = RoundArena::for_model(model.as_ref());
    loop {
        arena.begin_round();
        let span = match machine.poll_into(&mut arena)? {
            None => return finished_sample(&mut *machine),
            Some(span) => span,
        };
        let t0 = std::time::Instant::now();
        model.denoise_round(&mut arena)?;
        let exec = RoundExec {
            latency_s: t0.elapsed().as_secs_f64(),
            shards: model.round_shards(span.rows),
        };
        machine.resume_from(&arena, span, exec)?;
    }
}

/// Fetch the final sample after `poll_into` reported done.
fn finished_sample(machine: &mut dyn StepSampler) -> Result<Vec<f64>> {
    match machine.poll()? {
        SamplerPoll::Done(y0) => Ok(y0.to_vec()),
        SamplerPoll::Demand(_) => {
            anyhow::bail!("machine demanded rows after reporting done")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two-round toy machine: demands one row, then its double, then is
    /// done with the sum — exercises the poll/resume protocol itself
    /// (and, through the default shims, the arena protocol).
    struct Toy {
        stage: usize,
        ys: Vec<f64>,
        ts: Vec<f64>,
        acc: Vec<f64>,
        execs: Vec<RoundExec>,
    }

    impl StepSampler for Toy {
        fn poll(&mut self) -> Result<SamplerPoll<'_>> {
            if self.stage >= 2 {
                return Ok(SamplerPoll::Done(&self.acc));
            }
            Ok(SamplerPoll::Demand(DenoiseDemand {
                ys: &self.ys,
                ts: &self.ts,
                cond: &[],
                n: 1,
            }))
        }

        fn resume(&mut self, x0: &[f64], exec: RoundExec) -> Result<()> {
            anyhow::ensure!(x0.len() == 2, "row shape");
            for i in 0..2 {
                self.acc[i] += x0[i];
                self.ys[i] = 2.0 * x0[i];
            }
            self.stage += 1;
            self.ts[0] += 1.0;
            self.execs.push(exec);
            Ok(())
        }
    }

    fn toy() -> Toy {
        Toy {
            stage: 0,
            ys: vec![1.0, 2.0],
            ts: vec![0.0],
            acc: vec![0.0, 0.0],
            execs: vec![],
        }
    }

    #[test]
    fn drive_with_runs_machine_to_done() {
        let mut m = toy();
        // evaluator: identity on ys
        let y0 = drive_with(&mut m, 2, 0, PoolConfig::default(),
                            |ys, _ts, _c, n, out| {
                                out[..n * 2].copy_from_slice(&ys[..n * 2]);
                                Ok(())
                            })
            .unwrap();
        // round 1 adds [1,2]; round 2 adds [2,4]
        assert_eq!(y0, vec![3.0, 6.0]);
        assert_eq!(m.execs.len(), 2);
        assert!(m.execs.iter().all(|e| e.shards == 1));
        // poll is idempotent after Done
        assert!(matches!(m.poll().unwrap(), SamplerPoll::Done(_)));
    }

    #[test]
    fn poll_is_idempotent_between_resumes() {
        let mut m = Toy {
            stage: 0,
            ys: vec![5.0, 7.0],
            ts: vec![3.0],
            acc: vec![0.0, 0.0],
            execs: vec![],
        };
        for _ in 0..3 {
            match m.poll().unwrap() {
                SamplerPoll::Demand(d) => {
                    assert_eq!(d.ys, &[5.0, 7.0]);
                    assert_eq!(d.ts, &[3.0]);
                    assert_eq!(d.n, 1);
                }
                _ => panic!("expected demand"),
            }
        }
    }

    #[test]
    fn drive_surfaces_eval_errors() {
        let mut m = toy();
        let err = drive_with(&mut m, 2, 0, PoolConfig::default(),
                             |_, _, _, _, _| anyhow::bail!("injected"))
            .unwrap_err();
        assert!(err.to_string().contains("injected"));
    }

    #[test]
    fn arena_reserve_lays_rows_out_contiguously() {
        let mut a = RoundArena::new(3, 2);
        a.begin_round();
        let (s1, rows1) = a.reserve(2);
        rows1.ys.copy_from_slice(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        rows1.ts.copy_from_slice(&[9.0, 8.0]);
        rows1.cond.copy_from_slice(&[0.1, 0.2, 0.3, 0.4]);
        let (s2, rows2) = a.reserve(1);
        rows2.ys.copy_from_slice(&[7.0, 8.0, 9.0]);
        rows2.ts[0] = 7.0;
        rows2.cond.copy_from_slice(&[0.5, 0.6]);
        assert_eq!(s1, ArenaSpan { off: 0, rows: 2 });
        assert_eq!(s2, ArenaSpan { off: 2, rows: 1 });
        assert_eq!(a.rows(), 3);
        let (ys, ts, cond, n, out) = a.round_io();
        assert_eq!(n, 3);
        assert_eq!(ys, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0]);
        assert_eq!(ts, &[9.0, 8.0, 7.0]);
        assert_eq!(cond, &[0.1, 0.2, 0.3, 0.4, 0.5, 0.6]);
        assert_eq!(out.len(), 9);
        out.copy_from_slice(&[0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        assert_eq!(a.out_rows(s2), &[6.0, 7.0, 8.0]);
        assert_eq!(a.out_rows(s1), &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn arena_reuses_capacity_across_rounds() {
        let mut a = RoundArena::new(2, 0);
        a.begin_round();
        let _ = a.reserve(8);
        let cap = (a.ys.capacity(), a.ts.capacity(), a.out.capacity());
        for _ in 0..5 {
            a.begin_round();
            let _ = a.reserve(3);
            let _ = a.reserve(5);
            assert_eq!(a.rows(), 8);
        }
        // shrinking/regrowing rounds never reallocate past high water
        assert_eq!(cap,
                   (a.ys.capacity(), a.ts.capacity(), a.out.capacity()));
    }

    #[test]
    fn arena_byte_cap_bounds_the_high_water_footprint() {
        let mut a = RoundArena::new(4, 0);
        assert_eq!(a.byte_cap(), 0);
        assert_eq!(a.bytes(), 0);
        // uncapped: shrink_to_cap is a no-op however large we grow
        a.begin_round();
        let _ = a.reserve(128);
        a.begin_round(); // samples high water at the round boundary
        let grown = a.bytes();
        assert!(grown >= 128 * 4 * 8);
        assert!(a.high_water_bytes() >= grown);
        a.shrink_to_cap();
        assert_eq!(a.bytes(), grown, "uncapped arena must never shrink");
        // capped: under-cap footprints stay, over-cap ones release
        a.set_byte_cap(grown);
        a.shrink_to_cap();
        assert_eq!(a.bytes(), grown);
        a.set_byte_cap(grown - 1);
        a.shrink_to_cap();
        assert_eq!(a.bytes(), 0, "over-cap arena must release buffers");
        // high water survives the shrink (it is a lifetime gauge) and
        // the arena regrows transparently
        assert!(a.high_water_bytes() >= grown);
        a.begin_round();
        let (span, _) = a.reserve(3);
        assert_eq!(span.rows, 3);
        assert_eq!(a.rows(), 3);
    }

    #[test]
    fn push_demand_validates_shapes() {
        let mut a = RoundArena::new(2, 1);
        a.begin_round();
        let bad = DenoiseDemand { ys: &[1.0], ts: &[1.0], cond: &[0.0],
                                  n: 1 };
        assert!(a.push_demand(&bad).is_err());
        let good = DenoiseDemand { ys: &[1.0, 2.0], ts: &[3.0],
                                   cond: &[0.5], n: 1 };
        let span = a.push_demand(&good).unwrap();
        assert_eq!(span, ArenaSpan { off: 0, rows: 1 });
        let (ys, ts, cond, n, _) = a.round_io();
        assert_eq!((ys, ts, cond, n),
                   (&[1.0, 2.0][..], &[3.0][..], &[0.5][..], 1));
    }

    #[test]
    fn default_poll_into_shim_matches_poll() {
        let mut m = toy();
        let mut a = RoundArena::new(2, 0);
        a.begin_round();
        let span = m.poll_into(&mut a).unwrap().unwrap();
        assert_eq!(span, ArenaSpan { off: 0, rows: 1 });
        {
            let (ys, ts, _c, n, out) = a.round_io();
            assert_eq!(ys, &[1.0, 2.0]);
            assert_eq!(ts, &[0.0]);
            out[..n * 2].copy_from_slice(&ys[..n * 2]);
        }
        m.resume_from(&a, span, RoundExec::inline()).unwrap();
        assert_eq!(m.acc, vec![1.0, 2.0]);
        assert_eq!(m.ys, vec![2.0, 4.0]);
        // done: poll_into returns None, poll still yields the sample
        a.begin_round();
        let span = m.poll_into(&mut a).unwrap().unwrap();
        {
            let (ys, _t, _c, n, out) = a.round_io();
            out[..n * 2].copy_from_slice(&ys[..n * 2]);
        }
        m.resume_from(&a, span, RoundExec::inline()).unwrap();
        a.begin_round();
        assert!(m.poll_into(&mut a).unwrap().is_none());
        assert!(matches!(m.poll().unwrap(), SamplerPoll::Done(_)));
    }
}
