//! `asd` CLI — leader entrypoint for the serving stack and one-shot
//! sampling.
//!
//! Subcommands:
//!   info                          list artifacts/variants
//!   sample   --model V [...]      draw samples, print stats
//!   serve    --model V [...]      run the coordinator on a synthetic
//!                                 request trace, report latency/throughput
//!   pool     [...]                sweep worker-pool sizes on an analytic
//!                                 GMM workload: measured wall-clock
//!                                 speedup next to the algorithmic
//!                                 rounds speedup (no artifacts needed)
//!   pareto   [...]                speedup-vs-cost Pareto grid: sequential
//!                                 vs ASD vs SL-ASD vs draft-model
//!                                 speculative sampling across target ×
//!                                 draft × precision cells
//!   chaos    [...]                fault-injection sweep: serve a mixed
//!                                 burst under a seeded FaultPlan and
//!                                 report completion rate, goodput and
//!                                 recovery latency per fault rate
//!
//! Examples live in examples/ (quickstart, image_generation,
//! robot_control, serve, scaling_law).

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use asd::asd::{AsdConfig, AsdEngine, DraftConfig, DraftEngine,
               KernelBackend};
use asd::coordinator::{Coordinator, Request, SamplerSpec, ServerConfig};
use asd::ddpm::SequentialSampler;
use asd::math::isa::{IsaRequest, KernelPolicy, Precision};
use asd::model::{distill_draft, NativeMlp};
use asd::runtime::Runtime;
use asd::util::cli::Args;

/// Parse `--gemm-isa` / `--gemm-precision` into the [`KernelPolicy`]
/// handed to native model loads. Unset flags keep the defaults
/// (auto-detected ISA, f32 panels); the `ASD_GEMM_ISA` env var still
/// overrides the ISA at resolve time (see `math::isa`).
fn kernel_policy_from_args(args: &Args) -> Result<KernelPolicy> {
    let mut policy = KernelPolicy::default();
    if let Some(s) = args.get("gemm-isa") {
        policy.isa = IsaRequest::parse(s).with_context(
            || format!("bad --gemm-isa '{s}' (use auto|portable|avx2|neon)"))?;
    }
    if let Some(s) = args.get("gemm-precision") {
        policy.precision = Precision::parse(s).with_context(
            || format!("bad --gemm-precision '{s}' (use f32|f16|int8)"))?;
    }
    Ok(policy)
}

fn main() {
    let args = Args::from_env(&["verbose", "native", "hlo-kernels", "help",
                                "analytic", "gemm-grid"]);
    if args.flag("verbose") {
        asd::util::log::set_level(asd::util::log::Level::Debug);
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "info" => cmd_info(),
        "sample" => cmd_sample(&args),
        "serve" => cmd_serve(&args),
        "pool" => cmd_pool(&args),
        "pareto" => cmd_pareto(&args),
        "chaos" => cmd_chaos(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "asd — Autospeculative Decoding for DDPMs\n\n\
         USAGE: asd <command> [options]\n\n\
         COMMANDS:\n  \
         info                       list artifact variants\n  \
         sample --model <v>         sample; options: --n 4 --theta 8\n    \
         [--sampler asd|ddpm|draft] [--seed 0] [--native] [--hlo-kernels]\n    \
         [--draft-fold 4] (draft sampler: distill hidden/fold draft)\n    \
         [--gemm-isa auto|portable|avx2|neon] (native GEMM kernels)\n    \
         [--gemm-precision f32|f16|int8] (native packed-panel store)\n  \
         serve  --model <v>         synthetic serving trace; options:\n    \
         [--requests 32] [--workers 2] [--asd-frac 0.5] [--theta 8]\n    \
         [--pool 1] [--shard-min 2] [--max-batch 8] [--native]\n    \
         [--gemm-isa ...] [--gemm-precision ...] (native backend)\n    \
         [--max-queue-depth 1024] [--arena-cap-mb 64] (per-lane round\n    \
         arena byte cap; 0 = unbounded) [--analytic] (GMM oracle, no\n    \
         artifacts) [--analytic-variants 2] (mixed-variant lanes)\n    \
         [--json BENCH_coordinator.json]\n    \
         [--concurrency 1,8,64] [--bench-requests 32]\n  \
         pool                       pool-size sweep on an analytic GMM;\n    \
         [--d 64] [--components 96] [--k 150] [--theta 16] [--n 4]\n    \
         [--pool-sizes 1,2,4,8] [--shard-min 2] [--json out.json]\n    \
         [--gemm-grid] (time ref/v1/packed/packed2d GEMM kernels over\n    \
         the shape grid) [--gemm-json BENCH_gemm.json] [--gemm-reps 3]\n  \
         pareto                     speedup-vs-cost Pareto grid over\n    \
         sequential / ASD / SL-ASD / draft-SD; artifact-free; options:\n    \
         [--analytic] (GMM cells only, skip native MLP cells)\n    \
         [--n 4] [--k 8] [--json BENCH_pareto.json]\n  \
         chaos                      deterministic fault-injection sweep\n    \
         on the analytic GMM serving stack (always artifact-free);\n    \
         [--requests 48] [--workers 2] [--theta 8] [--k 20] [--seed 7]\n    \
         [--fault-rates 0,0.05,0.1,0.25] [--json BENCH_chaos.json]\n"
    );
}

fn cmd_info() -> Result<()> {
    let manifest = asd::model::Manifest::load_default()?;
    println!("artifacts: {}", manifest.dir.display());
    println!("{:<18} {:>6} {:>6} {:>6} {:>8} {:>12}", "variant", "d",
             "cond", "K", "loss", "batches");
    for (name, v) in &manifest.variants {
        println!("{:<18} {:>6} {:>6} {:>6} {:>8.3} {:>12}", name, v.d,
                 v.cond_dim, v.k_steps, v.train_loss,
                 v.artifacts.keys().map(|b| b.to_string())
                     .collect::<Vec<_>>().join(","));
    }
    Ok(())
}

fn cmd_sample(args: &Args) -> Result<()> {
    let variant = args.get("model").context("--model is required")?;
    let n = args.get_usize("n", 4)?;
    let theta = args.get_usize("theta", 8)?;
    let seed0 = args.get_u64("seed", 0)?;
    let sampler = args.get_or("sampler", "asd");

    let rt = Runtime::load_default()?;
    let model: Arc<dyn asd::model::DenoiseModel> = if args.flag("native") {
        let info = rt.manifest.variant(variant)?;
        let policy = kernel_policy_from_args(args)?;
        let mlp = NativeMlp::load_with(info, &rt.manifest.dir, policy)?;
        println!("native backend: isa={} precision={} tier={}",
                 mlp.isa(), mlp.kernel_policy().precision,
                 mlp.determinism_tier());
        mlp
    } else {
        rt.model(variant)?
    };
    let k = model.k_steps();
    let cond_dim = model.cond_dim();
    // conditional variants get a class one-hot (--class, default 0)
    let cls = args.get_usize("class", 0)?;
    let mut cond = vec![0.0; cond_dim];
    if cond_dim > 0 {
        cond[cls.min(cond_dim - 1)] = 1.0;
    }
    println!("variant={variant} d={} K={k} sampler={sampler}", model.dim());

    match sampler {
        "ddpm" => {
            let s = SequentialSampler::new(model);
            for i in 0..n {
                let t0 = std::time::Instant::now();
                let (y, st) = s.sample(seed0 + i as u64, &cond)?;
                println!(
                    "sample {i}: {} model calls, {:.1} ms, y[0..4]={:?}",
                    st.model_calls,
                    t0.elapsed().as_secs_f64() * 1e3,
                    &y[..y.len().min(4)]
                );
            }
        }
        "asd" => {
            let backend = if args.flag("hlo-kernels") {
                KernelBackend::Hlo(rt.kernels(model.dim())?)
            } else {
                KernelBackend::Native
            };
            let mut e = AsdEngine::new(
                model,
                AsdConfig {
                    theta,
                    eval_tail: true,
                    backend,
                    ..Default::default()
                });
            for i in 0..n {
                let out = e.sample_cond(seed0 + i as u64, &cond)?;
                println!(
                    "sample {i}: {} rounds ({} calls, {:.2}x alg speedup), \
                     {:.1} ms, acc {:.3}, y[0..4]={:?}",
                    out.stats.parallel_rounds,
                    out.stats.model_calls,
                    out.stats.algorithmic_speedup(k),
                    out.wallclock_s * 1e3,
                    out.stats.acceptance_rate(),
                    &out.y0[..out.y0.len().min(4)]
                );
            }
        }
        "draft" => {
            // distill a cheap draft from the target's own weights and
            // run draft-model speculative sampling: the draft proposes
            // --theta-step windows sequentially, the target verifies
            // each window in one fused round
            let fold = args.get_usize("draft-fold", 4)?.max(2);
            let info = rt.manifest.variant(variant)?;
            let path = rt.manifest.dir.join(&info.weights_file);
            let bytes = std::fs::read(&path)
                .with_context(|| format!("reading {}", path.display()))?;
            if bytes.len() % 4 != 0 {
                bail!("weights file not a multiple of 4 bytes");
            }
            let flat: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let (dinfo, dflat) = distill_draft(info, &flat, fold)?;
            let policy = kernel_policy_from_args(args)?;
            let draft: Arc<dyn asd::model::DenoiseModel> =
                NativeMlp::from_flat_with(&dinfo, &dflat, policy)?;
            println!("draft: {} (hidden {} -> {}, fold {fold})",
                     dinfo.name, info.hidden, dinfo.hidden);
            let mut e = DraftEngine::new(
                model, draft,
                DraftConfig { k: theta, ..Default::default() });
            for i in 0..n {
                let out = e.sample_cond(seed0 + i as u64, &cond)?;
                println!(
                    "sample {i}: {} rounds ({} target + {} draft calls, \
                     {:.2}x alg speedup), {:.1} ms, acc {:.3}, \
                     y[0..4]={:?}",
                    out.stats.parallel_rounds,
                    out.stats.model_calls,
                    out.stats.draft_calls,
                    out.stats.algorithmic_speedup(k),
                    out.wallclock_s * 1e3,
                    out.stats.acceptance_rate(),
                    &out.y0[..out.y0.len().min(4)]
                );
            }
        }
        other => bail!("unknown sampler '{other}' (use asd|ddpm|draft)"),
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let n_requests = args.get_usize("requests", 32)?;
    let workers = args.get_usize("workers", 2)?;
    let theta = args.get_usize("theta", 8)?;
    let asd_frac = args.get_f64("asd-frac", 0.5)?;
    let pool_size = args.get_usize("pool", 1)?;
    let shard_min = args.get_usize("shard-min", 2)?;
    let max_batch = args.get_usize("max-batch", 8)?;
    let max_queue_depth = args.get_usize("max-queue-depth", 1024)?;
    let arena_cap_mb = args.get_usize("arena-cap-mb", 64)?;

    let config = ServerConfig {
        workers,
        max_batch,
        enable_batching: true,
        max_queue_depth,
        pool: asd::runtime::pool::PoolConfig { pool_size, shard_min },
        // 0 disables the cap (lanes grow to high water forever)
        arena_byte_cap: arena_cap_mb << 20,
        kernel: kernel_policy_from_args(args)?,
        ..ServerConfig::default()
    };

    // --analytic serves GMM posterior-mean oracles: no AOT artifacts
    // needed, so the serving stack (and its CI smoke) runs anywhere.
    // --analytic-variants N registers N distinct oracle variants so
    // the mixed-variant lane scheduler is exercised end to end.
    let mut models: Vec<(String, Arc<dyn asd::model::DenoiseModel>)> =
        Vec::new();
    if args.flag("analytic") {
        let k = args.get_usize("k", 60)?;
        let n_variants = args.get_usize("analytic-variants", 1)?.max(1);
        for v in 0..n_variants {
            let gmm = if v == 0 {
                asd::model::Gmm::circle_2d()
            } else {
                asd::model::Gmm::random(2, 4 + v, 1.5, 7 + v as u64)
            };
            let m: Arc<dyn asd::model::DenoiseModel> =
                asd::model::GmmDdpmOracle::new(gmm, k, false);
            models.push((format!("gmm-analytic-{v}"), m));
        }
    } else {
        let variant = args.get("model").unwrap_or("gmm2d").to_string();
        let rt = Runtime::load_default()?;
        let model: Arc<dyn asd::model::DenoiseModel> =
            if args.flag("native") {
                // native backend honors the server's kernel policy:
                // the resolved ISA/precision (and therefore the
                // determinism tier) are fixed per deployment
                let info = rt.manifest.variant(&variant)?;
                let mlp = NativeMlp::load_with(info, &rt.manifest.dir,
                                               config.kernel)?;
                println!("native backend: isa={} precision={} tier={}",
                         mlp.isa(), mlp.kernel_policy().precision,
                         mlp.determinism_tier());
                mlp
            } else {
                let model = rt.model(&variant)?;
                model.warmup()?;
                model
            };
        models.push((variant, model));
    }
    let coordinator = Coordinator::new(config.clone())?;
    for (name, model) in &models {
        coordinator.register_model(name, model.clone());
    }

    println!("serving {n_requests} requests on {workers} workers \
              across {} variant lane(s) (asd fraction {asd_frac})",
             models.len());
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    for i in 0..n_requests {
        let sampler = if (i as f64 / n_requests as f64) < asd_frac {
            SamplerSpec::Asd(theta)
        } else {
            SamplerSpec::Sequential
        };
        // rotate requests across the registered variants
        let (variant, model) = &models[i % models.len()];
        let cond_dim = model.cond_dim();
        let mut cond = vec![0.0; cond_dim];
        if cond_dim > 0 {
            cond[i % cond_dim] = 1.0; // rotate classes across requests
        }
        let (_, rx) = coordinator.submit(Request {
            id: 0,
            variant: variant.clone(),
            sampler,
            seed: 1000 + i as u64,
            cond,
            deadline: None,
        });
        rxs.push(rx);
    }
    let mut failed = 0;
    for rx in rxs {
        let r = rx.recv()?;
        if r.error.is_some() {
            failed += 1;
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let m = coordinator.metrics();
    println!(
        "done in {elapsed:.2}s — {:.1} req/s, mean latency {:.1} ms \
         (queue {:.1} ms), {} batched into {} fusion groups \
         ({:.1} rows/fused round, occupancy {:.2}), {failed} failed, \
         {} rejected",
        n_requests as f64 / elapsed,
        m.mean_service_ms,
        m.mean_queue_wait_ms,
        m.batched_requests,
        m.batched_groups,
        m.fused_rows_per_round,
        m.fused_occupancy,
        m.rejected
    );
    if !m.lanes.is_empty() {
        print!("{}", asd::exp::serve_bench::format_lanes(&m.lanes));
    }
    coordinator.shutdown();

    // --json: run the concurrency-sweep bench (first variant) plus —
    // with >= 2 variants — the mixed-variant lane scenario, and emit
    // BENCH_coordinator.json (schema v2: per-lane occupancy/queue-wait)
    if let Some(path) = args.get("json") {
        let concurrencies =
            args.get_usize_list("concurrency", &[1, 8, 64])?;
        let bench_requests = args.get_usize("bench-requests",
                                            n_requests.max(16))?;
        let (variant, model) = &models[0];
        let rows = asd::exp::serve_bench::bench_coordinator(
            model.clone(), variant, &concurrencies, bench_requests,
            &config, theta)?;
        print!("{}", asd::exp::serve_bench::format_coord_rows(&rows));
        let mixed = if models.len() >= 2 {
            let b = asd::exp::serve_bench::bench_mixed_variants(
                &models, bench_requests.div_ceil(models.len()).max(2),
                &config, theta)?;
            println!("mixed-variant lanes (overlap: {}):",
                     b.lanes_overlap);
            print!("{}", asd::exp::serve_bench::format_lanes(&b.lanes));
            Some(b)
        } else {
            None
        };
        let doc = asd::exp::serve_bench::bench_coordinator_json(
            variant, model.k_steps(), &rows, mixed.as_ref());
        asd::exp::speedup::write_bench_json(std::path::Path::new(path),
                                            &doc)?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Pool-size sweep on a heavy analytic GMM oracle — runs without any
/// AOT artifacts, so it demonstrates the measured-vs-algorithmic
/// speedup columns anywhere the crate builds.
fn cmd_pool(args: &Args) -> Result<()> {
    let d = args.get_usize("d", 64)?;
    let components = args.get_usize("components", 96)?;
    let k = args.get_usize("k", 150)?;
    let theta = args.get_usize("theta", 16)?;
    let n = args.get_usize("n", 4)?;
    let shard_min = args.get_usize("shard-min", 2)?;
    let pool_sizes = args.get_usize_list("pool-sizes", &[1, 2, 4, 8])?;
    if pool_sizes.first() != Some(&1) {
        eprintln!("note: the first --pool-sizes entry is the measured \
                   baseline (usually 1)");
    }

    let gmm = asd::model::Gmm::random(d, components, 1.5, 7);
    let model: Arc<dyn asd::model::DenoiseModel> =
        asd::model::GmmDdpmOracle::new(gmm, k, false);
    println!("pool sweep: analytic GMM d={d} components={components} K={k} \
              theta={theta} samples={n} (pool threads: {})",
             asd::runtime::pool::default_threads());
    let rows = asd::exp::speedup::sweep_pool_sizes(
        model, &pool_sizes, shard_min, theta, n, 100)?;
    print!("{}", asd::exp::speedup::format_pool_rows(k, &rows));
    println!("outputs bit-identical across pool sizes: {}",
             asd::exp::speedup::outputs_bit_identical(&rows));
    if let Some(path) = args.get("json") {
        let doc = asd::exp::speedup::bench_parallel_json(&[], k, theta,
                                                         &rows);
        asd::exp::speedup::write_bench_json(std::path::Path::new(path),
                                            &doc)?;
        println!("wrote {path}");
    }

    // --gemm-grid / --gemm-json: time the GEMM kernel generations
    // (ref / v1 / packed / packed+2D-sharded) over the square + small-M
    // serve shape grid and emit BENCH_gemm.json — artifact-free, so CI
    // smokes the packed kernel end to end anywhere the crate builds
    if args.flag("gemm-grid") || args.get("gemm-json").is_some() {
        let tile_shards = pool_sizes.iter().copied().max()
            .unwrap_or_else(asd::runtime::pool::default_threads)
            .max(1);
        let reps = args.get_usize("gemm-reps", 3)?.max(1);
        println!("\nGEMM shape grid (tile_shards={tile_shards}, \
                  reps={reps}):");
        let gemm_path = args.get("gemm-json").unwrap_or("BENCH_gemm.json");
        asd::exp::speedup::run_gemm_grid(
            tile_shards, 1, reps, std::path::Path::new(gemm_path))?;
    }
    Ok(())
}

/// Speedup-vs-cost Pareto grid: sequential DDPM vs ASD vs SL-ASD vs
/// draft-model speculative sampling across target-size × draft-size ×
/// precision cells. Artifact-free (analytic GMM oracles plus synthetic
/// native MLPs), so the frontier — including the draft-SD
/// rounds-vs-FLOPs trade — reproduces anywhere the crate builds.
fn cmd_pareto(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 4)?;
    let k_window = args.get_usize("k", 8)?;
    let analytic_only = args.flag("analytic");
    let path = args.get("json").unwrap_or("BENCH_pareto.json");
    asd::exp::speedup::run_pareto_grid(
        analytic_only, n, k_window, std::path::Path::new(path))
}

/// Deterministic fault-injection sweep over the serving stack — always
/// analytic (GMM oracle target + shifted-mean draft), so the chaos
/// smoke runs anywhere the crate builds. `--analytic` is accepted for
/// symmetry with `serve` but is the only mode.
fn cmd_chaos(args: &Args) -> Result<()> {
    let n_requests = args.get_usize("requests", 48)?;
    let workers = args.get_usize("workers", 2)?;
    let theta = args.get_usize("theta", 8)?;
    let k = args.get_usize("k", 20)?;
    let seed = args.get_u64("seed", 7)?;
    // comma-separated f64 list (Args has no float-list helper)
    let rates_s = args.get_or("fault-rates", "0,0.05,0.1,0.25");
    let mut fault_rates = Vec::new();
    for part in rates_s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        fault_rates.push(part.parse::<f64>().with_context(
            || format!("bad --fault-rates entry '{part}'"))?);
    }
    if fault_rates.is_empty() {
        bail!("--fault-rates needs at least one rate");
    }
    println!("chaos sweep: analytic GMM d=8 K={k} theta={theta} \
              requests={n_requests}/rate workers={workers} seed={seed}");
    let rows = asd::exp::chaos_bench::bench_chaos(
        k, theta, n_requests, workers, &fault_rates, seed)?;
    print!("{}", asd::exp::chaos_bench::format_chaos_rows(&rows));
    let path = args.get("json").unwrap_or("BENCH_chaos.json");
    let doc = asd::exp::chaos_bench::bench_chaos_json(
        k, theta, n_requests, seed, &rows);
    asd::exp::speedup::write_bench_json(std::path::Path::new(path), &doc)?;
    println!("wrote {path}");
    Ok(())
}
