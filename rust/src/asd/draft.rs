//! Draft-model speculative sampling (draft-SD): the fifth poll/resume
//! [`StepSampler`] machine, beside sequential DDPM, Picard, ASD and
//! SL-ASD.
//!
//! ASD is draft-free: it speculates with the *target's own* x0hat and
//! pays one parallel round per proposal plus one per verification.
//! Draft-SD (De Bortoli et al., "Accelerated Diffusion Models via
//! Speculative Sampling") replaces the proposal round with a *cheap
//! draft model* chained sequentially inside the machine: the draft
//! proposes a k-step trajectory at negligible cost, then the target
//! verifies all k proposed steps in ONE fused `denoise_batch` round.
//! The accept/reject decision is the same GRS (Algorithm 3) the ASD
//! verifier uses — by Theorem 12 each corrected step is an *exact*
//! sample from the target transition N(m, sigma^2 I) regardless of the
//! draft's proposal mean, so draft-SD samples the exact DDPM law. On
//! rejection the GRS reflection-coupled sample replaces the first
//! rejected position and the proposed suffix is discarded.
//!
//! Round accounting: one parallel round per iteration (the fused
//! verify of the whole window) — structurally half of ASD's
//! propose+verify cadence. The draft's own chain calls never hit the
//! round plane: they are machine-internal sampler math (the draft is
//! assumed cheap relative to the target; `AsdStats::draft_calls`
//! counts them so the Pareto bench can price the trade honestly).
//!
//! The machine consumes the same pre-drawn Philox streams as every
//! other sampler (`xi[j]`/`u[j]` for transition j+1 -> j), so fused
//! coordinator execution is bit-identical to solo execution, and a
//! draft that equals the target yields v = 0 at every position and
//! never rejects (Lemma 13) — reproducing sequential DDPM bit-for-bit.

use std::sync::Arc;

use anyhow::Result;

use crate::asd::adaptive::WindowController;
use crate::asd::engine::{AsdOutput, AsdStats};
use crate::asd::grs::grs_native;
use crate::ddpm::NoiseStreams;
use crate::math::vec_ops::lincomb_into;
use crate::model::{DenoiseModel, ParallelModel};
use crate::runtime::pool::PoolConfig;
use crate::sampler::{ArenaSpan, DenoiseDemand, RoundArena, RoundExec,
                     SamplerPoll, StepSampler};

/// Configuration for the draft-speculative engine/machine.
#[derive(Clone)]
pub struct DraftConfig {
    /// Draft speculation window; 0 = speculate to the end.
    pub k: usize,
    /// Sharded execution of the fused verify rounds on the global
    /// worker pool (bit-transparent; see [`crate::asd::AsdConfig`]).
    pub pool: PoolConfig,
    /// Optional acceptance-driven window controller (shared economics
    /// with ASD's adaptive theta — see `asd::adaptive`). The engine
    /// threads it through each sample's machine and carries the learned
    /// state across samples.
    pub adaptive: Option<WindowController>,
}

impl Default for DraftConfig {
    fn default() -> DraftConfig {
        DraftConfig {
            k: 8,
            pool: PoolConfig::default(),
            adaptive: None,
        }
    }
}

/// The draft-SD engine — a thin [`crate::sampler::drive`] loop over
/// [`DraftStepMachine`], mirroring [`crate::asd::AsdEngine`]'s API.
/// `model` is the (pool-wrapped) target; `draft` stays unwrapped — its
/// chain runs as sequential single-row calls inside the machine.
pub struct DraftEngine {
    pub model: Arc<dyn DenoiseModel>,
    pub draft: Arc<dyn DenoiseModel>,
    pub config: DraftConfig,
}

impl DraftEngine {
    pub fn new(target: Arc<dyn DenoiseModel>, draft: Arc<dyn DenoiseModel>,
               config: DraftConfig) -> DraftEngine {
        let model = ParallelModel::wrap(target, config.pool);
        DraftEngine { model, draft, config }
    }

    pub fn sample(&mut self, seed: u64) -> Result<AsdOutput> {
        let noise = NoiseStreams::draw(seed, 0, self.model.k_steps(),
                                       self.model.dim());
        self.sample_owned_noise(noise, &[])
    }

    pub fn sample_cond(&mut self, seed: u64, cond: &[f64])
                       -> Result<AsdOutput> {
        let noise = NoiseStreams::draw(seed, 0, self.model.k_steps(),
                                       self.model.dim());
        self.sample_owned_noise(noise, cond)
    }

    pub fn sample_with_noise(&mut self, noise: &NoiseStreams, cond: &[f64])
                             -> Result<AsdOutput> {
        self.sample_owned_noise(noise.clone(), cond)
    }

    fn sample_owned_noise(&mut self, noise: NoiseStreams, cond: &[f64])
                          -> Result<AsdOutput> {
        let t_start = std::time::Instant::now();
        let mut machine = DraftStepMachine::new(
            self.model.clone(),
            self.draft.clone(),
            self.config.k,
            self.config.adaptive.clone(),
            noise,
            cond,
        )?;
        let y0 = crate::sampler::drive(&mut machine, &self.model,
                                       self.config.pool)?;
        // carry the controller's learned acceptance across samples
        self.config.adaptive = machine.take_controller();
        Ok(AsdOutput {
            y0,
            stats: machine.into_stats(),
            wallclock_s: t_start.elapsed().as_secs_f64(),
        })
    }
}

/// Where the draft machine is between rounds. Unlike ASD there is no
/// Propose phase: the draft chain is built inline (machine-internal),
/// so every round is a fused verify of the whole proposed window.
enum DraftPhase {
    /// demand `th` verify rows: the current state plus the first
    /// `th - 1` draft-proposed points
    Verify { th: usize },
    Done,
}

/// Draft-model speculative sampling as a poll/resume state machine.
/// Each demand is one parallel round: the batched target verification
/// of a draft-proposed window. The draft chain and the GRS scan run
/// inside the machine (`new` / `resume`); the *target* is never called
/// by the machine — only demanded through the round plane, so the
/// coordinator fuses draft-SD verify rounds with any other machine's
/// rows bit-identically to solo execution.
pub struct DraftStepMachine {
    target: Arc<dyn DenoiseModel>,
    draft: Arc<dyn DenoiseModel>,
    k_window: usize,
    adaptive: Option<WindowController>,
    noise: NoiseStreams,
    cond: Vec<f64>,
    // chain buffers (sized K x d)
    m_hat: Vec<f64>,
    y_hat: Vec<f64>,
    x0_eval: Vec<f64>,
    eval_in: Vec<f64>,
    eval_ts: Vec<f64>,
    eval_cond: Vec<f64>,
    x0_draft: Vec<f64>,
    m_buf: Vec<f64>,
    z_buf: Vec<f64>,
    v_buf: Vec<f64>,
    // loop state
    y: Vec<f64>,
    i_cur: usize,
    phase: DraftPhase,
    /// whether the eval buffers hold the current Verify demand (lazy
    /// staging for the compatibility `poll`; `poll_into` writes the
    /// arena straight from the chain buffers)
    staged: bool,
    stats: AsdStats,
}

impl DraftStepMachine {
    pub fn new(target: Arc<dyn DenoiseModel>, draft: Arc<dyn DenoiseModel>,
               k_window: usize, adaptive: Option<WindowController>,
               noise: NoiseStreams, cond: &[f64])
               -> Result<DraftStepMachine> {
        anyhow::ensure!(cond.len() == target.cond_dim(),
                        "conditioning length {} != cond_dim {}",
                        cond.len(), target.cond_dim());
        anyhow::ensure!(draft.dim() == target.dim(),
                        "draft dim {} != target dim {}",
                        draft.dim(), target.dim());
        anyhow::ensure!(draft.cond_dim() == target.cond_dim(),
                        "draft cond_dim {} != target cond_dim {}",
                        draft.cond_dim(), target.cond_dim());
        anyhow::ensure!(draft.k_steps() == target.k_steps(),
                        "draft k_steps {} != target k_steps {}",
                        draft.k_steps(), target.k_steps());
        let d = target.dim();
        let k = target.k_steps();
        let c = target.cond_dim();
        let mut m = DraftStepMachine {
            k_window,
            adaptive,
            cond: cond.to_vec(),
            m_hat: vec![0.0; k.max(1) * d],
            y_hat: vec![0.0; k.max(1) * d],
            x0_eval: vec![0.0; k.max(1) * d],
            eval_in: vec![0.0; k.max(1) * d],
            eval_ts: vec![0.0; k.max(1)],
            eval_cond: vec![0.0; k.max(1) * c.max(1)],
            x0_draft: vec![0.0; d],
            m_buf: vec![0.0; d],
            z_buf: vec![0.0; d],
            v_buf: vec![0.0; d],
            y: noise.y_k.clone(),
            i_cur: k,
            phase: DraftPhase::Done,
            staged: false,
            noise,
            target,
            draft,
            stats: AsdStats::default(),
        };
        if m.i_cur > 0 {
            m.stats.iterations = 1; // entering the first iteration
            m.start_window()?;
        }
        Ok(m)
    }

    pub fn stats(&self) -> &AsdStats {
        &self.stats
    }

    pub fn into_stats(self) -> AsdStats {
        self.stats
    }

    /// Hand back the (possibly updated) window controller so callers
    /// can carry its acceptance estimate across samples.
    pub fn take_controller(&mut self) -> Option<WindowController> {
        self.adaptive.take()
    }

    /// Effective draft window for the current iteration.
    fn window_for(&self, i_cur: usize) -> usize {
        let want = match &self.adaptive {
            Some(ctl) => ctl.window(),
            None if self.k_window == 0 => i_cur,
            None => self.k_window,
        };
        want.min(i_cur).max(1)
    }

    /// Run the draft chain for the next window and stage its fused
    /// verify demand. Requires `i_cur > 0`.
    fn start_window(&mut self) -> Result<()> {
        let th = self.window_for(self.i_cur);
        self.speculate_draft(th)?;
        self.phase = DraftPhase::Verify { th };
        self.staged = false;
        Ok(())
    }

    /// Draft speculation chain: position kpos covers transition
    /// j -> j-1 with j = i_cur - kpos. The draft predicts x0hat at each
    /// chain point sequentially (cheap single-row calls); means and
    /// proposed points use the *target's* schedule, so the GRS compares
    /// same-variance Gaussians (Theorem 12's setting).
    fn speculate_draft(&mut self, th: usize) -> Result<()> {
        let d = self.target.dim();
        let i_cur = self.i_cur;
        let model = self.target.clone();
        let sched = model.schedule();
        let (c1, c2, sigma) = (&sched.c1, &sched.c2, &sched.sigma);
        for kpos in 0..th {
            let j = i_cur - kpos;
            let row = j - 1;
            {
                let y_base: &[f64] = if kpos == 0 {
                    &self.y
                } else {
                    &self.y_hat[(kpos - 1) * d..kpos * d]
                };
                self.draft.denoise_one(y_base, j, &self.cond,
                                       &mut self.x0_draft)?;
            }
            self.stats.draft_calls += 1;
            let (head, tail_buf) = self.y_hat.split_at_mut(kpos * d);
            let y_base: &[f64] = if kpos == 0 {
                &self.y
            } else {
                &head[(kpos - 1) * d..kpos * d]
            };
            let m_slice = &mut self.m_hat[kpos * d..(kpos + 1) * d];
            lincomb_into(m_slice, c1[row], &self.x0_draft, c2[row], y_base);
            let xi = self.noise.xi_row(row, d);
            let y_slice = &mut tail_buf[..d];
            for i in 0..d {
                y_slice[i] = m_slice[i] + sigma[row] * xi[i];
            }
        }
        Ok(())
    }

    /// Verifier scan: sequential GRS over the window, every position
    /// checked against the target's x0hat (no Lemma 13 shortcut at
    /// position 0 — the draft's mean differs from the target's there
    /// too). An accepted z bit-equals the proposed y_hat point, so the
    /// chain base stays valid; the first reject yields the
    /// reflection-coupled exact sample and discards the suffix.
    fn scan(&mut self, th: usize) {
        let d = self.target.dim();
        let model = self.target.clone();
        let sched = model.schedule();
        let (c1, c2, sigma) = (&sched.c1, &sched.c2, &sched.sigma);
        let mut advanced = 0usize;
        let mut win_accepted = 0usize;
        let mut win_rejected = 0usize;
        for kpos in 0..th {
            let j = self.i_cur - kpos; // transition j -> j-1
            let row = j - 1;
            let y_base: &[f64] = if kpos == 0 {
                &self.y
            } else {
                &self.y_hat[(kpos - 1) * d..kpos * d]
            };
            // target mean: c1 x0hat_target + c2 y_base
            lincomb_into(&mut self.m_buf, c1[row],
                         &self.x0_eval[kpos * d..(kpos + 1) * d],
                         c2[row], y_base);
            let accept = grs_native(
                self.noise.u[row],
                self.noise.xi_row(row, d),
                &self.m_hat[kpos * d..(kpos + 1) * d],
                &self.m_buf,
                sigma[row],
                &mut self.z_buf,
                &mut self.v_buf,
            );
            self.y.copy_from_slice(&self.z_buf);
            advanced += 1;
            if accept {
                win_accepted += 1;
            } else {
                win_rejected += 1;
                break;
            }
        }
        self.i_cur -= advanced;
        self.stats.accepted += win_accepted;
        self.stats.rejected += win_rejected;
        if let Some(ctl) = &mut self.adaptive {
            ctl.observe(win_accepted, win_rejected);
        }
    }

    /// Write the current Verify demand's rows into arbitrary target
    /// slices (sized exactly `th`): slot 0 is the current state at
    /// `i_cur`, slot s >= 1 the draft-proposed point at `i_cur - s`.
    fn write_verify_rows(&self, th: usize, ys: &mut [f64], ts: &mut [f64],
                         cond: &mut [f64]) {
        let d = self.target.dim();
        ys[..d].copy_from_slice(&self.y);
        ts[0] = self.i_cur as f64;
        for slot in 1..th {
            ys[slot * d..(slot + 1) * d]
                .copy_from_slice(&self.y_hat[(slot - 1) * d..slot * d]);
            ts[slot] = (self.i_cur - slot) as f64;
        }
        let c_dim = self.target.cond_dim();
        if c_dim > 0 {
            for slot in 0..th {
                cond[slot * c_dim..(slot + 1) * c_dim]
                    .copy_from_slice(&self.cond);
            }
        }
    }

    /// Compatibility staging for the slice-based `poll`.
    fn stage_verify(&mut self) {
        if let DraftPhase::Verify { th } = self.phase {
            let mut ys = std::mem::take(&mut self.eval_in);
            let mut ts = std::mem::take(&mut self.eval_ts);
            let mut cond = std::mem::take(&mut self.eval_cond);
            let d = self.target.dim();
            let c_dim = self.target.cond_dim();
            self.write_verify_rows(th, &mut ys[..th * d], &mut ts[..th],
                                   &mut cond[..th * c_dim]);
            self.eval_in = ys;
            self.eval_ts = ts;
            self.eval_cond = cond;
            self.staged = true;
        }
    }
}

impl StepSampler for DraftStepMachine {
    fn poll(&mut self) -> Result<SamplerPoll<'_>> {
        if matches!(self.phase, DraftPhase::Verify { .. }) && !self.staged {
            self.stage_verify();
        }
        let d = self.target.dim();
        let c_dim = self.target.cond_dim();
        match self.phase {
            DraftPhase::Done => Ok(SamplerPoll::Done(&self.y)),
            DraftPhase::Verify { th } => {
                Ok(SamplerPoll::Demand(DenoiseDemand {
                    ys: &self.eval_in[..th * d],
                    ts: &self.eval_ts[..th],
                    cond: &self.eval_cond[..th * c_dim],
                    n: th,
                }))
            }
        }
    }

    /// Arena path: the verify window is written straight from the
    /// draft chain into the arena's reserved row range.
    fn poll_into(&mut self, arena: &mut RoundArena)
                 -> Result<Option<ArenaSpan>> {
        match self.phase {
            DraftPhase::Done => Ok(None),
            DraftPhase::Verify { th } => {
                let (span, rows) = arena.reserve(th);
                self.write_verify_rows(th, rows.ys, rows.ts, rows.cond);
                Ok(Some(span))
            }
        }
    }

    fn resume(&mut self, x0: &[f64], exec: RoundExec) -> Result<()> {
        let d = self.target.dim();
        match self.phase {
            DraftPhase::Done => anyhow::bail!("resume after Done"),
            DraftPhase::Verify { th } => {
                anyhow::ensure!(x0.len() == th * d,
                                "verify rows length {} != {}", x0.len(),
                                th * d);
                self.x0_eval[..th * d].copy_from_slice(x0);
                self.stats.model_calls += th;
                self.stats.parallel_rounds += 1;
                self.stats.round_batches.push(th);
                self.stats.round_shards.push(exec.shards);
                self.stats.round_latency_s.push(exec.latency_s);
                self.scan(th);
                if self.i_cur == 0 {
                    self.phase = DraftPhase::Done;
                    Ok(())
                } else {
                    self.stats.iterations += 1;
                    self.start_window()
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ddpm::SequentialSampler;
    use crate::model::{Gmm, GmmDdpmOracle};

    fn perturbed_oracle(base: &Gmm, k: usize, eps: f64)
                        -> Arc<GmmDdpmOracle> {
        let comps = base.weights.len();
        let means: Vec<Vec<f64>> = (0..comps)
            .map(|c| {
                base.mean_of(c)
                    .iter()
                    .enumerate()
                    .map(|(i, &v)| v + eps * if i % 2 == 0 { 1.0 } else { -1.0 })
                    .collect()
            })
            .collect();
        let gmm = Gmm::new(means, base.sigmas.clone(),
                           base.weights.clone());
        GmmDdpmOracle::new(gmm, k, false)
    }

    #[test]
    fn identical_draft_never_rejects_and_matches_sequential_bits() {
        // draft == target => v = 0 at every position (Lemma 13): every
        // window fully accepts and the trajectory IS the sequential
        // DDPM trajectory on the same Philox streams, bit for bit.
        let k = 40;
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), k, false);
        let seq = SequentialSampler::new(oracle.clone());
        let mut e = DraftEngine::new(oracle.clone(), oracle,
                                     DraftConfig { k: 8,
                                                   ..Default::default() });
        for seed in 0..6 {
            let out = e.sample(seed).unwrap();
            assert_eq!(out.stats.rejected, 0, "seed {seed}");
            assert_eq!(out.stats.accepted, k);
            assert_eq!(out.stats.parallel_rounds, k / 8);
            assert_eq!(out.stats.model_calls, k);
            assert_eq!(out.stats.draft_calls, k);
            let (s, _) = seq.sample(seed, &[]).unwrap();
            let bits = |v: &[f64]| -> Vec<u64> {
                v.iter().map(|x| x.to_bits()).collect()
            };
            assert_eq!(bits(&out.y0), bits(&s), "seed {seed}");
        }
    }

    #[test]
    fn all_transitions_consumed_once() {
        let k = 60;
        let gmm = Gmm::circle_2d();
        let target = GmmDdpmOracle::new(gmm.clone(), k, false);
        let draft = perturbed_oracle(&gmm, k, 0.05);
        let mut e = DraftEngine::new(target, draft, DraftConfig {
            k: 8,
            ..Default::default()
        });
        for seed in 0..8 {
            let out = e.sample(seed).unwrap();
            assert_eq!(out.stats.accepted + out.stats.rejected, k,
                       "seed {seed}");
            // every proposed row was verified in a fused round, and the
            // draft chain priced every proposal
            assert_eq!(out.stats.model_calls, out.stats.draft_calls);
            let sum: usize = out.stats.round_batches.iter().sum();
            assert_eq!(sum, out.stats.model_calls);
            assert_eq!(out.stats.round_batches.len(),
                       out.stats.parallel_rounds);
            assert_eq!(out.stats.round_shards.len(),
                       out.stats.parallel_rounds);
            // one fused round per iteration — no separate propose round
            assert_eq!(out.stats.parallel_rounds, out.stats.iterations);
        }
    }

    #[test]
    fn close_draft_beats_sequential_rounds() {
        let k = 80;
        let gmm = Gmm::circle_2d();
        let target = GmmDdpmOracle::new(gmm.clone(), k, false);
        let draft = perturbed_oracle(&gmm, k, 0.02);
        let mut e = DraftEngine::new(target, draft, DraftConfig {
            k: 8,
            ..Default::default()
        });
        let mut rounds = 0usize;
        for seed in 0..6 {
            rounds += e.sample(seed).unwrap().stats.parallel_rounds;
        }
        let mean = rounds as f64 / 6.0;
        assert!(mean < k as f64 / 3.0,
                "draft-SD rounds {mean} not well below K={k}");
    }

    #[test]
    fn distribution_matches_sequential() {
        let k = 60;
        let gmm = Gmm::circle_2d();
        let target = GmmDdpmOracle::new(gmm.clone(), k, false);
        let seq = SequentialSampler::new(target.clone());
        let draft = perturbed_oracle(&gmm, k, 0.15);
        let mut e = DraftEngine::new(target, draft,
                                     DraftConfig { k: 6,
                                                   ..Default::default() });
        let n = 150;
        let mut r_seq = 0.0;
        let mut r_dsd = 0.0;
        let mut rejected = 0usize;
        for seed in 0..n {
            let (s, _) = seq.sample(seed, &[]).unwrap();
            r_seq += (s[0] * s[0] + s[1] * s[1]).sqrt();
            let out = e.sample(10_000 + seed).unwrap();
            rejected += out.stats.rejected;
            let a = out.y0;
            r_dsd += (a[0] * a[0] + a[1] * a[1]).sqrt();
        }
        // the draft is visibly wrong (it must actually reject) yet the
        // corrected marginal stays on the target
        assert!(rejected > 0, "perturbed draft never rejected");
        let (r_seq, r_dsd) = (r_seq / n as f64, r_dsd / n as f64);
        assert!((r_seq - r_dsd).abs() < 0.08,
                "radius mismatch: seq {r_seq} vs draft-sd {r_dsd}");
        assert!((r_dsd - 1.5).abs() < 0.1);
    }

    #[test]
    fn adaptive_controller_drives_the_window() {
        let k = 60;
        let gmm = Gmm::circle_2d();
        let target = GmmDdpmOracle::new(gmm.clone(), k, false);
        let draft = perturbed_oracle(&gmm, k, 0.05);
        let mut e = DraftEngine::new(target, draft, DraftConfig {
            k: 8,
            adaptive: Some(WindowController::new(2, 24)),
            ..Default::default()
        });
        let mut last_estimate = 0.0;
        for seed in 0..5 {
            let out = e.sample(seed).unwrap();
            assert_eq!(out.stats.accepted + out.stats.rejected, k);
            let ctl = e.config.adaptive.as_ref()
                .expect("controller must survive the sample");
            last_estimate = ctl.acceptance_estimate();
        }
        // a close draft must have pushed the estimate above the prior
        assert!(last_estimate > 0.7, "estimate {last_estimate}");
    }

    #[test]
    fn mismatched_draft_is_rejected_at_construction() {
        let target = GmmDdpmOracle::new(Gmm::circle_2d(), 40, false);
        let wrong_k = GmmDdpmOracle::new(Gmm::circle_2d(), 20, false);
        let noise = NoiseStreams::draw(1, 0, 40, 2);
        assert!(DraftStepMachine::new(target.clone(), wrong_k, 8, None,
                                      noise.clone(), &[]).is_err());
        let wrong_d = GmmDdpmOracle::new(Gmm::random(3, 4, 1.0, 7), 40,
                                         false);
        assert!(DraftStepMachine::new(target, wrong_d, 8, None, noise,
                                      &[]).is_err());
    }
}
