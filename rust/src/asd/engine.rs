//! The ASD engine: Algorithm 1 (+ Verifier, Algorithm 2) in the
//! DDPM-native x0-prediction form (paper Remark 2).
//!
//! Executable-spec parity: python/compile/asd_ref.py implements the same
//! loop; the integration tests replay its golden traces through this
//! engine over the HLO model and demand matching outputs and stats.
//!
//! Round accounting (what Theorem 4 bounds): every iteration spends one
//! parallel round on the proposal call (unless chained from the previous
//! verify round via `eval_tail`) and one parallel round on the batched
//! verification calls. `round_batches` records the batch size of every
//! round so the experiment layer can model multi-worker wall-clock
//! (DESIGN.md §3).

use std::sync::Arc;

use anyhow::Result;

use crate::asd::grs::grs_native;
use crate::ddpm::NoiseStreams;
use crate::math::vec_ops::lincomb_into;
use crate::model::{DenoiseModel, ParallelModel};
use crate::runtime::pool::PoolConfig;
use crate::runtime::HloKernels;
use crate::sampler::{ArenaSpan, DenoiseDemand, RoundArena, RoundExec,
                     SamplerPoll, StepSampler};

/// Which implementation computes the speculation chain and the GRS.
/// The denoiser itself is always whatever `DenoiseModel` was given.
#[derive(Clone)]
pub enum KernelBackend {
    /// Rust-native (default: PJRT dispatch overhead dominates these
    /// O(theta*d) ops on the CPU testbed).
    Native,
    /// The AOT Pallas kernels through PJRT (full three-layer path;
    /// parity-tested against Native).
    Hlo(HloKernels),
}

#[derive(Clone)]
pub struct AsdConfig {
    /// Speculation length; 0 = ASD-infinity (speculate to the end).
    pub theta: usize,
    /// Also evaluate the chain's final point during verification so a
    /// fully-accepted window chains into the next proposal for free.
    pub eval_tail: bool,
    pub backend: KernelBackend,
    /// Sharded execution of batched verify rounds on the global worker
    /// pool; `pool_size <= 1` (default) keeps rounds inline. For
    /// row-independent native models (analytic oracles, `NativeMlp`)
    /// sharding never changes sampled bits — only measured round
    /// latency. HLO-backed models pad batches to compiled sizes, so
    /// sharding may perturb their f32 outputs within artifact tolerance
    /// (see `model::parallel`).
    pub pool: PoolConfig,
}

impl Default for AsdConfig {
    fn default() -> AsdConfig {
        AsdConfig {
            theta: 8,
            eval_tail: true,
            backend: KernelBackend::Native,
            pool: PoolConfig::default(),
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct AsdStats {
    /// total denoiser evaluations (sequential DDPM needs K)
    pub model_calls: usize,
    /// rounds of (possibly batched) denoiser calls — the Thm 4 quantity
    pub parallel_rounds: usize,
    pub iterations: usize,
    pub accepted: usize,
    pub rejected: usize,
    /// draft-model evaluations (draft-SD only; 0 for every other
    /// sampler) — the chain calls that never hit the round plane but
    /// must be priced by the Pareto bench
    pub draft_calls: usize,
    /// batch size of each parallel round (for the latency model)
    pub round_batches: Vec<usize>,
    /// shard occupancy of each parallel round (1 = ran inline; >1 =
    /// that many worker-pool shards executed the round concurrently)
    pub round_shards: Vec<usize>,
    /// measured wall-clock seconds of each parallel round's model calls
    pub round_latency_s: Vec<f64>,
}

impl AsdStats {
    /// Algorithmic speedup vs the K-round sequential sampler.
    pub fn algorithmic_speedup(&self, k: usize) -> f64 {
        k as f64 / self.parallel_rounds.max(1) as f64
    }

    pub fn acceptance_rate(&self) -> f64 {
        let total = self.accepted + self.rejected;
        if total == 0 { 1.0 } else { self.accepted as f64 / total as f64 }
    }

    /// Mean measured round latency over all rounds (seconds).
    pub fn mean_round_latency_s(&self) -> f64 {
        if self.round_latency_s.is_empty() {
            return 0.0;
        }
        self.round_latency_s.iter().sum::<f64>()
            / self.round_latency_s.len() as f64
    }

    /// Mean measured latency over batched (verify) rounds only —
    /// the rounds sharding can actually speed up (seconds).
    pub fn mean_batched_round_latency_s(&self) -> f64 {
        let mut total = 0.0;
        let mut n = 0usize;
        for (i, &lat) in self.round_latency_s.iter().enumerate() {
            if self.round_batches.get(i).copied().unwrap_or(1) > 1 {
                total += lat;
                n += 1;
            }
        }
        if n == 0 { 0.0 } else { total / n as f64 }
    }

    /// Mean shard occupancy across rounds (1.0 = fully serial).
    pub fn mean_occupancy(&self) -> f64 {
        if self.round_shards.is_empty() {
            return 1.0;
        }
        self.round_shards.iter().sum::<usize>() as f64
            / self.round_shards.len() as f64
    }
}

#[derive(Debug, Clone)]
pub struct AsdOutput {
    pub y0: Vec<f64>,
    pub stats: AsdStats,
    pub wallclock_s: f64,
}

/// The ASD engine — a thin [`crate::sampler::drive`] loop over
/// [`AsdStepMachine`]. Public API (`sample`, `sample_cond`,
/// `sample_with_noise`) and outputs are unchanged from the closed-loop
/// implementation it replaced; the machine form exists so the serving
/// coordinator can fuse many requests' rounds into one batched call.
pub struct AsdEngine {
    pub model: Arc<dyn DenoiseModel>,
    pub config: AsdConfig,
}

impl AsdEngine {
    pub fn new(model: Arc<dyn DenoiseModel>, config: AsdConfig) -> AsdEngine {
        // sharded verify rounds on the one global pool (no-op wrap when
        // pool_size <= 1); sharding is bit-transparent to the sampler
        let model = ParallelModel::wrap(model, config.pool);
        AsdEngine { model, config }
    }

    /// Sample with a fresh Philox stream for `seed`.
    pub fn sample(&mut self, seed: u64) -> Result<AsdOutput> {
        let noise = NoiseStreams::draw(seed, 0, self.model.k_steps(),
                                       self.model.dim());
        self.sample_owned_noise(noise, &[])
    }

    pub fn sample_cond(&mut self, seed: u64, cond: &[f64]) -> Result<AsdOutput> {
        let noise = NoiseStreams::draw(seed, 0, self.model.k_steps(),
                                       self.model.dim());
        self.sample_owned_noise(noise, cond)
    }

    /// Algorithm 1 with explicit noise streams (golden-trace parity).
    /// Clones the streams for the machine; the `sample`/`sample_cond`
    /// paths hand theirs over without a copy.
    pub fn sample_with_noise(&mut self, noise: &NoiseStreams, cond: &[f64])
                             -> Result<AsdOutput> {
        self.sample_owned_noise(noise.clone(), cond)
    }

    fn sample_owned_noise(&mut self, noise: NoiseStreams, cond: &[f64])
                          -> Result<AsdOutput> {
        let t_start = std::time::Instant::now();
        let mut machine = AsdStepMachine::new(
            self.model.clone(),
            self.config.theta,
            self.config.eval_tail,
            self.config.backend.clone(),
            noise,
            cond,
        )?;
        let y0 = crate::sampler::drive(&mut machine, &self.model,
                                       self.config.pool)?;
        Ok(AsdOutput {
            y0,
            stats: machine.into_stats(),
            wallclock_s: t_start.elapsed().as_secs_f64(),
        })
    }
}

/// Where the ASD state machine is between rounds.
enum AsdPhase {
    /// demand one proposal row: x0hat at (y, i_cur) — Alg 1 line 6
    Propose,
    /// demand `n_eval` verify rows for the speculated chain
    Verify { th: usize, tail: bool, n_eval: usize },
    Done,
}

/// Algorithm 1 (+ Verifier, Algorithm 2) as a poll/resume state
/// machine. Each demand is one parallel round: a single proposal row,
/// or the batched verification of a speculated window. All sampler
/// math (speculation chain, GRS scan) runs inside `resume`; the machine
/// never calls the model. Demands answered row-for-row reproduce the
/// closed-loop engine bit-for-bit — regardless of whether the executor
/// evaluates them solo or fused with other requests' rows (native
/// models are row-independent; see `model::parallel`).
pub struct AsdStepMachine {
    model: Arc<dyn DenoiseModel>,
    theta: usize,
    eval_tail: bool,
    backend: KernelBackend,
    noise: NoiseStreams,
    cond: Vec<f64>,
    // chain buffers (sized K x d, as the closed-loop engine had)
    m_hat: Vec<f64>,
    y_hat: Vec<f64>,
    x0_eval: Vec<f64>,
    eval_in: Vec<f64>,
    eval_ts: Vec<f64>,
    eval_cond: Vec<f64>,
    m_buf: Vec<f64>,
    z_buf: Vec<f64>,
    v_buf: Vec<f64>,
    // loop state
    y: Vec<f64>,
    x0a: Vec<f64>,
    i_cur: usize,
    have_x0: bool,
    /// staged proposal timestep (len 1)
    prop_ts: Vec<f64>,
    phase: AsdPhase,
    /// whether the internal eval buffers hold the current Verify
    /// demand. Staging is deferred to `poll` so the arena path
    /// (`poll_into`) can write the verify rows straight from `y_hat`
    /// into the arena without ever touching the eval buffers.
    staged: bool,
    stats: AsdStats,
}

impl AsdStepMachine {
    pub fn new(model: Arc<dyn DenoiseModel>, theta: usize, eval_tail: bool,
               backend: KernelBackend, noise: NoiseStreams, cond: &[f64])
               -> Result<AsdStepMachine> {
        anyhow::ensure!(cond.len() == model.cond_dim(),
                        "conditioning length {} != cond_dim {}",
                        cond.len(), model.cond_dim());
        let d = model.dim();
        let k = model.k_steps();
        let c = model.cond_dim();
        let mut m = AsdStepMachine {
            theta,
            eval_tail,
            backend,
            cond: cond.to_vec(),
            m_hat: vec![0.0; k * d],
            y_hat: vec![0.0; k * d],
            x0_eval: vec![0.0; (k + 1) * d],
            eval_in: vec![0.0; (k + 1) * d],
            eval_ts: vec![0.0; k + 1],
            eval_cond: vec![0.0; (k + 1) * c.max(1)],
            m_buf: vec![0.0; d],
            z_buf: vec![0.0; d],
            v_buf: vec![0.0; d],
            y: noise.y_k.clone(),
            x0a: vec![0.0; d],
            i_cur: k,
            have_x0: false,
            prop_ts: vec![k as f64],
            phase: if k == 0 { AsdPhase::Done } else { AsdPhase::Propose },
            staged: false,
            noise,
            model,
            stats: AsdStats::default(),
        };
        if m.i_cur > 0 {
            m.stats.iterations = 1; // entering the first iteration
        }
        Ok(m)
    }

    pub fn stats(&self) -> &AsdStats {
        &self.stats
    }

    pub fn into_stats(self) -> AsdStats {
        self.stats
    }

    /// Effective speculation cap per iteration.
    fn theta_for(&self, i_cur: usize) -> usize {
        let want = if self.theta == 0 { i_cur } else { self.theta };
        let capped = match &self.backend {
            KernelBackend::Hlo(k) => want.min(k.t_steps),
            KernelBackend::Native => want,
        };
        capped.min(i_cur).max(1)
    }

    /// With x0a valid at (y, i_cur): speculate, then either stage the
    /// verify demand or (when the window needs no verify rows) run the
    /// scan immediately and fall through to the next iteration.
    fn advance_from_x0(&mut self) -> Result<()> {
        loop {
            let th = self.theta_for(self.i_cur);
            self.run_speculate(th)?;

            // positions 1..th-1 evaluate x0hat at the proposed points
            // (position 0 reuses x0a — Lemma 13); `eval_tail` adds the
            // final chain point so an all-accept window chains onward.
            // The demand rows themselves are written lazily — straight
            // into the executor's arena by `poll_into`, or into the
            // eval buffers by the compatibility `poll`.
            let tail = self.eval_tail && self.i_cur - th > 0 && th >= 1;
            let n_eval = (th - 1) + tail as usize;
            if n_eval > 0 {
                self.phase = AsdPhase::Verify { th, tail, n_eval };
                self.staged = false;
                return Ok(());
            }

            // zero-eval window (th == 1, no tail): scan right away
            self.scan(th, false);
            if !self.next_iteration() {
                return Ok(()); // Done or Propose staged
            }
            // have_x0 carried over (cannot actually happen without a
            // tail slot, but the loop keeps it structurally safe)
        }
    }

    /// Verifier scan (Alg 2): sequential GRS over the window.
    fn scan(&mut self, th: usize, tail: bool) {
        let d = self.model.dim();
        let model = self.model.clone();
        let sched = model.schedule();
        let (c1, c2, sigma) = (&sched.c1, &sched.c2, &sched.sigma);
        let mut advanced = 0usize;
        let mut tail_chained = false;
        for kpos in 0..th {
            let j = self.i_cur - kpos; // transition j -> j-1, schedule row j-1
            let row = j - 1;
            // target mean: c1 x0hat(y_base, j) + c2 y_base
            let x0_at: &[f64] = if kpos == 0 {
                &self.x0a
            } else {
                &self.x0_eval[(kpos - 1) * d..kpos * d]
            };
            let y_base: &[f64] = if kpos == 0 {
                &self.y
            } else {
                &self.y_hat[(kpos - 1) * d..kpos * d]
            };
            lincomb_into(&mut self.m_buf, c1[row], x0_at, c2[row], y_base);
            let accept = grs_native(
                self.noise.u[row],
                self.noise.xi_row(row, d),
                &self.m_hat[kpos * d..(kpos + 1) * d],
                &self.m_buf,
                sigma[row],
                &mut self.z_buf,
                &mut self.v_buf,
            );
            self.y.copy_from_slice(&self.z_buf);
            advanced += 1;
            if accept {
                self.stats.accepted += 1;
                if kpos == th - 1 && tail {
                    tail_chained = true;
                }
            } else {
                self.stats.rejected += 1;
                break;
            }
        }
        self.i_cur -= advanced;
        if tail_chained {
            // accepted tail: z == y_hat[th-1], whose x0hat is the last
            // verify slot — reuse it as the next proposal
            self.x0a.copy_from_slice(&self.x0_eval[(th - 1) * d..th * d]);
        }
        self.have_x0 = tail_chained;
    }

    /// After a scan: stage the next iteration. Returns `true` when the
    /// caller (`advance_from_x0`) should keep going because `x0a` is
    /// already valid for the new iteration.
    fn next_iteration(&mut self) -> bool {
        if self.i_cur == 0 {
            self.phase = AsdPhase::Done;
            return false;
        }
        self.stats.iterations += 1;
        if self.have_x0 {
            true
        } else {
            self.prop_ts[0] = self.i_cur as f64;
            self.phase = AsdPhase::Propose;
            false
        }
    }

    /// Write the current Verify demand's rows into arbitrary target
    /// slices (sized exactly `n_eval`): the arena's reserved row range
    /// or the internal eval buffers. Reads only the speculation chain
    /// — identical values either way.
    fn write_verify_rows(&self, th: usize, tail: bool, n_eval: usize,
                         ys: &mut [f64], ts: &mut [f64], cond: &mut [f64]) {
        let d = self.model.dim();
        for (slot, kpos) in (1..th).enumerate() {
            let j = self.i_cur - kpos; // transition j -> j-1
            ys[slot * d..(slot + 1) * d].copy_from_slice(
                &self.y_hat[(kpos - 1) * d..kpos * d]);
            ts[slot] = j as f64;
        }
        if tail {
            let slot = th - 1;
            ys[slot * d..(slot + 1) * d].copy_from_slice(
                &self.y_hat[(th - 1) * d..th * d]);
            ts[slot] = (self.i_cur - th) as f64;
        }
        let c_dim = self.model.cond_dim();
        if c_dim > 0 {
            for slot in 0..n_eval {
                cond[slot * c_dim..(slot + 1) * c_dim]
                    .copy_from_slice(&self.cond);
            }
        }
    }

    /// Compatibility staging for the slice-based `poll`: materialize
    /// the Verify demand in the internal eval buffers.
    fn stage_verify(&mut self) {
        if let AsdPhase::Verify { th, tail, n_eval } = self.phase {
            let mut ys = std::mem::take(&mut self.eval_in);
            let mut ts = std::mem::take(&mut self.eval_ts);
            let mut cond = std::mem::take(&mut self.eval_cond);
            let d = self.model.dim();
            let c_dim = self.model.cond_dim();
            self.write_verify_rows(th, tail, n_eval,
                                   &mut ys[..n_eval * d],
                                   &mut ts[..n_eval],
                                   &mut cond[..n_eval * c_dim]);
            self.eval_in = ys;
            self.eval_ts = ts;
            self.eval_cond = cond;
            self.staged = true;
        }
    }

    /// Speculation chain (Alg 1 lines 7-9; L1 kernel `speculate`):
    /// chain position k covers transition j -> j-1, j = i_cur - k.
    fn run_speculate(&mut self, th: usize) -> Result<()> {
        let d = self.model.dim();
        let i_cur = self.i_cur;
        let model = self.model.clone();
        let sched = model.schedule();
        let (c1, c2, sigma) = (&sched.c1, &sched.c2, &sched.sigma);
        match &self.backend {
            KernelBackend::Native => {
                // y_hat[k] = c1 x0a + c2 y_hat[k-1] + sigma xi
                for kpos in 0..th {
                    let row = i_cur - kpos - 1;
                    let (head, tail_buf) = self.y_hat.split_at_mut(kpos * d);
                    let y_prev: &[f64] = if kpos == 0 {
                        &self.y
                    } else {
                        &head[(kpos - 1) * d..kpos * d]
                    };
                    let m_slice = &mut self.m_hat[kpos * d..(kpos + 1) * d];
                    lincomb_into(m_slice, c1[row], &self.x0a, c2[row], y_prev);
                    let xi = self.noise.xi_row(row, d);
                    let y_slice = &mut tail_buf[..d];
                    for i in 0..d {
                        y_slice[i] = m_slice[i] + sigma[row] * xi[i];
                    }
                }
            }
            KernelBackend::Hlo(kernels) => {
                let mut c1v = Vec::with_capacity(th);
                let mut c2v = Vec::with_capacity(th);
                let mut sv = Vec::with_capacity(th);
                let mut xiv = Vec::with_capacity(th * d);
                for kpos in 0..th {
                    let row = i_cur - kpos - 1;
                    c1v.push(c1[row]);
                    c2v.push(c2[row]);
                    sv.push(sigma[row]);
                    xiv.extend_from_slice(self.noise.xi_row(row, d));
                }
                let (m_hat, y_hat) =
                    kernels.speculate(&self.y, &self.x0a, &c1v, &c2v, &sv,
                                      &xiv)?;
                self.m_hat[..th * d].copy_from_slice(&m_hat);
                self.y_hat[..th * d].copy_from_slice(&y_hat);
            }
        }
        Ok(())
    }
}

impl StepSampler for AsdStepMachine {
    fn poll(&mut self) -> Result<SamplerPoll<'_>> {
        if matches!(self.phase, AsdPhase::Verify { .. }) && !self.staged {
            self.stage_verify();
        }
        let d = self.model.dim();
        let c_dim = self.model.cond_dim();
        match self.phase {
            AsdPhase::Done => Ok(SamplerPoll::Done(&self.y)),
            AsdPhase::Propose => Ok(SamplerPoll::Demand(DenoiseDemand {
                ys: &self.y,
                ts: &self.prop_ts,
                cond: &self.cond,
                n: 1,
            })),
            AsdPhase::Verify { n_eval, .. } => {
                Ok(SamplerPoll::Demand(DenoiseDemand {
                    ys: &self.eval_in[..n_eval * d],
                    ts: &self.eval_ts[..n_eval],
                    cond: &self.eval_cond[..n_eval * c_dim],
                    n: n_eval,
                }))
            }
        }
    }

    /// Arena path: the proposal row or the whole verify window is
    /// written straight from the speculation chain into the arena's
    /// reserved row range — the eval staging buffers are bypassed
    /// entirely (no pack copy).
    fn poll_into(&mut self, arena: &mut RoundArena)
                 -> Result<Option<ArenaSpan>> {
        match self.phase {
            AsdPhase::Done => Ok(None),
            AsdPhase::Propose => {
                let (span, rows) = arena.reserve(1);
                rows.ys.copy_from_slice(&self.y);
                rows.ts[0] = self.prop_ts[0];
                rows.cond.copy_from_slice(&self.cond);
                Ok(Some(span))
            }
            AsdPhase::Verify { th, tail, n_eval } => {
                let (span, rows) = arena.reserve(n_eval);
                self.write_verify_rows(th, tail, n_eval, rows.ys, rows.ts,
                                       rows.cond);
                Ok(Some(span))
            }
        }
    }

    fn resume(&mut self, x0: &[f64], exec: RoundExec) -> Result<()> {
        let d = self.model.dim();
        match self.phase {
            AsdPhase::Done => anyhow::bail!("resume after Done"),
            AsdPhase::Propose => {
                anyhow::ensure!(x0.len() == d,
                                "proposal row length {} != d {d}", x0.len());
                self.x0a.copy_from_slice(x0);
                self.stats.model_calls += 1;
                self.stats.parallel_rounds += 1;
                self.stats.round_batches.push(1);
                self.stats.round_shards.push(exec.shards);
                self.stats.round_latency_s.push(exec.latency_s);
                self.advance_from_x0()
            }
            AsdPhase::Verify { th, tail, n_eval } => {
                anyhow::ensure!(x0.len() == n_eval * d,
                                "verify rows length {} != {}", x0.len(),
                                n_eval * d);
                self.x0_eval[..n_eval * d].copy_from_slice(x0);
                self.stats.model_calls += n_eval;
                self.stats.parallel_rounds += 1;
                self.stats.round_batches.push(n_eval);
                self.stats.round_shards.push(exec.shards);
                self.stats.round_latency_s.push(exec.latency_s);
                self.scan(th, tail);
                if self.next_iteration() {
                    // tail-chained: x0a already valid, keep advancing
                    self.advance_from_x0()
                } else {
                    Ok(())
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ddpm::SequentialSampler;
    use crate::model::{Gmm, GmmDdpmOracle};

    fn engine(theta: usize, k: usize) -> AsdEngine {
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), k, false);
        AsdEngine::new(oracle, AsdConfig { theta, ..Default::default() })
    }

    #[test]
    fn all_transitions_consumed_once() {
        let mut e = engine(8, 60);
        for seed in 0..10 {
            let out = e.sample(seed).unwrap();
            assert_eq!(out.stats.accepted + out.stats.rejected, 60);
            // at least one accept per iteration (Lemma 13)
            assert!(out.stats.accepted >= out.stats.iterations);
        }
    }

    #[test]
    fn theta1_never_rejects() {
        let mut e = engine(1, 40);
        let out = e.sample(3).unwrap();
        assert_eq!(out.stats.iterations, 40);
        assert_eq!(out.stats.rejected, 0);
    }

    #[test]
    fn asd_inf_beats_sequential_rounds() {
        let mut e = engine(0, 100);
        let mut total_rounds = 0;
        for seed in 0..5 {
            total_rounds += e.sample(seed).unwrap().stats.parallel_rounds;
        }
        assert!((total_rounds as f64 / 5.0) < 75.0,
                "ASD-inf rounds {} not < 75", total_rounds as f64 / 5.0);
    }

    #[test]
    fn distribution_matches_sequential() {
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 60, false);
        let seq = SequentialSampler::new(oracle.clone());
        let mut e = AsdEngine::new(oracle, AsdConfig { theta: 8, ..Default::default() });
        let n = 150;
        let mut r_seq = 0.0;
        let mut r_asd = 0.0;
        for seed in 0..n {
            let (s, _) = seq.sample(seed, &[]).unwrap();
            r_seq += (s[0] * s[0] + s[1] * s[1]).sqrt();
            let a = e.sample(10_000 + seed).unwrap().y0;
            r_asd += (a[0] * a[0] + a[1] * a[1]).sqrt();
        }
        let (r_seq, r_asd) = (r_seq / n as f64, r_asd / n as f64);
        assert!((r_seq - r_asd).abs() < 0.08,
                "radius mismatch: seq {r_seq} vs asd {r_asd}");
        assert!((r_asd - 1.5).abs() < 0.1);
    }

    #[test]
    fn rounds_decrease_with_theta() {
        let mut by_theta = vec![];
        for theta in [1usize, 4, 16] {
            let mut e = engine(theta, 80);
            let mut rounds = 0;
            for seed in 0..6 {
                rounds += e.sample(seed).unwrap().stats.parallel_rounds;
            }
            by_theta.push(rounds as f64 / 6.0);
        }
        assert!(by_theta[1] < by_theta[0]);
        assert!(by_theta[2] <= by_theta[1] + 2.0);
    }

    #[test]
    fn round_batches_sum_to_model_calls() {
        let mut e = engine(6, 60);
        let out = e.sample(9).unwrap();
        let sum: usize = out.stats.round_batches.iter().sum();
        assert_eq!(sum, out.stats.model_calls);
        assert_eq!(out.stats.round_batches.len(), out.stats.parallel_rounds);
    }

    #[test]
    fn round_stats_vectors_stay_aligned() {
        let mut e = engine(6, 60);
        let out = e.sample(11).unwrap();
        let st = &out.stats;
        assert_eq!(st.round_shards.len(), st.parallel_rounds);
        assert_eq!(st.round_latency_s.len(), st.parallel_rounds);
        // serial config: every round runs inline
        assert!(st.round_shards.iter().all(|&s| s == 1));
        assert!(st.round_latency_s.iter().all(|&l| l >= 0.0));
        assert!(st.mean_round_latency_s() >= 0.0);
        assert_eq!(st.mean_occupancy(), 1.0);
    }

    #[test]
    fn sharded_engine_same_bits_and_occupancy_reported() {
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 60, false);
        let mut serial = AsdEngine::new(
            oracle.clone(),
            AsdConfig { theta: 8, ..Default::default() });
        let mut sharded = AsdEngine::new(
            oracle,
            AsdConfig {
                theta: 8,
                pool: crate::runtime::pool::PoolConfig {
                    pool_size: 4,
                    shard_min: 1,
                },
                ..Default::default()
            });
        for seed in 0..4 {
            let a = serial.sample(seed).unwrap();
            let b = sharded.sample(seed).unwrap();
            let bits = |v: &[f64]| -> Vec<u64> {
                v.iter().map(|x| x.to_bits()).collect()
            };
            assert_eq!(bits(&a.y0), bits(&b.y0), "seed {seed}");
            assert_eq!(a.stats.accepted, b.stats.accepted);
            assert_eq!(a.stats.parallel_rounds, b.stats.parallel_rounds);
            // batched verify rounds report multi-shard occupancy
            assert!(b.stats.mean_occupancy() > 1.0,
                    "occupancy {}", b.stats.mean_occupancy());
        }
    }
}
