//! Autospeculative Decoding — the paper's core contribution.
//!
//! * [`grs`] — Gaussian Rejection Sampler (Algorithm 3, native path).
//! * [`engine`] — the DDPM-native ASD loop (Algorithm 1 + Verifier
//!   Algorithm 2), mirroring python/compile/asd_ref.py.
//! * [`sl_engine`] — SL-native ASD + sequential Euler over a
//!   [`crate::model::GmmSlOracle`] (theory benches, Thm 4).
//! * [`draft`] — draft-model speculative sampling: a cheap draft
//!   proposes the window sequentially, the target verifies it in one
//!   fused round through the same GRS (exact by Theorem 12).
//! * [`adaptive`] — extension: online speculation-window controller
//!   driven by the observed acceptance rate (shared by ASD and
//!   draft-SD).

pub mod adaptive;
pub mod draft;
pub mod engine;
pub mod grs;
pub mod sl_engine;

pub use adaptive::{AdaptiveTheta, WindowController};
pub use draft::{DraftConfig, DraftEngine, DraftStepMachine};
pub use engine::{AsdConfig, AsdEngine, AsdOutput, AsdStats, AsdStepMachine,
                 KernelBackend};
pub use grs::grs_native;
pub use sl_engine::{SlAsd, SlAsdStats, SlAsdStepMachine, SlSequential};
