//! SL-native sampling: Euler discretization of Stochastic Localization
//! (paper Eq. 4/5) plus ASD over it — the setting of Theorems 4/15.
//!
//! Uses the analytic GMM oracle `m(t, y)` so the Thm-4 scaling benches
//! measure the *algorithm*, not network error. Target/proposal of the
//! Euler step on grid {t_k}:
//!
//!   target:    y_{k+1} = y_k + eta_k m(t_k, y_k)   + sqrt(eta_k) xi
//!   proposal:  y_{k+1} = y_k + eta_k m(t_a, y_a)   + sqrt(eta_k) xi
//!
//! Both Gaussians share variance eta_k I => GRS applies verbatim.


use crate::asd::grs::grs_native;
use crate::math::vec_ops::axpy_into;
use crate::model::GmmSlOracle;
use crate::rng::Philox;
use crate::runtime::pool::PoolConfig;
use crate::sampler::{ArenaSpan, DenoiseDemand, RoundArena, RoundExec,
                     SamplerPoll, StepSampler};
use crate::schedule::SlGrid;

pub struct SlSequential<'a> {
    pub oracle: &'a GmmSlOracle,
    pub grid: &'a SlGrid,
}

impl<'a> SlSequential<'a> {
    /// Returns y_{t_K} / t_K (the localized sample, Law -> mu as t grows).
    pub fn sample(&self, seed: u64) -> Vec<f64> {
        let d = self.oracle.gmm.d;
        let k = self.grid.k_steps();
        let mut rng = Philox::new(seed, 1);
        let mut y = vec![0.0; d];
        let mut m = vec![0.0; d];
        for step in 0..k {
            let t = self.grid.times[step];
            let eta = self.grid.etas[step];
            self.oracle.gmm.sl_posterior_mean(&y, t, &mut m);
            let se = eta.sqrt();
            for i in 0..d {
                y[i] += eta * m[i] + se * rng.normal();
            }
        }
        let t_final = *self.grid.times.last().unwrap();
        y.iter().map(|v| v / t_final).collect()
    }
}

#[derive(Debug, Clone, Default)]
pub struct SlAsdStats {
    pub oracle_calls: usize,
    pub parallel_rounds: usize,
    pub iterations: usize,
    pub accepted: usize,
    pub rejected: usize,
}

pub struct SlAsd<'a> {
    pub oracle: &'a GmmSlOracle,
    pub grid: &'a SlGrid,
    /// speculation length; 0 = infinity
    pub theta: usize,
}

impl<'a> SlAsd<'a> {
    /// ASD over the SL Euler chain. Exactly Algorithm 1 with
    /// b(eta, y) = y + eta m(t, y) and sigma_k = sqrt(eta_k). A thin
    /// [`crate::sampler::drive_with`] loop over [`SlAsdStepMachine`],
    /// evaluating each demanded row against the analytic oracle.
    pub fn sample(&self, seed: u64) -> (Vec<f64>, SlAsdStats) {
        let d = self.oracle.gmm.d;
        let mut machine = SlAsdStepMachine::new(self.grid, self.theta,
                                               d, seed);
        let gmm = &self.oracle.gmm;
        let y0 = crate::sampler::drive_with(
            &mut machine, d, 0, PoolConfig::default(),
            |ys, ts, _cond, n, out| {
                for r in 0..n {
                    gmm.sl_posterior_mean(&ys[r * d..(r + 1) * d], ts[r],
                                          &mut out[r * d..(r + 1) * d]);
                }
                Ok(())
            })
            .expect("SL oracle evaluation is infallible");
        (y0, machine.into_stats())
    }
}

/// Where the SL-ASD state machine is between rounds.
enum SlPhase {
    /// demand the drift m(t_a, y_a) — one row
    Propose,
    /// demand drifts at the th-1 proposed chain points
    Verify { th: usize },
    Done,
}

/// SL-native ASD as a poll/resume state machine (same shape as the
/// DDPM [`crate::asd::engine::AsdStepMachine`]): demands are drift
/// evaluations m(t, y) instead of x0hat rows, with `ts` carrying the
/// continuous localization times. Bit-identical to the closed loop it
/// replaced.
pub struct SlAsdStepMachine {
    times: Vec<f64>,
    etas: Vec<f64>,
    theta: usize,
    d: usize,
    // pre-drawn per-step noise (same contract as the DDPM engine)
    xi: Vec<f64>,
    u: Vec<f64>,
    y: Vec<f64>,
    a: usize,
    m_a: Vec<f64>,
    m_hat: Vec<f64>,
    y_hat: Vec<f64>,
    evals: Vec<f64>,
    m_buf: Vec<f64>,
    z_buf: Vec<f64>,
    v_buf: Vec<f64>,
    /// staged proposal time (len 1)
    prop_ts: Vec<f64>,
    /// staged verify times (len th-1)
    eval_ts: Vec<f64>,
    /// the localized sample y_{t_K} / t_K, filled at Done
    y0: Vec<f64>,
    phase: SlPhase,
    stats: SlAsdStats,
}

impl SlAsdStepMachine {
    pub fn new(grid: &SlGrid, theta: usize, d: usize, seed: u64)
               -> SlAsdStepMachine {
        let k = grid.k_steps();
        let mut rng = Philox::new(seed, 1);
        let xi: Vec<f64> = (0..k * d).map(|_| rng.normal()).collect();
        let u: Vec<f64> = (0..k).map(|_| rng.uniform()).collect();
        let mut m = SlAsdStepMachine {
            times: grid.times.clone(),
            etas: grid.etas.clone(),
            theta,
            d,
            xi,
            u,
            y: vec![0.0; d],
            a: 0,
            m_a: vec![0.0; d],
            m_hat: vec![0.0; k * d],
            y_hat: vec![0.0; k * d],
            evals: vec![0.0; k * d],
            m_buf: vec![0.0; d],
            z_buf: vec![0.0; d],
            v_buf: vec![0.0; d],
            prop_ts: vec![0.0],
            eval_ts: vec![0.0; k],
            y0: vec![0.0; d],
            phase: if k == 0 { SlPhase::Done } else { SlPhase::Propose },
            stats: SlAsdStats::default(),
        };
        if k > 0 {
            m.stats.iterations = 1; // entering the first iteration
            m.prop_ts[0] = m.times[0];
        } else {
            m.finalize();
        }
        m
    }

    pub fn stats(&self) -> &SlAsdStats {
        &self.stats
    }

    pub fn into_stats(self) -> SlAsdStats {
        self.stats
    }

    fn k_steps(&self) -> usize {
        self.times.len()
    }

    fn th(&self) -> usize {
        let k = self.k_steps();
        let want = if self.theta == 0 { k - self.a } else { self.theta };
        want.min(k - self.a).max(1)
    }

    fn finalize(&mut self) {
        let t_final = self.times.last().copied().unwrap_or(1.0);
        for i in 0..self.d {
            self.y0[i] = self.y[i] / t_final;
        }
        self.phase = SlPhase::Done;
    }

    /// Verifier scan over the speculated window, then stage the next
    /// iteration's proposal (or finish).
    fn scan_and_advance(&mut self, th: usize) {
        let d = self.d;
        let mut advanced = 0usize;
        for kpos in 0..th {
            let step = self.a + kpos;
            let eta = self.etas[step];
            let sigma = eta.sqrt();
            let y_base: &[f64] = if kpos == 0 {
                &self.y
            } else {
                &self.y_hat[(kpos - 1) * d..kpos * d]
            };
            let drift: &[f64] = if kpos == 0 {
                &self.m_a
            } else {
                &self.evals[kpos * d..(kpos + 1) * d]
            };
            axpy_into(&mut self.m_buf, y_base, eta, drift);
            let accept = grs_native(
                self.u[step], &self.xi[step * d..(step + 1) * d],
                &self.m_hat[kpos * d..(kpos + 1) * d], &self.m_buf, sigma,
                &mut self.z_buf, &mut self.v_buf,
            );
            self.y.copy_from_slice(&self.z_buf);
            advanced += 1;
            if accept {
                self.stats.accepted += 1;
            } else {
                self.stats.rejected += 1;
                break;
            }
        }
        self.a += advanced;
        if self.a >= self.k_steps() {
            self.finalize();
        } else {
            self.stats.iterations += 1;
            self.prop_ts[0] = self.times[self.a];
            self.phase = SlPhase::Propose;
        }
    }
}

impl StepSampler for SlAsdStepMachine {
    fn poll(&mut self) -> anyhow::Result<SamplerPoll<'_>> {
        let d = self.d;
        match self.phase {
            SlPhase::Done => Ok(SamplerPoll::Done(&self.y0)),
            SlPhase::Propose => Ok(SamplerPoll::Demand(DenoiseDemand {
                ys: &self.y,
                ts: &self.prop_ts,
                cond: &[],
                n: 1,
            })),
            SlPhase::Verify { th } => {
                // rows 0..th-1 of the chain, evaluated at times a+1..a+th
                Ok(SamplerPoll::Demand(DenoiseDemand {
                    ys: &self.y_hat[..(th - 1) * d],
                    ts: &self.eval_ts[..th - 1],
                    cond: &[],
                    n: th - 1,
                }))
            }
        }
    }

    /// Arena path: proposal / verify rows written straight from the
    /// machine's chain into the arena's reserved row range (the verify
    /// times are computed in place — `eval_ts` staging bypassed).
    fn poll_into(&mut self, arena: &mut RoundArena)
                 -> anyhow::Result<Option<ArenaSpan>> {
        let d = self.d;
        match self.phase {
            SlPhase::Done => Ok(None),
            SlPhase::Propose => {
                let (span, rows) = arena.reserve(1);
                rows.ys.copy_from_slice(&self.y);
                rows.ts[0] = self.prop_ts[0];
                Ok(Some(span))
            }
            SlPhase::Verify { th } => {
                let (span, rows) = arena.reserve(th - 1);
                rows.ys.copy_from_slice(&self.y_hat[..(th - 1) * d]);
                for kpos in 1..th {
                    rows.ts[kpos - 1] = self.times[self.a + kpos];
                }
                Ok(Some(span))
            }
        }
    }

    fn resume(&mut self, m: &[f64], _exec: RoundExec) -> anyhow::Result<()> {
        let d = self.d;
        match self.phase {
            SlPhase::Done => anyhow::bail!("resume after Done"),
            SlPhase::Propose => {
                anyhow::ensure!(m.len() == d,
                                "proposal row length {} != d {d}", m.len());
                self.m_a.copy_from_slice(m);
                self.stats.oracle_calls += 1;
                self.stats.parallel_rounds += 1;
                let th = self.th();

                // speculate: frozen drift m_a
                for kpos in 0..th {
                    let step = self.a + kpos;
                    let eta = self.etas[step];
                    let (head, tail_buf) = self.y_hat.split_at_mut(kpos * d);
                    let y_prev: &[f64] = if kpos == 0 {
                        &self.y
                    } else {
                        &head[(kpos - 1) * d..kpos * d]
                    };
                    let mh = &mut self.m_hat[kpos * d..(kpos + 1) * d];
                    axpy_into(mh, y_prev, eta, &self.m_a);
                    let se = eta.sqrt();
                    let y_slice = &mut tail_buf[..d];
                    for i in 0..d {
                        y_slice[i] = mh[i] + se * self.xi[step * d + i];
                    }
                }

                if th > 1 {
                    // verify round: oracle at proposed points (positions
                    // 1..th-1; position 0's target mean equals the
                    // proposal mean exactly)
                    for kpos in 1..th {
                        self.eval_ts[kpos - 1] = self.times[self.a + kpos];
                    }
                    self.phase = SlPhase::Verify { th };
                } else {
                    self.scan_and_advance(th);
                }
                Ok(())
            }
            SlPhase::Verify { th } => {
                anyhow::ensure!(m.len() == (th - 1) * d,
                                "verify rows length {} != {}", m.len(),
                                (th - 1) * d);
                self.evals[d..th * d].copy_from_slice(m);
                self.stats.oracle_calls += th - 1;
                self.stats.parallel_rounds += 1;
                self.scan_and_advance(th);
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Gmm;
    use crate::schedule::SlGrid;

    fn radius(p: &[f64]) -> f64 {
        (p[0] * p[0] + p[1] * p[1]).sqrt()
    }

    #[test]
    fn sl_sequential_localizes_to_target() {
        let oracle = GmmSlOracle { gmm: Gmm::circle_2d() };
        let grid = SlGrid::uniform(300.0, 600);
        let seq = SlSequential { oracle: &oracle, grid: &grid };
        let n = 40;
        let mean_r: f64 = (0..n).map(|s| radius(&seq.sample(s))).sum::<f64>()
            / n as f64;
        assert!((mean_r - 1.5).abs() < 0.12, "mean radius {mean_r}");
    }

    #[test]
    fn sl_asd_matches_sequential_law() {
        let oracle = GmmSlOracle { gmm: Gmm::circle_2d() };
        let grid = SlGrid::uniform(300.0, 400);
        let asd = SlAsd { oracle: &oracle, grid: &grid, theta: 8 };
        let n = 40;
        let mut mean_r = 0.0;
        for s in 0..n {
            let (y, stats) = asd.sample(s);
            mean_r += radius(&y);
            assert_eq!(stats.accepted + stats.rejected, 400);
        }
        mean_r /= n as f64;
        assert!((mean_r - 1.5).abs() < 0.12, "mean radius {mean_r}");
    }

    #[test]
    fn sl_asd_fewer_rounds_than_sequential() {
        let oracle = GmmSlOracle { gmm: Gmm::circle_2d() };
        let grid = SlGrid::uniform(300.0, 512);
        let asd = SlAsd { oracle: &oracle, grid: &grid, theta: 16 };
        let (_, stats) = asd.sample(7);
        assert!(stats.parallel_rounds < 512,
                "rounds {}", stats.parallel_rounds);
    }

    #[test]
    fn first_speculation_always_accepted_sl() {
        let oracle = GmmSlOracle { gmm: Gmm::circle_2d() };
        let grid = SlGrid::uniform(200.0, 256);
        let asd = SlAsd { oracle: &oracle, grid: &grid, theta: 4 };
        for s in 0..5 {
            let (_, stats) = asd.sample(s);
            assert!(stats.accepted >= stats.iterations);
        }
    }
}
