//! SL-native sampling: Euler discretization of Stochastic Localization
//! (paper Eq. 4/5) plus ASD over it — the setting of Theorems 4/15.
//!
//! Uses the analytic GMM oracle `m(t, y)` so the Thm-4 scaling benches
//! measure the *algorithm*, not network error. Target/proposal of the
//! Euler step on grid {t_k}:
//!
//!   target:    y_{k+1} = y_k + eta_k m(t_k, y_k)   + sqrt(eta_k) xi
//!   proposal:  y_{k+1} = y_k + eta_k m(t_a, y_a)   + sqrt(eta_k) xi
//!
//! Both Gaussians share variance eta_k I => GRS applies verbatim.


use crate::asd::grs::grs_native;
use crate::math::vec_ops::axpy_into;
use crate::model::GmmSlOracle;
use crate::rng::Philox;
use crate::schedule::SlGrid;

pub struct SlSequential<'a> {
    pub oracle: &'a GmmSlOracle,
    pub grid: &'a SlGrid,
}

impl<'a> SlSequential<'a> {
    /// Returns y_{t_K} / t_K (the localized sample, Law -> mu as t grows).
    pub fn sample(&self, seed: u64) -> Vec<f64> {
        let d = self.oracle.gmm.d;
        let k = self.grid.k_steps();
        let mut rng = Philox::new(seed, 1);
        let mut y = vec![0.0; d];
        let mut m = vec![0.0; d];
        for step in 0..k {
            let t = self.grid.times[step];
            let eta = self.grid.etas[step];
            self.oracle.gmm.sl_posterior_mean(&y, t, &mut m);
            let se = eta.sqrt();
            for i in 0..d {
                y[i] += eta * m[i] + se * rng.normal();
            }
        }
        let t_final = *self.grid.times.last().unwrap();
        y.iter().map(|v| v / t_final).collect()
    }
}

#[derive(Debug, Clone, Default)]
pub struct SlAsdStats {
    pub oracle_calls: usize,
    pub parallel_rounds: usize,
    pub iterations: usize,
    pub accepted: usize,
    pub rejected: usize,
}

pub struct SlAsd<'a> {
    pub oracle: &'a GmmSlOracle,
    pub grid: &'a SlGrid,
    /// speculation length; 0 = infinity
    pub theta: usize,
}

impl<'a> SlAsd<'a> {
    /// ASD over the SL Euler chain. Exactly Algorithm 1 with
    /// b(eta, y) = y + eta m(t, y) and sigma_k = sqrt(eta_k).
    pub fn sample(&self, seed: u64) -> (Vec<f64>, SlAsdStats) {
        let d = self.oracle.gmm.d;
        let k = self.grid.k_steps();
        let mut rng = Philox::new(seed, 1);
        // pre-draw the per-step noise (same contract as the DDPM engine)
        let xi: Vec<f64> = (0..k * d).map(|_| rng.normal()).collect();
        let u: Vec<f64> = (0..k).map(|_| rng.uniform()).collect();

        let mut stats = SlAsdStats::default();
        let mut y = vec![0.0; d];
        let mut a = 0usize; // current grid index
        let mut m_a = vec![0.0; d];
        let mut m_hat = vec![0.0; k * d];
        let mut y_hat = vec![0.0; k * d];
        let mut evals = vec![0.0; k * d];
        let mut m_buf = vec![0.0; d];
        let mut z_buf = vec![0.0; d];
        let mut v_buf = vec![0.0; d];

        while a < k {
            stats.iterations += 1;
            let want = if self.theta == 0 { k - a } else { self.theta };
            let th = want.min(k - a).max(1);

            // proposal round: one oracle call at (t_a, y_a)
            self.oracle.gmm.sl_posterior_mean(&y, self.grid.times[a], &mut m_a);
            stats.oracle_calls += 1;
            stats.parallel_rounds += 1;

            // speculate: frozen drift m_a
            for kpos in 0..th {
                let step = a + kpos;
                let eta = self.grid.etas[step];
                let (mh, yh) = (&mut m_hat[kpos * d..(kpos + 1) * d],
                                kpos * d);
                let y_prev: Vec<f64> = if kpos == 0 {
                    y.clone()
                } else {
                    y_hat[(kpos - 1) * d..kpos * d].to_vec()
                };
                axpy_into(mh, &y_prev, eta, &m_a);
                let se = eta.sqrt();
                for i in 0..d {
                    y_hat[yh + i] = mh[i] + se * xi[step * d + i];
                }
            }

            // verify round: oracle at proposed points (positions 1..th-1;
            // position 0's target mean equals the proposal mean exactly)
            if th > 1 {
                for kpos in 1..th {
                    let step = a + kpos;
                    self.oracle.gmm.sl_posterior_mean(
                        &y_hat[(kpos - 1) * d..kpos * d],
                        self.grid.times[step],
                        &mut evals[kpos * d..(kpos + 1) * d],
                    );
                }
                stats.oracle_calls += th - 1;
                stats.parallel_rounds += 1;
            }

            // verifier scan
            let mut advanced = 0usize;
            for kpos in 0..th {
                let step = a + kpos;
                let eta = self.grid.etas[step];
                let sigma = eta.sqrt();
                let y_base: Vec<f64> = if kpos == 0 {
                    y.clone()
                } else {
                    y_hat[(kpos - 1) * d..kpos * d].to_vec()
                };
                let drift: &[f64] = if kpos == 0 {
                    &m_a
                } else {
                    &evals[kpos * d..(kpos + 1) * d]
                };
                axpy_into(&mut m_buf, &y_base, eta, drift);
                let accept = grs_native(
                    u[step], &xi[step * d..(step + 1) * d],
                    &m_hat[kpos * d..(kpos + 1) * d], &m_buf, sigma,
                    &mut z_buf, &mut v_buf,
                );
                y.copy_from_slice(&z_buf);
                advanced += 1;
                if accept {
                    stats.accepted += 1;
                } else {
                    stats.rejected += 1;
                    break;
                }
            }
            a += advanced;
        }
        let t_final = *self.grid.times.last().unwrap();
        (y.iter().map(|v| v / t_final).collect(), stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Gmm;
    use crate::schedule::SlGrid;

    fn radius(p: &[f64]) -> f64 {
        (p[0] * p[0] + p[1] * p[1]).sqrt()
    }

    #[test]
    fn sl_sequential_localizes_to_target() {
        let oracle = GmmSlOracle { gmm: Gmm::circle_2d() };
        let grid = SlGrid::uniform(300.0, 600);
        let seq = SlSequential { oracle: &oracle, grid: &grid };
        let n = 40;
        let mean_r: f64 = (0..n).map(|s| radius(&seq.sample(s))).sum::<f64>()
            / n as f64;
        assert!((mean_r - 1.5).abs() < 0.12, "mean radius {mean_r}");
    }

    #[test]
    fn sl_asd_matches_sequential_law() {
        let oracle = GmmSlOracle { gmm: Gmm::circle_2d() };
        let grid = SlGrid::uniform(300.0, 400);
        let asd = SlAsd { oracle: &oracle, grid: &grid, theta: 8 };
        let n = 40;
        let mut mean_r = 0.0;
        for s in 0..n {
            let (y, stats) = asd.sample(s);
            mean_r += radius(&y);
            assert_eq!(stats.accepted + stats.rejected, 400);
        }
        mean_r /= n as f64;
        assert!((mean_r - 1.5).abs() < 0.12, "mean radius {mean_r}");
    }

    #[test]
    fn sl_asd_fewer_rounds_than_sequential() {
        let oracle = GmmSlOracle { gmm: Gmm::circle_2d() };
        let grid = SlGrid::uniform(300.0, 512);
        let asd = SlAsd { oracle: &oracle, grid: &grid, theta: 16 };
        let (_, stats) = asd.sample(7);
        assert!(stats.parallel_rounds < 512,
                "rounds {}", stats.parallel_rounds);
    }

    #[test]
    fn first_speculation_always_accepted_sl() {
        let oracle = GmmSlOracle { gmm: Gmm::circle_2d() };
        let grid = SlGrid::uniform(200.0, 256);
        let asd = SlAsd { oracle: &oracle, grid: &grid, theta: 4 };
        for s in 0..5 {
            let (_, stats) = asd.sample(s);
            assert!(stats.accepted >= stats.iterations);
        }
    }
}
