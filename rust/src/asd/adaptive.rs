//! Extension: online speculation-window controller, shared by ASD
//! (speculation length theta) and draft-SD (draft window k).
//!
//! The paper tunes theta offline (Fig 2: theta = 6-8 saturates for
//! images; Fig 5: 20-24 for policies, where acceptance is much higher).
//! This controller discovers that setting online from the observed
//! acceptance run-lengths: it targets the window that keeps the
//! expected wasted verification work below budget.
//!
//! Model: if per-step acceptance is ~p (estimated online by EWMA), the
//! expected number of accepted steps in a window of w is
//! E = sum_{i=1..w} p^{i-1} ~ (1 - p^w) / (1 - p); wasted calls are
//! w - E. The controller picks the largest w (within [min, max]) whose
//! marginal acceptance probability p^w stays above `marginal_floor` —
//! i.e. stop speculating where the chance the window survives that far
//! drops too low. The same economics govern ASD's self-speculated
//! window and draft-SD's draft-proposed window; only the proposal cost
//! differs, which is what min/max bounds encode per sampler.

/// Online acceptance-driven window controller. For ASD the window is
/// theta; for draft-SD it is the draft speculation length k.
#[derive(Debug, Clone)]
pub struct WindowController {
    /// EWMA of per-step acceptance
    p_accept: f64,
    ewma: f64,
    pub min_window: usize,
    pub max_window: usize,
    pub marginal_floor: f64,
}

/// Historical name from when the controller was ASD-only.
pub type AdaptiveTheta = WindowController;

impl WindowController {
    pub fn new(min_window: usize, max_window: usize) -> WindowController {
        WindowController {
            p_accept: 0.7, // optimistic prior
            ewma: 0.05,
            min_window,
            max_window,
            marginal_floor: 0.2,
        }
    }

    /// Feed one verification window's outcome.
    pub fn observe(&mut self, accepted: usize, rejected: usize) {
        let total = accepted + rejected;
        if total == 0 {
            return;
        }
        let rate = accepted as f64 / total as f64;
        self.p_accept = (1.0 - self.ewma) * self.p_accept + self.ewma * rate;
    }

    pub fn acceptance_estimate(&self) -> f64 {
        self.p_accept
    }

    /// Current recommendation.
    pub fn window(&self) -> usize {
        let p = self.p_accept.clamp(1e-6, 1.0 - 1e-9);
        // largest w with p^w >= marginal_floor
        let t = (self.marginal_floor.ln() / p.ln()).floor();
        let t = if t.is_finite() { t.max(1.0) as usize } else { self.max_window };
        t.clamp(self.min_window, self.max_window)
    }

    /// ASD-flavored alias for [`window`](Self::window).
    pub fn theta(&self) -> usize {
        self.window()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn high_acceptance_grows_theta() {
        let mut c = WindowController::new(2, 32);
        for _ in 0..200 {
            c.observe(19, 1); // 95% acceptance
        }
        assert!(c.theta() >= 20, "theta {} for p={}", c.theta(),
                c.acceptance_estimate());
    }

    #[test]
    fn low_acceptance_shrinks_theta() {
        let mut c = WindowController::new(2, 32);
        for _ in 0..200 {
            c.observe(1, 1); // 50% acceptance
        }
        let th = c.theta();
        assert!((2..=4).contains(&th), "theta {th}");
    }

    #[test]
    fn respects_bounds() {
        let mut c = WindowController::new(4, 8);
        for _ in 0..100 {
            c.observe(0, 1);
        }
        assert_eq!(c.theta(), 4);
        for _ in 0..2000 {
            c.observe(1, 0);
        }
        assert_eq!(c.theta(), 8);
    }

    #[test]
    fn empty_observation_is_noop() {
        let mut c = WindowController::new(2, 32);
        let before = c.acceptance_estimate();
        c.observe(0, 0);
        assert_eq!(c.acceptance_estimate(), before);
    }

    #[test]
    fn converges_on_a_synthetic_accept_rate_sequence() {
        // drive the controller with windows drawn from a fixed per-step
        // acceptance p: run length ~ Geometric(1-p) truncated at the
        // window. The recommendation must converge to the analytic
        // largest-w-with-p^w>=floor value and then stay put.
        let p = 0.85f64;
        let mut c = WindowController::new(1, 64);
        let mut state = 0x1234_5678_9abc_def0u64;
        let mut unit = move || {
            // xorshift64*: deterministic synthetic stream
            state ^= state >> 12;
            state ^= state << 25;
            state ^= state >> 27;
            (state.wrapping_mul(0x2545_F491_4F6C_DD1D) >> 11) as f64
                / (1u64 << 53) as f64
        };
        for _ in 0..600 {
            let w = c.window();
            let mut accepted = 0usize;
            let mut rejected = 0usize;
            for _ in 0..w {
                if unit() < p {
                    accepted += 1;
                } else {
                    rejected = 1;
                    break;
                }
            }
            c.observe(accepted, rejected);
        }
        let expect = (c.marginal_floor.ln() / p.ln()).floor() as usize;
        let got = c.window();
        // the EWMA sees the *truncated* run-length rate, so allow a
        // band around the analytic fixed point — but it must be far
        // from both bounds and stable under further identical feeds
        assert!(got >= expect / 2 && got <= expect * 2,
                "window {got} vs analytic {expect} (p_est {})",
                c.acceptance_estimate());
        assert!(got > 1 && got < 64, "window pinned at a bound: {got}");
        let before = got;
        for _ in 0..100 {
            let w = c.window();
            let acc = ((w as f64) * p).round() as usize;
            c.observe(acc, w - acc);
        }
        let after = c.window();
        assert!(after.abs_diff(before) <= 2,
                "controller did not settle: {before} -> {after}");
    }
}
