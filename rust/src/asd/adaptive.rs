//! Extension: online speculation-length controller.
//!
//! The paper tunes theta offline (Fig 2: theta = 6-8 saturates for
//! images; Fig 5: 20-24 for policies, where acceptance is much higher).
//! This controller discovers that setting online from the observed
//! acceptance run-lengths: it targets the theta that keeps the expected
//! wasted verification work below `waste_budget` of the batch.
//!
//! Model: if per-step acceptance is ~p (estimated online by EWMA), the
//! expected number of accepted steps in a window of theta is
//! E = sum_{i=1..theta} p^{i-1} ~ (1 - p^theta) / (1 - p); wasted calls
//! are theta - E. The controller picks the largest theta (within
//! [min, max]) whose marginal acceptance probability p^theta stays above
//! `marginal_floor` — i.e. stop speculating where the chance the window
//! survives that far drops too low.

#[derive(Debug, Clone)]
pub struct AdaptiveTheta {
    /// EWMA of per-step acceptance
    p_accept: f64,
    ewma: f64,
    pub min_theta: usize,
    pub max_theta: usize,
    pub marginal_floor: f64,
}

impl AdaptiveTheta {
    pub fn new(min_theta: usize, max_theta: usize) -> AdaptiveTheta {
        AdaptiveTheta {
            p_accept: 0.7, // optimistic prior
            ewma: 0.05,
            min_theta,
            max_theta,
            marginal_floor: 0.2,
        }
    }

    /// Feed one verification window's outcome.
    pub fn observe(&mut self, accepted: usize, rejected: usize) {
        let total = accepted + rejected;
        if total == 0 {
            return;
        }
        let rate = accepted as f64 / total as f64;
        self.p_accept = (1.0 - self.ewma) * self.p_accept + self.ewma * rate;
    }

    pub fn acceptance_estimate(&self) -> f64 {
        self.p_accept
    }

    /// Current recommendation.
    pub fn theta(&self) -> usize {
        let p = self.p_accept.clamp(1e-6, 1.0 - 1e-9);
        // largest theta with p^theta >= marginal_floor
        let t = (self.marginal_floor.ln() / p.ln()).floor();
        let t = if t.is_finite() { t.max(1.0) as usize } else { self.max_theta };
        t.clamp(self.min_theta, self.max_theta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn high_acceptance_grows_theta() {
        let mut c = AdaptiveTheta::new(2, 32);
        for _ in 0..200 {
            c.observe(19, 1); // 95% acceptance
        }
        assert!(c.theta() >= 20, "theta {} for p={}", c.theta(),
                c.acceptance_estimate());
    }

    #[test]
    fn low_acceptance_shrinks_theta() {
        let mut c = AdaptiveTheta::new(2, 32);
        for _ in 0..200 {
            c.observe(1, 1); // 50% acceptance
        }
        let th = c.theta();
        assert!((2..=4).contains(&th), "theta {th}");
    }

    #[test]
    fn respects_bounds() {
        let mut c = AdaptiveTheta::new(4, 8);
        for _ in 0..100 {
            c.observe(0, 1);
        }
        assert_eq!(c.theta(), 4);
        for _ in 0..2000 {
            c.observe(1, 0);
        }
        assert_eq!(c.theta(), 8);
    }

    #[test]
    fn empty_observation_is_noop() {
        let mut c = AdaptiveTheta::new(2, 32);
        let before = c.acceptance_estimate();
        c.observe(0, 0);
        assert_eq!(c.acceptance_estimate(), before);
    }
}
