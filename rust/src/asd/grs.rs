//! Gaussian Rejection Sampler (Algorithm 3) — native implementation.
//!
//! Given proposal mean `m_hat`, target mean `m` (same variance
//! `sigma^2 I`), pre-drawn `xi ~ N(0,I)` and uniform `u`:
//!
//! accept  <=>  ln u <= -(<v, xi>/sigma + ||v||^2 / (2 sigma^2)),
//!              v = m_hat - m
//! accepted:  z = m_hat + sigma xi      (the proposal sample)
//! rejected:  z = m + sigma reflect(xi) (reflection coupling)
//!
//! Theorem 12: z ~ N(m, sigma^2 I) exactly either way, and
//! P[reject] = TV(N(m_hat, s^2 I), N(m, s^2 I)). Edge cases match
//! python/compile/kernels/grs.py: v = 0 always accepts (Lemma 13);
//! sigma = 0 compares Diracs.

use crate::math::vec_ops::{dot, norm_sq, reflect_into};

pub const SIGMA0_TOL: f64 = 1e-6;
const EPS: f64 = 1e-12;

/// Runs GRS for one step; writes the corrected sample into `z`.
/// Returns `true` on accept.
pub fn grs_native(u: f64, xi: &[f64], m_hat: &[f64], m: &[f64], sigma: f64,
                  z: &mut [f64], v_buf: &mut [f64]) -> bool {
    let d = xi.len();
    debug_assert!(m_hat.len() == d && m.len() == d && z.len() == d
                  && v_buf.len() == d);
    for i in 0..d {
        v_buf[i] = m_hat[i] - m[i];
    }
    let v_sq = norm_sq(v_buf);

    if sigma <= SIGMA0_TOL {
        // Dirac vs Dirac
        z.copy_from_slice(m);
        return v_sq <= SIGMA0_TOL * SIGMA0_TOL;
    }

    let log_ratio = -(dot(v_buf, xi) / sigma + 0.5 * v_sq / (sigma * sigma));
    let accept = u.max(EPS).ln() <= log_ratio || v_sq <= EPS;
    if accept {
        for i in 0..d {
            z[i] = m_hat[i] + sigma * xi[i];
        }
    } else {
        reflect_into(z, xi, v_buf);
        for i in 0..d {
            z[i] = m[i] + sigma * z[i];
        }
    }
    accept
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::erf::gaussian_tv;
    use crate::rng::Philox;
    use crate::util::prop;

    #[test]
    fn equal_means_always_accept() {
        prop::check("grs-equal-means", 40, |g| {
            let d = g.usize_in(1, 32);
            let m = g.normal_vec(d);
            let xi = g.normal_vec(d);
            let u = g.rng.uniform();
            let sigma = g.f64_in(0.01, 2.0);
            let mut z = vec![0.0; d];
            let mut v = vec![0.0; d];
            let ok = grs_native(u, &xi, &m, &m, sigma, &mut z, &mut v);
            assert!(ok, "v=0 must always accept (Lemma 13)");
            for i in 0..d {
                assert!((z[i] - (m[i] + sigma * xi[i])).abs() < 1e-12);
            }
        });
    }

    #[test]
    fn sigma_zero_dirac_semantics() {
        let m = [1.0, 2.0];
        let mut z = [0.0; 2];
        let mut v = [0.0; 2];
        let ok = grs_native(0.5, &[0.3, -0.1], &m, &m, 0.0, &mut z, &mut v);
        assert!(ok);
        assert_eq!(z, m);
        let m_hat = [1.5, 2.0];
        let ok = grs_native(0.5, &[0.3, -0.1], &m_hat, &m, 0.0, &mut z, &mut v);
        assert!(!ok);
        assert_eq!(z, m, "rejected Dirac must return the target mean");
    }

    #[test]
    fn marginal_law_is_target_theorem12() {
        // z ~ N(m, sigma^2 I) regardless of m_hat
        let mut rng = Philox::new(42, 0);
        let d = 3;
        let m = vec![0.0; d];
        let m_hat = vec![0.5, -0.3, 0.2];
        let sigma = 0.7;
        let n = 40_000;
        let mut sum = vec![0.0; d];
        let mut sum_sq = vec![0.0; d];
        let mut rejects = 0usize;
        let mut z = vec![0.0; d];
        let mut v = vec![0.0; d];
        for _ in 0..n {
            let xi: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            let u = rng.uniform();
            if !grs_native(u, &xi, &m_hat, &m, sigma, &mut z, &mut v) {
                rejects += 1;
            }
            for i in 0..d {
                sum[i] += z[i];
                sum_sq[i] += z[i] * z[i];
            }
        }
        for i in 0..d {
            let mean = sum[i] / n as f64;
            let var = sum_sq[i] / n as f64 - mean * mean;
            assert!(mean.abs() < 0.02, "dim {i} mean {mean}");
            assert!((var - sigma * sigma).abs() < 0.02, "dim {i} var {var}");
        }
        // P[reject] == TV( N(m_hat, s^2), N(m, s^2) )
        let v_norm = crate::math::vec_ops::dist(&m_hat, &m);
        let want = gaussian_tv(v_norm, sigma);
        let got = rejects as f64 / n as f64;
        assert!((got - want).abs() < 0.01, "reject rate {got} vs TV {want}");
    }

    #[test]
    fn rejected_sample_is_reflection() {
        prop::check("grs-reflection", 30, |g| {
            let d = g.usize_in(2, 8);
            let m = g.normal_vec(d);
            let mut m_hat = m.clone();
            m_hat[0] += 10.0; // huge v: reject with u ~ 1
            let xi = g.normal_vec(d);
            let sigma = 0.5;
            let mut z = vec![0.0; d];
            let mut v = vec![0.0; d];
            let ok = grs_native(0.999999, &xi, &m_hat, &m, sigma, &mut z, &mut v);
            if !ok {
                // ||(z - m)/sigma|| == ||xi|| (reflection is an isometry)
                let r: Vec<f64> = (0..d).map(|i| (z[i] - m[i]) / sigma).collect();
                let (n1, n2) = (crate::math::vec_ops::norm(&r),
                                crate::math::vec_ops::norm(&xi));
                assert!((n1 - n2).abs() < 1e-9);
            }
        });
    }
}
