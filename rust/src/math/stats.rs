//! Statistics: summary stats, two-sample tests, distribution distances.
//!
//! Backs both the quality metrics (FID-proxy, sliced Wasserstein, MMD)
//! and the statistical assertions in the property tests.

use crate::rng::Philox;

/// Streaming mean/variance (Welford).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    pub n: usize,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std() / (self.n as f64).sqrt()
        }
    }
}

pub fn mean(v: &[f64]) -> f64 {
    v.iter().sum::<f64>() / v.len().max(1) as f64
}

pub fn variance(v: &[f64]) -> f64 {
    let m = mean(v);
    v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (v.len().max(2) - 1) as f64
}

pub fn quantile(sorted: &[f64], q: f64) -> f64 {
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx]
}

/// Two-sample Kolmogorov–Smirnov statistic (1-D).
pub fn ks_statistic(a: &[f64], b: &[f64]) -> f64 {
    let mut sa = a.to_vec();
    let mut sb = b.to_vec();
    sa.sort_by(|x, y| x.partial_cmp(y).unwrap());
    sb.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let (mut i, mut j) = (0usize, 0usize);
    let mut d: f64 = 0.0;
    while i < sa.len() && j < sb.len() {
        if sa[i] <= sb[j] {
            i += 1;
        } else {
            j += 1;
        }
        let fa = i as f64 / sa.len() as f64;
        let fb = j as f64 / sb.len() as f64;
        d = d.max((fa - fb).abs());
    }
    d
}

/// Asymptotic two-sample KS critical value at significance `alpha`.
pub fn ks_critical(n1: usize, n2: usize, alpha: f64) -> f64 {
    let c = (-(alpha / 2.0).ln() / 2.0).sqrt();
    c * ((n1 + n2) as f64 / (n1 * n2) as f64).sqrt()
}

/// Sliced Wasserstein-1 distance between point clouds in R^d:
/// average over `n_proj` random 1-D projections of the 1-D W1 distance.
pub fn sliced_wasserstein(a: &[Vec<f64>], b: &[Vec<f64>], n_proj: usize,
                          seed: u64) -> f64 {
    assert!(!a.is_empty() && !b.is_empty());
    let d = a[0].len();
    let mut rng = Philox::new(seed, 0x57a7);
    let mut total = 0.0;
    for _ in 0..n_proj {
        let mut dir: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let n = crate::math::vec_ops::norm(&dir).max(1e-12);
        for x in &mut dir {
            *x /= n;
        }
        let mut pa: Vec<f64> = a.iter()
            .map(|r| crate::math::vec_ops::dot(r, &dir)).collect();
        let mut pb: Vec<f64> = b.iter()
            .map(|r| crate::math::vec_ops::dot(r, &dir)).collect();
        pa.sort_by(|x, y| x.partial_cmp(y).unwrap());
        pb.sort_by(|x, y| x.partial_cmp(y).unwrap());
        total += w1_sorted(&pa, &pb);
    }
    total / n_proj as f64
}

/// W1 between two sorted 1-D samples (quantile coupling).
pub fn w1_sorted(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len().max(b.len());
    let mut acc = 0.0;
    for i in 0..n {
        let qa = a[(i * a.len()) / n];
        let qb = b[(i * b.len()) / n];
        acc += (qa - qb).abs();
    }
    acc / n as f64
}

/// RBF-kernel MMD^2 (biased V-statistic) between two point clouds.
pub fn mmd_sq_rbf(a: &[Vec<f64>], b: &[Vec<f64>], bandwidth: f64) -> f64 {
    let g = 1.0 / (2.0 * bandwidth * bandwidth);
    let k = |x: &[f64], y: &[f64]| {
        let d2: f64 = x.iter().zip(y).map(|(p, q)| (p - q) * (p - q)).sum();
        (-g * d2).exp()
    };
    let kaa: f64 = a.iter()
        .map(|x| a.iter().map(|y| k(x, y)).sum::<f64>())
        .sum::<f64>() / (a.len() * a.len()) as f64;
    let kbb: f64 = b.iter()
        .map(|x| b.iter().map(|y| k(x, y)).sum::<f64>())
        .sum::<f64>() / (b.len() * b.len()) as f64;
    let kab: f64 = a.iter()
        .map(|x| b.iter().map(|y| k(x, y)).sum::<f64>())
        .sum::<f64>() / (a.len() * b.len()) as f64;
    kaa + kbb - 2.0 * kab
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::normal_vec;

    #[test]
    fn welford_matches_batch() {
        let data = [1.0, 2.0, 4.0, 8.0, 16.5];
        let mut w = Welford::default();
        for &x in &data {
            w.push(x);
        }
        assert!((w.mean() - mean(&data)).abs() < 1e-12);
        assert!((w.var() - variance(&data)).abs() < 1e-12);
    }

    #[test]
    fn ks_same_distribution_small() {
        let mut rng = Philox::new(1, 0);
        let a = normal_vec(&mut rng, 2000);
        let b = normal_vec(&mut rng, 2000);
        let d = ks_statistic(&a, &b);
        assert!(d < ks_critical(2000, 2000, 0.001), "d = {d}");
    }

    #[test]
    fn ks_different_distribution_large() {
        let mut rng = Philox::new(2, 0);
        let a = normal_vec(&mut rng, 1000);
        let b: Vec<f64> = normal_vec(&mut rng, 1000)
            .into_iter().map(|x| x + 1.0).collect();
        assert!(ks_statistic(&a, &b) > ks_critical(1000, 1000, 0.001));
    }

    #[test]
    fn w1_shift_identity() {
        // W1 between N(0,1) samples and the same +c shifted is ~c
        let mut rng = Philox::new(3, 0);
        let mut a = normal_vec(&mut rng, 4000);
        let mut b: Vec<f64> = a.iter().map(|x| x + 0.7).collect();
        a.sort_by(|x, y| x.partial_cmp(y).unwrap());
        b.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert!((w1_sorted(&a, &b) - 0.7).abs() < 1e-9);
    }

    #[test]
    fn sliced_w_zero_for_identical() {
        let mut rng = Philox::new(4, 0);
        let cloud: Vec<Vec<f64>> =
            (0..200).map(|_| normal_vec(&mut rng, 3)).collect();
        let d = sliced_wasserstein(&cloud, &cloud, 8, 0);
        assert!(d < 1e-12);
    }

    #[test]
    fn sliced_w_detects_shift() {
        let mut rng = Philox::new(5, 0);
        let a: Vec<Vec<f64>> =
            (0..500).map(|_| normal_vec(&mut rng, 3)).collect();
        let b: Vec<Vec<f64>> = a.iter()
            .map(|r| r.iter().map(|x| x + 1.0).collect()).collect();
        let d = sliced_wasserstein(&a, &b, 16, 0);
        // E|<1, dir>| over random unit dirs in R^3 is ~0.5-0.6
        assert!(d > 0.3, "d = {d}");
    }

    #[test]
    fn mmd_separates() {
        let mut rng = Philox::new(6, 0);
        let a: Vec<Vec<f64>> =
            (0..150).map(|_| normal_vec(&mut rng, 2)).collect();
        let b: Vec<Vec<f64>> =
            (0..150).map(|_| normal_vec(&mut rng, 2)).collect();
        let c: Vec<Vec<f64>> = a.iter()
            .map(|r| r.iter().map(|x| x + 2.0).collect()).collect();
        let same = mmd_sq_rbf(&a, &b, 1.0);
        let diff = mmd_sq_rbf(&a, &c, 1.0);
        assert!(diff > 10.0 * same.abs().max(1e-6), "{same} vs {diff}");
    }
}
