//! Runtime kernel selection for the packed GEMM path.
//!
//! [`KernelPolicy`] is the knob threaded from `NativeMlp::from_flat`
//! (and `ServerConfig` / the CLI flags `--gemm-isa`,
//! `--gemm-precision`) down to `math::gemm`: which instruction set the
//! packed micro-kernels should use — an [`IsaRequest`], resolved once
//! per model load against the host into an [`Isa`] — and which
//! precision the weight panels are stored at ([`Precision`]). Every
//! (ISA, precision) combination lands in an explicit
//! [`DeterminismTier`]:
//!
//! * [`DeterminismTier::BitExact`] — portable f32 kernels. Bit-identical
//!   to `gemm_ref` on every host; this is the seed contract and the
//!   `ASD_GEMM_ISA=portable` CI leg.
//! * [`DeterminismTier::ReproducibleGivenConfig`] — SIMD f32 kernels
//!   (AVX2+FMA on x86-64, NEON on aarch64). Fused multiply-add
//!   contracts the intermediate rounding, so the bits differ from the
//!   portable reduction — but for a *fixed* resolved ISA the outputs
//!   are bit-stable across pool sizes, tile grids and work-steal
//!   schedules. The argument: IEEE-754 requires FMA to be exactly
//!   rounded, so a scalar `mul_add` and one lane of a vector
//!   `fmadd` produce identical bits for identical inputs; the tile
//!   grid is MR/NR block-aligned and never splits a k-reduction; and
//!   the kernel is chosen once per GEMM call, never per tile. Asserted
//!   in `tests/test_parallel_determinism.rs`.
//! * [`DeterminismTier::QuantizedWithErrorBound`] — int8 or f16 weight
//!   panels. Outputs carry a documented relative error bound vs
//!   `NativeMlp::denoise_batch_ref`
//!   ([`KernelPolicy::denoise_rel_tolerance`]), asserted by the tier
//!   oracle in `tests/test_properties.rs`; still bit-stable across
//!   pool sizes and schedules for a fixed config.
//!
//! The environment variable `ASD_GEMM_ISA` (`auto` | `portable` |
//! `avx2` | `neon`) overrides every policy's ISA request — the
//! forced-fallback hook CI uses to keep the portable path exercised on
//! SIMD runners. An unknown value warns once and is ignored (auto); a
//! requested ISA the host cannot run warns once and falls back to
//! portable, mirroring the `ASD_POOL_THREADS` diagnostics.

use std::fmt;
use std::sync::{Once, OnceLock};

/// A concrete instruction set the packed kernels can run on, resolved
/// against the host. `Portable` is always available and always
/// correct; the SIMD variants are only ever produced on hosts that
/// support them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// Plain-Rust kernels: separate mul + add, bit-identical to
    /// `gemm_ref`.
    Portable,
    /// AVX2 + FMA 256-bit kernels (x86-64). F16 panels additionally
    /// use F16C when the host has it.
    Avx2,
    /// NEON 128-bit FMA kernels (aarch64, f32 panels only — quantized
    /// panels route to the portable kernels there).
    Neon,
}

impl Isa {
    /// Stable lower-case name used in BENCH_gemm.json rows and logs.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Portable => "portable",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }
}

impl fmt::Display for Isa {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// What the user/config *asked* for; resolved to an [`Isa`] via
/// [`resolve`]. `Auto` picks the fastest ISA the host supports.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum IsaRequest {
    #[default]
    Auto,
    Portable,
    Avx2,
    Neon,
}

impl IsaRequest {
    /// Parse a CLI/env spelling (case-insensitive). `None` for unknown
    /// values — callers decide whether that is a warning (env) or an
    /// error (CLI flag).
    pub fn parse(s: &str) -> Option<IsaRequest> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Some(IsaRequest::Auto),
            "portable" | "scalar" => Some(IsaRequest::Portable),
            "avx2" => Some(IsaRequest::Avx2),
            "neon" => Some(IsaRequest::Neon),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            IsaRequest::Auto => "auto",
            IsaRequest::Portable => "portable",
            IsaRequest::Avx2 => "avx2",
            IsaRequest::Neon => "neon",
        }
    }
}

impl fmt::Display for IsaRequest {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Storage precision of the packed weight panels. Activations and
/// accumulators are always f32; only the B panels shrink.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    /// Full f32 panels — bit-exact or reproducible-given-config
    /// depending on the resolved ISA.
    #[default]
    F32,
    /// IEEE binary16 bit patterns (half the L2 footprint); dequant is
    /// exact per element and fused into the kernel.
    F16,
    /// Per-(k-panel, column) scaled int8 (quarter the footprint);
    /// dequant is fused into the kernel epilogue.
    Int8,
}

impl Precision {
    pub fn parse(s: &str) -> Option<Precision> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" => Some(Precision::F32),
            "f16" | "fp16" | "half" => Some(Precision::F16),
            "int8" | "i8" => Some(Precision::Int8),
            _ => None,
        }
    }

    /// Stable lower-case name used in BENCH_gemm.json rows and logs.
    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::F16 => "f16",
            Precision::Int8 => "int8",
        }
    }
}

impl fmt::Display for Precision {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// The determinism contract a kernel configuration ships under. See
/// the module docs for the exact guarantees.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeterminismTier {
    /// Bit-identical to `gemm_ref` / `denoise_batch_ref` reduction
    /// order on every host.
    BitExact,
    /// Bit-stable across pool sizes, tile grids and steal schedules
    /// for a fixed resolved ISA; not bit-comparable across ISAs.
    ReproducibleGivenConfig,
    /// Tracks the f32 reference within
    /// [`KernelPolicy::denoise_rel_tolerance`]; bit-stable across
    /// schedules for a fixed config.
    QuantizedWithErrorBound,
}

impl DeterminismTier {
    pub fn name(self) -> &'static str {
        match self {
            DeterminismTier::BitExact => "bit-exact",
            DeterminismTier::ReproducibleGivenConfig => {
                "reproducible-given-config"
            }
            DeterminismTier::QuantizedWithErrorBound => {
                "quantized-with-error-bound"
            }
        }
    }
}

impl fmt::Display for DeterminismTier {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// The kernel knob threaded from model load / `ServerConfig` down to
/// `math::gemm`. The default (`auto` ISA, f32 panels) is the fast
/// path; `ASD_GEMM_ISA=portable` restores the seed's bit-exact
/// behaviour globally without touching any config.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct KernelPolicy {
    pub isa: IsaRequest,
    pub precision: Precision,
}

impl KernelPolicy {
    /// Resolve the ISA request against the host (and the
    /// `ASD_GEMM_ISA` override). Call once at model load and reuse the
    /// result — the kernel choice must be per model, never per tile.
    pub fn resolve_isa(&self) -> Isa {
        resolve(self.isa)
    }

    /// Which determinism tier this policy lands in on this host.
    pub fn tier(&self) -> DeterminismTier {
        if self.precision != Precision::F32 {
            DeterminismTier::QuantizedWithErrorBound
        } else if self.resolve_isa() == Isa::Portable {
            DeterminismTier::BitExact
        } else {
            DeterminismTier::ReproducibleGivenConfig
        }
    }

    /// Documented end-to-end relative error bound of `denoise_batch`
    /// vs `denoise_batch_ref` under this policy, relative to
    /// `max(1, |ref|)` per output element. The f32 figure is the
    /// existing `exp_fast`-vs-libm budget; the quantized figures are
    /// conservative worst-case bounds for unit-scale weights (typical
    /// observed error is ~10x smaller) and are pinned by the tier
    /// oracle in `tests/test_properties.rs`.
    pub fn denoise_rel_tolerance(&self) -> f64 {
        match self.precision {
            Precision::F32 => 1e-5,
            Precision::F16 => 5e-2,
            Precision::Int8 => 2e-1,
        }
    }
}

/// Per-GEMM relative error bound vs `gemm_ref` for a (precision, ISA)
/// pair, relative to `max(1, |ref|)` per output element. Used by the
/// bench-grid runner's in-loop tolerance checks. Zero means the
/// contract is bitwise.
pub fn gemm_rel_tolerance(isa: Isa, precision: Precision) -> f64 {
    match precision {
        // FMA contraction only: bounded by accumulated rounding
        // differences over the k-reduction
        Precision::F32 => {
            if isa == Isa::Portable {
                0.0
            } else {
                5e-5
            }
        }
        Precision::F16 => 5e-2,
        Precision::Int8 => 1.5e-1,
    }
}

/// The ISA `IsaRequest::Auto` resolves to on this host (after the
/// `ASD_GEMM_ISA` override).
pub fn detect_isa() -> Isa {
    resolve(IsaRequest::Auto)
}

/// Resolve a request against the host. The `ASD_GEMM_ISA` environment
/// override, when present and valid, replaces the request entirely.
pub fn resolve(req: IsaRequest) -> Isa {
    let req = env_override().unwrap_or(req);
    match req {
        IsaRequest::Auto => {
            if host_supports_avx2() {
                Isa::Avx2
            } else if host_supports_neon() {
                Isa::Neon
            } else {
                Isa::Portable
            }
        }
        IsaRequest::Portable => Isa::Portable,
        IsaRequest::Avx2 => {
            if host_supports_avx2() {
                Isa::Avx2
            } else {
                warn_unsupported("avx2");
                Isa::Portable
            }
        }
        IsaRequest::Neon => {
            if host_supports_neon() {
                Isa::Neon
            } else {
                warn_unsupported("neon");
                Isa::Portable
            }
        }
    }
}

/// Cached `ASD_GEMM_ISA` parse; `None` when unset or invalid (invalid
/// warns once and falls back to auto-resolution of the caller's
/// request).
fn env_override() -> Option<IsaRequest> {
    static OVERRIDE: OnceLock<Option<IsaRequest>> = OnceLock::new();
    *OVERRIDE.get_or_init(|| {
        let raw = std::env::var("ASD_GEMM_ISA").ok()?;
        match IsaRequest::parse(&raw) {
            Some(req) => Some(req),
            None => {
                eprintln!(
                    "warning: ASD_GEMM_ISA='{raw}' is not one of \
                     auto|portable|avx2|neon; ignoring"
                );
                None
            }
        }
    })
}

fn warn_unsupported(isa: &str) {
    static WARNED: Once = Once::new();
    WARNED.call_once(|| {
        eprintln!(
            "warning: requested GEMM ISA '{isa}' is not supported on \
             this host; falling back to portable kernels"
        );
    });
}

/// AVX2 *and* FMA — the microkernels need both, and requiring both
/// keeps "avx2" a single reproducible-given-config point.
#[cfg(target_arch = "x86_64")]
pub fn host_supports_avx2() -> bool {
    static CAP: OnceLock<bool> = OnceLock::new();
    *CAP.get_or_init(|| {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    })
}

#[cfg(not(target_arch = "x86_64"))]
pub fn host_supports_avx2() -> bool {
    false
}

/// F16C (hardware f16↔f32 converts). Checked separately from AVX2:
/// without it, f16 panels route to the portable kernel. The hardware
/// convert is exact, so it cannot change the f16 tier's bits.
#[cfg(target_arch = "x86_64")]
pub fn host_has_f16c() -> bool {
    static CAP: OnceLock<bool> = OnceLock::new();
    *CAP.get_or_init(|| is_x86_feature_detected!("f16c"))
}

#[cfg(not(target_arch = "x86_64"))]
pub fn host_has_f16c() -> bool {
    false
}

/// NEON is baseline on aarch64 — no runtime probe needed.
pub fn host_supports_neon() -> bool {
    cfg!(target_arch = "aarch64")
}

// ----------------------------------------------------------------------
// binary16 conversions (no external crate; both directions exact /
// round-to-nearest-even, used by the f16 panel store)
// ----------------------------------------------------------------------

/// Convert an IEEE-754 binary16 bit pattern to f32. Exact: every f16
/// value (including subnormals, infs and NaN payloads) is
/// representable in f32.
#[inline]
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    let bits = if exp == 0x1f {
        // inf / NaN: payload widens into the f32 mantissa top bits
        sign | 0x7f80_0000 | (mant << 13)
    } else if exp == 0 {
        if mant == 0 {
            sign // ±0
        } else {
            // subnormal: normalize into an f32 normal
            let mut e = 113u32; // 127 - 14, adjusted down per shift
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x3ff) << 13)
        }
    } else {
        // normal: rebias 15 -> 127
        sign | ((exp + 112) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Round an f32 to the nearest binary16 bit pattern
/// (round-to-nearest-even). Overflow goes to ±inf; NaN payloads keep
/// their top 10 bits (forced quiet if that truncates to zero, so a NaN
/// can never round-trip into an inf).
#[inline]
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x7f_ffff;
    if exp == 0xff {
        let payload = (mant >> 13) as u16;
        let keep = if mant != 0 && payload == 0 { 0x200 } else { payload };
        return sign | 0x7c00 | keep;
    }
    let e = exp - 127; // unbiased
    if e > 15 {
        return sign | 0x7c00; // overflow -> inf (covers e.g. 65520+)
    }
    if e >= -14 {
        // normal f16 range (rounding may still carry up to inf, which
        // the exponent-field add below produces naturally)
        let half_exp = (e + 15) as u32;
        let base = (half_exp << 10) | (mant >> 13);
        let rem = mant & 0x1fff;
        let round_up = rem > 0x1000 || (rem == 0x1000 && (base & 1) == 1);
        return sign | (base + round_up as u32) as u16;
    }
    if e < -25 {
        return sign; // underflow to signed zero (below half of min subnormal)
    }
    // subnormal f16: shift the implicit-1 mantissa right
    let m = mant | 0x80_0000; // restore implicit leading 1
    let shift = (-14 - e + 13) as u32; // bits dropped from the 24-bit mantissa
    let base = m >> shift;
    let rem = m & ((1u32 << shift) - 1);
    let half = 1u32 << (shift - 1);
    let round_up = rem > half || (rem == half && (base & 1) == 1);
    sign | (base + round_up as u32) as u16
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_and_precision_parse_all_spellings() {
        assert_eq!(IsaRequest::parse("AUTO"), Some(IsaRequest::Auto));
        assert_eq!(IsaRequest::parse("portable"), Some(IsaRequest::Portable));
        assert_eq!(IsaRequest::parse("Avx2"), Some(IsaRequest::Avx2));
        assert_eq!(IsaRequest::parse("neon"), Some(IsaRequest::Neon));
        assert_eq!(IsaRequest::parse("sse9"), None);
        assert_eq!(Precision::parse("f32"), Some(Precision::F32));
        assert_eq!(Precision::parse("F16"), Some(Precision::F16));
        assert_eq!(Precision::parse("int8"), Some(Precision::Int8));
        assert_eq!(Precision::parse("int4"), None);
    }

    #[test]
    fn tier_mapping_matches_contract() {
        // quantized precision always lands in the quantized tier,
        // whatever the host resolves the ISA to
        for prec in [Precision::F16, Precision::Int8] {
            let p = KernelPolicy { isa: IsaRequest::Auto, precision: prec };
            assert_eq!(p.tier(), DeterminismTier::QuantizedWithErrorBound);
        }
        // f32 tier depends only on the resolved ISA
        let p = KernelPolicy::default();
        match p.resolve_isa() {
            Isa::Portable => assert_eq!(p.tier(), DeterminismTier::BitExact),
            _ => assert_eq!(p.tier(),
                            DeterminismTier::ReproducibleGivenConfig),
        }
    }

    #[test]
    fn portable_f32_gemm_tolerance_is_bitwise() {
        assert_eq!(gemm_rel_tolerance(Isa::Portable, Precision::F32), 0.0);
        assert!(gemm_rel_tolerance(Isa::Avx2, Precision::F32) > 0.0);
        assert!(gemm_rel_tolerance(Isa::Portable, Precision::Int8)
                > gemm_rel_tolerance(Isa::Portable, Precision::F16));
    }

    #[test]
    fn resolved_isa_is_always_host_runnable() {
        // whatever the env says, the resolved ISA must be executable
        // here — the dispatch table relies on this invariant
        for req in [IsaRequest::Auto, IsaRequest::Portable,
                    IsaRequest::Avx2, IsaRequest::Neon] {
            match resolve(req) {
                Isa::Portable => {}
                Isa::Avx2 => assert!(host_supports_avx2()),
                Isa::Neon => assert!(host_supports_neon()),
            }
        }
    }

    #[test]
    fn f16_roundtrip_is_exact_for_all_65536_patterns() {
        for h in 0..=u16::MAX {
            let back = f32_to_f16(f16_to_f32(h));
            assert_eq!(back, h, "pattern {h:#06x} round-tripped to {back:#06x}");
        }
    }

    #[test]
    fn f16_conversion_spot_values() {
        assert_eq!(f32_to_f16(0.0), 0x0000);
        assert_eq!(f32_to_f16(-0.0), 0x8000);
        assert_eq!(f32_to_f16(1.0), 0x3c00);
        assert_eq!(f32_to_f16(-2.0), 0xc000);
        assert_eq!(f16_to_f32(0x3c00), 1.0);
        assert_eq!(f16_to_f32(0x0001), 2.0f32.powi(-24)); // min subnormal
        assert_eq!(f32_to_f16(65504.0), 0x7bff); // f16::MAX
        assert_eq!(f32_to_f16(65520.0), 0x7c00); // ties-to-even -> inf
        assert_eq!(f32_to_f16(1e9), 0x7c00); // overflow -> inf
        assert_eq!(f32_to_f16(2.0e-8), 0x0000); // below half min subnormal
        assert!(f16_to_f32(0x7e00).is_nan());
        assert_eq!(f32_to_f16(f32::NAN) & 0x7c00, 0x7c00);
        assert_ne!(f32_to_f16(f32::NAN) & 0x3ff, 0); // stays NaN, not inf
        // round-to-nearest-even at the first odd/even boundary:
        // 1 + 2^-11 is exactly halfway between 0x3c00 and 0x3c01
        assert_eq!(f32_to_f16(1.0 + 2.0f32.powi(-11)), 0x3c00);
        assert_eq!(f32_to_f16(1.0 + 3.0 * 2.0f32.powi(-11)), 0x3c02);
    }
}
