//! Numerical substrate: vector ops, the batched-GEMM kernel behind the
//! native model backend, special functions, statistics.

pub mod erf;
pub mod gemm;
pub mod isa;
pub mod stats;
pub mod vec_ops;

pub use erf::{erf, normal_cdf};
pub use vec_ops::*;
