//! Numerical substrate: vector ops, special functions, statistics.

pub mod erf;
pub mod stats;
pub mod vec_ops;

pub use erf::{erf, normal_cdf};
pub use vec_ops::*;
