//! Cache-blocked, register-tiled f32 GEMM for the native model backend.
//!
//! This is the kernel under `NativeMlp::denoise_batch`: every MLP layer
//! over a `B`-row batch is one `B×n_in · n_in×n_out` matrix product
//! with a fused bias + activation (+ residual) epilogue, instead of `B`
//! scalar `linear()` calls. Written as autovectorizer-friendly plain
//! Rust (no intrinsics, no unsafe in the serial path): exact-length
//! subslices let LLVM hoist the bounds checks and vectorize the
//! `j`-loops.
//!
//! **Determinism contract.** For every output element `c[i][j]` the
//! reduction over `p` (the shared dimension) runs in ascending order
//! starting from the bias, using plain IEEE mul/add (no `mul_add`):
//!
//! ```text
//! acc = bias[j];  for p in 0..k { acc += a[i][p] * b[p][j] }
//! ```
//!
//! Row-blocking (MR), k-panel blocking (KC) and M-dimension sharding
//! ([`gemm_sharded`]) only regroup *independent* output rows — they
//! never split or reorder a single element's reduction — so results are
//! bit-identical across tile shapes and pool sizes, and bit-identical
//! to [`gemm_ref`] (the naive triple loop with the same reduction
//! order). tests/test_properties.rs enforces both.
//!
//! The SiLU epilogue uses [`exp_fast`] — a branch-free Cody–Waite +
//! degree-6-polynomial `expf` the autovectorizer can turn into SIMD —
//! instead of scalar libm `expf`, which would otherwise dominate the
//! whole layer (a hidden layer is ~`n_in` MACs but only one `exp` per
//! output, and libm calls never vectorize). `exp_fast` is exact at 0
//! and within ~2 ulp elsewhere, so the GEMM forward tracks the scalar
//! libm reference (`NativeMlp::forward_one_ref`) to ~1e-7 relative per
//! layer — well inside the 1e-5 parity budget and the 2e-4 golden
//! tolerance.

use crate::runtime::pool;

/// Register-tile height: rows of `A` processed together so each loaded
/// row of `B` is reused MR times from registers.
pub const MR: usize = 4;

/// k-panel width (cache block): the slice of `B` touched per pass stays
/// resident in L1/L2 while MR-row blocks of `A` stream over it.
const KC: usize = 256;

/// Fused epilogue applied to the accumulator after the reduction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Epilogue {
    /// Store bias + A·B as-is (output layers).
    Linear,
    /// Store `silu(bias + A·B)` (hidden layers).
    Silu,
}

/// Branch-free `expf` approximation (Cody–Waite range reduction +
/// Cephes degree-6 minimax polynomial, 2^k scaling through the
/// exponent bits). Select-only control flow, no libm call — so the
/// epilogue loops vectorize. Exact at 0 (`exp_fast(0.0) == 1.0`),
/// ~2 ulp on `[-87.33, 88.3]`. Outside that: NaN propagates
/// (`f32::clamp` keeps NaN), `x > 88.3` (incl. `+inf`) returns `inf`
/// — saturating ~0.4 *earlier* than libm's 88.7228 overflow point —
/// and `x < -87.33` flushes to ~min-normal instead of going
/// subnormal → 0. Both divergences are below 1e-36 absolute once fed
/// through silu.
#[inline]
pub fn exp_fast(x: f32) -> f32 {
    let xc = x.clamp(-87.33, 88.3); // keeps k = round(x/ln2) <= 127
    // k = round(x / ln 2) via the 1.5·2^23 shift trick (SSE2-friendly,
    // unlike f32::round which needs SSE4.1 to stay vectorized)
    const SHIFT: f32 = 12_582_912.0; // 1.5 * 2^23
    let kf = (xc * std::f32::consts::LOG2_E + SHIFT) - SHIFT;
    // two-step range reduction: r = x - k ln 2, |r| <= ln2/2
    let r = (xc - kf * 0.693_359_375) - kf * (-2.121_944_4e-4);
    // exp(r) ~= 1 + r + r^2 P(r) (Cephes expf minimax coefficients)
    let p = 1.987_569_15e-4_f32;
    let p = p * r + 1.398_199_95e-3;
    let p = p * r + 8.333_451_9e-3;
    let p = p * r + 4.166_579_6e-2;
    let p = p * r + 1.666_666_55e-1;
    let p = p * r + 5.000_000_1e-1;
    let poly = (p * r + 1.0) * r + 1.0;
    // scale by 2^k through the exponent field (k in [-126, 127] after
    // the clamp, so 127 + k never leaves [1, 254]; NaN casts to 0)
    let scale = f32::from_bits(((127 + kf as i32) << 23) as u32);
    let y = poly * scale;
    // saturate the region the clamp capped straight to inf (libm
    // overflows at 88.7228; we overflow at the clamp point so there is
    // no band where the result silently underestimates). NaN fails the
    // compare and keeps y (= NaN); a float select, so the loop still
    // vectorizes (cmp + blend).
    if x > 88.3 { f32::INFINITY } else { y }
}

#[inline]
fn silu(x: f32) -> f32 {
    // silu(x) = x / (1 + e^-x). Edge semantics track the libm form:
    // NaN propagates through both operands, silu(-inf) = -inf/inf =
    // NaN, silu(+inf) = inf, deep-negative x gives -x/inf = -0.0.
    x / (1.0 + exp_fast(-x))
}

/// C[m×n] = epilogue(bias + A[m×k]·B[k×n]) (+ residual), all row-major.
///
/// * `bias`: length-`n` row added to every output row before the
///   reduction (it seeds the accumulator — same order as the scalar
///   path). `None` seeds with zero.
/// * `residual`: length `m*n`; when present the epilogue stores
///   `residual[i][j] + epi(acc)` — the fused skip-connection of the
///   MLP's hidden blocks.
///
/// `c` is fully overwritten; it must not alias `a`, `b` or `residual`.
pub fn gemm_bias_act(m: usize, n: usize, k: usize, a: &[f32], b: &[f32],
                     bias: Option<&[f32]>, epi: Epilogue,
                     residual: Option<&[f32]>, c: &mut [f32]) {
    assert_eq!(a.len(), m * k, "gemm: A is not m×k");
    assert_eq!(b.len(), k * n, "gemm: B is not k×n");
    assert_eq!(c.len(), m * n, "gemm: C is not m×n");
    if let Some(bias) = bias {
        assert_eq!(bias.len(), n, "gemm: bias is not length n");
    }
    if let Some(r) = residual {
        assert_eq!(r.len(), m * n, "gemm: residual is not m×n");
    }
    if m == 0 || n == 0 {
        return;
    }

    // seed the accumulators: C rows start at the bias (or zero)
    match bias {
        Some(bias) => {
            for row in c.chunks_exact_mut(n) {
                row.copy_from_slice(bias);
            }
        }
        None => c.fill(0.0),
    }

    // accumulate k-panels in ascending order (the determinism contract)
    let mut p0 = 0usize;
    while p0 < k {
        let pc = KC.min(k - p0);
        let mut i0 = 0usize;
        while i0 + MR <= m {
            kernel_mr(n, k, a, b, c, i0, p0, pc);
            i0 += MR;
        }
        while i0 < m {
            kernel_1(n, k, a, b, c, i0, p0, pc);
            i0 += 1;
        }
        p0 += pc;
    }

    // epilogue sweep (activation + fused residual add)
    match (epi, residual) {
        (Epilogue::Linear, None) => {}
        (Epilogue::Linear, Some(r)) => {
            for (ci, &ri) in c.iter_mut().zip(r) {
                *ci += ri;
            }
        }
        (Epilogue::Silu, None) => {
            for ci in c.iter_mut() {
                *ci = silu(*ci);
            }
        }
        (Epilogue::Silu, Some(r)) => {
            for (ci, &ri) in c.iter_mut().zip(r) {
                *ci = ri + silu(*ci);
            }
        }
    }
}

/// MR-row micro-kernel: accumulate `A[i0..i0+MR][p0..p0+pc] · B` into
/// the MR corresponding C rows. Every row of B loaded once per call is
/// reused MR times; the j-loops run over exact-length slices so the
/// autovectorizer sees bounds-check-free contiguous FMA chains.
#[inline]
fn kernel_mr(n: usize, k: usize, a: &[f32], b: &[f32], c: &mut [f32],
             i0: usize, p0: usize, pc: usize) {
    let cblk = &mut c[i0 * n..(i0 + MR) * n];
    let (c0, rest) = cblk.split_at_mut(n);
    let (c1, rest) = rest.split_at_mut(n);
    let (c2, c3) = rest.split_at_mut(n);
    let a0 = &a[i0 * k..i0 * k + k];
    let a1 = &a[(i0 + 1) * k..(i0 + 1) * k + k];
    let a2 = &a[(i0 + 2) * k..(i0 + 2) * k + k];
    let a3 = &a[(i0 + 3) * k..(i0 + 3) * k + k];
    for p in p0..p0 + pc {
        let (x0, x1, x2, x3) = (a0[p], a1[p], a2[p], a3[p]);
        let brow = &b[p * n..p * n + n];
        for j in 0..n {
            let bj = brow[j];
            c0[j] += x0 * bj;
            c1[j] += x1 * bj;
            c2[j] += x2 * bj;
            c3[j] += x3 * bj;
        }
    }
}

/// Single-row remainder kernel (same reduction order as `kernel_mr`).
#[inline]
fn kernel_1(n: usize, k: usize, a: &[f32], b: &[f32], c: &mut [f32],
            i0: usize, p0: usize, pc: usize) {
    let crow = &mut c[i0 * n..i0 * n + n];
    let arow = &a[i0 * k..i0 * k + k];
    for p in p0..p0 + pc {
        let x = arow[p];
        let brow = &b[p * n..p * n + n];
        for j in 0..n {
            crow[j] += x * brow[j];
        }
    }
}

/// Plain product without bias/activation.
pub fn gemm(m: usize, n: usize, k: usize, a: &[f32], b: &[f32],
            c: &mut [f32]) {
    gemm_bias_act(m, n, k, a, b, None, Epilogue::Linear, None, c);
}

/// Raw output pointer smuggled into `Fn` shards; sound because shards
/// write disjoint row ranges and the pool joins before `c` is reused.
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// [`gemm_bias_act`] with the M dimension split into up to `shards`
/// contiguous, MR-aligned row ranges executed concurrently on the
/// process-global worker pool. Output rows are independent (see the
/// determinism contract above), so the result is bit-identical to the
/// serial call for every shard count. Returns the effective shard
/// count.
pub fn gemm_sharded(m: usize, n: usize, k: usize, a: &[f32], b: &[f32],
                    bias: Option<&[f32]>, epi: Epilogue,
                    residual: Option<&[f32]>, c: &mut [f32],
                    shards: usize) -> usize {
    if shards <= 1 || m <= MR {
        gemm_bias_act(m, n, k, a, b, bias, epi, residual, c);
        return 1;
    }
    assert_eq!(a.len(), m * k, "gemm_sharded: A is not m×k");
    assert_eq!(c.len(), m * n, "gemm_sharded: C is not m×n");
    if let Some(r) = residual {
        assert_eq!(r.len(), m * n, "gemm_sharded: residual is not m×n");
    }
    let c_ptr = SendPtr(c.as_mut_ptr());
    pool::global().run_sharded_blocks(m, MR, shards, |r0, r1| {
        let rows = r1 - r0;
        // SAFETY: shard row ranges are disjoint and the pool joins
        // before `c` is touched again — no aliasing.
        let shard_c = unsafe {
            std::slice::from_raw_parts_mut(c_ptr.0.add(r0 * n), rows * n)
        };
        let shard_res = residual.map(|r| &r[r0 * n..r1 * n]);
        gemm_bias_act(rows, n, k, &a[r0 * k..r1 * k], b, bias, epi,
                      shard_res, shard_c);
    })
}

/// Naive triple-loop reference with the same per-element reduction
/// order — the oracle the blocked/tiled/sharded kernels are tested
/// against (bit-exact, not just approximately equal).
pub fn gemm_ref(m: usize, n: usize, k: usize, a: &[f32], b: &[f32],
                bias: Option<&[f32]>, epi: Epilogue,
                residual: Option<&[f32]>, c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = bias.map_or(0.0, |bv| bv[j]);
            for p in 0..k {
                acc += a[i * k + p] * b[p * n + j];
            }
            if epi == Epilogue::Silu {
                acc = silu(acc);
            }
            if let Some(r) = residual {
                // same operand order as the fused epilogue: res + act
                acc = r[i * n + j] + acc;
            }
            c[i * n + j] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(len: usize, seed: u32) -> Vec<f32> {
        (0..len)
            .map(|i| {
                let v = (i as u32).wrapping_mul(2654435761)
                    .wrapping_add(seed.wrapping_mul(40503));
                (v % 2003) as f32 / 2003.0 - 0.5
            })
            .collect()
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn blocked_matches_reference_bitwise_across_shapes() {
        // odd/rectangular shapes straddling the MR and KC boundaries
        for &(m, n, k) in &[(0usize, 3usize, 4usize), (1, 1, 1), (1, 7, 5),
                            (3, 2, 9), (4, 4, 4), (5, 3, 300), (7, 13, 257),
                            (8, 1, 2), (13, 17, 31)] {
            let a = fill(m * k, 1);
            let b = fill(k * n, 2);
            let bias = fill(n, 3);
            let res = fill(m * n, 4);
            for epi in [Epilogue::Linear, Epilogue::Silu] {
                for (bias_o, res_o) in [(None, None), (Some(&bias), None),
                                        (Some(&bias), Some(&res))] {
                    let mut want = vec![0.0f32; m * n];
                    gemm_ref(m, n, k, &a, &b, bias_o.map(|v| &v[..]), epi,
                             res_o.map(|v| &v[..]), &mut want);
                    let mut got = vec![7.0f32; m * n];
                    gemm_bias_act(m, n, k, &a, &b, bias_o.map(|v| &v[..]),
                                  epi, res_o.map(|v| &v[..]), &mut got);
                    assert_eq!(bits(&want), bits(&got),
                               "m={m} n={n} k={k} epi={epi:?}");
                }
            }
        }
    }

    #[test]
    fn sharded_matches_serial_bitwise() {
        let (m, n, k) = (37usize, 19usize, 23usize);
        let a = fill(m * k, 5);
        let b = fill(k * n, 6);
        let bias = fill(n, 7);
        let mut want = vec![0.0f32; m * n];
        gemm_bias_act(m, n, k, &a, &b, Some(&bias), Epilogue::Silu, None,
                      &mut want);
        for shards in [1usize, 2, 3, 8, 64] {
            let mut got = vec![0.0f32; m * n];
            let eff = gemm_sharded(m, n, k, &a, &b, Some(&bias),
                                   Epilogue::Silu, None, &mut got, shards);
            assert!(eff >= 1);
            assert_eq!(bits(&want), bits(&got), "shards={shards}");
        }
    }

    #[test]
    fn plain_gemm_identity() {
        // A · I == A
        let m = 5;
        let n = 6;
        let a = fill(m * n, 8);
        let mut eye = vec![0.0f32; n * n];
        for i in 0..n {
            eye[i * n + i] = 1.0;
        }
        let mut c = vec![0.0f32; m * n];
        gemm(m, n, n, &a, &eye, &mut c);
        assert_eq!(bits(&a), bits(&c));
    }

    #[test]
    fn silu_epilogue_matches_scalar_definition() {
        // 1×1 GEMM: c = silu(bias + a*b), silu built on exp_fast
        let mut c = vec![0.0f32];
        gemm_bias_act(1, 1, 1, &[2.0], &[3.0], Some(&[0.5]), Epilogue::Silu,
                      None, &mut c);
        let x = 0.5f32 + 2.0 * 3.0;
        assert_eq!(c[0].to_bits(), (x / (1.0 + exp_fast(-x))).to_bits());
        // and tracks the libm definition well inside the parity budget
        let libm = x / (1.0 + (-x).exp());
        assert!((c[0] - libm).abs() <= 1e-6 * libm.abs());
    }

    #[test]
    fn exp_fast_is_exact_at_zero_and_tracks_libm() {
        assert_eq!(exp_fast(0.0), 1.0);
        assert_eq!(exp_fast(-0.0), 1.0);
        for i in -8700..=8800 {
            let x = i as f32 * 0.01; // [-87, 88]: normal-range expf
            let want = x.exp();
            let got = exp_fast(x);
            let rel = ((got - want) / want).abs();
            assert!(rel < 1e-6,
                    "x={x}: libm {want} vs fast {got} (rel {rel})");
        }
        // non-finite / extreme semantics match the libm form
        assert!(exp_fast(f32::NAN).is_nan());
        assert_eq!(exp_fast(f32::INFINITY), f32::INFINITY);
        assert_eq!(exp_fast(100.0), f32::INFINITY); // libm overflow region
        // saturation starts right at the clamp point — no band where
        // the result silently underestimates
        assert_eq!(exp_fast(88.31), f32::INFINITY);
        assert!(exp_fast(88.3).is_finite());
        assert!((exp_fast(88.3) / 88.3f32.exp() - 1.0).abs() < 1e-6);
        assert!(exp_fast(f32::NEG_INFINITY) < 1.2e-38); // flushed, not 0
        assert!(silu(f32::NAN).is_nan());
        assert!(silu(f32::NEG_INFINITY).is_nan()); // -inf/inf, as libm
        assert_eq!(silu(f32::INFINITY), f32::INFINITY);
        // deep saturation: exact -0.0 on the left (x/inf), identity on
        // the right (denominator rounds to 1.0)
        assert_eq!(silu(-200.0), 0.0);
        assert!(silu(-200.0).is_sign_negative());
        assert_eq!(silu(200.0), 200.0);
    }

    #[test]
    #[should_panic(expected = "A is not m×k")]
    fn shape_mismatch_panics() {
        let mut c = vec![0.0f32; 4];
        gemm(2, 2, 3, &[0.0; 5], &[0.0; 6], &mut c);
    }
}
