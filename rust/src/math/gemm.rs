//! Cache-blocked, register-tiled f32 GEMM for the native model backend.
//!
//! This is the kernel under `NativeMlp::denoise_batch`: every MLP layer
//! over a `B`-row batch is one `B×n_in · n_in×n_out` matrix product
//! with a fused bias + activation (+ residual) epilogue, instead of `B`
//! scalar `linear()` calls. The portable kernels are
//! autovectorizer-friendly plain Rust (exact-length subslices and
//! fixed-size register tiles let LLVM hoist the bounds checks and
//! vectorize the `j`-loops); the packed path additionally has explicit
//! `std::arch` micro-kernels (AVX2+FMA on x86-64, NEON on aarch64)
//! selected at runtime through [`crate::math::isa`].
//!
//! Two kernel generations live here:
//!
//! * **v1** ([`gemm_bias_act`]) — MR-row register blocking over the
//!   caller's row-major `B`. Every micro-block re-streams `B` rows from
//!   memory.
//! * **v2 packed** ([`PackedB`] + [`gemm_packed_bias_act`]) — BLIS-style
//!   prepacked panels: `B` is repacked **once** (at model load for MLP
//!   weights) into `KC×NR` column panels, and an `MR×NR` register-tiled
//!   micro-kernel accumulates into a local C tile that stays in
//!   registers for a whole k-panel. Panel loads are contiguous
//!   exact-`NR` slices, so the hot loop is pure SIMD FMA with no
//!   strided traffic — the win is largest for the small-M GEMMs of
//!   fused serving rounds, where v1's bandwidth is wasted re-streaming
//!   weights.
//!
//! **Determinism contract (tiered — see [`crate::math::isa`]).** For
//! every output element `c[i][j]` the reduction over `p` (the shared
//! dimension) runs in ascending order starting from the bias. The
//! portable kernels use plain IEEE mul/add (no `mul_add`):
//!
//! ```text
//! acc = bias[j];  for p in 0..k { acc += a[i][p] * b[p][j] }
//! ```
//!
//! Row-blocking (MR), column panels (NR), k-panel blocking (KC) and
//! 2-D M×N sharding ([`gemm_sharded`], [`gemm_packed_sharded_on`]) only
//! regroup *independent* output elements — they never split or reorder
//! a single element's reduction. The packed micro-kernel loads each
//! MR×NR C tile into a register tile once per k-panel and replays the
//! identical ascending-`p` sequence there before storing back. From
//! that shared skeleton the three determinism tiers follow:
//!
//! * **bit-exact** — the portable f32 kernels
//!   ([`Isa::Portable`][crate::math::isa::Isa], the default for the
//!   plain `gemm_packed_bias_act` / `gemm_packed_sharded` entries)
//!   replay the same IEEE op stream per element as v1 and are
//!   **bit-identical to [`gemm_ref`]** for every tile shape and shard
//!   count, on every host. This is the seed contract, unchanged.
//! * **reproducible-given-config** — the SIMD f32 kernels fuse the
//!   mul/add into FMA, so bits differ from `gemm_ref`; but IEEE FMA is
//!   exactly rounded, the remainder rows run a one-row *vector* kernel
//!   with the same per-lane op stream as an MR-block lane, tile row
//!   starts are always MR-aligned and column starts NR-aligned, and
//!   the kernel is picked once per GEMM call ([`Isa`] argument of
//!   [`gemm_packed_bias_act_on`]) — never per tile. Hence for a fixed
//!   resolved ISA the output is bit-stable across shard counts, tile
//!   grids and steal schedules.
//! * **quantized-with-error-bound** — f16/int8 [`PackedB`] stores
//!   ([`PackedB::pack_as`]) dequantize inside the kernel (f16 per
//!   element before the FMA; int8 per k-panel in the epilogue). They
//!   track `gemm_ref` within
//!   [`crate::math::isa::gemm_rel_tolerance`] and are still
//!   shard/schedule bit-stable for a fixed config.
//!
//! tests/test_properties.rs and the in-module tests enforce all of it.
//!
//! The SiLU epilogue uses [`exp_fast`] — a branch-free Cody–Waite +
//! degree-6-polynomial `expf` the autovectorizer can turn into SIMD —
//! instead of scalar libm `expf`, which would otherwise dominate the
//! whole layer (a hidden layer is ~`n_in` MACs but only one `exp` per
//! output, and libm calls never vectorize). `exp_fast` is exact at 0
//! and within ~2 ulp elsewhere, so the GEMM forward tracks the scalar
//! libm reference (`NativeMlp::forward_one_ref`) to ~1e-7 relative per
//! layer — well inside the 1e-5 parity budget and the 2e-4 golden
//! tolerance.

use crate::math::isa::{f16_to_f32, f32_to_f16, Isa, Precision};
use crate::runtime::pool;

/// Register-tile height: rows of `A` processed together so each loaded
/// row (v1) or panel row (packed) of `B` is reused MR times from
/// registers.
pub const MR: usize = 4;

/// Column-panel width of the packed layout: the packed micro-kernel
/// produces an MR×NR C tile per k-panel pass, reading exact-`NR`
/// contiguous panel rows (one SIMD-friendly slice per `p`).
pub const NR: usize = 8;

/// k-panel height (cache block): the slice of `B` touched per pass
/// stays resident in L1/L2 while MR-row blocks of `A` stream over it.
pub const KC: usize = 256;

/// Fused epilogue applied to the accumulator after the reduction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Epilogue {
    /// Store bias + A·B as-is (output layers).
    Linear,
    /// Store `silu(bias + A·B)` (hidden layers).
    Silu,
}

/// Branch-free `expf` approximation (Cody–Waite range reduction +
/// Cephes degree-6 minimax polynomial, 2^k scaling through the
/// exponent bits). Select-only control flow, no libm call — so the
/// epilogue loops vectorize. Exact at 0 (`exp_fast(0.0) == 1.0`),
/// ~2 ulp on `[-87.33, 88.3]`. Outside that: NaN propagates
/// (`f32::clamp` keeps NaN), `x > 88.3` (incl. `+inf`) returns `inf`
/// — saturating ~0.4 *earlier* than libm's 88.7228 overflow point —
/// and `x < -87.33` flushes to ~min-normal instead of going
/// subnormal → 0. Both divergences are below 1e-36 absolute once fed
/// through silu.
#[inline]
pub fn exp_fast(x: f32) -> f32 {
    let xc = x.clamp(-87.33, 88.3); // keeps k = round(x/ln2) <= 127
    // k = round(x / ln 2) via the 1.5·2^23 shift trick (SSE2-friendly,
    // unlike f32::round which needs SSE4.1 to stay vectorized)
    const SHIFT: f32 = 12_582_912.0; // 1.5 * 2^23
    let kf = (xc * std::f32::consts::LOG2_E + SHIFT) - SHIFT;
    // two-step range reduction: r = x - k ln 2, |r| <= ln2/2
    let r = (xc - kf * 0.693_359_375) - kf * (-2.121_944_4e-4);
    // exp(r) ~= 1 + r + r^2 P(r) (Cephes expf minimax coefficients)
    let p = 1.987_569_15e-4_f32;
    let p = p * r + 1.398_199_95e-3;
    let p = p * r + 8.333_451_9e-3;
    let p = p * r + 4.166_579_6e-2;
    let p = p * r + 1.666_666_55e-1;
    let p = p * r + 5.000_000_1e-1;
    let poly = (p * r + 1.0) * r + 1.0;
    // scale by 2^k through the exponent field (k in [-126, 127] after
    // the clamp, so 127 + k never leaves [1, 254]; NaN casts to 0)
    let scale = f32::from_bits(((127 + kf as i32) << 23) as u32);
    let y = poly * scale;
    // saturate the region the clamp capped straight to inf (libm
    // overflows at 88.7228; we overflow at the clamp point so there is
    // no band where the result silently underestimates). NaN fails the
    // compare and keeps y (= NaN); a float select, so the loop still
    // vectorizes (cmp + blend).
    if x > 88.3 { f32::INFINITY } else { y }
}

#[inline]
fn silu(x: f32) -> f32 {
    // silu(x) = x / (1 + e^-x). Edge semantics track the libm form:
    // NaN propagates through both operands, silu(-inf) = -inf/inf =
    // NaN, silu(+inf) = inf, deep-negative x gives -x/inf = -0.0.
    x / (1.0 + exp_fast(-x))
}

/// Disjoint-region view of `C` handed to tile shards. Every tile owns
/// an exclusive rows×columns rectangle no other tile touches, so the
/// per-row slices materialized through [`CView::row`] never alias —
/// the same argument the M-sharded v1 made for whole rows, extended to
/// column ranges (a row-range `&mut` subslice can't express "columns
/// j0..j1 of rows r0..r1", hence the raw pointer).
struct CView {
    ptr: *mut f32,
    n: usize,
}

unsafe impl Send for CView {}
unsafe impl Sync for CView {}

impl CView {
    /// Columns `j0..j0+jw` of row `i` as an exclusive slice.
    ///
    /// SAFETY: the caller must own `[i*n + j0, i*n + j0 + jw)`
    /// exclusively while the returned slice lives, and the underlying
    /// buffer must outlive the pool join (both hold for tile shards:
    /// tiles are pairwise disjoint and the submitting thread blocks
    /// until every shard finished).
    #[allow(clippy::mut_from_ref)]
    unsafe fn row(&self, i: usize, j0: usize, jw: usize) -> &mut [f32] {
        std::slice::from_raw_parts_mut(self.ptr.add(i * self.n + j0), jw)
    }
}

/// Seed the `[r0, r1) × [j0, j1)` region of C with the bias row (or
/// zero) — the reduction's starting value, same order as the scalar
/// path.
fn region_seed(cv: &CView, r0: usize, r1: usize, j0: usize, j1: usize,
               bias: Option<&[f32]>) {
    for i in r0..r1 {
        // SAFETY: this tile owns the region (see CView::row).
        let row = unsafe { cv.row(i, j0, j1 - j0) };
        match bias {
            Some(bv) => row.copy_from_slice(&bv[j0..j1]),
            None => row.fill(0.0),
        }
    }
}

/// Apply the fused epilogue (activation + residual add) to the
/// `[r0, r1) × [j0, j1)` region of C.
fn region_epilogue(cv: &CView, n: usize, r0: usize, r1: usize, j0: usize,
                   j1: usize, epi: Epilogue, residual: Option<&[f32]>) {
    let jw = j1 - j0;
    for i in r0..r1 {
        // SAFETY: this tile owns the region (see CView::row).
        let row = unsafe { cv.row(i, j0, jw) };
        match (epi, residual) {
            (Epilogue::Linear, None) => {}
            (Epilogue::Linear, Some(r)) => {
                let rrow = &r[i * n + j0..i * n + j1];
                for (ci, &ri) in row.iter_mut().zip(rrow) {
                    *ci += ri;
                }
            }
            (Epilogue::Silu, None) => {
                for ci in row.iter_mut() {
                    *ci = silu(*ci);
                }
            }
            (Epilogue::Silu, Some(r)) => {
                let rrow = &r[i * n + j0..i * n + j1];
                for (ci, &ri) in row.iter_mut().zip(rrow) {
                    *ci = ri + silu(*ci);
                }
            }
        }
    }
}

/// Full bias→accumulate→epilogue computation of one C region against
/// the *unpacked* row-major `B` (the v1 kernel, generalized to column
/// ranges so 2-D shards can call it per tile).
fn unpacked_region(n: usize, k: usize, a: &[f32], b: &[f32],
                   bias: Option<&[f32]>, epi: Epilogue,
                   residual: Option<&[f32]>, cv: &CView, r0: usize,
                   r1: usize, j0: usize, j1: usize) {
    if r1 <= r0 || j1 <= j0 {
        return;
    }
    region_seed(cv, r0, r1, j0, j1, bias);
    // accumulate k-panels in ascending order (the determinism contract)
    let mut p0 = 0usize;
    while p0 < k {
        let pc = KC.min(k - p0);
        let mut i0 = r0;
        while i0 + MR <= r1 {
            kernel_mr(n, k, a, b, cv, i0, p0, pc, j0, j1);
            i0 += MR;
        }
        while i0 < r1 {
            kernel_1(n, k, a, b, cv, i0, p0, pc, j0, j1);
            i0 += 1;
        }
        p0 += pc;
    }
    region_epilogue(cv, n, r0, r1, j0, j1, epi, residual);
}

/// C[m×n] = epilogue(bias + A[m×k]·B[k×n]) (+ residual), all row-major.
///
/// * `bias`: length-`n` row added to every output row before the
///   reduction (it seeds the accumulator — same order as the scalar
///   path). `None` seeds with zero.
/// * `residual`: length `m*n`; when present the epilogue stores
///   `residual[i][j] + epi(acc)` — the fused skip-connection of the
///   MLP's hidden blocks.
///
/// `c` is fully overwritten; it must not alias `a`, `b` or `residual`.
pub fn gemm_bias_act(m: usize, n: usize, k: usize, a: &[f32], b: &[f32],
                     bias: Option<&[f32]>, epi: Epilogue,
                     residual: Option<&[f32]>, c: &mut [f32]) {
    assert_eq!(a.len(), m * k, "gemm: A is not m×k");
    assert_eq!(b.len(), k * n, "gemm: B is not k×n");
    assert_eq!(c.len(), m * n, "gemm: C is not m×n");
    if let Some(bias) = bias {
        assert_eq!(bias.len(), n, "gemm: bias is not length n");
    }
    if let Some(r) = residual {
        assert_eq!(r.len(), m * n, "gemm: residual is not m×n");
    }
    if m == 0 || n == 0 {
        return;
    }
    let cv = CView { ptr: c.as_mut_ptr(), n };
    unpacked_region(n, k, a, b, bias, epi, residual, &cv, 0, m, 0, n);
}

/// MR-row micro-kernel over columns `[j0, j1)`: accumulate
/// `A[i0..i0+MR][p0..p0+pc] · B[.., j0..j1]` into the MR corresponding
/// C row slices. Every B row slice loaded once per call is reused MR
/// times; the j-loops run over exact-length slices so the
/// autovectorizer sees bounds-check-free contiguous FMA chains.
#[inline]
fn kernel_mr(n: usize, k: usize, a: &[f32], b: &[f32], cv: &CView,
             i0: usize, p0: usize, pc: usize, j0: usize, j1: usize) {
    let jw = j1 - j0;
    // SAFETY: rows i0..i0+MR × columns j0..j1 belong to this tile.
    let (c0, c1, c2, c3) = unsafe {
        (cv.row(i0, j0, jw), cv.row(i0 + 1, j0, jw), cv.row(i0 + 2, j0, jw),
         cv.row(i0 + 3, j0, jw))
    };
    let a0 = &a[i0 * k..i0 * k + k];
    let a1 = &a[(i0 + 1) * k..(i0 + 1) * k + k];
    let a2 = &a[(i0 + 2) * k..(i0 + 2) * k + k];
    let a3 = &a[(i0 + 3) * k..(i0 + 3) * k + k];
    for p in p0..p0 + pc {
        let (x0, x1, x2, x3) = (a0[p], a1[p], a2[p], a3[p]);
        let brow = &b[p * n + j0..p * n + j1];
        for j in 0..jw {
            let bj = brow[j];
            c0[j] += x0 * bj;
            c1[j] += x1 * bj;
            c2[j] += x2 * bj;
            c3[j] += x3 * bj;
        }
    }
}

/// Single-row remainder kernel (same reduction order as `kernel_mr`).
#[inline]
fn kernel_1(n: usize, k: usize, a: &[f32], b: &[f32], cv: &CView,
            i0: usize, p0: usize, pc: usize, j0: usize, j1: usize) {
    let jw = j1 - j0;
    // SAFETY: row i0 × columns j0..j1 belong to this tile.
    let crow = unsafe { cv.row(i0, j0, jw) };
    let arow = &a[i0 * k..i0 * k + k];
    for p in p0..p0 + pc {
        let x = arow[p];
        let brow = &b[p * n + j0..p * n + j1];
        for j in 0..jw {
            crow[j] += x * brow[j];
        }
    }
}

/// Plain product without bias/activation.
pub fn gemm(m: usize, n: usize, k: usize, a: &[f32], b: &[f32],
            c: &mut [f32]) {
    gemm_bias_act(m, n, k, a, b, None, Epilogue::Linear, None, c);
}

// ---------------------------------------------------------------------
// v2: prepacked KC×NR column panels + MR×NR register-tiled micro-kernel
// ---------------------------------------------------------------------

/// A weight matrix repacked once into KC×NR column panels — the
/// load-time half of the v2 kernel.
///
/// Layout: the `k` rows are cut into KC-high k-panels (ascending), and
/// within each k-panel the `n` columns into NR-wide column panels;
/// each `(k-panel, column-panel)` block stores its `pc × NR` floats
/// contiguously, panel-row-major:
///
/// ```text
/// data[p0 * n_padded  +  jp * pc * NR  +  (p - p0) * NR  +  (j - jp*NR)]
/// ```
///
/// The last column panel is zero-padded to NR (padding columns are
/// computed in registers and never stored), so every panel row the
/// micro-kernel touches is one exact-`NR` contiguous slice. `n_padded`
/// is `n` rounded up to NR, and `p0 * n_padded` is exactly the size of
/// all preceding k-panels.
///
/// Besides the full-f32 store the panels can be packed at reduced
/// precision ([`PackedB::pack_as`]):
///
/// * **f16** — the same layout holding IEEE binary16 bit patterns
///   (`u16`), half the L2 footprint. Dequant (`f16_to_f32`, exact) is
///   fused into the kernel's panel-row load.
/// * **int8** — the same layout holding `i8` quants, plus one f32
///   scale per `(k-panel, column)` (`scales[(p0/KC) * n_padded + j]`,
///   where `scale = colmax/127` over that k-panel's column and
///   `q = round(w/scale)`), a quarter the footprint. The kernel
///   accumulates `a · q` into a zeroed register tile per k-panel and
///   applies `C[i][j] += t[i][j] * scale[j]` as a fused dequant
///   epilogue. An all-zero column (in particular the zero padding)
///   gets `scale = 0`, so its dequantized value is exactly `0.0`.
#[derive(Debug, Clone)]
enum PanelStore {
    F32(Vec<f32>),
    F16(Vec<u16>),
    Int8 { q: Vec<i8>, scales: Vec<f32> },
}

/// See [`PanelStore`] docs above for the reduced-precision variants.
#[derive(Debug, Clone)]
pub struct PackedB {
    k: usize,
    n: usize,
    /// n rounded up to the next NR multiple (elements per packed k-row)
    n_padded: usize,
    store: PanelStore,
}

/// Walk the packed layout's `(k-panel × column-panel)` blocks in store
/// order, handing each one `(p0, pc, j0, jw, base, panel_len)`. The
/// per-k-panel flat base and panel length are computed once per
/// k-panel (not per column panel), and the bounds are debug-asserted
/// against the buffer size so a precision variant can't silently read
/// or write past the zero padding.
fn for_each_panel(k: usize, n: usize, n_padded: usize,
                  mut f: impl FnMut(usize, usize, usize, usize, usize,
                                    usize)) {
    let total = k * n_padded;
    let mut p0 = 0usize;
    while p0 < k {
        let pc = KC.min(k - p0);
        // hoisted per k-panel: all preceding k-panels occupy exactly
        // p0 * n_padded elements, and every panel in this k-panel is
        // pc * NR long
        let kp_base = p0 * n_padded;
        let panel_len = pc * NR;
        for jp in 0..n_padded / NR {
            let j0 = jp * NR;
            let jw = NR.min(n - j0);
            let base = kp_base + jp * panel_len;
            debug_assert!(
                base + panel_len <= total,
                "packed panel (p0={p0}, jp={jp}) overruns the buffer"
            );
            f(p0, pc, j0, jw, base, panel_len);
        }
        p0 += pc;
    }
}

impl PackedB {
    /// Repack a row-major `k×n` matrix at full f32 precision. O(k·n)
    /// copy, done once per matrix lifetime (model load for MLP
    /// weights).
    pub fn pack(k: usize, n: usize, b: &[f32]) -> PackedB {
        PackedB::pack_as(k, n, b, Precision::F32)
    }

    /// Repack at the given panel precision (see the type docs for the
    /// quantization schemes).
    pub fn pack_as(k: usize, n: usize, b: &[f32],
                   precision: Precision) -> PackedB {
        assert_eq!(b.len(), k * n, "PackedB: B is not k×n");
        let n_padded = n.div_ceil(NR) * NR;
        let store = match precision {
            Precision::F32 => {
                let mut data = vec![0.0f32; k * n_padded];
                for_each_panel(k, n, n_padded, |p0, pc, j0, jw, base,
                                                panel_len| {
                    let panel = &mut data[base..base + panel_len];
                    for dp in 0..pc {
                        panel[dp * NR..dp * NR + jw].copy_from_slice(
                            &b[(p0 + dp) * n + j0..][..jw]);
                    }
                });
                PanelStore::F32(data)
            }
            Precision::F16 => {
                let mut data = vec![0u16; k * n_padded];
                for_each_panel(k, n, n_padded, |p0, pc, j0, jw, base,
                                                panel_len| {
                    let panel = &mut data[base..base + panel_len];
                    for dp in 0..pc {
                        let src = &b[(p0 + dp) * n + j0..][..jw];
                        for (dst, &w) in
                            panel[dp * NR..dp * NR + jw].iter_mut()
                                                        .zip(src) {
                            *dst = f32_to_f16(w);
                        }
                    }
                });
                PanelStore::F16(data)
            }
            Precision::Int8 => {
                let mut q = vec![0i8; k * n_padded];
                let mut scales = vec![0.0f32; k.div_ceil(KC) * n_padded];
                for_each_panel(k, n, n_padded, |p0, pc, j0, jw, base,
                                                panel_len| {
                    let srow = (p0 / KC) * n_padded;
                    let panel = &mut q[base..base + panel_len];
                    for dj in 0..jw {
                        let j = j0 + dj;
                        let mut colmax = 0.0f32;
                        for dp in 0..pc {
                            colmax = colmax.max(b[(p0 + dp) * n + j].abs());
                        }
                        let scale = colmax / 127.0;
                        scales[srow + j] = scale;
                        if scale == 0.0 {
                            continue; // all-zero column: q stays 0
                        }
                        for dp in 0..pc {
                            let w = b[(p0 + dp) * n + j];
                            panel[dp * NR + dj] =
                                (w / scale).round().clamp(-127.0, 127.0)
                                    as i8;
                        }
                    }
                });
                PanelStore::Int8 { q, scales }
            }
        };
        PackedB { k, n, n_padded, store }
    }

    /// Rows of the packed matrix (the GEMM's shared dimension).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Columns of the packed matrix (the GEMM's output width).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Precision the panels are stored at.
    pub fn precision(&self) -> Precision {
        match &self.store {
            PanelStore::F32(_) => Precision::F32,
            PanelStore::F16(_) => Precision::F16,
            PanelStore::Int8 { .. } => Precision::Int8,
        }
    }

    /// Bytes held by the packed store (the load-time memory cost;
    /// `k * round_up(n, NR) * 4` for f32, half that for f16, about a
    /// quarter for int8).
    pub fn bytes(&self) -> usize {
        match &self.store {
            PanelStore::F32(d) => d.len() * 4,
            PanelStore::F16(d) => d.len() * 2,
            PanelStore::Int8 { q, scales } => q.len() + scales.len() * 4,
        }
    }

    /// The value the kernels will use for element `(p, j)` after
    /// dequantization, including the zero-padding columns
    /// (`j < n_padded`). Test/oracle accessor, not a hot path.
    pub fn stored(&self, p: usize, j: usize) -> f32 {
        assert!(p < self.k && j < self.n_padded, "stored({p},{j}) oob");
        let p0 = (p / KC) * KC;
        let pc = KC.min(self.k - p0);
        let jp = j / NR;
        let idx = self.panel_base(p0, pc, jp) + (p - p0) * NR + (j % NR);
        match &self.store {
            PanelStore::F32(d) => d[idx],
            PanelStore::F16(d) => f16_to_f32(d[idx]),
            PanelStore::Int8 { q, scales } => {
                q[idx] as f32 * scales[(p0 / KC) * self.n_padded + j]
            }
        }
    }

    /// Flat offset of the panel for k-panel starting at `p0` (height
    /// `pc`) and column panel `jp`, bounds-asserted in debug builds.
    #[inline]
    fn panel_base(&self, p0: usize, pc: usize, jp: usize) -> usize {
        let base = p0 * self.n_padded + jp * pc * NR;
        debug_assert!(base + pc * NR <= self.k * self.n_padded,
                      "packed panel (p0={p0}, jp={jp}) overruns the buffer");
        base
    }

    /// The `pc × NR` f32 panel (panics if stored at another precision
    /// — the dispatch table matches on the store first).
    #[inline]
    fn panel_f32(&self, p0: usize, pc: usize, jp: usize) -> &[f32] {
        let base = self.panel_base(p0, pc, jp);
        match &self.store {
            PanelStore::F32(d) => &d[base..base + pc * NR],
            _ => unreachable!("panel_f32 on non-f32 store"),
        }
    }

    /// The `pc × NR` binary16 panel.
    #[inline]
    fn panel_f16(&self, p0: usize, pc: usize, jp: usize) -> &[u16] {
        let base = self.panel_base(p0, pc, jp);
        match &self.store {
            PanelStore::F16(d) => &d[base..base + pc * NR],
            _ => unreachable!("panel_f16 on non-f16 store"),
        }
    }

    /// The `pc × NR` int8 panel plus its NR per-column dequant scales.
    #[inline]
    fn panel_i8(&self, p0: usize, pc: usize, jp: usize)
                -> (&[i8], &[f32]) {
        let base = self.panel_base(p0, pc, jp);
        match &self.store {
            PanelStore::Int8 { q, scales } => {
                let srow = (p0 / KC) * self.n_padded + jp * NR;
                (&q[base..base + pc * NR], &scales[srow..srow + NR])
            }
            _ => unreachable!("panel_i8 on non-int8 store"),
        }
    }
}

/// Full bias→accumulate→epilogue computation of one C region against a
/// [`PackedB`]. `j0` must be NR-aligned; `j1` is NR-aligned or `n`
/// (both guaranteed by [`pool::ThreadPool::run_sharded_tiles`] and the
/// serial entry). `isa` selects the micro-kernel for the whole region
/// — the caller resolved it once per GEMM call, so every tile of one
/// product runs the same kernel.
fn packed_region(isa: Isa, n: usize, k: usize, a: &[f32], pb: &PackedB,
                 bias: Option<&[f32]>, epi: Epilogue,
                 residual: Option<&[f32]>, cv: &CView, r0: usize, r1: usize,
                 j0: usize, j1: usize) {
    if r1 <= r0 || j1 <= j0 {
        return;
    }
    debug_assert_eq!(j0 % NR, 0, "packed tile start must be NR-aligned");
    region_seed(cv, r0, r1, j0, j1, bias);
    let (jp0, jp1) = (j0 / NR, j1.div_ceil(NR));
    // k-panels ascending (the determinism contract); within a k-panel
    // each MR×NR C tile accumulates ascending-p in registers, which is
    // the identical per-element op sequence for every tiling
    let mut p0 = 0usize;
    while p0 < k {
        let pc = KC.min(k - p0);
        for jp in jp0..jp1 {
            let jcol = jp * NR;
            let jw = NR.min(j1 - jcol);
            run_panel_rows(isa, k, a, pb, cv, r0, r1, jcol, jw, p0, pc,
                           jp);
        }
        p0 += pc;
    }
    region_epilogue(cv, n, r0, r1, j0, j1, epi, residual);
}

/// The kernel dispatch table: one `(store precision, resolved ISA)`
/// match selecting the micro-kernel that sweeps rows `[r0, r1)` of one
/// `(k-panel × column-panel)` block. SIMD arms exist only on their
/// architecture (`#[cfg]` on the match arm); everything else falls
/// through to the portable kernels, which accept every store. The
/// f16 AVX2 kernel additionally needs F16C for the (exact) hardware
/// dequant — without it f16 routes portable; NEON runs f32 only.
#[inline]
#[allow(unused_variables)] // `isa` is unused on non-SIMD architectures
fn run_panel_rows(isa: Isa, k: usize, a: &[f32], pb: &PackedB,
                  cv: &CView, r0: usize, r1: usize, jcol: usize,
                  jw: usize, p0: usize, pc: usize, jp: usize) {
    match &pb.store {
        PanelStore::F32(_) => {
            let panel = pb.panel_f32(p0, pc, jp);
            match isa {
                #[cfg(target_arch = "x86_64")]
                // SAFETY: `isa` is only ever Avx2 when the host
                // supports AVX2+FMA (resolve() guarantees it)
                Isa::Avx2 => unsafe {
                    avx2::run_rows_f32(k, a, panel, cv, r0, r1, jcol, jw,
                                       p0, pc)
                },
                #[cfg(target_arch = "aarch64")]
                // SAFETY: NEON is baseline on aarch64
                Isa::Neon => unsafe {
                    neon::run_rows_f32(k, a, panel, cv, r0, r1, jcol, jw,
                                       p0, pc)
                },
                _ => run_rows_f32_portable(k, a, panel, cv, r0, r1, jcol,
                                           jw, p0, pc),
            }
        }
        PanelStore::F16(_) => {
            let panel = pb.panel_f16(p0, pc, jp);
            match isa {
                #[cfg(target_arch = "x86_64")]
                // SAFETY: as above, plus the F16C guard for vcvtph2ps
                Isa::Avx2 if crate::math::isa::host_has_f16c() => unsafe {
                    avx2::run_rows_f16(k, a, panel, cv, r0, r1, jcol, jw,
                                       p0, pc)
                },
                _ => run_rows_f16_portable(k, a, panel, cv, r0, r1, jcol,
                                           jw, p0, pc),
            }
        }
        PanelStore::Int8 { .. } => {
            let (panel, scales) = pb.panel_i8(p0, pc, jp);
            match isa {
                #[cfg(target_arch = "x86_64")]
                // SAFETY: as above
                Isa::Avx2 => unsafe {
                    avx2::run_rows_i8(k, a, panel, scales, cv, r0, r1,
                                      jcol, jw, p0, pc)
                },
                _ => run_rows_i8_portable(k, a, panel, scales, cv, r0, r1,
                                          jcol, jw, p0, pc),
            }
        }
    }
}

/// Portable f32 row sweep: MR-row register tiles plus single-row
/// remainder, identical op stream to v1 (the bit-exact tier).
fn run_rows_f32_portable(k: usize, a: &[f32], panel: &[f32], cv: &CView,
                         r0: usize, r1: usize, jcol: usize, jw: usize,
                         p0: usize, pc: usize) {
    let mut i0 = r0;
    while i0 + MR <= r1 {
        kernel_packed_mr(k, a, panel, cv, i0, jcol, jw, p0, pc);
        i0 += MR;
    }
    while i0 < r1 {
        kernel_packed_1(k, a, panel, cv, i0, jcol, jw, p0, pc);
        i0 += 1;
    }
}

/// Portable f16 row sweep: each panel row is dequantized into a local
/// `[f32; NR]` (exact, so this matches the f32 portable kernel run on
/// the dequantized matrix bit for bit) and accumulated exactly like
/// the f32 kernel.
fn run_rows_f16_portable(k: usize, a: &[f32], panel: &[u16], cv: &CView,
                         r0: usize, r1: usize, jcol: usize, jw: usize,
                         p0: usize, pc: usize) {
    let mut i0 = r0;
    while i0 + MR <= r1 {
        kernel_packed_mr_f16(k, a, panel, cv, i0, jcol, jw, p0, pc);
        i0 += MR;
    }
    while i0 < r1 {
        kernel_packed_1_f16(k, a, panel, cv, i0, jcol, jw, p0, pc);
        i0 += 1;
    }
}

/// Portable int8 row sweep: raw `a · q` accumulation into a zeroed
/// register tile, per-column scale applied once per k-panel as the
/// fused dequant epilogue.
fn run_rows_i8_portable(k: usize, a: &[f32], panel: &[i8], scales: &[f32],
                        cv: &CView, r0: usize, r1: usize, jcol: usize,
                        jw: usize, p0: usize, pc: usize) {
    let mut i0 = r0;
    while i0 + MR <= r1 {
        kernel_packed_mr_i8(k, a, panel, scales, cv, i0, jcol, jw, p0, pc);
        i0 += MR;
    }
    while i0 < r1 {
        kernel_packed_1_i8(k, a, panel, scales, cv, i0, jcol, jw, p0, pc);
        i0 += 1;
    }
}

/// MR×NR register-tiled packed micro-kernel: load the C tile into a
/// local `[ [f32; NR]; MR ]` (zero in the padding lanes), replay the
/// ascending-p accumulation against exact-`NR` panel rows entirely in
/// registers, store the valid `jw` columns back. Padding lanes
/// accumulate `x * 0.0` and are never stored. The per-element op
/// sequence matches the v1 in-memory accumulation bit for bit.
#[inline]
fn kernel_packed_mr(k: usize, a: &[f32], panel: &[f32], cv: &CView,
                    i0: usize, jcol: usize, jw: usize, p0: usize,
                    pc: usize) {
    // SAFETY: rows i0..i0+MR × columns jcol..jcol+jw belong to this
    // tile.
    let (c0, c1, c2, c3) = unsafe {
        (cv.row(i0, jcol, jw), cv.row(i0 + 1, jcol, jw),
         cv.row(i0 + 2, jcol, jw), cv.row(i0 + 3, jcol, jw))
    };
    let mut t = [[0.0f32; NR]; MR];
    t[0][..jw].copy_from_slice(c0);
    t[1][..jw].copy_from_slice(c1);
    t[2][..jw].copy_from_slice(c2);
    t[3][..jw].copy_from_slice(c3);
    let a0 = &a[i0 * k..i0 * k + k];
    let a1 = &a[(i0 + 1) * k..(i0 + 1) * k + k];
    let a2 = &a[(i0 + 2) * k..(i0 + 2) * k + k];
    let a3 = &a[(i0 + 3) * k..(i0 + 3) * k + k];
    for dp in 0..pc {
        let brow: &[f32; NR] =
            panel[dp * NR..(dp + 1) * NR].try_into().unwrap();
        let p = p0 + dp;
        let (x0, x1, x2, x3) = (a0[p], a1[p], a2[p], a3[p]);
        for j in 0..NR {
            let bj = brow[j];
            t[0][j] += x0 * bj;
            t[1][j] += x1 * bj;
            t[2][j] += x2 * bj;
            t[3][j] += x3 * bj;
        }
    }
    c0.copy_from_slice(&t[0][..jw]);
    c1.copy_from_slice(&t[1][..jw]);
    c2.copy_from_slice(&t[2][..jw]);
    c3.copy_from_slice(&t[3][..jw]);
}

/// Single-row packed remainder kernel (same reduction order).
#[inline]
fn kernel_packed_1(k: usize, a: &[f32], panel: &[f32], cv: &CView,
                   i0: usize, jcol: usize, jw: usize, p0: usize,
                   pc: usize) {
    // SAFETY: row i0 × columns jcol..jcol+jw belong to this tile.
    let crow = unsafe { cv.row(i0, jcol, jw) };
    let mut t = [0.0f32; NR];
    t[..jw].copy_from_slice(crow);
    let arow = &a[i0 * k..i0 * k + k];
    for dp in 0..pc {
        let brow: &[f32; NR] =
            panel[dp * NR..(dp + 1) * NR].try_into().unwrap();
        let x = arow[p0 + dp];
        for j in 0..NR {
            t[j] += x * brow[j];
        }
    }
    crow.copy_from_slice(&t[..jw]);
}

/// MR×NR f16 micro-kernel: [`kernel_packed_mr`] with an exact
/// per-panel-row dequant in front of the accumulation.
#[inline]
fn kernel_packed_mr_f16(k: usize, a: &[f32], panel: &[u16], cv: &CView,
                        i0: usize, jcol: usize, jw: usize, p0: usize,
                        pc: usize) {
    // SAFETY: rows i0..i0+MR × columns jcol..jcol+jw belong to this
    // tile.
    let (c0, c1, c2, c3) = unsafe {
        (cv.row(i0, jcol, jw), cv.row(i0 + 1, jcol, jw),
         cv.row(i0 + 2, jcol, jw), cv.row(i0 + 3, jcol, jw))
    };
    let mut t = [[0.0f32; NR]; MR];
    t[0][..jw].copy_from_slice(c0);
    t[1][..jw].copy_from_slice(c1);
    t[2][..jw].copy_from_slice(c2);
    t[3][..jw].copy_from_slice(c3);
    let a0 = &a[i0 * k..i0 * k + k];
    let a1 = &a[(i0 + 1) * k..(i0 + 1) * k + k];
    let a2 = &a[(i0 + 2) * k..(i0 + 2) * k + k];
    let a3 = &a[(i0 + 3) * k..(i0 + 3) * k + k];
    for dp in 0..pc {
        let praw: &[u16; NR] =
            panel[dp * NR..(dp + 1) * NR].try_into().unwrap();
        let mut brow = [0.0f32; NR];
        for j in 0..NR {
            brow[j] = f16_to_f32(praw[j]);
        }
        let p = p0 + dp;
        let (x0, x1, x2, x3) = (a0[p], a1[p], a2[p], a3[p]);
        for j in 0..NR {
            let bj = brow[j];
            t[0][j] += x0 * bj;
            t[1][j] += x1 * bj;
            t[2][j] += x2 * bj;
            t[3][j] += x3 * bj;
        }
    }
    c0.copy_from_slice(&t[0][..jw]);
    c1.copy_from_slice(&t[1][..jw]);
    c2.copy_from_slice(&t[2][..jw]);
    c3.copy_from_slice(&t[3][..jw]);
}

/// Single-row f16 remainder kernel (same reduction order).
#[inline]
fn kernel_packed_1_f16(k: usize, a: &[f32], panel: &[u16], cv: &CView,
                       i0: usize, jcol: usize, jw: usize, p0: usize,
                       pc: usize) {
    // SAFETY: row i0 × columns jcol..jcol+jw belong to this tile.
    let crow = unsafe { cv.row(i0, jcol, jw) };
    let mut t = [0.0f32; NR];
    t[..jw].copy_from_slice(crow);
    let arow = &a[i0 * k..i0 * k + k];
    for dp in 0..pc {
        let praw: &[u16; NR] =
            panel[dp * NR..(dp + 1) * NR].try_into().unwrap();
        let x = arow[p0 + dp];
        for j in 0..NR {
            t[j] += x * f16_to_f32(praw[j]);
        }
    }
    crow.copy_from_slice(&t[..jw]);
}

/// MR×NR int8 micro-kernel. Unlike the float kernels, the register
/// tile starts at zero and accumulates the *raw* `a · q` products for
/// this k-panel; the per-column scale is applied once at the end and
/// added into C (`C[i][j] += t[i][j] * scale[j]`) — the fused dequant
/// epilogue. Padding columns have `scale = 0` and are never stored.
#[inline]
fn kernel_packed_mr_i8(k: usize, a: &[f32], panel: &[i8], scales: &[f32],
                       cv: &CView, i0: usize, jcol: usize, jw: usize,
                       p0: usize, pc: usize) {
    // SAFETY: rows i0..i0+MR × columns jcol..jcol+jw belong to this
    // tile.
    let (c0, c1, c2, c3) = unsafe {
        (cv.row(i0, jcol, jw), cv.row(i0 + 1, jcol, jw),
         cv.row(i0 + 2, jcol, jw), cv.row(i0 + 3, jcol, jw))
    };
    let mut t = [[0.0f32; NR]; MR];
    let a0 = &a[i0 * k..i0 * k + k];
    let a1 = &a[(i0 + 1) * k..(i0 + 1) * k + k];
    let a2 = &a[(i0 + 2) * k..(i0 + 2) * k + k];
    let a3 = &a[(i0 + 3) * k..(i0 + 3) * k + k];
    for dp in 0..pc {
        let praw: &[i8; NR] =
            panel[dp * NR..(dp + 1) * NR].try_into().unwrap();
        let p = p0 + dp;
        let (x0, x1, x2, x3) = (a0[p], a1[p], a2[p], a3[p]);
        for j in 0..NR {
            let qj = praw[j] as f32;
            t[0][j] += x0 * qj;
            t[1][j] += x1 * qj;
            t[2][j] += x2 * qj;
            t[3][j] += x3 * qj;
        }
    }
    for j in 0..jw {
        let s = scales[j];
        c0[j] += t[0][j] * s;
        c1[j] += t[1][j] * s;
        c2[j] += t[2][j] * s;
        c3[j] += t[3][j] * s;
    }
}

/// Single-row int8 remainder kernel (same raw-accumulate + fused
/// dequant structure).
#[inline]
fn kernel_packed_1_i8(k: usize, a: &[f32], panel: &[i8], scales: &[f32],
                      cv: &CView, i0: usize, jcol: usize, jw: usize,
                      p0: usize, pc: usize) {
    // SAFETY: row i0 × columns jcol..jcol+jw belong to this tile.
    let crow = unsafe { cv.row(i0, jcol, jw) };
    let mut t = [0.0f32; NR];
    let arow = &a[i0 * k..i0 * k + k];
    for dp in 0..pc {
        let praw: &[i8; NR] =
            panel[dp * NR..(dp + 1) * NR].try_into().unwrap();
        let x = arow[p0 + dp];
        for j in 0..NR {
            t[j] += x * praw[j] as f32;
        }
    }
    for j in 0..jw {
        crow[j] += t[j] * scales[j];
    }
}

/// AVX2+FMA micro-kernels (x86-64). One 256-bit vector holds a full
/// NR=8 panel row, so an MR×NR C tile is four `__m256` accumulators
/// and the hot loop is four `vfmadd231ps` per panel row. Remainder
/// rows (`m % MR`) run a one-row *vector* kernel — the identical
/// per-lane op stream as one lane of the MR kernel — so a row's bits
/// never depend on which kernel processed it (the
/// reproducible-given-config argument; see the module docs). Partial
/// column panels (`jw < NR`) bounce through a stack `[f32; NR]` so
/// loads/stores never touch C memory outside the tile; the padding
/// lanes compute `x * 0.0` and are discarded.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{CView, MR, NR};
    use std::arch::x86_64::*;

    /// Load a (possibly partial) C row into a full vector; missing
    /// lanes are zero and are never stored back.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn load_c(row: &[f32]) -> __m256 {
        if row.len() == NR {
            _mm256_loadu_ps(row.as_ptr())
        } else {
            let mut buf = [0.0f32; NR];
            buf[..row.len()].copy_from_slice(row);
            _mm256_loadu_ps(buf.as_ptr())
        }
    }

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn store_c(v: __m256, row: &mut [f32]) {
        if row.len() == NR {
            _mm256_storeu_ps(row.as_mut_ptr(), v);
        } else {
            let mut buf = [0.0f32; NR];
            _mm256_storeu_ps(buf.as_mut_ptr(), v);
            let w = row.len();
            row.copy_from_slice(&buf[..w]);
        }
    }

    /// f32 panels: C-tile FMA accumulation.
    ///
    /// SAFETY: caller must have verified AVX2+FMA support.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn run_rows_f32(k: usize, a: &[f32], panel: &[f32],
                                      cv: &CView, r0: usize, r1: usize,
                                      jcol: usize, jw: usize, p0: usize,
                                      pc: usize) {
        let mut i0 = r0;
        while i0 + MR <= r1 {
            let (c0, c1, c2, c3) =
                (cv.row(i0, jcol, jw), cv.row(i0 + 1, jcol, jw),
                 cv.row(i0 + 2, jcol, jw), cv.row(i0 + 3, jcol, jw));
            let (mut v0, mut v1, mut v2, mut v3) =
                (load_c(c0), load_c(c1), load_c(c2), load_c(c3));
            let a0 = &a[i0 * k..i0 * k + k];
            let a1 = &a[(i0 + 1) * k..(i0 + 1) * k + k];
            let a2 = &a[(i0 + 2) * k..(i0 + 2) * k + k];
            let a3 = &a[(i0 + 3) * k..(i0 + 3) * k + k];
            for dp in 0..pc {
                let b = _mm256_loadu_ps(panel.as_ptr().add(dp * NR));
                let p = p0 + dp;
                v0 = _mm256_fmadd_ps(_mm256_set1_ps(a0[p]), b, v0);
                v1 = _mm256_fmadd_ps(_mm256_set1_ps(a1[p]), b, v1);
                v2 = _mm256_fmadd_ps(_mm256_set1_ps(a2[p]), b, v2);
                v3 = _mm256_fmadd_ps(_mm256_set1_ps(a3[p]), b, v3);
            }
            store_c(v0, c0);
            store_c(v1, c1);
            store_c(v2, c2);
            store_c(v3, c3);
            i0 += MR;
        }
        while i0 < r1 {
            let c0 = cv.row(i0, jcol, jw);
            let mut v0 = load_c(c0);
            let a0 = &a[i0 * k..i0 * k + k];
            for dp in 0..pc {
                let b = _mm256_loadu_ps(panel.as_ptr().add(dp * NR));
                v0 = _mm256_fmadd_ps(_mm256_set1_ps(a0[p0 + dp]), b, v0);
            }
            store_c(v0, c0);
            i0 += 1;
        }
    }

    /// f16 panels: `vcvtph2ps` (F16C) widens a panel row — the
    /// hardware convert is exact, identical to the scalar
    /// `f16_to_f32` — then the same FMA accumulation as f32.
    ///
    /// SAFETY: caller must have verified AVX2+FMA+F16C support.
    #[target_feature(enable = "avx2", enable = "fma", enable = "f16c")]
    pub(super) unsafe fn run_rows_f16(k: usize, a: &[f32], panel: &[u16],
                                      cv: &CView, r0: usize, r1: usize,
                                      jcol: usize, jw: usize, p0: usize,
                                      pc: usize) {
        let mut i0 = r0;
        while i0 + MR <= r1 {
            let (c0, c1, c2, c3) =
                (cv.row(i0, jcol, jw), cv.row(i0 + 1, jcol, jw),
                 cv.row(i0 + 2, jcol, jw), cv.row(i0 + 3, jcol, jw));
            let (mut v0, mut v1, mut v2, mut v3) =
                (load_c(c0), load_c(c1), load_c(c2), load_c(c3));
            let a0 = &a[i0 * k..i0 * k + k];
            let a1 = &a[(i0 + 1) * k..(i0 + 1) * k + k];
            let a2 = &a[(i0 + 2) * k..(i0 + 2) * k + k];
            let a3 = &a[(i0 + 3) * k..(i0 + 3) * k + k];
            for dp in 0..pc {
                let b = _mm256_cvtph_ps(_mm_loadu_si128(
                    panel.as_ptr().add(dp * NR) as *const __m128i));
                let p = p0 + dp;
                v0 = _mm256_fmadd_ps(_mm256_set1_ps(a0[p]), b, v0);
                v1 = _mm256_fmadd_ps(_mm256_set1_ps(a1[p]), b, v1);
                v2 = _mm256_fmadd_ps(_mm256_set1_ps(a2[p]), b, v2);
                v3 = _mm256_fmadd_ps(_mm256_set1_ps(a3[p]), b, v3);
            }
            store_c(v0, c0);
            store_c(v1, c1);
            store_c(v2, c2);
            store_c(v3, c3);
            i0 += MR;
        }
        while i0 < r1 {
            let c0 = cv.row(i0, jcol, jw);
            let mut v0 = load_c(c0);
            let a0 = &a[i0 * k..i0 * k + k];
            for dp in 0..pc {
                let b = _mm256_cvtph_ps(_mm_loadu_si128(
                    panel.as_ptr().add(dp * NR) as *const __m128i));
                v0 = _mm256_fmadd_ps(_mm256_set1_ps(a0[p0 + dp]), b, v0);
            }
            store_c(v0, c0);
            i0 += 1;
        }
    }

    /// int8 panels: sign-extend 8 quants to i32, convert to f32 (both
    /// exact), raw-accumulate with FMA, then the fused dequant
    /// epilogue `C += tile * scale`.
    ///
    /// SAFETY: caller must have verified AVX2+FMA support; `scales`
    /// must be exactly NR long.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn run_rows_i8(k: usize, a: &[f32], panel: &[i8],
                                     scales: &[f32], cv: &CView,
                                     r0: usize, r1: usize, jcol: usize,
                                     jw: usize, p0: usize, pc: usize) {
        let sv = _mm256_loadu_ps(scales.as_ptr());
        let mut i0 = r0;
        while i0 + MR <= r1 {
            let (c0, c1, c2, c3) =
                (cv.row(i0, jcol, jw), cv.row(i0 + 1, jcol, jw),
                 cv.row(i0 + 2, jcol, jw), cv.row(i0 + 3, jcol, jw));
            let (mut t0, mut t1, mut t2, mut t3) =
                (_mm256_setzero_ps(), _mm256_setzero_ps(),
                 _mm256_setzero_ps(), _mm256_setzero_ps());
            let a0 = &a[i0 * k..i0 * k + k];
            let a1 = &a[(i0 + 1) * k..(i0 + 1) * k + k];
            let a2 = &a[(i0 + 2) * k..(i0 + 2) * k + k];
            let a3 = &a[(i0 + 3) * k..(i0 + 3) * k + k];
            for dp in 0..pc {
                let b = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(
                    _mm_loadl_epi64(
                        panel.as_ptr().add(dp * NR) as *const __m128i)));
                let p = p0 + dp;
                t0 = _mm256_fmadd_ps(_mm256_set1_ps(a0[p]), b, t0);
                t1 = _mm256_fmadd_ps(_mm256_set1_ps(a1[p]), b, t1);
                t2 = _mm256_fmadd_ps(_mm256_set1_ps(a2[p]), b, t2);
                t3 = _mm256_fmadd_ps(_mm256_set1_ps(a3[p]), b, t3);
            }
            store_c(_mm256_fmadd_ps(t0, sv, load_c(c0)), c0);
            store_c(_mm256_fmadd_ps(t1, sv, load_c(c1)), c1);
            store_c(_mm256_fmadd_ps(t2, sv, load_c(c2)), c2);
            store_c(_mm256_fmadd_ps(t3, sv, load_c(c3)), c3);
            i0 += MR;
        }
        while i0 < r1 {
            let c0 = cv.row(i0, jcol, jw);
            let mut t0 = _mm256_setzero_ps();
            let a0 = &a[i0 * k..i0 * k + k];
            for dp in 0..pc {
                let b = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(
                    _mm_loadl_epi64(
                        panel.as_ptr().add(dp * NR) as *const __m128i)));
                t0 = _mm256_fmadd_ps(_mm256_set1_ps(a0[p0 + dp]), b, t0);
            }
            store_c(_mm256_fmadd_ps(t0, sv, load_c(c0)), c0);
            i0 += 1;
        }
    }
}

/// NEON micro-kernels (aarch64). An NR=8 panel row is two 128-bit
/// vectors; `vfmaq_n_f32` broadcasts the A scalar. f32 panels only —
/// f16/int8 stores route to the portable kernels on aarch64 (stable
/// Rust has no vector f16 loads there, and the quantized tiers'
/// contract is a tolerance, not bits, so the portable fallback is
/// always valid). Same one-row vector remainder argument as the AVX2
/// kernels.
#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{CView, MR, NR};
    use std::arch::aarch64::*;

    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn load_c(row: &[f32]) -> (float32x4_t, float32x4_t) {
        if row.len() == NR {
            (vld1q_f32(row.as_ptr()), vld1q_f32(row.as_ptr().add(4)))
        } else {
            let mut buf = [0.0f32; NR];
            buf[..row.len()].copy_from_slice(row);
            (vld1q_f32(buf.as_ptr()), vld1q_f32(buf.as_ptr().add(4)))
        }
    }

    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn store_c(lo: float32x4_t, hi: float32x4_t, row: &mut [f32]) {
        if row.len() == NR {
            vst1q_f32(row.as_mut_ptr(), lo);
            vst1q_f32(row.as_mut_ptr().add(4), hi);
        } else {
            let mut buf = [0.0f32; NR];
            vst1q_f32(buf.as_mut_ptr(), lo);
            vst1q_f32(buf.as_mut_ptr().add(4), hi);
            let w = row.len();
            row.copy_from_slice(&buf[..w]);
        }
    }

    /// SAFETY: NEON is baseline on aarch64.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn run_rows_f32(k: usize, a: &[f32], panel: &[f32],
                                      cv: &CView, r0: usize, r1: usize,
                                      jcol: usize, jw: usize, p0: usize,
                                      pc: usize) {
        let mut i0 = r0;
        while i0 + MR <= r1 {
            let (c0, c1, c2, c3) =
                (cv.row(i0, jcol, jw), cv.row(i0 + 1, jcol, jw),
                 cv.row(i0 + 2, jcol, jw), cv.row(i0 + 3, jcol, jw));
            let (mut v0l, mut v0h) = load_c(c0);
            let (mut v1l, mut v1h) = load_c(c1);
            let (mut v2l, mut v2h) = load_c(c2);
            let (mut v3l, mut v3h) = load_c(c3);
            let a0 = &a[i0 * k..i0 * k + k];
            let a1 = &a[(i0 + 1) * k..(i0 + 1) * k + k];
            let a2 = &a[(i0 + 2) * k..(i0 + 2) * k + k];
            let a3 = &a[(i0 + 3) * k..(i0 + 3) * k + k];
            for dp in 0..pc {
                let bl = vld1q_f32(panel.as_ptr().add(dp * NR));
                let bh = vld1q_f32(panel.as_ptr().add(dp * NR + 4));
                let p = p0 + dp;
                v0l = vfmaq_n_f32(v0l, bl, a0[p]);
                v0h = vfmaq_n_f32(v0h, bh, a0[p]);
                v1l = vfmaq_n_f32(v1l, bl, a1[p]);
                v1h = vfmaq_n_f32(v1h, bh, a1[p]);
                v2l = vfmaq_n_f32(v2l, bl, a2[p]);
                v2h = vfmaq_n_f32(v2h, bh, a2[p]);
                v3l = vfmaq_n_f32(v3l, bl, a3[p]);
                v3h = vfmaq_n_f32(v3h, bh, a3[p]);
            }
            store_c(v0l, v0h, c0);
            store_c(v1l, v1h, c1);
            store_c(v2l, v2h, c2);
            store_c(v3l, v3h, c3);
            i0 += MR;
        }
        while i0 < r1 {
            let c0 = cv.row(i0, jcol, jw);
            let (mut vl, mut vh) = load_c(c0);
            let a0 = &a[i0 * k..i0 * k + k];
            for dp in 0..pc {
                let bl = vld1q_f32(panel.as_ptr().add(dp * NR));
                let bh = vld1q_f32(panel.as_ptr().add(dp * NR + 4));
                let x = a0[p0 + dp];
                vl = vfmaq_n_f32(vl, bl, x);
                vh = vfmaq_n_f32(vh, bh, x);
            }
            store_c(vl, vh, c0);
            i0 += 1;
        }
    }
}

fn assert_packed_shapes(m: usize, n: usize, k: usize, a: &[f32],
                        pb: &PackedB, bias: Option<&[f32]>,
                        residual: Option<&[f32]>, c: &[f32]) {
    assert_eq!(a.len(), m * k, "packed gemm: A is not m×k");
    assert_eq!(pb.k, k, "packed gemm: PackedB k mismatch");
    assert_eq!(pb.n, n, "packed gemm: PackedB n mismatch");
    assert_eq!(c.len(), m * n, "packed gemm: C is not m×n");
    if let Some(bias) = bias {
        assert_eq!(bias.len(), n, "packed gemm: bias is not length n");
    }
    if let Some(r) = residual {
        assert_eq!(r.len(), m * n, "packed gemm: residual is not m×n");
    }
}

/// [`gemm_bias_act`] against a [`PackedB`] — the serial v2 kernel,
/// with the micro-kernel selected by `isa` (resolve it once per model
/// via [`crate::math::isa::KernelPolicy::resolve_isa`]; an ISA the
/// host can't run must never reach here — `resolve` guarantees that).
/// With `Isa::Portable` and an f32 store this is bit-identical to
/// [`gemm_ref`]; see the module contract for the other tiers.
pub fn gemm_packed_bias_act_on(isa: Isa, m: usize, n: usize, k: usize,
                               a: &[f32], pb: &PackedB,
                               bias: Option<&[f32]>, epi: Epilogue,
                               residual: Option<&[f32]>, c: &mut [f32]) {
    assert_packed_shapes(m, n, k, a, pb, bias, residual, c);
    if m == 0 || n == 0 {
        return;
    }
    let cv = CView { ptr: c.as_mut_ptr(), n };
    packed_region(isa, n, k, a, pb, bias, epi, residual, &cv, 0, m, 0, n);
}

/// [`gemm_packed_bias_act_on`] on the portable kernels — the bit-exact
/// entry existing callers and tests rely on.
pub fn gemm_packed_bias_act(m: usize, n: usize, k: usize, a: &[f32],
                            pb: &PackedB, bias: Option<&[f32]>,
                            epi: Epilogue, residual: Option<&[f32]>,
                            c: &mut [f32]) {
    gemm_packed_bias_act_on(Isa::Portable, m, n, k, a, pb, bias, epi,
                            residual, c);
}

/// [`gemm_packed_bias_act`] with the output split into a 2-D grid of
/// MR-aligned row ranges × NR-panel-aligned column ranges executed
/// concurrently on the process-global worker pool
/// ([`pool::ThreadPool::run_sharded_tiles`], which searches M×N
/// factorizations to fill every shard — e.g. 4 row blocks on 6 shards
/// run as a 3×2 grid, not a 4×1 grid with two workers idle). Small-M
/// products — the fused serving rounds — still occupy the whole pool
/// through their column panels. Each C tile is owned by exactly one
/// task and every element's reduction is computed whole inside its
/// tile, so the result is bit-identical to the serial
/// [`gemm_packed_bias_act_on`] call *with the same `isa`* for every
/// shard count and every steal schedule — the kernel is fixed for the
/// whole product, so tiling can't change which instruction stream a
/// row sees. Returns the effective tile count.
pub fn gemm_packed_sharded_on(isa: Isa, m: usize, n: usize, k: usize,
                              a: &[f32], pb: &PackedB,
                              bias: Option<&[f32]>, epi: Epilogue,
                              residual: Option<&[f32]>, c: &mut [f32],
                              shards: usize) -> usize {
    if shards <= 1 || (m <= MR && n <= NR) || m == 0 || n == 0 {
        gemm_packed_bias_act_on(isa, m, n, k, a, pb, bias, epi, residual,
                                c);
        return 1;
    }
    assert_packed_shapes(m, n, k, a, pb, bias, residual, c);
    let cv = CView { ptr: c.as_mut_ptr(), n };
    pool::global()
        .run_sharded_tiles(m, MR, n, NR, shards, |r0, r1, j0, j1| {
            packed_region(isa, n, k, a, pb, bias, epi, residual, &cv, r0,
                          r1, j0, j1);
        })
        .max(1)
}

/// One tile of a packed GEMM, executed as a node of a
/// [`pool::TileGraph`]: rows `0..rows` of a row block × packed column
/// panels `[j0, j1)` (`j0` NR-aligned, `j1` NR-aligned or `pb.n()`),
/// full bias→ascending-k accumulate→epilogue for every element it
/// owns. This is exactly the region a shard of
/// [`gemm_packed_sharded_on`] computes — same `packed_region` core,
/// same per-element op stream — so a layer executed as graph tiles is
/// bit-identical to the barrier path for every tier. Pointer-based
/// because graph tiles of *different* layers run concurrently over the
/// same activation planes: a tile may only materialize slices over its
/// own row block (frozen by the graph's dependency edges), never over
/// whole planes other tiles are still writing.
///
/// * `a_block`: row 0 of this row block's A rows (`rows × k`,
///   row-major, lda = k).
/// * `residual_block`: like `a_block` but `rows × pb.n()` (lda = n).
/// * `c_block`: row 0, column 0 of this row block in C (lda =
///   `pb.n()`); only columns `[j0, j1)` are touched.
///
/// # Safety
/// For the duration of the call, `a_block`/`residual_block` rows must
/// not be written by anyone, and columns `[j0, j1)` of `c_block`'s
/// `rows` rows must be exclusively this tile's. The graph dependency
/// rule (a layer-(l+1) tile of row block *i* waits on all layer-l
/// tiles of row block *i*; planes ping-pong by layer parity) provides
/// both.
#[allow(clippy::too_many_arguments)]
pub unsafe fn gemm_packed_tile_on(isa: Isa, rows: usize, j0: usize,
                                  j1: usize, k: usize,
                                  a_block: *const f32, pb: &PackedB,
                                  bias: Option<&[f32]>, epi: Epilogue,
                                  residual_block: Option<*const f32>,
                                  c_block: *mut f32) {
    let n = pb.n();
    debug_assert_eq!(pb.k, k, "packed tile: PackedB k mismatch");
    debug_assert!(j0 % NR == 0, "packed tile start must be NR-aligned");
    debug_assert!(j1 <= n, "packed tile end past n");
    if rows == 0 || j1 <= j0 {
        return;
    }
    let a = std::slice::from_raw_parts(a_block, rows * k);
    let residual = residual_block
        .map(|p| std::slice::from_raw_parts(p, rows * n));
    let cv = CView { ptr: c_block, n };
    packed_region(isa, n, k, a, pb, bias, epi, residual, &cv, 0, rows,
                  j0, j1);
}

/// [`gemm_packed_sharded_on`] on the portable kernels (bit-exact
/// tier).
pub fn gemm_packed_sharded(m: usize, n: usize, k: usize, a: &[f32],
                           pb: &PackedB, bias: Option<&[f32]>,
                           epi: Epilogue, residual: Option<&[f32]>,
                           c: &mut [f32], shards: usize) -> usize {
    gemm_packed_sharded_on(Isa::Portable, m, n, k, a, pb, bias, epi,
                           residual, c, shards)
}

/// [`gemm_bias_act`] (the unpacked v1 kernel) with the output split
/// into a 2-D grid of MR-aligned row ranges × NR-aligned column ranges
/// executed concurrently on the process-global worker pool (same
/// utilization-maximizing grid search as [`gemm_packed_sharded`]).
/// Bit-identical to the serial call for every shard count and steal
/// schedule (tiles own whole elements). Returns the effective tile
/// count.
pub fn gemm_sharded(m: usize, n: usize, k: usize, a: &[f32], b: &[f32],
                    bias: Option<&[f32]>, epi: Epilogue,
                    residual: Option<&[f32]>, c: &mut [f32],
                    shards: usize) -> usize {
    if shards <= 1 || (m <= MR && n <= NR) || m == 0 || n == 0 {
        gemm_bias_act(m, n, k, a, b, bias, epi, residual, c);
        return 1;
    }
    assert_eq!(a.len(), m * k, "gemm_sharded: A is not m×k");
    assert_eq!(b.len(), k * n, "gemm_sharded: B is not k×n");
    assert_eq!(c.len(), m * n, "gemm_sharded: C is not m×n");
    if let Some(bias) = bias {
        assert_eq!(bias.len(), n, "gemm_sharded: bias is not length n");
    }
    if let Some(r) = residual {
        assert_eq!(r.len(), m * n, "gemm_sharded: residual is not m×n");
    }
    let cv = CView { ptr: c.as_mut_ptr(), n };
    pool::global()
        .run_sharded_tiles(m, MR, n, NR, shards, |r0, r1, j0, j1| {
            unpacked_region(n, k, a, b, bias, epi, residual, &cv, r0, r1,
                            j0, j1);
        })
        .max(1)
}

/// Naive triple-loop reference with the same per-element reduction
/// order — the oracle the blocked/tiled/packed/sharded kernels are
/// tested against (bit-exact, not just approximately equal).
pub fn gemm_ref(m: usize, n: usize, k: usize, a: &[f32], b: &[f32],
                bias: Option<&[f32]>, epi: Epilogue,
                residual: Option<&[f32]>, c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = bias.map_or(0.0, |bv| bv[j]);
            for p in 0..k {
                acc += a[i * k + p] * b[p * n + j];
            }
            if epi == Epilogue::Silu {
                acc = silu(acc);
            }
            if let Some(r) = residual {
                // same operand order as the fused epilogue: res + act
                acc = r[i * n + j] + acc;
            }
            c[i * n + j] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(len: usize, seed: u32) -> Vec<f32> {
        (0..len)
            .map(|i| {
                let v = (i as u32).wrapping_mul(2654435761)
                    .wrapping_add(seed.wrapping_mul(40503));
                (v % 2003) as f32 / 2003.0 - 0.5
            })
            .collect()
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    /// Shapes straddling the MR (4), NR (8) and KC (256) boundaries.
    const SHAPES: &[(usize, usize, usize)] = &[
        (0, 3, 4), (1, 1, 1), (1, 7, 5), (3, 2, 9), (4, 4, 4), (4, 8, 8),
        (5, 3, 300), (5, 9, 17), (7, 13, 257), (8, 1, 2), (8, 16, 256),
        (13, 17, 31), (4, 24, 256),
    ];

    #[test]
    fn blocked_matches_reference_bitwise_across_shapes() {
        for &(m, n, k) in SHAPES {
            let a = fill(m * k, 1);
            let b = fill(k * n, 2);
            let bias = fill(n, 3);
            let res = fill(m * n, 4);
            for epi in [Epilogue::Linear, Epilogue::Silu] {
                for (bias_o, res_o) in [(None, None), (Some(&bias), None),
                                        (Some(&bias), Some(&res))] {
                    let mut want = vec![0.0f32; m * n];
                    gemm_ref(m, n, k, &a, &b, bias_o.map(|v| &v[..]), epi,
                             res_o.map(|v| &v[..]), &mut want);
                    let mut got = vec![7.0f32; m * n];
                    gemm_bias_act(m, n, k, &a, &b, bias_o.map(|v| &v[..]),
                                  epi, res_o.map(|v| &v[..]), &mut got);
                    assert_eq!(bits(&want), bits(&got),
                               "m={m} n={n} k={k} epi={epi:?}");
                }
            }
        }
    }

    #[test]
    fn packed_matches_reference_bitwise_across_shapes() {
        for &(m, n, k) in SHAPES {
            let a = fill(m * k, 11);
            let b = fill(k * n, 12);
            let bias = fill(n, 13);
            let res = fill(m * n, 14);
            let pb = PackedB::pack(k, n, &b);
            assert_eq!(pb.k(), k);
            assert_eq!(pb.n(), n);
            assert_eq!(pb.bytes(), k * n.div_ceil(NR) * NR * 4);
            for epi in [Epilogue::Linear, Epilogue::Silu] {
                for (bias_o, res_o) in [(None, None), (Some(&bias), None),
                                        (Some(&bias), Some(&res))] {
                    let mut want = vec![0.0f32; m * n];
                    gemm_ref(m, n, k, &a, &b, bias_o.map(|v| &v[..]), epi,
                             res_o.map(|v| &v[..]), &mut want);
                    let mut got = vec![7.0f32; m * n];
                    gemm_packed_bias_act(m, n, k, &a, &pb,
                                         bias_o.map(|v| &v[..]), epi,
                                         res_o.map(|v| &v[..]), &mut got);
                    assert_eq!(bits(&want), bits(&got),
                               "packed m={m} n={n} k={k} epi={epi:?}");
                }
            }
        }
    }

    #[test]
    fn sharded_matches_serial_bitwise() {
        let (m, n, k) = (37usize, 19usize, 23usize);
        let a = fill(m * k, 5);
        let b = fill(k * n, 6);
        let bias = fill(n, 7);
        let mut want = vec![0.0f32; m * n];
        gemm_bias_act(m, n, k, &a, &b, Some(&bias), Epilogue::Silu, None,
                      &mut want);
        for shards in [1usize, 2, 3, 8, 64] {
            let mut got = vec![0.0f32; m * n];
            let eff = gemm_sharded(m, n, k, &a, &b, Some(&bias),
                                   Epilogue::Silu, None, &mut got, shards);
            assert!(eff >= 1);
            assert_eq!(bits(&want), bits(&got), "shards={shards}");
        }
    }

    #[test]
    fn packed_sharded_is_bit_invariant_in_shard_count() {
        // odd/rectangular shapes, including the small-M serve shape
        // whose parallelism comes entirely from column panels
        for &(m, n, k) in &[(4usize, 96usize, 64usize), (37, 19, 23),
                            (16, 40, 300), (5, 64, 16)] {
            let a = fill(m * k, 21);
            let b = fill(k * n, 22);
            let bias = fill(n, 23);
            let res = fill(m * n, 24);
            let pb = PackedB::pack(k, n, &b);
            let mut want = vec![0.0f32; m * n];
            gemm_ref(m, n, k, &a, &b, Some(&bias), Epilogue::Silu,
                     Some(&res), &mut want);
            for shards in [1usize, 2, 8, 64] {
                let mut got = vec![0.0f32; m * n];
                let eff = gemm_packed_sharded(m, n, k, &a, &pb, Some(&bias),
                                              Epilogue::Silu, Some(&res),
                                              &mut got, shards);
                assert!(eff >= 1 && eff <= shards.max(1));
                assert_eq!(bits(&want), bits(&got),
                           "m={m} n={n} k={k} shards={shards}");
            }
        }
    }

    #[test]
    fn small_m_sharding_tiles_column_panels() {
        // m=4 is a single MR block: v1's M-only split would have run
        // serial; the 2-D grid must still fan out over column panels
        let (m, n, k) = (4usize, 128usize, 32usize);
        let a = fill(m * k, 31);
        let b = fill(k * n, 32);
        let pb = PackedB::pack(k, n, &b);
        let mut want = vec![0.0f32; m * n];
        gemm_ref(m, n, k, &a, &b, None, Epilogue::Linear, None, &mut want);
        let mut got = vec![0.0f32; m * n];
        let eff = gemm_packed_sharded(m, n, k, &a, &pb, None,
                                      Epilogue::Linear, None, &mut got, 8);
        assert!(eff > 1, "small-M product did not tile over N (eff={eff})");
        assert_eq!(bits(&want), bits(&got));
    }

    #[test]
    fn packed_tile_entry_matches_serial_bitwise() {
        // the graph-node entry computes exactly a shard's region:
        // cutting a product into row blocks × NR panel ranges and
        // running every piece through gemm_packed_tile_on must
        // reproduce the serial call bit for bit, whatever the cut
        let (m, n, k) = (13usize, 40usize, 300usize);
        let a = fill(m * k, 51);
        let b = fill(k * n, 52);
        let bias = fill(n, 53);
        let res = fill(m * n, 54);
        let pb = PackedB::pack(k, n, &b);
        let mut want = vec![0.0f32; m * n];
        gemm_packed_bias_act(m, n, k, &a, &pb, Some(&bias), Epilogue::Silu,
                             Some(&res), &mut want);
        for rows_per_block in [4usize, 8, 16] {
            for panels_per_tile in [1usize, 2, 8] {
                let mut got = vec![7.0f32; m * n];
                let mut r0 = 0usize;
                while r0 < m {
                    let r1 = (r0 + rows_per_block).min(m);
                    let mut j0 = 0usize;
                    while j0 < n {
                        let j1 = (j0 + panels_per_tile * NR).min(n);
                        // SAFETY: serial loop — every region is
                        // exclusive, nothing else touches the buffers
                        unsafe {
                            gemm_packed_tile_on(
                                Isa::Portable, r1 - r0, j0, j1, k,
                                a.as_ptr().add(r0 * k), &pb, Some(&bias),
                                Epilogue::Silu,
                                Some(res.as_ptr().add(r0 * n)),
                                got.as_mut_ptr().add(r0 * n));
                        }
                        j0 = j1;
                    }
                    r0 = r1;
                }
                assert_eq!(bits(&want), bits(&got),
                           "rows_per_block={rows_per_block} \
                            panels_per_tile={panels_per_tile}");
            }
        }
    }

    #[test]
    fn plain_gemm_identity() {
        // A · I == A
        let m = 5;
        let n = 6;
        let a = fill(m * n, 8);
        let mut eye = vec![0.0f32; n * n];
        for i in 0..n {
            eye[i * n + i] = 1.0;
        }
        let mut c = vec![0.0f32; m * n];
        gemm(m, n, n, &a, &eye, &mut c);
        assert_eq!(bits(&a), bits(&c));
    }

    #[test]
    fn silu_epilogue_matches_scalar_definition() {
        // 1×1 GEMM: c = silu(bias + a*b), silu built on exp_fast
        let mut c = vec![0.0f32];
        gemm_bias_act(1, 1, 1, &[2.0], &[3.0], Some(&[0.5]), Epilogue::Silu,
                      None, &mut c);
        let x = 0.5f32 + 2.0 * 3.0;
        assert_eq!(c[0].to_bits(), (x / (1.0 + exp_fast(-x))).to_bits());
        // and tracks the libm definition well inside the parity budget
        let libm = x / (1.0 + (-x).exp());
        assert!((c[0] - libm).abs() <= 1e-6 * libm.abs());
    }

    #[test]
    fn exp_fast_is_exact_at_zero_and_tracks_libm() {
        assert_eq!(exp_fast(0.0), 1.0);
        assert_eq!(exp_fast(-0.0), 1.0);
        for i in -8700..=8800 {
            let x = i as f32 * 0.01; // [-87, 88]: normal-range expf
            let want = x.exp();
            let got = exp_fast(x);
            let rel = ((got - want) / want).abs();
            assert!(rel < 1e-6,
                    "x={x}: libm {want} vs fast {got} (rel {rel})");
        }
        // non-finite / extreme semantics match the libm form
        assert!(exp_fast(f32::NAN).is_nan());
        assert_eq!(exp_fast(f32::INFINITY), f32::INFINITY);
        assert_eq!(exp_fast(100.0), f32::INFINITY); // libm overflow region
        // saturation starts right at the clamp point — no band where
        // the result silently underestimates
        assert_eq!(exp_fast(88.31), f32::INFINITY);
        assert!(exp_fast(88.3).is_finite());
        assert!((exp_fast(88.3) / 88.3f32.exp() - 1.0).abs() < 1e-6);
        assert!(exp_fast(f32::NEG_INFINITY) < 1.2e-38); // flushed, not 0
        assert!(silu(f32::NAN).is_nan());
        assert!(silu(f32::NEG_INFINITY).is_nan()); // -inf/inf, as libm
        assert_eq!(silu(f32::INFINITY), f32::INFINITY);
        // deep saturation: exact -0.0 on the left (x/inf), identity on
        // the right (denominator rounds to 1.0)
        assert_eq!(silu(-200.0), 0.0);
        assert!(silu(-200.0).is_sign_negative());
        assert_eq!(silu(200.0), 200.0);
    }

    #[test]
    #[should_panic(expected = "A is not m×k")]
    fn shape_mismatch_panics() {
        let mut c = vec![0.0f32; 4];
        gemm(2, 2, 3, &[0.0; 5], &[0.0; 6], &mut c);
    }

    #[test]
    #[should_panic(expected = "PackedB k mismatch")]
    fn packed_shape_mismatch_panics() {
        let pb = PackedB::pack(3, 2, &[0.0; 6]);
        let mut c = vec![0.0f32; 4];
        gemm_packed_bias_act(2, 2, 2, &[0.0; 4], &pb, None,
                             Epilogue::Linear, None, &mut c);
    }

    // -- determinism-tier tests (quantized stores + ISA dispatch) -----

    use crate::math::isa::{detect_isa, gemm_rel_tolerance};

    /// NR-straddling shapes incl. a KC-straddling k, as the quantized
    /// round-trip property demands.
    const QSHAPES: &[(usize, usize, usize)] =
        &[(3, 2, 9), (5, 9, 17), (7, 13, 257), (8, 16, 256), (6, 13, 300)];

    /// `b` with every element replaced by what the packed store will
    /// reconstruct — the oracle for the quantized kernels.
    fn dequantized(pb: &PackedB, k: usize, n: usize) -> Vec<f32> {
        (0..k * n).map(|i| pb.stored(i / n, i % n)).collect()
    }

    #[test]
    fn quantized_pack_roundtrip_and_padding_stay_bounded() {
        for &(_, n, k) in QSHAPES {
            let b = fill(k * n, 42);
            for prec in [Precision::F32, Precision::F16, Precision::Int8] {
                let pb = PackedB::pack_as(k, n, &b, prec);
                assert_eq!(pb.precision(), prec);
                let n_padded = n.div_ceil(NR) * NR;
                for p in 0..k {
                    // zero-padded panel tail dequantizes to exactly 0.0
                    for j in n..n_padded {
                        assert_eq!(pb.stored(p, j).to_bits(), 0,
                                   "padding ({p},{j}) not exactly zero");
                    }
                    for j in 0..n {
                        let w = b[p * n + j];
                        let got = pb.stored(p, j);
                        match prec {
                            Precision::F32 => {
                                assert_eq!(got.to_bits(), w.to_bits())
                            }
                            Precision::F16 => assert_eq!(
                                got.to_bits(),
                                f16_to_f32(f32_to_f16(w)).to_bits(),
                                "f16 ({p},{j})"
                            ),
                            Precision::Int8 => {
                                // per-(k-panel, column) scale: error is
                                // at most half a quant step
                                let p0 = (p / KC) * KC;
                                let pc = KC.min(k - p0);
                                let colmax = (0..pc)
                                    .map(|dp| b[(p0 + dp) * n + j].abs())
                                    .fold(0.0f32, f32::max);
                                let bound = colmax / 254.0 + 1e-6;
                                assert!((got - w).abs() <= bound,
                                        "int8 ({p},{j}): |{got} - {w}| \
                                         > {bound}");
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn quantized_bytes_shrink_as_documented() {
        let (n, k) = (24usize, 300usize);
        let b = fill(k * n, 9);
        let f32b = PackedB::pack_as(k, n, &b, Precision::F32).bytes();
        let f16b = PackedB::pack_as(k, n, &b, Precision::F16).bytes();
        let i8b = PackedB::pack_as(k, n, &b, Precision::Int8).bytes();
        assert_eq!(f32b, k * n.div_ceil(NR) * NR * 4);
        assert_eq!(f16b, f32b / 2);
        assert!(i8b < f32b / 3, "int8 {i8b} vs f32 {f32b}");
    }

    #[test]
    fn f16_portable_kernel_matches_ref_on_dequantized_matrix_bitwise() {
        // the portable f16 kernel is the f32 kernel run on the
        // (exactly) dequantized matrix — bit for bit
        for &(m, n, k) in QSHAPES {
            let a = fill(m * k, 51);
            let b = fill(k * n, 52);
            let bias = fill(n, 53);
            let pb = PackedB::pack_as(k, n, &b, Precision::F16);
            let bdeq = dequantized(&pb, k, n);
            let mut want = vec![0.0f32; m * n];
            gemm_ref(m, n, k, &a, &bdeq, Some(&bias), Epilogue::Silu, None,
                     &mut want);
            let mut got = vec![7.0f32; m * n];
            gemm_packed_bias_act(m, n, k, &a, &pb, Some(&bias),
                                 Epilogue::Silu, None, &mut got);
            assert_eq!(bits(&want), bits(&got), "f16 m={m} n={n} k={k}");
        }
    }

    #[test]
    fn int8_portable_kernel_tracks_ref_on_dequantized_matrix() {
        // int8 applies the scale once per k-panel (s * sum(a*q)) where
        // the dequantized ref multiplies per element (sum(a*(q*s))) —
        // same value up to f32 rounding
        for &(m, n, k) in QSHAPES {
            let a = fill(m * k, 61);
            let b = fill(k * n, 62);
            let bias = fill(n, 63);
            let pb = PackedB::pack_as(k, n, &b, Precision::Int8);
            let bdeq = dequantized(&pb, k, n);
            let mut want = vec![0.0f32; m * n];
            gemm_ref(m, n, k, &a, &bdeq, Some(&bias), Epilogue::Linear,
                     None, &mut want);
            let mut got = vec![7.0f32; m * n];
            gemm_packed_bias_act(m, n, k, &a, &pb, Some(&bias),
                                 Epilogue::Linear, None, &mut got);
            for i in 0..m * n {
                let rel = (got[i] - want[i]).abs() / want[i].abs().max(1.0);
                assert!(rel <= 1e-4,
                        "int8 m={m} n={n} k={k} i={i}: {} vs {} (rel {rel})",
                        got[i], want[i]);
            }
        }
    }

    #[test]
    fn isa_dispatch_tracks_ref_within_tier_tolerance_and_is_bit_stable() {
        // whatever ISA this host resolves: f32 within the tier
        // tolerance of gemm_ref (bitwise when portable), and a repeat
        // run reproduces the bits exactly
        let isa = detect_isa();
        for &(m, n, k) in QSHAPES {
            let a = fill(m * k, 71);
            let b = fill(k * n, 72);
            let bias = fill(n, 73);
            let mut want = vec![0.0f32; m * n];
            gemm_ref(m, n, k, &a, &b, Some(&bias), Epilogue::Silu, None,
                     &mut want);
            for prec in [Precision::F32, Precision::F16, Precision::Int8] {
                let pb = PackedB::pack_as(k, n, &b, prec);
                let tol = gemm_rel_tolerance(isa, prec);
                let mut got = vec![7.0f32; m * n];
                gemm_packed_bias_act_on(isa, m, n, k, &a, &pb, Some(&bias),
                                        Epilogue::Silu, None, &mut got);
                if tol == 0.0 {
                    assert_eq!(bits(&want), bits(&got),
                               "portable f32 m={m} n={n} k={k}");
                } else {
                    for i in 0..m * n {
                        let rel = ((got[i] - want[i]).abs()
                                   / want[i].abs().max(1.0)) as f64;
                        assert!(rel <= tol,
                                "{isa}/{prec} m={m} n={n} k={k} i={i}: \
                                 {} vs {} (rel {rel:e} > {tol:e})",
                                got[i], want[i]);
                    }
                }
                let first = bits(&got);
                let mut again = vec![3.0f32; m * n];
                gemm_packed_bias_act_on(isa, m, n, k, &a, &pb, Some(&bias),
                                        Epilogue::Silu, None, &mut again);
                assert_eq!(first, bits(&again),
                           "{isa}/{prec} m={m} n={n} k={k} not bit-stable");
            }
        }
    }

    #[test]
    fn sharded_on_active_isa_is_bit_invariant_in_shards_for_every_store() {
        // the reproducible-given-config contract: for a fixed ISA and
        // store, the tile grid and shard count never change a bit
        let isa = detect_isa();
        for &(m, n, k) in &[(4usize, 96usize, 64usize), (16, 40, 300),
                            (13, 17, 31)] {
            let a = fill(m * k, 81);
            let b = fill(k * n, 82);
            let bias = fill(n, 83);
            for prec in [Precision::F32, Precision::F16, Precision::Int8] {
                let pb = PackedB::pack_as(k, n, &b, prec);
                let mut want = vec![0.0f32; m * n];
                gemm_packed_bias_act_on(isa, m, n, k, &a, &pb, Some(&bias),
                                        Epilogue::Silu, None, &mut want);
                for shards in [1usize, 2, 8, 64] {
                    let mut got = vec![0.0f32; m * n];
                    let eff = gemm_packed_sharded_on(
                        isa, m, n, k, &a, &pb, Some(&bias), Epilogue::Silu,
                        None, &mut got, shards);
                    assert!(eff >= 1 && eff <= shards.max(1));
                    assert_eq!(bits(&want), bits(&got),
                               "{isa}/{prec} m={m} n={n} k={k} \
                                shards={shards}");
                }
            }
        }
    }
}
