//! Cache-blocked, register-tiled f32 GEMM for the native model backend.
//!
//! This is the kernel under `NativeMlp::denoise_batch`: every MLP layer
//! over a `B`-row batch is one `B×n_in · n_in×n_out` matrix product
//! with a fused bias + activation (+ residual) epilogue, instead of `B`
//! scalar `linear()` calls. Written as autovectorizer-friendly plain
//! Rust (no intrinsics, no unsafe in the micro-kernels): exact-length
//! subslices and fixed-size register tiles let LLVM hoist the bounds
//! checks and vectorize the `j`-loops.
//!
//! Two kernel generations live here:
//!
//! * **v1** ([`gemm_bias_act`]) — MR-row register blocking over the
//!   caller's row-major `B`. Every micro-block re-streams `B` rows from
//!   memory.
//! * **v2 packed** ([`PackedB`] + [`gemm_packed_bias_act`]) — BLIS-style
//!   prepacked panels: `B` is repacked **once** (at model load for MLP
//!   weights) into `KC×NR` column panels, and an `MR×NR` register-tiled
//!   micro-kernel accumulates into a local C tile that stays in
//!   registers for a whole k-panel. Panel loads are contiguous
//!   exact-`NR` slices, so the hot loop is pure SIMD FMA with no
//!   strided traffic — the win is largest for the small-M GEMMs of
//!   fused serving rounds, where v1's bandwidth is wasted re-streaming
//!   weights.
//!
//! **Determinism contract.** For every output element `c[i][j]` the
//! reduction over `p` (the shared dimension) runs in ascending order
//! starting from the bias, using plain IEEE mul/add (no `mul_add`):
//!
//! ```text
//! acc = bias[j];  for p in 0..k { acc += a[i][p] * b[p][j] }
//! ```
//!
//! Row-blocking (MR), column panels (NR), k-panel blocking (KC) and
//! 2-D M×N sharding ([`gemm_sharded`], [`gemm_packed_sharded`]) only
//! regroup *independent* output elements — they never split or reorder
//! a single element's reduction. The packed micro-kernel loads each
//! MR×NR C tile into a register tile once per k-panel and replays the
//! identical ascending-`p` add/mul sequence there before storing back,
//! which is the same IEEE op stream per element as the in-memory v1
//! accumulation. So every kernel here is **bit-identical to
//! [`gemm_ref`]** (the naive triple loop with the same reduction
//! order), for every tile shape and every shard count.
//! tests/test_properties.rs enforces all of it.
//!
//! The SiLU epilogue uses [`exp_fast`] — a branch-free Cody–Waite +
//! degree-6-polynomial `expf` the autovectorizer can turn into SIMD —
//! instead of scalar libm `expf`, which would otherwise dominate the
//! whole layer (a hidden layer is ~`n_in` MACs but only one `exp` per
//! output, and libm calls never vectorize). `exp_fast` is exact at 0
//! and within ~2 ulp elsewhere, so the GEMM forward tracks the scalar
//! libm reference (`NativeMlp::forward_one_ref`) to ~1e-7 relative per
//! layer — well inside the 1e-5 parity budget and the 2e-4 golden
//! tolerance.

use crate::runtime::pool;

/// Register-tile height: rows of `A` processed together so each loaded
/// row (v1) or panel row (packed) of `B` is reused MR times from
/// registers.
pub const MR: usize = 4;

/// Column-panel width of the packed layout: the packed micro-kernel
/// produces an MR×NR C tile per k-panel pass, reading exact-`NR`
/// contiguous panel rows (one SIMD-friendly slice per `p`).
pub const NR: usize = 8;

/// k-panel height (cache block): the slice of `B` touched per pass
/// stays resident in L1/L2 while MR-row blocks of `A` stream over it.
pub const KC: usize = 256;

/// Fused epilogue applied to the accumulator after the reduction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Epilogue {
    /// Store bias + A·B as-is (output layers).
    Linear,
    /// Store `silu(bias + A·B)` (hidden layers).
    Silu,
}

/// Branch-free `expf` approximation (Cody–Waite range reduction +
/// Cephes degree-6 minimax polynomial, 2^k scaling through the
/// exponent bits). Select-only control flow, no libm call — so the
/// epilogue loops vectorize. Exact at 0 (`exp_fast(0.0) == 1.0`),
/// ~2 ulp on `[-87.33, 88.3]`. Outside that: NaN propagates
/// (`f32::clamp` keeps NaN), `x > 88.3` (incl. `+inf`) returns `inf`
/// — saturating ~0.4 *earlier* than libm's 88.7228 overflow point —
/// and `x < -87.33` flushes to ~min-normal instead of going
/// subnormal → 0. Both divergences are below 1e-36 absolute once fed
/// through silu.
#[inline]
pub fn exp_fast(x: f32) -> f32 {
    let xc = x.clamp(-87.33, 88.3); // keeps k = round(x/ln2) <= 127
    // k = round(x / ln 2) via the 1.5·2^23 shift trick (SSE2-friendly,
    // unlike f32::round which needs SSE4.1 to stay vectorized)
    const SHIFT: f32 = 12_582_912.0; // 1.5 * 2^23
    let kf = (xc * std::f32::consts::LOG2_E + SHIFT) - SHIFT;
    // two-step range reduction: r = x - k ln 2, |r| <= ln2/2
    let r = (xc - kf * 0.693_359_375) - kf * (-2.121_944_4e-4);
    // exp(r) ~= 1 + r + r^2 P(r) (Cephes expf minimax coefficients)
    let p = 1.987_569_15e-4_f32;
    let p = p * r + 1.398_199_95e-3;
    let p = p * r + 8.333_451_9e-3;
    let p = p * r + 4.166_579_6e-2;
    let p = p * r + 1.666_666_55e-1;
    let p = p * r + 5.000_000_1e-1;
    let poly = (p * r + 1.0) * r + 1.0;
    // scale by 2^k through the exponent field (k in [-126, 127] after
    // the clamp, so 127 + k never leaves [1, 254]; NaN casts to 0)
    let scale = f32::from_bits(((127 + kf as i32) << 23) as u32);
    let y = poly * scale;
    // saturate the region the clamp capped straight to inf (libm
    // overflows at 88.7228; we overflow at the clamp point so there is
    // no band where the result silently underestimates). NaN fails the
    // compare and keeps y (= NaN); a float select, so the loop still
    // vectorizes (cmp + blend).
    if x > 88.3 { f32::INFINITY } else { y }
}

#[inline]
fn silu(x: f32) -> f32 {
    // silu(x) = x / (1 + e^-x). Edge semantics track the libm form:
    // NaN propagates through both operands, silu(-inf) = -inf/inf =
    // NaN, silu(+inf) = inf, deep-negative x gives -x/inf = -0.0.
    x / (1.0 + exp_fast(-x))
}

/// Disjoint-region view of `C` handed to tile shards. Every tile owns
/// an exclusive rows×columns rectangle no other tile touches, so the
/// per-row slices materialized through [`CView::row`] never alias —
/// the same argument the M-sharded v1 made for whole rows, extended to
/// column ranges (a row-range `&mut` subslice can't express "columns
/// j0..j1 of rows r0..r1", hence the raw pointer).
struct CView {
    ptr: *mut f32,
    n: usize,
}

unsafe impl Send for CView {}
unsafe impl Sync for CView {}

impl CView {
    /// Columns `j0..j0+jw` of row `i` as an exclusive slice.
    ///
    /// SAFETY: the caller must own `[i*n + j0, i*n + j0 + jw)`
    /// exclusively while the returned slice lives, and the underlying
    /// buffer must outlive the pool join (both hold for tile shards:
    /// tiles are pairwise disjoint and the submitting thread blocks
    /// until every shard finished).
    #[allow(clippy::mut_from_ref)]
    unsafe fn row(&self, i: usize, j0: usize, jw: usize) -> &mut [f32] {
        std::slice::from_raw_parts_mut(self.ptr.add(i * self.n + j0), jw)
    }
}

/// Seed the `[r0, r1) × [j0, j1)` region of C with the bias row (or
/// zero) — the reduction's starting value, same order as the scalar
/// path.
fn region_seed(cv: &CView, r0: usize, r1: usize, j0: usize, j1: usize,
               bias: Option<&[f32]>) {
    for i in r0..r1 {
        // SAFETY: this tile owns the region (see CView::row).
        let row = unsafe { cv.row(i, j0, j1 - j0) };
        match bias {
            Some(bv) => row.copy_from_slice(&bv[j0..j1]),
            None => row.fill(0.0),
        }
    }
}

/// Apply the fused epilogue (activation + residual add) to the
/// `[r0, r1) × [j0, j1)` region of C.
fn region_epilogue(cv: &CView, n: usize, r0: usize, r1: usize, j0: usize,
                   j1: usize, epi: Epilogue, residual: Option<&[f32]>) {
    let jw = j1 - j0;
    for i in r0..r1 {
        // SAFETY: this tile owns the region (see CView::row).
        let row = unsafe { cv.row(i, j0, jw) };
        match (epi, residual) {
            (Epilogue::Linear, None) => {}
            (Epilogue::Linear, Some(r)) => {
                let rrow = &r[i * n + j0..i * n + j1];
                for (ci, &ri) in row.iter_mut().zip(rrow) {
                    *ci += ri;
                }
            }
            (Epilogue::Silu, None) => {
                for ci in row.iter_mut() {
                    *ci = silu(*ci);
                }
            }
            (Epilogue::Silu, Some(r)) => {
                let rrow = &r[i * n + j0..i * n + j1];
                for (ci, &ri) in row.iter_mut().zip(rrow) {
                    *ci = ri + silu(*ci);
                }
            }
        }
    }
}

/// Full bias→accumulate→epilogue computation of one C region against
/// the *unpacked* row-major `B` (the v1 kernel, generalized to column
/// ranges so 2-D shards can call it per tile).
fn unpacked_region(n: usize, k: usize, a: &[f32], b: &[f32],
                   bias: Option<&[f32]>, epi: Epilogue,
                   residual: Option<&[f32]>, cv: &CView, r0: usize,
                   r1: usize, j0: usize, j1: usize) {
    if r1 <= r0 || j1 <= j0 {
        return;
    }
    region_seed(cv, r0, r1, j0, j1, bias);
    // accumulate k-panels in ascending order (the determinism contract)
    let mut p0 = 0usize;
    while p0 < k {
        let pc = KC.min(k - p0);
        let mut i0 = r0;
        while i0 + MR <= r1 {
            kernel_mr(n, k, a, b, cv, i0, p0, pc, j0, j1);
            i0 += MR;
        }
        while i0 < r1 {
            kernel_1(n, k, a, b, cv, i0, p0, pc, j0, j1);
            i0 += 1;
        }
        p0 += pc;
    }
    region_epilogue(cv, n, r0, r1, j0, j1, epi, residual);
}

/// C[m×n] = epilogue(bias + A[m×k]·B[k×n]) (+ residual), all row-major.
///
/// * `bias`: length-`n` row added to every output row before the
///   reduction (it seeds the accumulator — same order as the scalar
///   path). `None` seeds with zero.
/// * `residual`: length `m*n`; when present the epilogue stores
///   `residual[i][j] + epi(acc)` — the fused skip-connection of the
///   MLP's hidden blocks.
///
/// `c` is fully overwritten; it must not alias `a`, `b` or `residual`.
pub fn gemm_bias_act(m: usize, n: usize, k: usize, a: &[f32], b: &[f32],
                     bias: Option<&[f32]>, epi: Epilogue,
                     residual: Option<&[f32]>, c: &mut [f32]) {
    assert_eq!(a.len(), m * k, "gemm: A is not m×k");
    assert_eq!(b.len(), k * n, "gemm: B is not k×n");
    assert_eq!(c.len(), m * n, "gemm: C is not m×n");
    if let Some(bias) = bias {
        assert_eq!(bias.len(), n, "gemm: bias is not length n");
    }
    if let Some(r) = residual {
        assert_eq!(r.len(), m * n, "gemm: residual is not m×n");
    }
    if m == 0 || n == 0 {
        return;
    }
    let cv = CView { ptr: c.as_mut_ptr(), n };
    unpacked_region(n, k, a, b, bias, epi, residual, &cv, 0, m, 0, n);
}

/// MR-row micro-kernel over columns `[j0, j1)`: accumulate
/// `A[i0..i0+MR][p0..p0+pc] · B[.., j0..j1]` into the MR corresponding
/// C row slices. Every B row slice loaded once per call is reused MR
/// times; the j-loops run over exact-length slices so the
/// autovectorizer sees bounds-check-free contiguous FMA chains.
#[inline]
fn kernel_mr(n: usize, k: usize, a: &[f32], b: &[f32], cv: &CView,
             i0: usize, p0: usize, pc: usize, j0: usize, j1: usize) {
    let jw = j1 - j0;
    // SAFETY: rows i0..i0+MR × columns j0..j1 belong to this tile.
    let (c0, c1, c2, c3) = unsafe {
        (cv.row(i0, j0, jw), cv.row(i0 + 1, j0, jw), cv.row(i0 + 2, j0, jw),
         cv.row(i0 + 3, j0, jw))
    };
    let a0 = &a[i0 * k..i0 * k + k];
    let a1 = &a[(i0 + 1) * k..(i0 + 1) * k + k];
    let a2 = &a[(i0 + 2) * k..(i0 + 2) * k + k];
    let a3 = &a[(i0 + 3) * k..(i0 + 3) * k + k];
    for p in p0..p0 + pc {
        let (x0, x1, x2, x3) = (a0[p], a1[p], a2[p], a3[p]);
        let brow = &b[p * n + j0..p * n + j1];
        for j in 0..jw {
            let bj = brow[j];
            c0[j] += x0 * bj;
            c1[j] += x1 * bj;
            c2[j] += x2 * bj;
            c3[j] += x3 * bj;
        }
    }
}

/// Single-row remainder kernel (same reduction order as `kernel_mr`).
#[inline]
fn kernel_1(n: usize, k: usize, a: &[f32], b: &[f32], cv: &CView,
            i0: usize, p0: usize, pc: usize, j0: usize, j1: usize) {
    let jw = j1 - j0;
    // SAFETY: row i0 × columns j0..j1 belong to this tile.
    let crow = unsafe { cv.row(i0, j0, jw) };
    let arow = &a[i0 * k..i0 * k + k];
    for p in p0..p0 + pc {
        let x = arow[p];
        let brow = &b[p * n + j0..p * n + j1];
        for j in 0..jw {
            crow[j] += x * brow[j];
        }
    }
}

/// Plain product without bias/activation.
pub fn gemm(m: usize, n: usize, k: usize, a: &[f32], b: &[f32],
            c: &mut [f32]) {
    gemm_bias_act(m, n, k, a, b, None, Epilogue::Linear, None, c);
}

// ---------------------------------------------------------------------
// v2: prepacked KC×NR column panels + MR×NR register-tiled micro-kernel
// ---------------------------------------------------------------------

/// A weight matrix repacked once into KC×NR column panels — the
/// load-time half of the v2 kernel.
///
/// Layout: the `k` rows are cut into KC-high k-panels (ascending), and
/// within each k-panel the `n` columns into NR-wide column panels;
/// each `(k-panel, column-panel)` block stores its `pc × NR` floats
/// contiguously, panel-row-major:
///
/// ```text
/// data[p0 * n_padded  +  jp * pc * NR  +  (p - p0) * NR  +  (j - jp*NR)]
/// ```
///
/// The last column panel is zero-padded to NR (padding columns are
/// computed in registers and never stored), so every panel row the
/// micro-kernel touches is one exact-`NR` contiguous slice. `n_padded`
/// is `n` rounded up to NR, and `p0 * n_padded` is exactly the size of
/// all preceding k-panels.
#[derive(Debug, Clone)]
pub struct PackedB {
    k: usize,
    n: usize,
    /// n rounded up to the next NR multiple (floats per packed k-row)
    n_padded: usize,
    data: Vec<f32>,
}

impl PackedB {
    /// Repack a row-major `k×n` matrix. O(k·n) copy, done once per
    /// matrix lifetime (model load for MLP weights).
    pub fn pack(k: usize, n: usize, b: &[f32]) -> PackedB {
        assert_eq!(b.len(), k * n, "PackedB: B is not k×n");
        let n_padded = n.div_ceil(NR) * NR;
        let mut data = vec![0.0f32; k * n_padded];
        let mut p0 = 0usize;
        while p0 < k {
            let pc = KC.min(k - p0);
            let base = p0 * n_padded;
            for jp in 0..n_padded / NR {
                let j0 = jp * NR;
                let jw = NR.min(n - j0);
                let panel = &mut data[base + jp * pc * NR..][..pc * NR];
                for dp in 0..pc {
                    panel[dp * NR..dp * NR + jw].copy_from_slice(
                        &b[(p0 + dp) * n + j0..(p0 + dp) * n + j0 + jw]);
                }
            }
            p0 += pc;
        }
        PackedB { k, n, n_padded, data }
    }

    /// Rows of the packed matrix (the GEMM's shared dimension).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Columns of the packed matrix (the GEMM's output width).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Bytes held by the packed buffer (the load-time memory cost:
    /// `k * round_up(n, NR) * 4`).
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    /// The `pc × NR` panel for k-panel starting at `p0` (height `pc`)
    /// and column panel `jp`.
    #[inline]
    fn panel(&self, p0: usize, pc: usize, jp: usize) -> &[f32] {
        let base = p0 * self.n_padded + jp * pc * NR;
        &self.data[base..base + pc * NR]
    }
}

/// Full bias→accumulate→epilogue computation of one C region against a
/// [`PackedB`]. `j0` must be NR-aligned; `j1` is NR-aligned or `n`
/// (both guaranteed by [`pool::ThreadPool::run_sharded_tiles`] and the
/// serial entry).
fn packed_region(n: usize, k: usize, a: &[f32], pb: &PackedB,
                 bias: Option<&[f32]>, epi: Epilogue,
                 residual: Option<&[f32]>, cv: &CView, r0: usize, r1: usize,
                 j0: usize, j1: usize) {
    if r1 <= r0 || j1 <= j0 {
        return;
    }
    debug_assert_eq!(j0 % NR, 0, "packed tile start must be NR-aligned");
    region_seed(cv, r0, r1, j0, j1, bias);
    let (jp0, jp1) = (j0 / NR, j1.div_ceil(NR));
    // k-panels ascending (the determinism contract); within a k-panel
    // each MR×NR C tile accumulates ascending-p in registers, which is
    // the identical per-element IEEE op sequence
    let mut p0 = 0usize;
    while p0 < k {
        let pc = KC.min(k - p0);
        for jp in jp0..jp1 {
            let jcol = jp * NR;
            let jw = NR.min(j1 - jcol);
            let panel = pb.panel(p0, pc, jp);
            let mut i0 = r0;
            while i0 + MR <= r1 {
                kernel_packed_mr(k, a, panel, cv, i0, jcol, jw, p0, pc);
                i0 += MR;
            }
            while i0 < r1 {
                kernel_packed_1(k, a, panel, cv, i0, jcol, jw, p0, pc);
                i0 += 1;
            }
        }
        p0 += pc;
    }
    region_epilogue(cv, n, r0, r1, j0, j1, epi, residual);
}

/// MR×NR register-tiled packed micro-kernel: load the C tile into a
/// local `[ [f32; NR]; MR ]` (zero in the padding lanes), replay the
/// ascending-p accumulation against exact-`NR` panel rows entirely in
/// registers, store the valid `jw` columns back. Padding lanes
/// accumulate `x * 0.0` and are never stored. The per-element op
/// sequence matches the v1 in-memory accumulation bit for bit.
#[inline]
fn kernel_packed_mr(k: usize, a: &[f32], panel: &[f32], cv: &CView,
                    i0: usize, jcol: usize, jw: usize, p0: usize,
                    pc: usize) {
    // SAFETY: rows i0..i0+MR × columns jcol..jcol+jw belong to this
    // tile.
    let (c0, c1, c2, c3) = unsafe {
        (cv.row(i0, jcol, jw), cv.row(i0 + 1, jcol, jw),
         cv.row(i0 + 2, jcol, jw), cv.row(i0 + 3, jcol, jw))
    };
    let mut t = [[0.0f32; NR]; MR];
    t[0][..jw].copy_from_slice(c0);
    t[1][..jw].copy_from_slice(c1);
    t[2][..jw].copy_from_slice(c2);
    t[3][..jw].copy_from_slice(c3);
    let a0 = &a[i0 * k..i0 * k + k];
    let a1 = &a[(i0 + 1) * k..(i0 + 1) * k + k];
    let a2 = &a[(i0 + 2) * k..(i0 + 2) * k + k];
    let a3 = &a[(i0 + 3) * k..(i0 + 3) * k + k];
    for dp in 0..pc {
        let brow: &[f32; NR] =
            panel[dp * NR..(dp + 1) * NR].try_into().unwrap();
        let p = p0 + dp;
        let (x0, x1, x2, x3) = (a0[p], a1[p], a2[p], a3[p]);
        for j in 0..NR {
            let bj = brow[j];
            t[0][j] += x0 * bj;
            t[1][j] += x1 * bj;
            t[2][j] += x2 * bj;
            t[3][j] += x3 * bj;
        }
    }
    c0.copy_from_slice(&t[0][..jw]);
    c1.copy_from_slice(&t[1][..jw]);
    c2.copy_from_slice(&t[2][..jw]);
    c3.copy_from_slice(&t[3][..jw]);
}

/// Single-row packed remainder kernel (same reduction order).
#[inline]
fn kernel_packed_1(k: usize, a: &[f32], panel: &[f32], cv: &CView,
                   i0: usize, jcol: usize, jw: usize, p0: usize,
                   pc: usize) {
    // SAFETY: row i0 × columns jcol..jcol+jw belong to this tile.
    let crow = unsafe { cv.row(i0, jcol, jw) };
    let mut t = [0.0f32; NR];
    t[..jw].copy_from_slice(crow);
    let arow = &a[i0 * k..i0 * k + k];
    for dp in 0..pc {
        let brow: &[f32; NR] =
            panel[dp * NR..(dp + 1) * NR].try_into().unwrap();
        let x = arow[p0 + dp];
        for j in 0..NR {
            t[j] += x * brow[j];
        }
    }
    crow.copy_from_slice(&t[..jw]);
}

fn assert_packed_shapes(m: usize, n: usize, k: usize, a: &[f32],
                        pb: &PackedB, bias: Option<&[f32]>,
                        residual: Option<&[f32]>, c: &[f32]) {
    assert_eq!(a.len(), m * k, "packed gemm: A is not m×k");
    assert_eq!(pb.k, k, "packed gemm: PackedB k mismatch");
    assert_eq!(pb.n, n, "packed gemm: PackedB n mismatch");
    assert_eq!(c.len(), m * n, "packed gemm: C is not m×n");
    if let Some(bias) = bias {
        assert_eq!(bias.len(), n, "packed gemm: bias is not length n");
    }
    if let Some(r) = residual {
        assert_eq!(r.len(), m * n, "packed gemm: residual is not m×n");
    }
}

/// [`gemm_bias_act`] against a [`PackedB`] — the serial v2 kernel.
/// Bit-identical to [`gemm_ref`] (see the module contract).
pub fn gemm_packed_bias_act(m: usize, n: usize, k: usize, a: &[f32],
                            pb: &PackedB, bias: Option<&[f32]>,
                            epi: Epilogue, residual: Option<&[f32]>,
                            c: &mut [f32]) {
    assert_packed_shapes(m, n, k, a, pb, bias, residual, c);
    if m == 0 || n == 0 {
        return;
    }
    let cv = CView { ptr: c.as_mut_ptr(), n };
    packed_region(n, k, a, pb, bias, epi, residual, &cv, 0, m, 0, n);
}

/// [`gemm_packed_bias_act`] with the output split into a 2-D grid of
/// MR-aligned row ranges × NR-panel-aligned column ranges executed
/// concurrently on the process-global worker pool
/// ([`pool::ThreadPool::run_sharded_tiles`], which searches M×N
/// factorizations to fill every shard — e.g. 4 row blocks on 6 shards
/// run as a 3×2 grid, not a 4×1 grid with two workers idle). Small-M
/// products — the fused serving rounds — still occupy the whole pool
/// through their column panels. Each C tile is owned by exactly one
/// task and every element's reduction is computed whole inside its
/// tile, so the result is bit-identical to the serial call for every
/// shard count and every steal schedule. Returns the effective tile
/// count.
pub fn gemm_packed_sharded(m: usize, n: usize, k: usize, a: &[f32],
                           pb: &PackedB, bias: Option<&[f32]>,
                           epi: Epilogue, residual: Option<&[f32]>,
                           c: &mut [f32], shards: usize) -> usize {
    if shards <= 1 || (m <= MR && n <= NR) || m == 0 || n == 0 {
        gemm_packed_bias_act(m, n, k, a, pb, bias, epi, residual, c);
        return 1;
    }
    assert_packed_shapes(m, n, k, a, pb, bias, residual, c);
    let cv = CView { ptr: c.as_mut_ptr(), n };
    pool::global()
        .run_sharded_tiles(m, MR, n, NR, shards, |r0, r1, j0, j1| {
            packed_region(n, k, a, pb, bias, epi, residual, &cv, r0, r1,
                          j0, j1);
        })
        .max(1)
}

/// [`gemm_bias_act`] (the unpacked v1 kernel) with the output split
/// into a 2-D grid of MR-aligned row ranges × NR-aligned column ranges
/// executed concurrently on the process-global worker pool (same
/// utilization-maximizing grid search as [`gemm_packed_sharded`]).
/// Bit-identical to the serial call for every shard count and steal
/// schedule (tiles own whole elements). Returns the effective tile
/// count.
pub fn gemm_sharded(m: usize, n: usize, k: usize, a: &[f32], b: &[f32],
                    bias: Option<&[f32]>, epi: Epilogue,
                    residual: Option<&[f32]>, c: &mut [f32],
                    shards: usize) -> usize {
    if shards <= 1 || (m <= MR && n <= NR) || m == 0 || n == 0 {
        gemm_bias_act(m, n, k, a, b, bias, epi, residual, c);
        return 1;
    }
    assert_eq!(a.len(), m * k, "gemm_sharded: A is not m×k");
    assert_eq!(b.len(), k * n, "gemm_sharded: B is not k×n");
    assert_eq!(c.len(), m * n, "gemm_sharded: C is not m×n");
    if let Some(bias) = bias {
        assert_eq!(bias.len(), n, "gemm_sharded: bias is not length n");
    }
    if let Some(r) = residual {
        assert_eq!(r.len(), m * n, "gemm_sharded: residual is not m×n");
    }
    let cv = CView { ptr: c.as_mut_ptr(), n };
    pool::global()
        .run_sharded_tiles(m, MR, n, NR, shards, |r0, r1, j0, j1| {
            unpacked_region(n, k, a, b, bias, epi, residual, &cv, r0, r1,
                            j0, j1);
        })
        .max(1)
}

/// Naive triple-loop reference with the same per-element reduction
/// order — the oracle the blocked/tiled/packed/sharded kernels are
/// tested against (bit-exact, not just approximately equal).
pub fn gemm_ref(m: usize, n: usize, k: usize, a: &[f32], b: &[f32],
                bias: Option<&[f32]>, epi: Epilogue,
                residual: Option<&[f32]>, c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = bias.map_or(0.0, |bv| bv[j]);
            for p in 0..k {
                acc += a[i * k + p] * b[p * n + j];
            }
            if epi == Epilogue::Silu {
                acc = silu(acc);
            }
            if let Some(r) = residual {
                // same operand order as the fused epilogue: res + act
                acc = r[i * n + j] + acc;
            }
            c[i * n + j] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(len: usize, seed: u32) -> Vec<f32> {
        (0..len)
            .map(|i| {
                let v = (i as u32).wrapping_mul(2654435761)
                    .wrapping_add(seed.wrapping_mul(40503));
                (v % 2003) as f32 / 2003.0 - 0.5
            })
            .collect()
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    /// Shapes straddling the MR (4), NR (8) and KC (256) boundaries.
    const SHAPES: &[(usize, usize, usize)] = &[
        (0, 3, 4), (1, 1, 1), (1, 7, 5), (3, 2, 9), (4, 4, 4), (4, 8, 8),
        (5, 3, 300), (5, 9, 17), (7, 13, 257), (8, 1, 2), (8, 16, 256),
        (13, 17, 31), (4, 24, 256),
    ];

    #[test]
    fn blocked_matches_reference_bitwise_across_shapes() {
        for &(m, n, k) in SHAPES {
            let a = fill(m * k, 1);
            let b = fill(k * n, 2);
            let bias = fill(n, 3);
            let res = fill(m * n, 4);
            for epi in [Epilogue::Linear, Epilogue::Silu] {
                for (bias_o, res_o) in [(None, None), (Some(&bias), None),
                                        (Some(&bias), Some(&res))] {
                    let mut want = vec![0.0f32; m * n];
                    gemm_ref(m, n, k, &a, &b, bias_o.map(|v| &v[..]), epi,
                             res_o.map(|v| &v[..]), &mut want);
                    let mut got = vec![7.0f32; m * n];
                    gemm_bias_act(m, n, k, &a, &b, bias_o.map(|v| &v[..]),
                                  epi, res_o.map(|v| &v[..]), &mut got);
                    assert_eq!(bits(&want), bits(&got),
                               "m={m} n={n} k={k} epi={epi:?}");
                }
            }
        }
    }

    #[test]
    fn packed_matches_reference_bitwise_across_shapes() {
        for &(m, n, k) in SHAPES {
            let a = fill(m * k, 11);
            let b = fill(k * n, 12);
            let bias = fill(n, 13);
            let res = fill(m * n, 14);
            let pb = PackedB::pack(k, n, &b);
            assert_eq!(pb.k(), k);
            assert_eq!(pb.n(), n);
            assert_eq!(pb.bytes(), k * n.div_ceil(NR) * NR * 4);
            for epi in [Epilogue::Linear, Epilogue::Silu] {
                for (bias_o, res_o) in [(None, None), (Some(&bias), None),
                                        (Some(&bias), Some(&res))] {
                    let mut want = vec![0.0f32; m * n];
                    gemm_ref(m, n, k, &a, &b, bias_o.map(|v| &v[..]), epi,
                             res_o.map(|v| &v[..]), &mut want);
                    let mut got = vec![7.0f32; m * n];
                    gemm_packed_bias_act(m, n, k, &a, &pb,
                                         bias_o.map(|v| &v[..]), epi,
                                         res_o.map(|v| &v[..]), &mut got);
                    assert_eq!(bits(&want), bits(&got),
                               "packed m={m} n={n} k={k} epi={epi:?}");
                }
            }
        }
    }

    #[test]
    fn sharded_matches_serial_bitwise() {
        let (m, n, k) = (37usize, 19usize, 23usize);
        let a = fill(m * k, 5);
        let b = fill(k * n, 6);
        let bias = fill(n, 7);
        let mut want = vec![0.0f32; m * n];
        gemm_bias_act(m, n, k, &a, &b, Some(&bias), Epilogue::Silu, None,
                      &mut want);
        for shards in [1usize, 2, 3, 8, 64] {
            let mut got = vec![0.0f32; m * n];
            let eff = gemm_sharded(m, n, k, &a, &b, Some(&bias),
                                   Epilogue::Silu, None, &mut got, shards);
            assert!(eff >= 1);
            assert_eq!(bits(&want), bits(&got), "shards={shards}");
        }
    }

    #[test]
    fn packed_sharded_is_bit_invariant_in_shard_count() {
        // odd/rectangular shapes, including the small-M serve shape
        // whose parallelism comes entirely from column panels
        for &(m, n, k) in &[(4usize, 96usize, 64usize), (37, 19, 23),
                            (16, 40, 300), (5, 64, 16)] {
            let a = fill(m * k, 21);
            let b = fill(k * n, 22);
            let bias = fill(n, 23);
            let res = fill(m * n, 24);
            let pb = PackedB::pack(k, n, &b);
            let mut want = vec![0.0f32; m * n];
            gemm_ref(m, n, k, &a, &b, Some(&bias), Epilogue::Silu,
                     Some(&res), &mut want);
            for shards in [1usize, 2, 8, 64] {
                let mut got = vec![0.0f32; m * n];
                let eff = gemm_packed_sharded(m, n, k, &a, &pb, Some(&bias),
                                              Epilogue::Silu, Some(&res),
                                              &mut got, shards);
                assert!(eff >= 1 && eff <= shards.max(1));
                assert_eq!(bits(&want), bits(&got),
                           "m={m} n={n} k={k} shards={shards}");
            }
        }
    }

    #[test]
    fn small_m_sharding_tiles_column_panels() {
        // m=4 is a single MR block: v1's M-only split would have run
        // serial; the 2-D grid must still fan out over column panels
        let (m, n, k) = (4usize, 128usize, 32usize);
        let a = fill(m * k, 31);
        let b = fill(k * n, 32);
        let pb = PackedB::pack(k, n, &b);
        let mut want = vec![0.0f32; m * n];
        gemm_ref(m, n, k, &a, &b, None, Epilogue::Linear, None, &mut want);
        let mut got = vec![0.0f32; m * n];
        let eff = gemm_packed_sharded(m, n, k, &a, &pb, None,
                                      Epilogue::Linear, None, &mut got, 8);
        assert!(eff > 1, "small-M product did not tile over N (eff={eff})");
        assert_eq!(bits(&want), bits(&got));
    }

    #[test]
    fn plain_gemm_identity() {
        // A · I == A
        let m = 5;
        let n = 6;
        let a = fill(m * n, 8);
        let mut eye = vec![0.0f32; n * n];
        for i in 0..n {
            eye[i * n + i] = 1.0;
        }
        let mut c = vec![0.0f32; m * n];
        gemm(m, n, n, &a, &eye, &mut c);
        assert_eq!(bits(&a), bits(&c));
    }

    #[test]
    fn silu_epilogue_matches_scalar_definition() {
        // 1×1 GEMM: c = silu(bias + a*b), silu built on exp_fast
        let mut c = vec![0.0f32];
        gemm_bias_act(1, 1, 1, &[2.0], &[3.0], Some(&[0.5]), Epilogue::Silu,
                      None, &mut c);
        let x = 0.5f32 + 2.0 * 3.0;
        assert_eq!(c[0].to_bits(), (x / (1.0 + exp_fast(-x))).to_bits());
        // and tracks the libm definition well inside the parity budget
        let libm = x / (1.0 + (-x).exp());
        assert!((c[0] - libm).abs() <= 1e-6 * libm.abs());
    }

    #[test]
    fn exp_fast_is_exact_at_zero_and_tracks_libm() {
        assert_eq!(exp_fast(0.0), 1.0);
        assert_eq!(exp_fast(-0.0), 1.0);
        for i in -8700..=8800 {
            let x = i as f32 * 0.01; // [-87, 88]: normal-range expf
            let want = x.exp();
            let got = exp_fast(x);
            let rel = ((got - want) / want).abs();
            assert!(rel < 1e-6,
                    "x={x}: libm {want} vs fast {got} (rel {rel})");
        }
        // non-finite / extreme semantics match the libm form
        assert!(exp_fast(f32::NAN).is_nan());
        assert_eq!(exp_fast(f32::INFINITY), f32::INFINITY);
        assert_eq!(exp_fast(100.0), f32::INFINITY); // libm overflow region
        // saturation starts right at the clamp point — no band where
        // the result silently underestimates
        assert_eq!(exp_fast(88.31), f32::INFINITY);
        assert!(exp_fast(88.3).is_finite());
        assert!((exp_fast(88.3) / 88.3f32.exp() - 1.0).abs() < 1e-6);
        assert!(exp_fast(f32::NEG_INFINITY) < 1.2e-38); // flushed, not 0
        assert!(silu(f32::NAN).is_nan());
        assert!(silu(f32::NEG_INFINITY).is_nan()); // -inf/inf, as libm
        assert_eq!(silu(f32::INFINITY), f32::INFINITY);
        // deep saturation: exact -0.0 on the left (x/inf), identity on
        // the right (denominator rounds to 1.0)
        assert_eq!(silu(-200.0), 0.0);
        assert!(silu(-200.0).is_sign_negative());
        assert_eq!(silu(200.0), 200.0);
    }

    #[test]
    #[should_panic(expected = "A is not m×k")]
    fn shape_mismatch_panics() {
        let mut c = vec![0.0f32; 4];
        gemm(2, 2, 3, &[0.0; 5], &[0.0; 6], &mut c);
    }

    #[test]
    #[should_panic(expected = "PackedB k mismatch")]
    fn packed_shape_mismatch_panics() {
        let pb = PackedB::pack(3, 2, &[0.0; 6]);
        let mut c = vec![0.0f32; 4];
        gemm_packed_bias_act(2, 2, 2, &[0.0; 4], &pb, None,
                             Epilogue::Linear, None, &mut c);
    }
}
