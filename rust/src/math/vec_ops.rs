//! Dense vector primitives for the ASD hot path.
//!
//! Everything operates on `&[f64]` / `&mut [f64]` slices so the engine
//! can run allocation-free over preallocated chain buffers.

#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[inline]
pub fn norm_sq(a: &[f64]) -> f64 {
    a.iter().map(|x| x * x).sum()
}

#[inline]
pub fn norm(a: &[f64]) -> f64 {
    norm_sq(a).sqrt()
}

#[inline]
pub fn dist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt()
}

/// out = c1 * x + c2 * y
#[inline]
pub fn lincomb_into(out: &mut [f64], c1: f64, x: &[f64], c2: f64, y: &[f64]) {
    debug_assert!(out.len() == x.len() && x.len() == y.len());
    for i in 0..out.len() {
        out[i] = c1 * x[i] + c2 * y[i];
    }
}

/// out = a + s * b
#[inline]
pub fn axpy_into(out: &mut [f64], a: &[f64], s: f64, b: &[f64]) {
    debug_assert!(out.len() == a.len() && a.len() == b.len());
    for i in 0..out.len() {
        out[i] = a[i] + s * b[i];
    }
}

/// a += s * b
#[inline]
pub fn axpy(a: &mut [f64], s: f64, b: &[f64]) {
    debug_assert_eq!(a.len(), b.len());
    for i in 0..a.len() {
        a[i] += s * b[i];
    }
}

#[inline]
pub fn scale(a: &mut [f64], s: f64) {
    for x in a {
        *x *= s;
    }
}

/// Column-wise mean over `rows`. An empty input has no dimensionality,
/// so it returns the empty vector (the seed indexed `rows[0]` and
/// panicked) — callers that need a fixed-width zero mean must handle
/// the empty case themselves.
pub fn mean_axis0(rows: &[Vec<f64>]) -> Vec<f64> {
    let Some(first) = rows.first() else {
        return Vec::new();
    };
    let mut m = vec![0.0; first.len()];
    for r in rows {
        axpy(&mut m, 1.0, r);
    }
    scale(&mut m, 1.0 / rows.len() as f64);
    m
}

/// Raw IEEE-754 bit patterns of a slice — the currency of the
/// bit-exactness tests (sharded execution must reproduce serial
/// output exactly; see runtime::pool).
pub fn to_bits_vec(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Reflection of `xi` along `v` (Alg 3 line 6): xi - 2 v <v,xi>/||v||^2.
pub fn reflect_into(out: &mut [f64], xi: &[f64], v: &[f64]) {
    debug_assert_eq!(out.len(), xi.len());
    debug_assert_eq!(out.len(), v.len());
    let v_sq = norm_sq(v).max(1e-300);
    let coef = 2.0 * dot(v, xi) / v_sq;
    for i in 0..out.len() {
        out[i] = xi[i] - coef * v[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn basic_ops() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert_eq!(norm(&[3.0, 4.0]), 5.0);
        assert_eq!(dist(&[0.0, 0.0], &[3.0, 4.0]), 5.0);
        let mut out = vec![0.0; 2];
        lincomb_into(&mut out, 2.0, &[1.0, 1.0], 3.0, &[1.0, 2.0]);
        assert_eq!(out, vec![5.0, 8.0]);
        axpy_into(&mut out, &[1.0, 1.0], 0.5, &[2.0, 4.0]);
        assert_eq!(out, vec![2.0, 3.0]);
    }

    #[test]
    fn reflection_is_isometric_involution() {
        prop::check("reflect", 50, |g| {
            let d = g.usize_in(1, 16);
            let xi = g.normal_vec(d);
            let mut v = g.normal_vec(d);
            if norm(&v) < 1e-9 {
                v[0] += 1.0;
            }
            let mut r = vec![0.0; d];
            reflect_into(&mut r, &xi, &v);
            // isometry
            assert!((norm(&r) - norm(&xi)).abs() < 1e-9);
            // involution
            let mut rr = vec![0.0; d];
            reflect_into(&mut rr, &r, &v);
            for i in 0..d {
                assert!((rr[i] - xi[i]).abs() < 1e-9);
            }
            // flips the v-component, keeps the orthogonal part
            let v_comp = dot(&r, &v) / norm(&v);
            let xi_comp = dot(&xi, &v) / norm(&v);
            assert!((v_comp + xi_comp).abs() < 1e-9);
        });
    }

    #[test]
    fn mean_axis0_works() {
        let rows = vec![vec![1.0, 2.0], vec![3.0, 6.0]];
        assert_eq!(mean_axis0(&rows), vec![2.0, 4.0]);
    }

    #[test]
    fn mean_axis0_empty_input_is_empty_not_a_panic() {
        let rows: Vec<Vec<f64>> = Vec::new();
        assert_eq!(mean_axis0(&rows), Vec::<f64>::new());
        // single empty row is also well-defined
        assert_eq!(mean_axis0(&[Vec::new()]), Vec::<f64>::new());
    }
}
