//! Error function and the standard normal CDF.
//!
//! `erf` uses the Abramowitz & Stegun 7.1.26-style rational approximation
//! refined to double precision (max abs error < 1.2e-7 for the classic
//! form; we use the higher-order W. J. Cody-style expansion below, good
//! to ~1e-15 via the complementary series for large x).

/// erf(x) to ~1e-12 absolute accuracy.
pub fn erf(x: f64) -> f64 {
    // series for small |x|, continued-fraction-free complementary
    // expansion otherwise
    let ax = x.abs();
    if ax < 0.5 {
        // Taylor/series: erf(x) = 2/sqrt(pi) sum (-1)^n x^(2n+1)/(n!(2n+1))
        let t = x * x;
        let mut term = x;
        let mut sum = x;
        for n in 1..40 {
            term *= -t / n as f64;
            let add = term / (2 * n + 1) as f64;
            sum += add;
            if add.abs() < 1e-17 * sum.abs() {
                break;
            }
        }
        return sum * 2.0 / std::f64::consts::PI.sqrt();
    }
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    sign * (1.0 - erfc_pos(ax))
}

/// erfc(x) for x >= 0.5 via the asymptotic-safe rational approximation
/// (Numerical Recipes' erfccheb-quality fit).
fn erfc_pos(x: f64) -> f64 {
    debug_assert!(x >= 0.0);
    let t = 2.0 / (2.0 + x);
    let ty = 4.0 * t - 2.0;
    // Chebyshev coefficients (Numerical Recipes 3rd ed., erfc)
    const COF: [f64; 28] = [
        -1.3026537197817094,
        6.4196979235649026e-1,
        1.9476473204185836e-2,
        -9.561514786808631e-3,
        -9.46595344482036e-4,
        3.66839497852761e-4,
        4.2523324806907e-5,
        -2.0278578112534e-5,
        -1.624290004647e-6,
        1.303655835580e-6,
        1.5626441722e-8,
        -8.5238095915e-8,
        6.529054439e-9,
        5.059343495e-9,
        -9.91364156e-10,
        -2.27365122e-10,
        9.6467911e-11,
        2.394038e-12,
        -6.886027e-12,
        8.94487e-13,
        3.13092e-13,
        -1.12708e-13,
        3.81e-16,
        7.106e-15,
        -1.523e-15,
        -9.4e-17,
        1.21e-16,
        -2.8e-17,
    ];
    let mut d = 0.0;
    let mut dd = 0.0;
    for &c in COF.iter().rev().take(COF.len() - 1) {
        let tmp = d;
        d = ty * d - dd + c;
        dd = tmp;
    }
    t * (-x * x + 0.5 * (COF[0] + ty * d) - dd).exp()
}

/// Standard normal CDF.
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// TV distance between N(m1, s^2 I) and N(m2, s^2 I) with
/// ||m1 - m2|| = v_norm:  TV = 2 Phi(v/2s) - 1  (used by Thm 12 tests).
pub fn gaussian_tv(v_norm: f64, sigma: f64) -> f64 {
    if sigma <= 0.0 {
        return if v_norm > 0.0 { 1.0 } else { 0.0 };
    }
    2.0 * normal_cdf(v_norm / (2.0 * sigma)) - 1.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_reference_values() {
        // reference values from tables
        let cases = [
            (0.0, 0.0),
            (0.5, 0.5204998778130465),
            (1.0, 0.8427007929497149),
            (2.0, 0.9953222650189527),
            (3.0, 0.9999779095030014),
            (-1.0, -0.8427007929497149),
        ];
        for (x, want) in cases {
            let got = erf(x);
            assert!((got - want).abs() < 1e-10, "erf({x}) = {got}, want {want}");
        }
    }

    #[test]
    fn normal_cdf_values() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-12);
        assert!((normal_cdf(1.96) - 0.9750021048517795).abs() < 1e-9);
        assert!((normal_cdf(-1.0) - 0.15865525393145707).abs() < 1e-9);
    }

    #[test]
    fn erf_is_odd_and_monotone() {
        let mut prev = -1.0;
        for i in -40..=40 {
            let x = i as f64 * 0.1;
            assert!((erf(x) + erf(-x)).abs() < 1e-12);
            assert!(erf(x) >= prev);
            prev = erf(x);
        }
    }

    #[test]
    fn gaussian_tv_limits() {
        assert!(gaussian_tv(0.0, 1.0).abs() < 1e-12);
        assert!(gaussian_tv(1e6, 1.0) > 0.999999);
        assert_eq!(gaussian_tv(1.0, 0.0), 1.0);
        assert_eq!(gaussian_tv(0.0, 0.0), 0.0);
    }
}
