//! Noise schedules: DDPM (linear-beta, x0-parametrization coefficients)
//! and the Stochastic Localization reparametrization (Thm 9).

pub mod ddpm;
pub mod sl;

pub use ddpm::DdpmSchedule;
pub use sl::{ddpm_time_of_sl, sl_time_of_ddpm, SlGrid};
