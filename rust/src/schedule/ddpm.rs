//! DDPM linear-beta schedule in x0-prediction form (paper Remark 2).
//!
//! Mirrors python/compile/schedule.py exactly; integration tests
//! cross-check against the abar table exported in manifest.json and the
//! spot values in golden.json.
//!
//! Reverse step (descending index i = K..1; arrays are 0-based at i-1):
//!   y_{i-1} = c1[i-1] * x0hat(y_i, i) + c2[i-1] * y_i + sigma[i-1] * xi

pub const BETA_START: f64 = 1e-4;
pub const BETA_END: f64 = 2e-2;
pub const REF_STEPS: f64 = 1000.0;

#[derive(Debug, Clone)]
pub struct DdpmSchedule {
    pub k_steps: usize,
    pub betas: Vec<f64>,
    pub alphas: Vec<f64>,
    pub abar: Vec<f64>,
    pub abar_prev: Vec<f64>,
    /// coefficient on x0hat
    pub c1: Vec<f64>,
    /// coefficient on the current iterate
    pub c2: Vec<f64>,
    /// posterior stddev; sigma[0] == 0 (final step is a Dirac)
    pub sigma: Vec<f64>,
}

impl DdpmSchedule {
    pub fn new(k_steps: usize) -> DdpmSchedule {
        assert!(k_steps >= 2, "need at least 2 steps");
        let scale = REF_STEPS / k_steps as f64;
        let lo = BETA_START * scale;
        let hi = BETA_END * scale;
        let mut betas = Vec::with_capacity(k_steps);
        for i in 0..k_steps {
            let t = i as f64 / (k_steps - 1) as f64;
            betas.push((lo + t * (hi - lo)).min(0.999));
        }
        Self::from_betas(betas)
    }

    pub fn from_betas(betas: Vec<f64>) -> DdpmSchedule {
        let k = betas.len();
        let alphas: Vec<f64> = betas.iter().map(|b| 1.0 - b).collect();
        let mut abar = Vec::with_capacity(k);
        let mut acc = 1.0;
        for &a in &alphas {
            acc *= a;
            abar.push(acc);
        }
        let mut abar_prev = Vec::with_capacity(k);
        abar_prev.push(1.0);
        abar_prev.extend_from_slice(&abar[..k - 1]);
        let mut c1 = Vec::with_capacity(k);
        let mut c2 = Vec::with_capacity(k);
        let mut sigma = Vec::with_capacity(k);
        for i in 0..k {
            let denom = 1.0 - abar[i];
            c1.push(abar_prev[i].sqrt() * betas[i] / denom);
            c2.push(alphas[i].sqrt() * (1.0 - abar_prev[i]) / denom);
            sigma.push(((1.0 - abar_prev[i]) * betas[i] / denom).sqrt());
        }
        DdpmSchedule { k_steps: k, betas, alphas, abar, abar_prev, c1, c2, sigma }
    }

    /// Build from an explicit abar table (e.g. the manifest's) — used to
    /// guarantee bit-consistency with the python-side training schedule.
    pub fn from_abar(abar: Vec<f64>) -> DdpmSchedule {
        let k = abar.len();
        let mut betas = Vec::with_capacity(k);
        let mut prev = 1.0;
        for &a in &abar {
            betas.push(1.0 - a / prev);
            prev = a;
        }
        Self::from_betas(betas)
    }

    /// Forward-noising coefficients: y_i = sa * x0 + s1m * eps.
    pub fn forward_coefs(&self, i: usize) -> (f64, f64) {
        let a = self.abar[i - 1];
        (a.sqrt(), (1.0 - a).sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn posterior_mean_identity() {
        // c1_i + c2_i sqrt(abar_i) == sqrt(abar_{i-1})
        for k in [50, 100, 1000] {
            let s = DdpmSchedule::new(k);
            for i in 0..k {
                let lhs = s.c1[i] + s.c2[i] * s.abar[i].sqrt();
                let rhs = s.abar_prev[i].sqrt();
                assert!((lhs - rhs).abs() < 1e-10, "k={k} i={i}");
            }
        }
    }

    #[test]
    fn posterior_variance_identity() {
        // c2^2 (1-abar) + sigma^2 == 1 - abar_prev
        for k in [100, 1000] {
            let s = DdpmSchedule::new(k);
            for i in 0..k {
                let lhs = s.c2[i] * s.c2[i] * (1.0 - s.abar[i])
                    + s.sigma[i] * s.sigma[i];
                assert!((lhs - (1.0 - s.abar_prev[i])).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn shapes_and_bounds() {
        let s = DdpmSchedule::new(100);
        assert_eq!(s.sigma[0], 0.0);
        assert!(s.sigma[1..].iter().all(|&x| x > 0.0));
        assert!(s.abar.windows(2).all(|w| w[1] < w[0]));
        assert!(s.abar[99] < 5e-5);
    }

    #[test]
    fn from_abar_roundtrip() {
        let s1 = DdpmSchedule::new(100);
        let s2 = DdpmSchedule::from_abar(s1.abar.clone());
        for i in 0..100 {
            assert!((s1.c1[i] - s2.c1[i]).abs() < 1e-9);
            assert!((s1.c2[i] - s2.c2[i]).abs() < 1e-9);
            assert!((s1.sigma[i] - s2.sigma[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn matches_python_linspace() {
        // python: np.linspace(1e-4*10, 2e-2*10, 100) for K=100
        let s = DdpmSchedule::new(100);
        assert!((s.betas[0] - 1e-3).abs() < 1e-12);
        assert!((s.betas[99] - 0.2).abs() < 1e-12);
        let mid = 1e-3 + (0.2 - 1e-3) * (50.0 / 99.0);
        assert!((s.betas[50] - mid).abs() < 1e-12);
    }
}
