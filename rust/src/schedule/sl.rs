//! Stochastic Localization time grids + the DDPM<->SL reparametrization
//! (paper Thm 9): ybar_t = t e^{s(t)} xbar_{s(t)}, s(t) = ln(1 + 1/t)/2.
//!
//! The SL-native path drives the theory benches (Thm 4 scaling) with the
//! analytic GMM posterior-mean oracle: Euler steps
//!   y_{k+1} = y_k + eta_k m(t_k, y_k) + sqrt(eta_k) xi.

/// s(t) = ln(1 + 1/t)/2: SL time -> OU (DDPM) time.
pub fn ddpm_time_of_sl(t: f64) -> f64 {
    0.5 * (1.0 + 1.0 / t).ln()
}

/// t(s) = 1/(e^{2s} - 1): OU time -> SL time.
pub fn sl_time_of_ddpm(s: f64) -> f64 {
    1.0 / (2.0 * s).exp_m1()
}

/// An SL Euler discretization grid on [t0, t_max].
#[derive(Debug, Clone)]
pub struct SlGrid {
    /// grid points t_0 < t_1 < ... < t_K
    pub times: Vec<f64>,
    /// eta_k = t_{k+1} - t_k (len K)
    pub etas: Vec<f64>,
}

impl SlGrid {
    /// Uniform grid: eta = t_max / K starting at t = 0.
    pub fn uniform(t_max: f64, k_steps: usize) -> SlGrid {
        let eta = t_max / k_steps as f64;
        let times: Vec<f64> = (0..=k_steps).map(|k| k as f64 * eta).collect();
        let etas = vec![eta; k_steps];
        SlGrid { times, etas }
    }

    /// Geometric grid from t0 > 0 to t_max (finer early, as DDPM
    /// schedules effectively are after reparametrization).
    pub fn geometric(t0: f64, t_max: f64, k_steps: usize) -> SlGrid {
        assert!(t0 > 0.0 && t_max > t0);
        let ratio = (t_max / t0).powf(1.0 / k_steps as f64);
        let mut times = Vec::with_capacity(k_steps + 1);
        let mut t = t0;
        for _ in 0..=k_steps {
            times.push(t);
            t *= ratio;
        }
        let etas = times.windows(2).map(|w| w[1] - w[0]).collect();
        SlGrid { times, etas }
    }

    pub fn k_steps(&self) -> usize {
        self.etas.len()
    }

    pub fn max_eta(&self) -> f64 {
        self.etas.iter().cloned().fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_maps_roundtrip() {
        for i in 1..50 {
            let s = i as f64 * 0.1;
            let t = sl_time_of_ddpm(s);
            assert!((ddpm_time_of_sl(t) - s).abs() < 1e-10);
        }
    }

    #[test]
    fn time_maps_monotone_inverse() {
        // larger SL time (more localized) <-> smaller OU time (less noise)
        assert!(ddpm_time_of_sl(10.0) < ddpm_time_of_sl(0.1));
        assert!(sl_time_of_ddpm(3.0) < sl_time_of_ddpm(0.5));
    }

    #[test]
    fn uniform_grid() {
        let g = SlGrid::uniform(10.0, 40);
        assert_eq!(g.k_steps(), 40);
        assert!((g.times[0]).abs() < 1e-12);
        assert!((g.times[40] - 10.0).abs() < 1e-9);
        assert!(g.etas.iter().all(|&e| (e - 0.25).abs() < 1e-12));
        assert!((g.max_eta() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn geometric_grid() {
        let g = SlGrid::geometric(0.01, 100.0, 64);
        assert_eq!(g.k_steps(), 64);
        assert!((g.times[0] - 0.01).abs() < 1e-12);
        assert!((g.times[64] - 100.0).abs() < 1e-6);
        // etas increase
        assert!(g.etas.windows(2).all(|w| w[1] > w[0]));
    }
}
