//! Sample-quality metrics (the paper's CLIP / FID, substituted per
//! DESIGN.md §4 for the known synthetic targets).

use crate::math::stats::{mmd_sq_rbf, sliced_wasserstein};
use crate::model::Gmm;

/// CLIP-proxy: mean Bayes-posterior probability of the conditioning
/// class under the known GMM target (higher = better conditioning
/// fidelity; the target's own samples score ~1 when modes are separated).
pub fn alignment_score(gmm: &Gmm, samples: &[Vec<f64>], classes: &[usize]) -> f64 {
    assert_eq!(samples.len(), classes.len());
    let mut total = 0.0;
    for (x, &c) in samples.iter().zip(classes) {
        total += gmm.class_posterior(x)[c];
    }
    total / samples.len() as f64
}

/// FID-proxy: Frechet distance between Gaussian moment fits of two point
/// clouds, with diagonal covariances (the full-covariance matrix sqrt is
/// overkill at d <= 224 sample sizes and the diagonal version preserves
/// the ranking FID is used for):
///   d^2 = ||mu1 - mu2||^2 + sum_i (s1_i + s2_i - 2 sqrt(s1_i s2_i))
pub fn frechet_diag(a: &[Vec<f64>], b: &[Vec<f64>]) -> f64 {
    let d = a[0].len();
    let (mu_a, var_a) = moments(a, d);
    let (mu_b, var_b) = moments(b, d);
    let mut acc = 0.0;
    for i in 0..d {
        let dm = mu_a[i] - mu_b[i];
        acc += dm * dm;
        acc += var_a[i] + var_b[i] - 2.0 * (var_a[i] * var_b[i]).sqrt();
    }
    acc
}

fn moments(rows: &[Vec<f64>], d: usize) -> (Vec<f64>, Vec<f64>) {
    let n = rows.len() as f64;
    let mut mu = vec![0.0; d];
    for r in rows {
        for i in 0..d {
            mu[i] += r[i];
        }
    }
    mu.iter_mut().for_each(|m| *m /= n);
    let mut var = vec![0.0; d];
    for r in rows {
        for i in 0..d {
            let x = r[i] - mu[i];
            var[i] += x * x;
        }
    }
    var.iter_mut().for_each(|v| *v /= (n - 1.0).max(1.0));
    (mu, var)
}

/// Sliced Wasserstein-1 (distribution-level check used in Table 1/2
/// alongside the primary metric).
pub fn sliced_w(a: &[Vec<f64>], b: &[Vec<f64>]) -> f64 {
    sliced_wasserstein(a, b, 32, 7)
}

/// RBF MMD^2 with the median heuristic bandwidth.
pub fn mmd(a: &[Vec<f64>], b: &[Vec<f64>]) -> f64 {
    let bw = median_pairwise(a).max(1e-6);
    mmd_sq_rbf(a, b, bw)
}

fn median_pairwise(a: &[Vec<f64>]) -> f64 {
    let n = a.len().min(100);
    let mut d = Vec::with_capacity(n * (n - 1) / 2);
    for i in 0..n {
        for j in (i + 1)..n {
            d.push(crate::math::vec_ops::dist(&a[i], &a[j]));
        }
    }
    d.sort_by(|x, y| x.partial_cmp(y).unwrap());
    if d.is_empty() { 1.0 } else { d[d.len() / 2] }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Philox;

    fn cloud(seed: u64, n: usize, d: usize, shift: f64) -> Vec<Vec<f64>> {
        let mut rng = Philox::new(seed, 0);
        (0..n)
            .map(|_| (0..d).map(|_| rng.normal() + shift).collect())
            .collect()
    }

    #[test]
    fn frechet_zero_for_same_law() {
        let a = cloud(1, 800, 4, 0.0);
        let b = cloud(2, 800, 4, 0.0);
        assert!(frechet_diag(&a, &b) < 0.05);
    }

    #[test]
    fn frechet_detects_shift_and_scale() {
        let a = cloud(1, 500, 4, 0.0);
        let shifted = cloud(2, 500, 4, 1.0);
        assert!(frechet_diag(&a, &shifted) > 2.0);
        let mut scaled = cloud(3, 500, 4, 0.0);
        for r in scaled.iter_mut() {
            r.iter_mut().for_each(|x| *x *= 3.0);
        }
        assert!(frechet_diag(&a, &scaled) > 2.0);
    }

    #[test]
    fn alignment_score_on_target_samples() {
        let gmm = Gmm::circle_2d();
        let mut rng = Philox::new(5, 0);
        let mut xs = Vec::new();
        let mut cs = Vec::new();
        for _ in 0..300 {
            let (x, c) = gmm.sample(&mut rng);
            xs.push(x);
            cs.push(c);
        }
        let s = alignment_score(&gmm, &xs, &cs);
        assert!(s > 0.9, "alignment {s}"); // well-separated modes
        // wrong labels score badly
        let wrong: Vec<usize> = cs.iter().map(|c| (c + 4) % 8).collect();
        assert!(alignment_score(&gmm, &xs, &wrong) < 0.05);
    }

    #[test]
    fn mmd_wraps_stats() {
        let a = cloud(7, 150, 3, 0.0);
        let b = cloud(8, 150, 3, 2.0);
        assert!(mmd(&a, &b) > 0.1);
    }
}
