//! Counter-based RNG substrate.
//!
//! All randomness on the request path — the per-step `(u_i, xi_i)` streams
//! that drive sequential DDPM, Picard and ASD (DESIGN.md "randomness
//! contract") — comes from Philox4x32-10, a counter-based generator:
//! streams are addressable by `(seed, stream, counter)`, so experiments
//! are exactly reproducible and independent across requests without
//! shared mutable state.

mod philox;

pub use philox::Philox;

/// Draw a whole standard-normal vector.
pub fn normal_vec(rng: &mut Philox, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.normal()).collect()
}

/// Draw a whole uniform [0,1) vector.
pub fn uniform_vec(rng: &mut Philox, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.uniform()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normal_moments() {
        let mut rng = Philox::new(7, 0);
        let n = 200_000;
        let v = normal_vec(&mut rng, n);
        let mean = v.iter().sum::<f64>() / n as f64;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / (n - 1) as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
        // kurtosis of a standard normal is 3
        let kurt = v.iter().map(|x| x.powi(4)).sum::<f64>() / n as f64;
        assert!((kurt - 3.0).abs() < 0.1, "kurtosis {kurt}");
    }

    #[test]
    fn uniform_moments() {
        let mut rng = Philox::new(8, 0);
        let n = 100_000;
        let v = uniform_vec(&mut rng, n);
        let mean = v.iter().sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01);
        assert!(v.iter().all(|&x| (0.0..1.0).contains(&x)));
    }
}
