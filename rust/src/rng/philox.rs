//! Philox4x32-10 counter-based PRNG (Salmon et al., SC'11) with
//! Box-Muller Gaussian sampling.
//!
//! Counter-based: the i-th block of randomness is a pure function of
//! `(key, counter)`, so streams can be split per request / per DDPM step
//! without locking or state hand-off.

const PHILOX_M0: u32 = 0xD251_1F53;
const PHILOX_M1: u32 = 0xCD9E_8D57;
const PHILOX_W0: u32 = 0x9E37_79B9;
const PHILOX_W1: u32 = 0xBB67_AE85;
const ROUNDS: usize = 10;

/// A Philox4x32-10 stream. `new(seed, stream)` gives independent streams
/// for different `(seed, stream)` pairs.
#[derive(Debug, Clone)]
pub struct Philox {
    key: [u32; 2],
    counter: u64,
    /// buffered 32-bit outputs from the last block
    buf: [u32; 4],
    buf_pos: usize,
    /// cached second Box-Muller output
    spare_normal: Option<f64>,
}

impl Philox {
    pub fn new(seed: u64, stream: u64) -> Philox {
        // mix the stream id into the key halves
        let k0 = (seed as u32) ^ (stream as u32).rotate_left(16);
        let k1 = ((seed >> 32) as u32) ^ ((stream >> 32) as u32);
        Philox {
            key: [k0, k1 ^ 0xA511_E9B3],
            counter: 0,
            buf: [0; 4],
            buf_pos: 4,
            spare_normal: None,
        }
    }

    /// The raw 4x32 block function (pure; exposed for tests).
    pub fn block(key: [u32; 2], counter: u64) -> [u32; 4] {
        let mut c = [
            counter as u32,
            (counter >> 32) as u32,
            0x0123_4567,
            0x89AB_CDEF,
        ];
        let mut k = key;
        for _ in 0..ROUNDS {
            let p0 = (c[0] as u64) * (PHILOX_M0 as u64);
            let p1 = (c[2] as u64) * (PHILOX_M1 as u64);
            c = [
                ((p1 >> 32) as u32) ^ c[1] ^ k[0],
                p1 as u32,
                ((p0 >> 32) as u32) ^ c[3] ^ k[1],
                p0 as u32,
            ];
            k[0] = k[0].wrapping_add(PHILOX_W0);
            k[1] = k[1].wrapping_add(PHILOX_W1);
        }
        c
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        if self.buf_pos == 4 {
            self.buf = Self::block(self.key, self.counter);
            self.counter = self.counter.wrapping_add(1);
            self.buf_pos = 0;
        }
        let v = self.buf[self.buf_pos];
        self.buf_pos += 1;
        v
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        (self.next_u32() as u64) << 32 | self.next_u32() as u64
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in (0, 1] — safe for `ln()`.
    #[inline]
    pub fn uniform_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) + 1) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box-Muller (exact, no tail truncation).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let u1 = self.uniform_open();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare_normal = Some(r * s);
        r * c
    }

    /// Skip to an absolute block counter (stream addressing).
    pub fn seek(&mut self, counter: u64) {
        self.counter = counter;
        self.buf_pos = 4;
        self.spare_normal = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_stream_independent() {
        let mut a = Philox::new(1, 0);
        let mut b = Philox::new(1, 0);
        let va: Vec<u32> = (0..16).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..16).map(|_| b.next_u32()).collect();
        assert_eq!(va, vb);

        let mut c = Philox::new(1, 1);
        let vc: Vec<u32> = (0..16).map(|_| c.next_u32()).collect();
        assert_ne!(va, vc);

        let mut d = Philox::new(2, 0);
        let vd: Vec<u32> = (0..16).map(|_| d.next_u32()).collect();
        assert_ne!(va, vd);
    }

    #[test]
    fn block_is_pure() {
        let b1 = Philox::block([3, 4], 17);
        let b2 = Philox::block([3, 4], 17);
        assert_eq!(b1, b2);
        assert_ne!(Philox::block([3, 4], 18), b1);
    }

    #[test]
    fn seek_replays() {
        let mut a = Philox::new(9, 9);
        let first: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        a.seek(0);
        let replay: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        assert_eq!(first, replay);
    }

    #[test]
    fn uniform_in_range() {
        let mut rng = Philox::new(5, 0);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            let uo = rng.uniform_open();
            assert!(uo > 0.0 && uo <= 1.0);
        }
    }

    #[test]
    fn bit_balance() {
        // each of the 32 bits should be ~50% set
        let mut rng = Philox::new(123, 7);
        let n = 50_000;
        let mut counts = [0u32; 32];
        for _ in 0..n {
            let v = rng.next_u32();
            for (bit, count) in counts.iter_mut().enumerate() {
                *count += (v >> bit) & 1;
            }
        }
        for (bit, &c) in counts.iter().enumerate() {
            let frac = c as f64 / n as f64;
            assert!((frac - 0.5).abs() < 0.02, "bit {bit}: {frac}");
        }
    }

    #[test]
    fn no_short_cycles() {
        let mut rng = Philox::new(0, 0);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..10_000 {
            assert!(seen.insert(rng.next_u64()), "cycle detected");
        }
    }
}
