//! The coordinator: owns the queue, worker pool and model registry.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;


use crate::asd::{AsdConfig, AsdEngine, KernelBackend};
use crate::coordinator::batcher::{next_work_item, WorkItem};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{QueuedJob, Request, Response, SamplerSpec};
use crate::ddpm::{BatchedSequentialSampler, SequentialSampler};
use crate::model::DenoiseModel;
use crate::picard::{PicardConfig, PicardSampler};
use crate::runtime::pool::PoolConfig;

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub workers: usize,
    /// gang at most this many sequential requests into one lockstep batch
    pub max_batch: usize,
    pub enable_batching: bool,
    /// sharding config for every batched denoise call served by this
    /// coordinator (ASD verify rounds, Picard sweeps, lockstep gangs).
    /// All workers share the ONE global pool — worker threads gate
    /// concurrency at the request level, the pool at the row level, so
    /// cores are never oversubscribed. Bit-transparency holds for
    /// native row-independent models; HLO models may shift within f32
    /// padding tolerance (see `model::parallel`).
    pub pool: PoolConfig,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            workers: 2,
            max_batch: 8,
            enable_batching: true,
            pool: PoolConfig::default(),
        }
    }
}

struct Shared {
    queue: Mutex<VecDeque<QueuedJob>>,
    cv: Condvar,
    shutdown: AtomicBool,
    metrics: Metrics,
    models: Mutex<HashMap<String, Arc<dyn DenoiseModel>>>,
    config: ServerConfig,
    next_id: AtomicU64,
}

/// The serving coordinator. Models are registered up front (they wrap
/// either HLO executables or the native oracle); requests are submitted
/// from any thread and answered over per-request channels.
pub struct Coordinator {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    pub fn new(config: ServerConfig) -> Coordinator {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            metrics: Metrics::default(),
            models: Mutex::new(HashMap::new()),
            config: config.clone(),
            next_id: AtomicU64::new(1),
        });
        let mut handles = Vec::new();
        for w in 0..config.workers.max(1) {
            let s = shared.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("asd-worker-{w}"))
                    .spawn(move || worker_loop(s))
                    .expect("spawn worker"),
            );
        }
        Coordinator { shared, handles }
    }

    pub fn register_model(&self, name: &str, model: Arc<dyn DenoiseModel>) {
        self.shared
            .models
            .lock()
            .unwrap()
            .insert(name.to_string(), model);
    }

    pub fn has_model(&self, name: &str) -> bool {
        self.shared.models.lock().unwrap().contains_key(name)
    }

    /// Submit a request; returns the response channel and the assigned id.
    pub fn submit(&self, mut request: Request) -> (u64, Receiver<Response>) {
        let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
        request.id = id;
        let (tx, rx) = channel();
        self.shared.metrics.on_submit();
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(QueuedJob {
                request,
                reply: tx,
                enqueued: Instant::now(),
            });
        }
        self.shared.cv.notify_one();
        (id, rx)
    }

    pub fn metrics(&self) -> crate::coordinator::MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    pub fn queue_depth(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    pub fn shutdown(mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let item = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                match next_work_item(&mut q, shared.config.max_batch,
                                     shared.config.enable_batching) {
                    Some(item) => break item,
                    None => q = shared.cv.wait(q).unwrap(),
                }
            }
        };
        match item {
            WorkItem::Single(job) => serve_single(&shared, job),
            WorkItem::SequentialGang(gang) => serve_gang(&shared, gang),
        }
    }
}

fn model_for(shared: &Shared, variant: &str) -> Option<Arc<dyn DenoiseModel>> {
    shared.models.lock().unwrap().get(variant).cloned()
}

fn serve_single(shared: &Shared, job: QueuedJob) {
    let queued_s = job.enqueued.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let req = &job.request;
    let outcome = match model_for(shared, &req.variant) {
        None => Err(format!("unknown model '{}'", req.variant)),
        Some(model) => run_sampler(model, req, shared.config.pool),
    };
    let service_s = t0.elapsed().as_secs_f64();
    if let Ok((_, _, _, Some(st))) = &outcome {
        shared.metrics.on_round_stats(&st.round_latency_s, &st.round_shards);
    }
    let resp = match outcome {
        Ok((sample, calls, rounds, asd_stats)) => Response {
            id: req.id,
            sample,
            model_calls: calls,
            parallel_rounds: rounds,
            asd_stats,
            queued_s,
            service_s,
            error: None,
        },
        Err(e) => Response {
            id: req.id,
            sample: vec![],
            model_calls: 0,
            parallel_rounds: 0,
            asd_stats: None,
            queued_s,
            service_s,
            error: Some(e),
        },
    };
    shared.metrics.on_complete(queued_s, service_s, resp.model_calls,
                               resp.parallel_rounds, resp.error.is_some());
    let _ = job.reply.send(resp);
}

type SampleOutcome =
    std::result::Result<(Vec<f64>, usize, usize, Option<crate::asd::AsdStats>), String>;

fn run_sampler(model: Arc<dyn DenoiseModel>, req: &Request,
               pool: PoolConfig) -> SampleOutcome {
    match req.sampler {
        SamplerSpec::Sequential => {
            let sampler = SequentialSampler::new(model);
            sampler
                .sample(req.seed, &req.cond)
                .map(|(y, st)| (y, st.model_calls, st.model_calls, None))
                .map_err(|e| e.to_string())
        }
        SamplerSpec::Asd(theta) => {
            let mut engine = AsdEngine::new(
                model,
                AsdConfig {
                    theta,
                    eval_tail: true,
                    backend: KernelBackend::Native,
                    pool,
                },
            );
            engine
                .sample_cond(req.seed, &req.cond)
                .map(|out| {
                    let calls = out.stats.model_calls;
                    let rounds = out.stats.parallel_rounds;
                    (out.y0, calls, rounds, Some(out.stats))
                })
                .map_err(|e| e.to_string())
        }
        SamplerSpec::Picard(window, tol) => {
            let sampler = PicardSampler::new(
                model,
                PicardConfig { window, tol, max_sweeps: 1000, pool });
            sampler
                .sample(req.seed, &req.cond)
                .map(|(y, st)| (y, st.model_calls, st.parallel_rounds, None))
                .map_err(|e| e.to_string())
        }
    }
}

fn serve_gang(shared: &Shared, gang: Vec<QueuedJob>) {
    shared.metrics.on_batch(gang.len());
    let t0 = Instant::now();
    let variant = gang[0].request.variant.clone();
    let model = match model_for(shared, &variant) {
        Some(m) => m,
        None => {
            for job in gang {
                fail_job(shared, job, &format!("unknown model '{variant}'"));
            }
            return;
        }
    };
    let d = model.dim();
    let c = model.cond_dim();
    let seeds: Vec<u64> = gang.iter().map(|j| j.request.seed).collect();
    let mut conds = vec![0.0; gang.len() * c];
    for (r, job) in gang.iter().enumerate() {
        if job.request.cond.len() == c {
            conds[r * c..(r + 1) * c].copy_from_slice(&job.request.cond);
        }
    }
    let sampler =
        BatchedSequentialSampler::with_pool(model, shared.config.pool);
    match sampler.sample_batch(&seeds, &conds) {
        Ok((ys, st)) => {
            let service_s = t0.elapsed().as_secs_f64();
            // per-request accounting: the gang shares the batched calls
            let per_calls = st.model_calls; // K rounds regardless of gang size
            for (r, job) in gang.into_iter().enumerate() {
                let queued_s = job.enqueued.elapsed().as_secs_f64() - service_s;
                let resp = Response {
                    id: job.request.id,
                    sample: ys[r * d..(r + 1) * d].to_vec(),
                    model_calls: per_calls,
                    parallel_rounds: per_calls,
                    asd_stats: None,
                    queued_s: queued_s.max(0.0),
                    service_s,
                    error: None,
                };
                shared.metrics.on_complete(resp.queued_s, service_s,
                                           per_calls, per_calls, false);
                let _ = job.reply.send(resp);
            }
        }
        Err(e) => {
            let msg = e.to_string();
            for job in gang {
                fail_job(shared, job, &msg);
            }
        }
    }
}

fn fail_job(shared: &Shared, job: QueuedJob, msg: &str) {
    let queued_s = job.enqueued.elapsed().as_secs_f64();
    shared.metrics.on_complete(queued_s, 0.0, 0, 0, true);
    let _ = job.reply.send(Response {
        id: job.request.id,
        sample: vec![],
        model_calls: 0,
        parallel_rounds: 0,
        asd_stats: None,
        queued_s,
        service_s: 0.0,
        error: Some(msg.to_string()),
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Gmm, GmmDdpmOracle};

    fn coordinator_with_oracle(workers: usize) -> Coordinator {
        let c = Coordinator::new(ServerConfig {
            workers,
            max_batch: 4,
            enable_batching: true,
            ..Default::default()
        });
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 40, false);
        c.register_model("gmm", oracle);
        c
    }

    fn req(sampler: SamplerSpec, seed: u64) -> Request {
        Request {
            id: 0,
            variant: "gmm".into(),
            sampler,
            seed,
            cond: vec![],
        }
    }

    #[test]
    fn serves_sequential_and_asd() {
        let c = coordinator_with_oracle(2);
        let (_, rx1) = c.submit(req(SamplerSpec::Sequential, 1));
        let (_, rx2) = c.submit(req(SamplerSpec::Asd(8), 2));
        let r1 = rx1.recv().unwrap();
        let r2 = rx2.recv().unwrap();
        assert!(r1.error.is_none() && r2.error.is_none());
        assert_eq!(r1.sample.len(), 2);
        assert_eq!(r1.model_calls, 40);
        assert!(r2.parallel_rounds < 40);
        assert!(r2.asd_stats.is_some());
        c.shutdown();
    }

    #[test]
    fn unknown_model_fails_cleanly() {
        let c = coordinator_with_oracle(1);
        let (_, rx) = c.submit(Request {
            id: 0,
            variant: "nope".into(),
            sampler: SamplerSpec::Sequential,
            seed: 0,
            cond: vec![],
        });
        let r = rx.recv().unwrap();
        assert!(r.error.unwrap().contains("unknown model"));
        let m = c.metrics();
        assert_eq!(m.failed, 1);
    }

    #[test]
    fn burst_of_sequential_requests_batches() {
        let c = coordinator_with_oracle(1);
        let rxs: Vec<_> = (0..8)
            .map(|s| c.submit(req(SamplerSpec::Sequential, s)).1)
            .collect();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!(r.error.is_none());
        }
        let m = c.metrics();
        assert_eq!(m.completed, 8);
        // at least one gang formed (worker races may split the burst)
        assert!(m.batched_requests >= 2, "batched {}", m.batched_requests);
        c.shutdown();
    }

    #[test]
    fn picard_request_works() {
        let c = coordinator_with_oracle(1);
        let (_, rx) = c.submit(req(SamplerSpec::Picard(8, 1e-6), 3));
        let r = rx.recv().unwrap();
        assert!(r.error.is_none());
        assert!(r.parallel_rounds >= 5);
        c.shutdown();
    }

    #[test]
    fn shutdown_joins_workers() {
        let c = coordinator_with_oracle(3);
        let (_, rx) = c.submit(req(SamplerSpec::Sequential, 9));
        rx.recv().unwrap();
        c.shutdown(); // must not hang
    }

    #[test]
    fn sharded_pool_serves_identical_samples_and_records_occupancy() {
        let serve = |pool: PoolConfig| -> (Vec<f64>, f64) {
            let c = Coordinator::new(ServerConfig {
                workers: 2,
                max_batch: 4,
                enable_batching: true,
                pool,
            });
            let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 40, false);
            c.register_model("gmm", oracle);
            let mut samples = Vec::new();
            for seed in 0..4 {
                let (_, rx) = c.submit(req(SamplerSpec::Asd(8), seed));
                let r = rx.recv().unwrap();
                assert!(r.error.is_none());
                samples.extend(r.sample);
            }
            let occ = c.metrics().mean_shard_occupancy;
            c.shutdown();
            (samples, occ)
        };
        let (inline, occ1) = serve(PoolConfig::default());
        let (sharded, occ4) =
            serve(PoolConfig { pool_size: 4, shard_min: 1 });
        let bits = |v: &[f64]| -> Vec<u64> {
            v.iter().map(|x| x.to_bits()).collect()
        };
        assert_eq!(bits(&inline), bits(&sharded));
        assert!((occ1 - 1.0).abs() < 1e-12, "inline occupancy {occ1}");
        assert!(occ4 > 1.0, "sharded occupancy {occ4}");
    }
}
