//! The coordinator: owns the lane state (variant-keyed queues + lane
//! table), worker pool and model registry.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::asd::AsdEngine;
use crate::coordinator::lanes::{Lane, LaneClaim, LaneState};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{QueuedJob, Request, Response, SamplerSpec};
use crate::ddpm::SequentialSampler;
use crate::model::DenoiseModel;
use crate::picard::PicardSampler;
use crate::runtime::pool::{self, PoolConfig};

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub workers: usize,
    /// fuse at most this many concurrent requests into one lane's
    /// round-synchronous group (any sampler mix; see
    /// `coordinator::fusion`)
    pub max_batch: usize,
    pub enable_batching: bool,
    /// bounded admission: submissions beyond this *total* queue depth
    /// (summed across variant lanes) are answered immediately with a
    /// rejected [`Response`] instead of growing the queues without
    /// limit
    pub max_queue_depth: usize,
    /// sharding config for every batched denoise call served by this
    /// coordinator (each lane's fused round, or the per-request
    /// batched calls when batching is disabled). All workers share the
    /// ONE global pool — worker threads gate concurrency at the lane
    /// level, the pool at the row level, so cores are never
    /// oversubscribed. Bit-transparency holds for native
    /// row-independent models; HLO models may shift within f32 padding
    /// tolerance (see `model::parallel`).
    pub pool: PoolConfig,
    /// byte budget per lane for the round arena + GEMM workspace
    /// (which grow to the high-water round size): once a lane drains,
    /// a footprint past this cap is released instead of pinning a
    /// burst's memory for the coordinator's lifetime. 0 = unbounded
    /// (the pre-cap behavior). Surfaced per lane as
    /// `LaneSnapshot::arena_high_water_bytes`.
    pub arena_byte_cap: usize,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            workers: 2,
            max_batch: 8,
            enable_batching: true,
            max_queue_depth: 1024,
            pool: PoolConfig::default(),
            arena_byte_cap: 64 << 20, // 64 MiB per lane
        }
    }
}

impl ServerConfig {
    /// Reject degenerate configs up front: a zero here used to mean a
    /// coordinator that either silently clamped (`workers`) or wedged /
    /// rejected everything (`max_batch`, `max_queue_depth`).
    fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.workers >= 1,
                        "ServerConfig::workers must be >= 1 (got 0)");
        anyhow::ensure!(self.max_batch >= 1,
                        "ServerConfig::max_batch must be >= 1 (got 0)");
        anyhow::ensure!(self.max_queue_depth >= 1,
                        "ServerConfig::max_queue_depth must be >= 1 \
                         (got 0)");
        Ok(())
    }
}

struct Shared {
    /// variant-keyed queues + lane table, under ONE mutex (paired with
    /// `cv`). Held only for queue/claim bookkeeping — never across a
    /// model call.
    state: Mutex<LaneState>,
    cv: Condvar,
    shutdown: AtomicBool,
    metrics: Metrics,
    /// model registry. Locked at registration and once per lane
    /// creation (the lane snapshots its model `Arc`) — never on the
    /// round hot path.
    models: Mutex<HashMap<String, Arc<dyn DenoiseModel>>>,
    config: ServerConfig,
    next_id: AtomicU64,
}

/// The serving coordinator. Models are registered up front (they wrap
/// either HLO executables or the native oracle); requests are submitted
/// from any thread and answered over per-request channels. Each
/// registered variant is served by its own lane (`coordinator::lanes`):
/// workers claim busy lanes and co-schedule their fused rounds on the
/// global pool, so no variant ever waits behind another variant's
/// burst.
pub struct Coordinator {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Build the coordinator, validating the config (degenerate values
    /// like `max_batch: 0` are a clean error, not silent misbehavior).
    pub fn new(config: ServerConfig) -> Result<Coordinator> {
        config.validate()?;
        let shared = Arc::new(Shared {
            state: Mutex::new(LaneState::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            metrics: Metrics::default(),
            models: Mutex::new(HashMap::new()),
            config: config.clone(),
            next_id: AtomicU64::new(1),
        });
        let mut handles = Vec::new();
        for w in 0..config.workers {
            let s = shared.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("asd-worker-{w}"))
                    .spawn(move || worker_loop(s))
                    .expect("spawn worker"),
            );
        }
        Ok(Coordinator { shared, handles })
    }

    pub fn register_model(&self, name: &str, model: Arc<dyn DenoiseModel>) {
        self.shared
            .models
            .lock()
            .unwrap()
            .insert(name.to_string(), model);
    }

    pub fn has_model(&self, name: &str) -> bool {
        self.shared.models.lock().unwrap().contains_key(name)
    }

    /// Submit a request; returns the response channel and the assigned
    /// id. When the total queued depth is at `max_queue_depth` the
    /// request is not enqueued: a rejected [`Response`] is delivered on
    /// the channel immediately (bounded admission — a loaded
    /// coordinator sheds traffic instead of accumulating unbounded
    /// latency).
    pub fn submit(&self, mut request: Request) -> (u64, Receiver<Response>) {
        let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
        request.id = id;
        let (tx, rx) = channel();
        self.shared.metrics.on_submit();
        {
            let mut st = self.shared.state.lock().unwrap();
            let depth = st.depth();
            if depth >= self.shared.config.max_queue_depth {
                drop(st);
                self.shared.metrics.on_reject();
                let _ = tx.send(Response::rejected(
                    id, depth, self.shared.config.max_queue_depth));
                return (id, rx);
            }
            st.enqueue(QueuedJob {
                request,
                reply: tx,
                enqueued: Instant::now(),
            });
        }
        self.shared.cv.notify_one();
        (id, rx)
    }

    pub fn metrics(&self) -> crate::coordinator::MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    /// Total queued (not yet admitted) jobs across all variant lanes.
    pub fn queue_depth(&self) -> usize {
        self.shared.state.lock().unwrap().depth()
    }

    pub fn shutdown(mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    if !shared.config.enable_batching || shared.config.max_batch <= 1 {
        return single_loop(shared);
    }
    lane_loop(shared);
}

/// Batching disabled (or `max_batch == 1`): serve one request at a
/// time with dedicated solo engines, oldest-first across variants.
fn single_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                match st.pop_oldest() {
                    Some(job) => break job,
                    None => st = shared.cv.wait(st).unwrap(),
                }
            }
        };
        serve_single(&shared, job);
    }
}

/// Jobs popped for a lane this worker holds, tagged with the `held`
/// index, lane-contiguous (a flat, reusable buffer — the machines are
/// built outside the state lock, since construction does Philox
/// draws).
type Admissions = Vec<(usize, QueuedJob)>;

/// Holds a worker's claimed lanes and releases them back to the lane
/// table if the worker unwinds. Without this, a panic escaping a tick
/// (a machine-math bug, a poisoned metrics mutex, ...) would leave
/// every held lane's slot claimed forever — the variant could never be
/// served again and its queue would pin `max_queue_depth` budget.
/// Normal control flow drains `lanes` itself, making the drop a no-op.
struct LaneGuard<'a> {
    shared: &'a Shared,
    lanes: Vec<Box<Lane>>,
}

impl Drop for LaneGuard<'_> {
    fn drop(&mut self) {
        if self.lanes.is_empty() {
            return;
        }
        // a panicking sibling may have poisoned the state mutex; still
        // recover the guard — a poisoned queue table beats permanently
        // unservable variants
        let mut st = match self.shared.state.lock() {
            Ok(st) => st,
            Err(poisoned) => poisoned.into_inner(),
        };
        for lane in self.lanes.drain(..) {
            st.release(lane);
        }
        drop(st);
        self.shared.cv.notify_all();
    }
}

/// The lane-scheduling worker loop: claim every busy, unclaimed lane,
/// then drive all held lanes tick by tick — each tick polls ALL lanes
/// and co-schedules their fused rounds on the one global pool
/// ([`tick_lanes`]), so a worker holding two variants' lanes advances
/// both inside the same tick instead of serving them back to back.
/// All loop bookkeeping buffers are worker-local and reused across
/// ticks; the per-round data plane itself (arena + workspace, inside
/// each lane) allocates nothing in steady state.
fn lane_loop(shared: Arc<Shared>) {
    let mut guard = LaneGuard { shared: &*shared, lanes: Vec::new() };
    let held = &mut guard.lanes;
    let mut admissions: Admissions = Vec::new();
    let mut failures: Vec<(QueuedJob, String)> = Vec::new();
    let mut variants: Vec<String> = Vec::new();
    let mut jobs: Vec<QueuedJob> = Vec::new();
    let mut batch: Vec<QueuedJob> = Vec::new();
    let mut busy: Vec<*mut Lane> = Vec::new();
    loop {
        // ---- blocking claim: wait until some lane has work ----
        {
            let mut st = guard.shared.state.lock().unwrap();
            loop {
                if guard.shared.shutdown.load(Ordering::SeqCst) {
                    for lane in held.drain(..) {
                        st.release(lane);
                    }
                    return;
                }
                gather(guard.shared, &mut st, held, &mut admissions,
                       &mut failures, &mut variants, &mut jobs);
                if !held.is_empty() || !failures.is_empty() {
                    break;
                }
                st = guard.shared.cv.wait(st).unwrap();
            }
        }
        answer_failures(guard.shared, &mut failures);
        apply_admissions(guard.shared, held, &mut admissions, &mut batch);

        // ---- drive the held lanes until they all drain ----
        while !held.is_empty() {
            tick_lanes(held, &guard.shared.metrics, &mut busy);
            {
                let mut st = guard.shared.state.lock().unwrap();
                if guard.shared.shutdown.load(Ordering::SeqCst) {
                    // wind down: finish in-flight machines only — park
                    // drained lanes, admit nothing new
                    let mut i = 0;
                    while i < held.len() {
                        if held[i].is_idle() {
                            st.release(held.swap_remove(i));
                        } else {
                            i += 1;
                        }
                    }
                } else {
                    // park lanes that drained and have no queued work;
                    // top up / newly claim the rest (continuous
                    // admission + cross-variant pickup)
                    let mut i = 0;
                    while i < held.len() {
                        if held[i].is_idle()
                            && !st.has_queued(&held[i].variant)
                        {
                            st.release(held.swap_remove(i));
                        } else {
                            i += 1;
                        }
                    }
                    gather(guard.shared, &mut st, held, &mut admissions,
                           &mut failures, &mut variants, &mut jobs);
                }
            }
            answer_failures(guard.shared, &mut failures);
            apply_admissions(guard.shared, held, &mut admissions,
                             &mut batch);
        }
    }
}

/// Under the state lock: top up every held lane from its variant queue
/// and claim any other busy, unclaimed lane (creating it — with its
/// model `Arc` snapshot — on first use). Popped jobs land flat in
/// `admissions` keyed by `held` index; unknown-variant jobs land in
/// `failures`. Machine construction and response sends happen outside
/// the lock. `jobs` is a reusable scratch buffer.
fn gather(shared: &Shared, st: &mut LaneState, held: &mut Vec<Box<Lane>>,
          admissions: &mut Admissions,
          failures: &mut Vec<(QueuedJob, String)>,
          variants: &mut Vec<String>, jobs: &mut Vec<QueuedJob>) {
    let max_batch = shared.config.max_batch;
    // 1) continuous admission into lanes this worker already holds
    for (i, lane) in held.iter().enumerate() {
        let room = max_batch.saturating_sub(lane.in_flight());
        if room == 0 {
            continue;
        }
        jobs.clear();
        if st.take(&lane.variant, room, jobs) > 0 {
            admissions.extend(jobs.drain(..).map(|j| (i, j)));
        }
    }
    // 2) claim lanes for every other variant with queued work
    // (`variants` recycles its String buffers across ticks)
    st.queued_variants(variants);
    for vi in 0..variants.len() {
        let variant = variants[vi].as_str();
        if held.iter().any(|l| l.variant == variant) {
            continue; // held but out of room this tick
        }
        let lane = match st.claim(variant) {
            LaneClaim::Busy => continue, // another worker drives it
            LaneClaim::Claimed(lane) => lane,
            LaneClaim::Create => {
                // snapshot the model Arc once per lane — the registry
                // is never locked again for this variant's rounds. The
                // slot is already marked held; if the lookup or lane
                // construction unwinds (poisoned registry mutex, model
                // metadata panic) the marker must be abandoned, or the
                // variant would answer Busy forever.
                let built = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| {
                        shared.models.lock().unwrap().get(variant).cloned()
                            .map(|m| Box::new(Lane::new(
                                variant, m, shared.config.pool,
                                shared.config.arena_byte_cap)))
                    }));
                match built {
                    Ok(Some(lane)) => lane,
                    Ok(None) => {
                        st.abandon(variant);
                        let msg = format!("unknown model '{variant}'");
                        for job in st.drain_variant(variant) {
                            failures.push((job, msg.clone()));
                        }
                        continue;
                    }
                    Err(panic) => {
                        st.abandon(variant);
                        std::panic::resume_unwind(panic);
                    }
                }
            }
        };
        let room = max_batch.saturating_sub(lane.in_flight());
        jobs.clear();
        st.take(variant, room, jobs);
        let idx = held.len();
        held.push(lane);
        admissions.extend(jobs.drain(..).map(|j| (idx, j)));
    }
    // 3) panic-recovery backstop: adopt parked lanes that still hold
    // in-flight machines (only possible when LaneGuard parked a
    // panicking worker's lanes mid-flight) so their admitted requests
    // keep making progress instead of stranding their clients
    st.parked_nonidle(variants);
    for vi in 0..variants.len() {
        let variant = variants[vi].as_str();
        if held.iter().any(|l| l.variant == variant) {
            continue;
        }
        if let LaneClaim::Claimed(lane) = st.claim(variant) {
            held.push(lane);
        }
    }
}

fn answer_failures(shared: &Shared, failures: &mut Vec<(QueuedJob, String)>) {
    for (job, msg) in failures.drain(..) {
        fail_job(shared, job, &msg);
    }
}

/// Build machines for freshly popped jobs (outside the state lock),
/// batch-admitting per lane so group-formation metrics see whole
/// batches. `batch` is a reusable scratch buffer; `admissions` entries
/// are lane-contiguous by construction (gather appends per lane).
fn apply_admissions(shared: &Shared, held: &mut [Box<Lane>],
                    admissions: &mut Admissions,
                    batch: &mut Vec<QueuedJob>) {
    let mut iter = admissions.drain(..).peekable();
    while let Some((idx, job)) = iter.next() {
        batch.clear();
        batch.push(job);
        while iter.peek().is_some_and(|&(next_idx, _)| next_idx == idx) {
            batch.push(iter.next().unwrap().1);
        }
        held[idx].admit(batch, &shared.metrics);
    }
}

/// Raw lane pointers smuggled into the pool's `Fn` tasks; sound because
/// every index is executed exactly once (disjoint task ranges), the
/// lanes are distinct boxed allocations, and the pool joins before the
/// pointer array drops.
struct SendLanes(*mut *mut Lane);
unsafe impl Send for SendLanes {}
unsafe impl Sync for SendLanes {}

/// One co-scheduled tick over this worker's held lanes:
/// 1. poll phase (serial — cheap sampler math): every lane retires
///    finished machines and stages demands into its own arena;
/// 2. execute phase: ALL busy lanes' fused `denoise_round` calls run
///    concurrently as tasks on the one global pool (each call may
///    itself shard rows on the same pool — nested sharding is
///    deadlock-free, see `runtime::pool`), so two variants' rounds
///    share the tick's wall-clock instead of queueing behind each
///    other;
/// 3. scatter phase (serial): machines resume from arena output views.
///
/// `busy` is a caller-owned scratch buffer of lane pointers, reused
/// across ticks. A panic in a lane's sampler math (poll or resume)
/// fails that lane's whole group cleanly instead of unwinding the
/// worker — the other held lanes keep ticking. (Model-call panics are
/// already contained inside `execute_round`.)
fn tick_lanes(held: &mut [Box<Lane>], metrics: &Metrics,
              busy: &mut Vec<*mut Lane>) {
    for lane in held.iter_mut() {
        guard_phase(lane, metrics, "poll", |l| l.begin_round(metrics));
    }
    busy.clear();
    busy.extend(held.iter_mut()
        .filter(|l| l.has_round())
        .map(|l| &mut **l as *mut Lane));
    if !busy.is_empty() {
        // run_tasks already degenerates to an inline call for a single
        // lane (no queue-lock round-trip; see ThreadPool::run_sharded)
        let lanes = SendLanes(busy.as_mut_ptr());
        pool::global().run_tasks(busy.len(), |i| {
            // SAFETY: see `SendLanes`
            unsafe { (*(*lanes.0.add(i))).execute_round() };
        });
    }
    for lane in held.iter_mut() {
        guard_phase(lane, metrics, "resume", |l| l.finish_round(metrics));
    }
}

/// Run one serial tick phase on a lane, converting a sampler-machine
/// panic into a clean whole-group failure (the panicking machine's
/// state is unusable; stranding its group's clients would be worse).
fn guard_phase<F: FnOnce(&mut Lane)>(lane: &mut Box<Lane>,
                                     metrics: &Metrics, phase: &str, f: F) {
    let outcome = std::panic::catch_unwind(
        std::panic::AssertUnwindSafe(|| f(lane)));
    if outcome.is_err() {
        lane.fail_all(
            &format!("sampler machine panicked during fused {phase}"),
            metrics);
    }
}

fn model_for(shared: &Shared, variant: &str) -> Option<Arc<dyn DenoiseModel>> {
    shared.models.lock().unwrap().get(variant).cloned()
}

fn serve_single(shared: &Shared, job: QueuedJob) {
    let queued_s = job.enqueued.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let req = &job.request;
    let outcome = match model_for(shared, &req.variant) {
        None => Err(format!("unknown model '{}'", req.variant)),
        Some(model) => run_sampler(model, req, shared.config.pool),
    };
    let service_s = t0.elapsed().as_secs_f64();
    if let Ok((_, _, _, Some(st))) = &outcome {
        shared.metrics.on_round_stats(&st.round_latency_s, &st.round_shards);
    }
    let resp = match outcome {
        Ok((sample, calls, rounds, asd_stats)) => Response {
            id: req.id,
            sample,
            model_calls: calls,
            parallel_rounds: rounds,
            asd_stats,
            queued_s,
            service_s,
            rejected: false,
            error: None,
        },
        Err(e) => Response {
            service_s,
            ..Response::failed(req.id, queued_s, &e)
        },
    };
    shared.metrics.on_complete(queued_s, service_s, resp.model_calls,
                               resp.parallel_rounds, resp.error.is_some());
    let _ = job.reply.send(resp);
}

type SampleOutcome =
    std::result::Result<(Vec<f64>, usize, usize, Option<crate::asd::AsdStats>), String>;

fn run_sampler(model: Arc<dyn DenoiseModel>, req: &Request,
               pool: PoolConfig) -> SampleOutcome {
    match req.sampler {
        SamplerSpec::Sequential => {
            let sampler = SequentialSampler::new(model);
            sampler
                .sample(req.seed, &req.cond)
                .map(|(y, st)| (y, st.model_calls, st.model_calls, None))
                .map_err(|e| e.to_string())
        }
        SamplerSpec::Asd(theta) => {
            // canonical config shared with the fused path — see
            // SamplerSpec::asd_config
            let mut engine = AsdEngine::new(
                model, SamplerSpec::asd_config(theta, pool));
            engine
                .sample_cond(req.seed, &req.cond)
                .map(|out| {
                    let calls = out.stats.model_calls;
                    let rounds = out.stats.parallel_rounds;
                    (out.y0, calls, rounds, Some(out.stats))
                })
                .map_err(|e| e.to_string())
        }
        SamplerSpec::Picard(window, tol) => {
            let sampler = PicardSampler::new(
                model, SamplerSpec::picard_config(window, tol, pool));
            sampler
                .sample(req.seed, &req.cond)
                .map(|(y, st)| (y, st.model_calls, st.parallel_rounds, None))
                .map_err(|e| e.to_string())
        }
    }
}

fn fail_job(shared: &Shared, job: QueuedJob, msg: &str) {
    let queued_s = job.enqueued.elapsed().as_secs_f64();
    shared.metrics.on_complete(queued_s, 0.0, 0, 0, true);
    let _ = job.reply.send(Response::failed(job.request.id, queued_s, msg));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Gmm, GmmDdpmOracle};
    use crate::schedule::DdpmSchedule;
    use anyhow::Result;

    fn coordinator_with_oracle(workers: usize) -> Coordinator {
        let c = Coordinator::new(ServerConfig {
            workers,
            max_batch: 4,
            enable_batching: true,
            ..Default::default()
        }).unwrap();
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 40, false);
        c.register_model("gmm", oracle);
        c
    }

    fn req(sampler: SamplerSpec, seed: u64) -> Request {
        Request {
            id: 0,
            variant: "gmm".into(),
            sampler,
            seed,
            cond: vec![],
        }
    }

    #[test]
    fn degenerate_configs_are_clean_errors() {
        for cfg in [
            ServerConfig { workers: 0, ..Default::default() },
            ServerConfig { max_batch: 0, ..Default::default() },
            ServerConfig { max_queue_depth: 0, ..Default::default() },
        ] {
            let err = Coordinator::new(cfg).err().expect("must reject");
            assert!(err.to_string().contains("must be >= 1"), "{err:#}");
        }
    }

    #[test]
    fn serves_sequential_and_asd() {
        let c = coordinator_with_oracle(2);
        let (_, rx1) = c.submit(req(SamplerSpec::Sequential, 1));
        let (_, rx2) = c.submit(req(SamplerSpec::Asd(8), 2));
        let r1 = rx1.recv().unwrap();
        let r2 = rx2.recv().unwrap();
        assert!(r1.error.is_none() && r2.error.is_none());
        assert_eq!(r1.sample.len(), 2);
        assert_eq!(r1.model_calls, 40);
        assert!(r2.parallel_rounds < 40);
        assert!(r2.asd_stats.is_some());
        c.shutdown();
    }

    #[test]
    fn unknown_model_fails_cleanly() {
        let c = coordinator_with_oracle(1);
        let (_, rx) = c.submit(Request {
            id: 0,
            variant: "nope".into(),
            sampler: SamplerSpec::Sequential,
            seed: 0,
            cond: vec![],
        });
        let r = rx.recv().unwrap();
        assert!(r.error.unwrap().contains("unknown model"));
        let m = c.metrics();
        assert_eq!(m.failed, 1);
        // the failed variant never created a lane
        assert!(m.lane("nope").is_none());
    }

    #[test]
    fn burst_of_sequential_requests_batches() {
        let c = coordinator_with_oracle(1);
        let rxs: Vec<_> = (0..8)
            .map(|s| c.submit(req(SamplerSpec::Sequential, s)).1)
            .collect();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!(r.error.is_none());
        }
        let m = c.metrics();
        assert_eq!(m.completed, 8);
        // at least one fusion group formed (worker races may split the
        // burst)
        assert!(m.batched_requests >= 2, "batched {}", m.batched_requests);
        // the lane reports its own round aggregates
        let lane = m.lane("gmm").unwrap();
        assert!(lane.fused_rounds > 0);
        assert_eq!(lane.admitted, 8);
        c.shutdown();
    }

    #[test]
    fn picard_request_works() {
        let c = coordinator_with_oracle(1);
        let (_, rx) = c.submit(req(SamplerSpec::Picard(8, 1e-6), 3));
        let r = rx.recv().unwrap();
        assert!(r.error.is_none());
        assert!(r.parallel_rounds >= 5);
        c.shutdown();
    }

    /// Test model whose denoise calls block until the gate opens —
    /// lets a test hold a worker busy so the queue actually fills.
    struct GatedModel {
        sched: DdpmSchedule,
        gate: Arc<(Mutex<bool>, Condvar)>,
    }

    impl GatedModel {
        fn open(gate: &Arc<(Mutex<bool>, Condvar)>) {
            let (lock, cv) = gate.as_ref();
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
    }

    impl crate::model::DenoiseModel for GatedModel {
        fn dim(&self) -> usize {
            1
        }
        fn cond_dim(&self) -> usize {
            0
        }
        fn k_steps(&self) -> usize {
            self.sched.k_steps
        }
        fn schedule(&self) -> &DdpmSchedule {
            &self.sched
        }
        fn denoise_batch(&self, _ys: &[f64], _ts: &[f64], _cond: &[f64],
                         n: usize, out: &mut [f64]) -> Result<()> {
            let (lock, cv) = self.gate.as_ref();
            let mut open = lock.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
            out[..n].fill(0.0);
            Ok(())
        }
    }

    #[test]
    fn bounded_admission_rejects_when_queue_is_full() {
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let c = Coordinator::new(ServerConfig {
            workers: 1,
            max_batch: 1, // no fusion: the worker blocks on one request
            enable_batching: true,
            max_queue_depth: 2,
            ..Default::default()
        }).unwrap();
        c.register_model("gated", Arc::new(GatedModel {
            sched: DdpmSchedule::new(2),
            gate: gate.clone(),
        }));
        let req = |seed| Request {
            id: 0,
            variant: "gated".into(),
            sampler: SamplerSpec::Sequential,
            seed,
            cond: vec![],
        };
        // r1 is picked up by the worker and blocks inside the model
        let (_, rx1) = c.submit(req(1));
        for _ in 0..200 {
            if c.queue_depth() == 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(c.queue_depth(), 0, "worker never picked up r1");
        // r2, r3 fill the queue to max_queue_depth
        let (_, rx2) = c.submit(req(2));
        let (_, rx3) = c.submit(req(3));
        assert_eq!(c.queue_depth(), 2);
        // r4 must be rejected immediately, without blocking
        let (_, rx4) = c.submit(req(4));
        let r4 = rx4.recv().unwrap();
        assert!(r4.rejected);
        assert!(r4.error.unwrap().contains("max_queue_depth"));
        let m = c.metrics();
        assert_eq!(m.rejected, 1);
        // open the gate: the admitted requests all complete
        GatedModel::open(&gate);
        for rx in [rx1, rx2, rx3] {
            let r = rx.recv().unwrap();
            assert!(!r.rejected);
            assert!(r.error.is_none(), "{:?}", r.error);
        }
        assert_eq!(c.metrics().completed, 3);
        c.shutdown();
    }

    #[test]
    fn mixed_samplers_fuse_into_mega_rounds() {
        // one worker, burst of all three sampler kinds on one variant:
        // the coordinator must fuse their rounds (rows/round > 1)
        let c = Coordinator::new(ServerConfig {
            workers: 1,
            max_batch: 16,
            enable_batching: true,
            ..Default::default()
        }).unwrap();
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 60, false);
        c.register_model("gmm", oracle);
        let rxs: Vec<_> = (0..9)
            .map(|i| {
                let sampler = match i % 3 {
                    0 => SamplerSpec::Sequential,
                    1 => SamplerSpec::Asd(8),
                    _ => SamplerSpec::Picard(8, 1e-6),
                };
                c.submit(req(sampler, 100 + i as u64)).1
            })
            .collect();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!(r.error.is_none(), "{:?}", r.error);
        }
        let m = c.metrics();
        assert_eq!(m.completed, 9);
        assert!(m.fused_rounds > 0);
        assert!(m.fused_rows_per_round > 1.0,
                "rows/round {}", m.fused_rows_per_round);
        c.shutdown();
    }

    #[test]
    fn two_variant_burst_progresses_both_lanes_in_one_tick_window() {
        // ONE worker, two variants submitted together: the lane
        // scheduler must interleave both lanes' rounds (the pre-lane
        // batcher served variant b only after variant a fully drained)
        let c = Coordinator::new(ServerConfig {
            workers: 1,
            max_batch: 8,
            enable_batching: true,
            ..Default::default()
        }).unwrap();
        c.register_model("a", GmmDdpmOracle::new(Gmm::circle_2d(), 60,
                                                 false));
        c.register_model("b", GmmDdpmOracle::new(Gmm::random(3, 4, 1.5, 9),
                                                 60, false));
        let mut rxs = Vec::new();
        for i in 0..8u64 {
            let variant = if i % 2 == 0 { "a" } else { "b" };
            rxs.push(c.submit(Request {
                id: 0,
                variant: variant.into(),
                sampler: SamplerSpec::Sequential,
                seed: 50 + i,
                cond: vec![],
            }).1);
        }
        for rx in rxs {
            assert!(rx.recv().unwrap().error.is_none());
        }
        let m = c.metrics();
        assert_eq!(m.completed, 8);
        let a = m.lane("a").expect("lane a");
        let b = m.lane("b").expect("lane b");
        assert!(a.fused_rounds > 0 && b.fused_rounds > 0);
        // the single worker must have driven both lanes concurrently:
        // their round windows overlap instead of running back to back
        assert!(a.overlaps(b),
                "lanes ran sequentially: a=[{:.2},{:.2}]ms \
                 b=[{:.2},{:.2}]ms",
                a.first_round_ms, a.last_round_ms, b.first_round_ms,
                b.last_round_ms);
        c.shutdown();
    }

    #[test]
    fn shutdown_joins_workers() {
        let c = coordinator_with_oracle(3);
        let (_, rx) = c.submit(req(SamplerSpec::Sequential, 9));
        rx.recv().unwrap();
        c.shutdown(); // must not hang
    }

    #[test]
    fn sharded_pool_serves_identical_samples_and_records_occupancy() {
        let serve = |pool: PoolConfig| -> (Vec<f64>, f64) {
            let c = Coordinator::new(ServerConfig {
                workers: 2,
                max_batch: 4,
                enable_batching: true,
                pool,
                ..Default::default()
            }).unwrap();
            let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 40, false);
            c.register_model("gmm", oracle);
            let mut samples = Vec::new();
            for seed in 0..4 {
                let (_, rx) = c.submit(req(SamplerSpec::Asd(8), seed));
                let r = rx.recv().unwrap();
                assert!(r.error.is_none());
                samples.extend(r.sample);
            }
            let occ = c.metrics().mean_shard_occupancy;
            c.shutdown();
            (samples, occ)
        };
        let (inline, occ1) = serve(PoolConfig::default());
        let (sharded, occ4) =
            serve(PoolConfig { pool_size: 4, shard_min: 1 });
        let bits = |v: &[f64]| -> Vec<u64> {
            v.iter().map(|x| x.to_bits()).collect()
        };
        assert_eq!(bits(&inline), bits(&sharded));
        assert!((occ1 - 1.0).abs() < 1e-12, "inline occupancy {occ1}");
        assert!(occ4 > 1.0, "sharded occupancy {occ4}");
    }
}
