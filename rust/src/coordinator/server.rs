//! The coordinator: owns the lane state (variant-keyed queues + lane
//! table), worker pool and model registry.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::asd::{AsdEngine, DraftEngine};
use crate::coordinator::fusion::RecoveryPolicy;
use crate::coordinator::lanes::{Lane, LaneClaim, LaneState};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{FailReason, QueuedJob, Request, Response,
                                  SamplerSpec};
use crate::ddpm::SequentialSampler;
use crate::faults::FaultPlan;
use crate::model::DenoiseModel;
use crate::math::isa::KernelPolicy;
use crate::picard::PicardSampler;
use crate::runtime::pool::{self, PoolConfig};

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub workers: usize,
    /// fuse at most this many concurrent requests into one lane's
    /// fused round group (any sampler mix; see `coordinator::fusion`)
    pub max_batch: usize,
    pub enable_batching: bool,
    /// bounded admission: submissions beyond this *total* queue depth
    /// (summed across variant lanes) are answered immediately with a
    /// rejected [`Response`] instead of growing the queues without
    /// limit
    pub max_queue_depth: usize,
    /// sharding config for every batched denoise call served by this
    /// coordinator (each lane's fused round, or the per-request
    /// batched calls when batching is disabled). All workers share the
    /// ONE global pool — worker threads gate concurrency at the lane
    /// level, the pool at the row level, so cores are never
    /// oversubscribed. Bit-transparency holds for native
    /// row-independent models; HLO models may shift within f32 padding
    /// tolerance (see `model::parallel`).
    pub pool: PoolConfig,
    /// byte budget per lane for the round arena + GEMM workspace
    /// (which grow to the high-water round size): once a lane drains,
    /// a footprint past this cap is released instead of pinning a
    /// burst's memory for the coordinator's lifetime. 0 = unbounded
    /// (the pre-cap behavior). Surfaced per lane as
    /// `LaneSnapshot::arena_high_water_bytes`.
    pub arena_byte_cap: usize,
    /// GEMM kernel policy for native models *loaded by this server's
    /// frontend* (`--native` serving): requested ISA + packed-panel
    /// precision, resolved once per model at load (see `math::isa`).
    /// Determines the determinism tier the deployment advertises —
    /// bit-exact, reproducible-given-config, or
    /// quantized-with-error-bound. Models registered directly by
    /// callers carry their own policy; this field does not rewrite
    /// them.
    pub kernel: KernelPolicy,
    /// failure-recovery knobs shared by every lane: per-request
    /// deadline handling, from-scratch retry with per-round backoff,
    /// the lane circuit breaker, and NaN/Inf output validation
    pub recovery: RecoveryPolicy,
    /// deterministic fault injection (chaos testing): when set, every
    /// lane's fused calls run through a `ChaosModel` seeded by this
    /// plan. `None` (the default) = production serving, no injection.
    pub faults: Option<FaultPlan>,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            workers: 2,
            max_batch: 8,
            enable_batching: true,
            max_queue_depth: 1024,
            pool: PoolConfig::default(),
            arena_byte_cap: 64 << 20, // 64 MiB per lane
            kernel: KernelPolicy::default(),
            recovery: RecoveryPolicy::default(),
            faults: None,
        }
    }
}

impl ServerConfig {
    /// Reject degenerate configs up front: a zero here used to mean a
    /// coordinator that either silently clamped (`workers`) or wedged /
    /// rejected everything (`max_batch`, `max_queue_depth`).
    fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.workers >= 1,
                        "ServerConfig::workers must be >= 1 (got 0)");
        anyhow::ensure!(self.max_batch >= 1,
                        "ServerConfig::max_batch must be >= 1 (got 0)");
        anyhow::ensure!(self.max_queue_depth >= 1,
                        "ServerConfig::max_queue_depth must be >= 1 \
                         (got 0)");
        anyhow::ensure!(self.recovery.breaker_threshold >= 1,
                        "RecoveryPolicy::breaker_threshold must be >= 1 \
                         (got 0)");
        Ok(())
    }
}

struct Shared {
    /// variant-keyed queues + lane table, under ONE mutex (paired with
    /// `cv`). Held only for queue/claim bookkeeping — never across a
    /// model call.
    state: Mutex<LaneState>,
    cv: Condvar,
    shutdown: AtomicBool,
    metrics: Metrics,
    /// model registry. Locked at registration and once per lane
    /// creation (the lane snapshots its model `Arc`) — never on the
    /// round hot path.
    models: Mutex<HashMap<String, Arc<dyn DenoiseModel>>>,
    /// draft pairings: target variant name -> draft variant name (both
    /// must be registered models). Resolved to an `Arc` snapshot once
    /// per lane creation, exactly like `models` — never locked on the
    /// round hot path.
    drafts: Mutex<HashMap<String, String>>,
    config: ServerConfig,
    next_id: AtomicU64,
    /// `Coordinator::drain` raised this: admissions are refused
    /// ([`FailReason::Draining`]) until `resume` clears it
    draining: AtomicBool,
    /// bumped by `Coordinator::reload_variant`; lanes carrying an older
    /// epoch re-snapshot their model from the registry before their
    /// next round (`Driver::pump`)
    reload_epoch: AtomicU64,
    /// requests currently being served by the batching-off solo path
    /// (`serve_single`) — they are invisible to the lane state, so
    /// `drain` waits on this too
    single_busy: AtomicU64,
}

/// The serving coordinator. Models are registered up front (they wrap
/// either HLO executables or the native oracle); requests are submitted
/// from any thread and answered over per-request channels. Each
/// registered variant is served by its own lane (`coordinator::lanes`):
/// workers claim busy lanes and submit each lane's fused round to the
/// global pool as an independent task ([`Driver`]) — rounds run
/// continuously with no tick barrier, so no variant ever waits behind
/// another variant's burst or straggler round.
pub struct Coordinator {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Build the coordinator, validating the config (degenerate values
    /// like `max_batch: 0` are a clean error, not silent misbehavior).
    pub fn new(config: ServerConfig) -> Result<Coordinator> {
        config.validate()?;
        let shared = Arc::new(Shared {
            state: Mutex::new(LaneState::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            metrics: Metrics::default(),
            models: Mutex::new(HashMap::new()),
            drafts: Mutex::new(HashMap::new()),
            config: config.clone(),
            next_id: AtomicU64::new(1),
            draining: AtomicBool::new(false),
            reload_epoch: AtomicU64::new(0),
            single_busy: AtomicU64::new(0),
        });
        let mut handles = Vec::new();
        for w in 0..config.workers {
            let s = shared.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("asd-worker-{w}"))
                    .spawn(move || worker_loop(s))
                    .expect("spawn worker"),
            );
        }
        Ok(Coordinator { shared, handles })
    }

    pub fn register_model(&self, name: &str, model: Arc<dyn DenoiseModel>) {
        self.shared
            .models
            .lock()
            .unwrap()
            .insert(name.to_string(), model);
    }

    pub fn has_model(&self, name: &str) -> bool {
        self.shared.models.lock().unwrap().contains_key(name)
    }

    /// Pair a draft variant with a target variant for
    /// [`SamplerSpec::Draft`] requests: draft requests addressed to
    /// `target` verify proposals produced by `draft`'s model. Both
    /// names must already be registered. The pairing is snapshotted at
    /// lane creation — pair before the first draft request for the
    /// variant (an existing lane keeps the pairing it was built with).
    pub fn pair_draft(&self, target: &str, draft: &str) -> Result<()> {
        let models = self.shared.models.lock().unwrap();
        anyhow::ensure!(models.contains_key(target),
                        "pair_draft: unknown target variant '{target}'");
        anyhow::ensure!(models.contains_key(draft),
                        "pair_draft: unknown draft variant '{draft}'");
        drop(models);
        self.shared.drafts.lock().unwrap()
            .insert(target.to_string(), draft.to_string());
        Ok(())
    }

    /// Submit a request; returns the response channel and the assigned
    /// id. When the total queued depth is at `max_queue_depth` the
    /// request is not enqueued: a rejected [`Response`] is delivered on
    /// the channel immediately (bounded admission — a loaded
    /// coordinator sheds traffic instead of accumulating unbounded
    /// latency).
    pub fn submit(&self, mut request: Request) -> (u64, Receiver<Response>) {
        let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
        request.id = id;
        let (tx, rx) = channel();
        self.shared.metrics.on_submit();
        if self.shared.draining.load(Ordering::SeqCst) {
            self.shared.metrics.on_reject();
            let _ = tx.send(Response {
                rejected: true,
                reason: Some(FailReason::Draining),
                error: Some("rejected: coordinator is draining \
                             (Coordinator::resume re-opens admissions)"
                                .to_string()),
                ..Response::failed(id, 0.0, "")
            });
            return (id, rx);
        }
        {
            let mut st = lock_state(&self.shared);
            let depth = st.depth();
            if depth >= self.shared.config.max_queue_depth {
                drop(st);
                self.shared.metrics.on_reject();
                let _ = tx.send(Response::rejected(
                    id, depth, self.shared.config.max_queue_depth));
                return (id, rx);
            }
            st.enqueue(QueuedJob {
                request,
                reply: tx,
                enqueued: Instant::now(),
            });
        }
        self.shared.cv.notify_one();
        (id, rx)
    }

    /// Hot-swap `name`'s model snapshot without dropping in-flight
    /// requests: the registry entry is replaced and the reload epoch
    /// bumped; each lane re-snapshots its model `Arc` before its next
    /// round (`Driver::pump`). Requests already mid-sample keep their
    /// own clone of the old model and finish against it untouched —
    /// only fused *calls*, retries and new admissions route through the
    /// new snapshot. The new model must match the old geometry
    /// (dim / cond_dim / k_steps): lane arenas and in-flight machines
    /// are sized against it.
    pub fn reload_variant(&self, name: &str,
                          model: Arc<dyn DenoiseModel>) -> Result<()> {
        {
            let mut models = self.shared.models.lock().unwrap();
            let old = models.get(name).ok_or_else(|| anyhow::anyhow!(
                "reload_variant: unknown variant '{name}'"))?;
            anyhow::ensure!(
                old.dim() == model.dim()
                    && old.cond_dim() == model.cond_dim()
                    && old.k_steps() == model.k_steps(),
                "reload_variant: geometry mismatch for '{name}' \
                 (dim/cond_dim/k_steps must match the serving snapshot; \
                 register a new variant name for a different geometry)");
            models.insert(name.to_string(), model);
        }
        self.shared.reload_epoch.fetch_add(1, Ordering::SeqCst);
        self.shared.metrics.on_reload(name);
        self.shared.cv.notify_all();
        Ok(())
    }

    /// Stop admitting work and block until every queued and in-flight
    /// request has been answered. New submissions are rejected with
    /// [`FailReason::Draining`] the moment this is called; nothing
    /// already accepted is dropped. Returns once all lanes are parked
    /// idle and the queues are empty; [`Coordinator::resume`] re-opens
    /// admissions (workers stay alive throughout — drain is a pause,
    /// not a shutdown).
    pub fn drain(&self) {
        self.shared.draining.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        let mut st = lock_state(&self.shared);
        while !(st.depth() == 0
                && st.all_parked_idle()
                && self.shared.single_busy.load(Ordering::SeqCst) == 0)
        {
            st = wait_state(&self.shared, st);
        }
    }

    /// Re-open admissions after [`Coordinator::drain`].
    pub fn resume(&self) {
        self.shared.draining.store(false, Ordering::SeqCst);
        self.shared.cv.notify_all();
    }

    pub fn metrics(&self) -> crate::coordinator::MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    /// Total queued (not yet admitted) jobs across all variant lanes.
    pub fn queue_depth(&self) -> usize {
        lock_state(&self.shared).depth()
    }

    pub fn shutdown(mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    if !shared.config.enable_batching || shared.config.max_batch <= 1 {
        return single_loop(shared);
    }
    lane_loop(shared);
}

/// Batching disabled (or `max_batch == 1`): serve one request at a
/// time with dedicated solo engines, oldest-first across variants.
fn single_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut st = lock_state(&shared);
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                match st.pop_oldest() {
                    Some(job) => break job,
                    None => st = wait_state(&shared, st),
                }
            }
        };
        serve_single(&shared, job);
    }
}

/// Jobs popped for a lane this driver holds, tagged with the lane's
/// slot index, slot-contiguous (a flat, reusable buffer — the machines
/// are built outside the state lock, since construction does Philox
/// draws).
type Admissions = Vec<(usize, QueuedJob)>;

/// Lock the coordinator state, recovering the guard if a panicking
/// sibling poisoned the mutex: the queue tables stay structurally
/// valid (panics never unwind mid-mutation under this lock), and a
/// recovered guard beats permanently unservable variants or a cascade
/// of worker deaths.
fn lock_state(shared: &Shared) -> std::sync::MutexGuard<'_, LaneState> {
    shared.state.lock()
        .unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// `cv.wait` on the state lock with the same poison recovery as
/// [`lock_state`].
fn wait_state<'a>(shared: &'a Shared,
                  st: std::sync::MutexGuard<'a, LaneState>)
                  -> std::sync::MutexGuard<'a, LaneState> {
    shared.cv.wait(st)
        .unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// A raw lane pointer smuggled into a round task's closure; sound
/// because the driver keeps the boxed lane alive — and never touches
/// it — from submission until the task's key is drained from the
/// driver's round group (enforced by the `inflight` flags; see
/// [`Driver`]).
struct SendLane(*mut Lane);
unsafe impl Send for SendLane {}

/// One worker's continuous round runtime over its claimed lanes.
///
/// Replaces the tick-synchronous `tick_lanes` barrier: each held
/// lane's fused round is submitted to the global pool as an
/// independent round task the moment the lane stages rows
/// ([`Driver::pump`]), and the lane is re-polled and re-submitted the
/// moment its completion is drained ([`Driver::wait_and_finish`]) —
/// while sibling lanes' rounds are still executing. A straggler lane
/// therefore delays nobody: fast lanes cycle at their own cadence,
/// idle pool workers steal whatever is queued (the driver itself helps
/// while blocked in `wait_rounds`), and the only per-lane
/// serialization left is the cheap poll/resume sampler math on this
/// driver thread.
///
/// Slots are stable: `held[i]` keeps its index for the lane's whole
/// claim (freed slots recycle through a free list) because in-flight
/// round tasks address their lane by slot key. An in-flight slot's
/// `Box<Lane>` is mutably aliased by its round task, so the driver
/// never reads it — `names[i]` carries the variant for bookkeeping
/// that must run mid-flight.
///
/// Dropping the driver (normal return or unwind) first waits out every
/// in-flight round, then parks all held lanes back in the table — the
/// panic-recovery role the old `LaneGuard` played, extended to never
/// release a lane whose round still executes on the pool.
struct Driver<'a> {
    shared: &'a Shared,
    held: Vec<Option<Box<Lane>>>,
    /// slot -> variant name, readable while the lane box is aliased
    names: Vec<Option<String>>,
    /// slot has a submitted round task whose completion is undrained
    inflight: Vec<bool>,
    free: Vec<usize>,
    n_held: usize,
    n_inflight: usize,
    group: pool::RoundGroup,
    /// `wait_rounds` drain buffer, reused across rounds
    done: Vec<(usize, bool)>,
}

impl<'a> Driver<'a> {
    fn new(shared: &'a Shared) -> Driver<'a> {
        Driver {
            shared,
            held: Vec::new(),
            names: Vec::new(),
            inflight: Vec::new(),
            free: Vec::new(),
            n_held: 0,
            n_inflight: 0,
            group: pool::RoundGroup::new(),
            done: Vec::new(),
        }
    }

    fn holds_variant(&self, variant: &str) -> bool {
        self.names.iter().any(|n| n.as_deref() == Some(variant))
    }

    /// Install a claimed lane in a stable slot, returning its index.
    fn place(&mut self, lane: Box<Lane>) -> usize {
        self.n_held += 1;
        match self.free.pop() {
            Some(i) => {
                self.names[i] = Some(lane.variant.clone());
                self.held[i] = Some(lane);
                self.inflight[i] = false;
                i
            }
            None => {
                self.names.push(Some(lane.variant.clone()));
                self.held.push(Some(lane));
                self.inflight.push(false);
                self.held.len() - 1
            }
        }
    }

    /// Under the state lock: top up every held, not-in-flight lane
    /// from its variant queue and claim any other busy, unclaimed lane
    /// (creating it — with its model `Arc` snapshot — on first use).
    /// Popped jobs land flat in `admissions` keyed by slot index;
    /// unknown-variant jobs land in `failures`. Machine construction
    /// and response sends happen outside the lock. An in-flight lane
    /// is never touched (its round task owns the `&mut`): its queued
    /// jobs wait at most one round for the completion to drain.
    fn gather(&mut self, st: &mut LaneState, admissions: &mut Admissions,
              failures: &mut Vec<(QueuedJob, String)>,
              variants: &mut Vec<String>, jobs: &mut Vec<QueuedJob>) {
        let shared = self.shared;
        let max_batch = shared.config.max_batch;
        // 1) continuous admission into lanes this driver already holds
        for i in 0..self.held.len() {
            if self.inflight[i] {
                continue;
            }
            let Some(lane) = self.held[i].as_ref() else { continue };
            let room = max_batch.saturating_sub(lane.in_flight());
            if room == 0 {
                continue;
            }
            jobs.clear();
            if st.take(&lane.variant, room, jobs) > 0 {
                admissions.extend(jobs.drain(..).map(|j| (i, j)));
            }
        }
        // 2) claim lanes for every other variant with queued work
        // (`variants` recycles its String buffers across rounds)
        st.queued_variants(variants);
        for vi in 0..variants.len() {
            let variant = variants[vi].as_str();
            if self.holds_variant(variant) {
                continue; // held but out of room, or mid-round
            }
            let lane = match st.claim(variant) {
                LaneClaim::Busy => continue, // another worker drives it
                LaneClaim::Claimed(lane) => lane,
                LaneClaim::Create => {
                    // snapshot the model Arc once per lane — the
                    // registry is never locked again for this
                    // variant's rounds. The slot is already marked
                    // held; if the lookup or lane construction unwinds
                    // (poisoned registry mutex, model metadata panic)
                    // the marker must be abandoned, or the variant
                    // would answer Busy forever.
                    let built = std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(|| {
                            // read the epoch BEFORE snapshotting: a
                            // reload racing in between leaves the lane
                            // stale-marked, so pump refreshes it — the
                            // safe direction
                            let epoch = shared.reload_epoch
                                .load(Ordering::SeqCst);
                            let models = shared.models.lock().unwrap();
                            models.get(variant).cloned().map(|m| {
                                // resolve the variant's draft pairing
                                // (if any) to an Arc snapshot alongside
                                // the target model
                                let draft = shared.drafts.lock().unwrap()
                                    .get(variant)
                                    .and_then(|d| models.get(d).cloned());
                                let mut lane = Box::new(Lane::new(
                                    variant, m, draft, shared.config.pool,
                                    shared.config.arena_byte_cap,
                                    shared.config.faults.as_ref(),
                                    shared.config.recovery));
                                lane.epoch = epoch;
                                lane
                            })
                        }));
                    match built {
                        Ok(Some(lane)) => lane,
                        Ok(None) => {
                            st.abandon(variant);
                            let msg = format!("unknown model '{variant}'");
                            for job in st.drain_variant(variant) {
                                failures.push((job, msg.clone()));
                            }
                            continue;
                        }
                        Err(panic) => {
                            st.abandon(variant);
                            std::panic::resume_unwind(panic);
                        }
                    }
                }
            };
            let room = max_batch.saturating_sub(lane.in_flight());
            jobs.clear();
            st.take(variant, room, jobs);
            let idx = self.place(lane);
            admissions.extend(jobs.drain(..).map(|j| (idx, j)));
        }
        // 3) panic-recovery backstop: adopt parked lanes that still
        // hold in-flight machines (only possible when a panicking
        // driver parked lanes mid-flight) so their admitted requests
        // keep making progress instead of stranding their clients
        st.parked_nonidle(variants);
        for vi in 0..variants.len() {
            let variant = variants[vi].as_str();
            if self.holds_variant(variant) {
                continue;
            }
            if let LaneClaim::Claimed(lane) = st.claim(variant) {
                self.place(lane);
            }
        }
    }

    /// Build machines for freshly popped jobs (outside the state
    /// lock), batch-admitting per lane so group-formation metrics see
    /// whole batches. `batch` is a reusable scratch buffer;
    /// `admissions` entries are slot-contiguous by construction
    /// (gather appends per lane) and only ever target lanes that are
    /// not in flight.
    fn apply_admissions(&mut self, admissions: &mut Admissions,
                        batch: &mut Vec<QueuedJob>) {
        let mut iter = admissions.drain(..).peekable();
        while let Some((idx, job)) = iter.next() {
            batch.clear();
            batch.push(job);
            while iter.peek().is_some_and(|&(next, _)| next == idx) {
                batch.push(iter.next().unwrap().1);
            }
            debug_assert!(!self.inflight[idx],
                          "admission into an in-flight lane");
            self.held[idx].as_mut().expect("admission into empty slot")
                .admit(batch, &self.shared.metrics);
        }
    }

    /// Poll every held, not-in-flight lane (retiring finished machines
    /// and staging demands) and submit each lane that staged rows to
    /// the pool — as the compiled barrier-free tile graph when the
    /// lane's backend has one (the graph's tiles interleave with every
    /// other lane's across the workers, and its single completion
    /// lands in the same round group), falling back to one opaque
    /// round task otherwise. Completions drain through
    /// [`Self::wait_and_finish`]. Lanes already mid-round are skipped —
    /// that is what makes rounds continuous instead of tick-aligned.
    fn pump(&mut self) {
        let metrics = &self.shared.metrics;
        let epoch = self.shared.reload_epoch.load(Ordering::SeqCst);
        for i in 0..self.held.len() {
            if self.inflight[i] {
                continue;
            }
            let Some(lane) = self.held[i].as_mut() else { continue };
            if lane.epoch != epoch {
                // a reload landed since this lane snapshotted its
                // model: re-snapshot before the next fused call. Not
                // on the hot path in steady state (one atomic load +
                // u64 compare per lane per round otherwise).
                refresh_lane(self.shared, lane, epoch);
            }
            guard_phase(lane, metrics, "poll", |l| l.begin_round(metrics));
            if !lane.has_round() {
                continue;
            }
            // SAFETY (both arms): see SendLane — the driver neither
            // touches nor drops this lane until the key drains from its
            // group, which is exactly the keep-alive contract the
            // graph's raw arena pointers need.
            if let Some(graph) = lane.compile_round() {
                pool::global().submit_graph(&self.group, i, graph);
            } else {
                let ptr = SendLane(&mut **lane as *mut Lane);
                pool::global().submit_round(
                    &self.group, i,
                    Box::new(move || {
                        let lane = unsafe { &mut *ptr.0 };
                        lane.execute_round();
                    }));
            }
            self.inflight[i] = true;
            self.n_inflight += 1;
        }
    }

    /// Block until at least one submitted round completes (helping the
    /// pool execute queued work while blocked — see
    /// `ThreadPool::wait_rounds`), then run the scatter phase for every
    /// completed lane. Sibling lanes' rounds keep executing throughout:
    /// there is no barrier anywhere in this path.
    fn wait_and_finish(&mut self) {
        let metrics = &self.shared.metrics;
        self.done.clear();
        pool::global().wait_rounds(&self.group, &mut self.done);
        for k in 0..self.done.len() {
            let (key, panicked) = self.done[k];
            self.inflight[key] = false;
            self.n_inflight -= 1;
            let lane = self.held[key].as_mut()
                .expect("round completion for an empty slot");
            // graph rounds report their outcome through the completion
            // flag: complete_round turns it into the staged execution
            // report (a tile panic fails the group like a model error,
            // with dependent tiles never having run) and the scatter
            // phase proceeds. No-op (false) for closure rounds, which
            // staged their report inline.
            let was_graph = lane.complete_round(panicked);
            if panicked && !was_graph {
                // the round task itself panicked (execute_round already
                // contains model-call panics, so this is scheduler
                // bookkeeping gone wrong): mid-round machines are
                // unusable — fail the group, keep the lane servable
                lane.fail_all(
                    Some(FailReason::ModelPanic),
                    "lane round task panicked during fused execute",
                    metrics);
            } else {
                guard_phase(lane, metrics, "resume",
                            |l| l.finish_round(metrics));
            }
        }
    }

    /// Under the state lock: park every held lane that drained and (in
    /// normal operation) has no queued work; during wind-down park
    /// every drained lane unconditionally. In-flight lanes are never
    /// released — their round task still owns the `&mut`.
    fn release_drained(&mut self, st: &mut LaneState, wind_down: bool) {
        for i in 0..self.held.len() {
            if self.inflight[i] {
                continue;
            }
            let Some(lane) = self.held[i].as_ref() else { continue };
            if !lane.is_idle() {
                continue;
            }
            if !wind_down && st.has_queued(&lane.variant) {
                continue;
            }
            st.release(self.held[i].take().unwrap());
            self.names[i] = None;
            self.free.push(i);
            self.n_held -= 1;
        }
    }
}

impl Drop for Driver<'_> {
    fn drop(&mut self) {
        // 1) wait out in-flight round tasks: a lane whose fused call
        // still executes on the pool must not be parked (the task holds
        // a &mut into the box). Completions always arrive — the global
        // pool is never torn down and round-task panics are contained.
        while self.n_inflight > 0 {
            self.done.clear();
            pool::global().wait_rounds(&self.group, &mut self.done);
            for k in 0..self.done.len() {
                let key = self.done[k].0;
                if self.inflight[key] {
                    self.inflight[key] = false;
                    self.n_inflight -= 1;
                }
            }
        }
        if self.n_held == 0 {
            return;
        }
        // 2) park every held lane — even non-idle ones. This drop runs
        // on unwind too: a panicking driver's lanes must go back to the
        // table, where gather's parked_nonidle backstop lets another
        // worker adopt them; a claimed-forever slot would make the
        // variant unservable and pin queue budget.
        let mut st = lock_state(self.shared);
        for slot in self.held.iter_mut() {
            if let Some(lane) = slot.take() {
                st.release(lane);
            }
        }
        drop(st);
        self.shared.cv.notify_all();
    }
}

/// The lane-scheduling worker loop: claim every busy, unclaimed lane,
/// then drive all held lanes continuously — each lane's fused round is
/// an independent task on the one global pool ([`Driver`]), finished
/// and re-submitted the moment it completes. There is no global tick
/// and no barrier: a straggler lane's round never gates its siblings'.
/// All loop bookkeeping buffers are worker-local and reused; the
/// per-round data plane itself (arena + workspace, inside each lane)
/// allocates nothing in steady state.
fn lane_loop(shared: Arc<Shared>) {
    let mut driver = Driver::new(&shared);
    let mut admissions: Admissions = Vec::new();
    let mut failures: Vec<(QueuedJob, String)> = Vec::new();
    let mut variants: Vec<String> = Vec::new();
    let mut jobs: Vec<QueuedJob> = Vec::new();
    let mut batch: Vec<QueuedJob> = Vec::new();
    loop {
        // ---- blocking claim: wait until some lane has work ----
        {
            let mut st = lock_state(&shared);
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    // no lanes are held here: the drive loop below only
                    // exits once every held lane drained and was parked
                    return;
                }
                driver.gather(&mut st, &mut admissions, &mut failures,
                              &mut variants, &mut jobs);
                if driver.n_held > 0 || !failures.is_empty() {
                    break;
                }
                st = wait_state(&shared, st);
            }
        }
        answer_failures(&shared, &mut failures);
        driver.apply_admissions(&mut admissions, &mut batch);

        // ---- continuous drive: no global tick ----
        while driver.n_held > 0 {
            driver.pump();
            if driver.n_inflight > 0 {
                driver.wait_and_finish();
            }
            {
                let mut st = lock_state(&shared);
                let wind_down = shared.shutdown.load(Ordering::SeqCst);
                driver.release_drained(&mut st, wind_down);
                if !wind_down {
                    // continuous admission + cross-variant pickup
                    driver.gather(&mut st, &mut admissions, &mut failures,
                                  &mut variants, &mut jobs);
                }
            }
            if shared.draining.load(Ordering::SeqCst) {
                // a drain() caller waits on the cv for the fully-
                // drained condition; progress here may have produced it
                shared.cv.notify_all();
            }
            answer_failures(&shared, &mut failures);
            driver.apply_admissions(&mut admissions, &mut batch);
        }
    }
}

fn answer_failures(shared: &Shared, failures: &mut Vec<(QueuedJob, String)>) {
    for (job, msg) in failures.drain(..) {
        fail_job(shared, job, &msg);
    }
}

/// Run one serial tick phase on a lane, converting a sampler-machine
/// panic into a clean whole-group failure (the panicking machine's
/// state is unusable; stranding its group's clients would be worse).
fn guard_phase<F: FnOnce(&mut Lane)>(lane: &mut Box<Lane>,
                                     metrics: &Metrics, phase: &str, f: F) {
    let outcome = std::panic::catch_unwind(
        std::panic::AssertUnwindSafe(|| f(lane)));
    if outcome.is_err() {
        lane.fail_all(
            Some(FailReason::ModelPanic),
            &format!("sampler machine panicked during fused {phase}"),
            metrics);
    }
}

/// Re-snapshot a stale lane's model (and draft pairing) from the
/// registry after a `reload_variant` bumped the epoch. Missing models
/// can't happen (the registry is insert-only) but are tolerated: the
/// lane just stays stale and retries next round.
fn refresh_lane(shared: &Shared, lane: &mut Lane, epoch: u64) {
    let models = shared.models.lock().unwrap();
    if let Some(m) = models.get(&lane.variant).cloned() {
        let draft = shared.drafts.lock().unwrap()
            .get(&lane.variant)
            .and_then(|d| models.get(d).cloned());
        lane.set_model(m, draft, epoch);
    }
}

fn model_for(shared: &Shared, variant: &str) -> Option<Arc<dyn DenoiseModel>> {
    shared.models.lock().unwrap().get(variant).cloned()
}

/// The variant's paired draft model, if one is registered.
fn draft_for(shared: &Shared, variant: &str)
             -> Option<Arc<dyn DenoiseModel>> {
    let name = shared.drafts.lock().unwrap().get(variant).cloned()?;
    model_for(shared, &name)
}

fn serve_single(shared: &Shared, job: QueuedJob) {
    let queued_s = job.enqueued.elapsed().as_secs_f64();
    if job.expired() {
        // solo path deadline check: the request's budget ran out while
        // it queued — answer it without spending a model call
        shared.metrics.on_timeout(&job.request.variant, false);
        shared.metrics.on_complete(queued_s, 0.0, 0, 0, true);
        let _ = job.reply.send(Response::failed_with(
            job.request.id, queued_s, FailReason::Timeout,
            "deadline exceeded while queued (request never admitted)"));
        return;
    }
    shared.single_busy.fetch_add(1, Ordering::SeqCst);
    let t0 = Instant::now();
    let req = &job.request;
    let outcome = match model_for(shared, &req.variant) {
        None => Err(format!("unknown model '{}'", req.variant)),
        Some(model) => {
            let draft = draft_for(shared, &req.variant);
            run_sampler(model, draft, req, shared.config.pool)
        }
    };
    let service_s = t0.elapsed().as_secs_f64();
    if let Ok((_, _, _, Some(st))) = &outcome {
        shared.metrics.on_round_stats(&st.round_latency_s, &st.round_shards);
        shared.metrics.on_grs_stats(&req.variant, st.accepted, st.rejected,
                                    st.iterations);
    }
    let resp = match outcome {
        Ok((sample, calls, rounds, asd_stats)) => Response {
            id: req.id,
            sample,
            model_calls: calls,
            parallel_rounds: rounds,
            asd_stats,
            queued_s,
            service_s,
            rejected: false,
            error: None,
            reason: None,
            retries: 0,
        },
        Err(e) => Response {
            service_s,
            ..Response::failed(req.id, queued_s, &e)
        },
    };
    shared.metrics.on_complete(queued_s, service_s, resp.model_calls,
                               resp.parallel_rounds, resp.error.is_some());
    let _ = job.reply.send(resp);
    shared.single_busy.fetch_sub(1, Ordering::SeqCst);
    if shared.draining.load(Ordering::SeqCst) {
        shared.cv.notify_all();
    }
}

type SampleOutcome =
    std::result::Result<(Vec<f64>, usize, usize, Option<crate::asd::AsdStats>), String>;

fn run_sampler(model: Arc<dyn DenoiseModel>,
               draft: Option<Arc<dyn DenoiseModel>>, req: &Request,
               pool: PoolConfig) -> SampleOutcome {
    match req.sampler {
        SamplerSpec::Sequential => {
            let sampler = SequentialSampler::new(model);
            sampler
                .sample(req.seed, &req.cond)
                .map(|(y, st)| (y, st.model_calls, st.model_calls, None))
                .map_err(|e| e.to_string())
        }
        SamplerSpec::Asd(theta) => {
            // canonical config shared with the fused path — see
            // SamplerSpec::asd_config
            let mut engine = AsdEngine::new(
                model, SamplerSpec::asd_config(theta, pool));
            engine
                .sample_cond(req.seed, &req.cond)
                .map(|out| {
                    let calls = out.stats.model_calls;
                    let rounds = out.stats.parallel_rounds;
                    (out.y0, calls, rounds, Some(out.stats))
                })
                .map_err(|e| e.to_string())
        }
        SamplerSpec::Picard(window, tol) => {
            let sampler = PicardSampler::new(
                model, SamplerSpec::picard_config(window, tol, pool));
            sampler
                .sample(req.seed, &req.cond)
                .map(|(y, st)| (y, st.model_calls, st.parallel_rounds, None))
                .map_err(|e| e.to_string())
        }
        SamplerSpec::Draft(k) => {
            let Some(draft) = draft else {
                return Err(
                    "no draft model paired for this variant (pair one \
                     with Coordinator::pair_draft before submitting \
                     draft requests)".to_string());
            };
            // canonical config shared with the fused path — see
            // SamplerSpec::draft_config
            let mut engine = DraftEngine::new(
                model, draft, SamplerSpec::draft_config(k, pool));
            engine
                .sample_cond(req.seed, &req.cond)
                .map(|out| {
                    let calls = out.stats.model_calls;
                    let rounds = out.stats.parallel_rounds;
                    (out.y0, calls, rounds, Some(out.stats))
                })
                .map_err(|e| e.to_string())
        }
    }
}

fn fail_job(shared: &Shared, job: QueuedJob, msg: &str) {
    let queued_s = job.enqueued.elapsed().as_secs_f64();
    shared.metrics.on_complete(queued_s, 0.0, 0, 0, true);
    let _ = job.reply.send(Response::failed(job.request.id, queued_s, msg));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Gmm, GmmDdpmOracle};
    use crate::schedule::DdpmSchedule;
    use anyhow::Result;

    fn coordinator_with_oracle(workers: usize) -> Coordinator {
        let c = Coordinator::new(ServerConfig {
            workers,
            max_batch: 4,
            enable_batching: true,
            ..Default::default()
        }).unwrap();
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 40, false);
        c.register_model("gmm", oracle);
        c
    }

    fn req(sampler: SamplerSpec, seed: u64) -> Request {
        Request {
            id: 0,
            variant: "gmm".into(),
            sampler,
            seed,
            cond: vec![],
            deadline: None,
        }
    }

    #[test]
    fn degenerate_configs_are_clean_errors() {
        for cfg in [
            ServerConfig { workers: 0, ..Default::default() },
            ServerConfig { max_batch: 0, ..Default::default() },
            ServerConfig { max_queue_depth: 0, ..Default::default() },
        ] {
            let err = Coordinator::new(cfg).err().expect("must reject");
            assert!(err.to_string().contains("must be >= 1"), "{err:#}");
        }
    }

    #[test]
    fn serves_sequential_and_asd() {
        let c = coordinator_with_oracle(2);
        let (_, rx1) = c.submit(req(SamplerSpec::Sequential, 1));
        let (_, rx2) = c.submit(req(SamplerSpec::Asd(8), 2));
        let r1 = rx1.recv().unwrap();
        let r2 = rx2.recv().unwrap();
        assert!(r1.error.is_none() && r2.error.is_none());
        assert_eq!(r1.sample.len(), 2);
        assert_eq!(r1.model_calls, 40);
        assert!(r2.parallel_rounds < 40);
        assert!(r2.asd_stats.is_some());
        c.shutdown();
    }

    #[test]
    fn unknown_model_fails_cleanly() {
        let c = coordinator_with_oracle(1);
        let (_, rx) = c.submit(Request {
            id: 0,
            variant: "nope".into(),
            sampler: SamplerSpec::Sequential,
            seed: 0,
            cond: vec![],
            deadline: None,
        });
        let r = rx.recv().unwrap();
        assert!(r.error.unwrap().contains("unknown model"));
        let m = c.metrics();
        assert_eq!(m.failed, 1);
        // the failed variant never created a lane
        assert!(m.lane("nope").is_none());
    }

    #[test]
    fn burst_of_sequential_requests_batches() {
        let c = coordinator_with_oracle(1);
        let rxs: Vec<_> = (0..8)
            .map(|s| c.submit(req(SamplerSpec::Sequential, s)).1)
            .collect();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!(r.error.is_none());
        }
        let m = c.metrics();
        assert_eq!(m.completed, 8);
        // at least one fusion group formed (worker races may split the
        // burst)
        assert!(m.batched_requests >= 2, "batched {}", m.batched_requests);
        // the lane reports its own round aggregates
        let lane = m.lane("gmm").unwrap();
        assert!(lane.fused_rounds > 0);
        assert_eq!(lane.admitted, 8);
        c.shutdown();
    }

    #[test]
    fn picard_request_works() {
        let c = coordinator_with_oracle(1);
        let (_, rx) = c.submit(req(SamplerSpec::Picard(8, 1e-6), 3));
        let r = rx.recv().unwrap();
        assert!(r.error.is_none());
        assert!(r.parallel_rounds >= 5);
        c.shutdown();
    }

    /// Test model whose denoise calls block until the gate opens —
    /// lets a test hold a worker busy so the queue actually fills.
    struct GatedModel {
        sched: DdpmSchedule,
        gate: Arc<(Mutex<bool>, Condvar)>,
    }

    impl GatedModel {
        fn open(gate: &Arc<(Mutex<bool>, Condvar)>) {
            let (lock, cv) = gate.as_ref();
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
    }

    impl crate::model::DenoiseModel for GatedModel {
        fn dim(&self) -> usize {
            1
        }
        fn cond_dim(&self) -> usize {
            0
        }
        fn k_steps(&self) -> usize {
            self.sched.k_steps
        }
        fn schedule(&self) -> &DdpmSchedule {
            &self.sched
        }
        fn denoise_batch(&self, _ys: &[f64], _ts: &[f64], _cond: &[f64],
                         n: usize, out: &mut [f64]) -> Result<()> {
            let (lock, cv) = self.gate.as_ref();
            let mut open = lock.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
            out[..n].fill(0.0);
            Ok(())
        }
    }

    #[test]
    fn bounded_admission_rejects_when_queue_is_full() {
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let c = Coordinator::new(ServerConfig {
            workers: 1,
            max_batch: 1, // no fusion: the worker blocks on one request
            enable_batching: true,
            max_queue_depth: 2,
            ..Default::default()
        }).unwrap();
        c.register_model("gated", Arc::new(GatedModel {
            sched: DdpmSchedule::new(2),
            gate: gate.clone(),
        }));
        let req = |seed| Request {
            id: 0,
            variant: "gated".into(),
            sampler: SamplerSpec::Sequential,
            seed,
            cond: vec![],
            deadline: None,
        };
        // r1 is picked up by the worker and blocks inside the model
        let (_, rx1) = c.submit(req(1));
        for _ in 0..200 {
            if c.queue_depth() == 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(c.queue_depth(), 0, "worker never picked up r1");
        // r2, r3 fill the queue to max_queue_depth
        let (_, rx2) = c.submit(req(2));
        let (_, rx3) = c.submit(req(3));
        assert_eq!(c.queue_depth(), 2);
        // r4 must be rejected immediately, without blocking
        let (_, rx4) = c.submit(req(4));
        let r4 = rx4.recv().unwrap();
        assert!(r4.rejected);
        assert!(r4.error.unwrap().contains("max_queue_depth"));
        let m = c.metrics();
        assert_eq!(m.rejected, 1);
        // open the gate: the admitted requests all complete
        GatedModel::open(&gate);
        for rx in [rx1, rx2, rx3] {
            let r = rx.recv().unwrap();
            assert!(!r.rejected);
            assert!(r.error.is_none(), "{:?}", r.error);
        }
        assert_eq!(c.metrics().completed, 3);
        c.shutdown();
    }

    #[test]
    fn mixed_samplers_fuse_into_mega_rounds() {
        // one worker, burst of all three sampler kinds on one variant:
        // the coordinator must fuse their rounds (rows/round > 1)
        let c = Coordinator::new(ServerConfig {
            workers: 1,
            max_batch: 16,
            enable_batching: true,
            ..Default::default()
        }).unwrap();
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 60, false);
        c.register_model("gmm", oracle);
        let rxs: Vec<_> = (0..9)
            .map(|i| {
                let sampler = match i % 3 {
                    0 => SamplerSpec::Sequential,
                    1 => SamplerSpec::Asd(8),
                    _ => SamplerSpec::Picard(8, 1e-6),
                };
                c.submit(req(sampler, 100 + i as u64)).1
            })
            .collect();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!(r.error.is_none(), "{:?}", r.error);
        }
        let m = c.metrics();
        assert_eq!(m.completed, 9);
        assert!(m.fused_rounds > 0);
        assert!(m.fused_rows_per_round > 1.0,
                "rows/round {}", m.fused_rows_per_round);
        c.shutdown();
    }

    #[test]
    fn two_variant_burst_progresses_both_lanes_in_one_tick_window() {
        // ONE worker, two variants submitted together: the lane
        // scheduler must interleave both lanes' rounds (the pre-lane
        // batcher served variant b only after variant a fully drained)
        let c = Coordinator::new(ServerConfig {
            workers: 1,
            max_batch: 8,
            enable_batching: true,
            ..Default::default()
        }).unwrap();
        c.register_model("a", GmmDdpmOracle::new(Gmm::circle_2d(), 60,
                                                 false));
        c.register_model("b", GmmDdpmOracle::new(Gmm::random(3, 4, 1.5, 9),
                                                 60, false));
        let mut rxs = Vec::new();
        for i in 0..8u64 {
            let variant = if i % 2 == 0 { "a" } else { "b" };
            rxs.push(c.submit(Request {
                id: 0,
                variant: variant.into(),
                sampler: SamplerSpec::Sequential,
                seed: 50 + i,
                cond: vec![],
                deadline: None,
            }).1);
        }
        for rx in rxs {
            assert!(rx.recv().unwrap().error.is_none());
        }
        let m = c.metrics();
        assert_eq!(m.completed, 8);
        let a = m.lane("a").expect("lane a");
        let b = m.lane("b").expect("lane b");
        assert!(a.fused_rounds > 0 && b.fused_rounds > 0);
        // the single worker must have driven both lanes concurrently:
        // their round windows overlap instead of running back to back
        assert!(a.overlaps(b),
                "lanes ran sequentially: a=[{:.2},{:.2}]ms \
                 b=[{:.2},{:.2}]ms",
                a.first_round_ms, a.last_round_ms, b.first_round_ms,
                b.last_round_ms);
        c.shutdown();
    }

    /// Test model whose denoise calls sleep — a controlled straggler
    /// lane for the no-barrier test below.
    struct SlowModel {
        sched: DdpmSchedule,
        delay: std::time::Duration,
    }

    impl crate::model::DenoiseModel for SlowModel {
        fn dim(&self) -> usize {
            1
        }
        fn cond_dim(&self) -> usize {
            0
        }
        fn k_steps(&self) -> usize {
            self.sched.k_steps
        }
        fn schedule(&self) -> &DdpmSchedule {
            &self.sched
        }
        fn denoise_batch(&self, _ys: &[f64], _ts: &[f64], _cond: &[f64],
                         n: usize, out: &mut [f64]) -> Result<()> {
            std::thread::sleep(self.delay);
            out[..n].fill(0.0);
            Ok(())
        }
    }

    #[test]
    fn single_worker_lanes_overlap_without_tick_barrier() {
        // ONE coordinator worker holding a straggler lane (every round
        // sleeps) and a fast lane. Under the old tick-synchronous
        // lane_loop every fast round barriered on a slow round, so the
        // fast lane's round window stretched to the slow lane's. The
        // continuous Driver must let the fast lane drain at its own
        // cadence while the straggler is still mid-burst: its window
        // must be a small fraction of the slow lane's, not ~equal.
        let c = Coordinator::new(ServerConfig {
            workers: 1,
            max_batch: 8,
            enable_batching: true,
            ..Default::default()
        }).unwrap();
        c.register_model("slow", Arc::new(SlowModel {
            sched: DdpmSchedule::new(30),
            delay: std::time::Duration::from_millis(4),
        }));
        c.register_model("fast",
                         GmmDdpmOracle::new(Gmm::circle_2d(), 25, false));
        let mk = |variant: &str, seed| Request {
            id: 0,
            variant: variant.into(),
            sampler: SamplerSpec::Sequential,
            seed,
            cond: vec![],
            deadline: None,
        };
        let (_, rx_slow) = c.submit(mk("slow", 1));
        let (_, rx_fast) = c.submit(mk("fast", 2));
        assert!(rx_fast.recv().unwrap().error.is_none());
        assert!(rx_slow.recv().unwrap().error.is_none());
        let m = c.metrics();
        let slow = m.lane("slow").expect("slow lane");
        let fast = m.lane("fast").expect("fast lane");
        assert!(slow.overlaps(fast),
                "lanes ran back to back: slow=[{:.2},{:.2}]ms \
                 fast=[{:.2},{:.2}]ms",
                slow.first_round_ms, slow.last_round_ms,
                fast.first_round_ms, fast.last_round_ms);
        let slow_window = slow.last_round_ms - slow.first_round_ms;
        let fast_window = fast.last_round_ms - fast.first_round_ms;
        assert!(slow_window >= 50.0,
                "straggler finished implausibly fast: {slow_window:.2}ms");
        assert!(fast_window < slow_window * 0.5,
                "fast lane was gated by the straggler (tick barrier): \
                 fast window {fast_window:.2}ms vs slow window \
                 {slow_window:.2}ms");
        // lane rounds flowed through the pool's round-task registry
        assert!(m.pool.rounds > 0, "no round tasks recorded");
        c.shutdown();
    }

    #[test]
    fn shutdown_joins_workers() {
        let c = coordinator_with_oracle(3);
        let (_, rx) = c.submit(req(SamplerSpec::Sequential, 9));
        rx.recv().unwrap();
        c.shutdown(); // must not hang
    }

    #[test]
    fn sharded_pool_serves_identical_samples_and_records_occupancy() {
        let serve = |pool: PoolConfig| -> (Vec<f64>, f64) {
            let c = Coordinator::new(ServerConfig {
                workers: 2,
                max_batch: 4,
                enable_batching: true,
                pool,
                ..Default::default()
            }).unwrap();
            let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 40, false);
            c.register_model("gmm", oracle);
            let mut samples = Vec::new();
            for seed in 0..4 {
                let (_, rx) = c.submit(req(SamplerSpec::Asd(8), seed));
                let r = rx.recv().unwrap();
                assert!(r.error.is_none());
                samples.extend(r.sample);
            }
            let occ = c.metrics().mean_shard_occupancy;
            c.shutdown();
            (samples, occ)
        };
        let (inline, occ1) = serve(PoolConfig::default());
        let (sharded, occ4) =
            serve(PoolConfig { pool_size: 4, shard_min: 1 });
        let bits = |v: &[f64]| -> Vec<u64> {
            v.iter().map(|x| x.to_bits()).collect()
        };
        assert_eq!(bits(&inline), bits(&sharded));
        assert!((occ1 - 1.0).abs() < 1e-12, "inline occupancy {occ1}");
        assert!(occ4 > 1.0, "sharded occupancy {occ4}");
    }

    /// Test model that fails every denoise call while `faulty` is
    /// raised — a controllable fault source for the breaker test.
    struct FlakyModel {
        sched: DdpmSchedule,
        faulty: Arc<AtomicBool>,
    }

    impl crate::model::DenoiseModel for FlakyModel {
        fn dim(&self) -> usize {
            1
        }
        fn cond_dim(&self) -> usize {
            0
        }
        fn k_steps(&self) -> usize {
            self.sched.k_steps
        }
        fn schedule(&self) -> &DdpmSchedule {
            &self.sched
        }
        fn denoise_batch(&self, _ys: &[f64], _ts: &[f64], _cond: &[f64],
                         n: usize, out: &mut [f64]) -> Result<()> {
            anyhow::ensure!(!self.faulty.load(Ordering::SeqCst),
                            "injected flaky model failure");
            out[..n].fill(0.0);
            Ok(())
        }
    }

    #[test]
    fn breaker_rejects_while_open_and_recovers_after_cooldown() {
        use std::time::Duration;
        let faulty = Arc::new(AtomicBool::new(true));
        let c = Coordinator::new(ServerConfig {
            workers: 1,
            max_batch: 4,
            enable_batching: true,
            recovery: RecoveryPolicy {
                retry_max: 0,
                backoff_rounds: 0,
                breaker_threshold: 1,
                breaker_cooldown: Duration::from_millis(300),
                validate_outputs: true,
            },
            ..Default::default()
        }).unwrap();
        c.register_model("flaky", Arc::new(FlakyModel {
            sched: DdpmSchedule::new(4),
            faulty: faulty.clone(),
        }));
        let mk = |seed| Request {
            id: 0,
            variant: "flaky".into(),
            sampler: SamplerSpec::Sequential,
            seed,
            cond: vec![],
            deadline: None,
        };
        // the first request faults its round and trips the breaker
        // (threshold 1, no retries)
        let (_, rx) = c.submit(mk(1));
        let r = rx.recv().unwrap();
        assert!(r.error.unwrap().contains("injected"), "first failure");
        // while the breaker is open, admissions bounce with a distinct
        // reason (admitted-and-failed rounds in a half-open probe keep
        // reopening it, so SOME submission must observe BreakerOpen)
        let mut saw_open = false;
        for seed in 2..120 {
            let (_, rx) = c.submit(mk(seed));
            let r = rx.recv().unwrap();
            if r.reason == Some(FailReason::BreakerOpen) {
                assert!(r.rejected);
                assert!(r.error.unwrap().contains("breaker"));
                saw_open = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(saw_open, "breaker never rejected an admission");
        // heal the model and wait past the cooldown: the half-open
        // probe must succeed and close the breaker — no lane is
        // permanently stranded
        faulty.store(false, Ordering::SeqCst);
        let mut recovered = false;
        for seed in 200..260 {
            std::thread::sleep(Duration::from_millis(10));
            let (_, rx) = c.submit(mk(seed));
            if rx.recv().unwrap().error.is_none() {
                recovered = true;
                break;
            }
        }
        assert!(recovered, "lane stayed stranded after cooldown");
        let m = c.metrics();
        assert!(m.breaker_trips >= 1, "trips {}", m.breaker_trips);
        let lane = m.lane("flaky").unwrap();
        assert!(lane.breaker_trips >= 1);
        assert!(lane.rejected >= 1);
        c.shutdown();
    }

    #[test]
    fn reload_variant_swaps_snapshots_without_dropping_requests() {
        let c = Coordinator::new(ServerConfig {
            workers: 1,
            max_batch: 8,
            enable_batching: true,
            ..Default::default()
        }).unwrap();
        let oracle = || GmmDdpmOracle::new(Gmm::circle_2d(), 60, false);
        c.register_model("gmm", oracle());
        let rxs: Vec<_> = (0..8)
            .map(|s| c.submit(req(SamplerSpec::Sequential, s)).1)
            .collect();
        // swap in an identical-weights snapshot mid-burst: every
        // in-flight request must complete, and (same weights) the
        // swap must be bit-invisible in the samples
        c.reload_variant("gmm", oracle()).unwrap();
        let bits = |v: &[f64]| -> Vec<u64> {
            v.iter().map(|x| x.to_bits()).collect()
        };
        for (seed, rx) in (0..8u64).zip(rxs) {
            let r = rx.recv().unwrap();
            assert!(r.error.is_none(), "{:?}", r.error);
            let (solo, _) = SequentialSampler::new(oracle())
                .sample(seed, &[]).unwrap();
            assert_eq!(bits(&r.sample), bits(&solo), "seed {seed}");
        }
        let m = c.metrics();
        assert_eq!(m.completed, 8);
        assert_eq!(m.reloads, 1);
        // geometry mismatch is a clean error, not a corrupted lane
        let err = c.reload_variant(
            "gmm", GmmDdpmOracle::new(Gmm::random(3, 4, 1.5, 9), 60,
                                      false)).err().expect("must reject");
        assert!(err.to_string().contains("geometry mismatch"), "{err:#}");
        assert!(c.reload_variant("nope", oracle()).is_err());
        c.shutdown();
    }

    #[test]
    fn drain_refuses_new_work_and_waits_out_in_flight() {
        let c = coordinator_with_oracle(2);
        let rxs: Vec<_> = (0..6)
            .map(|s| c.submit(req(SamplerSpec::Sequential, s)).1)
            .collect();
        c.drain();
        // drain returned: everything accepted beforehand was already
        // answered — zero drops
        for rx in rxs {
            let r = rx.try_recv()
                .expect("drain returned before a response landed");
            assert!(r.error.is_none(), "{:?}", r.error);
        }
        // new work bounces with the draining reason
        let (_, rx) = c.submit(req(SamplerSpec::Sequential, 99));
        let r = rx.recv().unwrap();
        assert!(r.rejected);
        assert_eq!(r.reason, Some(FailReason::Draining));
        // resume re-opens admissions on the same workers
        c.resume();
        let (_, rx) = c.submit(req(SamplerSpec::Sequential, 100));
        assert!(rx.recv().unwrap().error.is_none());
        assert_eq!(c.metrics().completed, 7);
        c.shutdown();
    }

    #[test]
    fn in_flight_deadline_is_swept_at_a_round_boundary() {
        use std::time::Duration;
        let c = Coordinator::new(ServerConfig {
            workers: 1,
            max_batch: 4,
            enable_batching: true,
            ..Default::default()
        }).unwrap();
        c.register_model("slow", Arc::new(SlowModel {
            sched: DdpmSchedule::new(60),
            delay: Duration::from_millis(4),
        }));
        // 60 rounds x 4ms >> the 60ms budget: the request is admitted
        // quickly, then cancelled at a round boundary mid-sample
        let (_, rx) = c.submit(Request {
            id: 0,
            variant: "slow".into(),
            sampler: SamplerSpec::Sequential,
            seed: 1,
            cond: vec![],
            deadline: Some(Duration::from_millis(60)),
        });
        let r = rx.recv().unwrap();
        assert!(!r.rejected, "timeout is a failure, not a rejection");
        assert_eq!(r.reason, Some(FailReason::Timeout));
        assert!(r.error.unwrap().contains("deadline"));
        let m = c.metrics();
        assert_eq!(m.timed_out, 1);
        assert_eq!(m.cancelled, 1);
        assert_eq!(m.failed, 1);
        c.shutdown();
    }
}
