//! The coordinator: owns the queue, worker pool and model registry.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;


use crate::asd::AsdEngine;
use crate::coordinator::batcher::{next_work_item, take_compatible_prefix,
                                  WorkItem};
use crate::coordinator::fusion::FusionScheduler;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{QueuedJob, Request, Response, SamplerSpec};
use crate::ddpm::SequentialSampler;
use crate::model::{DenoiseModel, ParallelModel};
use crate::picard::PicardSampler;
use crate::runtime::pool::PoolConfig;

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub workers: usize,
    /// fuse at most this many concurrent requests into one round-
    /// synchronous group (any sampler mix; see `coordinator::fusion`)
    pub max_batch: usize,
    pub enable_batching: bool,
    /// bounded admission: submissions beyond this queue depth are
    /// answered immediately with a rejected [`Response`] instead of
    /// growing the queue without limit
    pub max_queue_depth: usize,
    /// sharding config for every batched denoise call served by this
    /// coordinator (each fusion group's fused round, or the per-request
    /// batched calls when batching is disabled). All workers share the
    /// ONE global pool — worker threads gate concurrency at the request
    /// level, the pool at the row level, so cores are never
    /// oversubscribed. Bit-transparency holds for native
    /// row-independent models; HLO models may shift within f32 padding
    /// tolerance (see `model::parallel`).
    pub pool: PoolConfig,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            workers: 2,
            max_batch: 8,
            enable_batching: true,
            max_queue_depth: 1024,
            pool: PoolConfig::default(),
        }
    }
}

struct Shared {
    queue: Mutex<VecDeque<QueuedJob>>,
    cv: Condvar,
    shutdown: AtomicBool,
    metrics: Metrics,
    models: Mutex<HashMap<String, Arc<dyn DenoiseModel>>>,
    config: ServerConfig,
    next_id: AtomicU64,
}

/// The serving coordinator. Models are registered up front (they wrap
/// either HLO executables or the native oracle); requests are submitted
/// from any thread and answered over per-request channels.
pub struct Coordinator {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    pub fn new(config: ServerConfig) -> Coordinator {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            metrics: Metrics::default(),
            models: Mutex::new(HashMap::new()),
            config: config.clone(),
            next_id: AtomicU64::new(1),
        });
        let mut handles = Vec::new();
        for w in 0..config.workers.max(1) {
            let s = shared.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("asd-worker-{w}"))
                    .spawn(move || worker_loop(s))
                    .expect("spawn worker"),
            );
        }
        Coordinator { shared, handles }
    }

    pub fn register_model(&self, name: &str, model: Arc<dyn DenoiseModel>) {
        self.shared
            .models
            .lock()
            .unwrap()
            .insert(name.to_string(), model);
    }

    pub fn has_model(&self, name: &str) -> bool {
        self.shared.models.lock().unwrap().contains_key(name)
    }

    /// Submit a request; returns the response channel and the assigned
    /// id. When the queue is at `max_queue_depth` the request is not
    /// enqueued: a rejected [`Response`] is delivered on the channel
    /// immediately (bounded admission — a loaded coordinator sheds
    /// traffic instead of accumulating unbounded latency).
    pub fn submit(&self, mut request: Request) -> (u64, Receiver<Response>) {
        let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
        request.id = id;
        let (tx, rx) = channel();
        self.shared.metrics.on_submit();
        {
            let mut q = self.shared.queue.lock().unwrap();
            let depth = q.len();
            if depth >= self.shared.config.max_queue_depth {
                drop(q);
                self.shared.metrics.on_reject();
                let _ = tx.send(Response::rejected(
                    id, depth, self.shared.config.max_queue_depth));
                return (id, rx);
            }
            q.push_back(QueuedJob {
                request,
                reply: tx,
                enqueued: Instant::now(),
            });
        }
        self.shared.cv.notify_one();
        (id, rx)
    }

    pub fn metrics(&self) -> crate::coordinator::MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    pub fn queue_depth(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    pub fn shutdown(mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let item = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                match next_work_item(&mut q, shared.config.max_batch,
                                     shared.config.enable_batching) {
                    Some(item) => break item,
                    None => q = shared.cv.wait(q).unwrap(),
                }
            }
        };
        match item {
            WorkItem::Single(job) => serve_single(&shared, job),
            WorkItem::Fused(group) => serve_fused(&shared, group),
        }
    }
}

fn model_for(shared: &Shared, variant: &str) -> Option<Arc<dyn DenoiseModel>> {
    shared.models.lock().unwrap().get(variant).cloned()
}

fn serve_single(shared: &Shared, job: QueuedJob) {
    let queued_s = job.enqueued.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let req = &job.request;
    let outcome = match model_for(shared, &req.variant) {
        None => Err(format!("unknown model '{}'", req.variant)),
        Some(model) => run_sampler(model, req, shared.config.pool),
    };
    let service_s = t0.elapsed().as_secs_f64();
    if let Ok((_, _, _, Some(st))) = &outcome {
        shared.metrics.on_round_stats(&st.round_latency_s, &st.round_shards);
    }
    let resp = match outcome {
        Ok((sample, calls, rounds, asd_stats)) => Response {
            id: req.id,
            sample,
            model_calls: calls,
            parallel_rounds: rounds,
            asd_stats,
            queued_s,
            service_s,
            rejected: false,
            error: None,
        },
        Err(e) => Response {
            service_s,
            ..Response::failed(req.id, queued_s, &e)
        },
    };
    shared.metrics.on_complete(queued_s, service_s, resp.model_calls,
                               resp.parallel_rounds, resp.error.is_some());
    let _ = job.reply.send(resp);
}

type SampleOutcome =
    std::result::Result<(Vec<f64>, usize, usize, Option<crate::asd::AsdStats>), String>;

fn run_sampler(model: Arc<dyn DenoiseModel>, req: &Request,
               pool: PoolConfig) -> SampleOutcome {
    match req.sampler {
        SamplerSpec::Sequential => {
            let sampler = SequentialSampler::new(model);
            sampler
                .sample(req.seed, &req.cond)
                .map(|(y, st)| (y, st.model_calls, st.model_calls, None))
                .map_err(|e| e.to_string())
        }
        SamplerSpec::Asd(theta) => {
            // canonical config shared with the fused path — see
            // SamplerSpec::asd_config
            let mut engine = AsdEngine::new(
                model, SamplerSpec::asd_config(theta, pool));
            engine
                .sample_cond(req.seed, &req.cond)
                .map(|out| {
                    let calls = out.stats.model_calls;
                    let rounds = out.stats.parallel_rounds;
                    (out.y0, calls, rounds, Some(out.stats))
                })
                .map_err(|e| e.to_string())
        }
        SamplerSpec::Picard(window, tol) => {
            let sampler = PicardSampler::new(
                model, SamplerSpec::picard_config(window, tol, pool));
            sampler
                .sample(req.seed, &req.cond)
                .map(|(y, st)| (y, st.model_calls, st.parallel_rounds, None))
                .map_err(|e| e.to_string())
        }
    }
}

/// Serve a fusion group round-synchronously: every tick collects each
/// in-flight request's row demand, runs ONE fused `denoise_batch`, and
/// scatters results. Between ticks the worker absorbs newly queued
/// same-variant requests from the *front* of the shared queue
/// (continuous batching) — only the front, so requests for other
/// variants are never overtaken (see `batcher::take_compatible_prefix`).
fn serve_fused(shared: &Shared, group: Vec<QueuedJob>) {
    let variant = group[0].request.variant.clone();
    let model = match model_for(shared, &variant) {
        Some(m) => m,
        None => {
            let msg = format!("unknown model '{variant}'");
            for job in group {
                fail_job(shared, job, &msg);
            }
            return;
        }
    };
    // one ParallelModel wrapper for the whole group: fused rounds shard
    // on the global pool exactly like solo engines' batched rounds
    let model = ParallelModel::wrap(model, shared.config.pool);
    let mut sched = FusionScheduler::new(model, shared.config.pool);
    // `counted` tracks whether this group has been recorded as a batch:
    // a singleton group only becomes one when admission grows it, at
    // which point its founding member(s) must be counted too.
    let mut counted = group.len() >= 2;
    if counted {
        shared.metrics.on_batch(group.len());
    }
    for job in group {
        sched.admit(job, &shared.metrics);
    }
    while !sched.is_empty() {
        // continuous admission: absorb compatible front-of-queue
        // arrivals up to the fusion cap
        let room = shared.config.max_batch.saturating_sub(sched.len());
        if room > 0 {
            let mut admitted = Vec::new();
            {
                let mut q = shared.queue.lock().unwrap();
                take_compatible_prefix(&mut q, &variant, room, &mut admitted);
            }
            if !admitted.is_empty() {
                if counted {
                    shared.metrics.on_fused_admit(admitted.len());
                } else {
                    shared.metrics.on_batch(sched.len() + admitted.len());
                    counted = true;
                }
                for job in admitted {
                    sched.admit(job, &shared.metrics);
                }
            }
        }
        sched.tick(&shared.metrics);
    }
}

fn fail_job(shared: &Shared, job: QueuedJob, msg: &str) {
    let queued_s = job.enqueued.elapsed().as_secs_f64();
    shared.metrics.on_complete(queued_s, 0.0, 0, 0, true);
    let _ = job.reply.send(Response::failed(job.request.id, queued_s, msg));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Gmm, GmmDdpmOracle};
    use crate::schedule::DdpmSchedule;
    use anyhow::Result;

    fn coordinator_with_oracle(workers: usize) -> Coordinator {
        let c = Coordinator::new(ServerConfig {
            workers,
            max_batch: 4,
            enable_batching: true,
            ..Default::default()
        });
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 40, false);
        c.register_model("gmm", oracle);
        c
    }

    fn req(sampler: SamplerSpec, seed: u64) -> Request {
        Request {
            id: 0,
            variant: "gmm".into(),
            sampler,
            seed,
            cond: vec![],
        }
    }

    #[test]
    fn serves_sequential_and_asd() {
        let c = coordinator_with_oracle(2);
        let (_, rx1) = c.submit(req(SamplerSpec::Sequential, 1));
        let (_, rx2) = c.submit(req(SamplerSpec::Asd(8), 2));
        let r1 = rx1.recv().unwrap();
        let r2 = rx2.recv().unwrap();
        assert!(r1.error.is_none() && r2.error.is_none());
        assert_eq!(r1.sample.len(), 2);
        assert_eq!(r1.model_calls, 40);
        assert!(r2.parallel_rounds < 40);
        assert!(r2.asd_stats.is_some());
        c.shutdown();
    }

    #[test]
    fn unknown_model_fails_cleanly() {
        let c = coordinator_with_oracle(1);
        let (_, rx) = c.submit(Request {
            id: 0,
            variant: "nope".into(),
            sampler: SamplerSpec::Sequential,
            seed: 0,
            cond: vec![],
        });
        let r = rx.recv().unwrap();
        assert!(r.error.unwrap().contains("unknown model"));
        let m = c.metrics();
        assert_eq!(m.failed, 1);
    }

    #[test]
    fn burst_of_sequential_requests_batches() {
        let c = coordinator_with_oracle(1);
        let rxs: Vec<_> = (0..8)
            .map(|s| c.submit(req(SamplerSpec::Sequential, s)).1)
            .collect();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!(r.error.is_none());
        }
        let m = c.metrics();
        assert_eq!(m.completed, 8);
        // at least one gang formed (worker races may split the burst)
        assert!(m.batched_requests >= 2, "batched {}", m.batched_requests);
        c.shutdown();
    }

    #[test]
    fn picard_request_works() {
        let c = coordinator_with_oracle(1);
        let (_, rx) = c.submit(req(SamplerSpec::Picard(8, 1e-6), 3));
        let r = rx.recv().unwrap();
        assert!(r.error.is_none());
        assert!(r.parallel_rounds >= 5);
        c.shutdown();
    }

    /// Test model whose denoise calls block until the gate opens —
    /// lets a test hold a worker busy so the queue actually fills.
    struct GatedModel {
        sched: DdpmSchedule,
        gate: Arc<(Mutex<bool>, Condvar)>,
    }

    impl GatedModel {
        fn open(gate: &Arc<(Mutex<bool>, Condvar)>) {
            let (lock, cv) = gate.as_ref();
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
    }

    impl crate::model::DenoiseModel for GatedModel {
        fn dim(&self) -> usize {
            1
        }
        fn cond_dim(&self) -> usize {
            0
        }
        fn k_steps(&self) -> usize {
            self.sched.k_steps
        }
        fn schedule(&self) -> &DdpmSchedule {
            &self.sched
        }
        fn denoise_batch(&self, _ys: &[f64], _ts: &[f64], _cond: &[f64],
                         n: usize, out: &mut [f64]) -> Result<()> {
            let (lock, cv) = self.gate.as_ref();
            let mut open = lock.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
            out[..n].fill(0.0);
            Ok(())
        }
    }

    #[test]
    fn bounded_admission_rejects_when_queue_is_full() {
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let c = Coordinator::new(ServerConfig {
            workers: 1,
            max_batch: 1, // no fusion: the worker blocks on one request
            enable_batching: true,
            max_queue_depth: 2,
            ..Default::default()
        });
        c.register_model("gated", Arc::new(GatedModel {
            sched: DdpmSchedule::new(2),
            gate: gate.clone(),
        }));
        let req = |seed| Request {
            id: 0,
            variant: "gated".into(),
            sampler: SamplerSpec::Sequential,
            seed,
            cond: vec![],
        };
        // r1 is picked up by the worker and blocks inside the model
        let (_, rx1) = c.submit(req(1));
        for _ in 0..200 {
            if c.queue_depth() == 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(c.queue_depth(), 0, "worker never picked up r1");
        // r2, r3 fill the queue to max_queue_depth
        let (_, rx2) = c.submit(req(2));
        let (_, rx3) = c.submit(req(3));
        assert_eq!(c.queue_depth(), 2);
        // r4 must be rejected immediately, without blocking
        let (_, rx4) = c.submit(req(4));
        let r4 = rx4.recv().unwrap();
        assert!(r4.rejected);
        assert!(r4.error.unwrap().contains("max_queue_depth"));
        let m = c.metrics();
        assert_eq!(m.rejected, 1);
        // open the gate: the admitted requests all complete
        GatedModel::open(&gate);
        for rx in [rx1, rx2, rx3] {
            let r = rx.recv().unwrap();
            assert!(!r.rejected);
            assert!(r.error.is_none(), "{:?}", r.error);
        }
        assert_eq!(c.metrics().completed, 3);
        c.shutdown();
    }

    #[test]
    fn mixed_samplers_fuse_into_mega_rounds() {
        // one worker, burst of all three sampler kinds on one variant:
        // the coordinator must fuse their rounds (rows/round > 1)
        let c = Coordinator::new(ServerConfig {
            workers: 1,
            max_batch: 16,
            enable_batching: true,
            ..Default::default()
        });
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 60, false);
        c.register_model("gmm", oracle);
        let rxs: Vec<_> = (0..9)
            .map(|i| {
                let sampler = match i % 3 {
                    0 => SamplerSpec::Sequential,
                    1 => SamplerSpec::Asd(8),
                    _ => SamplerSpec::Picard(8, 1e-6),
                };
                c.submit(req(sampler, 100 + i as u64)).1
            })
            .collect();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!(r.error.is_none(), "{:?}", r.error);
        }
        let m = c.metrics();
        assert_eq!(m.completed, 9);
        assert!(m.fused_rounds > 0);
        assert!(m.fused_rows_per_round > 1.0,
                "rows/round {}", m.fused_rows_per_round);
        c.shutdown();
    }

    #[test]
    fn shutdown_joins_workers() {
        let c = coordinator_with_oracle(3);
        let (_, rx) = c.submit(req(SamplerSpec::Sequential, 9));
        rx.recv().unwrap();
        c.shutdown(); // must not hang
    }

    #[test]
    fn sharded_pool_serves_identical_samples_and_records_occupancy() {
        let serve = |pool: PoolConfig| -> (Vec<f64>, f64) {
            let c = Coordinator::new(ServerConfig {
                workers: 2,
                max_batch: 4,
                enable_batching: true,
                pool,
                ..Default::default()
            });
            let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 40, false);
            c.register_model("gmm", oracle);
            let mut samples = Vec::new();
            for seed in 0..4 {
                let (_, rx) = c.submit(req(SamplerSpec::Asd(8), seed));
                let r = rx.recv().unwrap();
                assert!(r.error.is_none());
                samples.extend(r.sample);
            }
            let occ = c.metrics().mean_shard_occupancy;
            c.shutdown();
            (samples, occ)
        };
        let (inline, occ1) = serve(PoolConfig::default());
        let (sharded, occ4) =
            serve(PoolConfig { pool_size: 4, shard_min: 1 });
        let bits = |v: &[f64]| -> Vec<u64> {
            v.iter().map(|x| x.to_bits()).collect()
        };
        assert_eq!(bits(&inline), bits(&sharded));
        assert!((occ1 - 1.0).abs() < 1e-12, "inline occupancy {occ1}");
        assert!(occ4 > 1.0, "sharded occupancy {occ4}");
    }
}
