//! Serving metrics: global counters + latency aggregates, plus
//! per-lane aggregates (one lane per served variant — see
//! `coordinator::lanes`). Cheap to update from every worker (single
//! short-lived mutex; the hot path does sampling, not metric churn).

use std::collections::BTreeMap;
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Instant;

use crate::math::stats::Welford;
use crate::runtime::pool::{self, PoolStats};

/// Per-lane (per-variant) round aggregates: how saturated each lane's
/// fused rounds run, how long its requests queue, and the elapsed-time
/// window its rounds executed in. Overlapping windows across lanes are
/// the observable proof that two variants' rounds ran concurrently
/// (continuous round tasks on the shared pool) instead of behind each
/// other.
#[derive(Debug, Default)]
struct LaneAgg {
    fused_rounds: u64,
    fused_rows: u64,
    /// requests contributing rows, per round
    requests: Welford,
    /// worker-pool shards per round
    shards: Welford,
    /// estimated time lost to intra-round pool fork/join barriers per
    /// round (ms): `latency * barriers / (barriers + 1)` — the
    /// equal-phase-cost upper estimate of layer-boundary idling.
    /// Identically 0 on the graph path (zero barriers by construction)
    layer_stall: Welford,
    /// queue wait at lane admission (ms)
    queue_wait: Welford,
    admitted: u64,
    /// elapsed seconds (since coordinator start) of the first/last
    /// fused round this lane executed
    first_round_s: f64,
    last_round_s: f64,
    /// largest round-arena footprint (staging buffers + GEMM
    /// workspace) this lane ever reported, bytes
    arena_high_water_bytes: u64,
    /// GRS verifier outcomes across this lane's speculative requests
    /// (ASD and draft-SD): transitions accepted / rejected, and the
    /// speculation windows they were scanned in
    accepted_steps: u64,
    rejected_steps: u64,
    grs_windows: u64,
    /// failure-domain counters (see `fusion::RecoveryPolicy`): requests
    /// turned away at this lane's admission gate (breaker open),
    /// deadline expiries, in-flight cancellations, granted retries,
    /// circuit-breaker trips and model hot-reloads
    rejected: u64,
    timed_out: u64,
    cancelled: u64,
    retried: u64,
    breaker_trips: u64,
    reloads: u64,
}

#[derive(Debug, Default)]
struct Inner {
    submitted: u64,
    /// turned away by bounded admission (`max_queue_depth`)
    rejected: u64,
    completed: u64,
    failed: u64,
    batched_groups: u64,
    batched_requests: u64,
    queue_wait: Welford,
    service: Welford,
    model_calls: u64,
    parallel_rounds: u64,
    /// measured per-round model-call latency (ms) across ASD requests
    round_latency: Welford,
    /// worker-pool shard occupancy per round (1 = ran inline)
    shard_occupancy: Welford,
    /// fused coordinator rounds (one mega denoise call per lane tick)
    fused_rounds: u64,
    /// total rows across all fused rounds
    fused_rows: u64,
    /// requests contributing rows, per fused round
    fused_requests: Welford,
    /// worker-pool shards per fused round
    fused_shards: Welford,
    /// GRS verifier outcomes across all speculative requests (ASD and
    /// draft-SD) this coordinator served
    accepted_steps: u64,
    rejected_steps: u64,
    grs_windows: u64,
    /// requests whose deadline expired before completion (at admission
    /// or at a round boundary)
    timed_out: u64,
    /// in-flight requests cancelled at a round boundary (deadline
    /// sweep); a timeout caught at admission cancels nothing
    cancelled: u64,
    /// from-scratch retries granted after faulted fused rounds
    retried: u64,
    /// lane circuit-breaker trips (closed/half-open -> open)
    breaker_trips: u64,
    /// variant model hot-reloads (`Coordinator::reload_variant`)
    reloads: u64,
    /// per-variant lane aggregates
    lanes: BTreeMap<String, LaneAgg>,
}

#[derive(Debug)]
pub struct Metrics {
    inner: Mutex<Inner>,
    /// coordinator birth — the zero point of the per-lane round windows
    started: Instant,
    /// global-pool counters at coordinator birth: snapshots report the
    /// delta, i.e. this coordinator's share of scheduler activity
    /// (other pool users in the same process inflate it — the counters
    /// are process-global — so treat the values as lower-bounded
    /// activity, not an exact attribution)
    pool_base: PoolStats,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics {
            inner: Mutex::new(Inner::default()),
            started: Instant::now(),
            // global_stats() reads counters without spawning the pool:
            // a coordinator that never runs a fused round never forces
            // worker threads into existence
            pool_base: pool::global_stats(),
        }
    }
}

/// One lane's aggregates in a [`MetricsSnapshot`].
#[derive(Debug, Clone)]
pub struct LaneSnapshot {
    /// the variant this lane serves
    pub lane: String,
    pub fused_rounds: u64,
    /// mean rows per fused round on this lane (> 1 = cross-request
    /// fusion on this lane)
    pub fused_rows_per_round: f64,
    pub mean_requests_per_round: f64,
    /// mean worker-pool shard occupancy of this lane's rounds
    pub occupancy: f64,
    /// mean estimated time per round lost to intra-round pool
    /// fork/join barriers (ms): `latency * barriers / (barriers + 1)`
    /// per round. Identically 0 when every round ran the barrier-free
    /// tile-graph path
    pub mean_layer_stall_ms: f64,
    /// mean queue wait of requests admitted to this lane (ms)
    pub mean_queue_wait_ms: f64,
    /// requests admitted into this lane's fused scheduler
    pub admitted: u64,
    /// elapsed ms (since coordinator start) of the lane's first fused
    /// round — with `last_round_ms` this is the lane's activity window
    pub first_round_ms: f64,
    pub last_round_ms: f64,
    /// high-water bytes of this lane's round arena (staging buffers +
    /// GEMM workspace) — what a burst leaves resident until the lane
    /// drains past `ServerConfig::arena_byte_cap` and releases
    pub arena_high_water_bytes: u64,
    /// GRS-accepted transitions across this lane's speculative requests
    /// (ASD and draft-SD)
    pub accepted_steps: u64,
    /// GRS-rejected transitions (each reject ends its window and costs
    /// a re-speculation)
    pub rejected_steps: u64,
    /// mean accepted transitions per speculation window — the observed
    /// accept-run length the speedup theorems price in
    pub mean_accept_run: f64,
    /// requests turned away at this lane's admission gate (circuit
    /// breaker open)
    pub rejected: u64,
    /// requests on this lane whose deadline expired
    pub timed_out: u64,
    /// in-flight requests cancelled at a round boundary by the
    /// deadline sweep
    pub cancelled: u64,
    /// from-scratch retries granted on this lane after faulted rounds
    pub retried: u64,
    /// circuit-breaker trips on this lane
    pub breaker_trips: u64,
    /// model hot-reloads applied to this lane
    pub reloads: u64,
}

impl LaneSnapshot {
    /// Whether this lane's round window overlaps `other`'s — i.e. both
    /// lanes made progress within the same tick window.
    pub fn overlaps(&self, other: &LaneSnapshot) -> bool {
        self.fused_rounds > 0
            && other.fused_rounds > 0
            && self.first_round_ms <= other.last_round_ms
            && other.first_round_ms <= self.last_round_ms
    }
}

#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub batched_groups: u64,
    pub batched_requests: u64,
    pub mean_queue_wait_ms: f64,
    pub mean_service_ms: f64,
    pub p_like_max_service_ms: f64,
    pub model_calls: u64,
    pub parallel_rounds: u64,
    /// rounds with measured latency recorded (ASD requests)
    pub rounds_measured: u64,
    pub mean_round_latency_ms: f64,
    pub mean_shard_occupancy: f64,
    /// fused coordinator rounds executed (one mega-call per lane tick)
    pub fused_rounds: u64,
    /// mean rows per fused round — the batch the kernels actually see;
    /// > 1 means cross-request fusion is happening
    pub fused_rows_per_round: f64,
    /// mean requests contributing to each fused round
    pub mean_fused_requests_per_round: f64,
    /// mean worker-pool shard occupancy of fused rounds
    pub fused_occupancy: f64,
    /// GRS-accepted transitions across all speculative requests served
    pub accepted_steps: u64,
    /// GRS-rejected transitions across all speculative requests served
    pub rejected_steps: u64,
    /// mean accepted transitions per speculation window
    pub mean_accept_run: f64,
    /// requests whose deadline expired before completion (these also
    /// count in `failed` when they were already in flight)
    pub timed_out: u64,
    /// in-flight requests cancelled at a round boundary (deadline
    /// sweep)
    pub cancelled: u64,
    /// from-scratch retries granted after faulted fused rounds
    pub retried: u64,
    /// lane circuit-breaker trips
    pub breaker_trips: u64,
    /// variant model hot-reloads
    pub reloads: u64,
    /// per-variant lane aggregates, sorted by lane name
    pub lanes: Vec<LaneSnapshot>,
    /// work-stealing scheduler activity since coordinator start
    /// (entries executed / stolen across deques / pushed through the
    /// injector / lane round tasks), from the process-global pool
    /// counters — see `runtime::pool::PoolStats`
    pub pool: PoolStats,
}

impl MetricsSnapshot {
    /// The lane snapshot for `variant`, if it ever admitted a request.
    pub fn lane(&self, variant: &str) -> Option<&LaneSnapshot> {
        self.lanes.iter().find(|l| l.lane == variant)
    }
}

impl Metrics {
    /// Lock the aggregate table, recovering from poisoning: a worker
    /// that panics while holding the metrics mutex must not take every
    /// other worker's metric updates (and `snapshot`) down with it —
    /// the aggregates are plain counters, valid at every intermediate
    /// state.
    fn lock(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    pub fn on_submit(&self) {
        self.lock().submitted += 1;
    }

    /// Bounded admission turned a request away.
    pub fn on_reject(&self) {
        self.lock().rejected += 1;
    }

    /// One fused round on `lane`: `rows` total rows from `requests`
    /// in-flight requests, executed as `shards` pool shards through
    /// `barriers` intra-round pool fork/joins (0 = the barrier-free
    /// graph path) in `latency_s` seconds, while the lane's round
    /// arena held `arena_bytes` at its high-water mark.
    #[allow(clippy::too_many_arguments)]
    pub fn on_fused_round(&self, lane: &str, rows: usize, requests: usize,
                          shards: usize, barriers: usize, latency_s: f64,
                          arena_bytes: usize) {
        let now_s = self.started.elapsed().as_secs_f64();
        // equal-phase-cost estimate of time spent re-gathering the pool
        // at layer boundaries: b barriers split a round into b+1 joined
        // phases, each join idling the stragglers' gap
        let stall_ms =
            latency_s * 1e3 * barriers as f64 / (barriers + 1) as f64;
        let mut m = self.lock();
        m.fused_rounds += 1;
        m.fused_rows += rows as u64;
        m.fused_requests.push(requests as f64);
        m.fused_shards.push(shards as f64);
        let agg = lane_agg(&mut m, lane);
        if agg.fused_rounds == 0 {
            agg.first_round_s = now_s;
        }
        agg.last_round_s = now_s;
        agg.fused_rounds += 1;
        agg.fused_rows += rows as u64;
        agg.requests.push(requests as f64);
        agg.shards.push(shards as f64);
        agg.layer_stall.push(stall_ms);
        agg.arena_high_water_bytes =
            agg.arena_high_water_bytes.max(arena_bytes as u64);
    }

    /// A request entered `lane`'s fused scheduler after waiting
    /// `queued_s` in the admission queue.
    pub fn on_lane_admit(&self, lane: &str, queued_s: f64) {
        let mut m = self.lock();
        let agg = lane_agg(&mut m, lane);
        agg.admitted += 1;
        agg.queue_wait.push(queued_s * 1e3);
    }

    pub fn on_complete(&self, queued_s: f64, service_s: f64,
                       model_calls: usize, rounds: usize, failed: bool) {
        let mut m = self.lock();
        if failed {
            m.failed += 1;
        } else {
            m.completed += 1;
        }
        m.queue_wait.push(queued_s * 1e3);
        m.service.push(service_s * 1e3);
        m.model_calls += model_calls as u64;
        m.parallel_rounds += rounds as u64;
    }

    pub fn on_batch(&self, group_size: usize) {
        let mut m = self.lock();
        m.batched_groups += 1;
        m.batched_requests += group_size as u64;
    }

    /// Continuous admission added `n` requests to an in-flight fusion
    /// group (they batch with the group but don't form a new one).
    pub fn on_fused_admit(&self, n: usize) {
        self.lock().batched_requests += n as u64;
    }

    /// Record a speculative request's GRS verifier outcome on `lane`:
    /// `accepted` / `rejected` transitions scanned across `windows`
    /// speculation windows (from `AsdStats` — ASD and draft-SD both
    /// report here; sequential and Picard requests never do).
    pub fn on_grs_stats(&self, lane: &str, accepted: usize, rejected: usize,
                        windows: usize) {
        let mut m = self.lock();
        m.accepted_steps += accepted as u64;
        m.rejected_steps += rejected as u64;
        m.grs_windows += windows as u64;
        let agg = lane_agg(&mut m, lane);
        agg.accepted_steps += accepted as u64;
        agg.rejected_steps += rejected as u64;
        agg.grs_windows += windows as u64;
    }

    /// A request's deadline expired on `lane`. `in_flight` says whether
    /// it was already sampling (cancelled at a round boundary, arena
    /// rows reclaimed) or still queued at admission; only the former
    /// counts as a cancellation. Timed-out requests also flow through
    /// `on_complete(failed = true)`, so `failed` includes them.
    pub fn on_timeout(&self, lane: &str, in_flight: bool) {
        let mut m = self.lock();
        m.timed_out += 1;
        if in_flight {
            m.cancelled += 1;
        }
        let agg = lane_agg(&mut m, lane);
        agg.timed_out += 1;
        if in_flight {
            agg.cancelled += 1;
        }
    }

    /// A faulted fused round granted one participant a from-scratch
    /// retry (bit-transparent: machines are pure in (seed, cond)).
    pub fn on_retry(&self, lane: &str) {
        let mut m = self.lock();
        m.retried += 1;
        lane_agg(&mut m, lane).retried += 1;
    }

    /// `lane`'s circuit breaker tripped open (consecutive-failure
    /// threshold reached, or a half-open probe failed).
    pub fn on_breaker_trip(&self, lane: &str) {
        let mut m = self.lock();
        m.breaker_trips += 1;
        lane_agg(&mut m, lane).breaker_trips += 1;
    }

    /// `lane`'s model snapshot was hot-reloaded
    /// (`Coordinator::reload_variant`).
    pub fn on_reload(&self, lane: &str) {
        let mut m = self.lock();
        m.reloads += 1;
        lane_agg(&mut m, lane).reloads += 1;
    }

    /// `lane`'s admission gate turned a request away (circuit breaker
    /// open). Counts into the global `rejected` alongside bounded-queue
    /// rejections.
    pub fn on_lane_reject(&self, lane: &str) {
        let mut m = self.lock();
        m.rejected += 1;
        lane_agg(&mut m, lane).rejected += 1;
    }

    /// Record a request's measured per-round latencies and shard
    /// occupancies (from `AsdStats`).
    pub fn on_round_stats(&self, latencies_s: &[f64], shards: &[usize]) {
        let mut m = self.lock();
        for &l in latencies_s {
            m.round_latency.push(l * 1e3);
        }
        for &s in shards {
            m.shard_occupancy.push(s as f64);
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.lock();
        MetricsSnapshot {
            submitted: m.submitted,
            rejected: m.rejected,
            completed: m.completed,
            failed: m.failed,
            batched_groups: m.batched_groups,
            batched_requests: m.batched_requests,
            mean_queue_wait_ms: m.queue_wait.mean(),
            mean_service_ms: m.service.mean(),
            p_like_max_service_ms: m.service.mean() + 2.0 * m.service.std(),
            model_calls: m.model_calls,
            parallel_rounds: m.parallel_rounds,
            rounds_measured: m.round_latency.n as u64,
            mean_round_latency_ms: m.round_latency.mean(),
            mean_shard_occupancy: if m.shard_occupancy.n == 0 {
                1.0
            } else {
                m.shard_occupancy.mean()
            },
            fused_rounds: m.fused_rounds,
            fused_rows_per_round: if m.fused_rounds == 0 {
                0.0
            } else {
                m.fused_rows as f64 / m.fused_rounds as f64
            },
            mean_fused_requests_per_round: m.fused_requests.mean(),
            fused_occupancy: if m.fused_shards.n == 0 {
                1.0
            } else {
                m.fused_shards.mean()
            },
            accepted_steps: m.accepted_steps,
            rejected_steps: m.rejected_steps,
            mean_accept_run: accept_run(m.accepted_steps, m.grs_windows),
            timed_out: m.timed_out,
            cancelled: m.cancelled,
            retried: m.retried,
            breaker_trips: m.breaker_trips,
            reloads: m.reloads,
            lanes: m.lanes.iter()
                .map(|(name, a)| LaneSnapshot {
                    lane: name.clone(),
                    fused_rounds: a.fused_rounds,
                    fused_rows_per_round: if a.fused_rounds == 0 {
                        0.0
                    } else {
                        a.fused_rows as f64 / a.fused_rounds as f64
                    },
                    mean_requests_per_round: a.requests.mean(),
                    occupancy: if a.shards.n == 0 {
                        1.0
                    } else {
                        a.shards.mean()
                    },
                    mean_layer_stall_ms: a.layer_stall.mean(),
                    mean_queue_wait_ms: a.queue_wait.mean(),
                    admitted: a.admitted,
                    first_round_ms: a.first_round_s * 1e3,
                    last_round_ms: a.last_round_s * 1e3,
                    arena_high_water_bytes: a.arena_high_water_bytes,
                    accepted_steps: a.accepted_steps,
                    rejected_steps: a.rejected_steps,
                    mean_accept_run: accept_run(a.accepted_steps,
                                                a.grs_windows),
                    rejected: a.rejected,
                    timed_out: a.timed_out,
                    cancelled: a.cancelled,
                    retried: a.retried,
                    breaker_trips: a.breaker_trips,
                    reloads: a.reloads,
                })
                .collect(),
            pool: pool::global_stats().since(&self.pool_base),
        }
    }
}

/// Mean accepted transitions per speculation window (0 when no
/// speculative request has reported yet).
fn accept_run(accepted: u64, windows: u64) -> f64 {
    if windows == 0 {
        0.0
    } else {
        accepted as f64 / windows as f64
    }
}

/// The lane's aggregate slot, allocating the `String` key only on the
/// lane's very first event — every later round stays allocation-free
/// (`on_fused_round` runs once per lane per tick on the serving hot
/// path).
fn lane_agg<'a>(m: &'a mut Inner, lane: &str) -> &'a mut LaneAgg {
    if !m.lanes.contains_key(lane) {
        m.lanes.insert(lane.to_string(), LaneAgg::default());
    }
    m.lanes.get_mut(lane).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        m.on_submit();
        m.on_submit();
        m.on_complete(0.001, 0.010, 100, 50, false);
        m.on_complete(0.002, 0.020, 200, 60, true);
        m.on_batch(4);
        let s = m.snapshot();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.completed, 1);
        assert_eq!(s.failed, 1);
        assert_eq!(s.model_calls, 300);
        assert_eq!(s.parallel_rounds, 110);
        assert_eq!(s.batched_requests, 4);
        assert!((s.mean_service_ms - 15.0).abs() < 1e-9);
        // no rounds recorded yet: occupancy defaults to serial
        assert_eq!(s.rounds_measured, 0);
        assert_eq!(s.mean_shard_occupancy, 1.0);
        assert!(s.lanes.is_empty());
    }

    #[test]
    fn fused_round_and_rejection_metrics_aggregate() {
        let m = Metrics::default();
        let s0 = m.snapshot();
        assert_eq!(s0.fused_rounds, 0);
        assert_eq!(s0.fused_rows_per_round, 0.0);
        assert_eq!(s0.fused_occupancy, 1.0);
        m.on_fused_round("a", 6, 3, 2, 1, 0.010, 4096);
        m.on_fused_round("a", 2, 1, 1, 0, 0.010, 1024);
        m.on_reject();
        let s = m.snapshot();
        assert_eq!(s.fused_rounds, 2);
        assert!((s.fused_rows_per_round - 4.0).abs() < 1e-12);
        assert!((s.mean_fused_requests_per_round - 2.0).abs() < 1e-12);
        assert!((s.fused_occupancy - 1.5).abs() < 1e-12);
        assert_eq!(s.rejected, 1);
    }

    #[test]
    fn round_stats_aggregate() {
        let m = Metrics::default();
        m.on_round_stats(&[0.001, 0.003], &[1, 4]);
        m.on_round_stats(&[0.002], &[3]);
        let s = m.snapshot();
        assert_eq!(s.rounds_measured, 3);
        assert!((s.mean_round_latency_ms - 2.0).abs() < 1e-9);
        assert!((s.mean_shard_occupancy - 8.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn lane_aggregates_split_by_variant() {
        let m = Metrics::default();
        m.on_lane_admit("a", 0.002);
        m.on_lane_admit("a", 0.004);
        m.on_lane_admit("b", 0.010);
        m.on_fused_round("a", 6, 2, 2, 1, 0.008, 2048);
        m.on_fused_round("a", 4, 2, 1, 1, 0.004, 4096);
        m.on_fused_round("b", 3, 1, 1, 0, 0.002, 512);
        let s = m.snapshot();
        assert_eq!(s.lanes.len(), 2);
        let a = s.lane("a").unwrap();
        let b = s.lane("b").unwrap();
        assert_eq!(a.fused_rounds, 2);
        assert!((a.fused_rows_per_round - 5.0).abs() < 1e-12);
        assert!((a.mean_requests_per_round - 2.0).abs() < 1e-12);
        assert!((a.occupancy - 1.5).abs() < 1e-12);
        assert!((a.mean_queue_wait_ms - 3.0).abs() < 1e-9);
        assert_eq!(a.admitted, 2);
        assert_eq!(b.fused_rounds, 1);
        assert_eq!(b.admitted, 1);
        // arena high water is a per-lane max gauge
        assert_eq!(a.arena_high_water_bytes, 4096);
        assert_eq!(b.arena_high_water_bytes, 512);
        // barrier rounds (b=1) charge latency/2 to the stall estimate;
        // graph rounds (b=0) charge nothing
        assert!((a.mean_layer_stall_ms - 3.0).abs() < 1e-9,
                "stall {}", a.mean_layer_stall_ms);
        assert_eq!(b.mean_layer_stall_ms, 0.0);
        // global aggregates still cover both lanes
        assert_eq!(s.fused_rounds, 3);
        // both lanes ran rounds; their windows are well-formed
        assert!(a.last_round_ms >= a.first_round_ms);
        assert!(a.overlaps(b) || !a.overlaps(b)); // structural smoke
        assert!(s.lane("c").is_none());
    }

    #[test]
    fn grs_stats_aggregate_globally_and_per_lane() {
        let m = Metrics::default();
        let s0 = m.snapshot();
        assert_eq!(s0.accepted_steps, 0);
        assert_eq!(s0.mean_accept_run, 0.0);
        // lane a: 2 requests — 38+20 accepts, 2+4 rejects, 5+6 windows
        m.on_grs_stats("a", 38, 2, 5);
        m.on_grs_stats("a", 20, 4, 6);
        // lane b: 1 request
        m.on_grs_stats("b", 10, 0, 2);
        let s = m.snapshot();
        assert_eq!(s.accepted_steps, 68);
        assert_eq!(s.rejected_steps, 6);
        assert!((s.mean_accept_run - 68.0 / 13.0).abs() < 1e-12);
        let a = s.lane("a").unwrap();
        assert_eq!(a.accepted_steps, 58);
        assert_eq!(a.rejected_steps, 6);
        assert!((a.mean_accept_run - 58.0 / 11.0).abs() < 1e-12);
        let b = s.lane("b").unwrap();
        assert_eq!(b.accepted_steps, 10);
        assert_eq!(b.rejected_steps, 0);
        assert!((b.mean_accept_run - 5.0).abs() < 1e-12);
    }

    #[test]
    fn failure_domain_counters_aggregate_globally_and_per_lane() {
        let m = Metrics::default();
        let s0 = m.snapshot();
        assert_eq!(s0.timed_out, 0);
        assert_eq!(s0.breaker_trips, 0);
        // lane a: in-flight timeout (cancels), admission timeout (no
        // cancel), one retry, one breaker trip, one lane rejection
        m.on_timeout("a", true);
        m.on_timeout("a", false);
        m.on_retry("a");
        m.on_breaker_trip("a");
        m.on_lane_reject("a");
        // lane b: a hot reload only
        m.on_reload("b");
        let s = m.snapshot();
        assert_eq!(s.timed_out, 2);
        assert_eq!(s.cancelled, 1);
        assert_eq!(s.retried, 1);
        assert_eq!(s.breaker_trips, 1);
        assert_eq!(s.reloads, 1);
        // lane rejections count into the global rejected alongside
        // bounded-queue rejections
        assert_eq!(s.rejected, 1);
        let a = s.lane("a").unwrap();
        assert_eq!(a.timed_out, 2);
        assert_eq!(a.cancelled, 1);
        assert_eq!(a.retried, 1);
        assert_eq!(a.breaker_trips, 1);
        assert_eq!(a.rejected, 1);
        assert_eq!(a.reloads, 0);
        let b = s.lane("b").unwrap();
        assert_eq!(b.reloads, 1);
        assert_eq!(b.timed_out, 0);
    }

    #[test]
    fn lane_window_overlap_detects_concurrent_progress() {
        let m = Metrics::default();
        m.on_fused_round("a", 1, 1, 1, 0, 0.001, 0);
        m.on_fused_round("b", 1, 1, 1, 0, 0.001, 0);
        m.on_fused_round("a", 1, 1, 1, 0, 0.001, 0);
        let s = m.snapshot();
        let a = s.lane("a").unwrap();
        let b = s.lane("b").unwrap();
        // b's single round falls inside a's [first, last] window
        assert!(a.overlaps(b));
        assert!(b.overlaps(a));
    }
}
