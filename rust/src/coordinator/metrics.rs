//! Serving metrics: counters + latency aggregates, cheap to update from
//! every worker (single short-lived mutex; the hot path does sampling,
//! not metric churn).

use std::sync::Mutex;

use crate::math::stats::Welford;

#[derive(Debug, Default)]
struct Inner {
    submitted: u64,
    completed: u64,
    failed: u64,
    batched_groups: u64,
    batched_requests: u64,
    queue_wait: Welford,
    service: Welford,
    model_calls: u64,
    parallel_rounds: u64,
    /// measured per-round model-call latency (ms) across ASD requests
    round_latency: Welford,
    /// worker-pool shard occupancy per round (1 = ran inline)
    shard_occupancy: Welford,
}

#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub batched_groups: u64,
    pub batched_requests: u64,
    pub mean_queue_wait_ms: f64,
    pub mean_service_ms: f64,
    pub p_like_max_service_ms: f64,
    pub model_calls: u64,
    pub parallel_rounds: u64,
    /// rounds with measured latency recorded (ASD requests)
    pub rounds_measured: u64,
    pub mean_round_latency_ms: f64,
    pub mean_shard_occupancy: f64,
}

impl Metrics {
    pub fn on_submit(&self) {
        self.inner.lock().unwrap().submitted += 1;
    }

    pub fn on_complete(&self, queued_s: f64, service_s: f64,
                       model_calls: usize, rounds: usize, failed: bool) {
        let mut m = self.inner.lock().unwrap();
        if failed {
            m.failed += 1;
        } else {
            m.completed += 1;
        }
        m.queue_wait.push(queued_s * 1e3);
        m.service.push(service_s * 1e3);
        m.model_calls += model_calls as u64;
        m.parallel_rounds += rounds as u64;
    }

    pub fn on_batch(&self, group_size: usize) {
        let mut m = self.inner.lock().unwrap();
        m.batched_groups += 1;
        m.batched_requests += group_size as u64;
    }

    /// Record a request's measured per-round latencies and shard
    /// occupancies (from `AsdStats`).
    pub fn on_round_stats(&self, latencies_s: &[f64], shards: &[usize]) {
        let mut m = self.inner.lock().unwrap();
        for &l in latencies_s {
            m.round_latency.push(l * 1e3);
        }
        for &s in shards {
            m.shard_occupancy.push(s as f64);
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        MetricsSnapshot {
            submitted: m.submitted,
            completed: m.completed,
            failed: m.failed,
            batched_groups: m.batched_groups,
            batched_requests: m.batched_requests,
            mean_queue_wait_ms: m.queue_wait.mean(),
            mean_service_ms: m.service.mean(),
            p_like_max_service_ms: m.service.mean() + 2.0 * m.service.std(),
            model_calls: m.model_calls,
            parallel_rounds: m.parallel_rounds,
            rounds_measured: m.round_latency.n as u64,
            mean_round_latency_ms: m.round_latency.mean(),
            mean_shard_occupancy: if m.shard_occupancy.n == 0 {
                1.0
            } else {
                m.shard_occupancy.mean()
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        m.on_submit();
        m.on_submit();
        m.on_complete(0.001, 0.010, 100, 50, false);
        m.on_complete(0.002, 0.020, 200, 60, true);
        m.on_batch(4);
        let s = m.snapshot();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.completed, 1);
        assert_eq!(s.failed, 1);
        assert_eq!(s.model_calls, 300);
        assert_eq!(s.parallel_rounds, 110);
        assert_eq!(s.batched_requests, 4);
        assert!((s.mean_service_ms - 15.0).abs() < 1e-9);
        // no rounds recorded yet: occupancy defaults to serial
        assert_eq!(s.rounds_measured, 0);
        assert_eq!(s.mean_shard_occupancy, 1.0);
    }

    #[test]
    fn round_stats_aggregate() {
        let m = Metrics::default();
        m.on_round_stats(&[0.001, 0.003], &[1, 4]);
        m.on_round_stats(&[0.002], &[3]);
        let s = m.snapshot();
        assert_eq!(s.rounds_measured, 3);
        assert!((s.mean_round_latency_ms - 2.0).abs() < 1e-9);
        assert!((s.mean_shard_occupancy - 8.0 / 3.0).abs() < 1e-9);
    }
}
