//! Serving metrics: counters + latency aggregates, cheap to update from
//! every worker (single short-lived mutex; the hot path does sampling,
//! not metric churn).

use std::sync::Mutex;

use crate::math::stats::Welford;

#[derive(Debug, Default)]
struct Inner {
    submitted: u64,
    /// turned away by bounded admission (`max_queue_depth`)
    rejected: u64,
    completed: u64,
    failed: u64,
    batched_groups: u64,
    batched_requests: u64,
    queue_wait: Welford,
    service: Welford,
    model_calls: u64,
    parallel_rounds: u64,
    /// measured per-round model-call latency (ms) across ASD requests
    round_latency: Welford,
    /// worker-pool shard occupancy per round (1 = ran inline)
    shard_occupancy: Welford,
    /// fused coordinator rounds (one mega denoise_batch per tick)
    fused_rounds: u64,
    /// total rows across all fused rounds
    fused_rows: u64,
    /// requests contributing rows, per fused round
    fused_requests: Welford,
    /// worker-pool shards per fused round
    fused_shards: Welford,
}

#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub batched_groups: u64,
    pub batched_requests: u64,
    pub mean_queue_wait_ms: f64,
    pub mean_service_ms: f64,
    pub p_like_max_service_ms: f64,
    pub model_calls: u64,
    pub parallel_rounds: u64,
    /// rounds with measured latency recorded (ASD requests)
    pub rounds_measured: u64,
    pub mean_round_latency_ms: f64,
    pub mean_shard_occupancy: f64,
    /// fused coordinator rounds executed (one mega-call per tick)
    pub fused_rounds: u64,
    /// mean rows per fused round — the batch the kernels actually see;
    /// > 1 means cross-request fusion is happening
    pub fused_rows_per_round: f64,
    /// mean requests contributing to each fused round
    pub mean_fused_requests_per_round: f64,
    /// mean worker-pool shard occupancy of fused rounds
    pub fused_occupancy: f64,
}

impl Metrics {
    pub fn on_submit(&self) {
        self.inner.lock().unwrap().submitted += 1;
    }

    /// Bounded admission turned a request away.
    pub fn on_reject(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// One fused coordinator round: `rows` total rows from `requests`
    /// in-flight requests, executed as `shards` pool shards.
    pub fn on_fused_round(&self, rows: usize, requests: usize,
                          shards: usize) {
        let mut m = self.inner.lock().unwrap();
        m.fused_rounds += 1;
        m.fused_rows += rows as u64;
        m.fused_requests.push(requests as f64);
        m.fused_shards.push(shards as f64);
    }

    pub fn on_complete(&self, queued_s: f64, service_s: f64,
                       model_calls: usize, rounds: usize, failed: bool) {
        let mut m = self.inner.lock().unwrap();
        if failed {
            m.failed += 1;
        } else {
            m.completed += 1;
        }
        m.queue_wait.push(queued_s * 1e3);
        m.service.push(service_s * 1e3);
        m.model_calls += model_calls as u64;
        m.parallel_rounds += rounds as u64;
    }

    pub fn on_batch(&self, group_size: usize) {
        let mut m = self.inner.lock().unwrap();
        m.batched_groups += 1;
        m.batched_requests += group_size as u64;
    }

    /// Continuous admission added `n` requests to an in-flight fusion
    /// group (they batch with the group but don't form a new one).
    pub fn on_fused_admit(&self, n: usize) {
        self.inner.lock().unwrap().batched_requests += n as u64;
    }

    /// Record a request's measured per-round latencies and shard
    /// occupancies (from `AsdStats`).
    pub fn on_round_stats(&self, latencies_s: &[f64], shards: &[usize]) {
        let mut m = self.inner.lock().unwrap();
        for &l in latencies_s {
            m.round_latency.push(l * 1e3);
        }
        for &s in shards {
            m.shard_occupancy.push(s as f64);
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        MetricsSnapshot {
            submitted: m.submitted,
            rejected: m.rejected,
            completed: m.completed,
            failed: m.failed,
            batched_groups: m.batched_groups,
            batched_requests: m.batched_requests,
            mean_queue_wait_ms: m.queue_wait.mean(),
            mean_service_ms: m.service.mean(),
            p_like_max_service_ms: m.service.mean() + 2.0 * m.service.std(),
            model_calls: m.model_calls,
            parallel_rounds: m.parallel_rounds,
            rounds_measured: m.round_latency.n as u64,
            mean_round_latency_ms: m.round_latency.mean(),
            mean_shard_occupancy: if m.shard_occupancy.n == 0 {
                1.0
            } else {
                m.shard_occupancy.mean()
            },
            fused_rounds: m.fused_rounds,
            fused_rows_per_round: if m.fused_rounds == 0 {
                0.0
            } else {
                m.fused_rows as f64 / m.fused_rounds as f64
            },
            mean_fused_requests_per_round: m.fused_requests.mean(),
            fused_occupancy: if m.fused_shards.n == 0 {
                1.0
            } else {
                m.fused_shards.mean()
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        m.on_submit();
        m.on_submit();
        m.on_complete(0.001, 0.010, 100, 50, false);
        m.on_complete(0.002, 0.020, 200, 60, true);
        m.on_batch(4);
        let s = m.snapshot();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.completed, 1);
        assert_eq!(s.failed, 1);
        assert_eq!(s.model_calls, 300);
        assert_eq!(s.parallel_rounds, 110);
        assert_eq!(s.batched_requests, 4);
        assert!((s.mean_service_ms - 15.0).abs() < 1e-9);
        // no rounds recorded yet: occupancy defaults to serial
        assert_eq!(s.rounds_measured, 0);
        assert_eq!(s.mean_shard_occupancy, 1.0);
    }

    #[test]
    fn fused_round_and_rejection_metrics_aggregate() {
        let m = Metrics::default();
        let s0 = m.snapshot();
        assert_eq!(s0.fused_rounds, 0);
        assert_eq!(s0.fused_rows_per_round, 0.0);
        assert_eq!(s0.fused_occupancy, 1.0);
        m.on_fused_round(6, 3, 2);
        m.on_fused_round(2, 1, 1);
        m.on_reject();
        let s = m.snapshot();
        assert_eq!(s.fused_rounds, 2);
        assert!((s.fused_rows_per_round - 4.0).abs() < 1e-12);
        assert!((s.mean_fused_requests_per_round - 2.0).abs() < 1e-12);
        assert!((s.fused_occupancy - 1.5).abs() < 1e-12);
        assert_eq!(s.rejected, 1);
    }

    #[test]
    fn round_stats_aggregate() {
        let m = Metrics::default();
        m.on_round_stats(&[0.001, 0.003], &[1, 4]);
        m.on_round_stats(&[0.002], &[3]);
        let s = m.snapshot();
        assert_eq!(s.rounds_measured, 3);
        assert!((s.mean_round_latency_ms - 2.0).abs() < 1e-9);
        assert!((s.mean_shard_occupancy - 8.0 / 3.0).abs() < 1e-9);
    }
}
