//! Request / response types for the serving stack.

use std::sync::mpsc::Sender;
use std::time::Instant;

use crate::asd::AsdStats;

/// Which sampler serves a request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SamplerSpec {
    Sequential,
    /// theta; 0 = ASD-infinity
    Asd(usize),
    /// window, tol
    Picard(usize, f64),
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub variant: String,
    pub sampler: SamplerSpec,
    pub seed: u64,
    /// conditioning row (empty for unconditional variants)
    pub cond: Vec<f64>,
}

#[derive(Debug)]
pub struct Response {
    pub id: u64,
    pub sample: Vec<f64>,
    /// denoiser evaluations spent on this request
    pub model_calls: usize,
    /// parallel rounds spent on this request
    pub parallel_rounds: usize,
    /// ASD-specific stats when applicable
    pub asd_stats: Option<AsdStats>,
    pub queued_s: f64,
    pub service_s: f64,
    pub error: Option<String>,
}

pub(crate) struct QueuedJob {
    pub request: Request,
    pub reply: Sender<Response>,
    pub enqueued: Instant,
}
