//! Request / response types for the serving stack.

use std::sync::mpsc::Sender;
use std::time::Instant;

use crate::asd::{AsdConfig, AsdStats, DraftConfig, KernelBackend};
use crate::picard::PicardConfig;
use crate::runtime::pool::PoolConfig;

/// Which sampler serves a request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SamplerSpec {
    Sequential,
    /// theta; 0 = ASD-infinity
    Asd(usize),
    /// window, tol
    Picard(usize, f64),
    /// draft-model speculative sampling: draft window k (0 = to the
    /// end). The draft *model* is not part of the spec — it is paired
    /// per variant at the coordinator (`Coordinator::pair_draft`), so
    /// the spec stays `Copy` and requests stay variant-addressed.
    Draft(usize),
}

impl SamplerSpec {
    /// The ONE canonical ASD config the coordinator serves requests
    /// with. Both execution paths — the per-request engines
    /// (`server::run_sampler`, batching off) and the fused machines
    /// (`fusion::Machine::for_request`) — must build from here, or the
    /// same request could sample different bits depending on which
    /// path served it.
    pub(crate) fn asd_config(theta: usize, pool: PoolConfig) -> AsdConfig {
        AsdConfig {
            theta,
            eval_tail: true,
            backend: KernelBackend::Native,
            pool,
        }
    }

    /// Canonical Picard config; see [`SamplerSpec::asd_config`].
    pub(crate) fn picard_config(window: usize, tol: f64, pool: PoolConfig)
                                -> PicardConfig {
        PicardConfig { window, tol, pool, ..PicardConfig::default() }
    }

    /// Canonical draft-SD config; see [`SamplerSpec::asd_config`]. The
    /// served paths never use an adaptive controller — a learned,
    /// order-dependent window would make fused and solo execution
    /// diverge.
    pub(crate) fn draft_config(k: usize, pool: PoolConfig) -> DraftConfig {
        DraftConfig { k, pool, adaptive: None }
    }
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub variant: String,
    pub sampler: SamplerSpec,
    pub seed: u64,
    /// conditioning row (empty for unconditional variants)
    pub cond: Vec<f64>,
}

#[derive(Debug)]
pub struct Response {
    pub id: u64,
    pub sample: Vec<f64>,
    /// denoiser evaluations spent on this request
    pub model_calls: usize,
    /// parallel rounds spent on this request
    pub parallel_rounds: usize,
    /// ASD-specific stats when applicable
    pub asd_stats: Option<AsdStats>,
    pub queued_s: f64,
    pub service_s: f64,
    /// true when admission control turned the request away (queue full)
    /// without ever scheduling it; `error` carries the reason
    pub rejected: bool,
    pub error: Option<String>,
}

impl Response {
    /// A failed (but admitted) request.
    pub fn failed(id: u64, queued_s: f64, msg: &str) -> Response {
        Response {
            id,
            sample: vec![],
            model_calls: 0,
            parallel_rounds: 0,
            asd_stats: None,
            queued_s,
            service_s: 0.0,
            rejected: false,
            error: Some(msg.to_string()),
        }
    }

    /// Bounded-admission rejection: the queue was at
    /// `ServerConfig::max_queue_depth` when the request arrived.
    pub fn rejected(id: u64, depth: usize, max_depth: usize) -> Response {
        Response {
            rejected: true,
            error: Some(format!(
                "rejected: queue depth {depth} at max_queue_depth \
                 {max_depth}")),
            ..Response::failed(id, 0.0, "")
        }
    }
}

pub(crate) struct QueuedJob {
    pub request: Request,
    pub reply: Sender<Response>,
    pub enqueued: Instant,
}
