//! Request / response types for the serving stack.

use std::sync::mpsc::Sender;
use std::time::{Duration, Instant};

use crate::asd::{AsdConfig, AsdStats, DraftConfig, KernelBackend};
use crate::picard::PicardConfig;
use crate::runtime::pool::PoolConfig;

/// Which sampler serves a request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SamplerSpec {
    Sequential,
    /// theta; 0 = ASD-infinity
    Asd(usize),
    /// window, tol
    Picard(usize, f64),
    /// draft-model speculative sampling: draft window k (0 = to the
    /// end). The draft *model* is not part of the spec — it is paired
    /// per variant at the coordinator (`Coordinator::pair_draft`), so
    /// the spec stays `Copy` and requests stay variant-addressed.
    Draft(usize),
}

impl SamplerSpec {
    /// The ONE canonical ASD config the coordinator serves requests
    /// with. Both execution paths — the per-request engines
    /// (`server::run_sampler`, batching off) and the fused machines
    /// (`fusion::Machine::for_request`) — must build from here, or the
    /// same request could sample different bits depending on which
    /// path served it.
    pub(crate) fn asd_config(theta: usize, pool: PoolConfig) -> AsdConfig {
        AsdConfig {
            theta,
            eval_tail: true,
            backend: KernelBackend::Native,
            pool,
        }
    }

    /// Canonical Picard config; see [`SamplerSpec::asd_config`].
    pub(crate) fn picard_config(window: usize, tol: f64, pool: PoolConfig)
                                -> PicardConfig {
        PicardConfig { window, tol, pool, ..PicardConfig::default() }
    }

    /// Canonical draft-SD config; see [`SamplerSpec::asd_config`]. The
    /// served paths never use an adaptive controller — a learned,
    /// order-dependent window would make fused and solo execution
    /// diverge.
    pub(crate) fn draft_config(k: usize, pool: PoolConfig) -> DraftConfig {
        DraftConfig { k, pool, adaptive: None }
    }
}

/// Structured failure taxonomy for [`Response`]. Clients and tests
/// branch on this instead of string-matching `Response::error`; the
/// free-text message stays alongside for display.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailReason {
    /// the fused model call (closure round) panicked or the round
    /// compilation panicked
    ModelPanic,
    /// a tile of the round's compiled graph panicked mid-graph (the
    /// pool cancelled its dependents and failed only this round)
    TilePanic,
    /// `Request::deadline` expired (pre-admission or swept at a round
    /// boundary)
    Timeout,
    /// the lane's circuit breaker was open — admission refused while
    /// the lane cools down
    BreakerOpen,
    /// the request's output rows contained NaN/Inf after an otherwise
    /// successful fused round
    NonFinite,
    /// bounded admission: the coordinator queue was at
    /// `max_queue_depth`
    QueueFull,
    /// a `SamplerSpec::Draft` request on a lane with no paired draft
    /// model (`Coordinator::pair_draft`)
    NoDraftPairing,
    /// the coordinator is draining (`Coordinator::drain`) and refuses
    /// new work
    Draining,
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub variant: String,
    pub sampler: SamplerSpec,
    pub seed: u64,
    /// conditioning row (empty for unconditional variants)
    pub cond: Vec<f64>,
    /// optional wall-clock budget, relative to submission. Expired
    /// requests are cancelled at the next round boundary (never
    /// mid-round — the fused call is indivisible) and answered with
    /// [`FailReason::Timeout`]. `None` = no deadline.
    pub deadline: Option<Duration>,
}

#[derive(Debug)]
pub struct Response {
    pub id: u64,
    pub sample: Vec<f64>,
    /// denoiser evaluations spent on this request
    pub model_calls: usize,
    /// parallel rounds spent on this request
    pub parallel_rounds: usize,
    /// ASD-specific stats when applicable
    pub asd_stats: Option<AsdStats>,
    pub queued_s: f64,
    pub service_s: f64,
    /// true when admission control turned the request away (queue
    /// full, breaker open, draining) without ever scheduling it;
    /// `error` carries the reason
    pub rejected: bool,
    pub error: Option<String>,
    /// structured failure class when `error` is set (may be `None` for
    /// generic sampler errors that predate the taxonomy)
    pub reason: Option<FailReason>,
    /// how many times the request was restarted from scratch after a
    /// faulted fused round (retry-from-scratch is bit-transparent:
    /// machines are pure functions of `(seed, cond)`)
    pub retries: u32,
}

impl Response {
    /// A failed (but admitted) request.
    pub fn failed(id: u64, queued_s: f64, msg: &str) -> Response {
        Response {
            id,
            sample: vec![],
            model_calls: 0,
            parallel_rounds: 0,
            asd_stats: None,
            queued_s,
            service_s: 0.0,
            rejected: false,
            error: Some(msg.to_string()),
            reason: None,
            retries: 0,
        }
    }

    /// A failed request with a structured [`FailReason`].
    pub fn failed_with(id: u64, queued_s: f64, reason: FailReason,
                       msg: &str) -> Response {
        Response { reason: Some(reason), ..Response::failed(id, queued_s, msg) }
    }

    /// Bounded-admission rejection: the queue was at
    /// `ServerConfig::max_queue_depth` when the request arrived.
    pub fn rejected(id: u64, depth: usize, max_depth: usize) -> Response {
        Response {
            rejected: true,
            reason: Some(FailReason::QueueFull),
            error: Some(format!(
                "rejected: queue depth {depth} at max_queue_depth \
                 {max_depth}")),
            ..Response::failed(id, 0.0, "")
        }
    }
}

pub(crate) struct QueuedJob {
    pub request: Request,
    pub reply: Sender<Response>,
    pub enqueued: Instant,
}

impl QueuedJob {
    /// Whether the request's deadline has already expired.
    pub(crate) fn expired(&self) -> bool {
        self.request.deadline
            .is_some_and(|d| self.enqueued.elapsed() >= d)
    }
}
