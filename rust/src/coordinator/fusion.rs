//! `FusionScheduler` — round-synchronous cross-request batch fusion.
//!
//! One scheduler owns the in-flight requests of a same-variant fusion
//! group. Each [`FusionScheduler::tick`]:
//!
//! 1. polls every request's sampler state machine for its
//!    `DenoiseDemand` (finished machines are retired and answered),
//! 2. packs all demanded rows into one contiguous mega-batch,
//! 3. issues a single fused `denoise_batch` call (through the group's
//!    `ParallelModel` wrapper, so the one global worker pool shards the
//!    fused rows), and
//! 4. scatters the results back, resuming every machine.
//!
//! **Fairness:** every in-flight request contributes to and is resumed
//! from *every* tick — a sequential request's one row rides the same
//! round as an ASD request's theta-row verify batch, so no request
//! starves while another speculates. Per-request row demands are
//! bounded (1, theta, or the Picard window), so no single request can
//! monopolize a round either.
//!
//! **Determinism:** machines consume only their own pre-drawn Philox
//! streams, and native models are row-independent (`model::parallel`),
//! so fused execution produces bit-identical samples to solo execution
//! — enforced by tests/test_fusion_determinism.rs.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::asd::engine::AsdStepMachine;
use crate::asd::AsdStats;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{QueuedJob, Response, SamplerSpec};
use crate::ddpm::{NoiseStreams, SequentialStepMachine};
use crate::model::DenoiseModel;
use crate::picard::PicardStepMachine;
use crate::runtime::pool::PoolConfig;
use crate::sampler::{RoundExec, SamplerPoll, StepSampler};

/// Per-request sampler state machine (concrete enum so finished
/// machines can surface their sampler-specific stats without downcasts).
pub(crate) enum Machine {
    Sequential(SequentialStepMachine),
    Asd(Box<AsdStepMachine>),
    Picard(PicardStepMachine),
}

impl Machine {
    /// Build the machine for a request. `model` is the group's shared
    /// (possibly `ParallelModel`-wrapped) model — machines only read
    /// its metadata and schedule, never call it.
    pub(crate) fn for_request(model: Arc<dyn DenoiseModel>,
                              sampler: SamplerSpec, seed: u64, cond: &[f64])
                              -> Result<Machine> {
        let noise = NoiseStreams::draw(seed, 0, model.k_steps(), model.dim());
        // machine parameters come from the canonical per-spec configs
        // (SamplerSpec::asd_config / picard_config) — the same source
        // server::run_sampler builds its engines from, so fused and
        // solo execution of a request can never drift apart. The pool
        // field is irrelevant here: machines never call the model.
        Ok(match sampler {
            SamplerSpec::Sequential => Machine::Sequential(
                SequentialStepMachine::new(model, noise, cond)?),
            SamplerSpec::Asd(theta) => {
                let cfg = SamplerSpec::asd_config(theta,
                                                  PoolConfig::default());
                Machine::Asd(Box::new(AsdStepMachine::new(
                    model, cfg.theta, cfg.eval_tail, cfg.backend, noise,
                    cond)?))
            }
            SamplerSpec::Picard(window, tol) => {
                let cfg = SamplerSpec::picard_config(window, tol,
                                                     PoolConfig::default());
                Machine::Picard(PicardStepMachine::new(
                    model, cfg.window, cfg.tol, cfg.max_sweeps, noise,
                    cond)?)
            }
        })
    }

    fn as_step(&mut self) -> &mut dyn StepSampler {
        match self {
            Machine::Sequential(m) => m,
            Machine::Asd(m) => m.as_mut(),
            Machine::Picard(m) => m,
        }
    }

    /// (model_calls, parallel_rounds, asd_stats) for the response.
    fn outcome(self) -> (usize, usize, Option<AsdStats>) {
        match self {
            Machine::Sequential(m) => {
                let st = m.into_stats();
                (st.model_calls, st.model_calls, None)
            }
            Machine::Asd(m) => {
                let st = m.into_stats();
                (st.model_calls, st.parallel_rounds, Some(st))
            }
            Machine::Picard(m) => {
                let st = m.into_stats();
                (st.model_calls, st.parallel_rounds, None)
            }
        }
    }
}

struct ActiveRequest {
    job: QueuedJob,
    machine: Machine,
    /// queue wait, frozen at admission
    queued_s: f64,
    admitted: Instant,
}

pub(crate) struct FusionScheduler {
    model: Arc<dyn DenoiseModel>,
    pool: PoolConfig,
    active: Vec<ActiveRequest>,
    // mega-batch staging, reused across ticks
    ys: Vec<f64>,
    ts: Vec<f64>,
    cond: Vec<f64>,
    out: Vec<f64>,
    /// (active index, row offset, rows) per demanding request this tick
    spans: Vec<(usize, usize, usize)>,
}

impl FusionScheduler {
    /// `model` should already be `ParallelModel`-wrapped with `pool` so
    /// fused rounds shard on the global worker pool.
    pub(crate) fn new(model: Arc<dyn DenoiseModel>, pool: PoolConfig)
                      -> FusionScheduler {
        FusionScheduler {
            model,
            pool,
            active: Vec::new(),
            ys: Vec::new(),
            ts: Vec::new(),
            cond: Vec::new(),
            out: Vec::new(),
            spans: Vec::new(),
        }
    }

    pub(crate) fn len(&self) -> usize {
        self.active.len()
    }

    pub(crate) fn is_empty(&self) -> bool {
        self.active.is_empty()
    }

    /// Admit a request: build its machine, or answer immediately with
    /// the construction error (bad conditioning shape, ...).
    pub(crate) fn admit(&mut self, job: QueuedJob, metrics: &Metrics) {
        let queued_s = job.enqueued.elapsed().as_secs_f64();
        match Machine::for_request(self.model.clone(), job.request.sampler,
                                   job.request.seed, &job.request.cond) {
            Ok(machine) => self.active.push(ActiveRequest {
                job,
                machine,
                queued_s,
                admitted: Instant::now(),
            }),
            Err(e) => {
                metrics.on_complete(queued_s, 0.0, 0, 0, true);
                let _ = job.reply.send(Response::failed(job.request.id,
                                                        queued_s,
                                                        &e.to_string()));
            }
        }
    }

    /// One fused round: poll all, retire finished, evaluate the fused
    /// batch, scatter results. Returns the number of requests completed
    /// this tick. On a model error the whole group fails (they shared
    /// the call) and is drained.
    pub(crate) fn tick(&mut self, metrics: &Metrics) -> usize {
        let d = self.model.dim();
        let c = self.model.cond_dim();
        self.ys.clear();
        self.ts.clear();
        self.cond.clear();
        self.spans.clear();

        // poll phase: collect demands; retire machines that are done
        let mut completed = 0usize;
        let mut idx = 0usize;
        while idx < self.active.len() {
            let poll = match self.active[idx].machine.as_step().poll() {
                Ok(p) => p,
                Err(e) => {
                    let msg = e.to_string();
                    self.fail_at(idx, &msg, metrics);
                    continue;
                }
            };
            match poll {
                SamplerPoll::Done(y0) => {
                    let sample = y0.to_vec();
                    self.finish_at(idx, sample, metrics);
                    completed += 1;
                    // swap_remove moved another request into `idx`
                }
                SamplerPoll::Demand(dem) => {
                    let off = self.ts.len();
                    self.ys.extend_from_slice(dem.ys);
                    self.ts.extend_from_slice(dem.ts);
                    self.cond.extend_from_slice(dem.cond);
                    self.spans.push((idx, off, dem.n));
                    idx += 1;
                }
            }
        }
        if self.spans.is_empty() {
            return completed;
        }

        // fused mega-call: one parallel round for the whole group
        let n_total = self.ts.len();
        debug_assert_eq!(self.ys.len(), n_total * d);
        debug_assert_eq!(self.cond.len(), n_total * c);
        if self.out.len() < n_total * d {
            self.out.resize(n_total * d, 0.0);
        }
        let t0 = Instant::now();
        let shards = self.pool.shards_for(n_total);
        if let Err(e) = self.model.denoise_batch(&self.ys, &self.ts,
                                                 &self.cond, n_total,
                                                 &mut self.out[..n_total * d])
        {
            let msg = e.to_string();
            self.fail_all(&msg, metrics);
            return completed;
        }
        let exec = RoundExec {
            latency_s: t0.elapsed().as_secs_f64(),
            shards,
        };
        metrics.on_fused_round(n_total, self.spans.len(), shards);

        // scatter phase: resume every demanding machine with its rows.
        // Failures are answered immediately but removed only after the
        // loop, so the span indices stay valid throughout.
        let mut failed: Vec<usize> = Vec::new();
        for &(idx, off, rows) in &self.spans {
            let slice = &self.out[off * d..(off + rows) * d];
            if let Err(e) = self.active[idx].machine.as_step()
                .resume(slice, exec)
            {
                let ar = &self.active[idx];
                metrics.on_complete(ar.queued_s,
                                    ar.admitted.elapsed().as_secs_f64(),
                                    0, 0, true);
                let _ = ar.job.reply.send(Response::failed(
                    ar.job.request.id, ar.queued_s, &e.to_string()));
                failed.push(idx);
            }
        }
        // remove highest-index first so earlier indices stay stable
        failed.sort_unstable_by(|a, b| b.cmp(a));
        for idx in failed {
            self.active.swap_remove(idx);
        }
        completed
    }

    /// Answer and remove the request at `idx` (success).
    fn finish_at(&mut self, idx: usize, sample: Vec<f64>,
                 metrics: &Metrics) {
        let ar = self.active.swap_remove(idx);
        let service_s = ar.admitted.elapsed().as_secs_f64();
        let (calls, rounds, asd_stats) = ar.machine.outcome();
        if let Some(st) = &asd_stats {
            metrics.on_round_stats(&st.round_latency_s, &st.round_shards);
        }
        metrics.on_complete(ar.queued_s, service_s, calls, rounds, false);
        let _ = ar.job.reply.send(Response {
            id: ar.job.request.id,
            sample,
            model_calls: calls,
            parallel_rounds: rounds,
            asd_stats,
            queued_s: ar.queued_s,
            service_s,
            rejected: false,
            error: None,
        });
    }

    /// Answer and remove the request at `idx` (failure).
    fn fail_at(&mut self, idx: usize, msg: &str, metrics: &Metrics) {
        let ar = self.active.swap_remove(idx);
        metrics.on_complete(ar.queued_s, ar.admitted.elapsed().as_secs_f64(),
                            0, 0, true);
        let _ = ar.job.reply.send(Response::failed(ar.job.request.id,
                                                   ar.queued_s, msg));
    }

    /// Fail every in-flight request (shared model call errored).
    pub(crate) fn fail_all(&mut self, msg: &str, metrics: &Metrics) {
        for ar in self.active.drain(..) {
            metrics.on_complete(ar.queued_s,
                                ar.admitted.elapsed().as_secs_f64(), 0, 0,
                                true);
            let _ = ar.job.reply.send(Response::failed(ar.job.request.id,
                                                       ar.queued_s, msg));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Request;
    use crate::ddpm::SequentialSampler;
    use crate::model::{Gmm, GmmDdpmOracle};
    use std::sync::mpsc::{channel, Receiver};

    fn queued(variant: &str, sampler: SamplerSpec, seed: u64)
              -> (QueuedJob, Receiver<Response>) {
        let (tx, rx) = channel();
        (QueuedJob {
            request: Request {
                id: seed,
                variant: variant.into(),
                sampler,
                seed,
                cond: vec![],
            },
            reply: tx,
            enqueued: Instant::now(),
        }, rx)
    }

    #[test]
    fn fused_sequential_pair_runs_lockstep_and_matches_solo() {
        let model: Arc<dyn DenoiseModel> =
            GmmDdpmOracle::new(Gmm::circle_2d(), 30, false);
        let metrics = Metrics::default();
        let mut sched = FusionScheduler::new(model.clone(),
                                             PoolConfig::default());
        let (j1, rx1) = queued("gmm", SamplerSpec::Sequential, 5);
        let (j2, rx2) = queued("gmm", SamplerSpec::Sequential, 6);
        sched.admit(j1, &metrics);
        sched.admit(j2, &metrics);
        let mut ticks = 0usize;
        while !sched.is_empty() {
            sched.tick(&metrics);
            ticks += 1;
            assert!(ticks < 100, "fused group failed to drain");
        }
        // K demand ticks + 1 retire tick
        assert_eq!(ticks, 31);
        let solo = SequentialSampler::new(model);
        for (rx, seed) in [(rx1, 5u64), (rx2, 6u64)] {
            let r = rx.recv().unwrap();
            assert!(r.error.is_none());
            assert_eq!(r.model_calls, 30);
            let (want, _) = solo.sample(seed, &[]).unwrap();
            let bits = |v: &[f64]| -> Vec<u64> {
                v.iter().map(|x| x.to_bits()).collect()
            };
            assert_eq!(bits(&r.sample), bits(&want), "seed {seed}");
        }
        // every lockstep round fused both requests' rows
        let m = metrics.snapshot();
        assert_eq!(m.fused_rounds, 30);
        assert!((m.fused_rows_per_round - 2.0).abs() < 1e-12,
                "rows/round {}", m.fused_rows_per_round);
    }

    #[test]
    fn mixed_group_completes_and_no_request_starves() {
        let model: Arc<dyn DenoiseModel> =
            GmmDdpmOracle::new(Gmm::circle_2d(), 40, false);
        let metrics = Metrics::default();
        let mut sched = FusionScheduler::new(model, PoolConfig::default());
        let (j1, rx1) = queued("gmm", SamplerSpec::Asd(8), 1);
        let (j2, rx2) = queued("gmm", SamplerSpec::Sequential, 2);
        let (j3, rx3) = queued("gmm", SamplerSpec::Picard(8, 1e-6), 3);
        sched.admit(j1, &metrics);
        sched.admit(j2, &metrics);
        sched.admit(j3, &metrics);
        let mut ticks = 0usize;
        while !sched.is_empty() {
            sched.tick(&metrics);
            ticks += 1;
            assert!(ticks < 10_000, "mixed group failed to drain");
        }
        let r1 = rx1.recv().unwrap();
        let r2 = rx2.recv().unwrap();
        let r3 = rx3.recv().unwrap();
        assert!(r1.error.is_none() && r2.error.is_none()
                && r3.error.is_none());
        assert!(r1.asd_stats.is_some());
        // the sequential request needs exactly K rounds; the group must
        // not have made it wait for the others to finish first
        assert_eq!(r2.model_calls, 40);
        assert!(r1.parallel_rounds < 40, "asd {}", r1.parallel_rounds);
        assert!(r3.parallel_rounds >= 5);
        // while >= 2 requests were in flight, rounds were fused
        let m = metrics.snapshot();
        assert!(m.fused_rows_per_round > 1.0,
                "rows/round {}", m.fused_rows_per_round);
    }

    #[test]
    fn bad_conditioning_is_answered_at_admission() {
        let model: Arc<dyn DenoiseModel> =
            GmmDdpmOracle::new(Gmm::circle_2d(), 10, false);
        let metrics = Metrics::default();
        let mut sched = FusionScheduler::new(model, PoolConfig::default());
        let (tx, rx) = channel();
        sched.admit(QueuedJob {
            request: Request {
                id: 7,
                variant: "gmm".into(),
                sampler: SamplerSpec::Sequential,
                seed: 0,
                cond: vec![1.0, 2.0], // model is unconditional
            },
            reply: tx,
            enqueued: Instant::now(),
        }, &metrics);
        assert!(sched.is_empty());
        let r = rx.recv().unwrap();
        assert!(r.error.unwrap().contains("cond_dim"));
        assert_eq!(metrics.snapshot().failed, 1);
    }
}
