//! `FusionScheduler` — cross-request batch fusion on the
//! [`RoundArena`](crate::sampler::RoundArena) data plane.
//!
//! One scheduler owns the in-flight requests of a serving lane (one
//! lane per variant — see `coordinator::lanes`). A round is three
//! phases, split so a lane driver can submit *many* lanes' rounds to
//! the one global pool as independent, continuously executing round
//! tasks (`server::Driver` — no global tick, no barrier between
//! lanes):
//!
//! 1. [`FusionScheduler::begin_round`] — poll phase: retire finished
//!    machines (answer their requests), then have every in-flight
//!    machine write its demanded rows **directly into the lane's
//!    arena** (`StepSampler::poll_into`; no mega-batch pack copy).
//! 2. [`FusionScheduler::execute_round`] — one fused `denoise_round`
//!    over the arena (through the lane's `ParallelModel` wrapper; the
//!    native backend converts f64→f32 once into the arena's per-lane
//!    GEMM workspace). Runs lock-free — safe to execute concurrently
//!    with other lanes. Graph-capable backends skip this opaque form:
//!    [`FusionScheduler::compile_round`] emits the round as a
//!    dependency-counted tile graph the driver hands to the pool
//!    (zero intra-round barriers, tiles of many lanes interleave),
//!    and [`FusionScheduler::complete_round`] stages the execution
//!    report when the round's single completion notification arrives.
//! 3. [`FusionScheduler::finish_round`] — scatter phase: resume every
//!    machine from a *view* into the arena's output region
//!    (`StepSampler::resume_from`; no scatter copy).
//!
//! The arena and workspace persist across rounds and across fusion
//! groups, so the steady-state fused path performs zero heap
//! allocations per round.
//!
//! **Fairness:** every in-flight request contributes to and is resumed
//! from *every* round — a sequential request's one row rides the same
//! round as an ASD request's theta-row verify batch, so no request
//! starves while another speculates. Per-request row demands are
//! bounded (1, theta, or the Picard window), so no single request can
//! monopolize a round either.
//!
//! **Determinism:** machines consume only their own pre-drawn Philox
//! streams, and native models are row-independent (`model::parallel`),
//! so fused execution produces bit-identical samples to solo execution
//! — enforced by tests/test_fusion_determinism.rs.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::asd::draft::DraftStepMachine;
use crate::asd::engine::AsdStepMachine;
use crate::asd::AsdStats;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{FailReason, QueuedJob, Response,
                                  SamplerSpec};
use crate::ddpm::{NoiseStreams, SequentialStepMachine};
use crate::model::DenoiseModel;
use crate::picard::PicardStepMachine;
use crate::runtime::pool::{PoolConfig, TileGraph};
use crate::sampler::{ArenaSpan, RoundArena, RoundExec, SamplerPoll,
                     StepSampler};

/// Failure-recovery knobs for a lane's fused rounds (part of
/// `ServerConfig`). Retry is *from scratch*: a request caught in a
/// faulted fused round gets a freshly built machine, which is
/// bit-transparent because machines are pure functions of
/// `(seed, cond)` over pre-drawn noise streams. Backoff is measured in
/// *lane rounds*, not wall-clock — a request waiting out its backoff
/// simply skips `backoff_rounds << (retries-1)` polls — so the retry
/// schedule is identical across pool sizes and steal schedules (the
/// chaos determinism suite depends on this).
#[derive(Debug, Clone, Copy)]
pub struct RecoveryPolicy {
    /// per-request restarts granted after faulted fused rounds; past
    /// this the request fails with the round's `FailReason`
    pub retry_max: u32,
    /// base backoff (in lane rounds) before a retried request polls
    /// again; doubles per retry
    pub backoff_rounds: u32,
    /// consecutive faulted rounds before the lane's circuit breaker
    /// opens and admissions are rejected (`FailReason::BreakerOpen`)
    pub breaker_threshold: u32,
    /// how long an open breaker rejects before letting a half-open
    /// probe batch through
    pub breaker_cooldown: Duration,
    /// scan each request's output rows for NaN/Inf after a successful
    /// fused round, failing only the offending request
    /// (`FailReason::NonFinite`)
    pub validate_outputs: bool,
}

impl Default for RecoveryPolicy {
    fn default() -> RecoveryPolicy {
        RecoveryPolicy {
            retry_max: 2,
            backoff_rounds: 1,
            breaker_threshold: 4,
            breaker_cooldown: Duration::from_millis(250),
            validate_outputs: true,
        }
    }
}

/// Per-lane circuit breaker: `threshold` consecutive faulted rounds
/// open it; while open, admissions are rejected; after `cooldown` one
/// half-open probe batch is admitted — success closes the breaker,
/// another fault reopens it immediately.
enum BreakerState {
    Closed,
    Open { until: Instant },
    HalfOpen,
}

struct Breaker {
    streak: u32,
    state: BreakerState,
}

impl Breaker {
    fn new() -> Breaker {
        Breaker { streak: 0, state: BreakerState::Closed }
    }

    /// Whether admissions may proceed. An expired cooldown flips the
    /// breaker half-open and admits the caller's batch as the probe.
    fn admit(&mut self) -> bool {
        match self.state {
            BreakerState::Closed | BreakerState::HalfOpen => true,
            BreakerState::Open { until } => {
                if Instant::now() >= until {
                    self.state = BreakerState::HalfOpen;
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Record a faulted round; returns true when this failure tripped
    /// the breaker open (a half-open probe failure reopens at once).
    fn on_failure(&mut self, policy: &RecoveryPolicy) -> bool {
        self.streak += 1;
        let trip = match self.state {
            BreakerState::HalfOpen => true,
            BreakerState::Closed => {
                self.streak >= policy.breaker_threshold.max(1)
            }
            BreakerState::Open { .. } => false,
        };
        if trip {
            self.state = BreakerState::Open {
                until: Instant::now() + policy.breaker_cooldown,
            };
        }
        trip
    }

    /// A clean fused round: reset the streak and close the breaker
    /// (a successful half-open probe is exactly this).
    fn on_success(&mut self) {
        self.streak = 0;
        self.state = BreakerState::Closed;
    }
}

/// Per-request sampler state machine (concrete enum so finished
/// machines can surface their sampler-specific stats without downcasts).
pub(crate) enum Machine {
    Sequential(SequentialStepMachine),
    Asd(Box<AsdStepMachine>),
    Picard(PicardStepMachine),
    Draft(Box<DraftStepMachine>),
}

impl Machine {
    /// Build the machine for a request. `model` is the lane's shared
    /// (possibly `ParallelModel`-wrapped) model — machines only read
    /// its metadata and schedule, never call it. `draft` is the lane's
    /// paired draft model (raw, unwrapped — its chain runs as cheap
    /// sequential calls inside the draft machine), required only for
    /// `SamplerSpec::Draft` requests.
    pub(crate) fn for_request(model: Arc<dyn DenoiseModel>,
                              draft: Option<Arc<dyn DenoiseModel>>,
                              sampler: SamplerSpec, seed: u64, cond: &[f64])
                              -> Result<Machine> {
        let noise = NoiseStreams::draw(seed, 0, model.k_steps(), model.dim());
        // machine parameters come from the canonical per-spec configs
        // (SamplerSpec::asd_config / picard_config / draft_config) —
        // the same source server::run_sampler builds its engines from,
        // so fused and solo execution of a request can never drift
        // apart. The pool field is irrelevant here: machines never call
        // the (target) model.
        Ok(match sampler {
            SamplerSpec::Sequential => Machine::Sequential(
                SequentialStepMachine::new(model, noise, cond)?),
            SamplerSpec::Asd(theta) => {
                let cfg = SamplerSpec::asd_config(theta,
                                                  PoolConfig::default());
                Machine::Asd(Box::new(AsdStepMachine::new(
                    model, cfg.theta, cfg.eval_tail, cfg.backend, noise,
                    cond)?))
            }
            SamplerSpec::Picard(window, tol) => {
                let cfg = SamplerSpec::picard_config(window, tol,
                                                     PoolConfig::default());
                Machine::Picard(PicardStepMachine::new(
                    model, cfg.window, cfg.tol, cfg.max_sweeps, noise,
                    cond)?)
            }
            SamplerSpec::Draft(k) => {
                let draft = draft.ok_or_else(|| anyhow::anyhow!(
                    "no draft model paired for this variant (pair one \
                     with Coordinator::pair_draft before submitting \
                     draft requests)"))?;
                let cfg = SamplerSpec::draft_config(k,
                                                    PoolConfig::default());
                Machine::Draft(Box::new(DraftStepMachine::new(
                    model, draft, cfg.k, cfg.adaptive, noise, cond)?))
            }
        })
    }

    fn as_step(&mut self) -> &mut dyn StepSampler {
        match self {
            Machine::Sequential(m) => m,
            Machine::Asd(m) => m.as_mut(),
            Machine::Picard(m) => m,
            Machine::Draft(m) => m.as_mut(),
        }
    }

    /// (model_calls, parallel_rounds, asd_stats) for the response.
    fn outcome(self) -> (usize, usize, Option<AsdStats>) {
        match self {
            Machine::Sequential(m) => {
                let st = m.into_stats();
                (st.model_calls, st.model_calls, None)
            }
            Machine::Asd(m) => {
                let st = m.into_stats();
                (st.model_calls, st.parallel_rounds, Some(st))
            }
            Machine::Picard(m) => {
                let st = m.into_stats();
                (st.model_calls, st.parallel_rounds, None)
            }
            Machine::Draft(m) => {
                let st = m.into_stats();
                (st.model_calls, st.parallel_rounds, Some(st))
            }
        }
    }
}

struct ActiveRequest {
    job: QueuedJob,
    machine: Machine,
    /// queue wait, frozen at admission
    queued_s: f64,
    admitted: Instant,
    /// from-scratch restarts consumed after faulted fused rounds
    retries: u32,
    /// backoff: rounds left to skip before this request polls again
    wait_rounds: u32,
}

pub(crate) struct FusionScheduler {
    model: Arc<dyn DenoiseModel>,
    /// paired draft model for `SamplerSpec::Draft` requests on this
    /// lane (None = draft requests fail cleanly at admission)
    draft: Option<Arc<dyn DenoiseModel>>,
    /// the lane label this scheduler reports per-lane metrics under
    lane: String,
    active: Vec<ActiveRequest>,
    /// round staging arena, reused across rounds and fusion groups
    arena: RoundArena,
    /// (active index, arena span) per demanding request this round
    spans: Vec<(usize, ArenaSpan)>,
    /// execution report staged between `execute_round` and
    /// `finish_round`
    round: Option<RoundExec>,
    /// fused-call failure staged for `finish_round` to run recovery on
    /// (structured reason when the failure class is known, plus the
    /// display message)
    round_err: Option<(Option<FailReason>, String)>,
    /// (t0, shards) staged by `compile_round` for `complete_round` to
    /// turn into the execution report once the pool finishes the graph
    staged_graph: Option<(Instant, usize)>,
    /// failure-recovery knobs (retry budget, backoff, breaker)
    recovery: RecoveryPolicy,
    /// per-lane circuit breaker gating admissions
    breaker: Breaker,
}

impl FusionScheduler {
    /// `model` should already be `ParallelModel`-wrapped so fused
    /// rounds shard on the global worker pool (reported occupancy
    /// comes from `model.round_shards`). `arena_byte_cap`
    /// bounds the lane arena's grow-to-high-water buffers: once the
    /// lane drains, a footprint past the cap is released instead of
    /// pinning a burst's memory forever (0 = unbounded, the pre-cap
    /// behavior).
    pub(crate) fn new(model: Arc<dyn DenoiseModel>,
                      draft: Option<Arc<dyn DenoiseModel>>, lane: &str,
                      arena_byte_cap: usize, recovery: RecoveryPolicy)
                      -> FusionScheduler {
        let mut arena = RoundArena::for_model(model.as_ref());
        arena.set_byte_cap(arena_byte_cap);
        FusionScheduler {
            model,
            draft,
            lane: lane.to_string(),
            active: Vec::new(),
            arena,
            spans: Vec::new(),
            round: None,
            round_err: None,
            staged_graph: None,
            recovery,
            breaker: Breaker::new(),
        }
    }

    /// Whether this lane has a paired draft model — `Lane::admit`
    /// rejects `SamplerSpec::Draft` jobs *before* they are counted
    /// admitted when it doesn't.
    pub(crate) fn has_draft(&self) -> bool {
        self.draft.is_some()
    }

    /// Breaker admission gate (see [`Breaker::admit`]).
    pub(crate) fn breaker_admits(&mut self) -> bool {
        self.breaker.admit()
    }

    /// Hot-swap the lane's model (and paired draft) —
    /// `Coordinator::reload_variant`. Already-built machines keep
    /// their own `Arc` clones of the old model's metadata and finish
    /// untouched; fused *calls* route through the new model from the
    /// next round, and retries/new admissions build against it. The
    /// caller guarantees matching geometry (dim / cond_dim / k_steps),
    /// so the arena carries over as-is.
    pub(crate) fn set_model(&mut self, model: Arc<dyn DenoiseModel>,
                            draft: Option<Arc<dyn DenoiseModel>>) {
        self.model = model;
        self.draft = draft;
    }

    pub(crate) fn len(&self) -> usize {
        self.active.len()
    }

    pub(crate) fn is_empty(&self) -> bool {
        self.active.is_empty()
    }

    /// Admit a request: build its machine, or answer immediately with
    /// the construction error (bad conditioning shape, ...).
    pub(crate) fn admit(&mut self, job: QueuedJob, metrics: &Metrics) {
        let queued_s = job.enqueued.elapsed().as_secs_f64();
        match Machine::for_request(self.model.clone(), self.draft.clone(),
                                   job.request.sampler, job.request.seed,
                                   &job.request.cond) {
            Ok(machine) => {
                metrics.on_lane_admit(&self.lane, queued_s);
                self.active.push(ActiveRequest {
                    job,
                    machine,
                    queued_s,
                    admitted: Instant::now(),
                    retries: 0,
                    wait_rounds: 0,
                });
            }
            Err(e) => {
                metrics.on_complete(queued_s, 0.0, 0, 0, true);
                let _ = job.reply.send(Response::failed(job.request.id,
                                                        queued_s,
                                                        &e.to_string()));
            }
        }
    }

    /// Phase 1 — poll: retire finished machines (answering their
    /// requests), then stage every remaining machine's demand directly
    /// into the arena. Returns the number of requests completed.
    pub(crate) fn begin_round(&mut self, metrics: &Metrics) -> usize {
        self.arena.begin_round();
        self.spans.clear();
        self.round = None;
        self.round_err = None;
        self.staged_graph = None;
        let mut completed = 0usize;
        let mut idx = 0usize;
        while idx < self.active.len() {
            // deadline sweep: an expired in-flight request is
            // cancelled here, at the round boundary — its rows are
            // simply never staged, so the arena reclaims them with
            // this round's begin_round reset
            if self.active[idx].job.expired() {
                metrics.on_timeout(&self.lane, true);
                self.fail_at(idx, Some(FailReason::Timeout),
                             "deadline exceeded (request cancelled at \
                              round boundary)", metrics);
                continue;
            }
            // backoff: a retried request sits out its wait without
            // contributing rows (rounds, not wall-clock — see
            // RecoveryPolicy)
            if self.active[idx].wait_rounds > 0 {
                self.active[idx].wait_rounds -= 1;
                idx += 1;
                continue;
            }
            match self.active[idx].machine.as_step()
                .poll_into(&mut self.arena)
            {
                Err(e) => {
                    let msg = e.to_string();
                    self.fail_at(idx, None, &msg, metrics);
                    // swap_remove moved an unpolled request into `idx`
                }
                Ok(None) => {
                    // done: fetch the final sample through `poll`
                    match self.active[idx].machine.as_step().poll() {
                        Ok(SamplerPoll::Done(y0)) => {
                            let sample = y0.to_vec();
                            self.finish_at(idx, sample, metrics);
                            completed += 1;
                        }
                        Ok(SamplerPoll::Demand(_)) => {
                            self.fail_at(idx, None,
                                         "machine demanded rows after \
                                          reporting done", metrics);
                        }
                        Err(e) => {
                            let msg = e.to_string();
                            self.fail_at(idx, None, &msg, metrics);
                        }
                    }
                }
                Ok(Some(span)) => {
                    self.spans.push((idx, span));
                    idx += 1;
                }
            }
        }
        if self.active.is_empty() {
            // lane drained: release an over-cap burst footprint (no-op
            // while under the byte cap or uncapped)
            self.arena.shrink_to_cap();
        }
        completed
    }

    /// Whether phase 1 staged any rows (so a round must execute).
    pub(crate) fn has_round(&self) -> bool {
        !self.spans.is_empty()
    }

    /// Phase 2a (graph path) — compile the fused round into a
    /// barrier-free tile graph for the driver to submit straight to
    /// the worker pool, instead of wrapping the whole round in one
    /// opaque `execute_round` task. Returns `None` when the model has
    /// no graph form (the driver falls back to `execute_round`) or
    /// when compilation failed — the error is staged, so a subsequent
    /// `execute_round` no-ops and `finish_round` fails the group.
    /// Round latency is stamped from here: it covers graph build plus
    /// pool execution, directly comparable to `execute_round`'s span.
    /// The returned graph holds raw pointers into the lane's arena —
    /// sound under the standing driver contract that an inflight
    /// lane's state is untouched until its completion arrives.
    pub(crate) fn compile_round(&mut self) -> Option<TileGraph> {
        if self.spans.is_empty() {
            return None;
        }
        let t0 = Instant::now();
        let shards = self.model.round_shards(self.arena.rows());
        let outcome = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                self.model.compile_round(&mut self.arena)
            }));
        match outcome {
            Ok(Ok(Some(graph))) => {
                self.staged_graph = Some((t0, shards));
                Some(graph)
            }
            Ok(Ok(None)) => None,
            Ok(Err(e)) => {
                self.round_err = Some((None, e.to_string()));
                None
            }
            Err(_) => {
                self.round_err = Some((
                    Some(FailReason::ModelPanic),
                    "model call panicked during round compilation".into(),
                ));
                None
            }
        }
    }

    /// Phase 2b (graph path) — the driver observed the round's
    /// completion notification from the pool: turn the staged stamp
    /// into the execution report `finish_round` reads. `panicked`
    /// relays the pool's tile-panic flag; it fails the group exactly
    /// like an `execute_round` panic (dependents of the failed tile
    /// never ran, so the arena's output region is simply discarded).
    /// Returns whether a graph round was actually staged — `false`
    /// tells the driver this was a closure round (whose report, or
    /// panic, is handled on the closure path).
    pub(crate) fn complete_round(&mut self, panicked: bool) -> bool {
        let Some((t0, shards)) = self.staged_graph.take() else {
            return false;
        };
        if panicked {
            self.round_err = Some((
                Some(FailReason::TilePanic),
                "tile panicked during fused graph round (dependents \
                 cancelled)".into(),
            ));
        } else {
            self.round = Some(RoundExec {
                latency_s: t0.elapsed().as_secs_f64(),
                shards,
            });
        }
        true
    }

    /// Phase 2 (closure path) — execute the fused call over the arena.
    /// Takes no locks and touches only lane-owned state, so lane
    /// drivers co-schedule many lanes' `execute_round`s concurrently
    /// on the global pool. Panics inside the model call (including
    /// re-raised pool shard panics) are contained here and fail the
    /// group like an `Err` — a panicking model must not unwind the
    /// lane driver, which would leave this lane's variant claimed and
    /// unservable forever. No-ops when `compile_round` already staged
    /// a failure for this round.
    pub(crate) fn execute_round(&mut self) {
        if self.spans.is_empty() || self.round_err.is_some() {
            return;
        }
        let t0 = Instant::now();
        // the model's own routing decision (row shards, or the whole
        // pool for graph-compiled rounds) — not shards_for, which
        // under-reports occupancy for graph rounds
        let shards = self.model.round_shards(self.arena.rows());
        let outcome = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                self.model.denoise_round(&mut self.arena)
            }));
        match outcome {
            Ok(Ok(())) => {
                self.round = Some(RoundExec {
                    latency_s: t0.elapsed().as_secs_f64(),
                    shards,
                });
            }
            Ok(Err(e)) => self.round_err = Some((None, e.to_string())),
            Err(_) => {
                self.round_err = Some((
                    Some(FailReason::ModelPanic),
                    "model call panicked during fused round".into(),
                ));
            }
        }
    }

    /// Phase 3 — scatter: resume every demanding machine from its view
    /// into the arena's output region. A fused-call failure runs
    /// recovery instead: every participant of the faulted call either
    /// restarts from scratch (bounded, backed-off) or — budget spent —
    /// fails with the round's `FailReason`; requests sitting out a
    /// backoff were never in the call and are untouched.
    pub(crate) fn finish_round(&mut self, metrics: &Metrics) {
        if self.spans.is_empty() {
            return;
        }
        if let Some((reason, msg)) = self.round_err.take() {
            self.recover_round(reason, &msg, metrics);
            return;
        }
        self.breaker.on_success();
        let exec = self.round.take()
            .expect("finish_round without execute_round");
        let rows = self.arena.rows();
        metrics.on_fused_round(&self.lane, rows, self.spans.len(),
                               exec.shards,
                               self.model.round_barriers(rows),
                               exec.latency_s,
                               self.arena.high_water_bytes()
                                   .max(self.arena.bytes()));
        // Failures are answered immediately but removed only after the
        // loop, so the span indices stay valid throughout.
        let mut failed: Vec<usize> = Vec::new();
        for &(idx, span) in &self.spans {
            // non-finite output validation: the fused call succeeded,
            // but THIS request's rows came back NaN/Inf — fail only
            // the offending request, never the lane or its roundmates
            if self.recovery.validate_outputs
                && !self.arena.out_rows(span).iter()
                    .all(|v| v.is_finite())
            {
                let ar = &self.active[idx];
                metrics.on_complete(ar.queued_s,
                                    ar.admitted.elapsed().as_secs_f64(),
                                    0, 0, true);
                let mut resp = Response::failed_with(
                    ar.job.request.id, ar.queued_s, FailReason::NonFinite,
                    "non-finite model output in this request's rows");
                resp.retries = ar.retries;
                let _ = ar.job.reply.send(resp);
                failed.push(idx);
                continue;
            }
            if let Err(e) = self.active[idx].machine.as_step()
                .resume_from(&self.arena, span, exec)
            {
                let ar = &self.active[idx];
                metrics.on_complete(ar.queued_s,
                                    ar.admitted.elapsed().as_secs_f64(),
                                    0, 0, true);
                let _ = ar.job.reply.send(Response::failed(
                    ar.job.request.id, ar.queued_s, &e.to_string()));
                failed.push(idx);
            }
        }
        // remove highest-index first so earlier indices stay stable
        failed.sort_unstable_by(|a, b| b.cmp(a));
        for idx in failed {
            self.active.swap_remove(idx);
        }
        self.spans.clear();
    }

    /// The staged round failed as a unit (panic, tile panic, or model
    /// error). Feed the breaker, then quarantine-and-retry: each
    /// participant with retry budget left gets a from-scratch machine
    /// (bit-transparent — pure function of `(seed, cond)`) plus an
    /// exponential round-count backoff; the rest fail with the round's
    /// reason. Fix for the old behavior where one poisoned row failed
    /// the whole fused group irrecoverably.
    fn recover_round(&mut self, reason: Option<FailReason>, msg: &str,
                     metrics: &Metrics) {
        if self.breaker.on_failure(&self.recovery) {
            metrics.on_breaker_trip(&self.lane);
        }
        let mut failed: Vec<usize> = Vec::new();
        for i in 0..self.spans.len() {
            let idx = self.spans[i].0;
            if self.active[idx].retries >= self.recovery.retry_max {
                failed.push(idx);
                continue;
            }
            let (sampler, seed, cond) = {
                let r = &self.active[idx].job.request;
                (r.sampler, r.seed, r.cond.clone())
            };
            match Machine::for_request(self.model.clone(),
                                       self.draft.clone(), sampler, seed,
                                       &cond) {
                Ok(machine) => {
                    let ar = &mut self.active[idx];
                    ar.retries += 1;
                    ar.machine = machine;
                    let shift = (ar.retries - 1).min(16);
                    ar.wait_rounds = self.recovery.backoff_rounds
                        .saturating_mul(1u32 << shift);
                    metrics.on_retry(&self.lane);
                }
                // unreachable in practice (the machine was already
                // built once at admission); fail cleanly if it happens
                Err(_) => failed.push(idx),
            }
        }
        failed.sort_unstable_by(|a, b| b.cmp(a));
        for idx in failed {
            self.fail_at(idx, reason, msg, metrics);
        }
        self.spans.clear();
    }

    /// One full round — poll, execute, scatter — for single-lane
    /// drivers and tests. Returns the number of requests completed.
    pub(crate) fn tick(&mut self, metrics: &Metrics) -> usize {
        let completed = self.begin_round(metrics);
        self.execute_round();
        self.finish_round(metrics);
        completed
    }

    /// Answer and remove the request at `idx` (success).
    fn finish_at(&mut self, idx: usize, sample: Vec<f64>,
                 metrics: &Metrics) {
        let ar = self.active.swap_remove(idx);
        let service_s = ar.admitted.elapsed().as_secs_f64();
        let (calls, rounds, asd_stats) = ar.machine.outcome();
        if let Some(st) = &asd_stats {
            metrics.on_round_stats(&st.round_latency_s, &st.round_shards);
            metrics.on_grs_stats(&self.lane, st.accepted, st.rejected,
                                 st.iterations);
        }
        metrics.on_complete(ar.queued_s, service_s, calls, rounds, false);
        let _ = ar.job.reply.send(Response {
            id: ar.job.request.id,
            sample,
            model_calls: calls,
            parallel_rounds: rounds,
            asd_stats,
            queued_s: ar.queued_s,
            service_s,
            rejected: false,
            error: None,
            reason: None,
            retries: ar.retries,
        });
    }

    /// Answer and remove the request at `idx` (failure).
    fn fail_at(&mut self, idx: usize, reason: Option<FailReason>, msg: &str,
               metrics: &Metrics) {
        let ar = self.active.swap_remove(idx);
        metrics.on_complete(ar.queued_s, ar.admitted.elapsed().as_secs_f64(),
                            0, 0, true);
        let mut resp = Response::failed(ar.job.request.id, ar.queued_s, msg);
        resp.reason = reason;
        resp.retries = ar.retries;
        let _ = ar.job.reply.send(resp);
    }

    /// Fail every in-flight request (the lane itself is unusable —
    /// driver-level panic containment and teardown paths).
    pub(crate) fn fail_all(&mut self, reason: Option<FailReason>, msg: &str,
                           metrics: &Metrics) {
        for ar in self.active.drain(..) {
            metrics.on_complete(ar.queued_s,
                                ar.admitted.elapsed().as_secs_f64(), 0, 0,
                                true);
            let mut resp = Response::failed(ar.job.request.id, ar.queued_s,
                                            msg);
            resp.reason = reason;
            resp.retries = ar.retries;
            let _ = ar.job.reply.send(resp);
        }
        self.spans.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Request;
    use crate::ddpm::SequentialSampler;
    use crate::model::{Gmm, GmmDdpmOracle};
    use std::sync::mpsc::{channel, Receiver};

    fn queued(variant: &str, sampler: SamplerSpec, seed: u64)
              -> (QueuedJob, Receiver<Response>) {
        let (tx, rx) = channel();
        (QueuedJob {
            request: Request {
                id: seed,
                variant: variant.into(),
                sampler,
                seed,
                cond: vec![],
                deadline: None,
            },
            reply: tx,
            enqueued: Instant::now(),
        }, rx)
    }

    #[test]
    fn fused_sequential_pair_runs_lockstep_and_matches_solo() {
        let model: Arc<dyn DenoiseModel> =
            GmmDdpmOracle::new(Gmm::circle_2d(), 30, false);
        let metrics = Metrics::default();
        let mut sched = FusionScheduler::new(model.clone(), None, "gmm", 0,
                                             RecoveryPolicy::default());
        let (j1, rx1) = queued("gmm", SamplerSpec::Sequential, 5);
        let (j2, rx2) = queued("gmm", SamplerSpec::Sequential, 6);
        sched.admit(j1, &metrics);
        sched.admit(j2, &metrics);
        let mut ticks = 0usize;
        while !sched.is_empty() {
            sched.tick(&metrics);
            ticks += 1;
            assert!(ticks < 100, "fused group failed to drain");
        }
        // K demand ticks + 1 retire tick
        assert_eq!(ticks, 31);
        let solo = SequentialSampler::new(model);
        for (rx, seed) in [(rx1, 5u64), (rx2, 6u64)] {
            let r = rx.recv().unwrap();
            assert!(r.error.is_none());
            assert_eq!(r.model_calls, 30);
            let (want, _) = solo.sample(seed, &[]).unwrap();
            let bits = |v: &[f64]| -> Vec<u64> {
                v.iter().map(|x| x.to_bits()).collect()
            };
            assert_eq!(bits(&r.sample), bits(&want), "seed {seed}");
        }
        // every lockstep round fused both requests' rows
        let m = metrics.snapshot();
        assert_eq!(m.fused_rounds, 30);
        assert!((m.fused_rows_per_round - 2.0).abs() < 1e-12,
                "rows/round {}", m.fused_rows_per_round);
        // the lane label carries the per-lane aggregates
        let lane = m.lane("gmm").unwrap();
        assert_eq!(lane.fused_rounds, 30);
        assert_eq!(lane.admitted, 2);
        assert!((lane.fused_rows_per_round - 2.0).abs() < 1e-12);
    }

    #[test]
    fn mixed_group_completes_and_no_request_starves() {
        let model: Arc<dyn DenoiseModel> =
            GmmDdpmOracle::new(Gmm::circle_2d(), 40, false);
        let metrics = Metrics::default();
        let mut sched = FusionScheduler::new(model, None, "gmm", 0,
                                             RecoveryPolicy::default());
        let (j1, rx1) = queued("gmm", SamplerSpec::Asd(8), 1);
        let (j2, rx2) = queued("gmm", SamplerSpec::Sequential, 2);
        let (j3, rx3) = queued("gmm", SamplerSpec::Picard(8, 1e-6), 3);
        sched.admit(j1, &metrics);
        sched.admit(j2, &metrics);
        sched.admit(j3, &metrics);
        let mut ticks = 0usize;
        while !sched.is_empty() {
            sched.tick(&metrics);
            ticks += 1;
            assert!(ticks < 10_000, "mixed group failed to drain");
        }
        let r1 = rx1.recv().unwrap();
        let r2 = rx2.recv().unwrap();
        let r3 = rx3.recv().unwrap();
        assert!(r1.error.is_none() && r2.error.is_none()
                && r3.error.is_none());
        assert!(r1.asd_stats.is_some());
        // the sequential request needs exactly K rounds; the group must
        // not have made it wait for the others to finish first
        assert_eq!(r2.model_calls, 40);
        assert!(r1.parallel_rounds < 40, "asd {}", r1.parallel_rounds);
        assert!(r3.parallel_rounds >= 5);
        // while >= 2 requests were in flight, rounds were fused
        let m = metrics.snapshot();
        assert!(m.fused_rows_per_round > 1.0,
                "rows/round {}", m.fused_rows_per_round);
    }

    #[test]
    fn drained_lane_releases_an_over_cap_arena() {
        let model: Arc<dyn DenoiseModel> =
            GmmDdpmOracle::new(Gmm::circle_2d(), 15, false);
        let metrics = Metrics::default();
        // a 1-byte cap: any staged round overflows it, so the drain
        // must release the buffers entirely
        let mut sched = FusionScheduler::new(model, None, "gmm", 1,
                                             RecoveryPolicy::default());
        let (j, rx) = queued("gmm", SamplerSpec::Sequential, 4);
        sched.admit(j, &metrics);
        let mut ticks = 0usize;
        while !sched.is_empty() {
            sched.tick(&metrics);
            ticks += 1;
            assert!(ticks < 100, "failed to drain");
        }
        assert!(rx.recv().unwrap().error.is_none());
        assert_eq!(sched.arena.bytes(), 0,
                   "drained lane kept an over-cap arena");
        // the burst footprint reached metrics before the release
        let hw = metrics.snapshot().lane("gmm").unwrap()
            .arena_high_water_bytes;
        assert!(hw > 0, "lane high-water gauge never recorded");
    }

    #[test]
    fn bad_conditioning_is_answered_at_admission() {
        let model: Arc<dyn DenoiseModel> =
            GmmDdpmOracle::new(Gmm::circle_2d(), 10, false);
        let metrics = Metrics::default();
        let mut sched = FusionScheduler::new(model, None, "gmm", 0,
                                             RecoveryPolicy::default());
        let (tx, rx) = channel();
        sched.admit(QueuedJob {
            request: Request {
                id: 7,
                variant: "gmm".into(),
                sampler: SamplerSpec::Sequential,
                seed: 0,
                cond: vec![1.0, 2.0], // model is unconditional
                deadline: None,
            },
            reply: tx,
            enqueued: Instant::now(),
        }, &metrics);
        assert!(sched.is_empty());
        let r = rx.recv().unwrap();
        assert!(r.error.unwrap().contains("cond_dim"));
        assert_eq!(metrics.snapshot().failed, 1);
    }

    #[test]
    fn split_phases_equal_one_tick() {
        // a lane driver calling begin/execute/finish must behave
        // exactly like the one-shot tick
        let model: Arc<dyn DenoiseModel> =
            GmmDdpmOracle::new(Gmm::circle_2d(), 20, false);
        let metrics = Metrics::default();
        let mut sched = FusionScheduler::new(model, None, "gmm", 0,
                                             RecoveryPolicy::default());
        let (j, rx) = queued("gmm", SamplerSpec::Sequential, 9);
        sched.admit(j, &metrics);
        let mut rounds = 0usize;
        while !sched.is_empty() {
            sched.begin_round(&metrics);
            if sched.has_round() {
                rounds += 1;
            }
            sched.execute_round();
            sched.finish_round(&metrics);
            assert!(rounds <= 20, "failed to drain");
        }
        assert_eq!(rounds, 20);
        let r = rx.recv().unwrap();
        assert!(r.error.is_none());
        assert_eq!(r.model_calls, 20);
    }

    use crate::schedule::DdpmSchedule;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    /// Fails the first `remaining` fused rounds with an `Err`, then
    /// delegates cleanly — the minimal fault the retry path must
    /// absorb.
    struct FailFirst {
        inner: Arc<dyn DenoiseModel>,
        remaining: AtomicUsize,
    }

    impl DenoiseModel for FailFirst {
        fn dim(&self) -> usize {
            self.inner.dim()
        }
        fn cond_dim(&self) -> usize {
            self.inner.cond_dim()
        }
        fn k_steps(&self) -> usize {
            self.inner.k_steps()
        }
        fn schedule(&self) -> &DdpmSchedule {
            self.inner.schedule()
        }
        fn denoise_batch(&self, ys: &[f64], ts: &[f64], cond: &[f64],
                         n: usize, out: &mut [f64]) -> Result<()> {
            self.inner.denoise_batch(ys, ts, cond, n, out)
        }
        fn denoise_round(&self, arena: &mut RoundArena) -> Result<()> {
            let r = self.remaining.load(Ordering::SeqCst);
            if r > 0 {
                self.remaining.store(r - 1, Ordering::SeqCst);
                anyhow::bail!("injected round failure");
            }
            self.inner.denoise_round(arena)
        }
    }

    #[test]
    fn faulted_round_retries_from_scratch_bit_identically() {
        let inner: Arc<dyn DenoiseModel> =
            GmmDdpmOracle::new(Gmm::circle_2d(), 20, false);
        let model: Arc<dyn DenoiseModel> = Arc::new(FailFirst {
            inner: inner.clone(),
            remaining: AtomicUsize::new(1),
        });
        let metrics = Metrics::default();
        let mut sched = FusionScheduler::new(model, None, "gmm", 0,
                                             RecoveryPolicy::default());
        let (j, rx) = queued("gmm", SamplerSpec::Sequential, 5);
        sched.admit(j, &metrics);
        let mut ticks = 0usize;
        while !sched.is_empty() {
            sched.tick(&metrics);
            ticks += 1;
            assert!(ticks < 200, "retried request failed to drain");
        }
        let r = rx.recv().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.retries, 1);
        // retry-from-scratch is bit-transparent
        let (want, _) = SequentialSampler::new(inner).sample(5, &[]).unwrap();
        let bits = |v: &[f64]| -> Vec<u64> {
            v.iter().map(|x| x.to_bits()).collect()
        };
        assert_eq!(bits(&r.sample), bits(&want));
        let m = metrics.snapshot();
        assert_eq!(m.retried, 1);
        assert_eq!(m.failed, 0);
        assert_eq!(m.lane("gmm").unwrap().retried, 1);
    }

    /// Always panics in the fused call.
    struct AlwaysPanics(Arc<dyn DenoiseModel>);

    impl DenoiseModel for AlwaysPanics {
        fn dim(&self) -> usize {
            self.0.dim()
        }
        fn cond_dim(&self) -> usize {
            self.0.cond_dim()
        }
        fn k_steps(&self) -> usize {
            self.0.k_steps()
        }
        fn schedule(&self) -> &DdpmSchedule {
            self.0.schedule()
        }
        fn denoise_batch(&self, ys: &[f64], ts: &[f64], cond: &[f64],
                         n: usize, out: &mut [f64]) -> Result<()> {
            self.0.denoise_batch(ys, ts, cond, n, out)
        }
        fn denoise_round(&self, _arena: &mut RoundArena) -> Result<()> {
            panic!("injected model panic");
        }
    }

    #[test]
    fn exhausted_retry_budget_fails_with_model_panic_reason() {
        let inner: Arc<dyn DenoiseModel> =
            GmmDdpmOracle::new(Gmm::circle_2d(), 10, false);
        let model: Arc<dyn DenoiseModel> = Arc::new(AlwaysPanics(inner));
        let metrics = Metrics::default();
        let recovery = RecoveryPolicy {
            retry_max: 1,
            backoff_rounds: 0,
            ..RecoveryPolicy::default()
        };
        let mut sched =
            FusionScheduler::new(model, None, "gmm", 0, recovery);
        let (j, rx) = queued("gmm", SamplerSpec::Sequential, 3);
        sched.admit(j, &metrics);
        let mut ticks = 0usize;
        while !sched.is_empty() {
            sched.tick(&metrics);
            ticks += 1;
            assert!(ticks < 50, "failed request did not drain");
        }
        let r = rx.recv().unwrap();
        assert_eq!(r.reason, Some(FailReason::ModelPanic));
        assert!(r.error.as_deref().unwrap().contains("panicked"));
        assert_eq!(r.retries, 1);
        let m = metrics.snapshot();
        assert_eq!(m.failed, 1);
        assert_eq!(m.retried, 1);
    }

    #[test]
    fn breaker_opens_after_streak_and_half_open_probe_recovers() {
        let inner: Arc<dyn DenoiseModel> =
            GmmDdpmOracle::new(Gmm::circle_2d(), 10, false);
        let model: Arc<dyn DenoiseModel> = Arc::new(FailFirst {
            inner,
            remaining: AtomicUsize::new(2),
        });
        let metrics = Metrics::default();
        let recovery = RecoveryPolicy {
            retry_max: 0,
            breaker_threshold: 2,
            breaker_cooldown: Duration::from_millis(5),
            ..RecoveryPolicy::default()
        };
        let mut sched =
            FusionScheduler::new(model, None, "gmm", 0, recovery);
        for seed in [1u64, 2] {
            assert!(sched.breaker_admits(),
                    "breaker closed before threshold");
            let (j, rx) = queued("gmm", SamplerSpec::Sequential, seed);
            sched.admit(j, &metrics);
            while !sched.is_empty() {
                sched.tick(&metrics);
            }
            assert!(rx.recv().unwrap().error.is_some());
        }
        // streak hit the threshold: open, admissions refused
        assert!(!sched.breaker_admits(), "breaker failed to open");
        assert_eq!(metrics.snapshot().breaker_trips, 1);
        std::thread::sleep(Duration::from_millis(10));
        // cooldown elapsed: half-open probe admitted, model is healthy
        // again, the clean round closes the breaker
        assert!(sched.breaker_admits(), "cooldown did not half-open");
        let (j, rx) = queued("gmm", SamplerSpec::Sequential, 3);
        sched.admit(j, &metrics);
        let mut ticks = 0usize;
        while !sched.is_empty() {
            sched.tick(&metrics);
            ticks += 1;
            assert!(ticks < 50, "probe failed to drain");
        }
        assert!(rx.recv().unwrap().error.is_none());
        assert!(sched.breaker_admits(), "probe success did not close");
        assert_eq!(metrics.snapshot().breaker_trips, 1);
    }

    #[test]
    fn expired_deadline_is_cancelled_at_the_round_boundary() {
        let model: Arc<dyn DenoiseModel> =
            GmmDdpmOracle::new(Gmm::circle_2d(), 10, false);
        let metrics = Metrics::default();
        let mut sched = FusionScheduler::new(model, None, "gmm", 0,
                                             RecoveryPolicy::default());
        let (tx, rx) = channel();
        sched.admit(QueuedJob {
            request: Request {
                id: 1,
                variant: "gmm".into(),
                sampler: SamplerSpec::Sequential,
                seed: 1,
                cond: vec![],
                deadline: Some(Duration::ZERO),
            },
            reply: tx,
            enqueued: Instant::now(),
        }, &metrics);
        sched.tick(&metrics);
        assert!(sched.is_empty());
        let r = rx.recv().unwrap();
        assert_eq!(r.reason, Some(FailReason::Timeout));
        assert!(!r.rejected, "timeout is a failure, not a rejection");
        assert!(r.error.as_deref().unwrap().contains("deadline"));
        let m = metrics.snapshot();
        assert_eq!(m.timed_out, 1);
        assert_eq!(m.cancelled, 1);
        assert_eq!(m.failed, 1);
    }

    /// Corrupts row 0's output whenever a round fuses >= 2 rows —
    /// exactly one request's span goes non-finite.
    struct NanRow0(Arc<dyn DenoiseModel>);

    impl DenoiseModel for NanRow0 {
        fn dim(&self) -> usize {
            self.0.dim()
        }
        fn cond_dim(&self) -> usize {
            self.0.cond_dim()
        }
        fn k_steps(&self) -> usize {
            self.0.k_steps()
        }
        fn schedule(&self) -> &DdpmSchedule {
            self.0.schedule()
        }
        fn denoise_batch(&self, ys: &[f64], ts: &[f64], cond: &[f64],
                         n: usize, out: &mut [f64]) -> Result<()> {
            self.0.denoise_batch(ys, ts, cond, n, out)
        }
        fn denoise_round(&self, arena: &mut RoundArena) -> Result<()> {
            self.0.denoise_round(arena)?;
            let (_, _, _, n, out) = arena.round_io();
            if n >= 2 {
                out[0] = f64::NAN;
            }
            Ok(())
        }
    }

    #[test]
    fn non_finite_output_fails_only_the_offending_request() {
        let inner: Arc<dyn DenoiseModel> =
            GmmDdpmOracle::new(Gmm::circle_2d(), 15, false);
        let model: Arc<dyn DenoiseModel> = Arc::new(NanRow0(inner.clone()));
        let metrics = Metrics::default();
        let mut sched = FusionScheduler::new(model, None, "gmm", 0,
                                             RecoveryPolicy::default());
        let (j1, rx1) = queued("gmm", SamplerSpec::Sequential, 5);
        let (j2, rx2) = queued("gmm", SamplerSpec::Sequential, 6);
        sched.admit(j1, &metrics);
        sched.admit(j2, &metrics);
        let mut ticks = 0usize;
        while !sched.is_empty() {
            sched.tick(&metrics);
            ticks += 1;
            assert!(ticks < 100, "group failed to drain");
        }
        // request 1 owned row 0 of the first fused round: it alone
        // fails; its roundmate finishes with solo bits
        let r1 = rx1.recv().unwrap();
        assert_eq!(r1.reason, Some(FailReason::NonFinite));
        assert!(r1.error.as_deref().unwrap().contains("non-finite"));
        let r2 = rx2.recv().unwrap();
        assert!(r2.error.is_none(), "{:?}", r2.error);
        let (want, _) = SequentialSampler::new(inner).sample(6, &[]).unwrap();
        let bits = |v: &[f64]| -> Vec<u64> {
            v.iter().map(|x| x.to_bits()).collect()
        };
        assert_eq!(bits(&r2.sample), bits(&want));
        let m = metrics.snapshot();
        assert_eq!(m.failed, 1);
        assert_eq!(m.completed, 1);
    }
}
