//! Variant-keyed lane scheduling: per-variant admission queues and
//! claimable serving lanes.
//!
//! The pre-lane coordinator extracted *same-variant prefixes* from one
//! global FIFO, so a mixed-variant workload suffered cross-variant
//! head-of-line blocking: a worker drove one variant's fusion group to
//! completion while every other variant's requests sat behind it. The
//! lane scheduler removes both the prefix scan and the blocking:
//!
//! * **Variant-keyed queues** ([`LaneState`]): `submit` enqueues into
//!   the request's own variant queue — no cross-variant ordering
//!   exists, so no arrival can sit behind another variant's burst.
//!   Bounded admission (`max_queue_depth`) counts the *total* queued
//!   jobs across variants.
//! * **One lane per variant** ([`Lane`]): a lane owns the variant's
//!   model `Arc` (snapshotted once at lane creation — the models map
//!   is never locked on the round hot path), its `ParallelModel`
//!   wrapper, and its arena-based `FusionScheduler` (round arena +
//!   GEMM workspace persist across rounds and fusion groups: zero
//!   steady-state allocations).
//! * **Claim/release**: a worker *claims* every busy, unclaimed lane
//!   it can and drives them together — each lane's fused
//!   `denoise_round` is submitted to the one global pool as an
//!   independent round task the moment the lane stages rows, and
//!   re-submitted the moment it completes (`server::Driver`; no global
//!   tick). Two variants' rounds therefore overlap even on a single
//!   worker, each cycling at its own cadence; with more workers, lanes
//!   spread dynamically. A drained lane whose queue is empty is
//!   released back to the table for any worker to claim later.
//!
//! Per-variant FIFO order is preserved (each queue is popped from the
//! front only); cross-variant order is intentionally abandoned — lanes
//! make it meaningless, which is exactly the point.

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

use crate::coordinator::fusion::{FusionScheduler, RecoveryPolicy};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{FailReason, QueuedJob, Response,
                                  SamplerSpec};
use crate::faults::{ChaosModel, FaultPlan};
use crate::model::{DenoiseModel, ParallelModel};
use crate::runtime::pool::PoolConfig;

/// One variant's serving lane: the variant's model snapshot (wrapped
/// for pool sharding) plus its arena-based fusion scheduler. Created
/// lazily on the first request for the variant and kept for the
/// coordinator's lifetime, so its arena and workspace amortize to zero
/// allocations per round.
pub(crate) struct Lane {
    pub variant: String,
    sched: FusionScheduler,
    /// whether the current fusion group has been counted in the
    /// batched_groups metrics (a group is >= 2 concurrent requests)
    counted: bool,
    /// the pool config the lane wraps models with — kept so
    /// `set_model` re-wraps hot-reloaded snapshots identically
    pool: PoolConfig,
    /// fault-injection plan, when this coordinator runs under chaos
    /// (`ServerConfig::faults`); re-applied on reload
    faults: Option<FaultPlan>,
    /// which registry epoch this lane's model snapshot came from
    /// (`server::Shared::reload_epoch`); stale lanes get refreshed by
    /// the driver before serving
    pub(crate) epoch: u64,
}

impl Lane {
    /// Build the lane for `variant`, snapshotting the model `Arc` once
    /// — round execution never touches the registry again. `draft` is
    /// the variant's paired draft model, resolved once at lane creation
    /// (None = `SamplerSpec::Draft` requests fail cleanly at
    /// admission). `arena_byte_cap` bounds the lane arena's burst
    /// footprint (`ServerConfig::arena_byte_cap`; 0 = unbounded).
    /// `faults` injects deterministic faults into the lane's fused
    /// calls (chaos testing); `recovery` governs deadline/retry/breaker
    /// behavior.
    pub(crate) fn new(variant: &str, model: Arc<dyn DenoiseModel>,
                      draft: Option<Arc<dyn DenoiseModel>>,
                      pool: PoolConfig, arena_byte_cap: usize,
                      faults: Option<&FaultPlan>,
                      recovery: RecoveryPolicy) -> Lane {
        let faults = faults.cloned();
        let model = Lane::wrap_model(variant, model, pool, &faults);
        Lane {
            variant: variant.to_string(),
            sched: FusionScheduler::new(model, draft, variant,
                                        arena_byte_cap, recovery),
            counted: false,
            pool,
            faults,
            epoch: 0,
        }
    }

    /// The lane's model wrapping chain: `ParallelModel` for pool
    /// sharding, then (under chaos) `ChaosModel` *outside* it so fault
    /// decisions are per-round, never per-shard — injection stays
    /// bit-identical across pool sizes. The draft stays un-wrapped —
    /// its chain calls are single-row `denoise_one`s that never hit
    /// the round plane.
    fn wrap_model(variant: &str, model: Arc<dyn DenoiseModel>,
                  pool: PoolConfig, faults: &Option<FaultPlan>)
                  -> Arc<dyn DenoiseModel> {
        let model = ParallelModel::wrap(model, pool);
        match faults {
            Some(plan) => ChaosModel::wrap(model, plan.clone(), variant),
            None => model,
        }
    }

    /// Hot-swap the lane's model snapshot (`Coordinator::reload_variant`
    /// bumped the registry epoch): re-wrap the new snapshot with the
    /// same pool/chaos chain and hand it to the scheduler. In-flight
    /// machines keep their old `Arc` clones and finish untouched.
    pub(crate) fn set_model(&mut self, model: Arc<dyn DenoiseModel>,
                            draft: Option<Arc<dyn DenoiseModel>>,
                            epoch: u64) {
        let model = Lane::wrap_model(&self.variant, model, self.pool,
                                     &self.faults);
        self.sched.set_model(model, draft);
        self.epoch = epoch;
    }

    pub(crate) fn in_flight(&self) -> usize {
        self.sched.len()
    }

    pub(crate) fn is_idle(&self) -> bool {
        self.sched.is_empty()
    }

    /// Admit a batch of queued jobs into the lane's fused scheduler
    /// (draining `jobs`, whose allocation the caller reuses across
    /// rounds), keeping the group-formation counters consistent with the
    /// pre-lane batcher: the first time a group reaches >= 2 concurrent
    /// requests it counts as one batched group (founding members
    /// included); later admissions into a counted group count as fused
    /// admits.
    pub(crate) fn admit(&mut self, jobs: &mut Vec<QueuedJob>,
                        metrics: &Metrics) {
        if jobs.is_empty() {
            return;
        }
        // Pre-admission gate: answer jobs the scheduler must never see
        // BEFORE the group-formation counters run, so admitted/rejected
        // accounting only covers requests that actually entered the
        // fused scheduler.
        if !self.sched.breaker_admits() {
            // breaker open: the whole batch is turned away while the
            // lane cools down (half-open lets the next batch probe)
            for job in jobs.drain(..) {
                metrics.on_lane_reject(&self.variant);
                let resp = Response {
                    rejected: true,
                    reason: Some(FailReason::BreakerOpen),
                    error: Some(format!(
                        "rejected: lane '{}' circuit breaker open \
                         (cooling down after repeated round failures)",
                        self.variant)),
                    ..Response::failed(
                        job.request.id,
                        job.enqueued.elapsed().as_secs_f64(), "")
                };
                let _ = job.reply.send(resp);
            }
            return;
        }
        jobs.retain(|job| {
            let queued_s = job.enqueued.elapsed().as_secs_f64();
            if job.expired() {
                // dead on arrival: its budget ran out in the queue
                metrics.on_timeout(&self.variant, false);
                metrics.on_complete(queued_s, 0.0, 0, 0, true);
                let _ = job.reply.send(Response::failed_with(
                    job.request.id, queued_s, FailReason::Timeout,
                    "deadline exceeded while queued (request never \
                     admitted)"));
                return false;
            }
            if matches!(job.request.sampler, SamplerSpec::Draft(_))
                && !self.sched.has_draft()
            {
                // reject BEFORE counting: a draft request with no
                // paired draft model must not inflate the lane's
                // admitted/batched counters on its way to an error
                metrics.on_complete(queued_s, 0.0, 0, 0, true);
                let _ = job.reply.send(Response::failed_with(
                    job.request.id, queued_s, FailReason::NoDraftPairing,
                    "no draft model paired for this variant (pair one \
                     with Coordinator::pair_draft before submitting \
                     draft requests)"));
                return false;
            }
            true
        });
        if jobs.is_empty() {
            return;
        }
        if self.sched.is_empty() {
            self.counted = false; // a drained lane starts a new group
        }
        let new_total = self.sched.len() + jobs.len();
        if !self.counted && new_total >= 2 {
            metrics.on_batch(new_total);
            self.counted = true;
        } else if self.counted {
            metrics.on_fused_admit(jobs.len());
        }
        for job in jobs.drain(..) {
            self.sched.admit(job, metrics);
        }
    }

    /// Phase 1 of a round: retire finished requests, stage demands
    /// into the lane arena.
    pub(crate) fn begin_round(&mut self, metrics: &Metrics) {
        self.sched.begin_round(metrics);
    }

    /// Whether this lane staged rows and needs its fused call executed.
    pub(crate) fn has_round(&self) -> bool {
        self.sched.has_round()
    }

    /// Phase 2a: compile the staged round into a barrier-free tile
    /// graph for the driver to submit to the pool, or `None` to fall
    /// back to the opaque [`execute_round`](Self::execute_round) task
    /// (non-graph backend, or a staged compile error `finish_round`
    /// will report).
    pub(crate) fn compile_round(&mut self)
                                -> Option<crate::runtime::pool::TileGraph> {
        self.sched.compile_round()
    }

    /// Phase 2b (graph path): the round's completion notification
    /// arrived from the pool — stage the execution report. Returns
    /// whether a graph round was staged (false = closure round).
    pub(crate) fn complete_round(&mut self, panicked: bool) -> bool {
        self.sched.complete_round(panicked)
    }

    /// Phase 2: the lane's fused model call. Lock-free; runs as an
    /// independent round task on the global pool (`server::Driver`),
    /// concurrently with other lanes' rounds.
    pub(crate) fn execute_round(&mut self) {
        self.sched.execute_round();
    }

    /// Phase 3: resume machines from the arena's output region.
    pub(crate) fn finish_round(&mut self, metrics: &Metrics) {
        self.sched.finish_round(metrics);
    }

    /// Fail every in-flight request on this lane (a sampler machine
    /// panicked mid-round: its state is unusable, so the whole group is
    /// answered with an error instead of stranding clients).
    pub(crate) fn fail_all(&mut self, reason: Option<FailReason>,
                           msg: &str, metrics: &Metrics) {
        self.sched.fail_all(reason, msg, metrics);
    }
}

/// The coordinator's shared scheduling state, guarded by ONE mutex:
/// per-variant admission queues plus the lane table. A lane slot is
/// either parked (`Some(lane)` — claimable) or held by a worker
/// (`None`). Missing entries mean the lane hasn't been created yet.
pub(crate) struct LaneState {
    queues: HashMap<String, VecDeque<QueuedJob>>,
    /// total queued jobs across variants (bounded admission)
    depth: usize,
    slots: HashMap<String, Option<Box<Lane>>>,
}

/// Result of trying to claim a variant's lane.
pub(crate) enum LaneClaim {
    /// the lane existed and is now held by the caller
    Claimed(Box<Lane>),
    /// no lane yet — the slot is now marked held; the caller must
    /// create the lane (or `abandon` on unknown model)
    Create,
    /// another worker holds the lane
    Busy,
}

impl LaneState {
    pub(crate) fn new() -> LaneState {
        LaneState {
            queues: HashMap::new(),
            depth: 0,
            slots: HashMap::new(),
        }
    }

    /// Total queued jobs across all variants.
    pub(crate) fn depth(&self) -> usize {
        self.depth
    }

    pub(crate) fn enqueue(&mut self, job: QueuedJob) {
        self.depth += 1;
        self.queues
            .entry(job.request.variant.clone())
            .or_default()
            .push_back(job);
    }

    pub(crate) fn has_queued(&self, variant: &str) -> bool {
        self.queues.get(variant).is_some_and(|q| !q.is_empty())
    }

    /// Pop up to `max` front jobs for `variant` into `out` (arrival
    /// order). Returns how many were taken.
    pub(crate) fn take(&mut self, variant: &str, max: usize,
                       out: &mut Vec<QueuedJob>) -> usize {
        let Some(q) = self.queues.get_mut(variant) else { return 0 };
        let mut taken = 0usize;
        while taken < max {
            let Some(job) = q.pop_front() else { break };
            out.push(job);
            taken += 1;
        }
        self.depth -= taken;
        taken
    }

    /// Variants that currently have queued jobs, collected into the
    /// caller's reusable buffer (String allocations are recycled across
    /// calls — the per-round claim scan stays allocation-free in
    /// steady state).
    pub(crate) fn queued_variants(&self, out: &mut Vec<String>) {
        collect_names(self.queues.iter()
                          .filter(|(_, q)| !q.is_empty())
                          .map(|(v, _)| v),
                      out);
    }

    /// Variants whose *parked* lanes still hold in-flight machines.
    /// Normal releases only park drained lanes, so this is non-empty
    /// only after a panic recovery (`server::Driver`'s drop) parked a lane
    /// mid-flight — gather scans it so those requests resume instead of
    /// stranding their clients.
    pub(crate) fn parked_nonidle(&self, out: &mut Vec<String>) {
        collect_names(self.slots.iter()
                          .filter(|(_, slot)| {
                              slot.as_ref().is_some_and(|l| !l.is_idle())
                          })
                          .map(|(v, _)| v),
                      out);
    }

    /// Whether every lane slot is parked (not held by a worker) and
    /// idle — together with `depth() == 0` this is the "fully drained"
    /// condition `Coordinator::drain` waits on. A held slot (`None`)
    /// counts as not-idle: its worker may still be driving rounds.
    pub(crate) fn all_parked_idle(&self) -> bool {
        self.slots.values().all(|slot| {
            slot.as_ref().is_some_and(|l| l.is_idle())
        })
    }

    /// Pop the single globally-oldest queued job (by request id — ids
    /// are assigned monotonically at submission). The batching-off /
    /// `max_batch == 1` serving path.
    pub(crate) fn pop_oldest(&mut self) -> Option<QueuedJob> {
        let variant = self.queues.iter()
            .filter_map(|(v, q)| q.front().map(|j| (j.request.id, v)))
            .min()
            .map(|(_, v)| v.clone())?;
        let job = self.queues.get_mut(&variant)?.pop_front()?;
        self.depth -= 1;
        Some(job)
    }

    /// Try to claim `variant`'s lane (see [`LaneClaim`]).
    pub(crate) fn claim(&mut self, variant: &str) -> LaneClaim {
        match self.slots.get_mut(variant) {
            Some(slot) => match slot.take() {
                Some(lane) => LaneClaim::Claimed(lane),
                None => LaneClaim::Busy,
            },
            None => {
                self.slots.insert(variant.to_string(), None);
                LaneClaim::Create
            }
        }
    }

    /// Park a held lane back into the table.
    pub(crate) fn release(&mut self, lane: Box<Lane>) {
        let variant = lane.variant.clone();
        self.slots.insert(variant, Some(lane));
    }

    /// Undo a `LaneClaim::Create` whose model turned out unknown.
    pub(crate) fn abandon(&mut self, variant: &str) {
        self.slots.remove(variant);
    }

    /// Drain every queued job for `variant` (unknown-model failure).
    pub(crate) fn drain_variant(&mut self, variant: &str)
                                -> Vec<QueuedJob> {
        let Some(q) = self.queues.get_mut(variant) else {
            return Vec::new();
        };
        let jobs: Vec<QueuedJob> = q.drain(..).collect();
        self.depth -= jobs.len();
        jobs
    }
}

/// Fill `out` with the iterated names, recycling its existing String
/// allocations (clear + push_str instead of fresh clones).
fn collect_names<'a>(names: impl Iterator<Item = &'a String>,
                     out: &mut Vec<String>) {
    let mut n = 0usize;
    for name in names {
        if n < out.len() {
            out[n].clear();
            out[n].push_str(name);
        } else {
            out.push(name.clone());
        }
        n += 1;
    }
    out.truncate(n);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Request, SamplerSpec};
    use std::sync::mpsc::channel;
    use std::time::Instant;

    fn job(variant: &str, id: u64) -> QueuedJob {
        let (j, _rx) = job_with_rx(variant, id, SamplerSpec::Sequential);
        // leak the receiver: these tests never reply
        std::mem::forget(_rx);
        j
    }

    fn job_with_rx(variant: &str, id: u64, sampler: SamplerSpec)
                   -> (QueuedJob, std::sync::mpsc::Receiver<Response>) {
        let (tx, rx) = channel();
        (QueuedJob {
            request: Request {
                id,
                variant: variant.into(),
                sampler,
                seed: 0,
                cond: vec![],
                deadline: None,
            },
            reply: tx,
            enqueued: Instant::now(),
        }, rx)
    }

    #[test]
    fn queues_are_variant_keyed_and_depth_counts_all() {
        let mut st = LaneState::new();
        st.enqueue(job("a", 1));
        st.enqueue(job("b", 2));
        st.enqueue(job("a", 3));
        assert_eq!(st.depth(), 3);
        assert!(st.has_queued("a"));
        assert!(st.has_queued("b"));
        assert!(!st.has_queued("c"));
        // taking from `a` never disturbs `b` — no cross-variant
        // head-of-line blocking at the queue level
        let mut out = Vec::new();
        assert_eq!(st.take("a", 8, &mut out), 2);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].request.id, 1); // arrival order within lane
        assert_eq!(out[1].request.id, 3);
        assert_eq!(st.depth(), 1);
        assert!(st.has_queued("b"));
    }

    #[test]
    fn take_respects_cap() {
        let mut st = LaneState::new();
        for i in 0..10 {
            st.enqueue(job("a", i));
        }
        let mut out = Vec::new();
        assert_eq!(st.take("a", 4, &mut out), 4);
        assert_eq!(st.depth(), 6);
        assert_eq!(st.take("missing", 4, &mut out), 0);
    }

    #[test]
    fn queued_variants_lists_nonempty_lanes_only() {
        let mut st = LaneState::new();
        st.enqueue(job("a", 1));
        st.enqueue(job("b", 2));
        let mut out = Vec::new();
        st.take("b", 8, &mut out);
        let mut variants = Vec::new();
        st.queued_variants(&mut variants);
        assert_eq!(variants, vec!["a".to_string()]);
        // the scratch buffer recycles: growing and shrinking result
        // sets stay correct across calls
        st.enqueue(job("b", 9));
        st.queued_variants(&mut variants);
        variants.sort();
        assert_eq!(variants, vec!["a".to_string(), "b".to_string()]);
        st.take("a", 8, &mut out);
        st.take("b", 8, &mut out);
        st.queued_variants(&mut variants);
        assert!(variants.is_empty());
    }

    #[test]
    fn parked_nonidle_flags_only_lanes_with_in_flight_machines() {
        use crate::coordinator::metrics::Metrics;
        use crate::model::{Gmm, GmmDdpmOracle};
        let mut st = LaneState::new();
        let model: Arc<dyn DenoiseModel> =
            GmmDdpmOracle::new(Gmm::circle_2d(), 10, false);
        // an idle parked lane is NOT flagged
        st.release(Box::new(Lane::new("idle", model.clone(), None,
                                      PoolConfig::default(), 0, None,
                                      RecoveryPolicy::default())));
        let mut out = Vec::new();
        st.parked_nonidle(&mut out);
        assert!(out.is_empty());
        // a parked lane with an in-flight machine IS flagged (the
        // panic-recovery path)
        let metrics = Metrics::default();
        let mut lane = Box::new(Lane::new("busy", model, None,
                                          PoolConfig::default(), 0, None,
                                          RecoveryPolicy::default()));
        let mut batch = vec![job("busy", 1)];
        lane.admit(&mut batch, &metrics);
        assert!(!lane.is_idle());
        st.release(lane);
        st.parked_nonidle(&mut out);
        assert_eq!(out, vec!["busy".to_string()]);
    }

    #[test]
    fn unpaired_draft_requests_are_rejected_before_counting() {
        use crate::coordinator::metrics::Metrics;
        use crate::model::{Gmm, GmmDdpmOracle};
        let model: Arc<dyn DenoiseModel> =
            GmmDdpmOracle::new(Gmm::circle_2d(), 10, false);
        // no draft paired on this lane
        let mut lane = Lane::new("gmm", model, None,
                                 PoolConfig::default(), 0, None,
                                 RecoveryPolicy::default());
        let metrics = Metrics::default();
        let (seq, seq_rx) =
            job_with_rx("gmm", 1, SamplerSpec::Sequential);
        let (draft, draft_rx) =
            job_with_rx("gmm", 2, SamplerSpec::Draft(8));
        let mut batch = vec![seq, draft];
        lane.admit(&mut batch, &metrics);
        // the draft job was answered at the gate, pre-admission
        let resp = draft_rx.try_recv().expect("draft job answered");
        assert_eq!(resp.reason, Some(FailReason::NoDraftPairing));
        assert!(resp.error.unwrap().contains("pair_draft"));
        assert!(!resp.rejected); // admitted-then-failed taxonomy: failed
        // the sequential job entered the scheduler and is in flight
        assert!(seq_rx.try_recv().is_err());
        assert_eq!(lane.in_flight(), 1);
        let s = metrics.snapshot();
        // accounting: exactly the surviving request was admitted, the
        // gate never formed a >= 2 "batch group" around the reject
        assert_eq!(s.lane("gmm").unwrap().admitted, 1);
        assert_eq!(s.batched_groups, 0);
        assert_eq!(s.batched_requests, 0);
        assert_eq!(s.failed, 1);
    }

    #[test]
    fn pop_oldest_orders_across_variants_by_id() {
        let mut st = LaneState::new();
        st.enqueue(job("b", 5));
        st.enqueue(job("a", 3));
        st.enqueue(job("b", 7));
        assert_eq!(st.pop_oldest().unwrap().request.id, 3);
        assert_eq!(st.pop_oldest().unwrap().request.id, 5);
        assert_eq!(st.pop_oldest().unwrap().request.id, 7);
        assert!(st.pop_oldest().is_none());
        assert_eq!(st.depth(), 0);
    }

    #[test]
    fn claim_release_cycle_is_exclusive() {
        use crate::model::{Gmm, GmmDdpmOracle};
        let mut st = LaneState::new();
        // first claim of an unknown variant asks for creation and
        // blocks other claimants
        assert!(matches!(st.claim("a"), LaneClaim::Create));
        assert!(matches!(st.claim("a"), LaneClaim::Busy));
        let model: Arc<dyn DenoiseModel> =
            GmmDdpmOracle::new(Gmm::circle_2d(), 10, false);
        let lane = Box::new(Lane::new("a", model, None,
                                      PoolConfig::default(), 0, None,
                                      RecoveryPolicy::default()));
        st.release(lane);
        // parked lane is claimable exactly once
        assert!(matches!(st.claim("a"), LaneClaim::Claimed(_)));
        assert!(matches!(st.claim("a"), LaneClaim::Busy));
        // abandoning a failed creation makes the variant claimable anew
        st.abandon("a");
        assert!(matches!(st.claim("a"), LaneClaim::Create));
    }

    #[test]
    fn drain_variant_empties_one_queue_only() {
        let mut st = LaneState::new();
        st.enqueue(job("a", 1));
        st.enqueue(job("a", 2));
        st.enqueue(job("b", 3));
        let drained = st.drain_variant("a");
        assert_eq!(drained.len(), 2);
        assert_eq!(st.depth(), 1);
        assert!(st.has_queued("b"));
        assert!(st.drain_variant("missing").is_empty());
    }
}
