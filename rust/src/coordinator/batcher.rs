//! Dynamic batcher: gangs compatible queued requests.
//!
//! Sequential DDPM requests to the same variant advance in lockstep, so
//! they can share one batched denoise call per step — the classic
//! continuous-batching win. ASD requests are adaptive (each follows its
//! own accept/reject path) and run per-request; their parallelism is the
//! *within*-request batched verification.

use std::collections::VecDeque;

use crate::coordinator::request::{QueuedJob, SamplerSpec};

/// A unit of worker execution.
pub(crate) enum WorkItem {
    Single(QueuedJob),
    /// lockstep gang of sequential requests to the same variant
    SequentialGang(Vec<QueuedJob>),
}

/// Pop the next work item, ganging sequential requests for the same
/// variant (up to `max_batch`). Caller holds the queue lock.
pub(crate) fn next_work_item(queue: &mut VecDeque<QueuedJob>, max_batch: usize,
                             batching: bool) -> Option<WorkItem> {
    let first = queue.pop_front()?;
    if !batching || first.request.sampler != SamplerSpec::Sequential
        || max_batch <= 1
    {
        return Some(WorkItem::Single(first));
    }
    let variant = first.request.variant.clone();
    let mut gang = vec![first];
    let mut idx = 0;
    while gang.len() < max_batch && idx < queue.len() {
        let compatible = {
            let job = &queue[idx];
            job.request.sampler == SamplerSpec::Sequential
                && job.request.variant == variant
        };
        if compatible {
            gang.push(queue.remove(idx).unwrap());
        } else {
            idx += 1;
        }
    }
    if gang.len() == 1 {
        Some(WorkItem::Single(gang.pop().unwrap()))
    } else {
        Some(WorkItem::SequentialGang(gang))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Request;
    use std::sync::mpsc::channel;
    use std::time::Instant;

    fn job(variant: &str, sampler: SamplerSpec) -> QueuedJob {
        let (tx, _rx) = channel();
        // leak the receiver: these tests never reply
        std::mem::forget(_rx);
        QueuedJob {
            request: Request {
                id: 0,
                variant: variant.into(),
                sampler,
                seed: 0,
                cond: vec![],
            },
            reply: tx,
            enqueued: Instant::now(),
        }
    }

    #[test]
    fn gangs_same_variant_sequential() {
        let mut q: VecDeque<QueuedJob> = VecDeque::new();
        q.push_back(job("a", SamplerSpec::Sequential));
        q.push_back(job("b", SamplerSpec::Sequential));
        q.push_back(job("a", SamplerSpec::Sequential));
        q.push_back(job("a", SamplerSpec::Asd(4)));
        let item = next_work_item(&mut q, 8, true).unwrap();
        match item {
            WorkItem::SequentialGang(g) => {
                assert_eq!(g.len(), 2);
                assert!(g.iter().all(|j| j.request.variant == "a"));
            }
            _ => panic!("expected gang"),
        }
        // remaining: b sequential, a asd
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn asd_requests_stay_single() {
        let mut q: VecDeque<QueuedJob> = VecDeque::new();
        q.push_back(job("a", SamplerSpec::Asd(8)));
        q.push_back(job("a", SamplerSpec::Asd(8)));
        match next_work_item(&mut q, 8, true).unwrap() {
            WorkItem::Single(j) => assert_eq!(j.request.variant, "a"),
            _ => panic!("asd must not gang"),
        }
    }

    #[test]
    fn respects_max_batch() {
        let mut q: VecDeque<QueuedJob> = VecDeque::new();
        for _ in 0..10 {
            q.push_back(job("a", SamplerSpec::Sequential));
        }
        match next_work_item(&mut q, 4, true).unwrap() {
            WorkItem::SequentialGang(g) => assert_eq!(g.len(), 4),
            _ => panic!(),
        }
        assert_eq!(q.len(), 6);
    }

    #[test]
    fn batching_disabled_returns_single() {
        let mut q: VecDeque<QueuedJob> = VecDeque::new();
        q.push_back(job("a", SamplerSpec::Sequential));
        q.push_back(job("a", SamplerSpec::Sequential));
        assert!(matches!(next_work_item(&mut q, 8, false).unwrap(),
                         WorkItem::Single(_)));
    }

    #[test]
    fn empty_queue_none() {
        let mut q: VecDeque<QueuedJob> = VecDeque::new();
        assert!(next_work_item(&mut q, 8, true).is_none());
    }
}
