//! Dynamic batcher: forms *fusion groups* of queued requests.
//!
//! Since the samplers became poll-style state machines
//! (`sampler::StepSampler`), every request — ASD verify rounds, Picard
//! sweeps and lockstep sequential steps alike — expresses each parallel
//! round as a row demand, so any set of same-variant requests can share
//! one fused `denoise_batch` call per round. The batcher therefore no
//! longer special-cases sequential requests: it extracts the maximal
//! *compatible prefix* (same variant, any sampler) from the queue
//! front.
//!
//! Prefix extraction is order-stable by construction: jobs are only
//! ever popped from the front, so neither the served set nor the
//! remaining queue is ever reordered, and a request can never be
//! overtaken by a later arrival of a different variant (the seed's
//! mid-queue `VecDeque::remove` scan could invert service order across
//! variants, and paid O(n) per extraction). Requests for *other*
//! variants that are interleaved at the front simply start their own
//! group on the next worker.

use std::collections::VecDeque;

use crate::coordinator::request::QueuedJob;

/// A unit of worker execution.
pub(crate) enum WorkItem {
    /// one request, served by its closed `run()` driver (batching off)
    Single(QueuedJob),
    /// same-variant fusion group (any mix of samplers), arrival order;
    /// may grow mid-flight via continuous admission
    /// ([`take_compatible_prefix`])
    Fused(Vec<QueuedJob>),
}

/// Pop the next work item: the front job plus the maximal same-variant
/// prefix behind it (up to `max_batch` requests total). Caller holds
/// the queue lock.
pub(crate) fn next_work_item(queue: &mut VecDeque<QueuedJob>, max_batch: usize,
                             batching: bool) -> Option<WorkItem> {
    let first = queue.pop_front()?;
    if !batching || max_batch <= 1 {
        return Some(WorkItem::Single(first));
    }
    let variant = first.request.variant.clone();
    let mut group = vec![first];
    take_compatible_prefix(queue, &variant, max_batch - 1, &mut group);
    Some(WorkItem::Fused(group))
}

/// Move up to `max` jobs from the queue *front* into `out` while they
/// match `variant`. Order-stable: taken jobs keep arrival order and the
/// remaining queue is untouched beyond the popped prefix. Also the
/// continuous-admission primitive: a worker mid-group calls this each
/// tick to absorb newly arrived compatible requests. Returns how many
/// jobs were taken.
pub(crate) fn take_compatible_prefix(queue: &mut VecDeque<QueuedJob>,
                                     variant: &str, max: usize,
                                     out: &mut Vec<QueuedJob>) -> usize {
    let mut taken = 0usize;
    while taken < max
        && queue.front().is_some_and(|j| j.request.variant == variant)
    {
        out.push(queue.pop_front().unwrap());
        taken += 1;
    }
    taken
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Request, SamplerSpec};
    use std::sync::mpsc::channel;
    use std::time::Instant;

    fn job(variant: &str, sampler: SamplerSpec) -> QueuedJob {
        let (tx, _rx) = channel();
        // leak the receiver: these tests never reply
        std::mem::forget(_rx);
        QueuedJob {
            request: Request {
                id: 0,
                variant: variant.into(),
                sampler,
                seed: 0,
                cond: vec![],
            },
            reply: tx,
            enqueued: Instant::now(),
        }
    }

    #[test]
    fn fuses_same_variant_prefix_across_sampler_kinds() {
        let mut q: VecDeque<QueuedJob> = VecDeque::new();
        q.push_back(job("a", SamplerSpec::Asd(8)));
        q.push_back(job("a", SamplerSpec::Sequential));
        q.push_back(job("a", SamplerSpec::Picard(8, 1e-6)));
        q.push_back(job("b", SamplerSpec::Sequential));
        match next_work_item(&mut q, 8, true).unwrap() {
            WorkItem::Fused(g) => {
                assert_eq!(g.len(), 3);
                assert!(g.iter().all(|j| j.request.variant == "a"));
                // arrival order preserved inside the group
                assert!(matches!(g[0].request.sampler, SamplerSpec::Asd(8)));
                assert!(matches!(g[1].request.sampler,
                                 SamplerSpec::Sequential));
            }
            _ => panic!("expected fused group"),
        }
        assert_eq!(q.len(), 1);
        assert_eq!(q[0].request.variant, "b");
    }

    #[test]
    fn extraction_is_order_stable_across_variants() {
        // [a, a, b, a]: the group must stop at b — the trailing a is NOT
        // pulled over b's head (the seed's mid-queue scan did that,
        // letting late arrivals overtake b).
        let mut q: VecDeque<QueuedJob> = VecDeque::new();
        q.push_back(job("a", SamplerSpec::Sequential));
        q.push_back(job("a", SamplerSpec::Sequential));
        q.push_back(job("b", SamplerSpec::Sequential));
        q.push_back(job("a", SamplerSpec::Sequential));
        match next_work_item(&mut q, 8, true).unwrap() {
            WorkItem::Fused(g) => assert_eq!(g.len(), 2),
            _ => panic!("expected fused group"),
        }
        // remaining queue keeps arrival order: b then a
        assert_eq!(q.len(), 2);
        assert_eq!(q[0].request.variant, "b");
        assert_eq!(q[1].request.variant, "a");
    }

    #[test]
    fn respects_max_batch() {
        let mut q: VecDeque<QueuedJob> = VecDeque::new();
        for _ in 0..10 {
            q.push_back(job("a", SamplerSpec::Sequential));
        }
        match next_work_item(&mut q, 4, true).unwrap() {
            WorkItem::Fused(g) => assert_eq!(g.len(), 4),
            _ => panic!(),
        }
        assert_eq!(q.len(), 6);
    }

    #[test]
    fn single_job_forms_a_growable_group() {
        // a lone request still goes through the fused path, so
        // continuous admission can add later arrivals mid-flight
        let mut q: VecDeque<QueuedJob> = VecDeque::new();
        q.push_back(job("a", SamplerSpec::Sequential));
        match next_work_item(&mut q, 8, true).unwrap() {
            WorkItem::Fused(g) => assert_eq!(g.len(), 1),
            _ => panic!("expected fused group"),
        }
    }

    #[test]
    fn batching_disabled_returns_single() {
        let mut q: VecDeque<QueuedJob> = VecDeque::new();
        q.push_back(job("a", SamplerSpec::Sequential));
        q.push_back(job("a", SamplerSpec::Sequential));
        assert!(matches!(next_work_item(&mut q, 8, false).unwrap(),
                         WorkItem::Single(_)));
    }

    #[test]
    fn admission_takes_only_the_compatible_prefix() {
        let mut q: VecDeque<QueuedJob> = VecDeque::new();
        q.push_back(job("a", SamplerSpec::Sequential));
        q.push_back(job("a", SamplerSpec::Asd(4)));
        q.push_back(job("b", SamplerSpec::Sequential));
        q.push_back(job("a", SamplerSpec::Sequential));
        let mut out = Vec::new();
        assert_eq!(take_compatible_prefix(&mut q, "a", 8, &mut out), 2);
        assert_eq!(out.len(), 2);
        assert_eq!(q.len(), 2);
        // capped admission
        let mut out2 = Vec::new();
        assert_eq!(take_compatible_prefix(&mut q, "b", 0, &mut out2), 0);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn empty_queue_none() {
        let mut q: VecDeque<QueuedJob> = VecDeque::new();
        assert!(next_work_item(&mut q, 8, true).is_none());
    }
}
