//! L3 serving stack: request router + variant lanes + worker pool.
//!
//! Lane-scheduled, round-synchronous fused-batch engine: clients
//! submit sampling [`Request`]s into *variant-keyed* queues; each
//! registered variant is served by its own lane ([`lanes`]) holding
//! the variant's model snapshot and an arena-based fusion scheduler
//! ([`fusion::FusionScheduler`]). Workers claim busy lanes and drive
//! them together: every tick polls ALL held lanes — ASD, Picard and
//! sequential requests alike, factored as `sampler::StepSampler`
//! machines writing demands straight into the lane's `RoundArena` —
//! then co-schedules the per-lane fused `denoise_round` calls on the
//! one global pool, so a mixed-variant workload never suffers
//! cross-variant head-of-line blocking. Native-model outputs are
//! bit-identical to per-request execution (row independence; see
//! `model::parallel`). Metrics cover queueing, latency, per-sampler
//! round counts, fused-round occupancy, admission rejections, and
//! per-lane aggregates ([`metrics::LaneSnapshot`]).

pub(crate) mod fusion;
pub(crate) mod lanes;
pub mod metrics;
pub mod request;
pub mod server;

pub use metrics::{LaneSnapshot, Metrics, MetricsSnapshot};
pub use request::{Request, Response, SamplerSpec};
pub use server::{Coordinator, ServerConfig};
