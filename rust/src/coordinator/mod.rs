//! L3 serving stack: request router + variant lanes + worker pool.
//!
//! Lane-scheduled, continuously-fused batch engine: clients submit
//! sampling [`Request`]s into *variant-keyed* queues; each registered
//! variant is served by its own lane ([`lanes`]) holding the variant's
//! model snapshot and an arena-based fusion scheduler
//! ([`fusion::FusionScheduler`]). Workers claim busy lanes and drive
//! them as independent round tasks on the one global work-stealing
//! pool (`server::Driver`): a lane's fused `denoise_round` is
//! submitted the moment the lane stages rows — ASD, Picard and
//! sequential requests alike, factored as `sampler::StepSampler`
//! machines writing demands straight into the lane's `RoundArena` —
//! and re-submitted the moment it completes, with no global tick and
//! no barrier, so a mixed-variant workload never suffers cross-variant
//! head-of-line blocking and a straggler lane never stalls its
//! siblings. Native-model outputs are bit-identical to per-request
//! execution for every pool size and steal schedule (row independence;
//! see `model::parallel`). Metrics cover queueing, latency,
//! per-sampler round counts, fused-round occupancy, admission
//! rejections, per-lane aggregates ([`metrics::LaneSnapshot`]) and the
//! pool's scheduler counters ([`MetricsSnapshot::pool`]).

pub(crate) mod fusion;
pub(crate) mod lanes;
pub mod metrics;
pub mod request;
pub mod server;

pub use fusion::RecoveryPolicy;
pub use metrics::{LaneSnapshot, Metrics, MetricsSnapshot};
pub use request::{FailReason, Request, Response, SamplerSpec};
pub use server::{Coordinator, ServerConfig};
