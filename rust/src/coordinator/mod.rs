//! L3 serving stack: request router + dynamic batcher + worker pool.
//!
//! vLLM-router-shaped: clients submit sampling [`Request`]s; a shared
//! FIFO feeds a pool of worker threads; compatible *sequential* requests
//! to the same variant are ganged into lockstep batches (one batched
//! denoise call per step across requests), while ASD requests run
//! per-request (their control flow is adaptive) with batched
//! verification inside each request. Metrics cover queueing, latency and
//! per-sampler round counts.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod server;

pub use metrics::{Metrics, MetricsSnapshot};
pub use request::{Request, Response, SamplerSpec};
pub use server::{Coordinator, ServerConfig};
