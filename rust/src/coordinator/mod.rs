//! L3 serving stack: request router + fusion batcher + worker pool.
//!
//! Round-synchronous fused-batch engine: clients submit sampling
//! [`Request`]s; a bounded FIFO feeds a pool of worker threads; each
//! worker serves a same-variant *fusion group* — ASD, Picard and
//! sequential requests alike, factored as `sampler::StepSampler` state
//! machines — by collecting every in-flight request's row demand each
//! tick and running ONE fused `denoise_batch` mega-call per round
//! ([`fusion::FusionScheduler`]), absorbing newly queued compatible
//! requests mid-flight (continuous batching). Native-model outputs are
//! bit-identical to per-request execution (row independence; see
//! `model::parallel`). Metrics cover queueing, latency, per-sampler
//! round counts, fused-round occupancy and admission rejections.

pub mod batcher;
pub(crate) mod fusion;
pub mod metrics;
pub mod request;
pub mod server;

pub use metrics::{Metrics, MetricsSnapshot};
pub use request::{Request, Response, SamplerSpec};
pub use server::{Coordinator, ServerConfig};
