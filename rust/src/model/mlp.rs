//! Rust-native MLP denoiser forward pass.
//!
//! Bit-architecture mirror of python/compile/model.py operating on the
//! flat `weights_*.bin` buffer (layout: per layer, W row-major then b).
//! Two roles:
//! * parity oracle pinning the HLO execution path (tests compare both
//!   against golden.json forwards), and
//! * a fast in-process backend (`--backend native`) for experiments that
//!   need millions of cheap model calls.
//!
//! All math in f32 (matching the HLO) then widened to f64 at the edge.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::model::{DenoiseModel, VariantInfo};
use crate::schedule::DdpmSchedule;

pub const TEMB_DIM: usize = 32;

#[derive(Debug)]
pub struct NativeMlp {
    pub d: usize,
    pub cond_dim: usize,
    pub k_steps: usize,
    layers: Vec<Layer>,
    schedule: DdpmSchedule,
    /// precomputed sinusoidal frequencies
    freqs: Vec<f32>,
}

#[derive(Debug)]
struct Layer {
    n_in: usize,
    n_out: usize,
    w: Vec<f32>, // row-major (n_in, n_out)
    b: Vec<f32>,
}

impl NativeMlp {
    pub fn load(info: &VariantInfo, artifacts_dir: &Path) -> Result<Arc<NativeMlp>> {
        let path = artifacts_dir.join(&info.weights_file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() % 4 != 0 {
            bail!("weights file not a multiple of 4 bytes");
        }
        let flat: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Self::from_flat(info, &flat)
    }

    pub fn from_flat(info: &VariantInfo, flat: &[f32]) -> Result<Arc<NativeMlp>> {
        let mut layers = Vec::new();
        let mut off = 0usize;
        for &(n_in, n_out) in &info.weights_layout {
            let w_end = off + n_in * n_out;
            let b_end = w_end + n_out;
            if b_end > flat.len() {
                bail!("weights file too short: need {b_end}, have {}", flat.len());
            }
            layers.push(Layer {
                n_in,
                n_out,
                w: flat[off..w_end].to_vec(),
                b: flat[w_end..b_end].to_vec(),
            });
            off = b_end;
        }
        if off != flat.len() {
            bail!("weights file has {} trailing floats", flat.len() - off);
        }
        let half = TEMB_DIM / 2;
        let freqs = (0..half)
            .map(|j| (-(10000f32.ln()) * j as f32 / (half - 1) as f32).exp())
            .collect();
        Ok(Arc::new(NativeMlp {
            d: info.d,
            cond_dim: info.cond_dim,
            k_steps: info.k_steps,
            layers,
            schedule: info.schedule(),
            freqs,
        }))
    }

    /// Input layer width: d + TEMB_DIM + cond_dim.
    pub fn in_dim(&self) -> usize {
        self.d + TEMB_DIM + self.cond_dim
    }

    fn embed_time(&self, t: f32, out: &mut [f32]) {
        let half = TEMB_DIM / 2;
        let scaled = t / self.k_steps as f32 * 1000.0;
        for j in 0..half {
            let ang = scaled * self.freqs[j];
            out[j] = ang.sin();
            out[half + j] = ang.cos();
        }
    }

    /// Single forward in f32: input (in_dim), returns x0hat (d).
    fn forward_one(&self, input: &[f32], out: &mut [f32]) {
        debug_assert_eq!(input.len(), self.in_dim());
        // first layer + silu
        let l0 = &self.layers[0];
        let mut h = vec![0f32; l0.n_out];
        linear_silu(input, l0, &mut h);
        // residual hidden blocks
        let mut tmp = vec![0f32; l0.n_out];
        for layer in &self.layers[1..self.layers.len() - 1] {
            linear_silu(&h, layer, &mut tmp);
            for i in 0..h.len() {
                h[i] += tmp[i];
            }
        }
        // output layer, no activation
        let lo = self.layers.last().unwrap();
        debug_assert_eq!(out.len(), lo.n_out);
        linear(&h, lo, out);
    }
}

#[inline]
fn linear(x: &[f32], l: &Layer, out: &mut [f32]) {
    debug_assert_eq!(x.len(), l.n_in);
    out.copy_from_slice(&l.b);
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &l.w[i * l.n_out..(i + 1) * l.n_out];
        for (o, &wv) in out.iter_mut().zip(row) {
            *o += xi * wv;
        }
    }
}

#[inline]
fn linear_silu(x: &[f32], l: &Layer, out: &mut [f32]) {
    linear(x, l, out);
    for o in out.iter_mut() {
        *o = *o / (1.0 + (-*o).exp());
    }
}

impl DenoiseModel for NativeMlp {
    fn dim(&self) -> usize {
        self.d
    }

    fn cond_dim(&self) -> usize {
        self.cond_dim
    }

    fn k_steps(&self) -> usize {
        self.k_steps
    }

    fn schedule(&self) -> &DdpmSchedule {
        &self.schedule
    }

    fn denoise_batch(&self, ys: &[f64], ts: &[f64], cond: &[f64], n: usize,
                     out: &mut [f64]) -> Result<()> {
        let (d, c) = (self.d, self.cond_dim);
        debug_assert_eq!(ys.len(), n * d);
        debug_assert_eq!(cond.len(), n * c);
        let mut input = vec![0f32; self.in_dim()];
        let mut x0 = vec![0f32; d];
        for r in 0..n {
            for i in 0..d {
                input[i] = ys[r * d + i] as f32;
            }
            let (temb, rest) = input[d..].split_at_mut(TEMB_DIM);
            self.embed_time(ts[r] as f32, temb);
            for i in 0..c {
                rest[i] = cond[r * c + i] as f32;
            }
            self.forward_one(&input, &mut x0);
            for i in 0..d {
                out[r * d + i] = x0[i] as f64;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::TargetSpec;

    fn toy_info(d: usize, cond: usize, hidden: usize, layers: usize) -> VariantInfo {
        let mut dims = vec![(d + TEMB_DIM + cond, hidden)];
        for _ in 1..layers {
            dims.push((hidden, hidden));
        }
        dims.push((hidden, d));
        VariantInfo {
            name: "toy".into(),
            d,
            cond_dim: cond,
            hidden,
            layers,
            temb_dim: TEMB_DIM,
            k_steps: 10,
            train_loss: 0.0,
            artifacts: Default::default(),
            weights_file: String::new(),
            weights_layout: dims,
            abar: (1..=10).map(|i| 0.95f64.powi(i)).collect(),
            target: TargetSpec::Env { task: "x".into() },
            env: None,
        }
    }

    fn flat_len(info: &VariantInfo) -> usize {
        info.weights_layout.iter().map(|(a, b)| a * b + b).sum()
    }

    #[test]
    fn zero_weights_zero_output() {
        let info = toy_info(2, 0, 4, 2);
        let flat = vec![0f32; flat_len(&info)];
        let mlp = NativeMlp::from_flat(&info, &flat).unwrap();
        let mut out = vec![9.0; 2];
        mlp.denoise_one(&[1.0, 2.0], 5, &[], &mut out).unwrap();
        assert_eq!(out, vec![0.0, 0.0]);
    }

    #[test]
    fn batch_equals_loop() {
        let info = toy_info(3, 2, 8, 2);
        let n_w = flat_len(&info);
        let flat: Vec<f32> = (0..n_w).map(|i| ((i * 37 % 101) as f32 / 101.0) - 0.5).collect();
        let mlp = NativeMlp::from_flat(&info, &flat).unwrap();
        let ys = [0.1, -0.2, 0.3, 0.5, 0.6, -0.7];
        let ts = [3.0, 7.0];
        let cond = [1.0, 0.0, 0.0, 1.0];
        let mut batch = vec![0.0; 6];
        mlp.denoise_batch(&ys, &ts, &cond, 2, &mut batch).unwrap();
        for r in 0..2 {
            let mut one = vec![0.0; 3];
            mlp.denoise_batch(&ys[r * 3..(r + 1) * 3], &ts[r..r + 1],
                              &cond[r * 2..(r + 1) * 2], 1, &mut one)
                .unwrap();
            assert_eq!(&batch[r * 3..(r + 1) * 3], &one[..]);
        }
    }

    #[test]
    fn wrong_length_weights_rejected() {
        let info = toy_info(2, 0, 4, 1);
        assert!(NativeMlp::from_flat(&info, &vec![0f32; 3]).is_err());
        let too_many = vec![0f32; flat_len(&info) + 1];
        assert!(NativeMlp::from_flat(&info, &too_many).is_err());
    }

    #[test]
    fn time_embedding_range_and_distinct() {
        let info = toy_info(2, 0, 4, 1);
        let flat = vec![0f32; flat_len(&info)];
        let mlp = NativeMlp::from_flat(&info, &flat).unwrap();
        let mut e1 = vec![0f32; TEMB_DIM];
        let mut e2 = vec![0f32; TEMB_DIM];
        mlp.embed_time(1.0, &mut e1);
        mlp.embed_time(9.0, &mut e2);
        assert!(e1.iter().all(|v| v.abs() <= 1.0));
        let diff: f32 = e1.iter().zip(&e2).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 0.1);
    }
}
